//! Bench harness for the **figures**: Fig 2 (legacy BLAS on CPUs/GPU),
//! Figs 3–6 (DAG analysis), Fig 11(a)–(e) (enhancement metrics),
//! Fig 11(j) (Gflops/W comparison), Fig 12 (REDEFINE scaling).
//!
//! Run: `cargo bench --bench paper_figures`
//! Filter: `cargo bench --bench paper_figures -- fig2`

use redefine_blas::dag;
use redefine_blas::energy::PowerModel;
use redefine_blas::metrics::{measure_gemm, paper};
use redefine_blas::noc::parallel_dgemm;
use redefine_blas::pe::AeLevel;
use redefine_blas::platforms::{
    cpu::{model_dgemm, model_dgemv, CompilerSetup},
    db, CpuModel, GpuModel,
};
use redefine_blas::util::Mat;

fn main() {
    let filter = std::env::args().nth(1).unwrap_or_default();
    let run = |tag: &str| filter.is_empty() || tag.contains(&filter) || filter == "--bench";

    if run("fig2") {
        fig2();
    }
    if run("dags") {
        dags();
    }
    if run("fig11abcde") || run("fig11a") {
        fig11_metrics();
    }
    if run("fig11j") {
        fig11j();
    }
    if run("fig12") {
        fig12();
    }
}

/// Fig 2: CPI and Gflops of DGEMM under gcc/icc/icc+avx on Haswell and
/// Bulldozer; %peak and Gflops/W of DGEMM/DGEMV on CPU and C2050.
fn fig2() {
    let sizes = [100usize, 200, 400, 800, 1200, 1600, 2000];
    for cpu in [CpuModel::haswell(), CpuModel::bulldozer()] {
        println!("=== Fig 2(a-f): DGEMM on {} (model) ===", cpu.name);
        println!(
            "{:<8} {:>8} {:>8} {:>8} {:>9} {:>9} {:>9}",
            "n", "CPI/gcc", "CPI/icc", "CPI/avx", "GF/gcc", "GF/icc", "GF/avx"
        );
        for &n in &sizes {
            let g = model_dgemm(&cpu, n, CompilerSetup::Gcc);
            let i = model_dgemm(&cpu, n, CompilerSetup::Icc);
            let v = model_dgemm(&cpu, n, CompilerSetup::IccAvx);
            println!(
                "{:<8} {:>8.3} {:>8.3} {:>8.3} {:>9.2} {:>9.2} {:>9.2}",
                n,
                g.cpi(),
                i.cpi(),
                v.cpi(),
                g.gflops(&cpu),
                i.gflops(&cpu),
                v.gflops(&cpu)
            );
        }
        println!();
    }

    let hw = CpuModel::haswell();
    let gpu = GpuModel::c2050();
    let n = 2000;
    let mm = model_dgemm(&hw, n, CompilerSetup::IccAvx);
    let mv = model_dgemv(&hw, 4000, CompilerSetup::IccAvx);
    println!("=== Fig 2(g,h): % of theoretical peak ===");
    println!("CPU  DGEMM {:>5.1}%  (paper 15-17%)", mm.pct_peak(&hw));
    println!("CPU  DGEMV {:>5.1}%  (paper ~5%)", mv.pct_peak(&hw));
    println!("GPU  DGEMM {:>5.1}%  (paper 55-57%)", gpu.dgemm_pct_peak(4096));
    println!("GPU  DGEMV {:>5.1}%  (paper 4-5%)", gpu.dgemv_pct_peak(4096));
    println!();
    println!("=== Fig 2(i): Gflops/W of legacy BLAS ===");
    println!("CPU  DGEMM {:.3}  DGEMV {:.3}  (paper: 0.25 / 0.14)",
        mm.gflops_per_watt(&hw), mv.gflops_per_watt(&hw));
    println!("GPU  DGEMM {:.3}  DGEMV {:.3}  (paper fig: 0.225 / 0.03; see EXPERIMENTS.md note)",
        gpu.dgemm_gflops_per_watt(4096), gpu.dgemv_gflops_per_watt(4096));
    println!();
}

/// Figs 3–6 + Tables 2–3: DAG structure of the analysed routines.
fn dags() {
    println!("=== Figs 3-6: DAG analysis (§4) ===");
    println!(
        "{:<22} {:>6} {:>8} {:>10} {:>10}",
        "routine", "ops", "depth", "max width", "avg par"
    );
    let rows: Vec<(String, dag::Dag)> = vec![
        ("ddot n=8 (fig 3)".into(), dag::ddot_dag(8)),
        ("dnrm2 n=8 (fig 3)".into(), dag::dnrm2_dag(8)),
        ("daxpy n=8 (fig 3)".into(), dag::daxpy_dag(8)),
        ("dgemv n=4 (fig 4)".into(), dag::dgemv_dag(4)),
        ("GEMM 2x2 (fig 5)".into(), dag::gemm_block_dag(2)),
        ("SMM 2x2 (fig 5/T2)".into(), dag::smm_block_dag()),
        ("WMM 2x2 (fig 5/T3)".into(), dag::wmm_block_dag()),
        ("GEMM 4x4 (fig 6)".into(), dag::gemm_block_dag(4)),
    ];
    for (name, d) in rows {
        let p = d.profile();
        println!(
            "{:<22} {:>6} {:>8} {:>10} {:>10.2}",
            name, p.ops, p.critical_path, p.max_width, p.avg_parallelism
        );
    }
    println!();
}

/// Fig 11(a)–(e): latency reduction, α, CPF, FPC, %peak per enhancement.
fn fig11_metrics() {
    println!("=== Fig 11(a-e): enhancement metrics at each AE level ===");
    println!(
        "{:<22} {:>5} {:>10} {:>8} {:>8} {:>8} {:>9}",
        "level", "n", "cycles", "alpha", "CPF", "FPC", "%peakFPC"
    );
    let mut first = Vec::new();
    let mut last = Vec::new();
    for &ae in &AeLevel::ALL {
        for &n in &[20usize, 40, 60, 80, 100] {
            let m = measure_gemm(n, ae);
            if ae == AeLevel::Ae0 {
                first.push(m.latency());
            }
            if ae == AeLevel::Ae5 {
                last.push(m.latency());
            }
            println!(
                "{:<22} {:>5} {:>10} {:>8.3} {:>8.3} {:>8.3} {:>8.1}%",
                format!("{ae}"),
                n,
                m.latency(),
                m.alpha(),
                m.paper_cpf(),
                m.paper_fpc(),
                m.pct_peak_fpc()
            );
        }
    }
    println!("\nFig 11(a) headline AE0->AE5 speed-up (paper 7 / 8.13 / 8.34 at n=20/40/60):");
    for (i, &n) in [20usize, 40, 60, 80, 100].iter().enumerate() {
        println!("  n={n:<4} {:.2}x", first[i] as f64 / last[i] as f64);
    }
    println!();
}

/// Fig 11(j): PE Gflops/W vs the platform database.
fn fig11j() {
    // Measured PE efficiency at AE5, n=100 (paper-convention flops).
    let m = measure_gemm(100, AeLevel::Ae5);
    let pe_gw = m.gflops_per_watt();
    println!("=== Fig 11(j): Gflops/W comparison (PE measured at {pe_gw:.1}) ===");
    println!("{:<42} {:>9} {:>10}", "platform", "Gfl/W", "PE ratio");
    for p in db::platform_db() {
        println!(
            "{:<42} {:>9.3} {:>9.1}x",
            p.name,
            p.gflops_per_watt(),
            pe_gw / p.gflops_per_watt()
        );
    }
    println!("(paper: 3x vs CSX700, 10x vs FPGA, 7-139x vs GPUs, 40-140x vs CPUs)\n");
    let _ = PowerModel::paper(); // linked for doc discoverability
}

/// Fig 12: REDEFINE speed-up for 2×2 / 3×3 / 4×4 tile arrays.
fn fig12() {
    println!("=== Fig 12: REDEFINE speed-up over single PE ===");
    println!("{:<8} {:>9} {:>9} {:>9}", "n", "2x2", "3x3", "4x4");
    for n in [24usize, 48, 60, 96, 120] {
        let a = Mat::random(n, n, 501);
        let b = Mat::random(n, n, 502);
        let c = Mat::random(n, n, 503);
        print!("{n:<8}");
        for bb in [2usize, 3, 4] {
            let r = parallel_dgemm(n, bb, AeLevel::Ae5, &a, &b, &c);
            print!(" {:>8.2}x", r.speedup());
        }
        println!();
    }
    println!("(paper: approaches 4 / 9 / 16 as n grows)");
    let _ = paper::FIG11A_SPEEDUP;
}
