//! Bench harness for **Tables 4–9**: the DGEMM enhancement sweep.
//!
//! Prints, for every enhancement level and every paper size, the simulated
//! latency / CPF / Gflops-per-watt next to the paper's published cell, the
//! per-enhancement improvement percentages (the paper's actual claims), and
//! host wall-time per simulation (the harness's own cost).
//!
//! Run: `cargo bench --bench paper_tables`
//! Filter: `cargo bench --bench paper_tables -- table6`

use redefine_blas::metrics::paper;
use redefine_blas::metrics::{measure_gemm, measure_level1, measure_gemv, Routine};
use redefine_blas::pe::AeLevel;
use std::time::Instant;

fn main() {
    let filter = std::env::args().nth(1).unwrap_or_default();
    let run = |tag: &str| filter.is_empty() || tag.contains(&filter) || filter == "--bench";

    let mut measured = [[0u64; 5]; 6];
    let mut gw = [[0f64; 5]; 6];

    for (ai, &ae) in AeLevel::ALL.iter().enumerate() {
        let tag = format!("table{}", 4 + ai);
        if !run(&tag) && !run("fig11") && !run("improvements") {
            continue;
        }
        println!("=== Table {} — {} ===", 4 + ai, ae);
        println!(
            "{:<10} {:>12} {:>12} {:>7} {:>8} {:>9} {:>9} {:>9} {:>9}",
            "n", "cycles", "paper", "ratio", "CPF", "paperCPF", "Gfl/W", "paper", "host ms"
        );
        for (si, &n) in paper::SIZES.iter().enumerate() {
            let t0 = Instant::now();
            let m = measure_gemm(n, ae);
            let host_ms = t0.elapsed().as_secs_f64() * 1e3;
            measured[ai][si] = m.latency();
            gw[ai][si] = m.gflops_per_watt();
            println!(
                "{:<10} {:>12} {:>12} {:>7.3} {:>8.3} {:>9.3} {:>9.2} {:>9.2} {:>9.1}",
                format!("{n}x{n}"),
                m.latency(),
                paper::LATENCY[ai][si],
                m.latency() as f64 / paper::LATENCY[ai][si] as f64,
                m.paper_cpf(),
                paper::paper_cpf(ai, si),
                m.gflops_per_watt(),
                paper::GFLOPS_W[ai][si],
                host_ms
            );
        }
        println!();
    }

    if run("improvements") {
        println!("=== Per-enhancement improvement (the tables' 'Improvement' rows) ===");
        println!("{:<14} {:>12} {:>12}", "transition", "measured", "paper");
        for ai in 0..5 {
            for (si, &n) in paper::SIZES.iter().enumerate() {
                if measured[ai][si] == 0 || measured[ai + 1][si] == 0 {
                    continue;
                }
                let meas = 1.0 - measured[ai + 1][si] as f64 / measured[ai][si] as f64;
                println!(
                    "AE{}->AE{} n={:<4} {:>11.1}% {:>11.1}%",
                    ai,
                    ai + 1,
                    n,
                    100.0 * meas,
                    100.0 * paper::paper_improvement(ai, si)
                );
            }
        }
        println!();
    }

    if run("blas_levels") {
        println!("=== Abstract headline: %peak-FPC at AE5 (paper-convention flops) ===");
        let mm = measure_gemm(100, AeLevel::Ae5);
        let mv = measure_gemv(100, AeLevel::Ae5);
        let dd = measure_level1(Routine::Ddot, 1024, AeLevel::Ae5);
        println!(
            "DGEMM  measured {:>5.1}%   paper {:>5.1}%",
            mm.pct_peak_fpc(),
            100.0 * paper::PCT_PEAK_DGEMM
        );
        println!(
            "DGEMV  measured {:>5.1}%   paper {:>5.1}%",
            mv.pct_peak_fpc(),
            100.0 * paper::PCT_PEAK_DGEMV
        );
        println!(
            "DDOT   measured {:>5.1}%   paper {:>5.1}%",
            dd.pct_peak_fpc(),
            100.0 * paper::PCT_PEAK_DDOT
        );
    }
}
