//! Ablation benches for the design choices DESIGN.md calls out.
//!
//! 1. `residual`   — DOT2/3 residual handling vs zero-padding (§5.2.1's
//!                    reconfigurable RDP widths).
//! 2. `gm_latency` — GM pipeline-depth sensitivity per AE level (how much
//!                    the LS CFU + pre-fetch decouple the PE from memory).
//! 3. `lm_port`    — LM port cost sensitivity (why AE4's wide path pays).
//! 4. `lsq`        — LS queue depth at AE1 (decoupling head-room).
//! 5. `optimizer`  — peephole wide-load fusion: AE3-shaped code on AE4.
//! 6. `noc`        — router/link cycle sensitivity of the Fig-12 speed-up.
//!
//! Run: `cargo bench --bench ablations [-- <tag>]`

use redefine_blas::codegen::{gen_gemm, gen_gemm_any, optimize, GemmLayout};
use redefine_blas::noc::{parallel_dgemm_cfg, RouterConfig};
use redefine_blas::pe::{AeLevel, Pe, PeConfig};
use redefine_blas::util::Mat;

fn run_with_cfg(n: usize, cfg: PeConfig) -> u64 {
    let layout = GemmLayout::packed(n);
    let prog = gen_gemm(n, cfg.ae, &layout);
    let a = Mat::random(n, n, 1);
    let b = Mat::random(n, n, 2);
    let c = Mat::random(n, n, 3);
    let mut pe = Pe::new(cfg, layout.gm_words());
    pe.write_gm(0, &layout.pack(&a, &b, &c));
    pe.run(&prog).cycles
}

fn main() {
    let filter = std::env::args().nth(1).unwrap_or_default();
    let run = |tag: &str| filter.is_empty() || tag.contains(&filter) || filter == "--bench";

    if run("residual") {
        println!("=== Ablation: DOT2/3 residual vs zero-padding (AE3 and AE5) ===");
        println!("{:<6} {:>10} {:>10} {:>10} {:>10}", "n", "resid@AE3", "pad@AE3", "resid@AE5", "pad@AE5");
        for n in [13usize, 17, 21, 29, 37] {
            let pad_n = n.div_ceil(4) * 4;
            let mut row = format!("{n:<6}");
            for ae in [AeLevel::Ae3, AeLevel::Ae5] {
                let l = GemmLayout { m: n, p: n, k: n, base_a: 0, base_b: n * n, base_c: 2 * n * n };
                let prog = gen_gemm_any(n, ae, &l);
                let a = Mat::random(n, n, 1);
                let b = Mat::random(n, n, 2);
                let c = Mat::random(n, n, 3);
                let mut pe = Pe::new(PeConfig::paper(ae), 3 * n * n);
                pe.write_gm(0, &l.pack(&a, &b, &c));
                let resid = pe.run(&prog).cycles;
                let padded = run_with_cfg(pad_n, PeConfig::paper(ae));
                row.push_str(&format!(" {resid:>10} {padded:>10}"));
            }
            println!("{row}");
        }
        println!("(padding wins once AE5's software pipelining exists — the aligned kernel");
        println!(" is better scheduled than mixed-width DOTs, despite up to 40% extra macs)\n");
    }

    if run("gm_latency") {
        println!("=== Ablation: GM pipeline depth sensitivity (n=40) ===");
        println!("{:<12} {:>10} {:>10} {:>10}", "gm_latency", "AE0", "AE2", "AE5");
        for lat in [5u32, 10, 20, 40, 80] {
            let mut row = format!("{lat:<12}");
            for ae in [AeLevel::Ae0, AeLevel::Ae2, AeLevel::Ae5] {
                let mut cfg = PeConfig::paper(ae);
                cfg.gm_latency = lat;
                row.push_str(&format!(" {:>10}", run_with_cfg(40, cfg)));
            }
            println!("{row}");
        }
        println!("(AE0 scales with latency; AE5 is nearly flat — the CFU + pre-fetch decouple)\n");
    }

    if run("lm_port") {
        println!("=== Ablation: LM scalar-port cost (n=40, AE2) ===");
        for cost in [1u32, 2, 3, 4] {
            let mut cfg = PeConfig::paper(AeLevel::Ae2);
            cfg.lm_word_cycles = cost;
            println!("lm_word_cycles={cost}: {} cycles", run_with_cfg(40, cfg));
        }
        println!("(the scalar port is the AE2/AE3 bottleneck — motivation for AE4)\n");
    }

    if run("lsq") {
        println!("=== Ablation: LS queue depth (n=40, AE1) ===");
        for depth in [1usize, 2, 4, 8, 16, 32] {
            let mut cfg = PeConfig::paper(AeLevel::Ae1);
            cfg.lsq_depth = depth;
            println!("lsq_depth={depth:<3}: {} cycles", run_with_cfg(40, cfg));
        }
        println!();
    }

    if run("optimizer") {
        println!("=== Ablation: peephole wide-load fusion (AE3 stream on AE4 hardware) ===");
        for n in [16usize, 40, 80] {
            let layout = GemmLayout::packed(n);
            let prog = gen_gemm(n, AeLevel::Ae3, &layout);
            let (fused, rep) = optimize(&prog, AeLevel::Ae4);
            let a = Mat::random(n, n, 1);
            let b = Mat::random(n, n, 2);
            let c = Mat::random(n, n, 3);
            let gm = layout.pack(&a, &b, &c);
            let mut pe1 = Pe::new(PeConfig::paper(AeLevel::Ae4), layout.gm_words());
            pe1.write_gm(0, &gm);
            let raw = pe1.run(&prog).cycles;
            let mut pe2 = Pe::new(PeConfig::paper(AeLevel::Ae4), layout.gm_words());
            pe2.write_gm(0, &gm);
            let opt = pe2.run(&fused).cycles;
            println!(
                "n={n:<4} raw={raw:<9} fused={opt:<9} (-{:.1}%)  [{} loads fused, {} instrs -> {}]",
                100.0 * (1.0 - opt as f64 / raw as f64),
                rep.loads_combined,
                rep.before,
                rep.after
            );
        }
        println!();
    }

    if run("noc") {
        println!("=== Ablation: NoC link/router cycle cost (n=96, 3x3 array) ===");
        let n = 96;
        let a = Mat::random(n, n, 1);
        let b = Mat::random(n, n, 2);
        let c = Mat::random(n, n, 3);
        for (rc, lc) in [(1u64, 1u64), (1, 2), (2, 2), (4, 4)] {
            let rcfg = RouterConfig { router_cycle: rc, link_cycle: lc, mem_service_cycle: 1 };
            let r = parallel_dgemm_cfg(n, 3, AeLevel::Ae5, &a, &b, &c, &rcfg);
            println!(
                "router={rc} link={lc}: speedup {:.2}x (makespan {})",
                r.speedup(),
                r.makespan
            );
        }
        println!("(Fig-12 saturation point moves with link bandwidth, as §5.5 argues)");
    }
}
