//! Host-performance bench of the system's own hot paths (deliverable (e)):
//! the PE cycle-loop throughput, codegen emission rate, coordinator
//! serve throughput, and host BLAS. These are the numbers the §Perf pass in
//! EXPERIMENTS.md optimizes — the simulator must be fast enough that a full
//! enhancement sweep is interactive.
//!
//! Run: `cargo bench --bench hot_paths`

use redefine_blas::codegen::{gen_gemm, GemmLayout};
use redefine_blas::coordinator::{request::random_workload, Coordinator, CoordinatorConfig};
use redefine_blas::metrics::measure_gemm;
use redefine_blas::pe::{AeLevel, Pe, PeConfig};
use redefine_blas::util::Mat;
use std::time::Instant;

fn timeit<F: FnMut()>(name: &str, iters: usize, mut f: F) -> f64 {
    // Warm-up.
    f();
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    println!("{name:<44} {:>10.3} ms/iter", per * 1e3);
    per
}

fn main() {
    println!("host hot-path benchmarks (release)\n");

    // 1) PE simulator throughput: simulated cycles per host second.
    let n = 100;
    let layout = GemmLayout::packed(n);
    let prog = gen_gemm(n, AeLevel::Ae5, &layout);
    let a = Mat::random(n, n, 1);
    let b = Mat::random(n, n, 2);
    let c = Mat::random(n, n, 3);
    let gm = layout.pack(&a, &b, &c);
    let mut cycles = 0u64;
    let per = timeit("PE sim: DGEMM n=100 AE5 (full run)", 5, || {
        let mut pe = Pe::new(PeConfig::paper(AeLevel::Ae5), layout.gm_words());
        pe.write_gm(0, &gm);
        cycles = pe.run(&prog).cycles;
    });
    println!(
        "{:<44} {:>10.1} Msimcycles/s  ({} instrs -> {} cycles)",
        "  throughput",
        cycles as f64 / per / 1e6,
        prog.len(),
        cycles
    );

    // 2) Codegen emission rate.
    timeit("codegen: gen_gemm n=100 AE5", 10, || {
        let p = gen_gemm(n, AeLevel::Ae5, &layout);
        assert!(!p.is_empty());
    });

    // 3) Full measurement (codegen + sim + numeric check).
    timeit("measure_gemm n=60 AE5 (incl. host check)", 5, || {
        let m = measure_gemm(60, AeLevel::Ae5);
        assert!(m.latency() > 0);
    });

    // 4) Full AE0..AE5 sweep at n=40 (the table harness inner loop).
    timeit("AE0..AE5 sweep n=40", 3, || {
        for ae in AeLevel::ALL {
            let _ = measure_gemm(40, ae);
        }
    });

    // 5) Coordinator serve throughput (multi-threaded tiles).
    timeit("coordinator: 8-request mixed workload", 3, || {
        let mut co = Coordinator::new(CoordinatorConfig {
            ae: AeLevel::Ae5,
            b: 2,
            artifact_dir: "/nonexistent".into(),
            verify: false,
        });
        let resps = co.serve(random_workload(8, 48, 7));
        assert_eq!(resps.len(), 8);
    });

    // 6) Host reference BLAS (oracle cost).
    let big = Mat::random(192, 192, 9);
    timeit("host dgemm_ref 192x192", 5, || {
        let r = redefine_blas::blas::level3::dgemm_ref(&big, &big, &big);
        assert!(r.rows() == 192);
    });
}
