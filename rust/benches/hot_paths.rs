//! Host-performance bench of the system's own hot paths (deliverable (e)):
//! the PE cycle-loop throughput, codegen emission rate, coordinator
//! serve throughput, and host BLAS. These are the numbers the §Perf pass in
//! EXPERIMENTS.md optimizes — the simulator must be fast enough that a full
//! enhancement sweep is interactive.
//!
//! Run: `cargo bench --bench hot_paths`
//!
//! Flags (after `--`):
//! * `--quick`     — smaller sizes / fewer iterations (CI smoke mode);
//! * `--json PATH` — also write every measurement to PATH as JSON (the
//!   `BENCH_hot_paths.json` workflow artifact that tracks the perf
//!   trajectory commit by commit).

use redefine_blas::codegen::{gen_gemm, gen_gemm_rect, GemmLayout};
use redefine_blas::coordinator::{
    request::{factor_workload, random_workload, repeated_gemm_workload, Request},
    Coordinator, CoordinatorConfig, OpenLoopOptions,
};
use redefine_blas::engine::traffic::{self, Arrival, TrafficConfig};
use redefine_blas::engine::{Engine, EngineConfig, SchedPolicy};
use redefine_blas::lapack::FactorKind;
use redefine_blas::metrics::{measure_gemm, Routine};
use redefine_blas::obs::{BufferSink, EventKind, NullSink, TraceSink};
use redefine_blas::pe::{AeLevel, ExecMode, Pe, PeConfig, ScheduledProgram};
use redefine_blas::util::{json, rel_fro_error, round_up, Mat};
use std::sync::Arc;
use std::time::Instant;

/// Collected (name, milliseconds-per-iteration) measurements, written out
/// as the JSON artifact at the end of the run.
struct Report {
    quick: bool,
    entries: Vec<(String, f64)>,
}

impl Report {
    fn record(&mut self, name: &str, ms_per_iter: f64) {
        self.entries.push((name.to_string(), ms_per_iter));
    }

    /// Hand-rolled JSON (the crate is dependency-free by design).
    fn to_json(&self) -> String {
        let mut s = String::from("{\n  \"bench\": \"hot_paths\",\n");
        s.push_str(&format!("  \"quick\": {},\n  \"results\": [\n", self.quick));
        for (i, (name, ms)) in self.entries.iter().enumerate() {
            let esc = json::escape(name);
            let comma = if i + 1 < self.entries.len() { "," } else { "" };
            s.push_str(&format!("    {{\"name\": \"{esc}\", \"ms_per_iter\": {ms:.6}}}{comma}\n"));
        }
        s.push_str("  ]\n}\n");
        s
    }
}

fn timeit<F: FnMut()>(report: &mut Report, name: &str, iters: usize, mut f: F) -> f64 {
    // Warm-up.
    f();
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    println!("{name:<44} {:>10.3} ms/iter", per * 1e3);
    report.record(name, per * 1e3);
    per
}

fn main() {
    let mut quick = false;
    let mut json_path: Option<String> = None;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--quick" => quick = true,
            "--json" => json_path = args.next(),
            other => eprintln!("ignoring unknown bench flag {other:?}"),
        }
    }
    let mut report = Report { quick, entries: Vec::new() };
    let mode = if quick { " (quick mode)" } else { "" };
    println!("host hot-path benchmarks (release){mode}\n");

    // 1) PE simulator throughput: simulated cycles per host second.
    let n = if quick { 32 } else { 100 };
    let iters = if quick { 2 } else { 5 };
    let layout = GemmLayout::packed(n);
    let prog = gen_gemm(n, AeLevel::Ae5, &layout);
    let a = Mat::random(n, n, 1);
    let b = Mat::random(n, n, 2);
    let c = Mat::random(n, n, 3);
    let gm = layout.pack(&a, &b, &c);
    let mut cycles = 0u64;
    let per = timeit(&mut report, &format!("PE sim: DGEMM n={n} AE5 (full run)"), iters, || {
        let mut pe = Pe::new(PeConfig::paper(AeLevel::Ae5), layout.gm_words());
        pe.write_gm(0, &gm);
        cycles = pe.run(&prog).cycles;
    });
    println!(
        "{:<44} {:>10.1} Msimcycles/s  ({} instrs -> {} cycles)",
        "  throughput",
        cycles as f64 / per / 1e6,
        prog.len(),
        cycles
    );

    // 1b) Two-tier split on the same kernel: decode once, then compare the
    //     combined (value + timing) interpreter against the tier-2
    //     value-only replay over the pre-decoded stream.
    let sched = ScheduledProgram::compile(&prog, AeLevel::Ae5).expect("gemm kernel decodes");
    let mut pe = Pe::new(PeConfig::paper(AeLevel::Ae5), layout.gm_words());
    pe.write_gm(0, &gm);
    let _ = sched.execute(&mut pe, ExecMode::Replay); // runs + memoizes the timing pass
    let t_combined =
        timeit(&mut report, &format!("PE tier1: combined interp n={n}"), iters, || {
            pe.reset(layout.gm_words());
            pe.write_gm(0, &gm);
            let st = pe.run_decoded(sched.decoded());
            assert_eq!(Some(&st), sched.scheduled_stats(), "timing pass must be reproducible");
        });
    let t_replay = timeit(&mut report, &format!("PE tier2: value replay n={n}"), iters, || {
        pe.reset(layout.gm_words());
        pe.write_gm(0, &gm);
        let st = sched.execute(&mut pe, ExecMode::Replay);
        assert!(st.cycles > 0);
    });
    println!(
        "{:<44} {:>10.2}x  ({} packed bytes vs {} enum bytes)",
        "  replay speedup over combined",
        t_combined / t_replay,
        sched.decoded().packed_bytes(),
        prog.len() * std::mem::size_of::<redefine_blas::pe::Instr>()
    );
    report.record("pe.replay_speedup_x", t_combined / t_replay);

    // 2) Codegen emission rate.
    timeit(&mut report, &format!("codegen: gen_gemm n={n} AE5"), if quick { 3 } else { 10 }, || {
        let p = gen_gemm(n, AeLevel::Ae5, &layout);
        assert!(!p.is_empty());
    });

    // 3) Full measurement (codegen + sim + numeric check).
    let mn = if quick { 20 } else { 60 };
    let miters = if quick { 2 } else { 5 };
    timeit(&mut report, &format!("measure_gemm n={mn} AE5 (incl. host check)"), miters, || {
        let m = measure_gemm(mn, AeLevel::Ae5);
        assert!(m.latency() > 0);
    });

    // 4) Full AE0..AE5 sweep (the table harness inner loop).
    let sn = if quick { 16 } else { 40 };
    timeit(&mut report, &format!("AE0..AE5 sweep n={sn}"), if quick { 1 } else { 3 }, || {
        for ae in AeLevel::ALL {
            let _ = measure_gemm(sn, ae);
        }
    });

    // 5) Coordinator serve throughput (multi-threaded pool, all levels).
    let (wreqs, wmax) = if quick { (6, 24) } else { (8, 48) };
    timeit(&mut report, &format!("coordinator: {wreqs}-request mixed workload"), 3, || {
        let mut co = Coordinator::new(CoordinatorConfig {
            ae: AeLevel::Ae5,
            b: 2,
            artifact_dir: "/nonexistent".into(),
            verify: false,
            ..CoordinatorConfig::default()
        });
        let resps = co.serve(random_workload(wreqs, wmax, 7));
        assert_eq!(resps.len(), wreqs);
    });

    // 6) Host reference BLAS (oracle cost).
    let hn = if quick { 96 } else { 192 };
    let big = Mat::random(hn, hn, 9);
    timeit(&mut report, &format!("host dgemm_ref {hn}x{hn}"), if quick { 2 } else { 5 }, || {
        let r = redefine_blas::blas::level3::dgemm_ref(&big, &big, &big);
        assert!(r.rows() == hn);
    });

    // 7) Serving engine: repeated-shape DGEMM workload — warm program
    //    cache + persistent pool (serve_batch) vs the seed-style
    //    per-request codegen + thread-spawn path. Values must be identical;
    //    wall-clock is the cached-vs-uncached headline recorded in
    //    CHANGES.md.
    if quick {
        serving_engine_bench(&mut report, 16, 16, 2, AeLevel::Ae5);
    } else {
        serving_engine_bench(&mut report, 64, 32, 2, AeLevel::Ae5);
    }

    // 8) Two-tier execution on the serve path: the repeated-shape DGEMM
    //    workload again, but comparing cache-hit **value replay** (the
    //    default ExecMode::Replay) against the **combined interpreter**
    //    forced on every kernel (ExecMode::Combined). Both run warm caches
    //    on the same pool — the delta is purely tier 2 vs tier 1 per job.
    if quick {
        replay_vs_combined_bench(&mut report, 16, 16, 2, AeLevel::Ae5);
    } else {
        replay_vs_combined_bench(&mut report, 64, 32, 2, AeLevel::Ae5);
    }

    // 8b) Tier-2b ablation: the same warm repeated-shape workload served
    //     with the coordinator coalescing same-kernel tiles into fused
    //     replay-batch jobs, swept over batch caps N in {1, 4, 16, 64}
    //     against the per-tile single-replay baseline. Batching must be
    //     invisible in every simulated observable — values, cycles,
    //     energy — and only move host wall-clock.
    if quick {
        replay_batch_bench(&mut report, 16, 16, 2, AeLevel::Ae5);
    } else {
        replay_batch_bench(&mut report, 64, 32, 2, AeLevel::Ae5);
    }

    // 9) Multi-tenant engine: two tenants serving the same repeated shape
    //    through one shared pool + shared program cache, vs two isolated
    //    coordinators. The shared cache's cross-tenant hits are the PR 4
    //    acceptance signal; the wall-clock ratio is the engine headline.
    if quick {
        multi_tenant_bench(&mut report, 8, 16, AeLevel::Ae5);
    } else {
        multi_tenant_bench(&mut report, 32, 32, AeLevel::Ae5);
    }

    // 10) Residual vs padded serving for a non-4-aligned shape: the
    //     cached DOT2/3 residual kernel (no padding) against the cached
    //     padded tile kernel, end to end through serve_batch.
    if quick {
        residual_vs_padded_bench(&mut report, 4, 18, AeLevel::Ae5);
    } else {
        residual_vs_padded_bench(&mut report, 8, 30, AeLevel::Ae5);
    }

    // 11) Scheduler fairness: cycle-cost DRR vs the slot-WRR baseline
    //     under deliberately mismatched kernel costs — a heavy DGEMM
    //     flood against a weight-3 DDOT tenant on one worker. Asserts the
    //     proportional-cycle-service ordering and records the ratios.
    if quick {
        drr_fairness_bench(&mut report, 16, 16, 96, AeLevel::Ae5);
    } else {
        drr_fairness_bench(&mut report, 24, 24, 128, AeLevel::Ae5);
    }

    // 12) Open-loop serving: a latency trajectory instead of a throughput
    //     point. A heavy DGEMM tenant is offered Poisson load at a
    //     multiple of the engine's measured closed-loop capacity while a
    //     weight-3 light DDOT tenant runs alongside; the light tenant's
    //     p99 total latency per (scheduler, load) is the recorded curve —
    //     cycle-cost DRR is supposed to keep the light tail flat where
    //     slot-WRR lets the flood push it out.
    open_loop_bench(&mut report, quick, AeLevel::Ae5);

    // 13) Fabric scaling: the same DGEMM workload served on NoC-modeled
    //     fabrics of order b = 1..4 under both placement policies and
    //     both schedulers — the serving-side analogue of the paper's
    //     §5.5 scalability curve. Records makespan / speedup /
    //     compute-comm ratio / max-link-busy per point and asserts the
    //     makespan improves monotonically with fabric order.
    fabric_scaling_bench(&mut report, quick, AeLevel::Ae5);

    // 14) Observability overhead: the warm repeated-shape DGEMM serve with
    //     no trace sink (the default), with the event-dropping NullSink,
    //     and with the buffering BufferSink. All three must produce
    //     identical simulated observables; the sink-off run is asserted to
    //     cost the same as the pre-obs serve path (loose band — host
    //     timing), and the buffered capture's overhead is recorded.
    if quick {
        obs_overhead_bench(&mut report, 16, 16, 2, AeLevel::Ae5);
    } else {
        obs_overhead_bench(&mut report, 64, 32, 2, AeLevel::Ae5);
    }

    // 15) LAPACK factorization DAG serving: QR / LU / Cholesky requests
    //     expanded into dependent kernel DAGs through the same pool. Per
    //     kind the `lapack.*` keys record the DAG critical path against
    //     the serial sum of its node kernels (the dependency-overlap
    //     headline) and the program-cache hit rate across repeated
    //     factorizations (every node is a cache customer, so repeats must
    //     be all-hit).
    lapack_bench(&mut report, quick, AeLevel::Ae5);

    if let Some(path) = json_path {
        std::fs::write(&path, report.to_json()).expect("write bench JSON");
        println!("\nwrote {} measurements to {path}", report.entries.len());
    }
}

/// The pre-serving-engine DGEMM path, kept verbatim as the bench baseline:
/// every request re-emits the tile program inside freshly spawned tile
/// threads and allocates a fresh PE per tile. Returns the assembled C.
fn seed_style_dgemm(a: &Mat, b: &Mat, c: &Mat, ae: AeLevel, bb: usize) -> Mat {
    let n = a.rows();
    let np = round_up(n, 4 * bb);
    let (ap, bp, cp) = (a.padded(np, np), b.padded(np, np), c.padded(np, np));
    let m = np / bb;
    let (tx, rx) = std::sync::mpsc::channel();
    std::thread::scope(|s| {
        for bi in 0..bb {
            for bj in 0..bb {
                let tx = tx.clone();
                let a_blk = ap.block(bi * m, 0, m, np);
                let b_blk = bp.block(0, bj * m, np, m);
                let c_blk = cp.block(bi * m, bj * m, m, m);
                s.spawn(move || {
                    let layout = GemmLayout::rect(m, m, np);
                    let prog = gen_gemm_rect(m, m, np, ae, &layout);
                    let mut pe = Pe::new(PeConfig::paper(ae), layout.gm_words());
                    pe.write_gm(0, &layout.pack(&a_blk, &b_blk, &c_blk));
                    pe.run(&prog);
                    let out = layout.unpack_c(&pe.gm, m, m);
                    tx.send((bi, bj, out)).expect("leader hung up");
                });
            }
        }
        drop(tx);
    });
    let mut cpad = cp.clone();
    for (bi, bj, out) in rx {
        cpad.set_block(bi * m, bj * m, &out);
    }
    cpad.block(0, 0, n, n)
}

fn serving_engine_bench(report: &mut Report, requests: usize, n: usize, b: usize, ae: AeLevel) {
    println!("\nserving engine: {requests} DGEMM requests, n={n}, {b}x{b} tiles, {ae}");
    let mk_coord = || {
        Coordinator::new(CoordinatorConfig {
            ae,
            b,
            artifact_dir: "/nonexistent".into(),
            verify: false,
            ..CoordinatorConfig::default()
        })
    };

    // Operands are materialized once, outside both timed regions, and both
    // paths consume the same concrete Dgemm requests — the comparison times
    // only codegen + simulation + dispatch.
    let materialized: Vec<(Mat, Mat, Mat)> = repeated_gemm_workload(requests, n, 4242)
        .into_iter()
        .map(|r| match r.materialize() {
            Request::Dgemm { a, b, c } => (a, b, c),
            _ => unreachable!(),
        })
        .collect();
    let concrete: Vec<Request> = materialized
        .iter()
        .map(|(a, bm, c)| Request::Dgemm { a: a.clone(), b: bm.clone(), c: c.clone() })
        .collect();

    // Baseline: per-request codegen + spawn, strictly sequential requests.
    let t0 = Instant::now();
    let baseline: Vec<Mat> =
        materialized.iter().map(|(a, bm, c)| seed_style_dgemm(a, bm, c, ae, b)).collect();
    let t_seed = t0.elapsed().as_secs_f64();

    // Serving engine: warm the program cache, then time the batch.
    let mut co = mk_coord();
    let _ = co.serve_batch(repeated_gemm_workload(1, n, 1));
    let t0 = Instant::now();
    let resps = co.serve_batch(concrete);
    let t_batch = t0.elapsed().as_secs_f64();

    // Identical numeric results, request by request.
    assert_eq!(resps.len(), baseline.len());
    for (r, want) in resps.iter().zip(&baseline) {
        let got = r.matrix.as_ref().expect("dgemm response carries a matrix");
        assert_eq!(got, want, "serving engine values diverged from baseline");
    }
    let cs = co.cache_stats();
    println!(
        "{:<44} {:>10.3} ms total  ({:.1} req/s)",
        "  seed-style: per-request codegen + spawn",
        t_seed * 1e3,
        requests as f64 / t_seed
    );
    println!(
        "{:<44} {:>10.3} ms total  ({:.1} req/s)",
        "  serve_batch: warm cache + worker pool",
        t_batch * 1e3,
        requests as f64 / t_batch
    );
    println!(
        "{:<44} {:>10.2}x  (cache: {} kernels, {} hits / {} misses)",
        "  throughput speedup",
        t_seed / t_batch,
        cs.entries,
        cs.hits,
        cs.misses
    );
    report.record("serve.seed_style_total_ms", t_seed * 1e3);
    report.record("serve.batch_total_ms", t_batch * 1e3);
    report.record("serve.speedup_x", t_seed / t_batch);
}

/// Serve the repeated-shape DGEMM workload twice over warm caches: once
/// with every kernel re-running the combined (value + timing) interpreter,
/// once on the default cache-hit value-replay path. Responses must be
/// identical (values, cycles, energy); the wall-clock ratio is the
/// two-tier engine's serve-path headline.
fn replay_vs_combined_bench(report: &mut Report, requests: usize, n: usize, b: usize, ae: AeLevel) {
    println!(
        "\ntwo-tier serve: {requests} repeated-shape DGEMM requests, n={n}, {b}x{b} tiles, {ae}"
    );
    let mk_coord = |exec: ExecMode| {
        Coordinator::new(CoordinatorConfig {
            ae,
            b,
            artifact_dir: "/nonexistent".into(),
            verify: false,
            exec,
            ..CoordinatorConfig::default()
        })
    };
    let reqs = repeated_gemm_workload(requests, n, 9090);

    // Warm both coordinators: one request emits, decodes and (for the
    // replay coordinator) schedules the kernel, so the timed regions see
    // cache hits only.
    let mut combined = mk_coord(ExecMode::Combined);
    let mut replay = mk_coord(ExecMode::Replay);
    let _ = combined.serve_batch(repeated_gemm_workload(1, n, 1));
    let _ = replay.serve_batch(repeated_gemm_workload(1, n, 1));

    let t0 = Instant::now();
    let r_combined = combined.serve_batch(reqs.clone());
    let t_combined = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let r_replay = replay.serve_batch(reqs);
    let t_replay = t0.elapsed().as_secs_f64();

    // Replay must change nothing but the wall-clock: identical values,
    // identical simulated cycles and energy, request by request.
    assert_eq!(r_combined.len(), r_replay.len());
    for (c, r) in r_combined.iter().zip(&r_replay) {
        assert_eq!(c.cycles, r.cycles, "replay changed simulated cycles");
        assert_eq!(c.energy_j, r.energy_j, "replay changed simulated energy");
        assert_eq!(c.matrix, r.matrix, "replay changed values");
    }
    let jc = replay.pool_job_counts();
    println!(
        "{:<44} {:>10.3} ms total  ({:.1} req/s)",
        "  combined interpreter per kernel",
        t_combined * 1e3,
        requests as f64 / t_combined
    );
    println!(
        "{:<44} {:>10.3} ms total  ({:.1} req/s)",
        "  cache-hit value replay",
        t_replay * 1e3,
        requests as f64 / t_replay
    );
    println!(
        "{:<44} {:>10.2}x  ({} replayed / {} combined kernels on the replay pool)",
        "  replay throughput speedup",
        t_combined / t_replay,
        jc.replays,
        jc.combined_runs
    );
    report.record("serve.combined_exec_total_ms", t_combined * 1e3);
    report.record("serve.replay_exec_total_ms", t_replay * 1e3);
    report.record("serve.replay_speedup_x", t_combined / t_replay);
}

/// Tier-2b replay-batching ablation on the serve path: the repeated-shape
/// DGEMM workload on warm caches, once per tile (`replay_batch: None`, the
/// single-replay tier) and once per batch cap N in {1, 4, 16, 64}
/// (`replay_batch: Some(N)` coalesces same-kernel tiles into one fused
/// pass over the decoded stream). Every cap must reproduce the baseline
/// responses bit for bit — values, simulated cycles, simulated energy —
/// and the N=64 host wall-clock ratio is recorded as
/// `serve.replay_batch_speedup_x`.
fn replay_batch_bench(report: &mut Report, requests: usize, n: usize, b: usize, ae: AeLevel) {
    println!(
        "\nreplay batching: {requests} repeated-shape DGEMM requests, n={n}, {b}x{b} tiles, {ae}"
    );
    let mk_coord = |cap: Option<usize>| {
        Coordinator::new(CoordinatorConfig {
            ae,
            b,
            artifact_dir: "/nonexistent".into(),
            verify: false,
            replay_batch: cap,
            ..CoordinatorConfig::default()
        })
    };
    let reqs = repeated_gemm_workload(requests, n, 6060);

    // Baseline: the PR 3 per-tile replay tier, warm cache.
    let mut solo = mk_coord(None);
    let _ = solo.serve_batch(repeated_gemm_workload(1, n, 1));
    let t0 = Instant::now();
    let r_solo = solo.serve_batch(reqs.clone());
    let t_solo = t0.elapsed().as_secs_f64();
    println!(
        "{:<44} {:>10.3} ms total  ({:.1} req/s)",
        "  per-tile replay (no coalescing)",
        t_solo * 1e3,
        requests as f64 / t_solo
    );
    report.record("serve.replay_batch_base_total_ms", t_solo * 1e3);

    for cap in [1usize, 4, 16, 64] {
        let mut co = mk_coord(Some(cap));
        let _ = co.serve_batch(repeated_gemm_workload(1, n, 1));
        let t0 = Instant::now();
        let r = co.serve_batch(reqs.clone());
        let t = t0.elapsed().as_secs_f64();

        // Coalescing must change nothing but the wall-clock.
        assert_eq!(r.len(), r_solo.len());
        for (x, y) in r.iter().zip(&r_solo) {
            assert_eq!(x.cycles, y.cycles, "replay batching changed simulated cycles");
            assert_eq!(x.energy_j, y.energy_j, "replay batching changed simulated energy");
            assert_eq!(x.matrix, y.matrix, "replay batching changed values");
        }
        let jc = co.pool_job_counts();
        println!(
            "{:<44} {:>10.3} ms total  ({:.2}x, {} coalesced batches, {} replays)",
            format!("  replay batch cap N={cap}"),
            t * 1e3,
            t_solo / t,
            jc.batched_replays,
            jc.replays
        );
        report.record(&format!("serve.replay_batch_total_ms_n{cap}"), t * 1e3);
        if cap == 64 {
            report.record("serve.replay_batch_speedup_x", t_solo / t);
        }
    }
}

/// Two tenants, each serving `per_tenant` repeated-shape DGEMM requests:
/// once on two isolated coordinators (private pool + cache each, served
/// back to back), once as concurrent tenants of one shared engine. Values
/// must be identical; the engine's shared cache must show cross-tenant
/// hits (strictly more than the isolated sum).
fn multi_tenant_bench(report: &mut Report, per_tenant: usize, n: usize, ae: AeLevel) {
    println!("\nmulti-tenant engine: 2 tenants x {per_tenant} repeated-shape DGEMMs, n={n}, {ae}");
    let tenant_cfg = || CoordinatorConfig {
        ae,
        b: 2,
        artifact_dir: "/nonexistent".into(),
        verify: false,
        ..CoordinatorConfig::default()
    };

    // Isolated baseline: private pools and private caches, so the second
    // tenant re-pays emission, decode and the timing pass.
    let t0 = Instant::now();
    let mut iso_hits = 0;
    let mut iso_resps = Vec::new();
    for t in 0..2u64 {
        let mut co = Coordinator::new(tenant_cfg());
        let resps = co.serve_batch(repeated_gemm_workload(per_tenant, n, 777 + t));
        iso_hits += co.cache_stats().hits;
        iso_resps.push(resps);
    }
    let t_iso = t0.elapsed().as_secs_f64();

    // Shared engine: same total worker count as one coordinator (4), both
    // tenants concurrent, one warm cache between them.
    let engine = Engine::new(EngineConfig { workers: 4, ..EngineConfig::default() });
    let ta = engine.tenant(tenant_cfg());
    let tb = engine.tenant(tenant_cfg());
    let t0 = Instant::now();
    let (ra, rb) = std::thread::scope(|s| {
        let ha = s.spawn(move || {
            let mut ta = ta;
            ta.serve_batch(repeated_gemm_workload(per_tenant, n, 777))
        });
        let hb = s.spawn(move || {
            let mut tb = tb;
            tb.serve_batch(repeated_gemm_workload(per_tenant, n, 778))
        });
        (ha.join().expect("tenant a"), hb.join().expect("tenant b"))
    });
    let t_mt = t0.elapsed().as_secs_f64();

    // Tenant responses must equal the isolated runs exactly.
    for (shared, isolated) in [(&ra, &iso_resps[0]), (&rb, &iso_resps[1])] {
        assert_eq!(shared.len(), isolated.len());
        for (x, y) in shared.iter().zip(isolated.iter()) {
            assert_eq!(x.cycles, y.cycles, "engine changed simulated cycles");
            assert_eq!(x.energy_j, y.energy_j, "engine changed simulated energy");
            assert_eq!(x.matrix, y.matrix, "engine changed values");
        }
    }
    let shared = engine.cache_stats();
    assert!(
        shared.hits > iso_hits,
        "shared cache must add cross-tenant hits: {} vs {iso_hits}",
        shared.hits
    );
    println!(
        "{:<44} {:>10.3} ms total  ({:.1} req/s)",
        "  isolated: 2 private coordinators",
        t_iso * 1e3,
        (2 * per_tenant) as f64 / t_iso
    );
    println!(
        "{:<44} {:>10.3} ms total  ({:.1} req/s)",
        "  engine: shared pool + shared cache",
        t_mt * 1e3,
        (2 * per_tenant) as f64 / t_mt
    );
    println!(
        "{:<44} {:>10.2}x  ({} shared hits vs {} isolated; {} misses total)",
        "  multi-tenant speedup",
        t_iso / t_mt,
        shared.hits,
        iso_hits,
        shared.misses
    );
    report.record("engine.isolated_total_ms", t_iso * 1e3);
    report.record("engine.mt_total_ms", t_mt * 1e3);
    report.record("engine.mt_speedup_x", t_iso / t_mt);
    report.record("engine.cross_tenant_extra_hits", (shared.hits - iso_hits) as f64);
}

/// Scheduler-fairness ablation: a heavy tenant (weight 1) floods
/// `heavy_reqs` repeated-shape DGEMM requests while a light tenant
/// (weight 3) serves `light_reqs` distinct-size DDOT requests, both on a
/// 1-worker engine — once under the slot-WRR baseline, once under the
/// cycle-cost DRR scheduler. Slots are cost-blind, so the heavy tiles
/// monopolize simulated-cycle service and the light tenant waits; DRR
/// prices every job (memoized cycles, or decoded op count while cold), so
/// the weight-3 light tenant receives at least its proportional cycle
/// share and completes far earlier. The lane-service snapshot is taken at
/// the instant the light batch completes — the proportional-service
/// observable the queue tests pin exactly.
fn drr_fairness_bench(
    report: &mut Report,
    heavy_reqs: usize,
    heavy_n: usize,
    light_reqs: usize,
    ae: AeLevel,
) {
    println!(
        "\nscheduler fairness: {heavy_reqs} DGEMM (w=1) vs {light_reqs} DDOT (w=3), 1 worker, {ae}"
    );
    let tenant_cfg = || CoordinatorConfig {
        ae,
        b: 2,
        artifact_dir: "/nonexistent".into(),
        verify: false,
        ..CoordinatorConfig::default()
    };
    let light_sizes: Vec<usize> = (0..light_reqs).map(|i| 16 + 4 * i).collect();
    let light_work: Vec<Request> = light_sizes
        .iter()
        // Distinct sizes → distinct kernels: the flood cannot memo-share.
        .map(|&n| Request::Ddot { x: vec![1.0; n], y: vec![0.5; n] })
        .collect();
    let mut ratios = Vec::new();
    for (tag, sched) in [("slots", SchedPolicy::Slots), ("cycles", SchedPolicy::Cycles)] {
        let engine = Engine::new(EngineConfig { workers: 1, sched, ..EngineConfig::default() });
        let heavy = engine.tenant(tenant_cfg());
        let light = engine.tenant_weighted(tenant_cfg(), 3);
        let heavy_work = repeated_gemm_workload(heavy_reqs, heavy_n, 13_337);
        let light_work = light_work.clone();
        // Pre-emit every kernel into the shared cache (no measurements
        // memoized, so every request still submits a pool job): staging
        // inside the timed region is then cheap memo lookups + submits,
        // and the measured window is genuinely contended instead of one
        // tenant serving solo while the other is still emitting kernels.
        for &n in &light_sizes {
            let _ = light.cache().level1(Routine::Ddot, n, 1.5, ae);
        }
        let np = round_up(heavy_n, 4 * 2);
        let _ = heavy.cache().gemm_rect(np / 2, np / 2, np, ae);
        let engine_ref = &engine;
        let (light_ms, service) = std::thread::scope(|s| {
            let hh = s.spawn(move || {
                let mut heavy = heavy;
                let r = heavy.serve_batch(heavy_work);
                assert_eq!(r.len(), heavy_reqs);
            });
            let lh = s.spawn(move || {
                let mut light = light;
                let t0 = Instant::now();
                let r = light.serve_batch(light_work);
                let ms = t0.elapsed().as_secs_f64() * 1e3;
                assert_eq!(r.len(), light_reqs);
                // Snapshot while the heavy flood is (still) draining: how
                // many estimated cycles each lane has been granted so far.
                (ms, engine_ref.lane_service())
            });
            hh.join().expect("heavy tenant");
            lh.join().expect("light tenant")
        });
        let (heavy_cycles, light_cycles) = (service[0].served_cost.max(1), service[1].served_cost);
        let ratio = light_cycles as f64 / heavy_cycles as f64;
        println!(
            "{:<44} {:>10.3} ms light batch  (light/heavy cycle service {ratio:.3}, want 3.0)",
            format!("  --sched {tag}"),
            light_ms
        );
        report.record(&format!("engine.drr.light_ms_{tag}"), light_ms);
        report.record(&format!("engine.drr.cycle_ratio_{tag}"), ratio);
        ratios.push((light_ms, ratio));
    }
    let (slots, cycles) = (ratios[0], ratios[1]);
    // Proportional cycle service: the DRR scheduler must grant the
    // weight-3 light tenant at least parity with the heavy flood (ideal is
    // 3.0; granularity of one in-flight tile keeps the bound loose here —
    // the queue unit tests pin the 25% band deterministically), while the
    // cost-blind slot scheduler demonstrably violates it.
    assert!(
        cycles.1 >= 1.0,
        "cycles scheduler must not under-serve the weight-3 tenant: ratio {:.3}",
        cycles.1
    );
    assert!(
        cycles.1 > slots.1,
        "DRR must beat slot-WRR on cycle proportionality: {:.3} vs {:.3}",
        cycles.1,
        slots.1
    );
    report.record("engine.drr.light_speedup_x", slots.0 / cycles.0);
}

/// Serve a non-4-aligned repeated-shape DGEMM workload twice on single-PE
/// coordinators: once padding to the aligned tile kernel, once on the
/// cached DOT2/3 residual kernel (no padding). Both warm their cache
/// first, values agree to FP reassociation, and the report records both
/// the host wall-clock and the simulated-cycle ratio (the ablation the
/// ROADMAP asked for, end to end through the serve path).
fn residual_vs_padded_bench(report: &mut Report, requests: usize, n: usize, ae: AeLevel) {
    assert!(n % 4 != 0, "residual bench needs a non-4-aligned n");
    println!("\nresidual vs padded serving: {requests} DGEMM requests, n={n}, single PE, {ae}");
    let mk = |residual: bool| {
        Coordinator::new(CoordinatorConfig {
            ae,
            b: 1,
            artifact_dir: "/nonexistent".into(),
            verify: false,
            residual,
            ..CoordinatorConfig::default()
        })
    };
    let mut padded = mk(false);
    let mut resid = mk(true);
    let _ = padded.serve_batch(repeated_gemm_workload(1, n, 1));
    let _ = resid.serve_batch(repeated_gemm_workload(1, n, 1));
    let reqs = repeated_gemm_workload(requests, n, 31_337);
    let t0 = Instant::now();
    let rp = padded.serve_batch(reqs.clone());
    let t_pad = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let rr = resid.serve_batch(reqs);
    let t_res = t0.elapsed().as_secs_f64();

    // Same math, different kernels: values agree to FP reassociation.
    assert_eq!(rp.len(), rr.len());
    for (p, r) in rp.iter().zip(rr.iter()) {
        let pm = p.matrix.as_ref().expect("dgemm response carries a matrix");
        let rm = r.matrix.as_ref().expect("dgemm response carries a matrix");
        let err = rel_fro_error(rm.as_slice(), pm.as_slice());
        assert!(err < 1e-12, "residual vs padded numerics: {err}");
    }
    let (cyc_pad, cyc_res) = (rp[0].cycles, rr[0].cycles);
    println!(
        "{:<44} {:>10.3} ms total  ({} simulated cycles/req)",
        "  padded tile kernel (cached)",
        t_pad * 1e3,
        cyc_pad
    );
    println!(
        "{:<44} {:>10.3} ms total  ({} simulated cycles/req)",
        "  DOT2/3 residual kernel (cached)",
        t_res * 1e3,
        cyc_res
    );
    println!(
        "{:<44} {:>10.2}x host, {:.2}x simulated",
        "  residual speedup over padded",
        t_pad / t_res,
        cyc_pad as f64 / cyc_res as f64
    );
    report.record("serve.padded_total_ms", t_pad * 1e3);
    report.record("serve.residual_total_ms", t_res * 1e3);
    report.record("serve.residual_vs_padded_host_x", t_pad / t_res);
    report.record("serve.residual_vs_padded_sim_x", cyc_pad as f64 / cyc_res as f64);
}

/// Open-loop serving trajectory (`serve.open_loop.*`): the closed-loop
/// benches above measure throughput with the next request always ready;
/// this one measures what a latency SLO would see. The engine's
/// closed-loop DGEMM capacity is probed once, then a weight-1 heavy
/// DGEMM tenant is offered seeded Poisson traffic at fixed multiples of
/// that capacity while a weight-3 light DDOT tenant runs alongside at a
/// quarter of it — under both schedulers. The recorded curve is the
/// light tenant's p99 total latency per (scheduler, load): cycle-cost
/// DRR should hold the light tail roughly flat where slot-WRR lets the
/// flood push it out. The heavy lane runs with a bounded queue, so
/// overload is shed explicitly (and its fraction recorded) instead of
/// queueing without bound — every offered request is accounted for.
fn open_loop_bench(report: &mut Report, quick: bool, ae: AeLevel) {
    let (probe_reqs, heavy_n, duration_ms) = if quick { (8, 16, 120u64) } else { (16, 24, 300) };
    let loads: &[f64] = if quick { &[1.0, 2.0] } else { &[0.5, 1.0, 2.0] };
    println!(
        "\nopen-loop serving: Poisson DGEMM flood (w=1) vs DDOT (w=3), {duration_ms} ms, {ae}"
    );
    let tenant_cfg = |queue_depth: Option<usize>| CoordinatorConfig {
        ae,
        b: 2,
        artifact_dir: "/nonexistent".into(),
        verify: false,
        queue_depth,
        ..CoordinatorConfig::default()
    };

    // Closed-loop capacity probe on the same pool size as the runs below:
    // how fast a warmed tenant drains the heavy shape when the next
    // request is always available. The open-loop rates are multiples of
    // this measured rate, so "2.00x" means the same on any host.
    let probe_engine = Engine::new(EngineConfig { workers: 2, ..EngineConfig::default() });
    let mut probe = probe_engine.tenant(tenant_cfg(None));
    let _ = probe.serve_batch(repeated_gemm_workload(2, heavy_n, 2));
    let t0 = Instant::now();
    let served = probe.serve_batch(repeated_gemm_workload(probe_reqs, heavy_n, 2)).len();
    let cap_rps = served as f64 / t0.elapsed().as_secs_f64().max(1e-9);
    println!("{:<44} {:>10.1} req/s", "  closed-loop DGEMM capacity", cap_rps);

    let duration_ns = duration_ms * 1_000_000;
    for (tag, sched) in [("slots", SchedPolicy::Slots), ("cycles", SchedPolicy::Cycles)] {
        for &load in loads {
            let engine =
                Engine::new(EngineConfig { workers: 2, sched, ..EngineConfig::default() });
            // Bounded heavy queue: past 2x capacity the backlog must shed
            // explicitly, never grow (or drop) silently.
            let heavy = engine.tenant(tenant_cfg(Some(64)));
            let light = engine.tenant_weighted(tenant_cfg(None), 3);
            let heavy_arrivals: Vec<Arrival> = traffic::arrival_times(&TrafficConfig {
                rate_rps: (cap_rps * load).max(50.0),
                duration_ns,
                seed: 7,
                ..TrafficConfig::default()
            })
            .into_iter()
            .enumerate()
            .map(|(i, at_ns)| {
                let req = Request::RandomDgemm { n: heavy_n, seed: 50 + i as u64 };
                Arrival { seq: i, at_ns, req }
            })
            .collect();
            let light_arrivals: Vec<Arrival> = traffic::arrival_times(&TrafficConfig {
                rate_rps: (cap_rps * 0.25).max(50.0),
                duration_ns,
                seed: 11,
                ..TrafficConfig::default()
            })
            .into_iter()
            .enumerate()
            .map(|(i, at_ns)| {
                // Cycled distinct sizes: the light lane exercises real
                // kernels instead of memo-sharing one shape with itself.
                let n = 16 + 4 * (i % 64);
                let req = Request::Ddot { x: vec![1.0; n], y: vec![0.5; n] };
                Arrival { seq: i, at_ns, req }
            })
            .collect();
            // Pre-emit every kernel into the shared cache so the timed
            // window measures contended serving, not codegen.
            for i in 0..64usize {
                let _ = light.cache().level1(Routine::Ddot, 16 + 4 * i, 1.5, ae);
            }
            let np = round_up(heavy_n, 4 * 2);
            let _ = heavy.cache().gemm_rect(np / 2, np / 2, np, ae);

            let (heavy_offered, light_offered) = (heavy_arrivals.len(), light_arrivals.len());
            let opts = OpenLoopOptions::default();
            let (hr, lr) = std::thread::scope(|s| {
                let hh = s.spawn(move || {
                    let mut heavy = heavy;
                    heavy.serve_open_loop(heavy_arrivals, &opts)
                });
                let lh = s.spawn(move || {
                    let mut light = light;
                    light.serve_open_loop(light_arrivals, &opts)
                });
                (hh.join().expect("heavy tenant"), lh.join().expect("light tenant"))
            });
            // Zero silent drops: every offered request resolves to exactly
            // one Served or Rejected outcome.
            assert_eq!(hr.outcomes.len(), heavy_offered, "heavy lane lost outcomes");
            assert_eq!(lr.outcomes.len(), light_offered, "light lane lost outcomes");
            assert_eq!(lr.stats.served, light_offered, "uncapped light lane must not shed");
            assert_eq!(hr.stats.served + hr.stats.shed, heavy_offered, "heavy lane accounting");

            let p99_ms = lr.stats.total.p99 as f64 / 1e6;
            let xload = (load * 100.0).round() as u64;
            println!(
                "{:<44} {:>10.3} ms light p99  (heavy shed {} of {heavy_offered})",
                format!("  --sched {tag} @ {load:.2}x capacity"),
                p99_ms,
                hr.stats.shed
            );
            report.record(&format!("serve.open_loop.light_p99_ms_{tag}_x{xload:03}"), p99_ms);
            report.record(
                &format!("serve.open_loop.heavy_p99_ms_{tag}_x{xload:03}"),
                hr.stats.total.p99 as f64 / 1e6,
            );
            report.record(
                &format!("serve.open_loop.heavy_shed_frac_{tag}_x{xload:03}"),
                hr.stats.shed as f64 / heavy_offered.max(1) as f64,
            );
        }
    }
}

/// Trace-sink overhead on the warm serve path (`obs.*`): the repeated-shape
/// DGEMM workload served over warm caches by three coordinators — sink
/// off (the shipping default), `NullSink` attached (events constructed
/// then dropped), and `BufferSink` attached (events retained in memory).
/// Tracing must be invisible in every simulated observable (values,
/// cycles, energy); `obs.off_overhead_x` (NullSink vs sink-off wall-clock)
/// is asserted to stay in a loose band around 1.0 — the sink-off path
/// constructs no events at all, so attaching a dropping sink is the upper
/// bound on what the default path could possibly pay — and
/// `obs.overhead_x` records the full buffered-capture cost.
fn obs_overhead_bench(report: &mut Report, requests: usize, n: usize, b: usize, ae: AeLevel) {
    println!(
        "\ntrace overhead: {requests} repeated-shape DGEMM requests, n={n}, {b}x{b} tiles, {ae}"
    );
    let mk = || {
        Coordinator::new(CoordinatorConfig {
            ae,
            b,
            artifact_dir: "/nonexistent".into(),
            verify: false,
            ..CoordinatorConfig::default()
        })
    };
    let reqs = repeated_gemm_workload(requests, n, 2025);

    let mut off = mk();
    let mut null = mk();
    let mut buf = mk();
    null.set_trace_sink(Arc::new(NullSink) as Arc<dyn TraceSink>);
    let buffer = Arc::new(BufferSink::new());
    buf.set_trace_sink(buffer.clone());
    // Warm all three so the timed regions serve cache hits only, and drop
    // the warm-up events so the capture below is just the timed batch.
    let _ = off.serve_batch(repeated_gemm_workload(1, n, 1));
    let _ = null.serve_batch(repeated_gemm_workload(1, n, 1));
    let _ = buf.serve_batch(repeated_gemm_workload(1, n, 1));
    let _ = buffer.take();

    let t0 = Instant::now();
    let r_off = off.serve_batch(reqs.clone());
    let t_off = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let r_null = null.serve_batch(reqs.clone());
    let t_null = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let r_buf = buf.serve_batch(reqs);
    let t_buf = t0.elapsed().as_secs_f64();
    let events = buffer.take().len();

    assert_eq!(r_off.len(), r_null.len());
    assert_eq!(r_off.len(), r_buf.len());
    for (o, (nl, bf)) in r_off.iter().zip(r_null.iter().zip(&r_buf)) {
        assert_eq!(o.cycles, nl.cycles, "NullSink changed simulated cycles");
        assert_eq!(o.energy_j, nl.energy_j, "NullSink changed simulated energy");
        assert_eq!(o.matrix, nl.matrix, "NullSink changed values");
        assert_eq!(o.cycles, bf.cycles, "BufferSink changed simulated cycles");
        assert_eq!(o.energy_j, bf.energy_j, "BufferSink changed simulated energy");
        assert_eq!(o.matrix, bf.matrix, "BufferSink changed values");
    }
    assert!(events > 0, "BufferSink captured no events from a traced serve");

    let off_x = t_null / t_off;
    let buf_x = t_buf / t_off;
    println!(
        "{:<44} {:>10.3} ms total  ({:.1} req/s)",
        "  sink off (default untraced path)",
        t_off * 1e3,
        requests as f64 / t_off
    );
    println!(
        "{:<44} {:>10.3} ms total  ({:.2}x vs off)",
        "  NullSink (emit + drop)",
        t_null * 1e3,
        off_x
    );
    println!(
        "{:<44} {:>10.3} ms total  ({:.2}x vs off, {events} events)",
        "  BufferSink (emit + retain)",
        t_buf * 1e3,
        buf_x
    );
    report.record("obs.no_sink_total_ms", t_off * 1e3);
    report.record("obs.null_sink_total_ms", t_null * 1e3);
    report.record("obs.buffer_sink_total_ms", t_buf * 1e3);
    report.record("obs.off_overhead_x", off_x);
    report.record("obs.overhead_x", buf_x);
    report.record("obs.events_captured", events as f64);
    // Event construction happens only behind an attached sink; even then it
    // must stay noise-level. Loose band — these are host wall-clock ratios
    // on a tens-of-ms batch, so allow generous scheduler jitter.
    assert!(
        (0.4..=2.5).contains(&off_x),
        "NullSink serve diverged from the untraced path: {off_x:.3}x"
    );
}

/// Fabric scaling curves: serve the repeated-shape DGEMM workload on
/// NoC-modeled fabrics of order b ∈ {1, 2, 3, 4}, crossed with both
/// placement policies and both schedulers. Each point records the routed
/// makespan (absolute fabric cycles), its speedup over the 1×1 fabric
/// under the same (place, sched), the compute-to-communication ratio, and
/// the busiest link's occupancy — the `noc.fabric.*` keys BENCH.md
/// tracks. Monotone improvement with fabric order is asserted, not just
/// recorded: a placement or pricing regression that flattens the curve
/// fails the bench.
fn fabric_scaling_bench(report: &mut Report, quick: bool, ae: AeLevel) {
    use redefine_blas::noc::{FabricConfig, PlacePolicy};
    let (requests, n) = if quick { (16, 16) } else { (64, 32) };
    println!("\nfabric scaling: {requests} DGEMM requests, n={n}, fabrics 1x1..4x4, {ae}");
    let reqs = repeated_gemm_workload(requests, n, 4242);
    for sched in [SchedPolicy::Cycles, SchedPolicy::Slots] {
        let sched_name = match sched {
            SchedPolicy::Cycles => "cycles",
            SchedPolicy::Slots => "slots",
        };
        for place in [PlacePolicy::Locality, PlacePolicy::RoundRobin] {
            let mut base = 0u64;
            let mut prev = u64::MAX;
            for b in [1usize, 2, 3, 4] {
                let mut co = Coordinator::new(CoordinatorConfig {
                    ae,
                    b: 2,
                    artifact_dir: "/nonexistent".into(),
                    verify: false,
                    sched,
                    fabric: Some(FabricConfig { place, ..FabricConfig::new(b) }),
                    ..CoordinatorConfig::default()
                });
                let _ = co.serve_batch(reqs.clone());
                let fs = co.fabric_stats().expect("fabric telemetry");
                if b == 1 {
                    base = fs.makespan;
                }
                let speedup = base as f64 / fs.makespan.max(1) as f64;
                let tag = format!("b{b}_{}_{sched_name}", place.name());
                println!(
                    "{:<44} {:>12} cyc  {:>5.2}x  C/C {:>6.1}  max-link {:>9}",
                    format!("  {tag}"),
                    fs.makespan,
                    speedup,
                    fs.compute_comm_ratio(),
                    fs.max_link_busy
                );
                let ratio = fs.compute_comm_ratio();
                report.record(&format!("noc.fabric.makespan_cycles_{tag}"), fs.makespan as f64);
                report.record(&format!("noc.fabric.speedup_x_{tag}"), speedup);
                report.record(&format!("noc.fabric.compute_comm_ratio_{tag}"), ratio);
                report.record(&format!("noc.fabric.max_link_busy_{tag}"), fs.max_link_busy as f64);
                assert!(
                    fs.makespan < prev,
                    "{tag}: fabric {b}x{b} must improve on the smaller fabric ({} vs {prev})",
                    fs.makespan
                );
                prev = fs.makespan;
            }
        }
    }
}

/// LAPACK factorization DAG serving (`lapack.*`): per kind, one traced
/// factorization yields the DAG critical path (the response's makespan)
/// and — from its `node_completed` events — the serial sum of the node
/// kernels, whose ratio is the dependency-overlap headline a flat
/// pipeline cannot have. A repeated batch on the warm shared cache then
/// pins the all-hit property (every node is a counted cache customer)
/// and records the factorization serve throughput.
fn lapack_bench(report: &mut Report, quick: bool, ae: AeLevel) {
    let (repeats, n) = if quick { (3usize, 16usize) } else { (6, 32) };
    println!("\nlapack DAG serving: {repeats}x qr/lu/chol factorizations, n={n}, {ae}");
    for kind in [FactorKind::Qr, FactorKind::Lu, FactorKind::Chol] {
        let tag = kind.tag();
        let mut co = Coordinator::new(CoordinatorConfig {
            ae,
            b: 2,
            artifact_dir: "/nonexistent".into(),
            verify: false,
            ..CoordinatorConfig::default()
        });
        let buffer = Arc::new(BufferSink::new());
        co.set_trace_sink(buffer.clone());

        // Warm factorization: emits every node kernel once and captures
        // the DAG trace.
        let warm = co.serve_batch(factor_workload(kind, 1, n, 1));
        let f = warm[0].factor.as_deref().expect("factor outcome");
        let serial: u64 = buffer
            .take()
            .iter()
            .filter_map(|e| match e.kind {
                EventKind::NodeCompleted { cycles, .. } => Some(cycles),
                _ => None,
            })
            .sum();
        assert!(
            f.makespan <= serial,
            "{tag}: DAG critical path {} exceeds the serial node sum {serial}",
            f.makespan
        );
        let overlap = serial as f64 / f.makespan.max(1) as f64;
        let warm_cs = co.cache_stats();

        // Repeated factorizations on the warm shared cache: every node
        // kernel must hit (no new misses) — the repeated-shape acceptance
        // signal — and the batch is the recorded throughput point.
        let t0 = Instant::now();
        let resps = co.serve_batch(factor_workload(kind, repeats, n, 42));
        let t = t0.elapsed().as_secs_f64();
        assert_eq!(resps.len(), repeats);
        let cs = co.cache_stats();
        assert_eq!(
            cs.misses, warm_cs.misses,
            "{tag}: repeated factorizations must not miss the program cache"
        );
        let warm_accesses = cs.hits.saturating_sub(warm_cs.hits).max(1);
        println!(
            "{:<44} {:>10.3} ms batch  ({} nodes, makespan {} / serial {}: {:.2}x overlap)",
            format!("  {tag}: {repeats} factorizations n={n}"),
            t * 1e3,
            f.nodes,
            f.makespan,
            serial,
            overlap
        );
        report.record(&format!("lapack.{tag}.serve_total_ms"), t * 1e3);
        report.record(&format!("lapack.{tag}.nodes"), f.nodes as f64);
        report.record(&format!("lapack.{tag}.makespan_cycles"), f.makespan as f64);
        report.record(&format!("lapack.{tag}.node_cycles_serial"), serial as f64);
        report.record(&format!("lapack.{tag}.dag_overlap_x"), overlap);
        let hits_per_repeat = warm_accesses as f64 / repeats as f64;
        report.record(&format!("lapack.{tag}.warm_hits_per_repeat"), hits_per_repeat);
    }
}
