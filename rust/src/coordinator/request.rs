//! Request/response types and the coordinator's serve loop — the
//! "request path" of the system. Requests are BLAS calls; responses carry
//! values plus the simulated cost report. Everything here is pure Rust over
//! AOT artifacts: Python is never on this path.

use super::{Coordinator, ValueSource};
use crate::util::{Mat, XorShift64};

/// A BLAS request to the coordinator.
#[derive(Debug, Clone)]
pub enum Request {
    /// C ← A·B + C.
    Dgemm { a: Mat, b: Mat, c: Mat },
    /// y ← A·x + y.
    Dgemv { a: Mat, x: Vec<f64>, y: Vec<f64> },
    /// xᵀ·y.
    Ddot { x: Vec<f64>, y: Vec<f64> },
    /// Synthetic request by shape only (workload generators).
    RandomDgemm { n: usize, seed: u64 },
}

impl Request {
    /// Human-readable request tag.
    pub fn name(&self) -> &'static str {
        match self {
            Request::Dgemm { .. } | Request::RandomDgemm { .. } => "dgemm",
            Request::Dgemv { .. } => "dgemv",
            Request::Ddot { .. } => "ddot",
        }
    }

    /// Problem size n.
    pub fn n(&self) -> usize {
        match self {
            Request::Dgemm { a, .. } => a.rows(),
            Request::Dgemv { a, .. } => a.rows(),
            Request::Ddot { x, .. } => x.len(),
            Request::RandomDgemm { n, .. } => *n,
        }
    }
}

/// Response: scalar/vector/matrix value + cost accounting.
#[derive(Debug)]
pub struct Response {
    pub op: &'static str,
    pub n: usize,
    pub source: ValueSource,
    /// Simulated latency in PE cycles (makespan for tiled ops).
    pub cycles: u64,
    /// Simulated energy (joules) where modelled (tiled DGEMM).
    pub energy_j: Option<f64>,
    /// Result payloads (exactly one is set).
    pub matrix: Option<Mat>,
    pub vector: Option<Vec<f64>>,
    pub scalar: Option<f64>,
}

impl Coordinator {
    /// Serve one request.
    pub fn serve_one(&mut self, req: Request) -> Response {
        match req {
            Request::Dgemm { a, b, c } => {
                let n = a.rows();
                let r = self.dgemm(&a, &b, &c);
                Response {
                    op: "dgemm",
                    n,
                    source: r.source,
                    cycles: r.makespan,
                    energy_j: Some(r.energy_j),
                    matrix: Some(r.c),
                    vector: None,
                    scalar: None,
                }
            }
            Request::RandomDgemm { n, seed } => {
                let a = Mat::random(n, n, seed);
                let b = Mat::random(n, n, seed ^ 0xBEEF);
                let c = Mat::zeros(n, n);
                self.serve_one(Request::Dgemm { a, b, c })
            }
            Request::Dgemv { a, x, y } => {
                let n = a.rows();
                let (v, meas, source) = self.dgemv(&a, &x, &y);
                Response {
                    op: "dgemv",
                    n,
                    source,
                    cycles: meas.latency(),
                    energy_j: None,
                    matrix: None,
                    vector: Some(v),
                    scalar: None,
                }
            }
            Request::Ddot { x, y } => {
                let n = x.len();
                let (d, meas, source) = self.ddot(&x, &y);
                Response {
                    op: "ddot",
                    n,
                    source,
                    cycles: meas.latency(),
                    energy_j: None,
                    matrix: None,
                    vector: None,
                    scalar: Some(d),
                }
            }
        }
    }

    /// Serve a batch of requests in order, returning all responses.
    pub fn serve(&mut self, reqs: Vec<Request>) -> Vec<Response> {
        reqs.into_iter().map(|r| self.serve_one(r)).collect()
    }
}

/// Workload generator: a random mix of BLAS requests, the driver used by
/// the end-to-end example and the throughput bench.
pub fn random_workload(count: usize, max_n: usize, seed: u64) -> Vec<Request> {
    let mut rng = XorShift64::new(seed);
    let mut reqs = Vec::with_capacity(count);
    for i in 0..count {
        let n = 8 + rng.below(max_n.saturating_sub(8).max(1));
        match rng.below(3) {
            0 => reqs.push(Request::RandomDgemm { n, seed: seed + i as u64 }),
            1 => {
                let a = Mat::random(n, n, seed + i as u64);
                let x = rng.vec(n);
                let y = rng.vec(n);
                reqs.push(Request::Dgemv { a, x, y });
            }
            _ => {
                let x = rng.vec(n);
                let y = rng.vec(n);
                reqs.push(Request::Ddot { x, y });
            }
        }
    }
    reqs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::CoordinatorConfig;
    use crate::pe::AeLevel;

    fn coord() -> Coordinator {
        Coordinator::new(CoordinatorConfig {
            ae: AeLevel::Ae5,
            b: 2,
            artifact_dir: "/nonexistent".into(),
            verify: false,
        })
    }

    #[test]
    fn serves_mixed_workload() {
        let reqs = random_workload(6, 24, 99);
        assert_eq!(reqs.len(), 6);
        let mut co = coord();
        let resps = co.serve(reqs);
        assert_eq!(resps.len(), 6);
        for r in &resps {
            assert!(r.cycles > 0, "{} has zero cycles", r.op);
            let payloads =
                r.matrix.is_some() as u8 + r.vector.is_some() as u8 + r.scalar.is_some() as u8;
            assert_eq!(payloads, 1, "{} must carry exactly one payload", r.op);
        }
    }

    #[test]
    fn request_metadata() {
        let r = Request::RandomDgemm { n: 32, seed: 1 };
        assert_eq!(r.name(), "dgemm");
        assert_eq!(r.n(), 32);
    }

    #[test]
    fn ddot_request_value() {
        let mut co = coord();
        let resp = co.serve_one(Request::Ddot { x: vec![1.0, 2.0, 0.0, 0.0], y: vec![3.0, 4.0, 0.0, 0.0] });
        assert_eq!(resp.scalar, Some(11.0));
    }
}
