//! Request/response types and the coordinator's serve loops — the
//! "request path" of the system. Requests are BLAS calls or LAPACK
//! factorizations; responses carry values plus the simulated cost report.
//! Everything here is pure Rust over AOT artifacts: Python is never on
//! this path.
//!
//! Factorization requests ([`Request::Dgeqrf`] / [`Request::Dgetrf`] /
//! [`Request::Dpotrf`]) are not flat kernels: admission expands them
//! (`lapack::expand`) into a dependency DAG of cached BLAS kernel calls,
//! and the pipeline dispatches that DAG **dependency-aware** — only the
//! initial ready set is staged, and every later node reaches the shared
//! worker queue exactly when its last predecessor's result is absorbed.
//! Factor values come from the host reference computed at expansion time
//! (the same convention as Level-1/2 serving: kernels model timing with
//! fixed operand seeds, values resolve host-side), so a served
//! factorization is bit-comparable to `lapack::{dgeqrf,dgetrf,dpotrf}`.
//!
//! Two serving modes:
//! * [`Coordinator::serve`] — strictly sequential (one request fully
//!   completes before the next starts), kept as the reference semantics;
//! * [`Coordinator::serve_batch`] — the serving-engine path: requests are
//!   admitted up to a bounded **admission window** (request count, and
//!   optionally a **byte budget** over the packed GM images staged
//!   requests pin — [`CoordinatorConfig::admission_bytes`]), their kernels
//!   (DGEMM tiles *and* Level-1/2 measurement kernels) staged on the
//!   persistent worker pool, and responses finalized in submission order
//!   as results drain — so kernels of independent requests overlap while
//!   huge batches never hold more than the window's worth of packed
//!   operands in memory. Identical in-flight Level-1/2 kernels are shared,
//!   not duplicated, and same-kernel DGEMM tiles can be coalesced into
//!   replay-batched pool jobs ([`CoordinatorConfig::replay_batch`]).
//!   Responses are value-, cycle- and energy-identical to `serve_one`
//!   (pinned by tests).

use super::pool::{Done, Job};
use super::{
    seal_slots, Coordinator, CoordinatorConfig, DgemmResult, MeasSpec, PendingDgemm, ProgramKey,
    StagedTiles, TileSlots, ValueSource,
};
use crate::codegen::layout::VecLayout;
use crate::codegen::GemmLayout;
use crate::dag::{ExecGraph, ExecState, KernelCall};
use crate::energy::PowerModel;
use crate::lapack::{expand, FactorKind, Factors, FlopProfile};
use crate::metrics::{Measurement, Routine};
use crate::obs::{Event, EventKind, Tier, NO_REQ};
use crate::pe::{AeLevel, PeConfig, PeStats, ScheduledProgram};
use crate::util::{round_up, Mat, XorShift64};
use std::collections::hash_map::Entry;
use std::collections::{HashMap, VecDeque};
use std::sync::Arc;

/// A BLAS request to the coordinator.
#[derive(Debug, Clone)]
pub enum Request {
    /// C ← A·B + C.
    Dgemm { a: Mat, b: Mat, c: Mat },
    /// y ← A·x + y.
    Dgemv { a: Mat, x: Vec<f64>, y: Vec<f64> },
    /// xᵀ·y.
    Ddot { x: Vec<f64>, y: Vec<f64> },
    /// y ← α·x + y.
    Daxpy { alpha: f64, x: Vec<f64>, y: Vec<f64> },
    /// ‖x‖₂.
    Dnrm2 { x: Vec<f64> },
    /// Blocked Householder QR of square `a`, served as a kernel DAG.
    Dgeqrf { a: Mat },
    /// Partial-pivot LU of square `a`, served as a kernel DAG.
    Dgetrf { a: Mat },
    /// Cholesky (lower) of SPD `a`, served as a kernel DAG.
    Dpotrf { a: Mat },
    /// Synthetic request by shape only (workload generators).
    RandomDgemm { n: usize, seed: u64 },
    /// Synthetic factorization by kind and shape only (Cholesky
    /// materializes an SPD operand).
    RandomFactor { kind: FactorKind, n: usize, seed: u64 },
}

impl Request {
    /// Human-readable request tag.
    pub fn name(&self) -> &'static str {
        match self {
            Request::Dgemm { .. } | Request::RandomDgemm { .. } => "dgemm",
            Request::Dgemv { .. } => "dgemv",
            Request::Ddot { .. } => "ddot",
            Request::Daxpy { .. } => "daxpy",
            Request::Dnrm2 { .. } => "dnrm2",
            Request::Dgeqrf { .. } => "dgeqrf",
            Request::Dgetrf { .. } => "dgetrf",
            Request::Dpotrf { .. } => "dpotrf",
            Request::RandomFactor { kind, .. } => kind.op_name(),
        }
    }

    /// Problem size n.
    pub fn n(&self) -> usize {
        match self {
            Request::Dgemm { a, .. } => a.rows(),
            Request::Dgemv { a, .. } => a.rows(),
            Request::Ddot { x, .. } => x.len(),
            Request::Daxpy { x, .. } => x.len(),
            Request::Dnrm2 { x } => x.len(),
            Request::Dgeqrf { a } | Request::Dgetrf { a } | Request::Dpotrf { a } => a.rows(),
            Request::RandomDgemm { n, .. } => *n,
            Request::RandomFactor { n, .. } => *n,
        }
    }

    /// Resolve synthetic requests into concrete operands. The single
    /// materialization rule shared by both serve paths, so batched and
    /// sequential serving see bit-identical inputs.
    pub fn materialize(self) -> Request {
        match self {
            Request::RandomDgemm { n, seed } => Request::Dgemm {
                a: Mat::random(n, n, seed),
                b: Mat::random(n, n, seed ^ 0xBEEF),
                c: Mat::zeros(n, n),
            },
            Request::RandomFactor { kind, n, seed } => {
                let a = match kind {
                    FactorKind::Chol => Mat::random_spd(n, seed),
                    FactorKind::Qr | FactorKind::Lu => Mat::random(n, n, seed),
                };
                match kind {
                    FactorKind::Qr => Request::Dgeqrf { a },
                    FactorKind::Lu => Request::Dgetrf { a },
                    FactorKind::Chol => Request::Dpotrf { a },
                }
            }
            other => other,
        }
    }
}

impl CoordinatorConfig {
    /// Packed GM bytes request `req` pins while staged on a coordinator
    /// with this configuration: the b² tile images a DGEMM holds on the
    /// job queue (or the single residual image in residual mode), or the
    /// worker-side kernel image of a Level-1/2 measurement. A pure
    /// function of the shape (8 bytes per GM word), so admission can price
    /// a request *before* materializing its operands — the currency of
    /// [`CoordinatorConfig::admission_bytes`].
    pub fn staged_bytes(&self, req: &Request) -> u64 {
        let n = req.n();
        let words = match req {
            Request::Dgemm { .. } | Request::RandomDgemm { .. } => {
                if self.residual_eligible(n) {
                    3 * n * n
                } else {
                    let np = round_up(n, 4 * self.b);
                    let m = np / self.b;
                    self.b * self.b * (m * np + np * m + m * m)
                }
            }
            Request::Dgemv { .. } => VecLayout::gemv(round_up(n, 4)).gm_words(),
            Request::Ddot { .. } | Request::Daxpy { .. } | Request::Dnrm2 { .. } => {
                VecLayout::level1(round_up(n.max(4), 4)).gm_words()
            }
            // A staged factorization pins its n×n operand; the node
            // kernels' transient images come and go with the DAG.
            Request::Dgeqrf { .. }
            | Request::Dgetrf { .. }
            | Request::Dpotrf { .. }
            | Request::RandomFactor { .. } => n * n,
        };
        8 * words as u64
    }
}

/// Response: scalar/vector/matrix value + cost accounting.
#[derive(Debug)]
pub struct Response {
    pub op: &'static str,
    pub n: usize,
    pub source: ValueSource,
    /// Simulated latency in PE cycles (makespan for tiled ops).
    pub cycles: u64,
    /// Simulated energy (joules) where modelled (tiled DGEMM).
    pub energy_j: Option<f64>,
    /// Result payloads (exactly one is set).
    pub matrix: Option<Mat>,
    pub vector: Option<Vec<f64>>,
    pub scalar: Option<f64>,
    /// Factorization payload (set for `Dgeqrf`/`Dgetrf`/`Dpotrf`).
    pub factor: Option<Box<FactorOutcome>>,
}

/// Payload of a served factorization: the factors, the Fig-1 flop
/// attribution, and the DAG execution summary.
#[derive(Debug)]
pub struct FactorOutcome {
    /// Host-computed factors (bit-identical to the `lapack` reference —
    /// values resolve host-side, kernels model timing).
    pub factors: Factors,
    /// Fig-1 flop attribution by BLAS routine — the serving-side view of
    /// the paper's observation that factorizations live in DGEMM/DGEMV.
    pub profile: FlopProfile,
    /// Kernel DAG nodes executed on the pool.
    pub nodes: usize,
    /// Critical-path makespan over the node kernels, in PE cycles.
    /// Equals `Response::cycles` off-fabric; a fabric adds NoC routing.
    pub makespan: u64,
}

/// Telemetry of one [`Coordinator::serve_batch`] call.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct BatchStats {
    /// Requests served.
    pub requests: usize,
    /// Peak number of requests staged (admitted, not yet finalized) at
    /// once — bounded by [`super::CoordinatorConfig::admission_window`].
    pub peak_staged: usize,
    /// Peak packed GM bytes pinned by staged requests at once (priced by
    /// [`CoordinatorConfig::staged_bytes`]) — bounded by
    /// [`super::CoordinatorConfig::admission_bytes`], except that a single
    /// request whose image alone exceeds the budget still stages (alone).
    pub peak_staged_bytes: u64,
    /// Requests that attached to an identical in-flight measurement kernel
    /// instead of submitting a duplicate.
    pub shared_measurements: usize,
    /// Requests shed by open-loop backpressure
    /// ([`Coordinator::serve_open_loop`] under
    /// [`CoordinatorConfig::queue_depth`] /
    /// [`CoordinatorConfig::shed_after_bytes`]); always 0 on the
    /// closed-loop `serve_batch` path, which never sheds.
    pub shed: usize,
}

/// The one place a [`DgemmResult`] becomes a [`Response`] — shared by the
/// sequential and batched paths so they cannot drift apart.
fn dgemm_response(n: usize, r: DgemmResult) -> Response {
    Response {
        op: "dgemm",
        n,
        source: r.source,
        cycles: r.makespan,
        energy_j: Some(r.energy_j),
        matrix: Some(r.c),
        vector: None,
        scalar: None,
        factor: None,
    }
}

/// Measurement spec for a Level-1/2 request (key + padded parameters).
fn meas_spec(req: &Request, ae: AeLevel) -> MeasSpec {
    match req {
        Request::Dgemv { a, .. } => MeasSpec::gemv(a.rows(), ae),
        Request::Ddot { x, .. } => MeasSpec::level1(Routine::Ddot, x.len(), 1.5, ae),
        Request::Daxpy { alpha, x, .. } => MeasSpec::level1(Routine::Daxpy, x.len(), *alpha, ae),
        Request::Dnrm2 { x } => MeasSpec::level1(Routine::Dnrm2, x.len(), 1.5, ae),
        Request::Dgemm { .. }
        | Request::RandomDgemm { .. }
        | Request::Dgeqrf { .. }
        | Request::Dgetrf { .. }
        | Request::Dpotrf { .. }
        | Request::RandomFactor { .. } => {
            unreachable!("not a Level-1/2 request")
        }
    }
}

/// Byte-budget admission rule: an empty window always admits (an oversized
/// request must not wedge the batch); otherwise the staged total may not
/// exceed the budget. `None` = unbudgeted.
fn admits_bytes(budget: Option<u64>, window_empty: bool, staged: u64, next: u64) -> bool {
    match budget {
        Some(b) => window_empty || staged + next <= b,
        None => true,
    }
}

/// Same-kernel tile coalescer of the batched serving path
/// ([`CoordinatorConfig::replay_batch`]). Tile jobs whose requests
/// resolved to the *same cached kernel* — pointer-identical
/// [`ScheduledProgram`], which the cache guarantees per resident
/// (routine, shape, AE) key — accumulate into groups of up to `cap`
/// members; a sealed group ships as one [`Job::ReplayBatch`], so a worker
/// walks the decoded program once for the whole group. With the feature
/// off (`cap == None`) every tile passes straight through as its own
/// [`Job::GemmTile`], the pre-batching behavior. Tiles of *different*
/// kernels never share a group: a mixed-key batch coalesces only its
/// same-key runs.
struct TileBatcher {
    cap: Option<usize>,
    /// Keyed by the shared program's allocation address. If the cache
    /// evicts and re-emits a key mid-batch the two allocations simply land
    /// in different groups — a lost coalescing opportunity, never a
    /// correctness hazard.
    groups: HashMap<usize, (Arc<ScheduledProgram>, GemmLayout, Vec<(u64, usize, Vec<f64>)>)>,
}

impl TileBatcher {
    fn new(cap: Option<usize>) -> Self {
        Self { cap: cap.map(|c| c.max(1)), groups: HashMap::new() }
    }

    /// Absorb one request's prepared tiles, returning the jobs ready to
    /// enqueue now: everything when batching is off, groups that just
    /// reached `cap` when it is on.
    fn add(&mut self, staged: StagedTiles) -> Vec<Job> {
        let StagedTiles { sched, layout, tiles } = staged;
        let Some(cap) = self.cap else {
            return tiles
                .into_iter()
                .map(|(job_id, tile_idx, gm)| Job::GemmTile {
                    job_id,
                    tile_idx,
                    sched: Arc::clone(&sched),
                    layout,
                    gm,
                })
                .collect();
        };
        let key = Arc::as_ptr(&sched) as usize;
        let group = self.groups.entry(key).or_insert_with(|| (sched, layout, Vec::new()));
        let mut ready = Vec::new();
        for t in tiles {
            group.2.push(t);
            if group.2.len() >= cap {
                ready.push(seal_group(&group.0, group.1, std::mem::take(&mut group.2)));
            }
        }
        ready
    }

    /// Flush every accumulated group, full or not — called before blocking
    /// on pool results, so no staged tile is ever waited on while it still
    /// sits unsubmitted in the coalescer. Groups ship ordered by their
    /// oldest member's request id — the map is keyed by allocation
    /// address, whose iteration order would otherwise vary run to run and
    /// leak host nondeterminism into the dispatch order (and the trace
    /// event log).
    fn drain(&mut self) -> Vec<Job> {
        let mut groups: Vec<_> =
            self.groups.drain().map(|(_, g)| g).filter(|g| !g.2.is_empty()).collect();
        groups.sort_unstable_by_key(|g| g.2.first().map(|m| m.0).unwrap_or(u64::MAX));
        groups.into_iter().map(|(sched, layout, members)| seal_group(&sched, layout, members)).collect()
    }
}

/// Seal one group into a pool job: a group of one stays a plain tile job
/// (there is nothing to amortize), anything larger becomes a
/// [`Job::ReplayBatch`].
fn seal_group(
    sched: &Arc<ScheduledProgram>,
    layout: GemmLayout,
    mut members: Vec<(u64, usize, Vec<f64>)>,
) -> Job {
    if members.len() == 1 {
        let (job_id, tile_idx, gm) = members.pop().expect("group of one");
        Job::GemmTile { job_id, tile_idx, sched: Arc::clone(sched), layout, gm }
    } else {
        Job::ReplayBatch { sched: Arc::clone(sched), layout, members }
    }
}

/// A DGEMM request whose tiles are on the pool, waiting to be merged.
struct InFlight {
    pending: PendingDgemm,
    a: Mat,
    b: Mat,
    c: Mat,
}

/// Per-request slot of a batch, in submission order.
enum Slot {
    /// DGEMM with tiles on the pool; complete when all tiles collected.
    /// `tiers` stashes each collected tile's execution tier (with its tile
    /// index, since workers race) for the `Executed` trace events emitted
    /// at finalize.
    Dgemm { flight: Box<InFlight>, tiles: TileSlots, got: usize, tiers: Vec<(usize, Tier)> },
    /// Level-1/2 request; complete when its measurement is available
    /// (boxed: a `Measurement` carries full `PeStats` + `PeConfig`). The
    /// tier is set only for the request that paid the simulation — cache
    /// hits and in-flight sharers executed nothing.
    Meas { req: Request, meas: Option<Box<Measurement>>, tier: Option<Tier> },
    /// A factorization expanded into a kernel DAG (host factors already
    /// resolved at staging); complete when every node's pool result has
    /// been absorbed. Successor nodes are dispatched from
    /// [`Coordinator::absorb`] as completions release them — the
    /// dependency-aware dispatch step.
    Factor {
        kind: FactorKind,
        n: usize,
        graph: ExecGraph,
        /// Ready-set tracker: which nodes completed, what each completion
        /// releases.
        state: ExecState,
        factors: Box<Factors>,
        profile: FlopProfile,
        /// Per-node kernel stats + execution tier (`None` = outstanding),
        /// indexed by DAG node.
        nodes: Vec<Option<(PeStats, Tier)>>,
    },
}

impl Slot {
    fn complete(&self) -> bool {
        match self {
            Slot::Dgemm { flight, got, .. } => *got == flight.pending.tile_count(),
            Slot::Meas { meas, .. } => meas.is_some(),
            Slot::Factor { state, .. } => state.is_done(),
        }
    }
}

/// An admitted, unfinalized request: its id, the packed bytes it pins
/// (admission accounting), its completion slot, and — for the open-loop
/// path — latency bookkeeping (all zero on the closed-loop path, where
/// arrival time is meaningless).
struct Staged {
    id: u64,
    bytes: u64,
    /// Caller-visible arrival index (equals `id` on the closed-loop path;
    /// skips shed arrivals on the open-loop path).
    seq: usize,
    /// Virtual arrival timestamp (ns from run start; 0 closed-loop).
    arrival_ns: u64,
    /// Host time the request was admitted (ns from run start; 0 closed-loop).
    admitted_ns: u64,
    slot: Slot,
}

/// The admission + completion state machine behind both serving modes: the
/// bounded window of in-flight requests plus the kernel-sharing and
/// tile-coalescing side tables. [`Coordinator::serve_batch`] drives it
/// closed-loop (admit from a list, block for completions);
/// [`Coordinator::serve_open_loop`] drives it from a timed arrival process,
/// polling completions between arrival deadlines. Both paths run the exact
/// same stage/absorb/finalize code, which is what keeps their responses
/// value-, cycle- and energy-identical (pinned by the open-loop tests).
pub(crate) struct Pipeline {
    window: usize,
    budget: Option<u64>,
    /// Admitted, unfinalized requests in submission order.
    inflight: VecDeque<Staged>,
    staged_bytes: u64,
    /// Key → ids waiting on an in-flight measurement; id → its key.
    waiting: HashMap<ProgramKey, Vec<u64>>,
    submitted: HashMap<u64, ProgramKey>,
    /// Factorization node jobs on the pool: pool job id → (owning request
    /// id, DAG node index). Node job ids are drawn from `next_id` like
    /// request ids (so they never collide) but never enter `inflight`.
    node_jobs: HashMap<u64, (u64, usize)>,
    /// Same-kernel tile coalescer (inert unless `replay_batch` is set).
    batcher: TileBatcher,
    next_id: u64,
    pub(crate) stats: BatchStats,
}

impl Pipeline {
    pub(crate) fn new(cfg: &CoordinatorConfig) -> Self {
        Self {
            window: cfg.admission_window.unwrap_or(usize::MAX).max(1),
            budget: cfg.admission_bytes,
            inflight: VecDeque::new(),
            staged_bytes: 0,
            waiting: HashMap::new(),
            submitted: HashMap::new(),
            node_jobs: HashMap::new(),
            batcher: TileBatcher::new(cfg.replay_batch),
            next_id: 0,
            stats: BatchStats::default(),
        }
    }

    /// Whether a request pinning `bytes` may be admitted right now: the
    /// window has a free slot and the byte budget accepts it (an empty
    /// window always admits, so an oversized request cannot wedge).
    pub(crate) fn has_room(&self, bytes: u64) -> bool {
        self.window > self.inflight.len()
            && admits_bytes(self.budget, self.inflight.is_empty(), self.staged_bytes, bytes)
    }

    /// No admitted request is outstanding.
    pub(crate) fn idle(&self) -> bool {
        self.inflight.is_empty()
    }
}

/// A finalized request leaving the [`Pipeline`], with the timestamps its
/// latency decomposition needs.
pub(crate) struct Finished {
    /// Pipeline-issued request id — the [`crate::obs::ReqId`] its trace
    /// events carry.
    pub(crate) id: u64,
    pub(crate) seq: usize,
    pub(crate) arrival_ns: u64,
    pub(crate) admitted_ns: u64,
    pub(crate) resp: Response,
}

/// The in-flight slot of request `id` (ids are issued in submission order,
/// so the deque is sorted by id).
fn slot_mut(inflight: &mut VecDeque<Staged>, id: u64) -> &mut Slot {
    let at = inflight
        .binary_search_by_key(&id, |s| s.id)
        .unwrap_or_else(|_| panic!("pool result for request {id} not in flight"));
    &mut inflight[at].slot
}

impl Coordinator {
    /// Serve one request.
    pub fn serve_one(&mut self, req: Request) -> Response {
        match req.materialize() {
            Request::Dgemm { a, b, c } => {
                let n = a.rows();
                let r = self.dgemm(&a, &b, &c);
                dgemm_response(n, r)
            }
            Request::RandomDgemm { .. } | Request::RandomFactor { .. } => {
                unreachable!("materialize() resolved synthetics")
            }
            req @ (Request::Dgeqrf { .. } | Request::Dgetrf { .. } | Request::Dpotrf { .. }) => {
                self.serve_factor_blocking(req)
            }
            other => {
                let meas = self.measure_blocking(meas_spec(&other, self.cfg.ae));
                self.measured_response(NO_REQ, other, meas)
            }
        }
    }

    /// Serve one factorization to completion through the graph-aware
    /// pipeline — factorizations are inherently multi-kernel, so even the
    /// sequential path drives a (single-request) DAG dispatch loop. The
    /// batched path produces identical responses (same staged kernels,
    /// same deterministic schedule).
    fn serve_factor_blocking(&mut self, req: Request) -> Response {
        let mut pipe = Pipeline::new(&self.cfg);
        pipe.stats.requests = 1;
        let bytes = self.cfg.staged_bytes(&req);
        self.admit(&mut pipe, req, bytes, 0, 0, 0);
        loop {
            if let Some(fin) = self.pop_ready(&mut pipe) {
                self.trace(|| Event {
                    req: fin.id,
                    sim: fin.resp.cycles,
                    host_ns: None,
                    kind: EventKind::Completed {
                        queue_ns: 0,
                        service_ns: 0,
                        cycles: fin.resp.cycles,
                    },
                });
                return fin.resp;
            }
            self.drain_blocking(&mut pipe);
        }
    }

    /// Build the response for a Level-1/2 request whose simulated cost is
    /// `meas` — the one place those request values are resolved, shared by
    /// `serve_one` and the batched path so they cannot drift apart.
    ///
    /// Under a modeled fabric the kernel is placed on a compute tile and
    /// its operand stream + result write-back are priced on the mesh:
    /// `cycles` becomes the absolute fabric cycle the result lands instead
    /// of the kernel latency alone.
    fn measured_response(&mut self, id: u64, req: Request, meas: Measurement) -> Response {
        let operand_words = self.cfg.staged_bytes(&req) / 8;
        let result_words = match &req {
            Request::Dgemv { a, .. } => a.rows() as u64,
            Request::Daxpy { x, .. } => x.len() as u64,
            Request::Ddot { .. } | Request::Dnrm2 { .. } => 1,
            Request::Dgemm { .. }
            | Request::RandomDgemm { .. }
            | Request::Dgeqrf { .. }
            | Request::Dgetrf { .. }
            | Request::Dpotrf { .. }
            | Request::RandomFactor { .. } => 0,
        };
        let cycles = match self.shared.fabric.as_ref() {
            Some(fabric) => {
                let job = {
                    let mut fab = fabric.lock().expect("fabric lock");
                    fab.route_job(self.home_row, operand_words, meas.latency(), result_words)
                };
                self.trace(|| Event {
                    req: id,
                    sim: job.depart,
                    host_ns: None,
                    kind: EventKind::FabricRouted {
                        tile: job.tile,
                        depart: job.depart,
                        ready: job.ready,
                        finish: job.finish,
                        compute: job.compute,
                    },
                });
                job.finish
            }
            None => meas.latency(),
        };
        let (op, n, source, vector, scalar) = match req {
            Request::Dgemv { a, x, y } => {
                let n = a.rows();
                let (v, source) = self.gemv_value(&a, &x, &y);
                ("dgemv", n, source, Some(v), None)
            }
            Request::Ddot { x, y } => {
                let n = x.len();
                let (d, source) = self.ddot_value(&x, &y);
                ("ddot", n, source, None, Some(d))
            }
            Request::Daxpy { alpha, x, y } => {
                let n = x.len();
                let (v, source) = self.daxpy_value(alpha, &x, &y);
                ("daxpy", n, source, Some(v), None)
            }
            Request::Dnrm2 { x } => {
                let n = x.len();
                let (s, source) = self.dnrm2_value(&x);
                ("dnrm2", n, source, None, Some(s))
            }
            Request::Dgemm { .. }
            | Request::RandomDgemm { .. }
            | Request::Dgeqrf { .. }
            | Request::Dgetrf { .. }
            | Request::Dpotrf { .. }
            | Request::RandomFactor { .. } => {
                unreachable!("measured_response() is for Level-1/2 requests")
            }
        };
        Response {
            op,
            n,
            source,
            cycles,
            energy_j: None,
            matrix: None,
            vector,
            scalar,
            factor: None,
        }
    }

    /// Serve a batch of requests strictly in order, returning all
    /// responses (the reference semantics; no cross-request overlap).
    pub fn serve(&mut self, reqs: Vec<Request>) -> Vec<Response> {
        reqs.into_iter().map(|r| self.serve_one(r)).collect()
    }

    /// Serve a batch with cross-request pipelining under a bounded
    /// admission window. Up to `admission_window` requests — and, when
    /// `admission_bytes` is set, at most that many bytes of packed GM
    /// images — are staged at once: every DGEMM's tile jobs and every
    /// Level-1/2 measurement kernel go to the persistent pool, identical
    /// in-flight measurements are shared, and responses are finalized in
    /// submission order as the oldest request completes (freeing its
    /// admission slot and its byte budget). With
    /// [`CoordinatorConfig::replay_batch`] set, staged DGEMM tiles that
    /// share a cached kernel are additionally coalesced into
    /// replay-batched pool jobs (the tier-2b fast path) before they ship.
    /// Responses match `serve_one`-in-a-loop exactly (values, cycles and
    /// energy — simulated timing is independent of host scheduling).
    ///
    /// # Examples
    ///
    /// ```no_run
    /// use redefine_blas::coordinator::{request::Request, Coordinator, CoordinatorConfig};
    ///
    /// let cfg = CoordinatorConfig { admission_window: Some(4), ..CoordinatorConfig::default() };
    /// let mut co = Coordinator::new(cfg);
    /// let reqs = vec![
    ///     Request::RandomDgemm { n: 16, seed: 1 },
    ///     Request::Ddot { x: vec![1.0; 32], y: vec![2.0; 32] },
    /// ];
    /// let resps = co.serve_batch(reqs);
    /// assert_eq!(resps.len(), 2);
    /// let stats = co.last_batch_stats().unwrap();
    /// assert!(stats.peak_staged <= 4);
    /// ```
    pub fn serve_batch(&mut self, reqs: Vec<Request>) -> Vec<Response> {
        let total = reqs.len();
        let mut pipe = Pipeline::new(&self.cfg);
        pipe.stats.requests = total;
        let mut queue = reqs.into_iter().peekable();
        let mut resps: Vec<Response> = Vec::with_capacity(total);

        while resps.len() < total {
            // Admit requests up to the window and the byte budget.
            while let Some(next) = queue.peek() {
                let bytes = self.cfg.staged_bytes(next);
                if !pipe.has_room(bytes) {
                    break;
                }
                let req = queue.next().expect("peeked above");
                let seq = pipe.next_id as usize;
                self.admit(&mut pipe, req, bytes, seq, 0, 0);
            }

            // Finalize completed requests from the front, in submission
            // order, freeing admission slots and budget.
            while let Some(fin) = self.pop_ready(&mut pipe) {
                self.trace(|| Event {
                    req: fin.id,
                    sim: fin.resp.cycles,
                    host_ns: None,
                    kind: EventKind::Completed {
                        queue_ns: 0,
                        service_ns: 0,
                        cycles: fin.resp.cycles,
                    },
                });
                resps.push(fin.resp);
            }
            // Refill freed slots before blocking, so the pool stays busy —
            // but only if the next request actually fits the byte budget
            // (otherwise we must block for completions to free budget).
            if let Some(next) = queue.peek() {
                if pipe.has_room(self.cfg.staged_bytes(next)) {
                    continue;
                }
            }
            if pipe.idle() {
                continue; // batch drained (loop condition exits)
            }

            // Block for one pooled result and record it.
            self.drain_blocking(&mut pipe);
        }
        self.set_last_batch_stats(pipe.stats);
        resps
    }

    /// Admit one request into the pipeline: stage its kernels, pin its
    /// bytes, and append its completion slot in submission order.
    pub(crate) fn admit(
        &mut self,
        pipe: &mut Pipeline,
        req: Request,
        bytes: u64,
        seq: usize,
        arrival_ns: u64,
        admitted_ns: u64,
    ) {
        let id = pipe.next_id;
        pipe.next_id += 1;
        let req = req.materialize();
        self.trace(|| Event {
            req: id,
            sim: 0,
            host_ns: None,
            kind: EventKind::Admitted { seq, op: req.name(), n: req.n(), bytes },
        });
        // Staging runs on the dispatcher thread, so the tenant tally's
        // delta across it is exactly this request's cache traffic.
        let cache_before = self.traced().then(|| self.tally.counts());
        let slot = self.stage(
            id,
            req,
            &mut pipe.waiting,
            &mut pipe.submitted,
            &mut pipe.batcher,
            &mut pipe.stats,
            &mut pipe.node_jobs,
            &mut pipe.next_id,
        );
        if let Some(before) = cache_before {
            self.trace_cache_delta(id, before);
        }
        pipe.inflight.push_back(Staged { id, bytes, seq, arrival_ns, admitted_ns, slot });
        pipe.staged_bytes += bytes;
        pipe.stats.peak_staged = pipe.stats.peak_staged.max(pipe.inflight.len());
        pipe.stats.peak_staged_bytes = pipe.stats.peak_staged_bytes.max(pipe.staged_bytes);
    }

    /// Finalize the oldest admitted request if it has completed, freeing
    /// its admission slot and byte budget. Completion is strictly in
    /// submission order (the order responses must be returned in), so a
    /// finished request behind an unfinished one stays queued.
    pub(crate) fn pop_ready(&mut self, pipe: &mut Pipeline) -> Option<Finished> {
        if !pipe.inflight.front().is_some_and(|s| s.slot.complete()) {
            return None;
        }
        let staged = pipe.inflight.pop_front().expect("front checked above");
        pipe.staged_bytes -= staged.bytes;
        Some(Finished {
            id: staged.id,
            seq: staged.seq,
            arrival_ns: staged.arrival_ns,
            admitted_ns: staged.admitted_ns,
            resp: self.finalize(staged.id, staged.slot),
        })
    }

    /// Emit one cache trace event per hit / miss / eviction this tenant's
    /// tally gained since `before` (a [`super::cache::CacheTally::counts`]
    /// snapshot taken on the dispatcher thread before staging).
    fn trace_cache_delta(&self, id: u64, before: (u64, u64, u64)) {
        let (h0, m0, e0) = before;
        let (h1, m1, e1) = self.tally.counts();
        for _ in h0..h1 {
            self.trace(|| Event { req: id, sim: 0, host_ns: None, kind: EventKind::CacheHit });
        }
        for _ in m0..m1 {
            self.trace(|| Event { req: id, sim: 0, host_ns: None, kind: EventKind::CacheMiss });
        }
        for _ in e0..e1 {
            self.trace(|| Event { req: id, sim: 0, host_ns: None, kind: EventKind::CacheEvicted });
        }
    }

    /// Submit one pool job, tracing a `Dispatched` event for every member
    /// request it carries. A coalesced replay batch charges each member
    /// its own share of the group's cost estimate.
    fn submit_job(&mut self, job: Job) {
        if self.traced() {
            let lane = self.pool.lane();
            let cost = job.cost_estimate();
            match &job {
                Job::ReplayBatch { members, .. } => {
                    let each = cost / members.len().max(1) as u64;
                    for (job_id, _, _) in members {
                        let req = *job_id;
                        self.trace(|| Event {
                            req,
                            sim: 0,
                            host_ns: None,
                            kind: EventKind::Dispatched { lane, cost: each },
                        });
                    }
                }
                Job::GemmTile { job_id, .. }
                | Job::Gemv { job_id, .. }
                | Job::Level1 { job_id, .. } => {
                    let req = *job_id;
                    self.trace(|| Event {
                        req,
                        sim: 0,
                        host_ns: None,
                        kind: EventKind::Dispatched { lane, cost },
                    });
                }
            }
        }
        self.pool.submit(job);
    }

    /// Ship every partially filled coalescer group: a tile about to be
    /// waited on must already be on the pool.
    fn flush_staged(&mut self, pipe: &mut Pipeline) {
        for job in pipe.batcher.drain() {
            self.submit_job(job);
        }
    }

    /// Submit one factorization DAG node's kernel to the pool: allocate a
    /// pool job id from the pipeline counter, record its owner, fetch the
    /// cached program (**counted** — every node is a first-class program
    /// cache customer, so repeated same-shape factorizations read as warm
    /// hits), pack fixed-seed operands and enqueue. Node kernels are
    /// priced, queued and scheduled exactly like flat requests' jobs —
    /// same WRR lanes, same lane-cycle currency.
    ///
    /// Deliberately traceless: successor submissions are driven by racy
    /// worker completions, so the per-node `Dispatched` events are
    /// re-emitted in node order at finalize (like DGEMM tile tiers),
    /// keeping the simulated event log deterministic. Cache traffic is
    /// tallied into the tenant's `CacheStats` counters either way; the
    /// per-event cache log covers the admission-time staging window only.
    fn submit_node(
        &mut self,
        owner: u64,
        node: usize,
        call: KernelCall,
        node_jobs: &mut HashMap<u64, (u64, usize)>,
        next_id: &mut u64,
    ) {
        let job_id = *next_id;
        *next_id += 1;
        node_jobs.insert(job_id, (owner, node));
        let ae = self.cfg.ae;
        let job = match call {
            KernelCall::Gemm { m, p, k } => {
                let (mp, pp, kp) = (round_up(m, 4), round_up(p, 4), round_up(k, 4));
                let sched = self.cache().gemm_rect_for(mp, pp, kp, ae, Some(&self.tally));
                let layout = GemmLayout::rect(mp, pp, kp);
                // Fixed operand seeds: PE timing is data-independent, so
                // the node's simulated cost depends only on its shape.
                let gm = layout.pack(
                    &Mat::random(mp, kp, 0xDA6),
                    &Mat::random(kp, pp, 0xDA7),
                    &Mat::zeros(mp, pp),
                );
                Job::GemmTile { job_id, tile_idx: node, sched, layout, gm }
            }
            KernelCall::Gemv { n } => {
                let np = round_up(n, 4);
                let sched = self.cache().gemv_for(np, ae, Some(&self.tally));
                Job::Gemv { job_id, n: np, sched }
            }
            KernelCall::Level1 { routine, n, alpha } => {
                let np = round_up(n.max(4), 4);
                let sched = self.cache().level1_for(routine, np, alpha, ae, Some(&self.tally));
                Job::Level1 { job_id, routine, n: np, alpha, sched }
            }
        };
        self.pool.submit(job);
    }

    /// Record one pooled result into its in-flight slot. Factorization
    /// node results are recognized by pool job id first: a node job is
    /// owned by its factorization request, not by a slot of its own.
    fn absorb(&mut self, pipe: &mut Pipeline, done: Done) {
        match done {
            Done::GemmTile { job_id, tile_idx, out, stats, tier } => {
                if let Some((owner, node)) = pipe.node_jobs.remove(&job_id) {
                    debug_assert_eq!(tile_idx, node, "node index rides in tile_idx");
                    drop(out); // node values resolve host-side
                    self.absorb_node(pipe, owner, node, stats, tier);
                    return;
                }
                match slot_mut(&mut pipe.inflight, job_id) {
                    Slot::Dgemm { tiles, got, tiers, .. } => {
                        debug_assert!(tiles[tile_idx].is_none(), "duplicate tile");
                        tiles[tile_idx] = Some((out, stats));
                        tiers.push((tile_idx, tier));
                        *got += 1;
                    }
                    // Factor nodes were intercepted via `node_jobs` above.
                    Slot::Meas { .. } | Slot::Factor { .. } => {
                        unreachable!("tile for a non-DGEMM slot")
                    }
                }
            }
            Done::Measured { job_id, meas, tier } => {
                if let Some((owner, node)) = pipe.node_jobs.remove(&job_id) {
                    self.absorb_node(pipe, owner, node, meas.stats, tier);
                    return;
                }
                let key = pipe.submitted.remove(&job_id).expect("measurement without a key");
                self.cache().store_measurement(key, meas.clone());
                for id in pipe.waiting.remove(&key).unwrap_or_default() {
                    match slot_mut(&mut pipe.inflight, id) {
                        // Only the submitter executed a kernel; sharers
                        // attached to its result.
                        Slot::Meas { meas: m, tier: t, .. } => {
                            *m = Some(Box::new(meas.clone()));
                            if id == job_id {
                                *t = Some(tier);
                            }
                        }
                        Slot::Dgemm { .. } | Slot::Factor { .. } => {
                            unreachable!("measurement for a non-Level-1/2 slot")
                        }
                    }
                }
            }
        }
    }

    /// Record one completed factorization node and dispatch whatever its
    /// completion released — the dependency-aware step: a successor's
    /// kernel reaches the shared worker queue only here, strictly after
    /// its last predecessor's result came back.
    fn absorb_node(
        &mut self,
        pipe: &mut Pipeline,
        owner: u64,
        node: usize,
        stats: PeStats,
        tier: Tier,
    ) {
        let released: Vec<(usize, KernelCall)> = match slot_mut(&mut pipe.inflight, owner) {
            Slot::Factor { graph, state, nodes, .. } => {
                debug_assert!(nodes[node].is_none(), "duplicate node result");
                nodes[node] = Some((stats, tier));
                state.complete(node).into_iter().map(|s| (s, graph.node(s).call)).collect()
            }
            _ => unreachable!("node result for a non-factorization slot"),
        };
        for (succ, call) in released {
            self.submit_node(owner, succ, call, &mut pipe.node_jobs, &mut pipe.next_id);
        }
    }

    /// Flush the coalescer, then block for one pooled result and record
    /// it — the closed-loop wait step.
    pub(crate) fn drain_blocking(&mut self, pipe: &mut Pipeline) {
        self.flush_staged(pipe);
        let done = self.recv_done();
        self.absorb(pipe, done);
    }

    /// Flush the coalescer, then absorb one pooled result if one is ready.
    /// Returns whether progress was made — the open-loop wait step, which
    /// must keep watching the arrival clock instead of parking.
    pub(crate) fn try_drain(&mut self, pipe: &mut Pipeline) -> bool {
        self.flush_staged(pipe);
        match self.try_recv_done() {
            Some(done) => {
                self.absorb(pipe, done);
                true
            }
            None => false,
        }
    }

    /// Stage one materialized request: a DGEMM enqueues its tile kernels; a
    /// Level-1/2 request resolves its measurement from the cache, attaches
    /// to an identical in-flight kernel, or submits a new one to the pool;
    /// a factorization expands into its kernel DAG and enqueues only the
    /// DAG's initial ready set (successors follow from `absorb`).
    #[allow(clippy::too_many_arguments)]
    fn stage(
        &mut self,
        id: u64,
        req: Request,
        waiting: &mut HashMap<ProgramKey, Vec<u64>>,
        submitted: &mut HashMap<u64, ProgramKey>,
        batcher: &mut TileBatcher,
        stats: &mut BatchStats,
        node_jobs: &mut HashMap<u64, (u64, usize)>,
        next_id: &mut u64,
    ) -> Slot {
        match req {
            Request::Dgemm { a, b, c } => {
                let (pending, staged) = self.prepare_dgemm(id, &a, &b, &c);
                for job in batcher.add(staged) {
                    self.submit_job(job);
                }
                let tiles = vec![None; pending.tile_count()];
                Slot::Dgemm {
                    flight: Box::new(InFlight { pending, a, b, c }),
                    tiles,
                    got: 0,
                    tiers: Vec::new(),
                }
            }
            Request::RandomDgemm { .. } | Request::RandomFactor { .. } => {
                unreachable!("materialize() resolved synthetics")
            }
            req @ (Request::Dgeqrf { .. } | Request::Dgetrf { .. } | Request::Dpotrf { .. }) => {
                let (kind, a) = match req {
                    Request::Dgeqrf { a } => (FactorKind::Qr, a),
                    Request::Dgetrf { a } => (FactorKind::Lu, a),
                    Request::Dpotrf { a } => (FactorKind::Chol, a),
                    _ => unreachable!("matched above"),
                };
                // Host factors + flop profile resolve at expansion time;
                // the DAG carries only timing kernels from here on.
                let expand::Expansion { graph, factors, profile, .. } = expand::expand(kind, &a);
                let state = ExecState::new(&graph);
                let nodes = vec![None; graph.len()];
                // Dependency-aware dispatch, step 1: only nodes with no
                // predecessors reach the pool at staging. Every other
                // node is submitted by `absorb` when its last
                // predecessor's result lands.
                for node in state.initial_ready() {
                    let call = graph.node(node).call;
                    self.submit_node(id, node, call, node_jobs, next_id);
                }
                Slot::Factor {
                    kind,
                    n: a.rows(),
                    graph,
                    state,
                    factors: Box::new(factors),
                    profile,
                    nodes,
                }
            }
            other => {
                let spec = meas_spec(&other, self.cfg.ae);
                let meas = self.cached_measurement_tallied(&spec.key);
                if meas.is_none() {
                    match waiting.entry(spec.key) {
                        Entry::Occupied(mut e) => {
                            // An identical kernel is in flight: attach
                            // instead of duplicating the simulation. Counts
                            // as a warm hit, as it would sequentially.
                            self.record_cache_hit();
                            stats.shared_measurements += 1;
                            e.get_mut().push(id);
                        }
                        Entry::Vacant(e) => {
                            // Pays the simulation: submit_measure records
                            // the request's one cache miss (the memo was
                            // empty) and fetches the program quietly.
                            self.submit_measure(id, &spec);
                            submitted.insert(id, spec.key);
                            e.insert(vec![id]);
                        }
                    }
                }
                Slot::Meas { req: other, meas: meas.map(Box::new), tier: None }
            }
        }
    }

    /// Memoized-measurement lookup charged to this tenant's tally.
    fn cached_measurement_tallied(&self, key: &ProgramKey) -> Option<Measurement> {
        self.cache().cached_measurement_for(key, Some(&self.tally))
    }

    /// Record an in-flight-shared kernel as a warm hit on this tenant.
    fn record_cache_hit(&self) {
        self.cache().record_hit(Some(&self.tally));
    }

    /// Merge one completed slot into its response.
    fn finalize(&mut self, id: u64, slot: Slot) -> Response {
        match slot {
            Slot::Dgemm { flight, tiles, mut tiers, .. } => {
                // Workers race, so tiles arrive in host order; report
                // execution tiers in tile order to keep the event log's
                // simulated view deterministic.
                tiers.sort_unstable_by_key(|&(idx, _)| idx);
                for (_, tier) in tiers {
                    self.trace(|| Event {
                        req: id,
                        sim: 0,
                        host_ns: None,
                        kind: EventKind::Executed { tier },
                    });
                }
                let InFlight { pending, a, b, c } = *flight;
                let outs = seal_slots(tiles);
                let n = a.rows();
                let r = self.finish_dgemm(pending, outs, &a, &b, &c);
                dgemm_response(n, r)
            }
            Slot::Meas { req, meas, tier } => {
                if let Some(tier) = tier {
                    self.trace(|| Event {
                        req: id,
                        sim: 0,
                        host_ns: None,
                        kind: EventKind::Executed { tier },
                    });
                }
                let meas = meas.expect("finalize() called on an incomplete slot");
                self.measured_response(id, req, *meas)
            }
            Slot::Factor { kind, n, graph, factors, profile, nodes, .. } => {
                let per: Vec<(PeStats, Tier)> = nodes
                    .into_iter()
                    .map(|s| s.expect("finalize() called on an incomplete DAG"))
                    .collect();
                // Worker-side truth re-emitted in node order, like DGEMM
                // tiles, so the log is independent of worker racing.
                // Successor submissions happen on racy completion order,
                // so their `Dispatched` events are also deferred to here.
                let lane = self.pool.lane();
                for (stats, _) in &per {
                    let cost = stats.cycles;
                    self.trace(|| Event {
                        req: id,
                        sim: 0,
                        host_ns: None,
                        kind: EventKind::Dispatched { lane, cost },
                    });
                }
                for &(_, tier) in &per {
                    self.trace(|| Event {
                        req: id,
                        sim: 0,
                        host_ns: None,
                        kind: EventKind::Executed { tier },
                    });
                }
                // Deterministic topological schedule over the node
                // kernel cycles (start = max predecessor finish): its
                // anchors drive the DAG trace events — release never
                // precedes the releasing completion — and its makespan
                // is the off-fabric response cost (the critical path).
                let node_cycles: Vec<u64> = per.iter().map(|(s, _)| s.cycles).collect();
                let sched = graph.schedule(&node_cycles);
                let makespan = sched.iter().map(|&(_, fin)| fin).max().unwrap_or(0);
                for (i, &(start, _)) in sched.iter().enumerate() {
                    let call = graph.node(i).call;
                    self.trace(|| Event {
                        req: id,
                        sim: start,
                        host_ns: None,
                        kind: EventKind::NodeReleased { node: i, call: call.tag(), n: call.n() },
                    });
                }
                for (i, &(_, finish)) in sched.iter().enumerate() {
                    let cycles = node_cycles[i];
                    self.trace(|| Event {
                        req: id,
                        sim: finish,
                        host_ns: None,
                        kind: EventKind::NodeCompleted { node: i, cycles },
                    });
                }
                // Energy: Σ node kernel energies under the paper model.
                let power = PowerModel::paper();
                let pe_cfg = PeConfig::paper(self.cfg.ae);
                let energy: f64 = per
                    .iter()
                    .map(|(s, _)| power.energy_joules(self.cfg.ae, &pe_cfg, s))
                    .sum();
                // Under a fabric, each node's operand stream and result
                // write-back (its region of the factor matrix) is priced
                // on the mesh in node order; the response cost is the
                // last landing, floored by the compute critical path
                // (link/tile contention is modeled, dependency stalls are
                // already captured by the makespan). Off-fabric, delivery
                // is free and the cost is the DAG critical path.
                let cycles = match self.shared.fabric.as_ref() {
                    Some(fabric) => {
                        let routed: Vec<_> = {
                            let mut fab = fabric.lock().expect("fabric lock");
                            per.iter()
                                .enumerate()
                                .map(|(i, (s, _))| {
                                    let words = graph.node(i).binding.words();
                                    fab.route_job(self.home_row, words, s.cycles, words)
                                })
                                .collect()
                        };
                        let mut last = makespan;
                        for job in routed {
                            last = last.max(job.finish);
                            self.trace(|| Event {
                                req: id,
                                sim: job.depart,
                                host_ns: None,
                                kind: EventKind::FabricRouted {
                                    tile: job.tile,
                                    depart: job.depart,
                                    ready: job.ready,
                                    finish: job.finish,
                                    compute: job.compute,
                                },
                            });
                        }
                        last
                    }
                    None => makespan,
                };
                Response {
                    op: kind.op_name(),
                    n,
                    source: ValueSource::PeSim,
                    cycles,
                    energy_j: Some(energy),
                    matrix: None,
                    vector: None,
                    scalar: None,
                    factor: Some(Box::new(FactorOutcome {
                        factors: *factors,
                        profile,
                        nodes: per.len(),
                        makespan,
                    })),
                }
            }
        }
    }
}

/// Workload generator: a random mix of BLAS requests over all three
/// levels, the driver used by the end-to-end example and the throughput
/// bench. DAXPY α is drawn from a small set so repeated requests can share
/// baked-α programs.
pub fn random_workload(count: usize, max_n: usize, seed: u64) -> Vec<Request> {
    let mut rng = XorShift64::new(seed);
    let mut reqs = Vec::with_capacity(count);
    for i in 0..count {
        let n = 8 + rng.below(max_n.saturating_sub(8).max(1));
        match rng.below(5) {
            0 => reqs.push(Request::RandomDgemm { n, seed: seed + i as u64 }),
            1 => {
                let a = Mat::random(n, n, seed + i as u64);
                let x = rng.vec(n);
                let y = rng.vec(n);
                reqs.push(Request::Dgemv { a, x, y });
            }
            2 => {
                let x = rng.vec(n);
                let y = rng.vec(n);
                reqs.push(Request::Ddot { x, y });
            }
            3 => {
                let alpha = [0.5, 1.0, 1.5][rng.below(3)];
                let x = rng.vec(n);
                let y = rng.vec(n);
                reqs.push(Request::Daxpy { alpha, x, y });
            }
            _ => reqs.push(Request::Dnrm2 { x: rng.vec(n) }),
        }
    }
    reqs
}

/// Repeated-shape DGEMM workload: `count` requests, all n×n, distinct
/// operand seeds — the serving engine's cache-friendly steady state (and
/// the bench workload for the cached-vs-uncached comparison).
pub fn repeated_gemm_workload(count: usize, n: usize, seed: u64) -> Vec<Request> {
    (0..count).map(|i| Request::RandomDgemm { n, seed: seed + i as u64 }).collect()
}

/// Repeated-shape factorization workload: `count` same-kind, same-order
/// factorizations with distinct operand seeds — the DAG-serving steady
/// state, where every node kernel after the first factorization replays a
/// warm cached program.
pub fn factor_workload(kind: FactorKind, count: usize, n: usize, seed: u64) -> Vec<Request> {
    (0..count).map(|i| Request::RandomFactor { kind, n, seed: seed + i as u64 }).collect()
}

/// Mixed workload: the flat random mix with every fourth request replaced
/// by a factorization of order `lapack_n` (kinds rotating QR → LU →
/// Cholesky), so factorization DAGs and flat BLAS share one pipeline.
pub fn mixed_lapack_workload(
    count: usize,
    max_n: usize,
    lapack_n: usize,
    seed: u64,
) -> Vec<Request> {
    let kinds = [FactorKind::Qr, FactorKind::Lu, FactorKind::Chol];
    let mut reqs = random_workload(count, max_n, seed);
    for (slot, i) in (0..reqs.len()).step_by(4).enumerate() {
        reqs[i] = Request::RandomFactor {
            kind: kinds[slot % kinds.len()],
            n: lapack_n,
            seed: seed ^ (0xFAC0 + i as u64),
        };
    }
    reqs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::CoordinatorConfig;
    use crate::pe::AeLevel;

    fn coord() -> Coordinator {
        Coordinator::new(CoordinatorConfig {
            ae: AeLevel::Ae5,
            b: 2,
            artifact_dir: "/nonexistent".into(),
            verify: false,
            ..CoordinatorConfig::default()
        })
    }

    #[test]
    fn serves_mixed_workload() {
        let reqs = random_workload(8, 24, 99);
        assert_eq!(reqs.len(), 8);
        let mut co = coord();
        let resps = co.serve(reqs);
        assert_eq!(resps.len(), 8);
        for r in &resps {
            assert!(r.cycles > 0, "{} has zero cycles", r.op);
            let payloads = r.matrix.is_some() as u8
                + r.vector.is_some() as u8
                + r.scalar.is_some() as u8
                + r.factor.is_some() as u8;
            assert_eq!(payloads, 1, "{} must carry exactly one payload", r.op);
        }
    }

    #[test]
    fn request_metadata() {
        let r = Request::RandomDgemm { n: 32, seed: 1 };
        assert_eq!(r.name(), "dgemm");
        assert_eq!(r.n(), 32);
        let r = Request::Daxpy { alpha: 2.0, x: vec![0.0; 12], y: vec![0.0; 12] };
        assert_eq!(r.name(), "daxpy");
        assert_eq!(r.n(), 12);
        let r = Request::Dnrm2 { x: vec![0.0; 5] };
        assert_eq!(r.name(), "dnrm2");
        assert_eq!(r.n(), 5);
    }

    #[test]
    fn staged_bytes_prices_shapes_not_values() {
        let cfg = CoordinatorConfig { ae: AeLevel::Ae5, b: 2, ..CoordinatorConfig::default() };
        // A 16×16 DGEMM on a 2×2 array: 4 tiles of (8·16 + 16·8 + 8·8)
        // words = 4 · 320 · 8 bytes.
        let dgemm = Request::RandomDgemm { n: 16, seed: 1 };
        assert_eq!(cfg.staged_bytes(&dgemm), 4 * 320 * 8);
        // Synthetic and concrete requests of the same shape price equally.
        let conc = dgemm.clone().materialize();
        assert_eq!(cfg.staged_bytes(&conc), cfg.staged_bytes(&dgemm));
        // Level-1: x | y | 4 scratch words.
        let ddot = Request::Ddot { x: vec![0.0; 16], y: vec![0.0; 16] };
        assert_eq!(cfg.staged_bytes(&ddot), (16 + 16 + 4) * 8);
        // Residual mode prices the unpadded single-PE image.
        let rcfg = CoordinatorConfig { residual: true, ..cfg };
        let odd = Request::RandomDgemm { n: 10, seed: 2 };
        assert_eq!(rcfg.staged_bytes(&odd), 3 * 100 * 8);
    }

    #[test]
    fn factor_requests_have_metadata_and_prices() {
        let r = Request::RandomFactor { kind: FactorKind::Qr, n: 24, seed: 3 };
        assert_eq!(r.name(), "dgeqrf");
        assert_eq!(r.n(), 24);
        let cfg = CoordinatorConfig::default();
        // A factorization pins its n×n operand: 8·n² bytes, shape-only.
        assert_eq!(cfg.staged_bytes(&r), 8 * 24 * 24);
        let conc = r.clone().materialize();
        assert!(matches!(conc, Request::Dgeqrf { .. }));
        assert_eq!(cfg.staged_bytes(&conc), 8 * 24 * 24);
        let lu = Request::RandomFactor { kind: FactorKind::Lu, n: 10, seed: 1 };
        assert_eq!(lu.name(), "dgetrf");
        assert!(matches!(lu.materialize(), Request::Dgetrf { .. }));
        let ch = Request::RandomFactor { kind: FactorKind::Chol, n: 10, seed: 1 };
        assert_eq!(ch.name(), "dpotrf");
        assert!(matches!(ch.materialize(), Request::Dpotrf { .. }));
    }

    #[test]
    fn served_factorization_carries_the_factor_payload() {
        let mut co = coord();
        let resp =
            co.serve_one(Request::RandomFactor { kind: FactorKind::Chol, n: 12, seed: 9 });
        assert_eq!(resp.op, "dpotrf");
        assert_eq!(resp.n, 12);
        assert!(resp.matrix.is_none() && resp.vector.is_none() && resp.scalar.is_none());
        let f = resp.factor.expect("factor payload");
        // n = 12, nb = 4 → 3 panels + 3 updates: a genuine multi-node DAG.
        assert_eq!(f.nodes, 6);
        assert!(f.makespan > 0);
        // Off-fabric the response cost is the DAG critical path.
        assert_eq!(resp.cycles, f.makespan);
        assert!(f.profile.total() > 0);
        assert!(resp.energy_j.expect("modelled energy") > 0.0);
    }

    #[test]
    fn factor_workloads_mix_and_repeat() {
        let reqs = factor_workload(FactorKind::Qr, 3, 16, 7);
        assert_eq!(reqs.len(), 3);
        assert!(reqs.iter().all(|r| r.name() == "dgeqrf" && r.n() == 16));
        let mixed = mixed_lapack_workload(9, 24, 16, 5);
        let factors =
            mixed.iter().filter(|r| matches!(r, Request::RandomFactor { .. })).count();
        assert_eq!(factors, 3, "every fourth request is a factorization");
        assert!(matches!(mixed[0], Request::RandomFactor { kind: FactorKind::Qr, .. }));
        assert!(matches!(mixed[4], Request::RandomFactor { kind: FactorKind::Lu, .. }));
        assert!(matches!(mixed[8], Request::RandomFactor { kind: FactorKind::Chol, .. }));
    }

    #[test]
    fn materialize_is_deterministic() {
        let r1 = Request::RandomDgemm { n: 12, seed: 7 }.materialize();
        let r2 = Request::RandomDgemm { n: 12, seed: 7 }.materialize();
        match (r1, r2) {
            (Request::Dgemm { a: a1, b: b1, c: c1 }, Request::Dgemm { a: a2, b: b2, c: c2 }) => {
                assert_eq!(a1, a2);
                assert_eq!(b1, b2);
                assert_eq!(c1, c2);
                assert_eq!(c1, Mat::zeros(12, 12));
            }
            _ => panic!("materialize must yield Dgemm"),
        }
    }

    #[test]
    fn ddot_request_value() {
        let mut co = coord();
        let resp = co.serve_one(Request::Ddot {
            x: vec![1.0, 2.0, 0.0, 0.0],
            y: vec![3.0, 4.0, 0.0, 0.0],
        });
        assert_eq!(resp.scalar, Some(11.0));
    }

    #[test]
    fn daxpy_and_dnrm2_request_values() {
        let mut co = coord();
        let resp = co.serve_one(Request::Daxpy {
            alpha: 2.0,
            x: vec![1.0, 2.0, 3.0, 4.0],
            y: vec![1.0, 1.0, 1.0, 1.0],
        });
        assert_eq!(resp.vector, Some(vec![3.0, 5.0, 7.0, 9.0]));
        let resp = co.serve_one(Request::Dnrm2 { x: vec![3.0, 4.0, 0.0, 0.0] });
        assert_eq!(resp.scalar, Some(5.0));
    }

    #[test]
    fn serve_batch_handles_mixed_and_empty() {
        let mut co = coord();
        assert!(co.serve_batch(Vec::new()).is_empty());
        let resps = co.serve_batch(random_workload(5, 20, 3));
        assert_eq!(resps.len(), 5);
        for r in &resps {
            assert!(r.cycles > 0);
        }
    }
}
