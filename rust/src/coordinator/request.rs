//! Request/response types and the coordinator's serve loops — the
//! "request path" of the system. Requests are BLAS calls; responses carry
//! values plus the simulated cost report. Everything here is pure Rust over
//! AOT artifacts: Python is never on this path.
//!
//! Two serving modes:
//! * [`Coordinator::serve`] — strictly sequential (one request fully
//!   completes before the next starts), kept as the reference semantics;
//! * [`Coordinator::serve_batch`] — the serving-engine path: every DGEMM's
//!   tile jobs are staged on the persistent worker pool up front, so tiles
//!   of independent requests are in flight simultaneously, while Level-1/2
//!   requests are answered inline. Responses come back in submission order
//!   and are value- and cycle-identical to `serve_one` (pinned by tests).

use super::{seal_slots, Coordinator, DgemmResult, PendingDgemm, TileSlots, ValueSource};
use crate::util::{Mat, XorShift64};
use std::collections::HashMap;

/// A BLAS request to the coordinator.
#[derive(Debug, Clone)]
pub enum Request {
    /// C ← A·B + C.
    Dgemm { a: Mat, b: Mat, c: Mat },
    /// y ← A·x + y.
    Dgemv { a: Mat, x: Vec<f64>, y: Vec<f64> },
    /// xᵀ·y.
    Ddot { x: Vec<f64>, y: Vec<f64> },
    /// Synthetic request by shape only (workload generators).
    RandomDgemm { n: usize, seed: u64 },
}

impl Request {
    /// Human-readable request tag.
    pub fn name(&self) -> &'static str {
        match self {
            Request::Dgemm { .. } | Request::RandomDgemm { .. } => "dgemm",
            Request::Dgemv { .. } => "dgemv",
            Request::Ddot { .. } => "ddot",
        }
    }

    /// Problem size n.
    pub fn n(&self) -> usize {
        match self {
            Request::Dgemm { a, .. } => a.rows(),
            Request::Dgemv { a, .. } => a.rows(),
            Request::Ddot { x, .. } => x.len(),
            Request::RandomDgemm { n, .. } => *n,
        }
    }

    /// Resolve synthetic requests into concrete operands. The single
    /// materialization rule shared by both serve paths, so batched and
    /// sequential serving see bit-identical inputs.
    pub fn materialize(self) -> Request {
        match self {
            Request::RandomDgemm { n, seed } => Request::Dgemm {
                a: Mat::random(n, n, seed),
                b: Mat::random(n, n, seed ^ 0xBEEF),
                c: Mat::zeros(n, n),
            },
            other => other,
        }
    }
}

/// Response: scalar/vector/matrix value + cost accounting.
#[derive(Debug)]
pub struct Response {
    pub op: &'static str,
    pub n: usize,
    pub source: ValueSource,
    /// Simulated latency in PE cycles (makespan for tiled ops).
    pub cycles: u64,
    /// Simulated energy (joules) where modelled (tiled DGEMM).
    pub energy_j: Option<f64>,
    /// Result payloads (exactly one is set).
    pub matrix: Option<Mat>,
    pub vector: Option<Vec<f64>>,
    pub scalar: Option<f64>,
}

/// The one place a [`DgemmResult`] becomes a [`Response`] — shared by the
/// sequential and batched paths so they cannot drift apart.
fn dgemm_response(n: usize, r: DgemmResult) -> Response {
    Response {
        op: "dgemm",
        n,
        source: r.source,
        cycles: r.makespan,
        energy_j: Some(r.energy_j),
        matrix: Some(r.c),
        vector: None,
        scalar: None,
    }
}

/// A DGEMM request whose tiles are on the pool, waiting to be merged.
struct InFlight {
    pending: PendingDgemm,
    a: Mat,
    b: Mat,
    c: Mat,
}

/// Per-request slot of a batch, in submission order.
enum Slot {
    Dgemm(Box<InFlight>),
    Ready(Response),
}

impl Coordinator {
    /// Serve one request.
    pub fn serve_one(&mut self, req: Request) -> Response {
        match req.materialize() {
            Request::Dgemm { a, b, c } => {
                let n = a.rows();
                let r = self.dgemm(&a, &b, &c);
                dgemm_response(n, r)
            }
            Request::Dgemv { a, x, y } => {
                let n = a.rows();
                let (v, meas, source) = self.dgemv(&a, &x, &y);
                Response {
                    op: "dgemv",
                    n,
                    source,
                    cycles: meas.latency(),
                    energy_j: None,
                    matrix: None,
                    vector: Some(v),
                    scalar: None,
                }
            }
            Request::Ddot { x, y } => {
                let n = x.len();
                let (d, meas, source) = self.ddot(&x, &y);
                Response {
                    op: "ddot",
                    n,
                    source,
                    cycles: meas.latency(),
                    energy_j: None,
                    matrix: None,
                    vector: None,
                    scalar: Some(d),
                }
            }
            Request::RandomDgemm { .. } => unreachable!("materialize() resolved synthetics"),
        }
    }

    /// Serve a batch of requests strictly in order, returning all
    /// responses (the reference semantics; no cross-request overlap).
    pub fn serve(&mut self, reqs: Vec<Request>) -> Vec<Response> {
        reqs.into_iter().map(|r| self.serve_one(r)).collect()
    }

    /// Serve a batch with cross-request pipelining. Every DGEMM's tile jobs
    /// go to the persistent pool immediately, so the pool stays busy across
    /// request boundaries; Level-1/2 requests are simulated inline on the
    /// dispatcher thread while tiles drain. Responses are returned in
    /// submission order and match `serve_one`-in-a-loop exactly (values,
    /// cycles and energy — simulated timing is independent of host
    /// scheduling).
    pub fn serve_batch(&mut self, reqs: Vec<Request>) -> Vec<Response> {
        // Phase 1: stage everything.
        let mut slots = Vec::with_capacity(reqs.len());
        let mut in_flight_tiles = 0usize;
        for (i, req) in reqs.into_iter().enumerate() {
            match req.materialize() {
                Request::Dgemm { a, b, c } => {
                    let pending = self.submit_dgemm(i as u64, &a, &b, &c);
                    in_flight_tiles += pending.tile_count();
                    slots.push(Slot::Dgemm(Box::new(InFlight { pending, a, b, c })));
                }
                other => slots.push(Slot::Ready(self.serve_one(other))),
            }
        }

        // Phase 2: drain the pool; tiles arrive in any order across jobs.
        let mut collected: HashMap<u64, TileSlots> = HashMap::new();
        for _ in 0..in_flight_tiles {
            let d = self.recv_tile();
            let count = match &slots[d.job_id as usize] {
                Slot::Dgemm(f) => f.pending.tile_count(),
                Slot::Ready(_) => unreachable!("tile for a non-DGEMM slot"),
            };
            let entry = collected.entry(d.job_id).or_insert_with(|| vec![None; count]);
            entry[d.tile_idx] = Some((d.out, d.stats));
        }

        // Phase 3: merge in submission order.
        let mut resps = Vec::with_capacity(slots.len());
        for (i, slot) in slots.into_iter().enumerate() {
            match slot {
                Slot::Ready(r) => resps.push(r),
                Slot::Dgemm(flight) => {
                    let InFlight { pending, a, b, c } = *flight;
                    let outs = seal_slots(collected.remove(&(i as u64)).expect("tiles lost"));
                    let n = a.rows();
                    let r = self.finish_dgemm(pending, outs, &a, &b, &c);
                    resps.push(dgemm_response(n, r));
                }
            }
        }
        resps
    }
}

/// Workload generator: a random mix of BLAS requests, the driver used by
/// the end-to-end example and the throughput bench.
pub fn random_workload(count: usize, max_n: usize, seed: u64) -> Vec<Request> {
    let mut rng = XorShift64::new(seed);
    let mut reqs = Vec::with_capacity(count);
    for i in 0..count {
        let n = 8 + rng.below(max_n.saturating_sub(8).max(1));
        match rng.below(3) {
            0 => reqs.push(Request::RandomDgemm { n, seed: seed + i as u64 }),
            1 => {
                let a = Mat::random(n, n, seed + i as u64);
                let x = rng.vec(n);
                let y = rng.vec(n);
                reqs.push(Request::Dgemv { a, x, y });
            }
            _ => {
                let x = rng.vec(n);
                let y = rng.vec(n);
                reqs.push(Request::Ddot { x, y });
            }
        }
    }
    reqs
}

/// Repeated-shape DGEMM workload: `count` requests, all n×n, distinct
/// operand seeds — the serving engine's cache-friendly steady state (and
/// the bench workload for the cached-vs-uncached comparison).
pub fn repeated_gemm_workload(count: usize, n: usize, seed: u64) -> Vec<Request> {
    (0..count).map(|i| Request::RandomDgemm { n, seed: seed + i as u64 }).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::CoordinatorConfig;
    use crate::pe::AeLevel;

    fn coord() -> Coordinator {
        Coordinator::new(CoordinatorConfig {
            ae: AeLevel::Ae5,
            b: 2,
            artifact_dir: "/nonexistent".into(),
            verify: false,
        })
    }

    #[test]
    fn serves_mixed_workload() {
        let reqs = random_workload(6, 24, 99);
        assert_eq!(reqs.len(), 6);
        let mut co = coord();
        let resps = co.serve(reqs);
        assert_eq!(resps.len(), 6);
        for r in &resps {
            assert!(r.cycles > 0, "{} has zero cycles", r.op);
            let payloads =
                r.matrix.is_some() as u8 + r.vector.is_some() as u8 + r.scalar.is_some() as u8;
            assert_eq!(payloads, 1, "{} must carry exactly one payload", r.op);
        }
    }

    #[test]
    fn request_metadata() {
        let r = Request::RandomDgemm { n: 32, seed: 1 };
        assert_eq!(r.name(), "dgemm");
        assert_eq!(r.n(), 32);
    }

    #[test]
    fn materialize_is_deterministic() {
        let r1 = Request::RandomDgemm { n: 12, seed: 7 }.materialize();
        let r2 = Request::RandomDgemm { n: 12, seed: 7 }.materialize();
        match (r1, r2) {
            (Request::Dgemm { a: a1, b: b1, c: c1 }, Request::Dgemm { a: a2, b: b2, c: c2 }) => {
                assert_eq!(a1, a2);
                assert_eq!(b1, b2);
                assert_eq!(c1, c2);
                assert_eq!(c1, Mat::zeros(12, 12));
            }
            _ => panic!("materialize must yield Dgemm"),
        }
    }

    #[test]
    fn ddot_request_value() {
        let mut co = coord();
        let resp = co.serve_one(Request::Ddot {
            x: vec![1.0, 2.0, 0.0, 0.0],
            y: vec![3.0, 4.0, 0.0, 0.0],
        });
        assert_eq!(resp.scalar, Some(11.0));
    }

    #[test]
    fn serve_batch_handles_mixed_and_empty() {
        let mut co = coord();
        assert!(co.serve_batch(Vec::new()).is_empty());
        let resps = co.serve_batch(random_workload(5, 20, 3));
        assert_eq!(resps.len(), 5);
        for r in &resps {
            assert!(r.cycles > 0);
        }
    }
}
