//! Open-loop serving driver — timed arrivals, event-driven completions,
//! explicit load shedding, and per-request latency decomposition.
//!
//! [`Coordinator::serve_batch`] is closed-loop: the next request is offered
//! only when the admission window frees, so the engine is never overloaded
//! and latency is not a meaningful output. [`Coordinator::serve_open_loop`]
//! drives the same admission + completion state machine
//! ([`super::request::Pipeline`]) from a pre-generated arrival schedule
//! ([`crate::engine::traffic`]): requests become *due* at their virtual
//! timestamp whether or not the engine has kept up, wait in a bounded
//! pending queue, are admitted as the window/byte budget frees, and drain
//! event-driven while the driver keeps watching the arrival clock.
//!
//! Every offered request gets exactly one [`OpenLoopOutcome`] — served with
//! its latency split, or [`OpenLoopOutcome::Rejected`] with the shed reason.
//! Nothing is ever dropped silently (pinned by the overload tests).
//!
//! Latency decomposition per served request, all in host nanoseconds
//! measured from the run start:
//! * **queue** — virtual arrival → admission into the pipeline (includes
//!   open-loop *lateness*: if the host falls behind the arrival schedule,
//!   the wait counts, exactly as a real client would experience it);
//! * **service** — admission → response finalized (kernel execution plus
//!   any wait behind earlier responses: completion is in admission order);
//! * **total** — arrival → finalized (= queue + service up to rounding).

use super::request::{Pipeline, Request, Response};
use super::Coordinator;
use crate::engine::latency::{Histogram, LatencySnapshot};
use crate::engine::traffic::Arrival;
use crate::obs::{Event, EventKind, NO_REQ};
use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// Knobs of one [`Coordinator::serve_open_loop`] run.
#[derive(Debug, Clone, Copy, Default)]
pub struct OpenLoopOptions {
    /// Total-latency SLO in nanoseconds: every served request whose
    /// arrival→finalized latency exceeds this counts into
    /// [`OpenLoopStats::slo_violations`]. `None` tracks no SLO.
    pub slo_total_ns: Option<u64>,
}

/// Why an arrival was shed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedReason {
    /// The pending queue already held
    /// [`super::CoordinatorConfig::queue_depth`] requests.
    QueueDepth,
    /// Accepting would push pending bytes past
    /// [`super::CoordinatorConfig::shed_after_bytes`].
    QueueBytes,
}

/// Exactly one outcome per offered arrival.
#[derive(Debug)]
pub enum OpenLoopOutcome {
    /// Served to completion.
    Served {
        /// The arrival's sequence index.
        seq: usize,
        /// Virtual arrival timestamp (ns from run start).
        arrival_ns: u64,
        /// Arrival → admission (ns).
        queue_ns: u64,
        /// Admission → finalized (ns).
        service_ns: u64,
        /// The response, identical to what `serve_batch` would return for
        /// the same request (values, cycles, energy).
        resp: Response,
    },
    /// Shed by backpressure — an explicit rejection, never a silent drop.
    Rejected {
        /// The arrival's sequence index.
        seq: usize,
        /// Virtual arrival timestamp (ns from run start).
        arrival_ns: u64,
        /// Routine name of the shed request.
        op: &'static str,
        /// Problem size of the shed request.
        n: usize,
        /// Which cap shed it.
        reason: ShedReason,
    },
}

impl OpenLoopOutcome {
    /// The arrival's sequence index.
    pub fn seq(&self) -> usize {
        match self {
            OpenLoopOutcome::Served { seq, .. } | OpenLoopOutcome::Rejected { seq, .. } => *seq,
        }
    }

    /// The response, when served.
    pub fn response(&self) -> Option<&Response> {
        match self {
            OpenLoopOutcome::Served { resp, .. } => Some(resp),
            OpenLoopOutcome::Rejected { .. } => None,
        }
    }
}

/// Aggregate telemetry of one open-loop run.
#[derive(Debug, Clone, Copy, Default)]
pub struct OpenLoopStats {
    /// Arrivals offered (served + shed).
    pub offered: usize,
    /// Requests served to completion.
    pub served: usize,
    /// Requests shed by backpressure.
    pub shed: usize,
    /// Peak depth of the pending (arrived, unadmitted) queue.
    pub peak_pending: usize,
    /// Peak packed-GM bytes priced against the pending queue.
    pub peak_pending_bytes: u64,
    /// Served requests whose total latency exceeded
    /// [`OpenLoopOptions::slo_total_ns`].
    pub slo_violations: usize,
    /// Arrival → admission latency percentiles (ns).
    pub queue: LatencySnapshot,
    /// Admission → finalized latency percentiles (ns).
    pub service: LatencySnapshot,
    /// Arrival → finalized latency percentiles (ns).
    pub total: LatencySnapshot,
}

/// Everything one open-loop run produced: per-arrival outcomes (in `seq`
/// order) plus the aggregate stats.
#[derive(Debug)]
pub struct OpenLoopReport {
    /// One outcome per offered arrival, sorted by sequence index.
    pub outcomes: Vec<OpenLoopOutcome>,
    /// Aggregate counters and latency percentiles.
    pub stats: OpenLoopStats,
}

impl OpenLoopReport {
    /// The served responses in arrival-sequence order (shed arrivals have
    /// no response).
    pub fn responses(&self) -> Vec<&Response> {
        self.outcomes.iter().filter_map(|o| o.response()).collect()
    }
}

/// An accepted arrival waiting for admission; the request stays
/// unmaterialized (synthetic operands are not generated), so a shed-heavy
/// overload run prices and rejects cheaply.
struct Pending {
    seq: usize,
    at_ns: u64,
    bytes: u64,
    req: Request,
}

impl Coordinator {
    /// Serve a timed arrival schedule open-loop. See the
    /// [module docs](self) for the exact semantics; in short, per driver
    /// iteration:
    ///
    /// 1. every arrival whose timestamp is due is accepted into the pending
    ///    queue — or shed (depth cap first, then byte cap) with an explicit
    ///    [`OpenLoopOutcome::Rejected`];
    /// 2. pending requests are admitted FIFO while the admission window and
    ///    byte budget have room (no reordering: head-of-line order is the
    ///    response order, exactly as in `serve_batch`);
    /// 3. finished requests are finalized from the front of the window and
    ///    their queue/service/total latencies recorded;
    /// 4. otherwise the driver polls the pool non-blocking, sleeping in
    ///    ~20 µs slices bounded by the next arrival deadline.
    ///
    /// Arrivals may be passed in any order (they are sorted by timestamp);
    /// `seq` indices should be distinct — outcomes are reported sorted by
    /// `seq`. After the run, [`Coordinator::last_batch_stats`] holds the
    /// pipeline telemetry with `requests` = served and `shed` filled in.
    ///
    /// # Examples
    ///
    /// ```no_run
    /// use redefine_blas::coordinator::{Coordinator, CoordinatorConfig, OpenLoopOptions};
    /// use redefine_blas::engine::traffic::{self, TrafficConfig};
    ///
    /// let cfg = CoordinatorConfig {
    ///     admission_window: Some(4),
    ///     queue_depth: Some(64),
    ///     ..CoordinatorConfig::default()
    /// };
    /// let mut co = Coordinator::new(cfg);
    /// let arrivals = traffic::generate(&TrafficConfig::default());
    /// let report = co.serve_open_loop(arrivals, &OpenLoopOptions::default());
    /// assert_eq!(report.stats.offered, report.stats.served + report.stats.shed);
    /// println!("p99 total: {} ns", report.stats.total.p99);
    /// ```
    pub fn serve_open_loop(
        &mut self,
        mut arrivals: Vec<Arrival>,
        opts: &OpenLoopOptions,
    ) -> OpenLoopReport {
        arrivals.sort_by_key(|a| (a.at_ns, a.seq));
        let offered = arrivals.len();
        let mut arr = arrivals.into_iter().peekable();
        let mut pipe = Pipeline::new(&self.cfg);
        let mut pending: VecDeque<Pending> = VecDeque::new();
        let mut pending_bytes: u64 = 0;
        let mut outcomes: Vec<OpenLoopOutcome> = Vec::with_capacity(offered);
        let mut hist_queue = Histogram::new();
        let mut hist_service = Histogram::new();
        let mut hist_total = Histogram::new();
        let mut stats = OpenLoopStats { offered, ..OpenLoopStats::default() };
        let depth_cap = self.cfg.queue_depth;
        let byte_cap = self.cfg.shed_after_bytes;
        // Each run restarts the rolling-window epoch: completion stamps
        // below are ns from this run's start.
        self.rolling.reset();
        let t0 = Instant::now();

        loop {
            // 1) Accept or shed every due arrival. All arrivals sharing a
            // due instant are resolved before any admission below, so a
            // simultaneous burst sheds deterministically.
            let now = t0.elapsed().as_nanos() as u64;
            while arr.peek().is_some_and(|a| a.at_ns <= now) {
                let a = arr.next().expect("peeked above");
                let bytes = self.cfg.staged_bytes(&a.req);
                let shed = if depth_cap.is_some_and(|cap| pending.len() >= cap) {
                    Some(ShedReason::QueueDepth)
                } else if byte_cap
                    .is_some_and(|cap| !pending.is_empty() && pending_bytes + bytes > cap)
                {
                    Some(ShedReason::QueueBytes)
                } else {
                    None
                };
                match shed {
                    Some(reason) => {
                        stats.shed += 1;
                        // Shed arrivals never got a request id — the event
                        // carries the arrival's seq instead.
                        self.trace(|| Event {
                            req: NO_REQ,
                            sim: 0,
                            host_ns: None,
                            kind: EventKind::Shed { seq: a.seq, reason },
                        });
                        outcomes.push(OpenLoopOutcome::Rejected {
                            seq: a.seq,
                            arrival_ns: a.at_ns,
                            op: a.req.name(),
                            n: a.req.n(),
                            reason,
                        });
                    }
                    None => {
                        pending_bytes += bytes;
                        let p = Pending { seq: a.seq, at_ns: a.at_ns, bytes, req: a.req };
                        pending.push_back(p);
                        stats.peak_pending = stats.peak_pending.max(pending.len());
                        stats.peak_pending_bytes = stats.peak_pending_bytes.max(pending_bytes);
                    }
                }
            }

            // 2) Admit FIFO from the pending queue while there is room.
            while pending.front().is_some_and(|p| pipe.has_room(p.bytes)) {
                let p = pending.pop_front().expect("front checked above");
                pending_bytes -= p.bytes;
                let admitted_ns = t0.elapsed().as_nanos() as u64;
                self.admit(&mut pipe, p.req, p.bytes, p.seq, p.at_ns, admitted_ns);
            }

            // 3) Finalize everything finished at the front of the window.
            while let Some(fin) = self.pop_ready(&mut pipe) {
                let done_ns = t0.elapsed().as_nanos() as u64;
                let queue_ns = fin.admitted_ns.saturating_sub(fin.arrival_ns);
                let service_ns = done_ns.saturating_sub(fin.admitted_ns);
                let total_ns = done_ns.saturating_sub(fin.arrival_ns);
                hist_queue.record(queue_ns);
                hist_service.record(service_ns);
                hist_total.record(total_ns);
                stats.served += 1;
                if opts.slo_total_ns.is_some_and(|slo| total_ns > slo) {
                    stats.slo_violations += 1;
                }
                self.rolling.record(done_ns, queue_ns, service_ns, total_ns);
                self.trace(|| Event {
                    req: fin.id,
                    sim: fin.resp.cycles,
                    host_ns: None,
                    kind: EventKind::Completed {
                        queue_ns,
                        service_ns,
                        cycles: fin.resp.cycles,
                    },
                });
                outcomes.push(OpenLoopOutcome::Served {
                    seq: fin.seq,
                    arrival_ns: fin.arrival_ns,
                    queue_ns,
                    service_ns,
                    resp: fin.resp,
                });
            }

            // 4) Every arrival accounted for?
            if arr.peek().is_none() && pending.is_empty() && pipe.idle() {
                break;
            }

            // 5) Wait for the next event. With work in flight, poll the
            // pool (an idle window always admits the pending front, so a
            // nonempty pending queue implies work in flight); otherwise
            // sleep toward the next arrival deadline.
            if !pipe.idle() {
                if !self.try_drain(&mut pipe) {
                    std::thread::sleep(Duration::from_micros(20));
                }
            } else if let Some(a) = arr.peek() {
                let now = t0.elapsed().as_nanos() as u64;
                if a.at_ns > now {
                    std::thread::sleep(Duration::from_nanos((a.at_ns - now).min(1_000_000)));
                }
            }
        }

        outcomes.sort_by_key(|o| o.seq());
        stats.queue = hist_queue.snapshot();
        stats.service = hist_service.snapshot();
        stats.total = hist_total.snapshot();
        pipe.stats.requests = stats.served;
        pipe.stats.shed = stats.shed;
        self.set_last_batch_stats(pipe.stats);
        self.last_open_loop = Some(stats);
        OpenLoopReport { outcomes, stats }
    }
}
