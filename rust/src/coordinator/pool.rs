//! Persistent worker pool of the serving engine — shared, multi-tenant.
//!
//! The seed coordinator spawned `b×b` fresh host threads (and allocated a
//! fresh [`Pe`]) for every DGEMM request; PR 1–3 made the pool persistent
//! and two-tier. This revision makes it **shared**: one [`PoolCore`]
//! (spawned by the engine, or privately by a standalone
//! [`super::Coordinator`]) serves any number of tenants, each through its
//! own [`PoolClient`] lane:
//!
//! * jobs are tenant-tagged — every client pushes onto its own lane of a
//!   weighted fair [`WrrQueue`], so one tenant's flood cannot starve
//!   another's traffic. Each job carries a **cost estimate** in simulated
//!   cycles ([`Job::cost_estimate`]: the kernel's memoized `PeStats`
//!   cycles once the schedule exists, its decoded op count before), which
//!   the cycle-cost deficit scheduler ([`SchedPolicy::Cycles`]) uses to
//!   keep per-tenant *cycle* service proportional to the weights even
//!   when tenants queue kernels of wildly mismatched cost;
//! * results are tenant-routed — every job carries its client's reply
//!   sender, so a client only ever receives its own completions (and a
//!   worker panic fails the *owning* tenant's request loudly while the
//!   pool keeps serving everyone else);
//! * execution is tenant-parameterized — the enhancement level comes from
//!   the job's pre-decoded kernel and the exec mode from the submitting
//!   client, so tenants at different AE levels share one worker fleet (a
//!   worker keeps one reset-reused PE per level it has seen — at most 6 —
//!   so per-job interleaving of mixed-AE tenants pays `Pe::reset`, not a
//!   fresh allocation; a single-AE stream reuses one PE exactly as
//!   before).
//!
//! Per-kind execution counters are kept twice: pool-wide totals on the
//! core and a per-tenant slice on each client — the tenant slices
//! partition the totals exactly.
//!
//! Host-thread parallelism only: simulated timing comes from the
//! per-kernel `PeStats` and the NoC transfer schedule, both independent of
//! which worker ran a job and in which order.
//!
//! The pool is dependency-oblivious by design: factorization DAG nodes
//! arrive as ordinary `Job::GemmTile`/`Gemv`/`Level1` submissions, because
//! the coordinator's pipeline withholds a node's job until its
//! predecessors complete. Every job the pool sees is ready to run.
//!
//! Fabric mode (`EngineConfig::fabric`) keeps that invariant by placing
//! jobs on **virtual** tiles, not host workers: the coordinator routes
//! each job on the shared [`crate::noc::Fabric`] at *finalize* time
//! (strict submission order per tenant), pricing its operand/result
//! movement on the modeled mesh. Host workers stay location-free
//! value/timing executors — which worker ran a job still cannot affect any
//! simulated observable.

use crate::codegen::GemmLayout;
use crate::engine::queue::{SchedPolicy, WrrQueue};
use crate::metrics::{measure_gemv_sched_on, measure_level1_sched_on, Measurement, Routine};
use crate::obs::Tier;
use crate::pe::{AeLevel, ExecMode, ExecTier, Pe, PeConfig, PeStats, ReplayCtx, ScheduledProgram};
use crate::util::Mat;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread;

/// One unit of pooled work: a cached pre-decoded program plus what the
/// worker needs to run it.
pub(crate) enum Job {
    /// One DGEMM tile kernel: shared cached program + packed operands. The
    /// output block unpacked after the run is the full
    /// `layout.m × layout.p` C block.
    GemmTile {
        /// Request this tile belongs to (dispatcher-assigned).
        job_id: u64,
        /// Tile index within the request (`bi * b + bj`).
        tile_idx: usize,
        sched: Arc<ScheduledProgram>,
        layout: GemmLayout,
        /// Packed GM image (length `layout.gm_words()`).
        gm: Vec<f64>,
    },
    /// Single-PE DGEMV measurement kernel at padded size `n`.
    Gemv { job_id: u64, n: usize, sched: Arc<ScheduledProgram> },
    /// Single-PE Level-1 measurement kernel at padded size `n`. `alpha` is
    /// the constant baked into a DAXPY stream (ignored for reductions).
    Level1 { job_id: u64, routine: Routine, n: usize, alpha: f64, sched: Arc<ScheduledProgram> },
    /// A coalesced run of same-kernel DGEMM tiles: one shared cached
    /// program and layout, one packed operand image per member. When the
    /// schedule is warm (and the worker's PE config matches it), the
    /// worker executes all members in a *single* tier-2b pass
    /// ([`crate::pe::replay_batch`]) and fans out one
    /// [`Done::GemmTile`] per member; a cold kernel or
    /// [`ExecMode::Combined`] falls back to the per-member sequential
    /// path, bit-identical either way.
    ReplayBatch {
        sched: Arc<ScheduledProgram>,
        layout: GemmLayout,
        /// `(job_id, tile_idx, packed GM image)` per member, in
        /// submission order.
        members: Vec<(u64, usize, Vec<f64>)>,
    },
}

impl Job {
    /// Human-readable tag for panic reports.
    fn describe(&self) -> String {
        match self {
            Job::GemmTile { job_id, tile_idx, .. } => format!("job {job_id} gemm tile {tile_idx}"),
            Job::Gemv { job_id, n, .. } => format!("job {job_id} gemv n={n}"),
            Job::Level1 { job_id, routine, n, .. } => format!("job {job_id} {routine:?} n={n}"),
            Job::ReplayBatch { members, .. } => {
                format!("replay batch of {} gemm tiles", members.len())
            }
        }
    }

    /// The enhancement level this job's kernel was decoded for — the level
    /// the executing worker must configure its PE to.
    fn ae(&self) -> AeLevel {
        self.sched().ae()
    }

    /// The cached kernel this job executes.
    fn sched(&self) -> &Arc<ScheduledProgram> {
        match self {
            Job::GemmTile { sched, .. }
            | Job::Gemv { sched, .. }
            | Job::Level1 { sched, .. }
            | Job::ReplayBatch { sched, .. } => sched,
        }
    }

    /// Estimated simulated cycles this job will burn — the currency of the
    /// cycle-cost deficit scheduler. Once the kernel's one-time timing
    /// pass has memoized its `PeStats`, the estimate is exact; before
    /// that (the first request of a cold kernel) it falls back to the
    /// decoded op count, which tracks the cycle cost to within the stall
    /// factor — more than enough to keep a DGEMM tile and a DDOT kernel
    /// orders of magnitude apart. A coalesced [`Job::ReplayBatch`] is
    /// priced as the **sum of its members'** estimates — coalescing
    /// amortizes host dispatch, not simulated cycles, so DRR fairness
    /// must still charge the lane for every member it serves.
    pub(crate) fn cost_estimate(&self) -> u64 {
        let sched = self.sched();
        let each = match sched.scheduled_stats() {
            Some(stats) => stats.cycles.max(1),
            None => (sched.decoded().len() as u64).max(1),
        };
        match self {
            Job::ReplayBatch { members, .. } => each.saturating_mul(members.len().max(1) as u64),
            _ => each,
        }
    }
}

/// Result of one pooled job. Carries the execution tier that really ran
/// ([`Tier`], worker-side truth) so the tracing layer can re-emit it at
/// finalize time in deterministic order.
pub(crate) enum Done {
    /// A finished DGEMM tile.
    GemmTile { job_id: u64, tile_idx: usize, out: Mat, stats: PeStats, tier: Tier },
    /// A finished single-PE measurement (DGEMV or Level-1).
    Measured { job_id: u64, meas: Measurement, tier: Tier },
}

/// Worker → client message: a finished job, or a caught worker panic
/// (re-raised on the owning client by [`PoolClient::recv`], preserving the
/// fail-loud behavior the scoped-thread design had — scoped to the tenant
/// that submitted the bad kernel).
enum Msg {
    Done(Done),
    Panicked(String),
}

/// A job on the shared queue: the work plus its tenant context (exec mode,
/// reply route, per-tenant counters).
struct TaggedJob {
    job: Job,
    exec: ExecMode,
    reply: mpsc::Sender<Msg>,
    counts: Arc<Counters>,
}

/// Jobs executed so far, by kind. Incremented by the worker that ran the
/// job — a nonzero count proves pool execution (pinned by tests).
#[derive(Debug, Default)]
struct Counters {
    gemm_tiles: AtomicU64,
    gemv: AtomicU64,
    level1: AtomicU64,
    replays: AtomicU64,
    combined_runs: AtomicU64,
    batched_replays: AtomicU64,
}

impl Counters {
    fn snapshot(&self) -> PoolJobCounts {
        PoolJobCounts {
            gemm_tiles: self.gemm_tiles.load(Ordering::Relaxed),
            gemv: self.gemv.load(Ordering::Relaxed),
            level1: self.level1.load(Ordering::Relaxed),
            replays: self.replays.load(Ordering::Relaxed),
            combined_runs: self.combined_runs.load(Ordering::Relaxed),
            batched_replays: self.batched_replays.load(Ordering::Relaxed),
        }
    }
}

/// Snapshot of per-kind execution counters — pool-wide from
/// [`super::Coordinator::shared_pool_job_counts`], per-tenant from
/// [`super::Coordinator::pool_job_counts`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PoolJobCounts {
    /// DGEMM tile kernels run on pool workers.
    pub gemm_tiles: u64,
    /// DGEMV measurement kernels run on pool workers.
    pub gemv: u64,
    /// Level-1 measurement kernels run on pool workers.
    pub level1: u64,
    /// Kernels executed on the tier-2 value-replay path (schedule already
    /// memoized when the worker picked the job up).
    pub replays: u64,
    /// Kernels executed by the combined value+timing interpreter (first
    /// run of a program, or every run in [`ExecMode::Combined`]).
    pub combined_runs: u64,
    /// Coalesced tier-2b executions: each counts *one* fused
    /// [`crate::pe::replay_batch`] pass over N member contexts (the
    /// members themselves still count in `gemm_tiles`/`replays`, so
    /// `replays + combined_runs == gemm_tiles + gemv + level1` holds
    /// with or without batching).
    pub batched_replays: u64,
}

/// The shared pool: `size` workers, spawned once, fed from a weighted
/// fair lane queue (slot WRR or cycle-cost DRR, per [`SchedPolicy`]).
/// Dropping the core closes the queue and joins the workers (the engine
/// holds it inside the shared state, so this happens when the engine
/// *and* every tenant handle are gone).
pub(crate) struct PoolCore {
    queue: Arc<WrrQueue<TaggedJob>>,
    workers: Vec<thread::JoinHandle<()>>,
    counts: Arc<Counters>,
}

impl PoolCore {
    /// Spawn `size` persistent workers scheduling under `sched`.
    pub fn new(size: usize, sched: SchedPolicy) -> Self {
        assert!(size >= 1, "worker pool needs at least one worker");
        // Dispatch-time repricing: a job queued while its kernel was cold
        // re-reads the cost estimate when the scheduler actually considers
        // it, so a schedule memoized mid-queue debits the lane by real
        // cycles, not the stale decoded-op-count estimate.
        let queue =
            Arc::new(WrrQueue::new(sched).with_repricer(|t: &TaggedJob| t.job.cost_estimate()));
        let counts = Arc::new(Counters::default());
        let workers = (0..size)
            .map(|i| {
                let queue = Arc::clone(&queue);
                let counts = Arc::clone(&counts);
                thread::Builder::new()
                    .name(format!("pe-worker-{i}"))
                    .spawn(move || worker_loop(queue, counts))
                    .expect("spawn pool worker")
            })
            .collect();
        Self { queue, workers, counts }
    }

    /// Number of persistent workers.
    pub fn worker_count(&self) -> usize {
        self.workers.len()
    }

    /// Pool-wide execution totals (all tenants).
    pub fn counts(&self) -> PoolJobCounts {
        self.counts.snapshot()
    }

    /// The fairness currency jobs are dispatched under.
    pub fn sched(&self) -> SchedPolicy {
        self.queue.policy()
    }

    /// Per-lane (weight, cumulative dispatched estimated cycles) — the
    /// proportional-service observable, in tenant attach order.
    pub fn lane_service(&self) -> Vec<(u64, u64)> {
        self.queue.lane_served()
    }

    /// Open a tenant lane with fair-scheduler `weight`, executing this
    /// tenant's kernels in `exec` mode.
    pub fn client(&self, weight: u64, exec: ExecMode) -> PoolClient {
        let lane = self.queue.add_lane(weight);
        let (reply_tx, reply_rx) = mpsc::channel();
        PoolClient {
            queue: Arc::clone(&self.queue),
            lane,
            exec,
            reply_tx,
            reply_rx,
            counts: Arc::new(Counters::default()),
            workers: self.workers.len(),
        }
    }
}

impl Drop for PoolCore {
    fn drop(&mut self) {
        // Closing the queue drains the backlog and then every worker's
        // pop() returns None → exit.
        self.queue.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// One tenant's handle into the shared pool: a private submission lane
/// and a private completion channel. A client only ever receives results
/// (or panics) of jobs it submitted itself.
pub(crate) struct PoolClient {
    queue: Arc<WrrQueue<TaggedJob>>,
    lane: usize,
    exec: ExecMode,
    reply_tx: mpsc::Sender<Msg>,
    reply_rx: mpsc::Receiver<Msg>,
    counts: Arc<Counters>,
    workers: usize,
}

impl PoolClient {
    /// Enqueue a job on this tenant's lane (returns immediately; the
    /// result comes back via [`PoolClient::recv`]). The job's cycle-cost
    /// estimate is taken here at submission *and refreshed again at
    /// dispatch* (the queue's repricer re-reads [`Job::cost_estimate`]),
    /// so a kernel whose schedule memoizes while the job sits queued is
    /// debited by its real cycles.
    pub fn submit(&self, job: Job) {
        let cost = job.cost_estimate();
        self.queue.push(
            self.lane,
            cost,
            TaggedJob {
                job,
                exec: self.exec,
                reply: self.reply_tx.clone(),
                counts: Arc::clone(&self.counts),
            },
        );
    }

    /// Block for this tenant's next finished job, in completion order.
    /// A worker panic on one of this tenant's kernels (caught in the
    /// worker loop) is re-raised here, so a bad kernel fails the request
    /// loudly instead of deadlocking it — without touching other tenants.
    pub fn recv(&self) -> Done {
        match self.reply_rx.recv().expect("pool workers gone") {
            Msg::Done(d) => d,
            Msg::Panicked(msg) => panic!("pool worker panicked on {msg}"),
        }
    }

    /// Non-blocking [`PoolClient::recv`]: `None` when no result is ready
    /// yet. Lets the open-loop serving driver poll for completions between
    /// arrival deadlines instead of parking on the reply channel. Worker
    /// panics and a dead pool are re-raised exactly as in `recv`.
    pub fn try_recv(&self) -> Option<Done> {
        match self.reply_rx.try_recv() {
            Ok(Msg::Done(d)) => Some(d),
            Ok(Msg::Panicked(msg)) => panic!("pool worker panicked on {msg}"),
            Err(std::sync::mpsc::TryRecvError::Empty) => None,
            Err(std::sync::mpsc::TryRecvError::Disconnected) => panic!("pool workers gone"),
        }
    }

    /// Jobs executed for this tenant so far, by kind.
    pub fn counts(&self) -> PoolJobCounts {
        self.counts.snapshot()
    }

    /// This tenant's scheduler lane index (attach order) — tagged onto
    /// `Dispatched` trace events.
    pub fn lane(&self) -> usize {
        self.lane
    }

    /// Workers in the shared pool this client submits to.
    pub fn worker_count(&self) -> usize {
        self.workers
    }
}

fn worker_loop(queue: Arc<WrrQueue<TaggedJob>>, totals: Arc<Counters>) {
    // PEs are created lazily, one per enhancement level this worker has
    // seen (at most 6), and reset()-reused across jobs — a reset PE is
    // bit-identical to a fresh one (see pe::core tests). Keeping one PE
    // per level matters under the engine: mixed-AE tenants round-robin
    // per-job on one worker, and rebuilding the PE (LM + full state) on
    // every level switch would charge that interleaving a fresh
    // allocation per job.
    let mut pes: Vec<(AeLevel, Pe)> = Vec::new();
    while let Some(tagged) = queue.pop() {
        let TaggedJob { job, exec, reply, counts } = tagged;
        let what = job.describe();
        let ae = job.ae();
        let at = match pes.iter().position(|(held, _)| *held == ae) {
            Some(at) => at,
            None => {
                pes.push((ae, Pe::new(PeConfig::paper(ae), 0)));
                pes.len() - 1
            }
        };
        let p = &mut pes[at].1;
        // Catch kernel panics (codegen bugs, feature misuse) and report
        // them to the owning tenant: a silently-missing result would
        // deadlock that tenant's dispatcher.
        let unwind = std::panic::AssertUnwindSafe(|| run_job(p, exec, job, &totals, &counts));
        let outcome = std::panic::catch_unwind(unwind);
        match outcome {
            // A coalesced batch fans out one Done per member; single jobs
            // send exactly one. A dropped tenant is not a pool failure:
            // keep serving the others.
            Ok(dones) => {
                for d in dones {
                    let _ = reply.send(Msg::Done(d));
                }
            }
            Err(payload) => {
                // State may be inconsistent; rebuild this level's PE on
                // its next job.
                pes.swap_remove(at);
                let _ =
                    reply.send(Msg::Panicked(format!("{what}: {}", panic_message(payload))));
            }
        }
    }
}

/// Run one job on the worker's (reset-reused) PE, tallying both the
/// pool-wide and the owning tenant's counters. Returns one [`Done`] per
/// request the job carried: exactly one for the single-job kinds, one per
/// member for a coalesced [`Job::ReplayBatch`].
fn run_job(
    pe: &mut Pe,
    exec: ExecMode,
    job: Job,
    totals: &Counters,
    tenant: &Counters,
) -> Vec<Done> {
    let bump = |pick: fn(&Counters) -> &AtomicU64| {
        pick(totals).fetch_add(1, Ordering::Relaxed);
        pick(tenant).fetch_add(1, Ordering::Relaxed);
    };
    // Count the tier the execution engine reports, not a prediction: a
    // worker that races another onto a fresh kernel may still replay if
    // the sibling's timing pass lands first.
    let tally_tier = |tier: ExecTier| match tier {
        ExecTier::Replayed => bump(|c| &c.replays),
        ExecTier::Combined => bump(|c| &c.combined_runs),
    };
    let obs_tier = |tier: ExecTier| match tier {
        ExecTier::Replayed => Tier::Replay,
        ExecTier::Combined => Tier::Combined,
    };
    match job {
        Job::GemmTile { job_id, tile_idx, sched, layout, gm } => {
            pe.reset(layout.gm_words());
            pe.write_gm(0, &gm);
            let (stats, tier) = sched.execute_traced(pe, exec);
            let out = layout.unpack_c(&pe.gm, layout.m, layout.p);
            bump(|c| &c.gemm_tiles);
            tally_tier(tier);
            vec![Done::GemmTile { job_id, tile_idx, out, stats, tier: obs_tier(tier) }]
        }
        Job::Gemv { job_id, n, sched } => {
            let (meas, tier) = measure_gemv_sched_on(pe, n, sched.ae(), &sched, exec);
            bump(|c| &c.gemv);
            tally_tier(tier);
            vec![Done::Measured { job_id, meas, tier: obs_tier(tier) }]
        }
        Job::Level1 { job_id, routine, n, alpha, sched } => {
            let (meas, tier) =
                measure_level1_sched_on(pe, routine, n, alpha, sched.ae(), &sched, exec);
            bump(|c| &c.level1);
            tally_tier(tier);
            vec![Done::Measured { job_id, meas, tier: obs_tier(tier) }]
        }
        Job::ReplayBatch { sched, layout, members } => {
            // Tier 2b: one fused value pass when the schedule is warm and
            // was taken under this worker's exact PE config (the memo is
            // write-once, so a warm check cannot go stale). Otherwise —
            // cold kernel or Combined mode — fall back to the per-member
            // sequential path, which is what the members would have run
            // as individual jobs.
            let warm =
                exec == ExecMode::Replay && sched.scheduled_config().is_some_and(|c| *c == pe.cfg);
            let mut dones = Vec::with_capacity(members.len());
            if warm {
                let mut ids = Vec::with_capacity(members.len());
                let mut ctxs = Vec::with_capacity(members.len());
                for (job_id, tile_idx, gm) in members {
                    ids.push((job_id, tile_idx));
                    ctxs.push(ReplayCtx::from_gm(gm));
                }
                let stats = sched
                    .replay_batch_scheduled(&mut ctxs, &pe.cfg)
                    .expect("schedule verified warm under this config");
                bump(|c| &c.batched_replays);
                for ((job_id, tile_idx), ctx) in ids.into_iter().zip(ctxs) {
                    let out = layout.unpack_c(&ctx.gm, layout.m, layout.p);
                    bump(|c| &c.gemm_tiles);
                    bump(|c| &c.replays);
                    dones.push(Done::GemmTile {
                        job_id,
                        tile_idx,
                        out,
                        stats: stats.clone(),
                        tier: Tier::Batched,
                    });
                }
            } else {
                for (job_id, tile_idx, gm) in members {
                    pe.reset(layout.gm_words());
                    pe.write_gm(0, &gm);
                    let (stats, tier) = sched.execute_traced(pe, exec);
                    let out = layout.unpack_c(&pe.gm, layout.m, layout.p);
                    bump(|c| &c.gemm_tiles);
                    tally_tier(tier);
                    dones.push(Done::GemmTile { job_id, tile_idx, out, stats, tier: obs_tier(tier) });
                }
            }
            dones
        }
    }
}

/// Human-readable text from a caught panic payload.
fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&'static str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "unknown panic payload".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codegen::layout::VecLayout;
    use crate::codegen::{gen_gemm_rect, gen_gemv};
    use crate::metrics::measure_gemv_prog;
    use crate::util::rel_fro_error;

    fn gemm_job(job_id: u64, tile_idx: usize, n: usize, seed: u64) -> (Job, Mat) {
        gemm_job_at(job_id, tile_idx, n, seed, AeLevel::Ae5)
    }

    fn gemm_job_at(job_id: u64, tile_idx: usize, n: usize, seed: u64, ae: AeLevel) -> (Job, Mat) {
        let a = Mat::random(n, n, seed);
        let b = Mat::random(n, n, seed + 1);
        let c = Mat::random(n, n, seed + 2);
        let layout = GemmLayout::rect(n, n, n);
        let prog = gen_gemm_rect(n, n, n, ae, &layout);
        let sched = Arc::new(ScheduledProgram::compile(&prog, ae).expect("tile kernel decodes"));
        let want = crate::blas::level3::dgemm_ref(&a, &b, &c);
        let gm = layout.pack(&a, &b, &c);
        (Job::GemmTile { job_id, tile_idx, sched, layout, gm }, want)
    }

    #[test]
    fn pool_runs_jobs_and_reuses_workers() {
        let core = PoolCore::new(2, SchedPolicy::Slots);
        let client = core.client(1, ExecMode::Replay);
        assert_eq!(core.worker_count(), 2);
        assert_eq!(client.worker_count(), 2);
        // More jobs than workers forces PE reuse; mixed shapes force
        // reset() resizing.
        let mut wants = std::collections::HashMap::new();
        for (i, n) in [8usize, 12, 8, 16, 12, 8].into_iter().enumerate() {
            let (job, want) = gemm_job(i as u64, 0, n, 100 + i as u64);
            wants.insert(i as u64, want);
            client.submit(job);
        }
        for _ in 0..6 {
            let (job_id, out, stats) = match client.recv() {
                Done::GemmTile { job_id, out, stats, .. } => (job_id, out, stats),
                Done::Measured { .. } => panic!("no measurement submitted"),
            };
            let want = &wants[&job_id];
            let err = rel_fro_error(out.as_slice(), want.as_slice());
            assert!(err < 1e-12, "job {job_id}: err {err}");
            assert!(stats.cycles > 0);
        }
        let counts = client.counts();
        assert_eq!((counts.gemm_tiles, counts.gemv, counts.level1), (6, 0, 0));
        // Every job carried a distinct fresh ScheduledProgram here, so all
        // six executions were combined timing passes.
        assert_eq!(counts.combined_runs, 6);
        assert_eq!(counts.replays, 0);
        assert_eq!(core.counts(), counts, "single client: totals equal the tenant slice");
    }

    #[test]
    fn shared_schedule_replays_after_first_run() {
        // One ScheduledProgram shared by several jobs: only the first
        // execution pays the timing pass; later jobs replay values and
        // return identical stats and identical output.
        let core = PoolCore::new(1, SchedPolicy::Slots);
        let client = core.client(1, ExecMode::Replay);
        let (first, want) = gemm_job(0, 0, 12, 500);
        let (sched, layout, gm) = match &first {
            Job::GemmTile { sched, layout, gm, .. } => (Arc::clone(sched), *layout, gm.clone()),
            _ => unreachable!(),
        };
        client.submit(first);
        for id in 1..4u64 {
            client.submit(Job::GemmTile {
                job_id: id,
                tile_idx: 0,
                sched: Arc::clone(&sched),
                layout,
                gm: gm.clone(),
            });
        }
        let mut stats = Vec::new();
        for _ in 0..4 {
            match client.recv() {
                Done::GemmTile { out, stats: st, .. } => {
                    let err = rel_fro_error(out.as_slice(), want.as_slice());
                    assert!(err < 1e-12, "replayed tile wrong: {err}");
                    stats.push(st);
                }
                Done::Measured { .. } => panic!("no measurement submitted"),
            }
        }
        assert!(stats.windows(2).all(|w| w[0] == w[1]), "replay must return the memoized stats");
        let counts = client.counts();
        assert_eq!(counts.combined_runs, 1, "one worker → exactly one timing pass");
        assert_eq!(counts.replays, 3, "later executions replay");
    }

    #[test]
    fn combined_mode_never_replays() {
        let core = PoolCore::new(1, SchedPolicy::Slots);
        let client = core.client(1, ExecMode::Combined);
        let (first, _) = gemm_job(0, 0, 8, 600);
        let (sched, layout, gm) = match &first {
            Job::GemmTile { sched, layout, gm, .. } => (Arc::clone(sched), *layout, gm.clone()),
            _ => unreachable!(),
        };
        client.submit(first);
        client.submit(Job::GemmTile { job_id: 1, tile_idx: 0, sched, layout, gm });
        let (a, b) = match (client.recv(), client.recv()) {
            (Done::GemmTile { stats: a, .. }, Done::GemmTile { stats: b, .. }) => (a, b),
            _ => panic!("no measurement submitted"),
        };
        assert_eq!(a, b, "combined re-runs must reproduce the schedule");
        let counts = client.counts();
        assert_eq!((counts.combined_runs, counts.replays), (2, 0));
    }

    #[test]
    fn measurement_jobs_run_on_workers_and_match_inline() {
        // A pooled DGEMV/Level-1 kernel must return exactly the inline
        // measurement (the pool only moves where the simulation runs).
        let ae = AeLevel::Ae5;
        let core = PoolCore::new(2, SchedPolicy::Slots);
        let client = core.client(1, ExecMode::Replay);
        let n = 16;
        let gprog = gen_gemv(n, ae, &VecLayout::gemv(n));
        let want = measure_gemv_prog(n, ae, &gprog);
        let gsched = Arc::new(ScheduledProgram::compile(&gprog, ae).expect("gemv decodes"));
        client.submit(Job::Gemv { job_id: 7, n, sched: gsched });
        let lprog = crate::codegen::gen_ddot(n, ae, &VecLayout::level1(n));
        let lsched = Arc::new(ScheduledProgram::compile(&lprog, ae).expect("ddot decodes"));
        client.submit(Job::Level1 {
            job_id: 8,
            routine: Routine::Ddot,
            n,
            alpha: 1.5,
            sched: lsched,
        });
        let mut got = Vec::new();
        for _ in 0..2 {
            match client.recv() {
                Done::Measured { job_id, meas, .. } => got.push((job_id, meas)),
                Done::GemmTile { .. } => panic!("no tile submitted"),
            }
        }
        got.sort_by_key(|(id, _)| *id);
        assert_eq!(got[0].0, 7);
        assert_eq!(got[0].1.latency(), want.latency());
        assert_eq!(got[0].1.routine, Routine::Dgemv);
        assert_eq!(got[1].0, 8);
        assert_eq!(got[1].1.routine, Routine::Ddot);
        assert!(got[1].1.latency() > 0);
        let counts = client.counts();
        assert_eq!((counts.gemv, counts.level1, counts.gemm_tiles), (1, 1, 0));
    }

    #[test]
    fn clients_only_receive_their_own_results_and_counts_partition() {
        // Two tenants on one shared pool: completions route to the
        // submitting client, and the per-tenant counters sum to the
        // pool-wide totals.
        let core = PoolCore::new(2, SchedPolicy::Slots);
        let a = core.client(1, ExecMode::Replay);
        let b = core.client(2, ExecMode::Replay);
        let (ja, want_a) = gemm_job(10, 0, 8, 700);
        let (jb, want_b) = gemm_job(20, 0, 12, 800);
        a.submit(ja);
        b.submit(jb);
        let got_a = match a.recv() {
            Done::GemmTile { job_id, out, .. } => {
                assert_eq!(job_id, 10, "client a got a foreign job");
                out
            }
            Done::Measured { .. } => panic!("no measurement submitted"),
        };
        let got_b = match b.recv() {
            Done::GemmTile { job_id, out, .. } => {
                assert_eq!(job_id, 20, "client b got a foreign job");
                out
            }
            Done::Measured { .. } => panic!("no measurement submitted"),
        };
        assert!(rel_fro_error(got_a.as_slice(), want_a.as_slice()) < 1e-12);
        assert!(rel_fro_error(got_b.as_slice(), want_b.as_slice()) < 1e-12);
        let (ca, cb, total) = (a.counts(), b.counts(), core.counts());
        assert_eq!(ca.gemm_tiles + cb.gemm_tiles, total.gemm_tiles);
        assert_eq!((ca.gemm_tiles, cb.gemm_tiles), (1, 1));
    }

    #[test]
    fn mixed_ae_clients_share_one_worker() {
        // One worker serving kernels decoded for different AE levels must
        // swap PE configurations per job and still return exactly the
        // per-level reference values.
        let core = PoolCore::new(1, SchedPolicy::Slots);
        let lo = core.client(1, ExecMode::Replay);
        let hi = core.client(1, ExecMode::Replay);
        for round in 0..2u64 {
            let (j0, want0) = gemm_job_at(round, 0, 8, 900 + round, AeLevel::Ae0);
            let (j5, want5) = gemm_job_at(round, 0, 8, 950 + round, AeLevel::Ae5);
            lo.submit(j0);
            hi.submit(j5);
            let out0 = match lo.recv() {
                Done::GemmTile { out, .. } => out,
                Done::Measured { .. } => panic!("no measurement submitted"),
            };
            let out5 = match hi.recv() {
                Done::GemmTile { out, .. } => out,
                Done::Measured { .. } => panic!("no measurement submitted"),
            };
            assert!(rel_fro_error(out0.as_slice(), want0.as_slice()) < 1e-12, "AE0 job wrong");
            assert!(rel_fro_error(out5.as_slice(), want5.as_slice()) < 1e-12, "AE5 job wrong");
        }
    }

    #[test]
    fn drop_joins_idle_workers() {
        let core = PoolCore::new(3, SchedPolicy::Slots);
        let _client = core.client(1, ExecMode::Replay);
        drop(core); // must not hang
    }

    #[test]
    fn cost_estimate_sharpens_once_the_schedule_memoizes() {
        // Before the timing pass: decode-derived op count. After: the
        // exact memoized cycle cost (which includes stalls, so it always
        // exceeds the op count for a real kernel).
        let (job, _) = gemm_job(0, 0, 12, 900);
        let (sched, gm_words) = match &job {
            Job::GemmTile { sched, layout, .. } => (Arc::clone(sched), layout.gm_words()),
            _ => unreachable!(),
        };
        let cold = job.cost_estimate();
        assert_eq!(cold, sched.decoded().len() as u64, "cold estimate is the op count");
        let mut pe = Pe::new(PeConfig::paper(AeLevel::Ae5), gm_words);
        let stats = sched.execute(&mut pe, ExecMode::Replay);
        assert_eq!(job.cost_estimate(), stats.cycles, "warm estimate is the memoized cycles");
        assert!(job.cost_estimate() > cold, "cycles include stalls beyond the op count");
    }

    /// Distinct operand images (and references) for `count` members of one
    /// shared kernel/layout.
    fn batch_members(
        layout: &GemmLayout,
        n: usize,
        count: u64,
        seed: u64,
    ) -> (Vec<(u64, usize, Vec<f64>)>, std::collections::HashMap<u64, Mat>) {
        let mut members = Vec::new();
        let mut wants = std::collections::HashMap::new();
        for id in 1..=count {
            let a = Mat::random(n, n, seed + 3 * id);
            let b = Mat::random(n, n, seed + 3 * id + 1);
            let c = Mat::random(n, n, seed + 3 * id + 2);
            wants.insert(id, crate::blas::level3::dgemm_ref(&a, &b, &c));
            members.push((id, 0, layout.pack(&a, &b, &c)));
        }
        (members, wants)
    }

    #[test]
    fn warm_replay_batch_fans_out_per_member_results() {
        // One coalesced job over a warm kernel: a single tier-2b pass must
        // return every member's correct values and the memoized stats,
        // counting each member as a replayed gemm tile and the fused pass
        // once in batched_replays.
        let core = PoolCore::new(1, SchedPolicy::Slots);
        let client = core.client(1, ExecMode::Replay);
        let n = 12;
        let (first, want0) = gemm_job(0, 0, n, 1200);
        let (sched, layout) = match &first {
            Job::GemmTile { sched, layout, .. } => (Arc::clone(sched), *layout),
            _ => unreachable!(),
        };
        client.submit(first); // warm the schedule
        let out0 = match client.recv() {
            Done::GemmTile { out, .. } => out,
            Done::Measured { .. } => panic!("no measurement submitted"),
        };
        assert!(rel_fro_error(out0.as_slice(), want0.as_slice()) < 1e-12);
        let memo = sched.scheduled_stats().expect("warmed").clone();

        let (members, wants) = batch_members(&layout, n, 3, 4000);
        client.submit(Job::ReplayBatch { sched, layout, members });
        for _ in 0..3 {
            match client.recv() {
                Done::GemmTile { job_id, out, stats, .. } => {
                    let want = &wants[&job_id];
                    let err = rel_fro_error(out.as_slice(), want.as_slice());
                    assert!(err < 1e-12, "batch member {job_id}: err {err}");
                    assert_eq!(stats, memo, "batch members report the memoized schedule");
                }
                Done::Measured { .. } => panic!("no measurement submitted"),
            }
        }
        let counts = client.counts();
        assert_eq!(counts.gemm_tiles, 4);
        assert_eq!(counts.combined_runs, 1, "only the warm-up paid the timing pass");
        assert_eq!(counts.replays, 3, "every batch member counts as a replay");
        assert_eq!(counts.batched_replays, 1, "one fused pass for the whole batch");
        assert_eq!(core.counts(), counts, "single client: totals equal the tenant slice");
    }

    #[test]
    fn cold_replay_batch_falls_back_to_sequential_members() {
        // A batch submitted before any execution memoized the schedule:
        // the first member pays the combined timing pass, the rest replay
        // — exactly what N individual jobs on one worker would do — and
        // no fused pass is counted.
        let core = PoolCore::new(1, SchedPolicy::Slots);
        let client = core.client(1, ExecMode::Replay);
        let n = 8;
        let (probe, _) = gemm_job(0, 0, n, 1300);
        let (sched, layout) = match &probe {
            Job::GemmTile { sched, layout, .. } => (Arc::clone(sched), *layout),
            _ => unreachable!(),
        };
        assert!(!sched.is_scheduled());
        let (members, wants) = batch_members(&layout, n, 3, 5000);
        client.submit(Job::ReplayBatch { sched, layout, members });
        for _ in 0..3 {
            match client.recv() {
                Done::GemmTile { job_id, out, .. } => {
                    let err = rel_fro_error(out.as_slice(), wants[&job_id].as_slice());
                    assert!(err < 1e-12, "cold batch member {job_id}: err {err}");
                }
                Done::Measured { .. } => panic!("no measurement submitted"),
            }
        }
        let counts = client.counts();
        assert_eq!(counts.gemm_tiles, 3);
        assert_eq!(counts.combined_runs, 1);
        assert_eq!(counts.replays, 2);
        assert_eq!(counts.batched_replays, 0, "cold batches never take the fused pass");
    }

    #[test]
    fn replay_batch_cost_is_the_sum_of_member_costs() {
        // DRR fairness must price a coalesced job as N members, warm or
        // cold — coalescing amortizes host dispatch, not simulated cycles.
        let n = 12;
        let (probe, _) = gemm_job(0, 0, n, 1400);
        let (sched, layout) = match &probe {
            Job::GemmTile { sched, layout, .. } => (Arc::clone(sched), *layout),
            _ => unreachable!(),
        };
        let (members, _) = batch_members(&layout, n, 4, 6000);
        let batch = Job::ReplayBatch { sched: Arc::clone(&sched), layout, members };
        assert_eq!(batch.cost_estimate(), 4 * probe.cost_estimate(), "cold: 4x the op count");
        let mut pe = Pe::new(PeConfig::paper(AeLevel::Ae5), layout.gm_words());
        let stats = sched.execute(&mut pe, ExecMode::Replay);
        assert_eq!(batch.cost_estimate(), 4 * stats.cycles, "warm: 4x the memoized cycles");
    }

    #[test]
    fn drr_pool_serves_both_tenants_and_reports_lane_service() {
        // A cycle-cost DRR pool end to end: two clients, mismatched kernel
        // costs, everything completes and the lane-service telemetry sums
        // to the dispatched estimates.
        let core = PoolCore::new(1, SchedPolicy::Cycles);
        assert_eq!(core.sched(), SchedPolicy::Cycles);
        let a = core.client(1, ExecMode::Replay);
        let b = core.client(3, ExecMode::Replay);
        let (ja, want_a) = gemm_job(1, 0, 16, 910);
        a.submit(ja);
        let ae = AeLevel::Ae5;
        let n = 16;
        let lprog = crate::codegen::gen_ddot(n, ae, &VecLayout::level1(n));
        let lsched = Arc::new(ScheduledProgram::compile(&lprog, ae).expect("ddot decodes"));
        for id in 0..3u64 {
            b.submit(Job::Level1 {
                job_id: id,
                routine: Routine::Ddot,
                n,
                alpha: 1.5,
                sched: Arc::clone(&lsched),
            });
        }
        match a.recv() {
            Done::GemmTile { out, .. } => {
                assert!(rel_fro_error(out.as_slice(), want_a.as_slice()) < 1e-12);
            }
            Done::Measured { .. } => panic!("no measurement submitted on a"),
        }
        for _ in 0..3 {
            match b.recv() {
                Done::Measured { meas, .. } => assert!(meas.latency() > 0),
                Done::GemmTile { .. } => panic!("no tile submitted on b"),
            }
        }
        let service = core.lane_service();
        assert_eq!(service.len(), 2);
        assert_eq!((service[0].0, service[1].0), (1, 3), "weights in attach order");
        assert!(service[0].1 > 0 && service[1].1 > 0, "both lanes served: {service:?}");
    }

    /// A Level-1 job whose schedule belongs to a *different* routine: the
    /// worker-side numeric cross-check panics deterministically.
    fn poison_job(job_id: u64) -> Job {
        let ae = AeLevel::Ae5;
        let n = 16;
        let prog = crate::codegen::gen_daxpy(n, 1.5, ae, &VecLayout::level1(n));
        let sched = Arc::new(ScheduledProgram::compile(&prog, ae).expect("daxpy decodes"));
        Job::Level1 { job_id, routine: Routine::Ddot, n, alpha: 1.5, sched }
    }

    #[test]
    #[should_panic(expected = "pool worker panicked")]
    fn worker_panic_propagates_instead_of_deadlocking() {
        let core = PoolCore::new(1, SchedPolicy::Slots);
        let client = core.client(1, ExecMode::Replay);
        client.submit(poison_job(0));
        let _ = client.recv();
    }

    #[test]
    fn worker_panic_is_scoped_to_the_owning_client() {
        // Tenant `bad` submits a poisoned kernel; tenant `good`'s traffic
        // must keep flowing on the same (single) worker.
        let core = PoolCore::new(1, SchedPolicy::Slots);
        let bad = core.client(1, ExecMode::Replay);
        let good = core.client(1, ExecMode::Replay);
        bad.submit(poison_job(1));
        let n = 16;
        let ae = AeLevel::Ae5;
        let gprog = gen_gemv(n, ae, &VecLayout::gemv(n));
        let want = measure_gemv_prog(n, ae, &gprog);
        let gsched = Arc::new(ScheduledProgram::compile(&gprog, ae).expect("gemv decodes"));
        good.submit(Job::Gemv { job_id: 2, n, sched: gsched });
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| bad.recv()));
        assert!(res.is_err(), "bad client must see its worker panic");
        match good.recv() {
            Done::Measured { job_id, meas, .. } => {
                assert_eq!(job_id, 2);
                assert_eq!(meas.latency(), want.latency(), "good client served after panic");
            }
            Done::GemmTile { .. } => panic!("no tile submitted"),
        }
    }
}
