//! Persistent worker pool of the serving engine.
//!
//! The seed coordinator spawned `b×b` fresh host threads (and allocated a
//! fresh [`Pe`]) for every DGEMM request, and simulated every Level-1/2
//! request inline on the dispatcher thread. This pool spawns the workers
//! once per [`super::Coordinator`], feeds them jobs over a shared channel,
//! and reuses each worker's `Pe` across kernels via [`Pe::reset`] — so a
//! request stream pays only for simulation, and kernels of *independent*
//! requests overlap (jobs are tagged with a `job_id` and collected by the
//! dispatcher in any arrival order).
//!
//! Every BLAS level flows through the same [`Job`] channel: DGEMM as
//! per-tile kernels, DGEMV and the Level-1 routines as single-PE
//! measurement kernels on the cached-program paths
//! ([`measure_gemv_prog_on`] / [`measure_level1_prog_on`]). Values are
//! resolved by the dispatcher; the pool burns the simulated cycles.
//!
//! Host-thread parallelism only: simulated timing comes from the per-kernel
//! `PeStats` and the NoC transfer schedule, both of which are independent
//! of which worker ran a job and in which order.

use crate::codegen::GemmLayout;
use crate::metrics::{measure_gemv_prog_on, measure_level1_prog_on, Measurement, Routine};
use crate::pe::{AeLevel, Pe, PeConfig, PeStats, Program};
use crate::util::Mat;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

/// One unit of pooled work: a cached program plus what the worker needs to
/// run it.
pub(crate) enum Job {
    /// One DGEMM tile kernel: shared cached program + packed operands. The
    /// output block unpacked after the run is the full
    /// `layout.m × layout.p` C block.
    GemmTile {
        /// Request this tile belongs to (dispatcher-assigned).
        job_id: u64,
        /// Tile index within the request (`bi * b + bj`).
        tile_idx: usize,
        prog: Arc<Program>,
        layout: GemmLayout,
        /// Packed GM image (length `layout.gm_words()`).
        gm: Vec<f64>,
    },
    /// Single-PE DGEMV measurement kernel at padded size `n`.
    Gemv { job_id: u64, n: usize, prog: Arc<Program> },
    /// Single-PE Level-1 measurement kernel at padded size `n`. `alpha` is
    /// the constant baked into a DAXPY stream (ignored for reductions).
    Level1 { job_id: u64, routine: Routine, n: usize, alpha: f64, prog: Arc<Program> },
}

impl Job {
    /// Human-readable tag for panic reports.
    fn describe(&self) -> String {
        match self {
            Job::GemmTile { job_id, tile_idx, .. } => format!("job {job_id} gemm tile {tile_idx}"),
            Job::Gemv { job_id, n, .. } => format!("job {job_id} gemv n={n}"),
            Job::Level1 { job_id, routine, n, .. } => format!("job {job_id} {routine:?} n={n}"),
        }
    }
}

/// Result of one pooled job.
pub(crate) enum Done {
    /// A finished DGEMM tile.
    GemmTile { job_id: u64, tile_idx: usize, out: Mat, stats: PeStats },
    /// A finished single-PE measurement (DGEMV or Level-1).
    Measured { job_id: u64, meas: Measurement },
}

/// Worker → dispatcher message: a finished job, or a caught worker panic
/// (re-raised on the dispatcher by [`WorkerPool::recv`], preserving the
/// fail-loud behavior the scoped-thread design had).
enum Msg {
    Done(Done),
    Panicked(String),
}

/// Jobs executed so far, by kind. Incremented by the worker that ran the
/// job — a nonzero count proves pool execution (pinned by tests).
#[derive(Debug, Default)]
struct Counters {
    gemm_tiles: AtomicU64,
    gemv: AtomicU64,
    level1: AtomicU64,
}

/// Snapshot of the pool's per-kind execution counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PoolJobCounts {
    /// DGEMM tile kernels run on pool workers.
    pub gemm_tiles: u64,
    /// DGEMV measurement kernels run on pool workers.
    pub gemv: u64,
    /// Level-1 measurement kernels run on pool workers.
    pub level1: u64,
}

/// The pool: `size` workers, spawned once, fed over a shared queue.
pub(crate) struct WorkerPool {
    jobs: Option<mpsc::Sender<Job>>,
    done_rx: mpsc::Receiver<Msg>,
    workers: Vec<thread::JoinHandle<()>>,
    counts: Arc<Counters>,
}

impl WorkerPool {
    /// Spawn `size` persistent workers simulating paper-configured PEs at
    /// enhancement level `ae`.
    pub fn new(size: usize, ae: AeLevel) -> Self {
        assert!(size >= 1, "worker pool needs at least one worker");
        let (jtx, jrx) = mpsc::channel::<Job>();
        let (dtx, drx) = mpsc::channel::<Msg>();
        let jrx = Arc::new(Mutex::new(jrx));
        let counts = Arc::new(Counters::default());
        let workers = (0..size)
            .map(|i| {
                let jrx = Arc::clone(&jrx);
                let dtx = dtx.clone();
                let counts = Arc::clone(&counts);
                thread::Builder::new()
                    .name(format!("pe-worker-{i}"))
                    .spawn(move || worker_loop(ae, jrx, dtx, counts))
                    .expect("spawn pool worker")
            })
            .collect();
        Self { jobs: Some(jtx), done_rx: drx, workers, counts }
    }

    /// Number of persistent workers.
    pub fn worker_count(&self) -> usize {
        self.workers.len()
    }

    /// Jobs executed so far, by kind.
    pub fn counts(&self) -> PoolJobCounts {
        PoolJobCounts {
            gemm_tiles: self.counts.gemm_tiles.load(Ordering::Relaxed),
            gemv: self.counts.gemv.load(Ordering::Relaxed),
            level1: self.counts.level1.load(Ordering::Relaxed),
        }
    }

    /// Enqueue a job (returns immediately; results come via `recv`).
    pub fn submit(&self, job: Job) {
        self.jobs
            .as_ref()
            .expect("pool already shut down")
            .send(job)
            .expect("worker pool hung up");
    }

    /// Block for the next finished job, in arrival order across jobs.
    /// A worker panic (caught in the worker loop) is re-raised here so a
    /// bad kernel fails the request loudly instead of deadlocking it.
    pub fn recv(&self) -> Done {
        match self.done_rx.recv().expect("pool workers gone") {
            Msg::Done(d) => d,
            Msg::Panicked(msg) => panic!("pool worker panicked on {msg}"),
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // Closing the job channel makes every worker's recv() fail → exit.
        drop(self.jobs.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(
    ae: AeLevel,
    jobs: Arc<Mutex<mpsc::Receiver<Job>>>,
    done: mpsc::Sender<Msg>,
    counts: Arc<Counters>,
) {
    // The worker's PE is created on the first job and reset()-reused after:
    // a reset PE is bit-identical to a fresh one (see pe::core tests).
    let mut pe: Option<Pe> = None;
    loop {
        // Hold the queue lock only while receiving; pickup is serialized,
        // simulation is not.
        let job = {
            let guard = match jobs.lock() {
                Ok(g) => g,
                Err(_) => return, // a sibling worker panicked mid-recv
            };
            match guard.recv() {
                Ok(j) => j,
                Err(_) => return, // pool dropped: shut down
            }
        };
        let what = job.describe();
        if pe.is_none() {
            pe = Some(Pe::new(PeConfig::paper(ae), 0));
        }
        let p = pe.as_mut().expect("worker PE initialized above");
        // Catch kernel panics (codegen bugs, feature misuse) and report
        // them: a silently-missing result would deadlock the dispatcher.
        let unwind = std::panic::AssertUnwindSafe(|| run_job(p, ae, job, &counts));
        let outcome = std::panic::catch_unwind(unwind);
        let msg = match outcome {
            Ok(d) => Msg::Done(d),
            Err(payload) => {
                pe = None; // state may be inconsistent; rebuild on next job
                Msg::Panicked(format!("{what}: {}", panic_message(payload)))
            }
        };
        if done.send(msg).is_err() {
            return; // dispatcher gone: shut down
        }
    }
}

/// Run one job on the worker's (reset-reused) PE.
fn run_job(pe: &mut Pe, ae: AeLevel, job: Job, counts: &Counters) -> Done {
    match job {
        Job::GemmTile { job_id, tile_idx, prog, layout, gm } => {
            pe.reset(layout.gm_words());
            pe.write_gm(0, &gm);
            let stats = pe.run(&prog);
            let out = layout.unpack_c(&pe.gm, layout.m, layout.p);
            counts.gemm_tiles.fetch_add(1, Ordering::Relaxed);
            Done::GemmTile { job_id, tile_idx, out, stats }
        }
        Job::Gemv { job_id, n, prog } => {
            let meas = measure_gemv_prog_on(pe, n, ae, &prog);
            counts.gemv.fetch_add(1, Ordering::Relaxed);
            Done::Measured { job_id, meas }
        }
        Job::Level1 { job_id, routine, n, alpha, prog } => {
            let meas = measure_level1_prog_on(pe, routine, n, alpha, ae, &prog);
            counts.level1.fetch_add(1, Ordering::Relaxed);
            Done::Measured { job_id, meas }
        }
    }
}

/// Human-readable text from a caught panic payload.
fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&'static str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "unknown panic payload".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codegen::layout::VecLayout;
    use crate::codegen::{gen_gemm_rect, gen_gemv};
    use crate::metrics::measure_gemv_prog;
    use crate::util::rel_fro_error;

    fn gemm_job(job_id: u64, tile_idx: usize, n: usize, seed: u64) -> (Job, Mat) {
        let ae = AeLevel::Ae5;
        let a = Mat::random(n, n, seed);
        let b = Mat::random(n, n, seed + 1);
        let c = Mat::random(n, n, seed + 2);
        let layout = GemmLayout::rect(n, n, n);
        let prog = Arc::new(gen_gemm_rect(n, n, n, ae, &layout));
        let want = crate::blas::level3::dgemm_ref(&a, &b, &c);
        let gm = layout.pack(&a, &b, &c);
        (Job::GemmTile { job_id, tile_idx, prog, layout, gm }, want)
    }

    #[test]
    fn pool_runs_jobs_and_reuses_workers() {
        let pool = WorkerPool::new(2, AeLevel::Ae5);
        assert_eq!(pool.worker_count(), 2);
        // More jobs than workers forces PE reuse; mixed shapes force
        // reset() resizing.
        let mut wants = std::collections::HashMap::new();
        for (i, n) in [8usize, 12, 8, 16, 12, 8].into_iter().enumerate() {
            let (job, want) = gemm_job(i as u64, 0, n, 100 + i as u64);
            wants.insert(i as u64, want);
            pool.submit(job);
        }
        for _ in 0..6 {
            let (job_id, out, stats) = match pool.recv() {
                Done::GemmTile { job_id, out, stats, .. } => (job_id, out, stats),
                Done::Measured { .. } => panic!("no measurement submitted"),
            };
            let want = &wants[&job_id];
            let err = rel_fro_error(out.as_slice(), want.as_slice());
            assert!(err < 1e-12, "job {job_id}: err {err}");
            assert!(stats.cycles > 0);
        }
        assert_eq!(pool.counts(), PoolJobCounts { gemm_tiles: 6, gemv: 0, level1: 0 });
    }

    #[test]
    fn measurement_jobs_run_on_workers_and_match_inline() {
        // A pooled DGEMV/Level-1 kernel must return exactly the inline
        // measurement (the pool only moves where the simulation runs).
        let ae = AeLevel::Ae5;
        let pool = WorkerPool::new(2, ae);
        let n = 16;
        let gprog = Arc::new(gen_gemv(n, ae, &VecLayout::gemv(n)));
        let want = measure_gemv_prog(n, ae, &gprog);
        pool.submit(Job::Gemv { job_id: 7, n, prog: Arc::clone(&gprog) });
        let lprog = Arc::new(crate::codegen::gen_ddot(n, ae, &VecLayout::level1(n)));
        pool.submit(Job::Level1 { job_id: 8, routine: Routine::Ddot, n, alpha: 1.5, prog: lprog });
        let mut got = Vec::new();
        for _ in 0..2 {
            match pool.recv() {
                Done::Measured { job_id, meas } => got.push((job_id, meas)),
                Done::GemmTile { .. } => panic!("no tile submitted"),
            }
        }
        got.sort_by_key(|(id, _)| *id);
        assert_eq!(got[0].0, 7);
        assert_eq!(got[0].1.latency(), want.latency());
        assert_eq!(got[0].1.routine, Routine::Dgemv);
        assert_eq!(got[1].0, 8);
        assert_eq!(got[1].1.routine, Routine::Ddot);
        assert!(got[1].1.latency() > 0);
        let counts = pool.counts();
        assert_eq!((counts.gemv, counts.level1, counts.gemm_tiles), (1, 1, 0));
    }

    #[test]
    fn drop_joins_idle_workers() {
        let pool = WorkerPool::new(3, AeLevel::Ae2);
        drop(pool); // must not hang
    }

    #[test]
    #[should_panic(expected = "pool worker panicked")]
    fn worker_panic_propagates_instead_of_deadlocking() {
        use crate::pe::{Instr, Program};
        // A DOT on an AE1-configured PE trips check_features inside the
        // worker; recv() must re-raise it rather than block forever.
        let pool = WorkerPool::new(1, AeLevel::Ae1);
        let layout = GemmLayout::rect(4, 4, 4);
        let mut prog = Program::new();
        prog.push(Instr::Dot { rd: 0, ra: 16, rb: 32, n: 4, acc: false });
        prog.push(Instr::Halt);
        pool.submit(Job::GemmTile {
            job_id: 0,
            tile_idx: 0,
            prog: Arc::new(prog),
            layout,
            gm: vec![0.0; layout.gm_words()],
        });
        let _ = pool.recv();
    }
}
