//! Persistent tile-worker pool of the serving engine.
//!
//! The seed coordinator spawned `b×b` fresh host threads (and allocated a
//! fresh [`Pe`]) for every DGEMM request. This pool spawns the workers once
//! per [`super::Coordinator`], feeds them tile jobs over a shared channel,
//! and reuses each worker's `Pe` across kernels via [`Pe::reset`] — so a
//! request stream pays only for simulation, and tiles of *independent*
//! requests overlap (jobs are tagged with a `job_id` and collected by the
//! dispatcher in any arrival order).
//!
//! Host-thread parallelism only: simulated timing comes from the per-tile
//! `PeStats` and the NoC transfer schedule, both of which are independent
//! of which worker ran a tile and in which order.

use crate::codegen::GemmLayout;
use crate::pe::{Pe, PeConfig, PeStats, Program};
use crate::util::Mat;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

/// One tile kernel to simulate: a cached program plus its packed operands.
pub(crate) struct TileJob {
    /// Request this tile belongs to (dispatcher-assigned).
    pub job_id: u64,
    /// Tile index within the request (`bi * b + bj`).
    pub tile_idx: usize,
    /// Shared, cached instruction stream (emitted once per shape).
    pub prog: Arc<Program>,
    /// GM layout of the packed operands; the output block unpacked after
    /// the run is the full `layout.m × layout.p` C block.
    pub layout: GemmLayout,
    /// Packed GM image (length `layout.gm_words()`).
    pub gm: Vec<f64>,
}

/// Result of one tile kernel.
pub(crate) struct TileDone {
    pub job_id: u64,
    pub tile_idx: usize,
    pub out: Mat,
    pub stats: PeStats,
}

/// Worker → dispatcher message: a finished tile, or a caught worker panic
/// (re-raised on the dispatcher by [`TilePool::recv`], preserving the
/// fail-loud behavior the scoped-thread design had).
enum TileMsg {
    Done(TileDone),
    Panicked { job_id: u64, tile_idx: usize, msg: String },
}

/// The pool: `size` workers, spawned once, fed over a shared queue.
pub(crate) struct TilePool {
    jobs: Option<mpsc::Sender<TileJob>>,
    done_rx: mpsc::Receiver<TileMsg>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl TilePool {
    /// Spawn `size` persistent workers simulating PEs configured by `cfg`.
    pub fn new(size: usize, cfg: PeConfig) -> Self {
        assert!(size >= 1, "tile pool needs at least one worker");
        let (jtx, jrx) = mpsc::channel::<TileJob>();
        let (dtx, drx) = mpsc::channel::<TileMsg>();
        let jrx = Arc::new(Mutex::new(jrx));
        let workers = (0..size)
            .map(|i| {
                let jrx = Arc::clone(&jrx);
                let dtx = dtx.clone();
                let cfg = cfg.clone();
                thread::Builder::new()
                    .name(format!("tile-worker-{i}"))
                    .spawn(move || worker_loop(cfg, jrx, dtx))
                    .expect("spawn tile worker")
            })
            .collect();
        Self { jobs: Some(jtx), done_rx: drx, workers }
    }

    /// Number of persistent workers.
    pub fn worker_count(&self) -> usize {
        self.workers.len()
    }

    /// Enqueue a tile job (returns immediately; results come via `recv`).
    pub fn submit(&self, job: TileJob) {
        self.jobs
            .as_ref()
            .expect("pool already shut down")
            .send(job)
            .expect("tile pool hung up");
    }

    /// Block for the next finished tile, in arrival order across jobs.
    /// A worker panic (caught in the worker loop) is re-raised here so a
    /// bad kernel fails the request loudly instead of deadlocking it.
    pub fn recv(&self) -> TileDone {
        match self.done_rx.recv().expect("tile workers gone") {
            TileMsg::Done(d) => d,
            TileMsg::Panicked { job_id, tile_idx, msg } => {
                panic!("tile worker panicked on job {job_id} tile {tile_idx}: {msg}")
            }
        }
    }
}

impl Drop for TilePool {
    fn drop(&mut self) {
        // Closing the job channel makes every worker's recv() fail → exit.
        drop(self.jobs.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(
    cfg: PeConfig,
    jobs: Arc<Mutex<mpsc::Receiver<TileJob>>>,
    done: mpsc::Sender<TileMsg>,
) {
    // The worker's PE is created on the first job and reset()-reused after:
    // a reset PE is bit-identical to a fresh one (see pe::core tests).
    let mut pe: Option<Pe> = None;
    loop {
        // Hold the queue lock only while receiving; pickup is serialized,
        // simulation is not.
        let job = {
            let guard = match jobs.lock() {
                Ok(g) => g,
                Err(_) => return, // a sibling worker panicked mid-recv
            };
            match guard.recv() {
                Ok(j) => j,
                Err(_) => return, // pool dropped: shut down
            }
        };
        let (job_id, tile_idx) = (job.job_id, job.tile_idx);
        let gm_words = job.layout.gm_words();
        if let Some(p) = pe.as_mut() {
            p.reset(gm_words);
        } else {
            pe = Some(Pe::new(cfg.clone(), gm_words));
        }
        let p = pe.as_mut().expect("worker PE initialized above");
        // Catch kernel panics (codegen bugs, feature misuse) and report
        // them: a silently-missing tile would deadlock the dispatcher.
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            p.write_gm(0, &job.gm);
            let stats = p.run(&job.prog);
            let out = job.layout.unpack_c(&p.gm, job.layout.m, job.layout.p);
            (out, stats)
        }));
        let msg = match outcome {
            Ok((out, stats)) => TileMsg::Done(TileDone { job_id, tile_idx, out, stats }),
            Err(payload) => {
                pe = None; // state may be inconsistent; rebuild on next job
                TileMsg::Panicked { job_id, tile_idx, msg: panic_message(payload) }
            }
        };
        if done.send(msg).is_err() {
            return; // dispatcher gone: shut down
        }
    }
}

/// Human-readable text from a caught panic payload.
fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&'static str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "unknown panic payload".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codegen::gen_gemm_rect;
    use crate::pe::AeLevel;
    use crate::util::rel_fro_error;

    fn gemm_job(job_id: u64, tile_idx: usize, n: usize, seed: u64) -> (TileJob, Mat) {
        let ae = AeLevel::Ae5;
        let a = Mat::random(n, n, seed);
        let b = Mat::random(n, n, seed + 1);
        let c = Mat::random(n, n, seed + 2);
        let layout = GemmLayout::rect(n, n, n);
        let prog = Arc::new(gen_gemm_rect(n, n, n, ae, &layout));
        let want = crate::blas::level3::dgemm_ref(&a, &b, &c);
        let gm = layout.pack(&a, &b, &c);
        (TileJob { job_id, tile_idx, prog, layout, gm }, want)
    }

    #[test]
    fn pool_runs_jobs_and_reuses_workers() {
        let pool = TilePool::new(2, PeConfig::paper(AeLevel::Ae5));
        assert_eq!(pool.worker_count(), 2);
        // More jobs than workers forces PE reuse; mixed shapes force
        // reset() resizing.
        let mut wants = std::collections::HashMap::new();
        for (i, n) in [8usize, 12, 8, 16, 12, 8].into_iter().enumerate() {
            let (job, want) = gemm_job(i as u64, 0, n, 100 + i as u64);
            wants.insert(i as u64, want);
            pool.submit(job);
        }
        for _ in 0..6 {
            let d = pool.recv();
            let want = &wants[&d.job_id];
            let err = rel_fro_error(d.out.as_slice(), want.as_slice());
            assert!(err < 1e-12, "job {}: err {err}", d.job_id);
            assert!(d.stats.cycles > 0);
        }
    }

    #[test]
    fn drop_joins_idle_workers() {
        let pool = TilePool::new(3, PeConfig::paper(AeLevel::Ae2));
        drop(pool); // must not hang
    }

    #[test]
    #[should_panic(expected = "tile worker panicked")]
    fn worker_panic_propagates_instead_of_deadlocking() {
        use crate::pe::{Instr, Program};
        // A DOT on an AE1-configured PE trips check_features inside the
        // worker; recv() must re-raise it rather than block forever.
        let pool = TilePool::new(1, PeConfig::paper(AeLevel::Ae1));
        let layout = GemmLayout::rect(4, 4, 4);
        let mut prog = Program::new();
        prog.push(Instr::Dot { rd: 0, ra: 16, rb: 32, n: 4, acc: false });
        prog.push(Instr::Halt);
        pool.submit(TileJob {
            job_id: 0,
            tile_idx: 0,
            prog: Arc::new(prog),
            layout,
            gm: vec![0.0; layout.gm_words()],
        });
        let _ = pool.recv();
    }
}
