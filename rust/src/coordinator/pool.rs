//! Persistent worker pool of the serving engine.
//!
//! The seed coordinator spawned `b×b` fresh host threads (and allocated a
//! fresh [`Pe`]) for every DGEMM request, and simulated every Level-1/2
//! request inline on the dispatcher thread. This pool spawns the workers
//! once per [`super::Coordinator`], feeds them jobs over a shared channel,
//! and reuses each worker's `Pe` across kernels via [`Pe::reset`] — so a
//! request stream pays only for simulation, and kernels of *independent*
//! requests overlap (jobs are tagged with a `job_id` and collected by the
//! dispatcher in any arrival order).
//!
//! Every BLAS level flows through the same [`Job`] channel: DGEMM as
//! per-tile kernels, DGEMV and the Level-1 routines as single-PE
//! measurement kernels on the cached-program paths
//! ([`measure_gemv_sched_on`] / [`measure_level1_sched_on`]). Values are
//! resolved by the dispatcher; the pool burns the simulated cycles.
//!
//! Jobs carry [`ScheduledProgram`]s — already validated and pre-decoded by
//! the program cache. In the default [`ExecMode::Replay`] a worker runs
//! the full combined (value + timing) interpreter only the *first* time a
//! program executes anywhere, memoizing its schedule; every later
//! execution of that program — on any worker — is a lean value-only
//! replay returning the memoized [`PeStats`]. [`ExecMode::Combined`]
//! forces the full interpreter every time (the bench baseline).
//!
//! Host-thread parallelism only: simulated timing comes from the per-kernel
//! `PeStats` and the NoC transfer schedule, both of which are independent
//! of which worker ran a job and in which order.

use crate::codegen::GemmLayout;
use crate::metrics::{measure_gemv_sched_on, measure_level1_sched_on, Measurement, Routine};
use crate::pe::{AeLevel, ExecMode, ExecTier, Pe, PeConfig, PeStats, ScheduledProgram};
use crate::util::Mat;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

/// One unit of pooled work: a cached pre-decoded program plus what the
/// worker needs to run it.
pub(crate) enum Job {
    /// One DGEMM tile kernel: shared cached program + packed operands. The
    /// output block unpacked after the run is the full
    /// `layout.m × layout.p` C block.
    GemmTile {
        /// Request this tile belongs to (dispatcher-assigned).
        job_id: u64,
        /// Tile index within the request (`bi * b + bj`).
        tile_idx: usize,
        sched: Arc<ScheduledProgram>,
        layout: GemmLayout,
        /// Packed GM image (length `layout.gm_words()`).
        gm: Vec<f64>,
    },
    /// Single-PE DGEMV measurement kernel at padded size `n`.
    Gemv { job_id: u64, n: usize, sched: Arc<ScheduledProgram> },
    /// Single-PE Level-1 measurement kernel at padded size `n`. `alpha` is
    /// the constant baked into a DAXPY stream (ignored for reductions).
    Level1 { job_id: u64, routine: Routine, n: usize, alpha: f64, sched: Arc<ScheduledProgram> },
}

impl Job {
    /// Human-readable tag for panic reports.
    fn describe(&self) -> String {
        match self {
            Job::GemmTile { job_id, tile_idx, .. } => format!("job {job_id} gemm tile {tile_idx}"),
            Job::Gemv { job_id, n, .. } => format!("job {job_id} gemv n={n}"),
            Job::Level1 { job_id, routine, n, .. } => format!("job {job_id} {routine:?} n={n}"),
        }
    }
}

/// Result of one pooled job.
pub(crate) enum Done {
    /// A finished DGEMM tile.
    GemmTile { job_id: u64, tile_idx: usize, out: Mat, stats: PeStats },
    /// A finished single-PE measurement (DGEMV or Level-1).
    Measured { job_id: u64, meas: Measurement },
}

/// Worker → dispatcher message: a finished job, or a caught worker panic
/// (re-raised on the dispatcher by [`WorkerPool::recv`], preserving the
/// fail-loud behavior the scoped-thread design had).
enum Msg {
    Done(Done),
    Panicked(String),
}

/// Jobs executed so far, by kind. Incremented by the worker that ran the
/// job — a nonzero count proves pool execution (pinned by tests).
#[derive(Debug, Default)]
struct Counters {
    gemm_tiles: AtomicU64,
    gemv: AtomicU64,
    level1: AtomicU64,
    replays: AtomicU64,
    combined_runs: AtomicU64,
}

/// Snapshot of the pool's per-kind execution counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PoolJobCounts {
    /// DGEMM tile kernels run on pool workers.
    pub gemm_tiles: u64,
    /// DGEMV measurement kernels run on pool workers.
    pub gemv: u64,
    /// Level-1 measurement kernels run on pool workers.
    pub level1: u64,
    /// Kernels executed on the tier-2 value-replay path (schedule already
    /// memoized when the worker picked the job up).
    pub replays: u64,
    /// Kernels executed by the combined value+timing interpreter (first
    /// run of a program, or every run in [`ExecMode::Combined`]).
    pub combined_runs: u64,
}

/// The pool: `size` workers, spawned once, fed over a shared queue.
pub(crate) struct WorkerPool {
    jobs: Option<mpsc::Sender<Job>>,
    done_rx: mpsc::Receiver<Msg>,
    workers: Vec<thread::JoinHandle<()>>,
    counts: Arc<Counters>,
}

impl WorkerPool {
    /// Spawn `size` persistent workers simulating paper-configured PEs at
    /// enhancement level `ae`, executing jobs in `exec` mode.
    pub fn new(size: usize, ae: AeLevel, exec: ExecMode) -> Self {
        assert!(size >= 1, "worker pool needs at least one worker");
        let (jtx, jrx) = mpsc::channel::<Job>();
        let (dtx, drx) = mpsc::channel::<Msg>();
        let jrx = Arc::new(Mutex::new(jrx));
        let counts = Arc::new(Counters::default());
        let workers = (0..size)
            .map(|i| {
                let jrx = Arc::clone(&jrx);
                let dtx = dtx.clone();
                let counts = Arc::clone(&counts);
                thread::Builder::new()
                    .name(format!("pe-worker-{i}"))
                    .spawn(move || worker_loop(ae, exec, jrx, dtx, counts))
                    .expect("spawn pool worker")
            })
            .collect();
        Self { jobs: Some(jtx), done_rx: drx, workers, counts }
    }

    /// Number of persistent workers.
    pub fn worker_count(&self) -> usize {
        self.workers.len()
    }

    /// Jobs executed so far, by kind.
    pub fn counts(&self) -> PoolJobCounts {
        PoolJobCounts {
            gemm_tiles: self.counts.gemm_tiles.load(Ordering::Relaxed),
            gemv: self.counts.gemv.load(Ordering::Relaxed),
            level1: self.counts.level1.load(Ordering::Relaxed),
            replays: self.counts.replays.load(Ordering::Relaxed),
            combined_runs: self.counts.combined_runs.load(Ordering::Relaxed),
        }
    }

    /// Enqueue a job (returns immediately; results come via `recv`).
    pub fn submit(&self, job: Job) {
        self.jobs
            .as_ref()
            .expect("pool already shut down")
            .send(job)
            .expect("worker pool hung up");
    }

    /// Block for the next finished job, in arrival order across jobs.
    /// A worker panic (caught in the worker loop) is re-raised here so a
    /// bad kernel fails the request loudly instead of deadlocking it.
    pub fn recv(&self) -> Done {
        match self.done_rx.recv().expect("pool workers gone") {
            Msg::Done(d) => d,
            Msg::Panicked(msg) => panic!("pool worker panicked on {msg}"),
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // Closing the job channel makes every worker's recv() fail → exit.
        drop(self.jobs.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(
    ae: AeLevel,
    exec: ExecMode,
    jobs: Arc<Mutex<mpsc::Receiver<Job>>>,
    done: mpsc::Sender<Msg>,
    counts: Arc<Counters>,
) {
    // The worker's PE is created on the first job and reset()-reused after:
    // a reset PE is bit-identical to a fresh one (see pe::core tests).
    let mut pe: Option<Pe> = None;
    loop {
        // Hold the queue lock only while receiving; pickup is serialized,
        // simulation is not.
        let job = {
            let guard = match jobs.lock() {
                Ok(g) => g,
                Err(_) => return, // a sibling worker panicked mid-recv
            };
            match guard.recv() {
                Ok(j) => j,
                Err(_) => return, // pool dropped: shut down
            }
        };
        let what = job.describe();
        if pe.is_none() {
            pe = Some(Pe::new(PeConfig::paper(ae), 0));
        }
        let p = pe.as_mut().expect("worker PE initialized above");
        // Catch kernel panics (codegen bugs, feature misuse) and report
        // them: a silently-missing result would deadlock the dispatcher.
        let unwind = std::panic::AssertUnwindSafe(|| run_job(p, ae, exec, job, &counts));
        let outcome = std::panic::catch_unwind(unwind);
        let msg = match outcome {
            Ok(d) => Msg::Done(d),
            Err(payload) => {
                pe = None; // state may be inconsistent; rebuild on next job
                Msg::Panicked(format!("{what}: {}", panic_message(payload)))
            }
        };
        if done.send(msg).is_err() {
            return; // dispatcher gone: shut down
        }
    }
}

/// Run one job on the worker's (reset-reused) PE.
fn run_job(pe: &mut Pe, ae: AeLevel, exec: ExecMode, job: Job, counts: &Counters) -> Done {
    // Count the tier the execution engine reports, not a prediction: a
    // worker that races another onto a fresh kernel may still replay if
    // the sibling's timing pass lands first.
    let tally = |tier: ExecTier| match tier {
        ExecTier::Replayed => counts.replays.fetch_add(1, Ordering::Relaxed),
        ExecTier::Combined => counts.combined_runs.fetch_add(1, Ordering::Relaxed),
    };
    match job {
        Job::GemmTile { job_id, tile_idx, sched, layout, gm } => {
            pe.reset(layout.gm_words());
            pe.write_gm(0, &gm);
            let (stats, tier) = sched.execute_traced(pe, exec);
            let out = layout.unpack_c(&pe.gm, layout.m, layout.p);
            counts.gemm_tiles.fetch_add(1, Ordering::Relaxed);
            tally(tier);
            Done::GemmTile { job_id, tile_idx, out, stats }
        }
        Job::Gemv { job_id, n, sched } => {
            let (meas, tier) = measure_gemv_sched_on(pe, n, ae, &sched, exec);
            counts.gemv.fetch_add(1, Ordering::Relaxed);
            tally(tier);
            Done::Measured { job_id, meas }
        }
        Job::Level1 { job_id, routine, n, alpha, sched } => {
            let (meas, tier) = measure_level1_sched_on(pe, routine, n, alpha, ae, &sched, exec);
            counts.level1.fetch_add(1, Ordering::Relaxed);
            tally(tier);
            Done::Measured { job_id, meas }
        }
    }
}

/// Human-readable text from a caught panic payload.
fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&'static str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "unknown panic payload".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codegen::layout::VecLayout;
    use crate::codegen::{gen_gemm_rect, gen_gemv};
    use crate::metrics::measure_gemv_prog;
    use crate::util::rel_fro_error;

    fn gemm_job(job_id: u64, tile_idx: usize, n: usize, seed: u64) -> (Job, Mat) {
        let ae = AeLevel::Ae5;
        let a = Mat::random(n, n, seed);
        let b = Mat::random(n, n, seed + 1);
        let c = Mat::random(n, n, seed + 2);
        let layout = GemmLayout::rect(n, n, n);
        let prog = gen_gemm_rect(n, n, n, ae, &layout);
        let sched = Arc::new(ScheduledProgram::compile(&prog, ae).expect("tile kernel decodes"));
        let want = crate::blas::level3::dgemm_ref(&a, &b, &c);
        let gm = layout.pack(&a, &b, &c);
        (Job::GemmTile { job_id, tile_idx, sched, layout, gm }, want)
    }

    #[test]
    fn pool_runs_jobs_and_reuses_workers() {
        let pool = WorkerPool::new(2, AeLevel::Ae5, ExecMode::Replay);
        assert_eq!(pool.worker_count(), 2);
        // More jobs than workers forces PE reuse; mixed shapes force
        // reset() resizing.
        let mut wants = std::collections::HashMap::new();
        for (i, n) in [8usize, 12, 8, 16, 12, 8].into_iter().enumerate() {
            let (job, want) = gemm_job(i as u64, 0, n, 100 + i as u64);
            wants.insert(i as u64, want);
            pool.submit(job);
        }
        for _ in 0..6 {
            let (job_id, out, stats) = match pool.recv() {
                Done::GemmTile { job_id, out, stats, .. } => (job_id, out, stats),
                Done::Measured { .. } => panic!("no measurement submitted"),
            };
            let want = &wants[&job_id];
            let err = rel_fro_error(out.as_slice(), want.as_slice());
            assert!(err < 1e-12, "job {job_id}: err {err}");
            assert!(stats.cycles > 0);
        }
        let counts = pool.counts();
        assert_eq!((counts.gemm_tiles, counts.gemv, counts.level1), (6, 0, 0));
        // Every job carried a distinct fresh ScheduledProgram here, so all
        // six executions were combined timing passes.
        assert_eq!(counts.combined_runs, 6);
        assert_eq!(counts.replays, 0);
    }

    #[test]
    fn shared_schedule_replays_after_first_run() {
        // One ScheduledProgram shared by several jobs: only the first
        // execution pays the timing pass; later jobs replay values and
        // return identical stats and identical output.
        let pool = WorkerPool::new(1, AeLevel::Ae5, ExecMode::Replay);
        let (first, want) = gemm_job(0, 0, 12, 500);
        let (sched, layout, gm) = match &first {
            Job::GemmTile { sched, layout, gm, .. } => {
                (Arc::clone(sched), *layout, gm.clone())
            }
            _ => unreachable!(),
        };
        pool.submit(first);
        for id in 1..4u64 {
            pool.submit(Job::GemmTile {
                job_id: id,
                tile_idx: 0,
                sched: Arc::clone(&sched),
                layout,
                gm: gm.clone(),
            });
        }
        let mut stats = Vec::new();
        for _ in 0..4 {
            match pool.recv() {
                Done::GemmTile { out, stats: st, .. } => {
                    let err = rel_fro_error(out.as_slice(), want.as_slice());
                    assert!(err < 1e-12, "replayed tile wrong: {err}");
                    stats.push(st);
                }
                Done::Measured { .. } => panic!("no measurement submitted"),
            }
        }
        assert!(stats.windows(2).all(|w| w[0] == w[1]), "replay must return the memoized stats");
        let counts = pool.counts();
        assert_eq!(counts.combined_runs, 1, "one worker → exactly one timing pass");
        assert_eq!(counts.replays, 3, "later executions replay");
    }

    #[test]
    fn combined_mode_never_replays() {
        let pool = WorkerPool::new(1, AeLevel::Ae5, ExecMode::Combined);
        let (first, _) = gemm_job(0, 0, 8, 600);
        let (sched, layout, gm) = match &first {
            Job::GemmTile { sched, layout, gm, .. } => {
                (Arc::clone(sched), *layout, gm.clone())
            }
            _ => unreachable!(),
        };
        pool.submit(first);
        pool.submit(Job::GemmTile { job_id: 1, tile_idx: 0, sched, layout, gm });
        let (a, b) = match (pool.recv(), pool.recv()) {
            (Done::GemmTile { stats: a, .. }, Done::GemmTile { stats: b, .. }) => (a, b),
            _ => panic!("no measurement submitted"),
        };
        assert_eq!(a, b, "combined re-runs must reproduce the schedule");
        let counts = pool.counts();
        assert_eq!((counts.combined_runs, counts.replays), (2, 0));
    }

    #[test]
    fn measurement_jobs_run_on_workers_and_match_inline() {
        // A pooled DGEMV/Level-1 kernel must return exactly the inline
        // measurement (the pool only moves where the simulation runs).
        let ae = AeLevel::Ae5;
        let pool = WorkerPool::new(2, ae, ExecMode::Replay);
        let n = 16;
        let gprog = gen_gemv(n, ae, &VecLayout::gemv(n));
        let want = measure_gemv_prog(n, ae, &gprog);
        let gsched = Arc::new(ScheduledProgram::compile(&gprog, ae).expect("gemv decodes"));
        pool.submit(Job::Gemv { job_id: 7, n, sched: gsched });
        let lprog = crate::codegen::gen_ddot(n, ae, &VecLayout::level1(n));
        let lsched = Arc::new(ScheduledProgram::compile(&lprog, ae).expect("ddot decodes"));
        pool.submit(Job::Level1 {
            job_id: 8,
            routine: Routine::Ddot,
            n,
            alpha: 1.5,
            sched: lsched,
        });
        let mut got = Vec::new();
        for _ in 0..2 {
            match pool.recv() {
                Done::Measured { job_id, meas } => got.push((job_id, meas)),
                Done::GemmTile { .. } => panic!("no tile submitted"),
            }
        }
        got.sort_by_key(|(id, _)| *id);
        assert_eq!(got[0].0, 7);
        assert_eq!(got[0].1.latency(), want.latency());
        assert_eq!(got[0].1.routine, Routine::Dgemv);
        assert_eq!(got[1].0, 8);
        assert_eq!(got[1].1.routine, Routine::Ddot);
        assert!(got[1].1.latency() > 0);
        let counts = pool.counts();
        assert_eq!((counts.gemv, counts.level1, counts.gemm_tiles), (1, 1, 0));
    }

    #[test]
    fn drop_joins_idle_workers() {
        let pool = WorkerPool::new(3, AeLevel::Ae2, ExecMode::Replay);
        drop(pool); // must not hang
    }

    #[test]
    #[should_panic(expected = "pool worker panicked")]
    fn worker_panic_propagates_instead_of_deadlocking() {
        use crate::pe::{Instr, Program};
        // A kernel decoded for AE5 submitted to an AE1 pool trips the
        // decoded-level assert inside the worker; recv() must re-raise it
        // rather than block forever.
        let pool = WorkerPool::new(1, AeLevel::Ae1, ExecMode::Replay);
        let layout = GemmLayout::rect(4, 4, 4);
        let mut prog = Program::new();
        prog.push(Instr::Dot { rd: 0, ra: 16, rb: 32, n: 4, acc: false });
        prog.push(Instr::Halt);
        let sched = ScheduledProgram::compile(&prog, AeLevel::Ae5).expect("valid for AE5");
        pool.submit(Job::GemmTile {
            job_id: 0,
            tile_idx: 0,
            sched: Arc::new(sched),
            layout,
            gm: vec![0.0; layout.gm_words()],
        });
        let _ = pool.recv();
    }
}
