//! Program/layout cache of the serving engine — shared, multi-tenant.
//!
//! The paper's request path never recompiles kernels: instruction streams
//! are fixed per (routine, shape, enhancement level) and only operands move
//! (the persistent-kernel approach of KBLAS-style GPU servers, realized
//! here for the PE). This cache makes the coordinator behave the same way:
//! `gen_gemm_rect`/`gen_gemm_any`/`gen_gemv`/Level-1 emission runs once per
//! key and the resulting kernel is shared by reference ([`Arc`]) across
//! pool workers, across requests — and, under the engine
//! ([`crate::engine::Engine`]), across *tenants*: the second tenant to
//! request a shape rides the first tenant's warm kernel.
//!
//! What is cached is a [`ScheduledProgram`] — the emitted stream already
//! **pre-decoded** into the packed two-tier form (validation and AE
//! feature checks done once, at insertion) and carrying its memoized
//! [`PeStats`](crate::pe::PeStats) schedule after the first execution. A
//! cache hit therefore skips emission, validation, decoding *and* (in
//! replay mode) the entire cycle-accurate timing pass: pool workers just
//! replay values over the packed stream.
//!
//! Keys are exact: a program is only reused for the identical padded shape
//! and AE level (and, for DAXPY, the identical α, which the generator bakes
//! into the stream as a `Li` constant). Layouts are pure functions of the
//! shape, so they are recomputed by callers rather than cached.
//!
//! Accounting is two-level: the cache keeps shared hit/miss/eviction
//! totals, and every accessor has a `_for` variant that additionally bumps
//! a caller-owned [`CacheTally`] — the per-tenant slice the coordinator
//! reports. The tallies partition the shared totals exactly (evictions are
//! attributed to the tenant whose insertion overflowed a limit).
//!
//! **The counting invariant** is per *request*, not per map probe: every
//! logical request records exactly one hit or one miss. DGEMM requests
//! count at their single program fetch. Level-1/2 requests count at the
//! measurement memo: a present memo is a hit
//! ([`ProgramCache::cached_measurement_for`]), an absent memo is a miss
//! recorded by the submitter (`ProgramCache::record_miss`) — the program
//! fetch that follows uses the *quiet* accessors (`gemv_quiet`,
//! `level1_quiet`), which attribute ownership and evictions but add no
//! second hit/miss event. So `hits + misses` equals the number of requests
//! served, on the sequential and the batched path alike (pinned by tests).
//!
//! The cache is unbounded by default (fine for the paper's shape set) but
//! takes two optional residency limits for adversarial shape streams:
//!
//! * a global **LRU capacity cap** ([`ProgramCache::with_capacity`]): when
//!   more than `capacity` programs are resident, a least-recently-used
//!   (program, measurement) pair is dropped and counted in
//!   [`CacheStats::evictions`]. Victim selection prefers the inserting
//!   tenant's own entries, then unowned entries, before touching a
//!   sibling tenant's warm kernels.
//! * a per-tenant **residency quota** ([`ProgramCache::with_limits`]):
//!   each [`CacheTally`] owner may keep at most `quota` resident kernels —
//!   an insertion that overflows the quota evicts within the overflowing
//!   tenant's *own* resident set, so a shape-churning tenant can no longer
//!   flush a sibling's warm kernels out of a shared capped cache.
//!   Ownership is not permanent: once warm uses by *other* tenants
//!   overtake the inserter's own, the entry is promoted to shared/unowned
//!   — a community kernel stops counting against (and being evictable
//!   under) the quota of whichever tenant happened to emit it first.
//!
//! Eviction never selects a slot whose kernel is still being emitted by a
//! concurrent cold miss (the [`OnceLock`] is unfilled): evicting it would
//! save no memory — the program is not resident yet — and would orphan the
//! in-flight emission, forcing a same-key re-emission. If every candidate
//! is unfilled the cap is transiently exceeded and re-enforced on the next
//! insertion. In-flight kernels are likewise safe from eviction of their
//! entry — workers hold the program by `Arc`.

use crate::codegen::{self, layout::VecLayout, GemmLayout};
use crate::metrics::{Measurement, Routine};
use crate::pe::{AeLevel, Program, ScheduledProgram};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Cache key: routine + padded shape + enhancement level.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ProgramKey {
    /// Rectangular tile DGEMM C (m×p) ← A (m×k)·B (k×p) + C.
    GemmRect { m: usize, p: usize, k: usize, ae: AeLevel },
    /// Single-PE DOT2/3 residual DGEMM at the *raw* (non-4-aligned) size
    /// n — the no-padding alternative served in residual mode.
    GemmAny { n: usize, ae: AeLevel },
    /// Single-PE DGEMV at padded size n.
    Gemv { n: usize, ae: AeLevel },
    /// Level-1 routine at padded size n. `alpha_bits` is the f64 bit
    /// pattern of the baked-in scalar (0 for the reduction routines).
    Level1 { routine: Routine, n: usize, alpha_bits: u64, ae: AeLevel },
}

impl ProgramKey {
    /// Level-1 key with the α normalization rule applied (α only matters
    /// for DAXPY, which bakes it into the stream as a `Li` constant).
    pub fn level1(routine: Routine, n: usize, alpha: f64, ae: AeLevel) -> Self {
        let alpha_bits = if routine == Routine::Daxpy { alpha.to_bits() } else { 0 };
        ProgramKey::Level1 { routine, n, alpha_bits, ae }
    }

    /// The enhancement level baked into the key — the level the cached
    /// kernel is decoded and feature-checked for.
    pub fn ae(&self) -> AeLevel {
        match *self {
            ProgramKey::GemmRect { ae, .. }
            | ProgramKey::GemmAny { ae, .. }
            | ProgramKey::Gemv { ae, .. }
            | ProgramKey::Level1 { ae, .. } => ae,
        }
    }
}

/// Cache hit/miss/eviction accounting (monotonic counters).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    /// Programs (with their paired measurements) dropped by the LRU cap
    /// or a tenant quota.
    pub evictions: u64,
    pub entries: usize,
}

/// One caller's (tenant's) slice of the cache counters — and, for the
/// per-tenant residency quota, the caller's *identity*: entries inserted
/// through a `_for`/`_quiet` accessor are owned by the tally that inserted
/// them, and the quota bounds each owner's resident set. The coordinator
/// passes its tally into the accessors so multi-tenant serving can split
/// [`CacheStats`] per tenant while the cache keeps shared totals.
#[derive(Debug)]
pub struct CacheTally {
    /// Process-unique owner id (assigned at construction).
    owner: u64,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
}

impl Default for CacheTally {
    fn default() -> Self {
        static NEXT_OWNER: AtomicU64 = AtomicU64::new(1);
        Self {
            owner: NEXT_OWNER.fetch_add(1, Ordering::Relaxed),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }
}

impl CacheTally {
    fn add_hit(&self) {
        self.hits.fetch_add(1, Ordering::Relaxed);
    }

    fn add_miss(&self) {
        self.misses.fetch_add(1, Ordering::Relaxed);
    }

    fn add_eviction(&self) {
        self.evictions.fetch_add(1, Ordering::Relaxed);
    }

    /// Raw `(hits, misses, evictions)` loads — the delta primitive the
    /// tracing layer uses: the coordinator snapshots the tally around
    /// staging one request and emits one typed cache event per increment
    /// (the tally is tenant-private and staging runs on the dispatcher
    /// thread, so the delta is exactly that request's cache traffic).
    pub(crate) fn counts(&self) -> (u64, u64, u64) {
        (
            self.hits.load(Ordering::Relaxed),
            self.misses.load(Ordering::Relaxed),
            self.evictions.load(Ordering::Relaxed),
        )
    }

    /// Snapshot as [`CacheStats`]. `entries` is supplied by the caller
    /// (residency is a property of the shared cache, not of one tenant).
    pub fn snapshot(&self, entries: usize) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            entries,
        }
    }
}

/// A resident kernel slot with its LRU clock stamp and owner. The slot is
/// filled *outside* the map lock (see [`ProgramCache::get_or_emit_for`]):
/// the inserting caller emits + decodes into the [`OnceLock`] while only
/// same-key callers block on it — a cold miss never head-of-line-blocks
/// other tenants' keys, and an emission panic unwinds that caller without
/// poisoning the shared map.
#[derive(Debug)]
struct Entry {
    slot: Arc<OnceLock<Arc<ScheduledProgram>>>,
    /// Monotonic clock value of the most recent use.
    last_used: u64,
    /// The [`CacheTally`] owner whose request inserted this entry (`None`
    /// for tally-less callers) — the identity the residency quota bounds.
    /// Cleared (promoted to shared/unowned) once cross-tenant use
    /// dominates the owner's own, so community property stops burning
    /// the inserting tenant's quota.
    owner: Option<u64>,
    /// Warm uses by the owning tenant since insertion.
    own_hits: u64,
    /// Warm uses by other tenants (or tally-less callers) — when these
    /// overtake `own_hits`, the entry is promoted to unowned.
    foreign_hits: u64,
}

impl Entry {
    /// A slot only counts as a resident eviction victim once its kernel
    /// has actually been emitted into it.
    fn filled(&self) -> bool {
        self.slot.get().is_some()
    }

    /// Record a warm use by `user` and promote the entry to shared/unowned
    /// once foreign uses overtake the owner's own. The first inserter paid
    /// the emission, but a kernel that mostly serves *other* tenants is
    /// community property — charging it against the inserter's quota
    /// forever would let siblings' traffic evict the inserter's genuinely
    /// private kernels (and, worse, let the inserter's own quota pressure
    /// evict a kernel everyone else is warm on).
    fn note_use(&mut self, user: Option<u64>) {
        if self.owner.is_none() {
            return;
        }
        if user == self.owner {
            self.own_hits += 1;
        } else {
            self.foreign_hits += 1;
            if self.foreign_hits > self.own_hits {
                self.owner = None;
            }
        }
    }
}

/// Lock-protected state: programs and their memoized measurements share one
/// lock (and one LRU clock) so eviction can drop both sides of a key
/// atomically.
#[derive(Debug, Default)]
struct Inner {
    programs: HashMap<ProgramKey, Entry>,
    /// Single-PE measurements are pure functions of the key (fixed operand
    /// seeds + cached program + data-independent timing), so they are
    /// memoized alongside the programs.
    measurements: HashMap<ProgramKey, Measurement>,
    clock: u64,
}

/// Thread-safe program cache. Emission happens at most once per resident
/// key: the map lock only covers the lookup/insert of a per-key slot, and
/// the multi-million-instruction emission + decode/validate pass runs
/// outside it, inside the slot's [`OnceLock`] — concurrent requests for
/// the *same* key block on the slot rather than duplicating the work,
/// while requests for other keys (other tenants) proceed untouched.
#[derive(Debug, Default)]
pub struct ProgramCache {
    inner: Mutex<Inner>,
    /// Global LRU capacity in resident programs (`None` = unbounded).
    capacity: Option<usize>,
    /// Per-[`CacheTally`]-owner residency quota (`None` = unscoped).
    quota: Option<usize>,
    /// Shared totals across every caller.
    totals: CacheTally,
}

impl ProgramCache {
    /// Unbounded cache (the default — every emitted kernel stays resident).
    pub fn new() -> Self {
        Self::default()
    }

    /// Cache holding at most `capacity` programs, evicting a
    /// least-recently-used kernel (and its memoized measurement) beyond
    /// that. No per-tenant quota.
    pub fn with_capacity(capacity: usize) -> Self {
        Self::with_limits(Some(capacity), None)
    }

    /// Cache with both residency limits: the global LRU `capacity` cap and
    /// the per-tenant `quota` (each [`CacheTally`] owner may keep at most
    /// `quota` kernels resident; overflowing insertions evict within the
    /// owner's own resident set). Either limit may be `None`.
    pub fn with_limits(capacity: Option<usize>, quota: Option<usize>) -> Self {
        if let Some(cap) = capacity {
            assert!(cap >= 1, "program cache capacity must be at least 1");
        }
        if let Some(q) = quota {
            assert!(q >= 1, "program cache tenant quota must be at least 1");
        }
        Self { capacity, quota, ..Self::default() }
    }

    /// The global LRU capacity (`None` = unbounded).
    pub fn capacity(&self) -> Option<usize> {
        self.capacity
    }

    /// The per-tenant residency quota (`None` = unscoped).
    pub fn quota(&self) -> Option<usize> {
        self.quota
    }

    fn note_hit(&self, tally: Option<&CacheTally>) {
        self.totals.add_hit();
        if let Some(t) = tally {
            t.add_hit();
        }
    }

    fn note_miss(&self, tally: Option<&CacheTally>) {
        self.totals.add_miss();
        if let Some(t) = tally {
            t.add_miss();
        }
    }

    fn note_eviction(&self, tally: Option<&CacheTally>) {
        self.totals.add_eviction();
        if let Some(t) = tally {
            t.add_eviction();
        }
    }

    /// Fetch the pre-decoded program for `key`, emitting it with `emit`
    /// (and decoding it for the key's AE level) on first use. Repeated
    /// calls with the same resident key return the *same* allocation
    /// (`Arc::ptr_eq` holds) — the determinism tests pin this — which is
    /// what lets the one-time timing schedule memoized inside the
    /// [`ScheduledProgram`] be shared by every later request.
    pub fn get_or_emit(
        &self,
        key: ProgramKey,
        emit: impl FnOnce() -> Program,
    ) -> Arc<ScheduledProgram> {
        self.get_or_emit_for(key, emit, None)
    }

    /// [`ProgramCache::get_or_emit`] that additionally bumps the caller's
    /// per-tenant [`CacheTally`] and owns the inserted entry for quota
    /// purposes.
    ///
    /// Locking: the shared map lock covers only the slot lookup/insert;
    /// emission + decode happen inside the per-key slot, so a cold miss
    /// blocks same-key callers only (the multi-tenant head-of-line
    /// guarantee), and a panicking emission unwinds the requesting tenant
    /// without poisoning the cache for everyone else (a later request for
    /// the key simply retries the emission into the still-empty slot).
    pub fn get_or_emit_for(
        &self,
        key: ProgramKey,
        emit: impl FnOnce() -> Program,
        tally: Option<&CacheTally>,
    ) -> Arc<ScheduledProgram> {
        self.get_or_emit_impl(key, emit, tally, true)
    }

    /// [`ProgramCache::get_or_emit_for`] without the hit/miss event: the
    /// program fetch of the Level-1/2 measurement path, whose one counting
    /// event is recorded at the memo instead (see the module docs).
    /// Ownership and eviction charging still follow `tally`.
    pub(crate) fn get_or_emit_quiet(
        &self,
        key: ProgramKey,
        emit: impl FnOnce() -> Program,
        tally: Option<&CacheTally>,
    ) -> Arc<ScheduledProgram> {
        self.get_or_emit_impl(key, emit, tally, false)
    }

    fn get_or_emit_impl(
        &self,
        key: ProgramKey,
        emit: impl FnOnce() -> Program,
        tally: Option<&CacheTally>,
        counted: bool,
    ) -> Arc<ScheduledProgram> {
        let slot = {
            let mut inner = self.inner.lock().expect("program cache poisoned");
            inner.clock += 1;
            let clock = inner.clock;
            if let Some(e) = inner.programs.get_mut(&key) {
                e.last_used = clock;
                e.note_use(tally.map(|t| t.owner));
                if counted {
                    self.note_hit(tally);
                }
                Arc::clone(&e.slot)
            } else {
                if counted {
                    self.note_miss(tally);
                }
                let slot = Arc::new(OnceLock::new());
                let owner = tally.map(|t| t.owner);
                let entry = Entry {
                    slot: Arc::clone(&slot),
                    last_used: clock,
                    owner,
                    own_hits: 0,
                    foreign_hits: 0,
                };
                inner.programs.insert(key, entry);
                self.enforce_limits(&mut inner, key, owner, tally);
                slot
            }
        };
        Arc::clone(slot.get_or_init(|| {
            let prog = emit();
            Arc::new(
                ScheduledProgram::compile(&prog, key.ae())
                    .unwrap_or_else(|e| panic!("emitted kernel for {key:?} is invalid: {e}")),
            )
        }))
    }

    /// The least-recently-used *resident* (filled) entry satisfying
    /// `pred`, never `keep` (the key just inserted/refreshed). Unfilled
    /// slots — kernels still being emitted by a concurrent cold miss —
    /// are exempt: evicting one saves no memory and would orphan the
    /// in-flight emission into a same-key re-emission.
    fn lru_victim(
        inner: &Inner,
        keep: ProgramKey,
        pred: impl Fn(&Entry) -> bool,
    ) -> Option<ProgramKey> {
        inner
            .programs
            .iter()
            .filter(|(k, e)| **k != keep && e.filled() && pred(e))
            .min_by_key(|(_, e)| e.last_used)
            .map(|(k, _)| *k)
    }

    /// Drop `victim` (program and paired measurement), charging the
    /// eviction to the inserting caller's tally.
    fn evict_key(&self, inner: &mut Inner, victim: ProgramKey, tally: Option<&CacheTally>) {
        inner.programs.remove(&victim);
        inner.measurements.remove(&victim);
        self.note_eviction(tally);
    }

    /// Enforce both residency limits after inserting `keep` for `owner`.
    /// Evictions are charged to the inserting caller's tally. If every
    /// candidate victim is an unfilled in-flight slot, the limit is
    /// transiently exceeded and re-enforced on the next insertion.
    fn enforce_limits(
        &self,
        inner: &mut Inner,
        keep: ProgramKey,
        owner: Option<u64>,
        tally: Option<&CacheTally>,
    ) {
        // Per-tenant quota: the overflowing tenant evicts within its own
        // resident set — a sibling's warm kernels are never candidates.
        if let (Some(quota), Some(o)) = (self.quota, owner) {
            loop {
                let owned = inner.programs.values().filter(|e| e.owner == Some(o)).count();
                if owned <= quota {
                    break;
                }
                let Some(victim) = Self::lru_victim(inner, keep, |e| e.owner == Some(o)) else {
                    break;
                };
                self.evict_key(inner, victim, tally);
            }
        }
        // Global LRU cap: prefer the inserter's own and unowned entries;
        // touch a sibling tenant's kernels only as the last resort that
        // keeps the cache bounded at all.
        let Some(cap) = self.capacity else { return };
        while inner.programs.len() > cap {
            let victim = Self::lru_victim(inner, keep, |e| e.owner == owner || e.owner.is_none())
                .or_else(|| Self::lru_victim(inner, keep, |_| true));
            let Some(victim) = victim else { break };
            self.evict_key(inner, victim, tally);
        }
    }

    /// Emit the DGEMV kernel for padded size `n` (shared by the counted
    /// and quiet accessors so they cannot drift apart).
    fn emit_gemv(n: usize, ae: AeLevel) -> Program {
        let l = VecLayout::gemv(n);
        codegen::gen_gemv(n, ae, &l)
    }

    /// Emit the Level-1 kernel for `routine` at padded size `n`.
    fn emit_level1(routine: Routine, n: usize, alpha: f64, ae: AeLevel) -> Program {
        let l = VecLayout::level1(n);
        match routine {
            Routine::Ddot => codegen::gen_ddot(n, ae, &l),
            Routine::Dnrm2 => codegen::gen_dnrm2(n, ae, &l),
            Routine::Daxpy => codegen::gen_daxpy(n, alpha, ae, &l),
            _ => panic!("not a level-1 routine: {routine:?}"),
        }
    }

    /// Cached rectangular DGEMM tile kernel (dims already padded to 4).
    pub fn gemm_rect(&self, m: usize, p: usize, k: usize, ae: AeLevel) -> Arc<ScheduledProgram> {
        self.gemm_rect_for(m, p, k, ae, None)
    }

    /// [`ProgramCache::gemm_rect`] with a per-tenant tally.
    pub fn gemm_rect_for(
        &self,
        m: usize,
        p: usize,
        k: usize,
        ae: AeLevel,
        tally: Option<&CacheTally>,
    ) -> Arc<ScheduledProgram> {
        self.get_or_emit_for(
            ProgramKey::GemmRect { m, p, k, ae },
            || {
                let layout = GemmLayout::rect(m, p, k);
                codegen::gen_gemm_rect(m, p, k, ae, &layout)
            },
            tally,
        )
    }

    /// Cached single-PE DOT2/3 residual DGEMM kernel at the raw size
    /// `n ≥ 2` (no padding — edge blocks use 2- and 3-lane dots). AE2+
    /// only: the residual path needs the RDP.
    pub fn gemm_any(&self, n: usize, ae: AeLevel) -> Arc<ScheduledProgram> {
        self.gemm_any_for(n, ae, None)
    }

    /// [`ProgramCache::gemm_any`] with a per-tenant tally.
    pub fn gemm_any_for(
        &self,
        n: usize,
        ae: AeLevel,
        tally: Option<&CacheTally>,
    ) -> Arc<ScheduledProgram> {
        self.get_or_emit_for(
            ProgramKey::GemmAny { n, ae },
            || {
                let layout = GemmLayout::rect_any(n, n, n);
                codegen::gen_gemm_any(n, ae, &layout)
            },
            tally,
        )
    }

    /// Cached DGEMV kernel (n already padded to 4).
    pub fn gemv(&self, n: usize, ae: AeLevel) -> Arc<ScheduledProgram> {
        self.gemv_for(n, ae, None)
    }

    /// [`ProgramCache::gemv`] with a per-tenant tally.
    pub fn gemv_for(
        &self,
        n: usize,
        ae: AeLevel,
        tally: Option<&CacheTally>,
    ) -> Arc<ScheduledProgram> {
        self.get_or_emit_for(ProgramKey::Gemv { n, ae }, || Self::emit_gemv(n, ae), tally)
    }

    /// [`ProgramCache::gemv`] without a hit/miss event — the measurement
    /// path's program fetch (its one event was recorded at the memo).
    pub(crate) fn gemv_quiet(
        &self,
        n: usize,
        ae: AeLevel,
        tally: Option<&CacheTally>,
    ) -> Arc<ScheduledProgram> {
        self.get_or_emit_quiet(ProgramKey::Gemv { n, ae }, || Self::emit_gemv(n, ae), tally)
    }

    /// Cached Level-1 kernel (n already padded to 4). `alpha` is only
    /// meaningful for [`Routine::Daxpy`]; it is normalized out of the key
    /// for the reduction routines.
    pub fn level1(
        &self,
        routine: Routine,
        n: usize,
        alpha: f64,
        ae: AeLevel,
    ) -> Arc<ScheduledProgram> {
        self.level1_for(routine, n, alpha, ae, None)
    }

    /// [`ProgramCache::level1`] with a per-tenant tally.
    pub fn level1_for(
        &self,
        routine: Routine,
        n: usize,
        alpha: f64,
        ae: AeLevel,
        tally: Option<&CacheTally>,
    ) -> Arc<ScheduledProgram> {
        self.get_or_emit_for(
            ProgramKey::level1(routine, n, alpha, ae),
            || Self::emit_level1(routine, n, alpha, ae),
            tally,
        )
    }

    /// [`ProgramCache::level1`] without a hit/miss event — the measurement
    /// path's program fetch (its one event was recorded at the memo).
    pub(crate) fn level1_quiet(
        &self,
        routine: Routine,
        n: usize,
        alpha: f64,
        ae: AeLevel,
        tally: Option<&CacheTally>,
    ) -> Arc<ScheduledProgram> {
        self.get_or_emit_quiet(
            ProgramKey::level1(routine, n, alpha, ae),
            || Self::emit_level1(routine, n, alpha, ae),
            tally,
        )
    }

    /// The memoized [`Measurement`] for `key`, if present. A memo return is
    /// a warm-cache hit (counted in [`CacheStats::hits`]) even though no
    /// program is fetched — repeated Level-1/2 requests skip the simulation
    /// entirely — and refreshes the key's LRU slot. An absent memo records
    /// nothing here: the submitter records the request's one miss via
    /// `ProgramCache::record_miss` when it actually pays the simulation
    /// (see the module-level counting invariant).
    pub fn cached_measurement(&self, key: &ProgramKey) -> Option<Measurement> {
        self.cached_measurement_for(key, None)
    }

    /// [`ProgramCache::cached_measurement`] with a per-tenant tally.
    pub fn cached_measurement_for(
        &self,
        key: &ProgramKey,
        tally: Option<&CacheTally>,
    ) -> Option<Measurement> {
        let mut inner = self.inner.lock().expect("program cache poisoned");
        inner.clock += 1;
        let clock = inner.clock;
        let meas = inner.measurements.get(key).cloned();
        if meas.is_some() {
            if let Some(e) = inner.programs.get_mut(key) {
                e.last_used = clock;
                e.note_use(tally.map(|t| t.owner));
            }
            self.note_hit(tally);
        }
        meas
    }

    /// Record a warm hit that was served outside the cache — a request that
    /// attached to an identical in-flight measurement instead of submitting
    /// a duplicate kernel — so `hits` stays comparable with the sequential
    /// path, where the same request would memo-hit.
    pub(crate) fn record_hit(&self, tally: Option<&CacheTally>) {
        self.note_hit(tally);
    }

    /// Record the miss side of the measurement memo: called once per
    /// Level-1/2 request that found no memo and submits (pays) the
    /// simulation — the symmetric counterpart of the memo hit, keeping
    /// `hits + misses` equal to the number of requests served.
    pub(crate) fn record_miss(&self, tally: Option<&CacheTally>) {
        self.note_miss(tally);
    }

    /// Store a measurement computed on a pool worker. Dropped silently if
    /// the paired program was evicted while the kernel was in flight
    /// (program and measurement must stay paired so eviction removes both).
    pub(crate) fn store_measurement(&self, key: ProgramKey, meas: Measurement) {
        let mut inner = self.inner.lock().expect("program cache poisoned");
        if inner.programs.contains_key(&key) {
            inner.measurements.entry(key).or_insert(meas);
        }
    }

    /// Shared hit/miss/eviction/entry counters since construction, over
    /// every caller (the per-tenant tallies partition these).
    pub fn stats(&self) -> CacheStats {
        self.totals.snapshot(self.len())
    }

    /// Number of cached programs.
    pub fn len(&self) -> usize {
        self.inner.lock().expect("program cache poisoned").programs.len()
    }

    /// True if nothing has been cached yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Resident program count for one [`CacheTally`] owner — what the
    /// per-tenant quota bounds.
    pub fn owned_len(&self, tally: &CacheTally) -> usize {
        let inner = self.inner.lock().expect("program cache poisoned");
        inner.programs.values().filter(|e| e.owner == Some(tally.owner)).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::measure_level1_prog;
    use crate::pe::DecodedProgram;

    #[test]
    fn same_key_is_pointer_equal() {
        let cache = ProgramCache::new();
        let p1 = cache.gemm_rect(8, 8, 8, AeLevel::Ae5);
        let p2 = cache.gemm_rect(8, 8, 8, AeLevel::Ae5);
        assert!(Arc::ptr_eq(&p1, &p2), "cache must return the shared program");
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.entries, s.evictions), (1, 1, 1, 0));
    }

    #[test]
    fn distinct_keys_are_distinct_programs() {
        let cache = ProgramCache::new();
        let a = cache.gemm_rect(8, 8, 8, AeLevel::Ae5);
        let b = cache.gemm_rect(8, 8, 8, AeLevel::Ae4);
        let c = cache.gemm_rect(8, 8, 16, AeLevel::Ae5);
        assert!(!Arc::ptr_eq(&a, &b));
        assert!(!Arc::ptr_eq(&a, &c));
        assert_eq!(cache.len(), 3);
    }

    #[test]
    fn cached_program_equals_direct_emission() {
        let cache = ProgramCache::new();
        let cached = cache.gemv(12, AeLevel::Ae3);
        let l = VecLayout::gemv(12);
        let direct = codegen::gen_gemv(12, AeLevel::Ae3, &l);
        let decoded_direct = DecodedProgram::decode(&direct, AeLevel::Ae3).unwrap();
        assert_eq!(cached.decoded(), &decoded_direct);
        assert_eq!(cached.ae(), AeLevel::Ae3);
    }

    #[test]
    fn gemm_any_is_cached_under_its_own_key() {
        let cache = ProgramCache::new();
        let r1 = cache.gemm_any(10, AeLevel::Ae5);
        let r2 = cache.gemm_any(10, AeLevel::Ae5);
        assert!(Arc::ptr_eq(&r1, &r2), "residual kernel must be shared");
        // A 4-aligned residual kernel and the padded tile kernel of the
        // same n are distinct keys (different instruction streams).
        let any8 = cache.gemm_any(8, AeLevel::Ae5);
        let rect8 = cache.gemm_rect(8, 8, 8, AeLevel::Ae5);
        assert!(!Arc::ptr_eq(&any8, &rect8));
        assert_eq!(ProgramKey::GemmAny { n: 10, ae: AeLevel::Ae5 }.ae(), AeLevel::Ae5);
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 3, 3));
    }

    #[test]
    fn daxpy_alpha_is_part_of_the_key() {
        let cache = ProgramCache::new();
        let a = cache.level1(Routine::Daxpy, 16, 1.5, AeLevel::Ae5);
        let b = cache.level1(Routine::Daxpy, 16, 2.5, AeLevel::Ae5);
        let c = cache.level1(Routine::Daxpy, 16, 1.5, AeLevel::Ae5);
        assert!(!Arc::ptr_eq(&a, &b), "different alpha must not share a program");
        assert!(Arc::ptr_eq(&a, &c));
        // Reduction routines ignore alpha entirely.
        let d = cache.level1(Routine::Ddot, 16, 1.5, AeLevel::Ae5);
        let e = cache.level1(Routine::Ddot, 16, 9.0, AeLevel::Ae5);
        assert!(Arc::ptr_eq(&d, &e));
    }

    #[test]
    fn unbounded_cache_never_evicts() {
        let cache = ProgramCache::new();
        assert_eq!(cache.capacity(), None);
        assert_eq!(cache.quota(), None);
        for n in 1..=10usize {
            let _ = cache.gemm_rect(4 * n, 4 * n, 4 * n, AeLevel::Ae5);
        }
        let s = cache.stats();
        assert_eq!((s.entries, s.evictions), (10, 0));
    }

    #[test]
    fn lru_evicts_least_recently_used_first() {
        let cache = ProgramCache::with_capacity(2);
        assert_eq!(cache.capacity(), Some(2));
        let a = cache.gemm_rect(4, 4, 4, AeLevel::Ae5); // A
        let _ = cache.gemm_rect(8, 8, 8, AeLevel::Ae5); // B
        let a2 = cache.gemm_rect(4, 4, 4, AeLevel::Ae5); // touch A → B is LRU
        assert!(Arc::ptr_eq(&a, &a2));
        let _ = cache.gemm_rect(12, 12, 12, AeLevel::Ae5); // C evicts B
        let s = cache.stats();
        assert_eq!((s.entries, s.evictions), (2, 1));
        // A stayed resident (pointer-equal); B was evicted (fresh miss).
        let a3 = cache.gemm_rect(4, 4, 4, AeLevel::Ae5);
        assert!(Arc::ptr_eq(&a, &a3), "recently used key must survive eviction");
        let misses_before = cache.stats().misses;
        let _ = cache.gemm_rect(8, 8, 8, AeLevel::Ae5);
        assert_eq!(cache.stats().misses, misses_before + 1, "evicted key must re-emit");
    }

    #[test]
    fn eviction_drops_the_paired_measurement() {
        let cache = ProgramCache::with_capacity(1);
        let key = ProgramKey::level1(Routine::Ddot, 8, 1.5, AeLevel::Ae4);
        let _ = cache.level1(Routine::Ddot, 8, 1.5, AeLevel::Ae4);
        let prog = codegen::gen_ddot(8, AeLevel::Ae4, &VecLayout::level1(8));
        let meas = measure_level1_prog(Routine::Ddot, 8, 1.5, AeLevel::Ae4, &prog);
        cache.store_measurement(key, meas);
        assert!(cache.cached_measurement(&key).is_some());
        let _ = cache.gemm_rect(4, 4, 4, AeLevel::Ae4); // evicts the DDOT pair
        assert_eq!(cache.stats().evictions, 1);
        assert!(cache.cached_measurement(&key).is_none(), "measurement must go with program");
    }

    #[test]
    fn store_measurement_requires_resident_program() {
        // A measurement landing after its program was evicted is dropped:
        // keys stay paired, so the LRU cap really bounds residency.
        let cache = ProgramCache::with_capacity(1);
        let key = ProgramKey::level1(Routine::Ddot, 8, 1.5, AeLevel::Ae4);
        let _ = cache.level1(Routine::Ddot, 8, 1.5, AeLevel::Ae4);
        let prog = codegen::gen_ddot(8, AeLevel::Ae4, &VecLayout::level1(8));
        let meas = measure_level1_prog(Routine::Ddot, 8, 1.5, AeLevel::Ae4, &prog);
        let _ = cache.gemm_rect(4, 4, 4, AeLevel::Ae4); // evicts the DDOT key
        cache.store_measurement(key, meas);
        assert!(cache.cached_measurement(&key).is_none());
    }

    #[test]
    fn tallies_partition_the_shared_totals() {
        let cache = ProgramCache::with_capacity(1);
        let ta = CacheTally::default();
        let tb = CacheTally::default();
        // Request 1–3 (program path): tenant a emits, tenant b rides the
        // warm kernel, then evicts it with its own shape (the eviction is
        // charged to b).
        let _ = cache.gemm_rect_for(8, 8, 8, AeLevel::Ae5, Some(&ta));
        let _ = cache.gemm_rect_for(8, 8, 8, AeLevel::Ae5, Some(&tb));
        let _ = cache.gemm_rect_for(4, 4, 4, AeLevel::Ae5, Some(&tb));
        // Request 4 (memo path, tenant a): no memo → a records the miss
        // and fetches the program quietly (no second event); inserting the
        // DDOT kernel overflows the cap and evicts b's resident GEMM —
        // charged to a.
        let key = ProgramKey::level1(Routine::Ddot, 8, 1.5, AeLevel::Ae4);
        assert!(cache.cached_measurement_for(&key, Some(&ta)).is_none());
        cache.record_miss(Some(&ta));
        let _ = cache.level1_quiet(Routine::Ddot, 8, 1.5, AeLevel::Ae4, Some(&ta));
        let prog = codegen::gen_ddot(8, AeLevel::Ae4, &VecLayout::level1(8));
        let meas = measure_level1_prog(Routine::Ddot, 8, 1.5, AeLevel::Ae4, &prog);
        cache.store_measurement(key, meas);
        // Request 5 (memo path, tenant b): warm memo — one hit, no program
        // fetch at all.
        assert!(cache.cached_measurement_for(&key, Some(&tb)).is_some());
        let (sa, sb, total) = (ta.snapshot(cache.len()), tb.snapshot(cache.len()), cache.stats());
        assert_eq!((sa.hits, sa.misses, sa.evictions), (0, 2, 1));
        assert_eq!((sb.hits, sb.misses, sb.evictions), (2, 1, 1));
        assert_eq!(sa.hits + sb.hits, total.hits);
        assert_eq!(sa.misses + sb.misses, total.misses);
        assert_eq!(sa.evictions + sb.evictions, total.evictions);
        assert_eq!(total.entries, 1);
        // The counting invariant: five requests, five hit-or-miss events.
        assert_eq!(total.hits + total.misses, 5, "one event per request: {total:?}");
    }

    #[test]
    fn quota_bounds_each_tenants_residency() {
        let cache = ProgramCache::with_limits(Some(4), Some(2));
        assert_eq!((cache.capacity(), cache.quota()), (Some(4), Some(2)));
        let churn = CacheTally::default();
        let sibling = CacheTally::default();
        let warm = cache.gemm_rect_for(8, 8, 8, AeLevel::Ae5, Some(&sibling));
        // The churning tenant cycles through many distinct shapes: its own
        // resident set is capped at the quota, its own LRU entries are the
        // victims, and the sibling's kernel is never touched.
        for n in [3usize, 4, 5, 6, 7, 8] {
            let _ = cache.gemm_rect_for(4 * n, 4 * n, 4 * n, AeLevel::Ae5, Some(&churn));
            assert!(cache.owned_len(&churn) <= 2, "quota must bound the churner");
        }
        let still_warm = cache.gemm_rect_for(8, 8, 8, AeLevel::Ae5, Some(&sibling));
        assert!(
            Arc::ptr_eq(&warm, &still_warm),
            "a churning tenant must not evict a sibling's resident kernel"
        );
        let (sc, ss) = (churn.snapshot(cache.len()), sibling.snapshot(cache.len()));
        assert_eq!(sc.evictions, 4, "six inserts into quota 2 evict four of the churner's own");
        assert_eq!(ss.evictions, 0);
        assert_eq!((ss.hits, ss.misses), (1, 1));
        assert_eq!(cache.owned_len(&sibling), 1);
    }

    #[test]
    fn dominated_entries_promote_to_shared_and_leave_the_inserters_quota() {
        let cache = ProgramCache::with_limits(None, Some(1));
        let gen = CacheTally::default();
        let sib = CacheTally::default();
        let warm = cache.gemm_rect_for(8, 8, 8, AeLevel::Ae5, Some(&gen));
        assert_eq!(cache.owned_len(&gen), 1);
        // The sibling's warm traffic overtakes the inserter's (one foreign
        // hit against zero own): the kernel becomes community property.
        let _ = cache.gemm_rect_for(8, 8, 8, AeLevel::Ae5, Some(&sib));
        assert_eq!(cache.owned_len(&gen), 0, "dominated entry must shed its owner");
        // The inserter's quota-1 slot is free again, so its next shape
        // coexists with the community kernel instead of evicting it.
        let _ = cache.gemm_rect_for(4, 4, 4, AeLevel::Ae5, Some(&gen));
        let again = cache.gemm_rect_for(8, 8, 8, AeLevel::Ae5, Some(&sib));
        assert!(
            Arc::ptr_eq(&warm, &again),
            "a promoted kernel must survive its first inserter's quota pressure"
        );
        assert_eq!(gen.snapshot(cache.len()).evictions, 0);
        assert_eq!(cache.owned_len(&gen), 1, "only the fresh private shape is charged");
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn capacity_eviction_prefers_own_then_unowned_entries() {
        let cache = ProgramCache::with_capacity(2);
        let ta = CacheTally::default();
        let tb = CacheTally::default();
        let b_kernel = cache.gemm_rect_for(8, 8, 8, AeLevel::Ae5, Some(&tb));
        let _ = cache.gemm_rect_for(4, 4, 4, AeLevel::Ae5, Some(&ta)); // a's own
        // a inserts a third shape: the cap overflows and a's *own* LRU
        // entry goes first, not b's older kernel.
        let _ = cache.gemm_rect_for(12, 12, 12, AeLevel::Ae5, Some(&ta));
        let b_again = cache.gemm_rect_for(8, 8, 8, AeLevel::Ae5, Some(&tb));
        assert!(Arc::ptr_eq(&b_kernel, &b_again), "own entries must be preferred victims");
        assert_eq!(ta.snapshot(cache.len()).evictions, 1);
        assert_eq!(tb.snapshot(cache.len()).evictions, 0);
    }

    #[test]
    fn in_flight_slot_is_never_an_eviction_victim() {
        use std::sync::atomic::AtomicUsize;
        use std::sync::Barrier;
        // Two threads race distinct keys into a capacity-1 cache. Both
        // emissions are in flight (unfilled slots) simultaneously — the
        // barrier guarantees it — so neither may be evicted: each key is
        // emitted exactly once, and both programs stay resident until a
        // later insertion finds filled victims.
        let cache = Arc::new(ProgramCache::with_capacity(1));
        let barrier = Arc::new(Barrier::new(2));
        let emits = Arc::new([AtomicUsize::new(0), AtomicUsize::new(0)]);
        let keys = [
            ProgramKey::level1(Routine::Ddot, 8, 1.5, AeLevel::Ae4),
            ProgramKey::level1(Routine::Ddot, 12, 1.5, AeLevel::Ae4),
        ];
        let progs: Vec<_> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..2)
                .map(|i| {
                    let (cache, barrier, emits) =
                        (Arc::clone(&cache), Arc::clone(&barrier), Arc::clone(&emits));
                    s.spawn(move || {
                        cache.get_or_emit(keys[i], || {
                            // Both slots are inserted (and unfilled) here.
                            barrier.wait();
                            emits[i].fetch_add(1, Ordering::Relaxed);
                            let n = 8 + 4 * i;
                            codegen::gen_ddot(n, AeLevel::Ae4, &VecLayout::level1(n))
                        })
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("racing emitter")).collect()
        });
        assert_eq!(emits[0].load(Ordering::Relaxed), 1);
        assert_eq!(emits[1].load(Ordering::Relaxed), 1);
        assert_eq!(cache.stats().evictions, 0, "unfilled slots must be exempt");
        assert_eq!(cache.len(), 2, "cap transiently exceeded rather than orphaning emissions");
        // Re-requests ride the still-resident kernels — no re-emission.
        for (i, key) in keys.iter().enumerate() {
            let again = cache.get_or_emit(*key, || panic!("must not re-emit"));
            assert!(Arc::ptr_eq(&progs[i], &again), "in-flight kernel was orphaned");
        }
        // The next insertion finds filled victims and re-enforces the cap.
        let _ = cache.gemm_rect(4, 4, 4, AeLevel::Ae4);
        assert_eq!(cache.len(), 1, "cap must be re-enforced once victims are resident");
        assert_eq!(cache.stats().evictions, 2);
    }
}
