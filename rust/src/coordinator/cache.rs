//! Program/layout cache of the serving engine.
//!
//! The paper's request path never recompiles kernels: instruction streams
//! are fixed per (routine, shape, enhancement level) and only operands move
//! (the persistent-kernel approach of KBLAS-style GPU servers, realized
//! here for the PE). This cache makes the coordinator behave the same way:
//! `gen_gemm_rect`/`gen_gemv`/Level-1 emission runs once per key and the
//! resulting kernel is shared by reference ([`Arc`]) across pool workers
//! and across requests.
//!
//! What is cached is a [`ScheduledProgram`] — the emitted stream already
//! **pre-decoded** into the packed two-tier form (validation and AE
//! feature checks done once, at insertion) and carrying its memoized
//! [`PeStats`](crate::pe::PeStats) schedule after the first execution. A
//! cache hit therefore skips emission, validation, decoding *and* (in
//! replay mode) the entire cycle-accurate timing pass: pool workers just
//! replay values over the packed stream.
//!
//! Keys are exact: a program is only reused for the identical padded shape
//! and AE level (and, for DAXPY, the identical α, which the generator bakes
//! into the stream as a `Li` constant). Layouts are pure functions of the
//! shape, so they are recomputed by callers rather than cached.
//!
//! The cache is unbounded by default (fine for the paper's shape set) but
//! takes an optional **LRU capacity cap** for adversarial shape streams:
//! when more than `capacity` programs are resident, the least recently
//! used (program, measurement) pair is dropped and counted in
//! [`CacheStats::evictions`]. In-flight kernels are unaffected — workers
//! hold the program by `Arc`.

use crate::codegen::{self, layout::VecLayout, GemmLayout};
use crate::metrics::{Measurement, Routine};
use crate::pe::{AeLevel, Program, ScheduledProgram};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Cache key: routine + padded shape + enhancement level.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ProgramKey {
    /// Rectangular tile DGEMM C (m×p) ← A (m×k)·B (k×p) + C.
    GemmRect { m: usize, p: usize, k: usize, ae: AeLevel },
    /// Single-PE DGEMV at padded size n.
    Gemv { n: usize, ae: AeLevel },
    /// Level-1 routine at padded size n. `alpha_bits` is the f64 bit
    /// pattern of the baked-in scalar (0 for the reduction routines).
    Level1 { routine: Routine, n: usize, alpha_bits: u64, ae: AeLevel },
}

impl ProgramKey {
    /// Level-1 key with the α normalization rule applied (α only matters
    /// for DAXPY, which bakes it into the stream as a `Li` constant).
    pub fn level1(routine: Routine, n: usize, alpha: f64, ae: AeLevel) -> Self {
        let alpha_bits = if routine == Routine::Daxpy { alpha.to_bits() } else { 0 };
        ProgramKey::Level1 { routine, n, alpha_bits, ae }
    }

    /// The enhancement level baked into the key — the level the cached
    /// kernel is decoded and feature-checked for.
    pub fn ae(&self) -> AeLevel {
        match *self {
            ProgramKey::GemmRect { ae, .. }
            | ProgramKey::Gemv { ae, .. }
            | ProgramKey::Level1 { ae, .. } => ae,
        }
    }
}

/// Cache hit/miss/eviction accounting (monotonic counters).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    /// Programs (with their paired measurements) dropped by the LRU cap.
    pub evictions: u64,
    pub entries: usize,
}

/// A resident pre-decoded program with its LRU clock stamp.
#[derive(Debug)]
struct Entry {
    sched: Arc<ScheduledProgram>,
    /// Monotonic clock value of the most recent use.
    last_used: u64,
}

/// Lock-protected state: programs and their memoized measurements share one
/// lock (and one LRU clock) so eviction can drop both sides of a key
/// atomically.
#[derive(Debug, Default)]
struct Inner {
    programs: HashMap<ProgramKey, Entry>,
    /// Single-PE measurements are pure functions of the key (fixed operand
    /// seeds + cached program + data-independent timing), so they are
    /// memoized alongside the programs.
    measurements: HashMap<ProgramKey, Measurement>,
    clock: u64,
}

/// Thread-safe program cache. Emission happens at most once per resident
/// key; the emitting call holds the map lock so concurrent requests for the
/// same key block rather than duplicating multi-million-instruction
/// emission work. The decode/validate pass runs under the same lock, once,
/// so a resident kernel is always ready to replay.
#[derive(Debug, Default)]
pub struct ProgramCache {
    inner: Mutex<Inner>,
    /// LRU capacity in resident programs (`None` = unbounded).
    capacity: Option<usize>,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
}

impl ProgramCache {
    /// Unbounded cache (the default — every emitted kernel stays resident).
    pub fn new() -> Self {
        Self::default()
    }

    /// Cache holding at most `capacity` programs, evicting the least
    /// recently used kernel (and its memoized measurement) beyond that.
    pub fn with_capacity(capacity: usize) -> Self {
        assert!(capacity >= 1, "program cache capacity must be at least 1");
        Self { capacity: Some(capacity), ..Self::default() }
    }

    /// The LRU capacity (`None` = unbounded).
    pub fn capacity(&self) -> Option<usize> {
        self.capacity
    }

    /// Fetch the pre-decoded program for `key`, emitting it with `emit`
    /// (and decoding it for the key's AE level) on first use. Repeated
    /// calls with the same resident key return the *same* allocation
    /// (`Arc::ptr_eq` holds) — the determinism tests pin this — which is
    /// what lets the one-time timing schedule memoized inside the
    /// [`ScheduledProgram`] be shared by every later request.
    pub fn get_or_emit(
        &self,
        key: ProgramKey,
        emit: impl FnOnce() -> Program,
    ) -> Arc<ScheduledProgram> {
        let mut inner = self.inner.lock().expect("program cache poisoned");
        inner.clock += 1;
        let clock = inner.clock;
        if let Some(e) = inner.programs.get_mut(&key) {
            e.last_used = clock;
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Arc::clone(&e.sched);
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let prog = emit();
        let sched = Arc::new(
            ScheduledProgram::compile(&prog, key.ae())
                .unwrap_or_else(|e| panic!("emitted kernel for {key:?} is invalid: {e}")),
        );
        inner.programs.insert(key, Entry { sched: Arc::clone(&sched), last_used: clock });
        self.evict_over_capacity(&mut inner, key);
        sched
    }

    /// Drop least-recently-used keys until the cap is respected, never
    /// evicting `keep` (the key just inserted/refreshed).
    fn evict_over_capacity(&self, inner: &mut Inner, keep: ProgramKey) {
        let Some(cap) = self.capacity else { return };
        while inner.programs.len() > cap {
            let victim = inner
                .programs
                .iter()
                .filter(|(k, _)| **k != keep)
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| *k)
                .expect("capacity >= 1 leaves a victim besides `keep`");
            inner.programs.remove(&victim);
            inner.measurements.remove(&victim);
            self.evictions.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Cached rectangular DGEMM tile kernel (dims already padded to 4).
    pub fn gemm_rect(&self, m: usize, p: usize, k: usize, ae: AeLevel) -> Arc<ScheduledProgram> {
        self.get_or_emit(ProgramKey::GemmRect { m, p, k, ae }, || {
            let layout = GemmLayout::rect(m, p, k);
            codegen::gen_gemm_rect(m, p, k, ae, &layout)
        })
    }

    /// Cached DGEMV kernel (n already padded to 4).
    pub fn gemv(&self, n: usize, ae: AeLevel) -> Arc<ScheduledProgram> {
        self.get_or_emit(ProgramKey::Gemv { n, ae }, || {
            let l = VecLayout::gemv(n);
            codegen::gen_gemv(n, ae, &l)
        })
    }

    /// Cached Level-1 kernel (n already padded to 4). `alpha` is only
    /// meaningful for [`Routine::Daxpy`]; it is normalized out of the key
    /// for the reduction routines.
    pub fn level1(
        &self,
        routine: Routine,
        n: usize,
        alpha: f64,
        ae: AeLevel,
    ) -> Arc<ScheduledProgram> {
        self.get_or_emit(ProgramKey::level1(routine, n, alpha, ae), || {
            let l = VecLayout::level1(n);
            match routine {
                Routine::Ddot => codegen::gen_ddot(n, ae, &l),
                Routine::Dnrm2 => codegen::gen_dnrm2(n, ae, &l),
                Routine::Daxpy => codegen::gen_daxpy(n, alpha, ae, &l),
                _ => panic!("not a level-1 routine: {routine:?}"),
            }
        })
    }

    /// The memoized [`Measurement`] for `key`, if present. A memo return is
    /// a warm-cache hit (counted in [`CacheStats::hits`]) even though no
    /// program is fetched — repeated Level-1/2 requests skip the simulation
    /// entirely — and refreshes the key's LRU slot.
    pub fn cached_measurement(&self, key: &ProgramKey) -> Option<Measurement> {
        let mut inner = self.inner.lock().expect("program cache poisoned");
        inner.clock += 1;
        let clock = inner.clock;
        let meas = inner.measurements.get(key).cloned();
        if meas.is_some() {
            if let Some(e) = inner.programs.get_mut(key) {
                e.last_used = clock;
            }
            self.hits.fetch_add(1, Ordering::Relaxed);
        }
        meas
    }

    /// Record a warm hit that was served outside the cache — a request that
    /// attached to an identical in-flight measurement instead of submitting
    /// a duplicate kernel — so `hits` stays comparable with the sequential
    /// path, where the same request would memo-hit.
    pub(crate) fn record_hit(&self) {
        self.hits.fetch_add(1, Ordering::Relaxed);
    }

    /// Store a measurement computed on a pool worker. Dropped silently if
    /// the paired program was evicted while the kernel was in flight
    /// (program and measurement must stay paired so eviction removes both).
    pub(crate) fn store_measurement(&self, key: ProgramKey, meas: Measurement) {
        let mut inner = self.inner.lock().expect("program cache poisoned");
        if inner.programs.contains_key(&key) {
            inner.measurements.entry(key).or_insert(meas);
        }
    }

    /// Hit/miss/eviction/entry counters since construction.
    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            entries: self.inner.lock().expect("program cache poisoned").programs.len(),
        }
    }

    /// Number of cached programs.
    pub fn len(&self) -> usize {
        self.stats().entries
    }

    /// True if nothing has been cached yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::measure_level1_prog;
    use crate::pe::DecodedProgram;

    #[test]
    fn same_key_is_pointer_equal() {
        let cache = ProgramCache::new();
        let p1 = cache.gemm_rect(8, 8, 8, AeLevel::Ae5);
        let p2 = cache.gemm_rect(8, 8, 8, AeLevel::Ae5);
        assert!(Arc::ptr_eq(&p1, &p2), "cache must return the shared program");
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.entries, s.evictions), (1, 1, 1, 0));
    }

    #[test]
    fn distinct_keys_are_distinct_programs() {
        let cache = ProgramCache::new();
        let a = cache.gemm_rect(8, 8, 8, AeLevel::Ae5);
        let b = cache.gemm_rect(8, 8, 8, AeLevel::Ae4);
        let c = cache.gemm_rect(8, 8, 16, AeLevel::Ae5);
        assert!(!Arc::ptr_eq(&a, &b));
        assert!(!Arc::ptr_eq(&a, &c));
        assert_eq!(cache.len(), 3);
    }

    #[test]
    fn cached_program_equals_direct_emission() {
        let cache = ProgramCache::new();
        let cached = cache.gemv(12, AeLevel::Ae3);
        let l = VecLayout::gemv(12);
        let direct = codegen::gen_gemv(12, AeLevel::Ae3, &l);
        let decoded_direct = DecodedProgram::decode(&direct, AeLevel::Ae3).unwrap();
        assert_eq!(cached.decoded(), &decoded_direct);
        assert_eq!(cached.ae(), AeLevel::Ae3);
    }

    #[test]
    fn daxpy_alpha_is_part_of_the_key() {
        let cache = ProgramCache::new();
        let a = cache.level1(Routine::Daxpy, 16, 1.5, AeLevel::Ae5);
        let b = cache.level1(Routine::Daxpy, 16, 2.5, AeLevel::Ae5);
        let c = cache.level1(Routine::Daxpy, 16, 1.5, AeLevel::Ae5);
        assert!(!Arc::ptr_eq(&a, &b), "different alpha must not share a program");
        assert!(Arc::ptr_eq(&a, &c));
        // Reduction routines ignore alpha entirely.
        let d = cache.level1(Routine::Ddot, 16, 1.5, AeLevel::Ae5);
        let e = cache.level1(Routine::Ddot, 16, 9.0, AeLevel::Ae5);
        assert!(Arc::ptr_eq(&d, &e));
    }

    #[test]
    fn unbounded_cache_never_evicts() {
        let cache = ProgramCache::new();
        assert_eq!(cache.capacity(), None);
        for n in 1..=10usize {
            let _ = cache.gemm_rect(4 * n, 4 * n, 4 * n, AeLevel::Ae5);
        }
        let s = cache.stats();
        assert_eq!((s.entries, s.evictions), (10, 0));
    }

    #[test]
    fn lru_evicts_least_recently_used_first() {
        let cache = ProgramCache::with_capacity(2);
        assert_eq!(cache.capacity(), Some(2));
        let a = cache.gemm_rect(4, 4, 4, AeLevel::Ae5); // A
        let _ = cache.gemm_rect(8, 8, 8, AeLevel::Ae5); // B
        let a2 = cache.gemm_rect(4, 4, 4, AeLevel::Ae5); // touch A → B is LRU
        assert!(Arc::ptr_eq(&a, &a2));
        let _ = cache.gemm_rect(12, 12, 12, AeLevel::Ae5); // C evicts B
        let s = cache.stats();
        assert_eq!((s.entries, s.evictions), (2, 1));
        // A stayed resident (pointer-equal); B was evicted (fresh miss).
        let a3 = cache.gemm_rect(4, 4, 4, AeLevel::Ae5);
        assert!(Arc::ptr_eq(&a, &a3), "recently used key must survive eviction");
        let misses_before = cache.stats().misses;
        let _ = cache.gemm_rect(8, 8, 8, AeLevel::Ae5);
        assert_eq!(cache.stats().misses, misses_before + 1, "evicted key must re-emit");
    }

    #[test]
    fn eviction_drops_the_paired_measurement() {
        let cache = ProgramCache::with_capacity(1);
        let key = ProgramKey::level1(Routine::Ddot, 8, 1.5, AeLevel::Ae4);
        let _ = cache.level1(Routine::Ddot, 8, 1.5, AeLevel::Ae4);
        let prog = codegen::gen_ddot(8, AeLevel::Ae4, &VecLayout::level1(8));
        let meas = measure_level1_prog(Routine::Ddot, 8, 1.5, AeLevel::Ae4, &prog);
        cache.store_measurement(key, meas);
        assert!(cache.cached_measurement(&key).is_some());
        let _ = cache.gemm_rect(4, 4, 4, AeLevel::Ae4); // evicts the DDOT pair
        assert_eq!(cache.stats().evictions, 1);
        assert!(cache.cached_measurement(&key).is_none(), "measurement must go with program");
    }

    #[test]
    fn store_measurement_requires_resident_program() {
        // A measurement landing after its program was evicted is dropped:
        // keys stay paired, so the LRU cap really bounds residency.
        let cache = ProgramCache::with_capacity(1);
        let key = ProgramKey::level1(Routine::Ddot, 8, 1.5, AeLevel::Ae4);
        let _ = cache.level1(Routine::Ddot, 8, 1.5, AeLevel::Ae4);
        let prog = codegen::gen_ddot(8, AeLevel::Ae4, &VecLayout::level1(8));
        let meas = measure_level1_prog(Routine::Ddot, 8, 1.5, AeLevel::Ae4, &prog);
        let _ = cache.gemm_rect(4, 4, 4, AeLevel::Ae4); // evicts the DDOT key
        cache.store_measurement(key, meas);
        assert!(cache.cached_measurement(&key).is_none());
    }
}
