//! Program/layout cache of the serving engine.
//!
//! The paper's request path never recompiles kernels: instruction streams
//! are fixed per (routine, shape, enhancement level) and only operands move
//! (the persistent-kernel approach of KBLAS-style GPU servers, realized
//! here for the PE). This cache makes the coordinator behave the same way:
//! `gen_gemm_rect`/`gen_gemv`/Level-1 emission runs once per key and the
//! resulting [`Program`] is shared by reference ([`Arc`]) across tile
//! workers and across requests.
//!
//! Keys are exact: a program is only reused for the identical padded shape
//! and AE level (and, for DAXPY, the identical α, which the generator bakes
//! into the stream as a `Li` constant). Layouts are pure functions of the
//! shape, so they are recomputed by callers rather than cached.

use crate::codegen::{self, layout::VecLayout, GemmLayout};
use crate::metrics::{Measurement, Routine};
use crate::pe::{AeLevel, Program};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Cache key: routine + padded shape + enhancement level.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ProgramKey {
    /// Rectangular tile DGEMM C (m×p) ← A (m×k)·B (k×p) + C.
    GemmRect { m: usize, p: usize, k: usize, ae: AeLevel },
    /// Single-PE DGEMV at padded size n.
    Gemv { n: usize, ae: AeLevel },
    /// Level-1 routine at padded size n. `alpha_bits` is the f64 bit
    /// pattern of the baked-in scalar (0 for the reduction routines).
    Level1 { routine: Routine, n: usize, alpha_bits: u64, ae: AeLevel },
}

impl ProgramKey {
    /// Level-1 key with the α normalization rule applied (α only matters
    /// for DAXPY, which bakes it into the stream as a `Li` constant).
    pub fn level1(routine: Routine, n: usize, alpha: f64, ae: AeLevel) -> Self {
        let alpha_bits = if routine == Routine::Daxpy { alpha.to_bits() } else { 0 };
        ProgramKey::Level1 { routine, n, alpha_bits, ae }
    }
}

/// Cache hit/miss accounting (monotonic counters).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub entries: usize,
}

/// Thread-safe program cache. Emission happens at most once per key; the
/// emitting call holds the map lock so concurrent requests for the same key
/// block rather than duplicating multi-million-instruction emission work.
#[derive(Debug, Default)]
pub struct ProgramCache {
    map: Mutex<HashMap<ProgramKey, Arc<Program>>>,
    /// Single-PE measurements are pure functions of the key (fixed operand
    /// seeds + cached program + data-independent timing), so they are
    /// memoized alongside the programs.
    measurements: Mutex<HashMap<ProgramKey, Measurement>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl ProgramCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Fetch the program for `key`, emitting it with `emit` on first use.
    /// Repeated calls with the same key return the *same* allocation
    /// (`Arc::ptr_eq` holds) — the determinism tests pin this.
    pub fn get_or_emit(&self, key: ProgramKey, emit: impl FnOnce() -> Program) -> Arc<Program> {
        let mut map = self.map.lock().expect("program cache poisoned");
        if let Some(p) = map.get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Arc::clone(p);
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let p = Arc::new(emit());
        map.insert(key, Arc::clone(&p));
        p
    }

    /// Cached rectangular DGEMM tile kernel (dims already padded to 4).
    pub fn gemm_rect(&self, m: usize, p: usize, k: usize, ae: AeLevel) -> Arc<Program> {
        self.get_or_emit(ProgramKey::GemmRect { m, p, k, ae }, || {
            let layout = GemmLayout::rect(m, p, k);
            codegen::gen_gemm_rect(m, p, k, ae, &layout)
        })
    }

    /// Cached DGEMV kernel (n already padded to 4).
    pub fn gemv(&self, n: usize, ae: AeLevel) -> Arc<Program> {
        self.get_or_emit(ProgramKey::Gemv { n, ae }, || {
            let l = VecLayout::gemv(n);
            codegen::gen_gemv(n, ae, &l)
        })
    }

    /// Cached Level-1 kernel (n already padded to 4). `alpha` is only
    /// meaningful for [`Routine::Daxpy`]; it is normalized out of the key
    /// for the reduction routines.
    pub fn level1(&self, routine: Routine, n: usize, alpha: f64, ae: AeLevel) -> Arc<Program> {
        self.get_or_emit(ProgramKey::level1(routine, n, alpha, ae), || {
            let l = VecLayout::level1(n);
            match routine {
                Routine::Ddot => codegen::gen_ddot(n, ae, &l),
                Routine::Dnrm2 => codegen::gen_dnrm2(n, ae, &l),
                Routine::Daxpy => codegen::gen_daxpy(n, alpha, ae, &l),
                _ => panic!("not a level-1 routine: {routine:?}"),
            }
        })
    }

    /// Fetch the memoized [`Measurement`] for `key`, computing it once via
    /// `compute` — the serving engine's single-PE timing path (running the
    /// same cached kernel on the same seeded operands is bit-identical, so
    /// repeated requests skip the simulation entirely).
    pub fn measurement_or(
        &self,
        key: ProgramKey,
        compute: impl FnOnce() -> Measurement,
    ) -> Measurement {
        if let Some(m) = self.measurements.lock().expect("measurement cache poisoned").get(&key) {
            // A memo return is a warm-cache hit even though get_or_emit
            // never runs — keep the counters honest for repeated L1/L2.
            self.hits.fetch_add(1, Ordering::Relaxed);
            return m.clone();
        }
        let m = compute();
        self.measurements
            .lock()
            .expect("measurement cache poisoned")
            .entry(key)
            .or_insert_with(|| m.clone());
        m
    }

    /// Hit/miss/entry counters since construction.
    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            entries: self.map.lock().expect("program cache poisoned").len(),
        }
    }

    /// Number of cached programs.
    pub fn len(&self) -> usize {
        self.stats().entries
    }

    /// True if nothing has been cached yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_key_is_pointer_equal() {
        let cache = ProgramCache::new();
        let p1 = cache.gemm_rect(8, 8, 8, AeLevel::Ae5);
        let p2 = cache.gemm_rect(8, 8, 8, AeLevel::Ae5);
        assert!(Arc::ptr_eq(&p1, &p2), "cache must return the shared program");
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 1, 1));
    }

    #[test]
    fn distinct_keys_are_distinct_programs() {
        let cache = ProgramCache::new();
        let a = cache.gemm_rect(8, 8, 8, AeLevel::Ae5);
        let b = cache.gemm_rect(8, 8, 8, AeLevel::Ae4);
        let c = cache.gemm_rect(8, 8, 16, AeLevel::Ae5);
        assert!(!Arc::ptr_eq(&a, &b));
        assert!(!Arc::ptr_eq(&a, &c));
        assert_eq!(cache.len(), 3);
    }

    #[test]
    fn cached_program_equals_direct_emission() {
        let cache = ProgramCache::new();
        let cached = cache.gemv(12, AeLevel::Ae3);
        let l = VecLayout::gemv(12);
        let direct = codegen::gen_gemv(12, AeLevel::Ae3, &l);
        assert_eq!(cached.instrs, direct.instrs);
    }

    #[test]
    fn daxpy_alpha_is_part_of_the_key() {
        let cache = ProgramCache::new();
        let a = cache.level1(Routine::Daxpy, 16, 1.5, AeLevel::Ae5);
        let b = cache.level1(Routine::Daxpy, 16, 2.5, AeLevel::Ae5);
        let c = cache.level1(Routine::Daxpy, 16, 1.5, AeLevel::Ae5);
        assert!(!Arc::ptr_eq(&a, &b), "different alpha must not share a program");
        assert!(Arc::ptr_eq(&a, &c));
        // Reduction routines ignore alpha entirely.
        let d = cache.level1(Routine::Ddot, 16, 1.5, AeLevel::Ae5);
        let e = cache.level1(Routine::Ddot, 16, 9.0, AeLevel::Ae5);
        assert!(Arc::ptr_eq(&d, &e));
    }
}
