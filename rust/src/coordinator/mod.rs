//! L3 coordinator — the REDEFINE leader, structured as a serving engine.
//!
//! Owns the request path of the system: it partitions BLAS calls into
//! 4×4-register-blocked tile jobs, dispatches them across a **persistent
//! pool** of PE workers, schedules the operand streams over the NoC model,
//! and merges results. Every BLAS level runs on the same pool: DGEMM as
//! `b×b` tile kernels, DGEMV and the Level-1 routines as single-PE
//! measurement kernels — the paper's point that one co-designed PE serves
//! all three levels through one fixed-program datapath. Instruction
//! streams are never re-emitted per request: a [`ProgramCache`] keyed by
//! (routine, shape, AE level) emits each kernel once — **pre-decoded and
//! validated** into a [`ScheduledProgram`](crate::pe::ScheduledProgram) —
//! and shares it (`Arc`) across pool workers and requests, with an
//! optional LRU cap for adversarial shape streams. Execution is two-tier:
//! the cycle-accurate timing pass runs once per cached kernel and is
//! memoized; every later request replays values only (the default
//! [`ExecMode::Replay`]; [`ExecMode::Combined`] forces the full
//! interpreter per request, as a baseline and cross-check).
//!
//! Since PR 4 the pool and the program cache are **shared state behind the
//! coordinator**, not owned by it: a standalone [`Coordinator::new`]
//! builds a private single-tenant engine (same behavior as before, pinned
//! by tests), while [`crate::engine::Engine::tenant`] attaches many
//! coordinators to one process-wide pool + cache so tenants share warm
//! kernels under a weighted fair scheduler. Non-4-aligned DGEMMs can
//! optionally serve on cached single-PE DOT2/3 **residual kernels**
//! instead of padding ([`CoordinatorConfig::residual`]).
//!
//! Beyond flat BLAS calls, the pipeline serves **LAPACK factorizations as
//! dependency DAGs**: `Request::Dgeqrf/Dgetrf/Dpotrf` are expanded at
//! admission ([`crate::lapack::expand`]) into graphs of cached kernel
//! nodes ([`crate::dag::ExecGraph`]), dispatched dependency-aware — a
//! node's pool job is submitted only once its predecessors complete, and
//! each completion releases its successors (see `request::Pipeline`). The
//! node kernels flow through the same program cache, replay tiers and
//! fabric routing as flat requests; the factorization response reports
//! the DAG makespan as its cycle cost plus the host-computed factors.
//!
//! Co-simulation split:
//! * **timing/energy** — always from the PE + NoC simulators;
//! * **values** — from the AOT-compiled XLA artifacts via [`crate::runtime`]
//!   when they exist for the request shape (the production path: Python
//!   never runs here, only HLO text compiled at build time), with the PE
//!   simulator's own functional execution as the fallback and as a
//!   cross-check (`verify`). Without the `pjrt` feature the runtime is a
//!   stub and every value comes from [`ValueSource::PeSim`].

pub mod cache;
pub mod open_loop;
pub(crate) mod pool;
pub mod request;

pub use cache::{CacheStats, CacheTally, ProgramCache, ProgramKey};
pub use open_loop::{OpenLoopOptions, OpenLoopOutcome, OpenLoopReport, OpenLoopStats, ShedReason};
pub use pool::PoolJobCounts;
pub use request::{BatchStats, FactorOutcome, Request, Response};

use crate::codegen::GemmLayout;
use crate::energy::PowerModel;
use crate::engine::{Engine, EngineConfig, EngineShared, SchedPolicy};
use crate::metrics::{Measurement, Routine};
use crate::noc::{Coord, FabricConfig, FabricStats, LinkTraffic, RouterConfig, Topology};
use crate::obs::{Event, EventKind, RollingLatency, TenantSnapshot, TraceSink};
use crate::pe::{AeLevel, ExecMode, PeConfig, PeStats, ScheduledProgram};
use crate::runtime::Runtime;
use crate::util::{round_up, Mat};
use pool::{Done, Job, PoolClient};
use std::sync::Arc;

/// Job id used by the blocking single-request paths (never collides with
/// `serve_batch` ids, which are dense from 0).
const SOLO_JOB_ID: u64 = u64::MAX;

/// Coordinator configuration.
///
/// # Examples
///
/// Configs are plain data — nothing is spawned until
/// [`Coordinator::new`] / [`crate::engine::Engine::tenant`]:
///
/// ```
/// use redefine_blas::coordinator::CoordinatorConfig;
///
/// let cfg = CoordinatorConfig {
///     admission_window: Some(4),
///     admission_bytes: Some(256 * 1024),
///     ..CoordinatorConfig::default()
/// };
/// assert!(cfg.verify, "the value cross-check defaults on");
/// assert_eq!(cfg.b, 2, "2x2 tile array by default");
/// assert!(cfg.queue_depth.is_none(), "open-loop shedding defaults off");
/// ```
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// PE enhancement level for every kernel.
    pub ae: AeLevel,
    /// Tile-array order b (b×b compute tiles + memory column). Controls
    /// DGEMM tiling; a *standalone* coordinator also sizes its private
    /// worker pool b², while an engine tenant shares the engine's pool.
    pub b: usize,
    /// Artifact directory for the XLA value path.
    pub artifact_dir: String,
    /// Cross-check XLA values against the PE simulator's functional output.
    pub verify: bool,
    /// Admission window of [`Coordinator::serve_batch`]: at most this many
    /// requests are staged (operands packed, kernels in flight) at once, so
    /// huge batches never hold every packed GM image in memory. `None`
    /// (default) stages the whole batch up front.
    pub admission_window: Option<usize>,
    /// Byte budget of [`Coordinator::serve_batch`]'s admission window:
    /// staged requests may not pin more than this many bytes of packed GM
    /// images (8 bytes per GM word, priced by
    /// [`CoordinatorConfig::staged_bytes`]) — except that one oversized
    /// request is always admitted alone so it cannot wedge the batch.
    /// Composes with `admission_window` (both bounds apply); `None`
    /// (default) bounds by request count only. Under the engine every
    /// tenant enforces its own budget.
    pub admission_bytes: Option<u64>,
    /// LRU capacity of the program cache, in resident kernels. `None`
    /// (default) keeps every emitted kernel — the seed behavior. Only
    /// meaningful for a standalone coordinator; engine tenants share the
    /// engine's cache (sized by
    /// [`crate::engine::EngineConfig::cache_capacity`]).
    pub cache_capacity: Option<usize>,
    /// Per-tenant residency quota of the program cache (`None` =
    /// unscoped). Only meaningful for a standalone coordinator; engine
    /// tenants are bounded by
    /// [`crate::engine::EngineConfig::cache_quota`].
    pub cache_quota: Option<usize>,
    /// Fairness currency of the worker pool's scheduler — cycle-cost
    /// deficit round-robin ([`SchedPolicy::Cycles`], the default) or the
    /// slot-WRR baseline. Only meaningful for a standalone coordinator
    /// (a single lane is FIFO either way); engine tenants schedule under
    /// [`crate::engine::EngineConfig::sched`].
    pub sched: SchedPolicy,
    /// How pool workers execute cached kernels: [`ExecMode::Replay`]
    /// (default) runs the cycle-accurate timing pass once per kernel and
    /// replays values only afterwards; [`ExecMode::Combined`] re-runs the
    /// full combined interpreter on every request (baseline/cross-check —
    /// responses are identical either way, pinned by tests).
    pub exec: ExecMode,
    /// Serve non-4-aligned DGEMMs on the cached single-PE DOT2/3 residual
    /// kernel ([`crate::codegen::gen_gemm_any`]) instead of padding to the
    /// tiled 4-aligned kernel. Applies at AE2+ (the residual path needs
    /// the RDP) and to shapes whose working set fits the LM; everything
    /// else pads as before. The residual kernel is not tiled: eligible
    /// requests run on one PE regardless of `b`.
    pub residual: bool,
    /// Coalesce same-kernel DGEMM tile jobs staged by
    /// [`Coordinator::serve_batch`] into replay-batched pool jobs of up to
    /// this many tiles: a worker walks the decoded program *once* per
    /// group, executing each op across every member's operand context (the
    /// tier-2b fast path, [`crate::pe::replay_batch`]). `None` (default)
    /// submits every tile as its own job, the pre-batching behavior.
    /// Values, cycles and energy are identical either way (pinned by
    /// tests); only host-side serving throughput changes.
    pub replay_batch: Option<usize>,
    /// Open-loop backpressure, by depth: an arrival finding this many
    /// requests already pending (arrived, not yet admitted) is shed with an
    /// explicit `Rejected` outcome instead of queueing without bound
    /// ([`Coordinator::serve_open_loop`]). Must be ≥ 1 to ever serve;
    /// `None` (default) never depth-sheds. Ignored by the closed-loop
    /// `serve_batch`, which offers the next request only after admission.
    pub queue_depth: Option<usize>,
    /// Open-loop backpressure, by bytes: an arrival that would push the
    /// pending queue's packed-GM footprint (priced by
    /// [`CoordinatorConfig::staged_bytes`], same currency as
    /// [`CoordinatorConfig::admission_bytes`]) past this budget is shed —
    /// except that an arrival finding the pending queue empty is always
    /// accepted, so one oversized request degrades to queueing rather than
    /// permanent rejection. `None` (default) never byte-sheds. Ignored by
    /// the closed-loop `serve_batch`.
    pub shed_after_bytes: Option<u64>,
    /// Serve on a modeled b×b REDEFINE fabric (`Some`): every finalized
    /// job is placed on a compute tile and its operand/result movement is
    /// priced on the mesh with real link contention, so reported cycles
    /// become communication + compute (absolute fabric completion time)
    /// instead of PE cycles alone. `None` (default, `--fabric 0`) keeps
    /// the location-free pool — bit- and stats-identical to the
    /// pre-fabric serving path. Only meaningful for a standalone
    /// coordinator; engine tenants share the engine's fabric
    /// ([`crate::engine::EngineConfig::fabric`]).
    pub fabric: Option<FabricConfig>,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            ae: AeLevel::Ae5,
            b: 2,
            artifact_dir: "artifacts".into(),
            verify: true,
            admission_window: None,
            admission_bytes: None,
            cache_capacity: None,
            cache_quota: None,
            sched: SchedPolicy::Cycles,
            exec: ExecMode::Replay,
            residual: false,
            replay_batch: None,
            queue_depth: None,
            shed_after_bytes: None,
            fabric: None,
        }
    }
}

impl CoordinatorConfig {
    /// True when an `n`-sized DGEMM serves on the cached DOT2/3 residual
    /// kernel instead of the padded tile path (see
    /// [`CoordinatorConfig::residual`]). The LM bound mirrors the residual
    /// generator's working set: 8n + 16 LM words.
    pub fn residual_eligible(&self, n: usize) -> bool {
        self.residual
            && n % 4 != 0
            && n >= 2
            && self.ae.has_dot()
            && 8 * n + 16 <= crate::pe::LM_WORDS
    }
}

/// Where the returned values came from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ValueSource {
    /// AOT-compiled XLA executable (PJRT).
    Xla,
    /// PE simulator functional execution.
    PeSim,
}

/// Result of a coordinated DGEMM.
#[derive(Debug)]
pub struct DgemmResult {
    pub c: Mat,
    pub source: ValueSource,
    /// Parallel makespan over the tile array, in PE cycles.
    pub makespan: u64,
    /// Aggregate PE statistics (summed over tiles).
    pub pe_stats: PeStats,
    /// Per-tile (coord, ready, compute, finish).
    pub tiles: Vec<(Coord, u64, u64, u64)>,
    /// Energy estimate over all tiles, joules.
    pub energy_j: f64,
}

impl DgemmResult {
    /// Achieved Gflops at the PE clock (standard 2n³ convention).
    pub fn gflops(&self, n: usize, cfg: &PeConfig) -> f64 {
        2.0 * (n as f64).powi(3) / (self.makespan as f64 * cfg.cycle_ns() * 1e-9) / 1e9
    }
}

/// Bookkeeping for a DGEMM whose tile kernels are in flight on the pool.
/// Created by [`Coordinator::submit_dgemm`], consumed by
/// [`Coordinator::finish_dgemm`] once every tile result has been collected.
/// The residual path is the `bb == 1, m == n` degenerate case (one
/// untiled kernel on one PE).
pub(crate) struct PendingDgemm {
    job_id: u64,
    n: usize,
    m: usize,
    bb: usize,
    ready: Vec<u64>,
    links: LinkTraffic,
    topo: Topology,
    rcfg: RouterConfig,
    cpad: Mat,
}

impl PendingDgemm {
    pub(crate) fn job_id(&self) -> u64 {
        self.job_id
    }

    pub(crate) fn tile_count(&self) -> usize {
        self.bb * self.bb
    }
}

/// One DGEMM's tile kernels, prepared but not yet enqueued: the shared
/// cached program, the tile layout, and each tile's `(job_id, tile_idx,
/// packed GM image)`. [`Coordinator::submit_dgemm`] enqueues them directly
/// as independent jobs; the batched serving path may first coalesce
/// same-program tiles across staged requests into replay-batched jobs
/// ([`CoordinatorConfig::replay_batch`]).
pub(crate) struct StagedTiles {
    pub(crate) sched: Arc<ScheduledProgram>,
    pub(crate) layout: GemmLayout,
    pub(crate) tiles: Vec<(u64, usize, Vec<f64>)>,
}

/// Everything needed to run a Level-1/2 measurement kernel: the cache key
/// plus the padded-problem parameters the generators and workers need.
#[derive(Debug, Clone, Copy)]
pub(crate) struct MeasSpec {
    pub key: ProgramKey,
    pub routine: Routine,
    /// Padded problem size (multiple of 4).
    pub np: usize,
    /// DAXPY's baked-in scalar (generator convention 1.5 for reductions).
    pub alpha: f64,
}

impl MeasSpec {
    /// Single-PE DGEMV at raw size `n`.
    pub fn gemv(n: usize, ae: AeLevel) -> Self {
        let np = round_up(n, 4);
        Self { key: ProgramKey::Gemv { n: np, ae }, routine: Routine::Dgemv, np, alpha: 1.5 }
    }

    /// Level-1 routine at raw size `n`.
    pub fn level1(routine: Routine, n: usize, alpha: f64, ae: AeLevel) -> Self {
        let np = round_up(n.max(4), 4);
        Self { key: ProgramKey::level1(routine, np, alpha, ae), routine, np, alpha }
    }
}

/// The coordinator: a tenant handle over shared serving state (program
/// cache + worker pool) plus the optional XLA value path. Standalone
/// ([`Coordinator::new`]) it owns a private single-tenant engine; under
/// [`crate::engine::Engine`] many coordinators share one.
pub struct Coordinator {
    pub cfg: CoordinatorConfig,
    runtime: Option<Runtime>,
    /// Shared engine state (pool + program cache), reference-counted so it
    /// outlives the engine value for as long as any tenant is alive.
    shared: Arc<EngineShared>,
    /// This tenant's lane into the shared pool (private reply channel,
    /// per-tenant execution counters, fair-scheduler weight).
    pool: PoolClient,
    /// This tenant's slice of the shared cache counters.
    tally: CacheTally,
    /// Telemetry of the last [`Coordinator::serve_batch`] call.
    last_batch: Option<BatchStats>,
    /// Aggregate stats of the last [`Coordinator::serve_open_loop`] run.
    pub(crate) last_open_loop: Option<OpenLoopStats>,
    /// Rolling windowed latency histograms fed by open-loop serving (the
    /// long-lived-daemon view; see [`crate::obs::WindowedHistogram`]).
    pub(crate) rolling: RollingLatency,
    /// Trace sink. `None` (the default) means no [`Event`] is ever
    /// constructed — the untraced path is bit-identical to pre-tracing
    /// serving (pinned by `tests/obs.rs`).
    sink: Option<Arc<dyn TraceSink>>,
    /// This tenant's home fabric row (attach order modulo fabric rows):
    /// routed results consolidate in this row's memory tile, and the
    /// locality placer prefers tiles near it. 0 when no fabric is modeled.
    home_row: usize,
}

impl Coordinator {
    /// Build a standalone coordinator: a private single-tenant engine with
    /// a b×b worker pool and its own program cache — behaviorally
    /// identical to the pre-engine per-coordinator pool (pinned by tests).
    /// The XLA runtime is attached if the artifact directory exists and
    /// PJRT initializes (otherwise values fall back to the PE simulator).
    pub fn new(cfg: CoordinatorConfig) -> Self {
        assert!(cfg.b >= 1, "need at least a 1x1 tile array");
        let engine = Engine::new(EngineConfig {
            workers: cfg.b * cfg.b,
            cache_capacity: cfg.cache_capacity,
            cache_quota: cfg.cache_quota,
            sched: cfg.sched,
            fabric: cfg.fabric.clone(),
        });
        engine.tenant(cfg)
    }

    /// Attach a tenant coordinator to shared engine state (the
    /// [`crate::engine::Engine::tenant`] entry point).
    pub(crate) fn attach(shared: Arc<EngineShared>, cfg: CoordinatorConfig, weight: u64) -> Self {
        assert!(cfg.b >= 1, "need at least a 1x1 tile array");
        let runtime = if std::path::Path::new(&cfg.artifact_dir).is_dir() {
            Runtime::new(&cfg.artifact_dir).ok()
        } else {
            None
        };
        let pool = shared.pool.client(weight, cfg.exec);
        let home_row = match shared.fabric.as_ref() {
            Some(f) => {
                let rows = f.lock().expect("fabric lock").rows();
                shared.fabric_tenants.fetch_add(1, std::sync::atomic::Ordering::Relaxed) % rows
            }
            None => 0,
        };
        Self {
            cfg,
            runtime,
            shared,
            pool,
            tally: CacheTally::default(),
            last_batch: None,
            last_open_loop: None,
            rolling: RollingLatency::daemon_default(),
            sink: None,
            home_row,
        }
    }

    /// Attach a trace sink: every subsequent serving call emits typed
    /// [`Event`]s into it from the dispatcher thread, in deterministic
    /// (simulated) order. Without a sink no event is ever constructed.
    pub fn set_trace_sink(&mut self, sink: Arc<dyn TraceSink>) {
        self.sink = Some(sink);
    }

    /// Whether a trace sink is attached.
    pub(crate) fn traced(&self) -> bool {
        self.sink.is_some()
    }

    /// Emit one trace event. The closure runs only when a sink is
    /// attached, so the untraced path pays a single branch and never
    /// builds the event.
    pub(crate) fn trace(&self, f: impl FnOnce() -> Event) {
        if let Some(sink) = self.sink.as_ref() {
            sink.emit(f());
        }
    }

    /// Fabric telemetry (per-link utilization, makespan, compute/comm
    /// split) of this coordinator's engine, when it models a fabric.
    pub fn fabric_stats(&self) -> Option<FabricStats> {
        self.shared.fabric.as_ref().map(|f| f.lock().expect("fabric lock").stats())
    }

    /// This tenant's home fabric row (0 without a fabric).
    pub fn home_row(&self) -> usize {
        self.home_row
    }

    /// True if the XLA value path is live.
    pub fn has_xla(&self) -> bool {
        self.runtime.is_some()
    }

    /// Artifacts visible to the runtime.
    pub fn artifacts(&self) -> Vec<String> {
        self.runtime
            .as_ref()
            .map(|r| r.available().iter().map(|k| k.file_name()).collect())
            .unwrap_or_default()
    }

    /// The (shared) program cache — shape/AE-keyed kernel store.
    pub fn cache(&self) -> &ProgramCache {
        &self.shared.cache
    }

    /// This tenant's program-cache counters: hits / misses / evictions
    /// attributed to this coordinator's traffic, with `entries` reporting
    /// the shared resident count. For a standalone coordinator this equals
    /// [`Coordinator::shared_cache_stats`]; under an engine, the tenant
    /// tallies partition the shared totals.
    pub fn cache_stats(&self) -> CacheStats {
        self.tally.snapshot(self.shared.cache.len())
    }

    /// Shared program-cache totals across every tenant of this
    /// coordinator's engine.
    pub fn shared_cache_stats(&self) -> CacheStats {
        self.shared.cache.stats()
    }

    /// Number of persistent workers in the (shared) pool serving this
    /// coordinator: b² standalone, the engine's worker count for tenants.
    pub fn pool_size(&self) -> usize {
        self.pool.worker_count()
    }

    /// Jobs executed on the worker pool for this tenant so far, by kind.
    /// Level-1/2 kernels count here too — they run on pool workers, not on
    /// the dispatcher. Under an engine the tenant counts partition
    /// [`Coordinator::shared_pool_job_counts`].
    pub fn pool_job_counts(&self) -> PoolJobCounts {
        self.pool.counts()
    }

    /// Pool-wide execution totals across every tenant of this
    /// coordinator's engine.
    pub fn shared_pool_job_counts(&self) -> PoolJobCounts {
        self.shared.pool.counts()
    }

    /// Telemetry of the last [`Coordinator::serve_batch`] call (admission
    /// peaks, shared measurements), if one ran.
    pub fn last_batch_stats(&self) -> Option<BatchStats> {
        self.last_batch
    }

    pub(crate) fn set_last_batch_stats(&mut self, stats: BatchStats) {
        self.last_batch = Some(stats);
    }

    /// Aggregate stats of the last [`Coordinator::serve_open_loop`] run,
    /// if one ran.
    pub fn last_open_loop_stats(&self) -> Option<OpenLoopStats> {
        self.last_open_loop
    }

    /// Everything this tenant knows about itself, in one value: cache and
    /// pool counters, the last batch / open-loop run's telemetry, the
    /// rolling latency windows, and the engine's fabric view. Every
    /// per-tenant number the CLI prints is derivable from this (the
    /// engine-wide counterpart is [`crate::engine::Engine::snapshot`]).
    pub fn snapshot(&self) -> TenantSnapshot {
        TenantSnapshot {
            home_row: self.home_row,
            pool_size: self.pool_size(),
            cache: self.cache_stats(),
            shared_cache: self.shared_cache_stats(),
            jobs: self.pool_job_counts(),
            batch: self.last_batch,
            open_loop: self.last_open_loop,
            rolling: self.rolling.snapshot(),
            fabric: self.fabric_stats(),
        }
    }

    /// Coordinated DGEMM: C ← A·B + C across the tile array.
    ///
    /// The problem is zero-padded to a multiple of 4b so each tile gets a
    /// 4-aligned block; padding cost is simulated (as it would be burned on
    /// the real fabric). The tile kernels run on the persistent pool with
    /// the cached program for this (shape, AE) key. In residual mode
    /// ([`CoordinatorConfig::residual`]), eligible non-4-aligned shapes
    /// run unpadded on one PE with the cached DOT2/3 kernel instead.
    pub fn dgemm(&mut self, a: &Mat, b: &Mat, c: &Mat) -> DgemmResult {
        let pending = self.submit_dgemm(SOLO_JOB_ID, a, b, c);
        let outs = self.collect_job(&pending);
        self.finish_dgemm(pending, outs, a, b, c)
    }

    /// Stage one DGEMM: schedule its operand streams on the NoC, fetch the
    /// cached tile program, and enqueue all b×b tile jobs on the pool (or
    /// the single residual kernel, when eligible).
    pub(crate) fn submit_dgemm(&self, job_id: u64, a: &Mat, b: &Mat, c: &Mat) -> PendingDgemm {
        let (pending, staged) = self.prepare_dgemm(job_id, a, b, c);
        let StagedTiles { sched, layout, tiles } = staged;
        for (job_id, tile_idx, gm) in tiles {
            self.pool.submit(Job::GemmTile {
                job_id,
                tile_idx,
                sched: Arc::clone(&sched),
                layout,
                gm,
            });
        }
        pending
    }

    /// [`Coordinator::submit_dgemm`] minus the enqueue: runs the NoC
    /// schedule and the cache fetch, packs every tile's GM image, and hands
    /// the jobs back instead of submitting them — the staging half the
    /// batched serving path needs so it can coalesce same-program tiles
    /// across requests before they reach the pool.
    pub(crate) fn prepare_dgemm(
        &self,
        job_id: u64,
        a: &Mat,
        b: &Mat,
        c: &Mat,
    ) -> (PendingDgemm, StagedTiles) {
        let n = a.rows();
        assert!(a.cols() == n && b.rows() == n && b.cols() == n, "square DGEMM only");
        assert!(c.rows() == n && c.cols() == n);
        if self.cfg.residual_eligible(n) {
            return self.prepare_dgemm_residual(job_id, a, b, c);
        }
        let bb = self.cfg.b;
        let ae = self.cfg.ae;
        let np = round_up(n, 4 * bb);
        let (ap, bp, cp) = (a.padded(np, np), b.padded(np, np), c.padded(np, np));
        let m = np / bb;

        // 1) NoC schedule: operand streams from the memory column
        //    (deterministic, sequential — cheap).
        let topo = Topology::new(bb);
        let rcfg = RouterConfig::default();
        let mut links = LinkTraffic::new();
        let mut ready = vec![0u64; bb * bb];
        for bi in 0..bb {
            for bj in 0..bb {
                let coord = Coord::new(bi, bj);
                let mem_a = topo.memory_for_row(bi);
                let mem_b = topo.memory_for_row(bj);
                let (_, ta) = links.transfer(&topo, &rcfg, mem_a, coord, (m * np) as u64, 0);
                let (_, tb) = links.transfer(&topo, &rcfg, mem_b, coord, (np * m) as u64, 0);
                let (_, tc) = links.transfer(&topo, &rcfg, mem_a, coord, (m * m) as u64, 0);
                ready[bi * bb + bj] = ta.max(tb).max(tc);
            }
        }

        // 2) One cached, pre-decoded program shared by every tile of this
        //    request (and by every later request of the same shape). The
        //    first tile to execute anywhere runs the timing pass and
        //    memoizes the schedule; the rest replay values only.
        let sched = self.shared.cache.gemm_rect_for(m, m, np, ae, Some(&self.tally));
        let layout = GemmLayout::rect(m, m, np);
        let mut tiles = Vec::with_capacity(bb * bb);
        for bi in 0..bb {
            for bj in 0..bb {
                let a_blk = ap.block(bi * m, 0, m, np);
                let b_blk = bp.block(0, bj * m, np, m);
                let c_blk = cp.block(bi * m, bj * m, m, m);
                tiles.push((job_id, bi * bb + bj, layout.pack(&a_blk, &b_blk, &c_blk)));
            }
        }

        let pending = PendingDgemm { job_id, n, m, bb, ready, links, topo, rcfg, cpad: cp };
        (pending, StagedTiles { sched, layout, tiles })
    }

    /// Stage one DGEMM on the residual path: no padding, no tiling — the
    /// whole problem runs on one PE with the cached DOT2/3 kernel
    /// ([`crate::codegen::gen_gemm_any`]). The NoC schedule degenerates to
    /// one compute tile's operand streams, so the request flows through
    /// exactly the same collect/finish machinery as the tiled path.
    fn prepare_dgemm_residual(
        &self,
        job_id: u64,
        a: &Mat,
        b: &Mat,
        c: &Mat,
    ) -> (PendingDgemm, StagedTiles) {
        let n = a.rows();
        let ae = self.cfg.ae;
        let topo = Topology::new(1);
        let rcfg = RouterConfig::default();
        let mut links = LinkTraffic::new();
        let coord = Coord::new(0, 0);
        let mem = topo.memory_for_row(0);
        let (_, ta) = links.transfer(&topo, &rcfg, mem, coord, (n * n) as u64, 0);
        let (_, tb) = links.transfer(&topo, &rcfg, mem, coord, (n * n) as u64, 0);
        let (_, tc) = links.transfer(&topo, &rcfg, mem, coord, (n * n) as u64, 0);
        let ready = vec![ta.max(tb).max(tc)];
        let sched = self.shared.cache.gemm_any_for(n, ae, Some(&self.tally));
        let layout = GemmLayout::rect_any(n, n, n);
        let tiles = vec![(job_id, 0, layout.pack(a, b, c))];
        let pending =
            PendingDgemm { job_id, n, m: n, bb: 1, ready, links, topo, rcfg, cpad: c.padded(n, n) };
        (pending, StagedTiles { sched, layout, tiles })
    }

    /// Fetch the cached program for `spec` and enqueue its measurement
    /// kernel on the pool, tagged `job_id`. Called only after the
    /// measurement memo came up empty, so this records the request's one
    /// cache **miss** (the symmetric counterpart of the memo hit) and
    /// fetches the program through the quiet accessors — one counting
    /// event per request, whether the request is warm or pays the
    /// simulation (see the cache module docs).
    pub(crate) fn submit_measure(&self, job_id: u64, spec: &MeasSpec) {
        let ae = self.cfg.ae;
        let cache = &self.shared.cache;
        cache.record_miss(Some(&self.tally));
        let job = match spec.routine {
            Routine::Dgemv => {
                let sched = cache.gemv_quiet(spec.np, ae, Some(&self.tally));
                Job::Gemv { job_id, n: spec.np, sched }
            }
            routine => {
                let sched = cache.level1_quiet(routine, spec.np, spec.alpha, ae, Some(&self.tally));
                Job::Level1 { job_id, routine, n: spec.np, alpha: spec.alpha, sched }
            }
        };
        self.trace(|| Event {
            req: job_id,
            sim: 0,
            host_ns: None,
            kind: EventKind::Dispatched { lane: self.pool.lane(), cost: job.cost_estimate() },
        });
        self.pool.submit(job);
    }

    /// Memoized measurement for `spec`, computed on a pool worker on first
    /// use — the blocking single-request path ([`Coordinator::serve_batch`]
    /// overlaps these kernels across requests instead).
    pub(crate) fn measure_blocking(&self, spec: MeasSpec) -> Measurement {
        if let Some(m) = self.shared.cache.cached_measurement_for(&spec.key, Some(&self.tally)) {
            return m;
        }
        self.submit_measure(SOLO_JOB_ID, &spec);
        let meas = match self.pool.recv() {
            Done::Measured { job_id, meas, .. } => {
                assert_eq!(job_id, SOLO_JOB_ID, "pool delivered a foreign measurement");
                meas
            }
            Done::GemmTile { job_id, .. } => {
                panic!("pool delivered a tile of job {job_id} during a solo measurement")
            }
        };
        self.shared.cache.store_measurement(spec.key, meas.clone());
        meas
    }

    /// Receive the next finished pool job (any request of this tenant).
    pub(crate) fn recv_done(&self) -> Done {
        self.pool.recv()
    }

    /// Non-blocking [`Coordinator::recv_done`]: `None` when nothing has
    /// finished yet (the open-loop poll step).
    pub(crate) fn try_recv_done(&self) -> Option<Done> {
        self.pool.try_recv()
    }

    /// Collect exactly this job's tiles (single-request path).
    pub(crate) fn collect_job(&self, pending: &PendingDgemm) -> Vec<(Mat, PeStats)> {
        let count = pending.tile_count();
        let mut slots: TileSlots = vec![None; count];
        for _ in 0..count {
            match self.recv_done() {
                Done::GemmTile { job_id, tile_idx, out, stats, .. } => {
                    assert_eq!(job_id, pending.job_id(), "pool delivered a foreign tile");
                    slots[tile_idx] = Some((out, stats));
                }
                Done::Measured { job_id, .. } => {
                    panic!("pool delivered a measurement (job {job_id}) during a solo DGEMM")
                }
            }
        }
        seal_slots(slots)
    }

    /// Merge collected tile results: assemble C, schedule write-backs in
    /// tile order (deterministic regardless of worker arrival order), fold
    /// stats/energy, and resolve the value source.
    ///
    /// Under a modeled fabric ([`CoordinatorConfig::fabric`] /
    /// [`crate::engine::EngineConfig::fabric`]) each tile job is instead
    /// placed on a shared fabric tile and its operand/result movement is
    /// priced on the mesh; the reported makespan is then the **absolute
    /// fabric cycle** the last result lands (it grows across requests as
    /// the fabric fills). Finalization runs in strict submission order per
    /// tenant, so routed schedules are deterministic regardless of which
    /// host worker computed which tile.
    pub(crate) fn finish_dgemm(
        &mut self,
        mut pending: PendingDgemm,
        outs: Vec<(Mat, PeStats)>,
        a: &Mat,
        b: &Mat,
        c: &Mat,
    ) -> DgemmResult {
        let (bb, m, n) = (pending.bb, pending.m, pending.n);
        assert_eq!(outs.len(), bb * bb);
        let mut agg = PeStats::default();
        let mut tiles = Vec::with_capacity(bb * bb);
        let mut makespan = 0u64;
        let mut energy = 0.0;
        let power = PowerModel::paper();
        let pe_cfg = PeConfig::paper(self.cfg.ae);
        let mut fabric = self.shared.fabric.as_ref().map(|f| f.lock().expect("fabric lock"));
        for (idx, (out, stats)) in outs.into_iter().enumerate() {
            let (bi, bj) = (idx / bb, idx % bb);
            pending.cpad.set_block(bi * m, bj * m, &out);
            let (coord, r, fin) = match fabric.as_deref_mut() {
                Some(fab) => {
                    // Per-tile operand footprint: the A row-panel (m×m·bb),
                    // B column-panel (m·bb×m) and C block (m×m) — streamed
                    // from the placed tile's row-local memory tile; the C
                    // result streams back to this tenant's home region.
                    let operand_words = (m * m * (2 * bb + 1)) as u64;
                    let job = fab.route_job(
                        self.home_row,
                        operand_words,
                        stats.cycles,
                        (m * m) as u64,
                    );
                    self.trace(|| Event {
                        req: pending.job_id,
                        sim: job.depart,
                        host_ns: None,
                        kind: EventKind::FabricRouted {
                            tile: job.tile,
                            depart: job.depart,
                            ready: job.ready,
                            finish: job.finish,
                            compute: job.compute,
                        },
                    });
                    (job.tile, job.ready, job.finish)
                }
                None => {
                    let coord = Coord::new(bi, bj);
                    let r = pending.ready[idx];
                    let (_, fin) = pending.links.transfer(
                        &pending.topo,
                        &pending.rcfg,
                        coord,
                        pending.topo.memory_for_row(bi),
                        (m * m) as u64,
                        r + stats.cycles,
                    );
                    (coord, r, fin)
                }
            };
            makespan = makespan.max(fin);
            energy += power.energy_joules(self.cfg.ae, &pe_cfg, &stats);
            tiles.push((coord, r, stats.cycles, fin));
            fold_stats(&mut agg, &stats);
        }
        drop(fabric);
        agg.cycles = makespan;
        let sim_c = pending.cpad.block(0, 0, n, n);

        // Values: prefer the XLA artifact for this shape.
        let (c_out, source) = match self.runtime.as_mut() {
            Some(rt) if rt.has("gemm", n) => match rt.gemm(a, b, c) {
                Ok(xc) => {
                    if self.cfg.verify {
                        let err = crate::util::rel_fro_error(xc.as_slice(), sim_c.as_slice());
                        assert!(err < 1e-10, "XLA and PE-sim DGEMM disagree: rel err {err}");
                    }
                    (xc, ValueSource::Xla)
                }
                Err(_) => (sim_c, ValueSource::PeSim),
            },
            _ => (sim_c, ValueSource::PeSim),
        };

        DgemmResult { c: c_out, source, makespan, pe_stats: agg, tiles, energy_j: energy }
    }

    /// Coordinated DGEMV on a single pooled PE (Level-2 is not tiled in the
    /// paper; the PE realization is the §5 result). Timing from the cached
    /// kernel run on a pool worker, values via XLA when available.
    pub fn dgemv(&mut self, a: &Mat, x: &[f64], y: &[f64]) -> (Vec<f64>, Measurement, ValueSource) {
        let meas = self.measure_blocking(MeasSpec::gemv(a.rows(), self.cfg.ae));
        let (v, source) = self.gemv_value(a, x, y);
        (v, meas, source)
    }

    /// Coordinated DDOT (single pooled PE, cached kernel).
    pub fn ddot(&mut self, x: &[f64], y: &[f64]) -> (f64, Measurement, ValueSource) {
        let spec = MeasSpec::level1(Routine::Ddot, x.len(), 1.5, self.cfg.ae);
        let meas = self.measure_blocking(spec);
        let (d, source) = self.ddot_value(x, y);
        (d, meas, source)
    }

    /// Coordinated DAXPY: y ← α·x + y (single pooled PE, cached kernel —
    /// α is baked into the instruction stream, so it is part of the key).
    pub fn daxpy(
        &mut self,
        alpha: f64,
        x: &[f64],
        y: &[f64],
    ) -> (Vec<f64>, Measurement, ValueSource) {
        let spec = MeasSpec::level1(Routine::Daxpy, x.len(), alpha, self.cfg.ae);
        let meas = self.measure_blocking(spec);
        let (v, source) = self.daxpy_value(alpha, x, y);
        (v, meas, source)
    }

    /// Coordinated DNRM2: ‖x‖₂ (single pooled PE, cached kernel).
    pub fn dnrm2(&mut self, x: &[f64]) -> (f64, Measurement, ValueSource) {
        let spec = MeasSpec::level1(Routine::Dnrm2, x.len(), 1.5, self.cfg.ae);
        let meas = self.measure_blocking(spec);
        let (v, source) = self.dnrm2_value(x);
        (v, meas, source)
    }

    /// DGEMV values: XLA artifact when present, host reference as the PE
    /// simulator's functional proxy otherwise.
    pub(crate) fn gemv_value(&mut self, a: &Mat, x: &[f64], y: &[f64]) -> (Vec<f64>, ValueSource) {
        let n = a.rows();
        if let Some(rt) = self.runtime.as_mut() {
            if rt.has("gemv", n) {
                if let Ok(v) = rt.gemv(a, x, y) {
                    return (v, ValueSource::Xla);
                }
            }
        }
        (crate::blas::level2::dgemv_ref(a, x, y), ValueSource::PeSim)
    }

    /// DDOT values (XLA artifact or host reference).
    pub(crate) fn ddot_value(&mut self, x: &[f64], y: &[f64]) -> (f64, ValueSource) {
        if let Some(rt) = self.runtime.as_mut() {
            if rt.has("dot", x.len()) {
                if let Ok(v) = rt.dot(x, y) {
                    return (v, ValueSource::Xla);
                }
            }
        }
        (crate::blas::level1::ddot(x, y), ValueSource::PeSim)
    }

    /// DAXPY values (XLA artifact or host reference).
    pub(crate) fn daxpy_value(
        &mut self,
        alpha: f64,
        x: &[f64],
        y: &[f64],
    ) -> (Vec<f64>, ValueSource) {
        if let Some(rt) = self.runtime.as_mut() {
            if rt.has("axpy", x.len()) {
                if let Ok(v) = rt.axpy(alpha, x, y) {
                    return (v, ValueSource::Xla);
                }
            }
        }
        let mut v = y.to_vec();
        crate::blas::level1::daxpy(alpha, x, &mut v);
        (v, ValueSource::PeSim)
    }

    /// DNRM2 values (XLA artifact or host reference).
    pub(crate) fn dnrm2_value(&mut self, x: &[f64]) -> (f64, ValueSource) {
        if let Some(rt) = self.runtime.as_mut() {
            if rt.has("nrm2", x.len()) {
                if let Ok(v) = rt.nrm2(x) {
                    return (v, ValueSource::Xla);
                }
            }
        }
        (crate::blas::level1::dnrm2(x), ValueSource::PeSim)
    }
}

/// Collected tile results of one job, indexed by tile (None = outstanding).
pub(crate) type TileSlots = Vec<Option<(Mat, PeStats)>>;

/// Turn a fully collected slot vector into merge-ready results; panics if
/// a tile is still outstanding (an accounting bug, not a runtime state).
pub(crate) fn seal_slots(slots: TileSlots) -> Vec<(Mat, PeStats)> {
    slots.into_iter().map(|o| o.expect("missing tile result")).collect()
}

/// Sum PE statistics across tiles (cycles handled separately as makespan).
fn fold_stats(agg: &mut PeStats, s: &PeStats) {
    agg.instructions += s.instructions;
    agg.flops += s.flops;
    agg.dot_ops += s.dot_ops;
    agg.scalar_fu_ops += s.scalar_fu_ops;
    agg.gm_words += s.gm_words;
    agg.gm_requests += s.gm_requests;
    agg.lm_words += s.lm_words;
    agg.rf_accesses += s.rf_accesses;
    agg.stall_raw += s.stall_raw;
    agg.stall_waw += s.stall_waw;
    agg.stall_fu += s.stall_fu;
    agg.stall_lsq += s.stall_lsq;
    agg.stall_mem_window += s.stall_mem_window;
    agg.gm_busy_cycles += s.gm_busy_cycles;
    agg.lm_busy_cycles += s.lm_busy_cycles;
}

#[cfg(test)]
mod tests {
    use super::*;

    fn coord(b: usize) -> Coordinator {
        Coordinator::new(CoordinatorConfig {
            ae: AeLevel::Ae5,
            b,
            artifact_dir: "/nonexistent".into(),
            verify: true,
            ..CoordinatorConfig::default()
        })
    }

    #[test]
    fn dgemm_values_match_host_reference() {
        let n = 24;
        let a = Mat::random(n, n, 71);
        let b = Mat::random(n, n, 72);
        let c = Mat::random(n, n, 73);
        let mut co = coord(2);
        let r = co.dgemm(&a, &b, &c);
        assert_eq!(r.source, ValueSource::PeSim);
        let want = crate::blas::level3::dgemm_ref(&a, &b, &c);
        let err = crate::util::rel_fro_error(r.c.as_slice(), want.as_slice());
        assert!(err < 1e-12, "coordinator DGEMM wrong: {err}");
        assert_eq!(r.tiles.len(), 4);
        assert!(r.makespan > 0);
        assert!(r.energy_j > 0.0);
    }

    #[test]
    fn dgemm_pads_odd_sizes() {
        let n = 10; // not a multiple of 4b = 8 → padded to 16
        let a = Mat::random(n, n, 74);
        let b = Mat::random(n, n, 75);
        let c = Mat::zeros(n, n);
        let mut co = coord(2);
        let r = co.dgemm(&a, &b, &c);
        let want = crate::blas::level3::dgemm_ref(&a, &b, &c);
        let err = crate::util::rel_fro_error(r.c.as_slice(), want.as_slice());
        assert!(err < 1e-12, "padded DGEMM wrong: {err}");
    }

    #[test]
    fn residual_mode_serves_odd_sizes_on_one_pe() {
        let n = 10;
        let a = Mat::random(n, n, 74);
        let b = Mat::random(n, n, 75);
        let c = Mat::random(n, n, 76);
        let mut co = Coordinator::new(CoordinatorConfig {
            ae: AeLevel::Ae5,
            b: 2,
            artifact_dir: "/nonexistent".into(),
            verify: false,
            residual: true,
            ..CoordinatorConfig::default()
        });
        let r = co.dgemm(&a, &b, &c);
        let want = crate::blas::level3::dgemm_ref(&a, &b, &c);
        let err = crate::util::rel_fro_error(r.c.as_slice(), want.as_slice());
        assert!(err < 1e-12, "residual DGEMM wrong: {err}");
        assert_eq!(r.tiles.len(), 1, "residual path is single-PE");
        assert!(r.makespan > 0);
        assert!(r.energy_j > 0.0);
        // Aligned shapes still take the tiled path in residual mode.
        let n = 8;
        let a = Mat::random(n, n, 80);
        let b = Mat::random(n, n, 81);
        let r = co.dgemm(&a, &b, &Mat::zeros(n, n));
        assert_eq!(r.tiles.len(), 4, "aligned shapes must stay tiled");
    }

    #[test]
    fn bigger_array_is_faster() {
        let n = 48;
        let a = Mat::random(n, n, 76);
        let b = Mat::random(n, n, 77);
        let c = Mat::zeros(n, n);
        let m1 = coord(1).dgemm(&a, &b, &c).makespan;
        let m2 = coord(2).dgemm(&a, &b, &c).makespan;
        let m3 = coord(3).dgemm(&a, &b, &c).makespan;
        assert!(m2 < m1, "2x2 ({m2}) not faster than 1x1 ({m1})");
        assert!(m3 < m2, "3x3 ({m3}) not faster than 2x2 ({m2})");
    }

    #[test]
    fn dgemv_and_level1_paths() {
        let n = 16;
        let a = Mat::random(n, n, 78);
        let mut rng = crate::util::XorShift64::new(79);
        let x = rng.vec(n);
        let y = rng.vec(n);
        let mut co = coord(2);
        let (v, meas, src) = co.dgemv(&a, &x, &y);
        assert_eq!(src, ValueSource::PeSim);
        assert!(meas.latency() > 0);
        crate::util::assert_allclose(&v, &crate::blas::level2::dgemv_ref(&a, &x, &y), 1e-12);
        let (d, m2, _) = co.ddot(&x, &y);
        assert!((d - crate::blas::level1::ddot(&x, &y)).abs() < 1e-12);
        assert!(m2.latency() > 0);
        let (ax, m3, _) = co.daxpy(1.5, &x, &y);
        let mut want = y.clone();
        crate::blas::level1::daxpy(1.5, &x, &mut want);
        crate::util::assert_allclose(&ax, &want, 1e-12);
        assert!(m3.latency() > 0);
        let (nrm, m4, _) = co.dnrm2(&x);
        assert!((nrm - crate::blas::level1::dnrm2(&x)).abs() < 1e-12);
        assert!(m4.latency() > 0);
        // All four kernels ran on pool workers, none inline.
        let counts = co.pool_job_counts();
        assert_eq!(counts.gemv, 1);
        assert_eq!(counts.level1, 3);
    }

    #[test]
    fn repeated_shapes_hit_the_program_cache() {
        let n = 16;
        let mut co = coord(2);
        for seed in 0..3 {
            let a = Mat::random(n, n, 200 + seed);
            let b = Mat::random(n, n, 300 + seed);
            let c = Mat::zeros(n, n);
            co.dgemm(&a, &b, &c);
        }
        let s = co.cache_stats();
        assert_eq!(s.misses, 1, "one shape must emit exactly one program: {s:?}");
        assert_eq!(s.hits, 2, "repeats must hit: {s:?}");
        assert_eq!(co.pool_size(), 4);
        // Standalone: the tenant slice and the shared totals coincide.
        assert_eq!(s, co.shared_cache_stats());
        assert_eq!(co.pool_job_counts(), co.shared_pool_job_counts());
    }

    #[test]
    fn mixed_shapes_fill_distinct_cache_entries() {
        let mut co = coord(2);
        for n in [8usize, 16, 8, 24, 16] {
            let a = Mat::random(n, n, n as u64);
            let b = Mat::random(n, n, n as u64 + 1);
            let c = Mat::zeros(n, n);
            co.dgemm(&a, &b, &c);
        }
        let s = co.cache_stats();
        assert_eq!(s.entries, 3, "three distinct padded shapes: {s:?}");
        assert_eq!(s.misses, 3);
        assert_eq!(s.hits, 2);
    }

    #[test]
    fn repeated_dgemm_replays_the_cached_schedule() {
        // Three same-shape DGEMMs: the first request's tiles run the
        // timing pass (workers may race, so 1..=4 combined runs); every
        // tile of the later requests replays the memoized schedule.
        let n = 16;
        let mut co = coord(2);
        for seed in 0..3u64 {
            let a = Mat::random(n, n, 400 + seed);
            let b = Mat::random(n, n, 500 + seed);
            let c = Mat::zeros(n, n);
            let r = co.dgemm(&a, &b, &c);
            let want = crate::blas::level3::dgemm_ref(&a, &b, &c);
            let err = crate::util::rel_fro_error(r.c.as_slice(), want.as_slice());
            assert!(err < 1e-12, "replayed DGEMM wrong: {err}");
        }
        let counts = co.pool_job_counts();
        assert_eq!(counts.gemm_tiles, 12);
        assert_eq!(counts.replays + counts.combined_runs, 12);
        assert!(
            (1..=4).contains(&counts.combined_runs),
            "only the first request's tiles may pay the timing pass: {counts:?}"
        );
        assert!(counts.replays >= 8, "later requests must replay: {counts:?}");
        // The resident kernel carries its memoized schedule.
        let sched = co.cache().gemm_rect(n / 2, n / 2, n, AeLevel::Ae5);
        assert!(sched.is_scheduled(), "cached kernel must hold the one-time schedule");
    }

    #[test]
    fn capped_coordinator_counts_evictions() {
        let mut co = Coordinator::new(CoordinatorConfig {
            ae: AeLevel::Ae5,
            b: 2,
            artifact_dir: "/nonexistent".into(),
            verify: false,
            cache_capacity: Some(1),
            ..CoordinatorConfig::default()
        });
        for n in [8usize, 16, 8] {
            let a = Mat::random(n, n, n as u64);
            let b = Mat::random(n, n, n as u64 + 1);
            let c = Mat::zeros(n, n);
            let r = co.dgemm(&a, &b, &c);
            let want = crate::blas::level3::dgemm_ref(&a, &b, &c);
            let err = crate::util::rel_fro_error(r.c.as_slice(), want.as_slice());
            assert!(err < 1e-12, "capped DGEMM n={n} wrong: {err}");
        }
        let s = co.cache_stats();
        assert_eq!(s.entries, 1, "cap must bound residency: {s:?}");
        assert_eq!(s.evictions, 2, "both shape switches must evict: {s:?}");
        assert_eq!(s.misses, 3, "the re-requested shape re-emits: {s:?}");
    }
}
