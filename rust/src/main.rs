//! `redefine` — CLI of the coordinator (the L3 leader entrypoint).
//!
//! Hand-rolled argument parsing (this environment vendors only the `xla`
//! crate closure — no clap). Subcommands:
//!
//! ```text
//! redefine gemm  --n 64 [--b 2] [--ae 5] [--artifacts DIR] [--residual]
//! redefine gemv  --n 64 [--ae 5]
//! redefine ddot  --n 1024 [--ae 5]
//! redefine serve --requests 16 --max-n 64 [--b 2] [--ae 5] [--seq]
//!                [--lapack qr|lu|chol --n N]
//!                [--window W] [--window-bytes BYTES] [--cache-cap N]
//!                [--cache-quota N] [--sched slots|cycles]
//!                [--exec replay|combined] [--residual] [--replay-batch N]
//!                [--tenants N [--weights w1,w2,...]]
//!                [--arrivals poisson|burst --rate R --duration-ms D]
//!                [--queue-depth N] [--shed-after-bytes BYTES] [--slo-ms MS]
//!                [--fabric B] [--place locality|round-robin]
//! redefine sweep                       # Tables 4-9 summary
//! redefine artifacts [--artifacts DIR] # list loadable artifacts
//! ```
//!
//! `serve` drives the serving engine: requests of every BLAS level flow
//! through the program cache and the persistent worker pool
//! (`serve_batch`); `--seq` falls back to the strictly sequential
//! reference loop. `--window W` bounds how many requests are staged in
//! flight at once and `--window-bytes B` additionally bounds the packed
//! GM bytes they pin (backpressure for huge batches); `--cache-cap N`
//! caps the program cache at N resident kernels (LRU eviction); `--exec
//! combined` disables the two-tier value-replay fast path; `--residual`
//! serves non-4-aligned DGEMMs on the cached DOT2/3 residual kernel
//! instead of padding; `--replay-batch N` coalesces up to N same-kernel
//! staged DGEMM tiles into one replay-batched pool job (the tier-2b fast
//! path — identical results, fewer decode-stream walks).
//!
//! `serve --lapack qr|lu|chol` serves LAPACK factorizations as
//! dependency-DAG workloads: each of the `--requests` requests is a
//! `--n`-sized DGEQRF / DGETRF / DPOTRF that admission expands into a
//! blocked kernel DAG (panels + trailing updates) dispatched
//! dependency-aware through the same cache, tiers and fabric as flat
//! BLAS. Closed-loop, the report adds per-response node counts, DAG
//! makespans and the Fig-1 flop attribution. Under `--tenants N`,
//! tenant 0 serves the factorization workload while the remaining
//! tenants flood flat BLAS (the proportional-service scenario); under
//! `--arrivals`, one arrival in four becomes a `--n`-sized
//! factorization mixed into the flat open-loop stream.
//!
//! `serve --tenants N` runs the **multi-tenant engine**: one shared
//! worker pool + one shared program cache serve N concurrent tenants
//! (cycling enhancement levels AE0–AE5) under a weighted fair scheduler
//! (`--weights`), reporting per-tenant and aggregate statistics.
//! `--sched cycles` (the default) schedules by estimated simulated
//! cycles (deficit round-robin), so mismatched kernel costs cannot skew
//! cycle service away from the weights; `--sched slots` pins the
//! PR 4 slot-WRR baseline. `--cache-quota N` bounds each tenant to N
//! resident kernels in the shared cache, so a shape-churning tenant
//! evicts its own warm kernels, never a sibling's.
//!
//! `serve --arrivals poisson|burst` switches to **open-loop** serving:
//! instead of replaying a fixed list as fast as completions allow, a
//! seeded arrival process offers `--rate R` requests/s for
//! `--duration-ms D` (`--requests` is ignored), and the report is
//! per-tenant p50/p95/p99 queue/service/total latency plus shed counts.
//! `--queue-depth N` / `--shed-after-bytes B` bound the pending queue,
//! shedding overflow arrivals with explicit rejections (never silent
//! drops); `--slo-ms MS` counts served requests whose total latency blew
//! the SLO. Composes with `--tenants N` (staggered per-tenant start
//! times — tenant churn) and with every closed-loop serving flag. See
//! `docs/CLI.md` for the full flag reference.
//!
//! `serve --fabric B` models the engine as a B×B REDEFINE fabric: every
//! job is placed on a compute tile (`--place locality|round-robin`) and
//! its operand/result movement is priced on the mesh with real link
//! contention, so reported cycles become communication + compute.
//! `--fabric 0` (the default) keeps the location-free pool — identical to
//! the pre-fabric serving path.
//!
//! `serve --trace-out FILE` attaches a per-tenant trace sink and writes
//! the captured event log after the run: one JSON object per line by
//! default (`--trace-format json`), or a Chrome trace-event file
//! (`--trace-format chrome`) loadable in `chrome://tracing` / Perfetto.
//! Without `--trace-out` no sink is attached and serving runs the exact
//! untraced path. See `docs/OBSERVABILITY.md`.

use redefine_blas::coordinator::{
    request::{factor_workload, random_workload},
    Coordinator, CoordinatorConfig, OpenLoopOptions, OpenLoopStats,
};
use redefine_blas::engine::traffic::{self, ArrivalKind, TrafficConfig};
use redefine_blas::engine::{Engine, EngineConfig, SchedPolicy};
use redefine_blas::lapack::FactorKind;
use redefine_blas::metrics::{gemm_sweep, PAPER_SIZES};
use redefine_blas::noc::{FabricConfig, FabricStats, PlacePolicy};
use redefine_blas::obs::{to_chrome, to_jsonl, BufferSink, Event};
use redefine_blas::pe::{AeLevel, ExecMode, PeConfig};
use redefine_blas::util::{Mat, XorShift64};
use std::process::exit;
use std::sync::Arc;

/// The usage string; `docs/CLI.md` documents every flag listed here, and a
/// unit test below asserts the two cannot drift apart.
const USAGE: &str = "usage: redefine <gemm|gemv|ddot|serve|sweep|artifacts> [--n N] [--b B] \
     [--ae 0..5] [--requests K] [--max-n N] [--artifacts DIR] [--seq] \
     [--window W] [--window-bytes BYTES] [--cache-cap N] [--cache-quota N] \
     [--sched slots|cycles] [--exec replay|combined] [--residual] \
     [--replay-batch N] [--tenants N] [--weights w1,w2,...] \
     [--arrivals poisson|burst] [--rate R] [--duration-ms D] \
     [--queue-depth N] [--shed-after-bytes BYTES] [--slo-ms MS] \
     [--fabric B] [--place locality|round-robin] [--lapack qr|lu|chol] \
     [--trace-out PATH] [--trace-format json|chrome]";

fn usage() -> ! {
    eprintln!("{USAGE}");
    exit(2)
}

/// On-disk layout for `--trace-out`: JSONL (one event object per line) or
/// the Chrome trace-event array.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum TraceFormat {
    Json,
    Chrome,
}

#[derive(Debug)]
struct Args {
    cmd: String,
    n: usize,
    b: usize,
    ae: AeLevel,
    requests: usize,
    max_n: usize,
    artifacts: String,
    seq: bool,
    window: Option<usize>,
    window_bytes: Option<u64>,
    cache_cap: Option<usize>,
    cache_quota: Option<usize>,
    sched: SchedPolicy,
    exec: ExecMode,
    residual: bool,
    replay_batch: Option<usize>,
    tenants: usize,
    weights: Option<String>,
    arrivals: Option<ArrivalKind>,
    rate: f64,
    duration_ms: u64,
    queue_depth: Option<usize>,
    shed_after_bytes: Option<u64>,
    slo_ms: Option<u64>,
    fabric: usize,
    place: PlacePolicy,
    lapack: Option<FactorKind>,
    trace_out: Option<String>,
    trace_format: TraceFormat,
}

impl Args {
    /// The modeled fabric, if any: `--fabric 0` (default) is the
    /// location-free pool, `--fabric B >= 1` a B×B routed fabric under the
    /// `--place` policy.
    fn fabric_cfg(&self) -> Option<FabricConfig> {
        (self.fabric >= 1)
            .then(|| FabricConfig { place: self.place, ..FabricConfig::new(self.fabric) })
    }
}

fn parse_args() -> Args {
    let mut it = std::env::args().skip(1);
    let cmd = it.next().unwrap_or_else(|| usage());
    let mut a = Args {
        cmd,
        n: 64,
        b: 2,
        ae: AeLevel::Ae5,
        requests: 16,
        max_n: 64,
        artifacts: "artifacts".into(),
        seq: false,
        window: None,
        window_bytes: None,
        cache_cap: None,
        cache_quota: None,
        sched: SchedPolicy::Cycles,
        exec: ExecMode::Replay,
        residual: false,
        replay_batch: None,
        tenants: 1,
        weights: None,
        arrivals: None,
        rate: 400.0,
        duration_ms: 500,
        queue_depth: None,
        shed_after_bytes: None,
        slo_ms: None,
        fabric: 0,
        place: PlacePolicy::Locality,
        lapack: None,
        trace_out: None,
        trace_format: TraceFormat::Json,
    };
    while let Some(flag) = it.next() {
        let mut val = || it.next().unwrap_or_else(|| usage());
        match flag.as_str() {
            "--n" => a.n = val().parse().unwrap_or_else(|_| usage()),
            "--b" => a.b = val().parse().unwrap_or_else(|_| usage()),
            "--requests" => a.requests = val().parse().unwrap_or_else(|_| usage()),
            "--max-n" => a.max_n = val().parse().unwrap_or_else(|_| usage()),
            "--artifacts" => a.artifacts = val(),
            "--seq" => a.seq = true,
            "--residual" => a.residual = true,
            "--window" => {
                a.window = Some(val().parse().ok().filter(|w| *w >= 1).unwrap_or_else(|| usage()))
            }
            "--window-bytes" => {
                a.window_bytes =
                    Some(val().parse().ok().filter(|b| *b >= 1).unwrap_or_else(|| usage()))
            }
            "--cache-cap" => {
                a.cache_cap =
                    Some(val().parse().ok().filter(|c| *c >= 1).unwrap_or_else(|| usage()))
            }
            "--cache-quota" => {
                a.cache_quota =
                    Some(val().parse().ok().filter(|q| *q >= 1).unwrap_or_else(|| usage()))
            }
            "--replay-batch" => {
                a.replay_batch =
                    Some(val().parse().ok().filter(|n| *n >= 1).unwrap_or_else(|| usage()))
            }
            "--sched" => {
                a.sched = match val().as_str() {
                    "slots" => SchedPolicy::Slots,
                    "cycles" => SchedPolicy::Cycles,
                    _ => usage(),
                }
            }
            "--tenants" => {
                a.tenants = val().parse().ok().filter(|t| *t >= 1).unwrap_or_else(|| usage())
            }
            "--weights" => a.weights = Some(val()),
            "--arrivals" => {
                a.arrivals = Some(match val().as_str() {
                    "poisson" => ArrivalKind::Poisson,
                    "burst" => ArrivalKind::Burst { size: 8 },
                    _ => usage(),
                })
            }
            "--rate" => {
                a.rate = val().parse().ok().filter(|r| *r > 0.0).unwrap_or_else(|| usage())
            }
            "--duration-ms" => {
                a.duration_ms = val().parse().ok().filter(|d| *d >= 1).unwrap_or_else(|| usage())
            }
            "--queue-depth" => {
                a.queue_depth =
                    Some(val().parse().ok().filter(|q| *q >= 1).unwrap_or_else(|| usage()))
            }
            "--shed-after-bytes" => {
                a.shed_after_bytes =
                    Some(val().parse().ok().filter(|b| *b >= 1).unwrap_or_else(|| usage()))
            }
            "--slo-ms" => a.slo_ms = Some(val().parse().unwrap_or_else(|_| usage())),
            "--fabric" => a.fabric = val().parse().unwrap_or_else(|_| usage()),
            "--lapack" => a.lapack = Some(FactorKind::parse(&val()).unwrap_or_else(|| usage())),
            "--trace-out" => a.trace_out = Some(val()),
            "--trace-format" => {
                a.trace_format = match val().as_str() {
                    "json" => TraceFormat::Json,
                    "chrome" => TraceFormat::Chrome,
                    _ => usage(),
                }
            }
            "--place" => {
                a.place = match val().as_str() {
                    "locality" => PlacePolicy::Locality,
                    "round-robin" => PlacePolicy::RoundRobin,
                    _ => usage(),
                }
            }
            "--exec" => {
                a.exec = match val().as_str() {
                    "replay" => ExecMode::Replay,
                    "combined" => ExecMode::Combined,
                    _ => usage(),
                }
            }
            "--ae" => {
                let i: usize = val().parse().unwrap_or_else(|_| usage());
                a.ae = *AeLevel::ALL.get(i).unwrap_or_else(|| usage());
            }
            _ => usage(),
        }
    }
    a
}

fn main() {
    let args = parse_args();
    let cfg = CoordinatorConfig {
        ae: args.ae,
        b: args.b,
        artifact_dir: args.artifacts.clone(),
        verify: true,
        admission_window: args.window,
        admission_bytes: args.window_bytes,
        cache_capacity: args.cache_cap,
        cache_quota: args.cache_quota,
        sched: args.sched,
        exec: args.exec,
        residual: args.residual,
        replay_batch: args.replay_batch,
        queue_depth: args.queue_depth,
        shed_after_bytes: args.shed_after_bytes,
        fabric: args.fabric_cfg(),
    };

    match args.cmd.as_str() {
        "gemm" => {
            let n = args.n;
            let a = Mat::random(n, n, 1);
            let b = Mat::random(n, n, 2);
            let c = Mat::zeros(n, n);
            let mut co = Coordinator::new(cfg);
            let r = co.dgemm(&a, &b, &c);
            let pe_cfg = PeConfig::paper(args.ae);
            println!(
                "dgemm n={n} tiles={}x{} ae={} source={:?}",
                args.b, args.b, args.ae, r.source
            );
            println!(
                "  makespan={} cycles ({:.3} ms @{} GHz)  {:.3} Gflops  energy={:.3e} J",
                r.makespan,
                r.makespan as f64 * pe_cfg.cycle_ns() / 1e6,
                pe_cfg.clock_ghz,
                r.gflops(n, &pe_cfg),
                r.energy_j
            );
            for (c, ready, compute, fin) in &r.tiles {
                println!(
                    "  tile ({},{})  ready={ready}  compute={compute}  finish={fin}",
                    c.row, c.col
                );
            }
        }
        "gemv" => {
            let n = args.n;
            let a = Mat::random(n, n, 3);
            let mut rng = XorShift64::new(4);
            let x = rng.vec(n);
            let y = rng.vec(n);
            let mut co = Coordinator::new(cfg);
            let (_, meas, source) = co.dgemv(&a, &x, &y);
            println!(
                "dgemv n={n} ae={} source={source:?}: {} cycles, {:.2}% of peak FPC, {:.2} Gflops/W",
                args.ae,
                meas.latency(),
                meas.pct_peak_fpc(),
                meas.gflops_per_watt()
            );
        }
        "ddot" => {
            let n = args.n;
            let mut rng = XorShift64::new(5);
            let x = rng.vec(n);
            let y = rng.vec(n);
            let mut co = Coordinator::new(cfg);
            let (v, meas, source) = co.ddot(&x, &y);
            println!(
                "ddot n={n} ae={} source={source:?}: value={v:.6}, {} cycles, {:.2}% of peak FPC",
                args.ae,
                meas.latency(),
                meas.pct_peak_fpc()
            );
        }
        "serve" if args.arrivals.is_some() => serve_open_loop_cmd(&args, &cfg),
        "serve" if args.tenants > 1 => serve_multi_tenant(&args, &cfg),
        "serve" => {
            let mut co = Coordinator::new(cfg);
            let sink = trace_sink(&args);
            if let Some(s) = &sink {
                co.set_trace_sink(s.clone());
            }
            let reqs = match args.lapack {
                Some(kind) => factor_workload(kind, args.requests, args.n, 42),
                None => random_workload(args.requests, args.max_n, 42),
            };
            let t0 = std::time::Instant::now();
            let resps = if args.seq { co.serve(reqs) } else { co.serve_batch(reqs) };
            let wall = t0.elapsed();
            let snap = co.snapshot();
            let total_cycles: u64 = resps.iter().map(|r| r.cycles).sum();
            let mode = if args.seq { "sequential" } else { "batched (pool + cache)" };
            println!(
                "served {} requests in {:.1} ms wall [{mode}]; {} simulated cycles total",
                resps.len(),
                wall.as_secs_f64() * 1e3,
                total_cycles
            );
            let cs = snap.cache;
            println!(
                "program cache: {} kernels resident, {} hits / {} misses / {} evictions; \
                 {} pool workers",
                cs.entries,
                cs.hits,
                cs.misses,
                cs.evictions,
                snap.pool_size
            );
            let jc = snap.jobs;
            println!(
                "pool executed {} gemm tiles, {} gemv kernels, {} level-1 kernels \
                 ({} value-replayed / {} combined timing passes, {} coalesced replay batches)",
                jc.gemm_tiles, jc.gemv, jc.level1, jc.replays, jc.combined_runs, jc.batched_replays
            );
            if let Some(bs) = snap.batch {
                println!(
                    "admission: window {}, byte budget {}, peak {} staged / {} B packed, \
                     {} shared measurements",
                    args.window.map_or("unbounded".into(), |w| w.to_string()),
                    args.window_bytes.map_or("unbounded".into(), |b| b.to_string()),
                    bs.peak_staged,
                    bs.peak_staged_bytes,
                    bs.shared_measurements
                );
            }
            if let Some(fs) = &snap.fabric {
                print_fabric(fs);
            }
            for r in &resps {
                match &r.factor {
                    Some(f) => println!(
                        "  {:<6} n={:<4} cycles={:<9} source={:?} [dag: {} nodes, makespan {}]",
                        r.op, r.n, r.cycles, r.source, f.nodes, f.makespan
                    ),
                    None => println!(
                        "  {:<6} n={:<4} cycles={:<9} source={:?}",
                        r.op, r.n, r.cycles, r.source
                    ),
                }
            }
            // Fig-1 flop attribution of the served factorization kind —
            // identical across same-shape responses, so print it once.
            if let Some(f) = resps.iter().find_map(|r| r.factor.as_deref()) {
                print!("{}", f.profile.report(&format!("{} flop profile", resps[0].op)));
            }
            if let Some(s) = &sink {
                write_trace(&args, vec![(0, s.take())]);
            }
        }
        "sweep" => {
            println!("DGEMM enhancement sweep (Tables 4-9):");
            let sweep = gemm_sweep(&PAPER_SIZES);
            for (ai, row) in sweep.iter().enumerate() {
                print!("{:<22}", format!("{}", AeLevel::ALL[ai]));
                for m in row {
                    print!("{:>10}", m.latency());
                }
                println!();
            }
        }
        "artifacts" => {
            // Disk listing works in every build; the PJRT platform line
            // only when the runtime initializes (pjrt feature + client).
            match redefine_blas::runtime::Runtime::new(&args.artifacts) {
                Ok(rt) => println!("platform: {}", rt.platform()),
                Err(e) => println!("runtime unavailable ({e}); listing artifacts on disk only"),
            }
            let dir = std::path::Path::new(&args.artifacts);
            let found = redefine_blas::runtime::scan_artifacts(dir);
            if found.is_empty() {
                println!("no artifacts under {}", dir.display());
            } else {
                for k in found {
                    println!("  {}", k.file_name());
                }
            }
        }
        _ => usage(),
    }
}

/// Parse `--weights w1,w2,...` (default: all 1s), enforcing one weight >= 1
/// per tenant.
fn parse_weights(args: &Args) -> Vec<u64> {
    let weights: Vec<u64> = match &args.weights {
        Some(spec) => spec
            .split(',')
            .map(|w| w.trim().parse().ok().filter(|w| *w >= 1).unwrap_or_else(|| usage()))
            .collect(),
        None => vec![1; args.tenants],
    };
    if weights.len() != args.tenants {
        eprintln!("--weights needs exactly {} comma-separated values >= 1", args.tenants);
        exit(2);
    }
    weights
}

/// Milliseconds from nanoseconds, for report lines.
fn ms(ns: u64) -> f64 {
    ns as f64 / 1e6
}

/// One host-clock-stamping buffer sink when `--trace-out` is set; `None`
/// otherwise, so the untraced serve path stays bit-identical.
fn trace_sink(args: &Args) -> Option<Arc<BufferSink>> {
    args.trace_out.as_ref().map(|_| Arc::new(BufferSink::with_host_clock()))
}

/// Serialize the per-tenant event groups in the requested `--trace-format`
/// and write them to `--trace-out`.
fn write_trace(args: &Args, groups: Vec<(usize, Vec<Event>)>) {
    let Some(path) = &args.trace_out else { return };
    let out = match args.trace_format {
        TraceFormat::Json => to_jsonl(&groups),
        TraceFormat::Chrome => to_chrome(&groups),
    };
    if let Err(e) = std::fs::write(path, out) {
        eprintln!("failed to write trace to {path}: {e}");
        exit(1);
    }
    let events: usize = groups.iter().map(|(_, evs)| evs.len()).sum();
    println!(
        "trace: {events} events from {} tenant(s) -> {path} [{}]",
        groups.len(),
        match args.trace_format {
            TraceFormat::Json => "jsonl",
            TraceFormat::Chrome => "chrome",
        }
    );
}

/// Fabric telemetry block: routed-job totals, compute/comm split, and the
/// per-link utilization listing.
fn print_fabric(fs: &FabricStats) {
    println!(
        "fabric {}x{} [{} placement]: {} jobs routed, makespan {} cycles, \
         compute/comm ratio {:.2} ({} compute / {} comm cycles)",
        fs.b,
        fs.b,
        fs.place.name(),
        fs.jobs_routed,
        fs.makespan,
        fs.compute_comm_ratio(),
        fs.compute_cycles,
        fs.comm_cycles
    );
    println!(
        "  links: max busy {} cycles, total busy {} cycles over {} active links; \
         jobs per tile {:?}",
        fs.max_link_busy,
        fs.total_link_busy,
        fs.link_busy.len(),
        fs.tile_jobs
    );
    for ((f, t), busy) in &fs.link_busy {
        println!("    ({},{}) -> ({},{}): {busy} busy cycles", f.row, f.col, t.row, t.col);
    }
}

/// Per-tenant open-loop report block: offered/served/shed accounting plus
/// the queue/service/total latency percentiles. Reads the stats slice of
/// the tenant snapshot (`Coordinator::snapshot().open_loop`).
fn print_open_loop(label: &str, s: &OpenLoopStats) {
    println!(
        "  {label}: offered {} -> served {} / shed {} (peak pending {} reqs / {} B); \
         slo violations {}",
        s.offered, s.served, s.shed, s.peak_pending, s.peak_pending_bytes, s.slo_violations
    );
    for (name, l) in [("queue", &s.queue), ("service", &s.service), ("total", &s.total)] {
        println!(
            "    {name:<8} p50/p95/p99/max = {:.3} / {:.3} / {:.3} / {:.3} ms",
            ms(l.p50),
            ms(l.p95),
            ms(l.p99),
            ms(l.max)
        );
    }
}

/// Open-loop serve: a seeded arrival process (`--arrivals poisson|burst`)
/// offers `--rate` requests/s for `--duration-ms`, independent of
/// completions; the engine admits under the window/byte budget, sheds past
/// the pending-queue caps, and reports per-tenant latency percentiles.
/// With `--tenants N`, tenants run concurrently on one shared engine with
/// staggered start times (tenant churn).
fn serve_open_loop_cmd(args: &Args, base: &CoordinatorConfig) {
    let kind = args.arrivals.expect("open-loop dispatch requires --arrivals");
    let base_traffic = TrafficConfig {
        kind,
        rate_rps: args.rate,
        duration_ns: args.duration_ms.saturating_mul(1_000_000),
        start_ns: 0,
        seed: 42,
        max_n: args.max_n,
        // With --lapack, one arrival in four is a --n-sized factorization
        // DAG mixed into the flat BLAS stream.
        lapack_fraction: if args.lapack.is_some() { 0.25 } else { 0.0 },
        lapack_n: args.n,
        ..TrafficConfig::default()
    };
    let opts = OpenLoopOptions { slo_total_ns: args.slo_ms.map(|ms| ms.saturating_mul(1_000_000)) };
    println!(
        "open-loop serve: {kind:?} arrivals, {} req/s for {} ms, seed {} [{:?} scheduler]",
        args.rate, args.duration_ms, base_traffic.seed, args.sched
    );

    if args.tenants == 1 {
        let mut co = Coordinator::new(base.clone());
        let sink = trace_sink(args);
        if let Some(s) = &sink {
            co.set_trace_sink(s.clone());
        }
        let t0 = std::time::Instant::now();
        co.serve_open_loop(traffic::generate(&base_traffic), &opts);
        let wall = t0.elapsed();
        let snap = co.snapshot();
        let stats = snap.open_loop.expect("open-loop run records its stats in the snapshot");
        print_open_loop("tenant 0", &stats);
        println!("drained in {:.1} ms wall", wall.as_secs_f64() * 1e3);
        if let Some(s) = &sink {
            write_trace(args, vec![(0, s.take())]);
        }
        return;
    }

    let weights = parse_weights(args);
    let engine = Engine::new(EngineConfig {
        workers: args.b * args.b,
        cache_capacity: args.cache_cap,
        cache_quota: args.cache_quota,
        sched: args.sched,
        fabric: args.fabric_cfg(),
    });
    let sinks: Vec<Arc<BufferSink>> = match args.trace_out {
        Some(_) => (0..args.tenants).map(|_| Arc::new(BufferSink::with_host_clock())).collect(),
        None => Vec::new(),
    };
    let tenants: Vec<(usize, AeLevel, u64, Coordinator)> = weights
        .iter()
        .enumerate()
        .map(|(i, &w)| {
            let ae = AeLevel::ALL[i % AeLevel::ALL.len()];
            let cfg = CoordinatorConfig { ae, ..base.clone() };
            let mut co = engine.tenant_weighted(cfg, w);
            if let Some(s) = sinks.get(i) {
                co.set_trace_sink(s.clone());
            }
            (i, ae, w, co)
        })
        .collect();
    let total = args.tenants;
    let t0 = std::time::Instant::now();
    let mut reports: Vec<_> = std::thread::scope(|s| {
        let handles: Vec<_> = tenants
            .into_iter()
            .map(|(i, ae, w, mut co)| {
                let tcfg = base_traffic.for_tenant(i, total);
                s.spawn(move || {
                    co.serve_open_loop(traffic::generate(&tcfg), &opts);
                    (i, ae, w, co.snapshot())
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("tenant thread panicked")).collect()
    });
    let wall = t0.elapsed();
    reports.sort_by_key(|r| r.0);
    let es = engine.snapshot();
    println!(
        "{} tenants drained in {:.1} ms wall on {} shared workers",
        reports.len(),
        wall.as_secs_f64() * 1e3,
        es.workers
    );
    for (i, ae, w, snap) in &reports {
        let stats = snap.open_loop.expect("open-loop run records its stats in the snapshot");
        print_open_loop(
            &format!("tenant {i} [{ae}, weight {w}, {} est. cycles]", es.lanes[*i].served_cost),
            &stats,
        );
    }
    let cs = es.cache;
    println!(
        "shared cache: {} kernels resident, {} hits / {} misses / {} evictions",
        cs.entries, cs.hits, cs.misses, cs.evictions
    );
    if let Some(fs) = &es.fabric {
        print_fabric(fs);
    }
    write_trace(args, sinks.iter().enumerate().map(|(i, s)| (i, s.take())).collect());
}

/// Multi-tenant serve: one shared engine (worker pool + program cache)
/// hosts `--tenants` coordinators at cycling AE0–AE5 enhancement levels,
/// each replaying its own mixed workload concurrently under the weighted
/// fair scheduler. Reports per-tenant slices and the shared aggregates.
fn serve_multi_tenant(args: &Args, base: &CoordinatorConfig) {
    let weights = parse_weights(args);
    let engine = Engine::new(EngineConfig {
        workers: args.b * args.b,
        cache_capacity: args.cache_cap,
        cache_quota: args.cache_quota,
        sched: args.sched,
        fabric: args.fabric_cfg(),
    });
    let sinks: Vec<Arc<BufferSink>> = match args.trace_out {
        Some(_) => (0..args.tenants).map(|_| Arc::new(BufferSink::with_host_clock())).collect(),
        None => Vec::new(),
    };
    let tenants: Vec<(usize, AeLevel, u64, Coordinator)> = weights
        .iter()
        .enumerate()
        .map(|(i, &w)| {
            let ae = AeLevel::ALL[i % AeLevel::ALL.len()];
            let cfg = CoordinatorConfig { ae, ..base.clone() };
            let mut co = engine.tenant_weighted(cfg, w);
            if let Some(s) = sinks.get(i) {
                co.set_trace_sink(s.clone());
            }
            (i, ae, w, co)
        })
        .collect();
    let (requests, max_n, seq) = (args.requests, args.max_n, args.seq);
    let (lapack, lapack_n) = (args.lapack, args.n);
    let t0 = std::time::Instant::now();
    let mut reports: Vec<_> = std::thread::scope(|s| {
        let handles: Vec<_> = tenants
            .into_iter()
            .map(|(i, ae, w, mut co)| {
                s.spawn(move || {
                    // With --lapack, tenant 0 is the factorization tenant
                    // and the rest flood flat BLAS — the proportional-
                    // service scenario for DAG vs flat workloads.
                    let reqs = match lapack {
                        Some(kind) if i == 0 => {
                            factor_workload(kind, requests, lapack_n, 42)
                        }
                        _ => random_workload(requests, max_n, 42 + i as u64),
                    };
                    let resps = if seq { co.serve(reqs) } else { co.serve_batch(reqs) };
                    let cycles: u64 = resps.iter().map(|r| r.cycles).sum();
                    (i, ae, w, resps.len(), cycles, co.snapshot())
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("tenant thread panicked")).collect()
    });
    let wall = t0.elapsed();
    reports.sort_by_key(|r| r.0);
    let es = engine.snapshot();
    println!(
        "served {} tenants x {requests} requests in {:.1} ms wall on {} shared workers \
         [{:?} scheduler]",
        reports.len(),
        wall.as_secs_f64() * 1e3,
        es.workers,
        es.sched
    );
    for (i, ae, w, served, cycles, snap) in &reports {
        println!(
            "  tenant {i} [{ae}, weight {w}]: {served} served, {cycles} simulated cycles \
             ({} est. cycles dispatched); \
             cache {} hits / {} misses / {} evictions; \
             pool {} tiles / {} gemv / {} level-1",
            es.lanes[*i].served_cost,
            snap.cache.hits,
            snap.cache.misses,
            snap.cache.evictions,
            snap.jobs.gemm_tiles,
            snap.jobs.gemv,
            snap.jobs.level1
        );
    }
    let cs = es.cache;
    let jc = es.jobs;
    println!(
        "shared cache: {} kernels resident, {} hits / {} misses / {} evictions",
        cs.entries, cs.hits, cs.misses, cs.evictions
    );
    println!(
        "shared pool: {} gemm tiles, {} gemv, {} level-1 kernels \
         ({} value-replayed / {} combined timing passes, {} coalesced replay batches)",
        jc.gemm_tiles, jc.gemv, jc.level1, jc.replays, jc.combined_runs, jc.batched_replays
    );
    if let Some(fs) = &es.fabric {
        print_fabric(fs);
    }
    write_trace(args, sinks.iter().enumerate().map(|(i, s)| (i, s.take())).collect());
}

#[cfg(test)]
mod tests {
    use super::USAGE;

    /// Every flag documented in `docs/CLI.md` must appear in the usage
    /// string (and the parser); this is the doc's anti-rot tripwire. When
    /// adding a flag, extend all three of: `parse_args`, `USAGE`, and the
    /// CLI.md table.
    #[test]
    fn usage_mentions_every_documented_flag() {
        let documented = [
            "--n",
            "--b",
            "--ae",
            "--requests",
            "--max-n",
            "--artifacts",
            "--seq",
            "--window",
            "--window-bytes",
            "--cache-cap",
            "--cache-quota",
            "--sched",
            "--exec",
            "--residual",
            "--replay-batch",
            "--tenants",
            "--weights",
            "--arrivals",
            "--rate",
            "--duration-ms",
            "--queue-depth",
            "--shed-after-bytes",
            "--slo-ms",
            "--fabric",
            "--place",
            "--trace-out",
            "--trace-format",
            "--lapack",
        ];
        for flag in documented {
            assert!(USAGE.contains(flag), "usage string is missing `{flag}`");
        }
    }

    #[test]
    fn usage_mentions_every_subcommand() {
        for cmd in ["gemm", "gemv", "ddot", "serve", "sweep", "artifacts"] {
            assert!(USAGE.contains(cmd), "usage string is missing the `{cmd}` subcommand");
        }
    }
}
