//! Analytical models of the comparison platforms (§3, Fig 2, Fig 11(j)).
//!
//! The paper measured legacy BLAS on Intel Haswell / AMD Bulldozer with
//! gcc, icc and icc+AVX, MAGMA on a Tesla C2050, and compared the PE's
//! energy efficiency against published numbers for CPUs, GPUs, ClearSpeed
//! CSX700 and an Altera FPGA. None of that hardware is available here, so
//! we substitute models that capture the mechanisms behind the curves (see
//! DESIGN.md substitution ledger):
//!
//! * [`cache`] — a set-associative cache simulator, trace-driven over the
//!   actual reference-BLAS loop nests for small n and cross-validated
//!   against the analytical miss model used for large n;
//! * [`cpu`] — an issue-width/CPI multicore model (Fig 2(a)–(f), (h));
//! * [`gpu`] — a roofline/occupancy model of the Tesla C2050
//!   (Fig 2(g)–(i));
//! * [`db`] — the platform database with published peak/TDP numbers
//!   (Fig 11(j), the 3–140× Gflops/W comparison).

pub mod cache;
pub mod cpu;
pub mod db;
pub mod gpu;

pub use cache::{Cache, CacheConfig, CacheHierarchy};
pub use cpu::{CompilerSetup, CpuModel, CpuRun};
pub use db::{platform_db, Platform};
pub use gpu::GpuModel;
