//! Set-associative cache simulator (LRU), used trace-driven over the
//! reference-BLAS loop nests to reproduce the Fig-2 cache knees exactly for
//! small n, and to cross-validate the analytical miss model in
//! [`super::cpu`] that extends the curves to the paper's large sizes.

/// One cache level's geometry.
#[derive(Debug, Clone)]
pub struct CacheConfig {
    pub size_bytes: usize,
    pub line_bytes: usize,
    pub ways: usize,
}

impl CacheConfig {
    /// Intel Haswell L1D: 32 KiB, 8-way, 64-byte lines.
    pub fn haswell_l1d() -> Self {
        Self { size_bytes: 32 * 1024, line_bytes: 64, ways: 8 }
    }

    /// Intel Haswell L2: 256 KiB, 8-way.
    pub fn haswell_l2() -> Self {
        Self { size_bytes: 256 * 1024, line_bytes: 64, ways: 8 }
    }

    /// Intel Haswell shared L3: 8 MiB, 16-way.
    pub fn haswell_l3() -> Self {
        Self { size_bytes: 8 * 1024 * 1024, line_bytes: 64, ways: 16 }
    }

    pub fn sets(&self) -> usize {
        self.size_bytes / (self.line_bytes * self.ways)
    }
}

/// A set-associative LRU cache.
#[derive(Debug, Clone)]
pub struct Cache {
    cfg: CacheConfig,
    /// Per-set tag stacks, most-recently-used first.
    sets: Vec<Vec<u64>>,
    pub accesses: u64,
    pub misses: u64,
}

impl Cache {
    pub fn new(cfg: CacheConfig) -> Self {
        let sets = vec![Vec::with_capacity(cfg.ways); cfg.sets()];
        Self { cfg, sets, accesses: 0, misses: 0 }
    }

    /// Access one byte address; returns true on hit.
    pub fn access(&mut self, addr: u64) -> bool {
        self.accesses += 1;
        let line = addr / self.cfg.line_bytes as u64;
        let set = (line % self.sets.len() as u64) as usize;
        let stack = &mut self.sets[set];
        if let Some(pos) = stack.iter().position(|&t| t == line) {
            let t = stack.remove(pos);
            stack.insert(0, t);
            true
        } else {
            self.misses += 1;
            if stack.len() == self.cfg.ways {
                stack.pop();
            }
            stack.insert(0, line);
            false
        }
    }

    pub fn miss_rate(&self) -> f64 {
        self.misses as f64 / self.accesses.max(1) as f64
    }
}

/// A two-level hierarchy (L1 + L2) with a flat memory behind it; enough to
/// produce the Fig-2 knees (L3 effects are folded into the analytical model
/// in `cpu.rs`).
#[derive(Debug)]
pub struct CacheHierarchy {
    pub l1: Cache,
    pub l2: Cache,
}

impl CacheHierarchy {
    pub fn haswell() -> Self {
        Self {
            l1: Cache::new(CacheConfig::haswell_l1d()),
            l2: Cache::new(CacheConfig::haswell_l2()),
        }
    }

    /// Access an address through the hierarchy; returns the level that hit
    /// (1, 2) or 3 for memory.
    pub fn access(&mut self, addr: u64) -> u8 {
        if self.l1.access(addr) {
            1
        } else if self.l2.access(addr) {
            2
        } else {
            3
        }
    }
}

/// Trace-driven cache statistics of the reference DGEMM (jki / column-gaxpy
/// order — the Netlib inner loop) on an n×n problem: returns (accesses,
/// l1_misses, l2_misses). Addresses are byte addresses of f64 elements with
/// A at 0, B after A, C after B (column-major).
pub fn trace_dgemm_jki(n: usize, h: &mut CacheHierarchy) -> (u64, u64, u64) {
    let esz = 8u64;
    let a0 = 0u64;
    let b0 = (n * n) as u64 * esz;
    let c0 = 2 * (n * n) as u64 * esz;
    let idx = |base: u64, i: usize, j: usize| base + ((j * n + i) as u64) * esz;
    let (a_l1_0, a_l2_0) = (h.l1.misses, h.l2.misses);
    let acc0 = h.l1.accesses;
    for j in 0..n {
        for k in 0..n {
            h.access(idx(b0, k, j)); // B(k,j) scalar
            for i in 0..n {
                h.access(idx(a0, i, k)); // A(i,k) stride-1
                h.access(idx(c0, i, j)); // C(i,j) stride-1 (read-modify-write)
            }
        }
    }
    (h.l1.accesses - acc0, h.l1.misses - a_l1_0, h.l2.misses - a_l2_0)
}

/// Trace-driven cache statistics of the reference DGEMV (column sweep).
pub fn trace_dgemv(n: usize, h: &mut CacheHierarchy) -> (u64, u64, u64) {
    let esz = 8u64;
    let a0 = 0u64;
    let x0 = (n * n) as u64 * esz;
    let y0 = x0 + n as u64 * esz;
    let (m1, m2) = (h.l1.misses, h.l2.misses);
    let acc0 = h.l1.accesses;
    for j in 0..n {
        h.access(x0 + (j as u64) * esz);
        for i in 0..n {
            h.access(a0 + ((j * n + i) as u64) * esz);
            h.access(y0 + (i as u64) * esz);
        }
    }
    (h.l1.accesses - acc0, h.l1.misses - m1, h.l2.misses - m2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geometry() {
        let c = CacheConfig::haswell_l1d();
        assert_eq!(c.sets(), 64);
    }

    #[test]
    fn lru_within_set() {
        // Direct-mapped-ish tiny cache: 2 ways, 1 set.
        let cfg = CacheConfig { size_bytes: 128, line_bytes: 64, ways: 2 };
        let mut c = Cache::new(cfg);
        assert!(!c.access(0)); // miss
        assert!(!c.access(64)); // miss
        assert!(c.access(0)); // hit (LRU keeps both lines)
        assert!(!c.access(128)); // miss, evicts 64
        assert!(c.access(0)); // still resident
        assert!(!c.access(64)); // was evicted
    }

    #[test]
    fn repeated_access_hits() {
        let mut c = Cache::new(CacheConfig::haswell_l1d());
        c.access(1000);
        for _ in 0..100 {
            assert!(c.access(1000));
        }
        assert_eq!(c.misses, 1);
    }

    #[test]
    fn small_gemm_fits_l1() {
        // 3 matrices of 16x16 f64 = 6 KiB < 32 KiB: only compulsory misses.
        let mut h = CacheHierarchy::haswell();
        let (acc, m1, _) = trace_dgemm_jki(16, &mut h);
        assert!(acc > 0);
        let lines = (3 * 16 * 16 * 8) / 64;
        assert!(
            m1 <= lines as u64 + 16,
            "in-L1 GEMM should see only compulsory misses: {m1} vs {lines}"
        );
    }

    #[test]
    fn large_gemm_misses_grow() {
        let mut h1 = CacheHierarchy::haswell();
        let (acc1, m1s, _) = trace_dgemm_jki(16, &mut h1);
        let mut h2 = CacheHierarchy::haswell();
        let (acc2, m1l, _) = trace_dgemm_jki(96, &mut h2);
        let rate_small = m1s as f64 / acc1 as f64;
        let rate_large = m1l as f64 / acc2 as f64;
        assert!(
            rate_large > 3.0 * rate_small,
            "out-of-L1 miss rate must jump: {rate_small:.5} → {rate_large:.5}"
        );
    }

    #[test]
    fn gemv_streams_a_once() {
        let mut h = CacheHierarchy::haswell();
        let (acc, m1, _) = trace_dgemv(64, &mut h);
        // A is n² = 32 KiB: streamed once, ~1 miss per 8 elements.
        let expected = (64 * 64) / 8;
        assert!(acc > 0);
        assert!(
            (m1 as i64 - expected as i64).unsigned_abs() < expected as u64 / 2,
            "GEMV misses {m1} far from streaming estimate {expected}"
        );
    }
}
