//! Roofline/occupancy model of the Nvidia Tesla C2050 running MAGMA —
//! regenerates Fig 2(g) (DGEMV ≈ 4–5%, DGEMM ≈ 55–57% of peak) and the GPU
//! bars of Fig 2(h)/(i).
//!
//! The C2050: 515 DP Gflops peak (the paper rounds to 512), 144 GB/s DRAM
//! bandwidth, 238 W TDP. MAGMA's DGEMM sustains ≈57% of the peak (the
//! paper's own measurement, consistent with MAGMA's published numbers);
//! DGEMV is bandwidth-bound: 2 flops per 8-byte element read.

/// A modelled GPU.
#[derive(Debug, Clone)]
pub struct GpuModel {
    pub name: &'static str,
    pub peak_dp_gflops: f64,
    pub mem_bw_gbs: f64,
    pub tdp_watts: f64,
    /// Fraction of peak that tuned compute-bound kernels sustain
    /// (instruction mix, occupancy, shared-memory bank effects).
    pub compute_efficiency: f64,
    /// Fraction of the pin bandwidth that streaming kernels sustain.
    pub bw_efficiency: f64,
}

impl GpuModel {
    /// Nvidia Tesla C2050 (Fermi).
    pub fn c2050() -> Self {
        Self {
            name: "Nvidia Tesla C2050",
            peak_dp_gflops: 515.0,
            mem_bw_gbs: 144.0,
            tdp_watts: 238.0,
            compute_efficiency: 0.57,
            bw_efficiency: 0.80,
        }
    }

    /// Achieved DGEMM Gflops at size n (compute-bound for all Fig-2 sizes;
    /// small sizes pay a launch/occupancy ramp).
    pub fn dgemm_gflops(&self, n: usize) -> f64 {
        let ramp = {
            // Occupancy ramp: kernels below ~1k² underfill the SMs.
            let x = n as f64 / 1024.0;
            (x / (1.0 + x)).min(1.0) * 2.0
        }
        .min(1.0);
        self.peak_dp_gflops * self.compute_efficiency * ramp
    }

    /// Achieved DGEMV Gflops at size n (bandwidth-bound: 2 flops per 8
    /// bytes of A traffic).
    pub fn dgemv_gflops(&self, _n: usize) -> f64 {
        let bytes_per_flop = 8.0 / 2.0;
        self.mem_bw_gbs * self.bw_efficiency / bytes_per_flop
    }

    pub fn dgemm_pct_peak(&self, n: usize) -> f64 {
        100.0 * self.dgemm_gflops(n) / self.peak_dp_gflops
    }

    pub fn dgemv_pct_peak(&self, n: usize) -> f64 {
        100.0 * self.dgemv_gflops(n) / self.peak_dp_gflops
    }

    pub fn dgemm_gflops_per_watt(&self, n: usize) -> f64 {
        self.dgemm_gflops(n) / self.tdp_watts
    }

    pub fn dgemv_gflops_per_watt(&self, n: usize) -> f64 {
        self.dgemv_gflops(n) / self.tdp_watts
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2g_dgemm_55_57_pct() {
        let g = GpuModel::c2050();
        let pct = g.dgemm_pct_peak(4096);
        assert!((53.0..59.0).contains(&pct), "MAGMA DGEMM %peak {pct:.1}");
    }

    #[test]
    fn fig2g_dgemv_4_5_pct() {
        let g = GpuModel::c2050();
        let pct = g.dgemv_pct_peak(4096);
        assert!((3.0..7.0).contains(&pct), "MAGMA DGEMV %peak {pct:.1}");
    }

    #[test]
    fn small_sizes_underfill() {
        let g = GpuModel::c2050();
        assert!(g.dgemm_gflops(256) < g.dgemm_gflops(4096));
    }

    #[test]
    fn fig2i_gpu_efficiency_range() {
        // Fig 2(i): MAGMA lands at ~0.03 (DGEMV) to ~0.22 (DGEMM) Gflops/W.
        let g = GpuModel::c2050();
        let mm = g.dgemm_gflops_per_watt(4096);
        let mv = g.dgemv_gflops_per_watt(4096);
        assert!((0.8..1.5).contains(&mm), "DGEMM {mm:.3} Gflops/W");
        assert!((0.05..0.35).contains(&mv), "DGEMV {mv:.3} Gflops/W");
    }
}
