//! Platform database for the Fig-11(j) comparison: published peak
//! performance, TDP and sustained-DGEMM efficiency for the platforms the
//! paper compares against (it uses the estimation methodology of its refs
//! [31], [41], [26] — i.e. published numbers, same as here).

/// A comparison platform with published characteristics.
#[derive(Debug, Clone)]
pub struct Platform {
    pub name: &'static str,
    pub class: PlatformClass,
    /// Peak double-precision Gflops.
    pub peak_gflops: f64,
    /// Typical board/package power in watts.
    pub watts: f64,
    /// Sustained fraction of peak on DGEMM (published / paper-measured).
    pub dgemm_efficiency: f64,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlatformClass {
    IntelCpu,
    AmdCpu,
    NvidiaGpu,
    ClearSpeed,
    Fpga,
    ThisPe,
}

impl Platform {
    /// Achieved DGEMM Gflops/W.
    pub fn gflops_per_watt(&self) -> f64 {
        self.peak_gflops * self.dgemm_efficiency / self.watts
    }
}

/// The Fig-11(j) platform set. PE numbers come from the simulator at AE5
/// (pass the measured value via [`pe_entry`]); the rest are the published
/// figures the paper's methodology relies on.
pub fn platform_db() -> Vec<Platform> {
    vec![
        Platform {
            name: "Intel Core i7-4770 (Haswell)",
            class: PlatformClass::IntelCpu,
            peak_gflops: 48.0,
            watts: 84.0,
            dgemm_efficiency: 0.17,
        },
        Platform {
            name: "Intel Core i7-2600 (Sandy Bridge)",
            class: PlatformClass::IntelCpu,
            peak_gflops: 54.4,
            watts: 95.0,
            dgemm_efficiency: 0.15,
        },
        Platform {
            name: "AMD FX-8150 (Bulldozer)",
            class: PlatformClass::AmdCpu,
            peak_gflops: 48.0,
            watts: 125.0,
            dgemm_efficiency: 0.15,
        },
        Platform {
            name: "Nvidia Tesla C2050 (MAGMA)",
            class: PlatformClass::NvidiaGpu,
            peak_gflops: 515.0,
            watts: 238.0,
            dgemm_efficiency: 0.57,
        },
        Platform {
            name: "Nvidia GTX 480 (DP)",
            class: PlatformClass::NvidiaGpu,
            peak_gflops: 168.0,
            watts: 250.0,
            dgemm_efficiency: 0.40,
        },
        Platform {
            name: "ClearSpeed CSX700",
            class: PlatformClass::ClearSpeed,
            peak_gflops: 96.0,
            watts: 12.0,
            dgemm_efficiency: 0.78, // published sustained DGEMM ≈ 75 Gflops
        },
        Platform {
            name: "Altera Stratix-IV FPGA (LAPACKrc-class)",
            class: PlatformClass::Fpga,
            peak_gflops: 100.0,
            watts: 30.0,
            dgemm_efficiency: 0.85,
        },
    ]
}

/// Wrap the simulator's measured AE5 PE efficiency as a platform row.
pub fn pe_entry(measured_gflops_per_watt: f64) -> Platform {
    Platform {
        name: "This work: PE (AE5)",
        class: PlatformClass::ThisPe,
        peak_gflops: 0.2 * 7.0, // 0.2 GHz × 7 flops/cycle
        watts: measured_gflops_per_watt.recip() * 0.2 * 7.0 * 0.74, // implied
        dgemm_efficiency: 0.74,
    }
}

/// Fig-11(j) ratios: PE Gflops/W over each platform's.
pub fn fig11j_ratios(pe_gflops_per_watt: f64) -> Vec<(&'static str, f64)> {
    platform_db()
        .into_iter()
        .map(|p| (p.name, pe_gflops_per_watt / p.gflops_per_watt()))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn db_is_populated_and_sane() {
        let db = platform_db();
        assert!(db.len() >= 6);
        for p in &db {
            assert!(p.peak_gflops > 0.0 && p.watts > 0.0);
            assert!((0.0..=1.0).contains(&p.dgemm_efficiency), "{}", p.name);
        }
    }

    #[test]
    fn fig11j_pe_beats_everything() {
        // At the paper's 35.7 Gflops/W the PE wins against every platform.
        for (name, ratio) in fig11j_ratios(35.7) {
            assert!(ratio > 1.0, "{name} not beaten: {ratio:.2}");
        }
    }

    #[test]
    fn fig11j_ratio_bands() {
        // Paper: ~3x vs CSX700, ~10x vs FPGA, 7-139x vs GPUs, 40-140x vs
        // Intel/AMD CPUs (at 35.7 Gflops/W).
        let ratios: std::collections::HashMap<_, _> =
            fig11j_ratios(35.7).into_iter().collect();
        let csx = ratios["ClearSpeed CSX700"];
        assert!((2.0..8.0).contains(&csx), "CSX700 ratio {csx:.1}");
        let fpga = ratios["Altera Stratix-IV FPGA (LAPACKrc-class)"];
        assert!((5.0..20.0).contains(&fpga), "FPGA ratio {fpga:.1}");
        let c2050 = ratios["Nvidia Tesla C2050 (MAGMA)"];
        assert!((7.0..139.0).contains(&c2050), "C2050 ratio {c2050:.1}");
        let hw = ratios["Intel Core i7-4770 (Haswell)"];
        assert!((40.0..400.0).contains(&hw), "Haswell ratio {hw:.1}");
    }

    #[test]
    fn pe_entry_round_trips_efficiency() {
        let p = pe_entry(35.7);
        assert!((p.gflops_per_watt() - 35.7).abs() < 0.5);
    }
}
