//! Analytical multicore CPU model — regenerates Fig 2(a)–(f) and the CPU
//! bars of Fig 2(h)/(i).
//!
//! The paper's Fig-2 curves are produced by Netlib DGEMM/DGEMV compiled
//! three ways (gcc -O3; icc; icc -mavx) on Haswell/Bulldozer. The curve
//! mechanics are: a base CPI set by the scalar/vector issue width, plus
//! cache-miss stalls that kick in when the working set leaves each level.
//! We reproduce exactly that: instruction counts from the loop nest,
//! vectorization/FMA factors from the compiler setup, and miss counts from
//! the reuse-distance model cross-validated against the trace-driven cache
//! simulator in [`super::cache`] (test `analytic_matches_trace`).

use super::cache::{trace_dgemm_jki, trace_dgemv, CacheHierarchy};

/// Compiler/ISA setups of Fig 2 (c)–(f).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CompilerSetup {
    /// gfortran/gcc -O3: scalar SSE, no FMA.
    Gcc,
    /// icc: better scheduling, partial vectorization.
    Icc,
    /// icc -mavx: 4-wide AVX + FMA (halves the instruction count — the
    /// paper's VTune observation in §3.2).
    IccAvx,
}

impl CompilerSetup {
    pub fn name(self) -> &'static str {
        match self {
            CompilerSetup::Gcc => "gcc -O3",
            CompilerSetup::Icc => "icc",
            CompilerSetup::IccAvx => "icc -mavx",
        }
    }

    /// Flops per arithmetic instruction (vector width × FMA fusion).
    fn flops_per_instr(self) -> f64 {
        match self {
            CompilerSetup::Gcc => 1.0,
            CompilerSetup::Icc => 1.33, // partial SSE2 vectorization
            CompilerSetup::IccAvx => 4.0, // 256-bit AVX, FMA-fused mul+add
        }
    }

    /// Non-arithmetic instruction overhead per flop (loads, address math,
    /// loop control) — what icc scheduling reduces.
    fn overhead_instr_per_flop(self) -> f64 {
        match self {
            CompilerSetup::Gcc => 0.4,
            CompilerSetup::Icc => 0.3,
            CompilerSetup::IccAvx => 0.2,
        }
    }
}

/// A modelled CPU (Fig 2 uses Haswell and Bulldozer).
#[derive(Debug, Clone)]
pub struct CpuModel {
    pub name: &'static str,
    pub clock_ghz: f64,
    /// Peak double-precision Gflops (per socket, all cores) — Fig 2 quotes
    /// 48 Gflops peak for the test machines.
    pub peak_gflops: f64,
    /// Sustained instructions-per-cycle of the scalar pipeline.
    pub base_ipc: f64,
    /// Effective cost per cache line fetched by a *stride-1 stream* from
    /// L2 / L3 / DRAM, after hardware prefetching has hidden most of the
    /// raw latency (the jki reference DGEMM is fully streaming).
    pub l2_line_cost: f64,
    pub l3_line_cost: f64,
    pub mem_line_cost: f64,
    /// Per-line cost for the latency-exposed DGEMV stream (prefetchers
    /// help less: the y read-modify-write interleaves).
    pub mem_line_cost_gemv: f64,
    /// L1/L2/L3 capacities in f64 words (for the analytical miss model).
    pub l1_words: usize,
    pub l2_words: usize,
    pub l3_words: usize,
    /// Package TDP in watts (Fig 2(i) divides by this).
    pub tdp_watts: f64,
}

impl CpuModel {
    /// Intel Haswell desktop part (i7-4770-class): 3.4 GHz, 48 DP Gflops,
    /// 84 W TDP.
    pub fn haswell() -> Self {
        Self {
            name: "Intel Haswell",
            clock_ghz: 3.4,
            peak_gflops: 48.0,
            base_ipc: 2.4,
            l2_line_cost: 2.0,
            l3_line_cost: 3.0,
            mem_line_cost: 4.0,
            mem_line_cost_gemv: 13.0,
            l1_words: 32 * 1024 / 8,
            l2_words: 256 * 1024 / 8,
            l3_words: 8 * 1024 * 1024 / 8,
            tdp_watts: 84.0,
        }
    }

    /// AMD Bulldozer (FX-8150-class): 3.6 GHz, 48 DP Gflops, 125 W TDP.
    pub fn bulldozer() -> Self {
        Self {
            name: "AMD Bulldozer",
            clock_ghz: 3.6,
            peak_gflops: 48.0,
            base_ipc: 2.0,
            l2_line_cost: 3.0,
            l3_line_cost: 4.5,
            mem_line_cost: 5.0,
            mem_line_cost_gemv: 15.0,
            l1_words: 16 * 1024 / 8,
            l2_words: 2 * 1024 * 1024 / 8,
            l3_words: 8 * 1024 * 1024 / 8,
            tdp_watts: 125.0,
        }
    }
}

/// One modelled run: CPI/Gflops for a routine, size and compiler setup.
#[derive(Debug, Clone)]
pub struct CpuRun {
    pub n: usize,
    pub setup: CompilerSetup,
    pub instructions: f64,
    pub cycles: f64,
    pub flops: f64,
}

impl CpuRun {
    /// Cycles per instruction — Fig 2(a)/(c)/(e). (The paper notes CPI is a
    /// misleading metric once FMA halves the instruction count; Fig 2
    /// reports it anyway, and so do we.)
    pub fn cpi(&self) -> f64 {
        self.cycles / self.instructions
    }

    /// Cycles per flop (eq. 1) — the paper's corrected metric.
    pub fn cpf(&self) -> f64 {
        self.cycles / self.flops
    }

    pub fn gflops(&self, cpu: &CpuModel) -> f64 {
        // cycles / (GHz·1e9) seconds → Gflops = flops·GHz / cycles.
        self.flops * cpu.clock_ghz / self.cycles
    }

    pub fn pct_peak(&self, cpu: &CpuModel) -> f64 {
        100.0 * self.gflops(cpu) / cpu.peak_gflops
    }

    pub fn gflops_per_watt(&self, cpu: &CpuModel) -> f64 {
        self.gflops(cpu) / cpu.tdp_watts
    }
}

/// Analytical line-fetch count for the jki reference DGEMM, with the level
/// the stream runs from: per j-sweep, A (n² words) is re-streamed and only
/// survives in a level that holds the working set. Returns (lines, cost
/// per line).
fn gemm_stream(cpu: &CpuModel, n: usize) -> (f64, f64) {
    let n2 = (n * n) as f64;
    let per_line = 8.0; // f64 words per 64-byte line
    let compulsory = 3.0 * n2 / per_line;
    let resweeps = (n as f64 - 1.0) * n2 / per_line; // A re-read per column sweep
    let ws = n * n + 4 * n; // resident working set (A + active columns)
    if ws <= cpu.l1_words {
        (compulsory, cpu.l2_line_cost) // only compulsory traffic
    } else if ws <= cpu.l2_words {
        (compulsory + resweeps, cpu.l2_line_cost)
    } else if ws <= cpu.l3_words {
        (compulsory + resweeps, cpu.l3_line_cost)
    } else {
        (compulsory + resweeps, cpu.mem_line_cost)
    }
}

/// Model a DGEMM run (Fig 2 a–f).
pub fn model_dgemm(cpu: &CpuModel, n: usize, setup: CompilerSetup) -> CpuRun {
    let flops = 2.0 * (n as f64).powi(3);
    let arith = flops / setup.flops_per_instr();
    let overhead = flops * setup.overhead_instr_per_flop();
    let instructions = arith + overhead;
    let (lines, cost) = gemm_stream(cpu, n);
    let cycles = instructions / cpu.base_ipc + lines * cost;
    CpuRun { n, setup, instructions, cycles, flops }
}

/// Model a DGEMV run (Fig 2 g/h): A is streamed exactly once — the routine
/// is bandwidth-bound for any n that leaves cache.
pub fn model_dgemv(cpu: &CpuModel, n: usize, setup: CompilerSetup) -> CpuRun {
    let flops = 2.0 * (n as f64).powi(2);
    let arith = flops / setup.flops_per_instr();
    let overhead = flops * setup.overhead_instr_per_flop();
    let instructions = arith + overhead;
    let lines = (n * n) as f64 / 8.0; // A streamed once
    let ws = n * n + 4 * n;
    let cost = if ws <= cpu.l1_words {
        0.0
    } else if ws <= cpu.l2_words {
        cpu.l2_line_cost
    } else if ws <= cpu.l3_words {
        cpu.l3_line_cost + 2.0
    } else {
        cpu.mem_line_cost_gemv
    };
    let cycles = instructions / cpu.base_ipc + lines * cost;
    CpuRun { n, setup, instructions, cycles, flops }
}

/// Cross-validation helper: trace-driven L1 misses for small n (tests).
pub fn traced_gemm_l1_misses(n: usize) -> u64 {
    let mut h = CacheHierarchy::haswell();
    let (_, m1, _) = trace_dgemm_jki(n, &mut h);
    m1
}

/// Cross-validation helper for GEMV.
pub fn traced_gemv_l1_misses(n: usize) -> u64 {
    let mut h = CacheHierarchy::haswell();
    let (_, m1, _) = trace_dgemv(n, &mut h);
    m1
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2ab_gcc_saturates_low() {
        // Fig 2(b): gcc DGEMM lands near 10-11% of peak for large n.
        let cpu = CpuModel::haswell();
        let r = model_dgemm(&cpu, 2000, CompilerSetup::Gcc);
        let pct = r.pct_peak(&cpu);
        assert!((5.0..16.0).contains(&pct), "gcc DGEMM %peak {pct:.1}");
        // Fig 2(a): CPI saturates around 0.85.
        assert!((0.55..1.2).contains(&r.cpi()), "gcc CPI {:.2}", r.cpi());
    }

    #[test]
    fn fig2ef_avx_reaches_15_17_pct() {
        let cpu = CpuModel::haswell();
        let r = model_dgemm(&cpu, 2000, CompilerSetup::IccAvx);
        let pct = r.pct_peak(&cpu);
        assert!((13.0..20.0).contains(&pct), "icc+avx DGEMM %peak {pct:.1}");
    }

    #[test]
    fn compiler_ladder_improves_gflops() {
        let cpu = CpuModel::haswell();
        let g = model_dgemm(&cpu, 1000, CompilerSetup::Gcc);
        let i = model_dgemm(&cpu, 1000, CompilerSetup::Icc);
        let v = model_dgemm(&cpu, 1000, CompilerSetup::IccAvx);
        assert!(g.gflops(&cpu) < i.gflops(&cpu));
        assert!(i.gflops(&cpu) < v.gflops(&cpu));
    }

    #[test]
    fn avx_raises_cpi_while_raising_gflops() {
        // §3.2: -mavx halves instructions, so VTune CPI *rises* even though
        // Gflops improve — the reason the paper defines CPF.
        let cpu = CpuModel::haswell();
        let i = model_dgemm(&cpu, 2000, CompilerSetup::Icc);
        let v = model_dgemm(&cpu, 2000, CompilerSetup::IccAvx);
        assert!(v.instructions < i.instructions);
        assert!(v.cpi() > i.cpi(), "CPI: icc {:.2} avx {:.2}", i.cpi(), v.cpi());
        assert!(v.gflops(&cpu) > i.gflops(&cpu));
        assert!(v.cpf() < i.cpf(), "CPF must still improve");
    }

    #[test]
    fn cache_knee_visible() {
        // Small matrices (fit in cache) achieve better CPF than large ones.
        let cpu = CpuModel::haswell();
        let small = model_dgemm(&cpu, 32, CompilerSetup::Gcc);
        let large = model_dgemm(&cpu, 1500, CompilerSetup::Gcc);
        assert!(small.cpf() < large.cpf());
    }

    #[test]
    fn dgemv_far_below_dgemm() {
        // Fig 2(h): DGEMV ≈ 5% of peak vs DGEMM 15-17% (with AVX).
        let cpu = CpuModel::haswell();
        let mv = model_dgemv(&cpu, 4000, CompilerSetup::IccAvx);
        let mm = model_dgemm(&cpu, 4000, CompilerSetup::IccAvx);
        let pv = mv.pct_peak(&cpu);
        assert!((2.0..9.0).contains(&pv), "DGEMV %peak {pv:.1}");
        assert!(mm.pct_peak(&cpu) > 2.0 * pv);
    }

    #[test]
    fn fig2i_gflops_per_watt_range() {
        // Fig 2(i): legacy BLAS lands at 0.02–0.25 Gflops/W.
        let cpu = CpuModel::haswell();
        let mm = model_dgemm(&cpu, 2000, CompilerSetup::IccAvx);
        let mv = model_dgemv(&cpu, 4000, CompilerSetup::Gcc);
        assert!((0.02..0.30).contains(&mm.gflops_per_watt(&cpu)));
        assert!((0.005..0.10).contains(&mv.gflops_per_watt(&cpu)));
    }

    #[test]
    fn analytic_matches_trace() {
        // Cross-validate the analytical line-fetch model against the
        // trace-driven cache simulator at a small and a large point.
        let cpu = CpuModel::haswell();
        for n in [16usize, 96] {
            let traced = traced_gemm_l1_misses(n) as f64;
            let (analytic, _) = super::gemm_stream(&cpu, n);
            let ratio = traced / analytic;
            assert!(
                (0.3..3.0).contains(&ratio),
                "n={n}: traced {traced} vs analytic {analytic} (ratio {ratio:.2})"
            );
        }
    }
}
