//! Shared utilities: deterministic PRNG, dense matrix container, assertions.
//!
//! The paper uses Octave-generated random input matrices (§5.5). The PE's
//! latency is data-independent, so any deterministic generator preserves the
//! experiments; we use xorshift for reproducibility without external deps.

pub mod json;
pub mod mat;
pub mod rng;

pub use mat::Mat;
pub use rng::XorShift64;

/// Maximum absolute elementwise difference between two slices.
pub fn max_abs_diff(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len(), "length mismatch: {} vs {}", a.len(), b.len());
    a.iter()
        .zip(b.iter())
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f64::max)
}

/// Relative Frobenius-norm error ||a - b||_F / max(||b||_F, eps).
pub fn rel_fro_error(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let mut num = 0.0;
    let mut den = 0.0;
    for (x, y) in a.iter().zip(b.iter()) {
        num += (x - y) * (x - y);
        den += y * y;
    }
    (num.sqrt()) / den.sqrt().max(1e-300)
}

/// Assert two f64 slices are close within `tol` (absolute + relative blend).
#[track_caller]
pub fn assert_allclose(a: &[f64], b: &[f64], tol: f64) {
    assert_eq!(a.len(), b.len(), "length mismatch");
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        let scale = 1.0f64.max(y.abs());
        assert!(
            (x - y).abs() <= tol * scale,
            "element {i}: {x} vs {y} (tol {tol}, scaled {})",
            tol * scale
        );
    }
}

/// Round `n` up to the next multiple of `m`.
pub const fn round_up(n: usize, m: usize) -> usize {
    n.div_ceil(m) * m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_up_basics() {
        assert_eq!(round_up(20, 4), 20);
        assert_eq!(round_up(21, 4), 24);
        assert_eq!(round_up(1, 4), 4);
        assert_eq!(round_up(0, 4), 0);
    }

    #[test]
    fn max_abs_diff_basics() {
        assert_eq!(max_abs_diff(&[1.0, 2.0], &[1.0, 2.5]), 0.5);
        assert_eq!(max_abs_diff(&[], &[]), 0.0);
    }

    #[test]
    fn rel_fro_error_zero_for_equal() {
        let v = [1.0, -2.0, 3.0];
        assert_eq!(rel_fro_error(&v, &v), 0.0);
    }

    #[test]
    #[should_panic]
    fn allclose_detects_mismatch() {
        assert_allclose(&[1.0], &[1.1], 1e-6);
    }
}
