//! Dense column-major f64 matrix, the container shared by the host BLAS,
//! the codegen address generators, and the co-simulation coordinator.
//!
//! Column-major matches Fortran/Netlib BLAS conventions used by the paper.

use crate::util::rng::XorShift64;

/// Dense column-major matrix of f64.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Mat {
    /// Zero matrix of shape (rows, cols).
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity matrix of order n.
    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Matrix from a column-major slice.
    pub fn from_col_major(rows: usize, cols: usize, data: &[f64]) -> Self {
        assert_eq!(data.len(), rows * cols, "data length != rows*cols");
        Self { rows, cols, data: data.to_vec() }
    }

    /// Matrix from a row-major slice (transposes into column-major storage).
    pub fn from_row_major(rows: usize, cols: usize, data: &[f64]) -> Self {
        assert_eq!(data.len(), rows * cols);
        let mut m = Self::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m[(i, j)] = data[i * cols + j];
            }
        }
        m
    }

    /// Random matrix with entries in [-1, 1), deterministic in `seed`.
    pub fn random(rows: usize, cols: usize, seed: u64) -> Self {
        let mut rng = XorShift64::new(seed);
        let mut m = Self::zeros(rows, cols);
        rng.fill(&mut m.data);
        m
    }

    /// Random symmetric positive-definite matrix (A = B·Bᵀ + n·I).
    pub fn random_spd(n: usize, seed: u64) -> Self {
        let b = Self::random(n, n, seed);
        let mut a = Self::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += b[(i, k)] * b[(j, k)];
                }
                a[(i, j)] = s;
            }
            a[(i, i)] += n as f64;
        }
        a
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Leading dimension of the column-major storage (== rows).
    pub fn ld(&self) -> usize {
        self.rows
    }

    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    pub fn as_mut_slice(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Column-major linear index of (i, j).
    #[inline]
    pub fn idx(&self, i: usize, j: usize) -> usize {
        debug_assert!(i < self.rows && j < self.cols, "({i},{j}) out of {}x{}", self.rows, self.cols);
        j * self.rows + i
    }

    /// Borrow column j as a slice.
    pub fn col(&self, j: usize) -> &[f64] {
        let s = j * self.rows;
        &self.data[s..s + self.rows]
    }

    /// Mutably borrow column j.
    pub fn col_mut(&mut self, j: usize) -> &mut [f64] {
        let s = j * self.rows;
        &mut self.data[s..s + self.rows]
    }

    /// Copy row i out (strided gather).
    pub fn row(&self, i: usize) -> Vec<f64> {
        (0..self.cols).map(|j| self[(i, j)]).collect()
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    /// Copy of the (br, bc) sub-block of shape (h, w) starting at (r0, c0).
    pub fn block(&self, r0: usize, c0: usize, h: usize, w: usize) -> Mat {
        assert!(r0 + h <= self.rows && c0 + w <= self.cols, "block out of range");
        let mut b = Mat::zeros(h, w);
        for j in 0..w {
            for i in 0..h {
                b[(i, j)] = self[(r0 + i, c0 + j)];
            }
        }
        b
    }

    /// Write a block back at (r0, c0).
    pub fn set_block(&mut self, r0: usize, c0: usize, b: &Mat) {
        assert!(r0 + b.rows <= self.rows && c0 + b.cols <= self.cols);
        for j in 0..b.cols {
            for i in 0..b.rows {
                self[(r0 + i, c0 + j)] = b[(i, j)];
            }
        }
    }

    /// Zero-pad (or keep) to shape (r, c) — used for 4×4-block alignment.
    pub fn padded(&self, r: usize, c: usize) -> Mat {
        assert!(r >= self.rows && c >= self.cols);
        let mut p = Mat::zeros(r, c);
        p.set_block(0, 0, self);
        p
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    /// Row-major copy of the data (for XLA literals, which default row-major).
    pub fn to_row_major(&self) -> Vec<f64> {
        let mut v = Vec::with_capacity(self.data.len());
        for i in 0..self.rows {
            for j in 0..self.cols {
                v.push(self[(i, j)]);
            }
        }
        v
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[j * self.rows + i]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[j * self.rows + i]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::assert_allclose;

    #[test]
    fn eye_diag() {
        let m = Mat::eye(3);
        assert_eq!(m[(1, 1)], 1.0);
        assert_eq!(m[(0, 1)], 0.0);
    }

    #[test]
    fn row_major_round_trip() {
        let m = Mat::from_row_major(2, 3, &[1., 2., 3., 4., 5., 6.]);
        assert_eq!(m[(0, 0)], 1.0);
        assert_eq!(m[(0, 2)], 3.0);
        assert_eq!(m[(1, 0)], 4.0);
        assert_eq!(m.to_row_major(), vec![1., 2., 3., 4., 5., 6.]);
    }

    #[test]
    fn col_major_layout() {
        let m = Mat::from_col_major(2, 2, &[1., 2., 3., 4.]);
        assert_eq!(m[(0, 0)], 1.0);
        assert_eq!(m[(1, 0)], 2.0);
        assert_eq!(m[(0, 1)], 3.0);
        assert_eq!(m.col(1), &[3., 4.]);
    }

    #[test]
    fn transpose_involution() {
        let m = Mat::random(5, 3, 11);
        let t = m.transpose().transpose();
        assert_allclose(m.as_slice(), t.as_slice(), 0.0);
    }

    #[test]
    fn block_round_trip() {
        let m = Mat::random(8, 8, 3);
        let b = m.block(4, 0, 4, 4);
        let mut m2 = Mat::zeros(8, 8);
        m2.set_block(4, 0, &b);
        assert_eq!(m2[(4, 0)], m[(4, 0)]);
        assert_eq!(m2[(7, 3)], m[(7, 3)]);
        assert_eq!(m2[(0, 0)], 0.0);
    }

    #[test]
    fn padding_preserves_content() {
        let m = Mat::random(3, 3, 5);
        let p = m.padded(4, 4);
        assert_eq!(p.rows(), 4);
        assert_eq!(p[(2, 2)], m[(2, 2)]);
        assert_eq!(p[(3, 3)], 0.0);
    }

    #[test]
    fn spd_is_symmetric() {
        let a = Mat::random_spd(6, 2);
        for i in 0..6 {
            for j in 0..6 {
                assert!((a[(i, j)] - a[(j, i)]).abs() < 1e-12);
            }
        }
    }
}
