//! Minimal JSON string escaping — the one implementation shared by the
//! `hot_paths` bench writer and the [`crate::obs::export`] JSONL /
//! Chrome-trace emitters.
//!
//! Only escaping lives here (the crate stays serde-free); emitters build
//! their objects by hand and route every string value through [`escape`].

/// Escape `s` for inclusion inside a JSON string literal (no surrounding
/// quotes added). Handles the characters RFC 8259 requires: `"`  `\` and
/// control characters below U+0020 (as `\uXXXX`).
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plain_strings_pass_through() {
        assert_eq!(escape("dgemm n=64"), "dgemm n=64");
        assert_eq!(escape(""), "");
    }

    #[test]
    fn quotes_and_backslashes_are_escaped() {
        assert_eq!(escape("a\"b"), "a\\\"b");
        assert_eq!(escape("a\\b"), "a\\\\b");
        assert_eq!(escape("\\\""), "\\\\\\\"");
    }

    #[test]
    fn control_characters_become_unicode_escapes() {
        assert_eq!(escape("a\nb"), "a\\u000ab");
        assert_eq!(escape("\t"), "\\u0009");
        assert_eq!(escape("\u{0}"), "\\u0000");
        assert_eq!(escape("\u{1f}"), "\\u001f");
    }

    #[test]
    fn non_ascii_is_left_verbatim() {
        // RFC 8259 allows raw UTF-8 above U+001F; keep bytes as-is.
        assert_eq!(escape("µs → cycles"), "µs → cycles");
    }
}
