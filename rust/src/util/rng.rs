//! Deterministic xorshift64* PRNG — replaces the paper's Octave matrix
//! generator (§5.5) with a dependency-free, reproducible source.

/// xorshift64* generator. Deterministic, fast, good enough for test matrices.
#[derive(Debug, Clone)]
pub struct XorShift64 {
    state: u64,
}

impl XorShift64 {
    /// Create a generator from a non-zero seed (zero is mapped to a fixed odd
    /// constant — xorshift is degenerate at state 0).
    pub fn new(seed: u64) -> Self {
        Self { state: if seed == 0 { 0x9E3779B97F4A7C15 } else { seed } }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform f64 in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform usize in [0, n). Panics if n == 0.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Fill a slice with uniform values in [-1, 1).
    pub fn fill(&mut self, out: &mut [f64]) {
        for v in out.iter_mut() {
            *v = self.range_f64(-1.0, 1.0);
        }
    }

    /// A fresh vector of `n` uniform values in [-1, 1).
    pub fn vec(&mut self, n: usize) -> Vec<f64> {
        let mut v = vec![0.0; n];
        self.fill(&mut v);
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = XorShift64::new(42);
        let mut b = XorShift64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = XorShift64::new(1);
        let mut b = XorShift64::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = XorShift64::new(7);
        for _ in 0..1000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn zero_seed_not_degenerate() {
        let mut r = XorShift64::new(0);
        let a = r.next_u64();
        let b = r.next_u64();
        assert_ne!(a, 0);
        assert_ne!(a, b);
    }

    #[test]
    fn fill_covers_range() {
        let mut r = XorShift64::new(9);
        let v = r.vec(4096);
        assert!(v.iter().any(|&x| x < -0.5));
        assert!(v.iter().any(|&x| x > 0.5));
        assert!(v.iter().all(|&x| (-1.0..1.0).contains(&x)));
    }
}
