//! Fabric placement: location-aware job routing for the serving engine.
//!
//! The standalone NoC simulator ([`super::sim`]) models one parallel DGEMM
//! at a time; this module is the serving-side counterpart. A [`Fabric`] is
//! a b×b REDEFINE compute array plus its memory column whose tiles are
//! claimed one *job* at a time: every pool job the coordinator finalizes is
//! **placed** on a compute tile, its operands **stream from the memory
//! column over the modeled mesh** (contending on shared links via
//! [`LinkTraffic::transfer`]), its result streams back, and its completion
//! time becomes operand arrival + PE compute + write-back instead of PE
//! cycles alone.
//!
//! Data-movement model (the striping the paper's memory column implies):
//! a tenant's working set is striped across the memory column, so a job on
//! tile `t` streams operands from the *same-row* memory tile
//! `memory_for_row(t.row)` — operand bandwidth scales with b. Results
//! consolidate in the tenant's **home region**: the write-back targets
//! `memory_for_row(home_row)`, so a tenant placed far from home pays for
//! the cross-region traffic honestly (the locality placer's job is to keep
//! that cheap without starving load balance).
//!
//! Placement policy is a scheduling decision ([`PlacePolicy`]):
//! * [`PlacePolicy::RoundRobin`] — a shared cursor walks the tiles
//!   row-major, ignoring both load and location;
//! * [`PlacePolicy::Locality`] — pick the tile minimizing
//!   `free_at + hops(tile, home_memory) · router_cycle`: load balance is
//!   the dominant term, and among near-idle tiles the placer prefers the
//!   tenant's home region so its write-back traffic stays short.
//!
//! Everything here is deterministic given the sequence of
//! [`Fabric::route_job`] calls: the coordinator calls it at *finalize*
//! time, which runs in strict submission order, so schedules (and the
//! per-link busy counts in [`FabricStats`]) are reproducible run to run
//! regardless of host worker interleaving.

use super::router::{LinkTraffic, RouterConfig};
use super::topology::{Coord, Topology};

/// Tile-placement policy for routed jobs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlacePolicy {
    /// Cursor walks compute tiles row-major; location-blind baseline.
    RoundRobin,
    /// Least-loaded tile with a home-region preference on near-ties.
    Locality,
}

impl PlacePolicy {
    /// Short name used in CLI parsing and bench keys.
    pub fn name(&self) -> &'static str {
        match self {
            PlacePolicy::RoundRobin => "round-robin",
            PlacePolicy::Locality => "locality",
        }
    }
}

/// Fabric configuration: array order + placement policy (+ link timing).
#[derive(Debug, Clone)]
pub struct FabricConfig {
    /// Compute-array order: b×b compute tiles plus a memory column.
    pub b: usize,
    /// Tile-placement policy.
    pub place: PlacePolicy,
    /// Router/link timing parameters.
    pub router: RouterConfig,
}

impl FabricConfig {
    /// A b×b fabric under the default locality placer and paper link
    /// timing.
    pub fn new(b: usize) -> Self {
        Self { b, place: PlacePolicy::Locality, router: RouterConfig::default() }
    }
}

/// One routed job's schedule on the fabric.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RoutedJob {
    /// Compute tile the job was placed on.
    pub tile: Coord,
    /// Cycle the operand stream left its memory tile.
    pub depart: u64,
    /// Cycle all operands had arrived (compute starts at
    /// `max(ready, tile free time)`).
    pub ready: u64,
    /// Cycle the result write-back completed.
    pub finish: u64,
    /// PE compute cycles the job burned on its tile — carried so the
    /// schedule is self-contained (`finish - depart - compute` bounds the
    /// job's communication + wait share).
    pub compute: u64,
}

/// Snapshot of fabric telemetry (see [`Fabric::stats`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FabricStats {
    /// Compute-array order.
    pub b: usize,
    /// Placement policy in force.
    pub place: PlacePolicy,
    /// Jobs routed so far.
    pub jobs_routed: u64,
    /// Completion cycle of the latest-finishing job (fabric makespan).
    pub makespan: u64,
    /// Total PE compute cycles across routed jobs.
    pub compute_cycles: u64,
    /// Total communication cycles (operand in-flight + write-back
    /// in-flight) across routed jobs.
    pub comm_cycles: u64,
    /// Busy cycles of the most-loaded link.
    pub max_link_busy: u64,
    /// Busy cycles summed over all links.
    pub total_link_busy: u64,
    /// Jobs placed per compute tile (row-major).
    pub tile_jobs: Vec<u64>,
    /// Per-directed-link busy cycles, sorted by (from, to) coordinate.
    pub link_busy: Vec<((Coord, Coord), u64)>,
}

impl FabricStats {
    /// Computation-to-communication ratio over everything routed so far
    /// (the Fig-12 regime indicator: below ~1 the fabric is comm-bound).
    pub fn compute_comm_ratio(&self) -> f64 {
        self.compute_cycles as f64 / (self.comm_cycles as f64).max(1.0)
    }
}

/// Location-aware routing state for the serving engine: tile occupancy +
/// link traffic of one modeled fabric, shared by every tenant attached to
/// an engine.
#[derive(Debug, Clone)]
pub struct Fabric {
    topo: Topology,
    rcfg: RouterConfig,
    policy: PlacePolicy,
    links: LinkTraffic,
    /// Per-compute-tile (row-major) cycle at which the tile's PE frees up.
    tile_free: Vec<u64>,
    /// Per-compute-tile routed-job count.
    tile_jobs: Vec<u64>,
    /// Round-robin cursor.
    cursor: usize,
    jobs_routed: u64,
    compute_cycles: u64,
    comm_cycles: u64,
    makespan: u64,
}

impl Fabric {
    pub fn new(cfg: &FabricConfig) -> Self {
        let topo = Topology::new(cfg.b);
        let tiles = topo.compute_tiles();
        Self {
            topo,
            rcfg: cfg.router.clone(),
            policy: cfg.place,
            links: LinkTraffic::new(),
            tile_free: vec![0; tiles],
            tile_jobs: vec![0; tiles],
            cursor: 0,
            jobs_routed: 0,
            compute_cycles: 0,
            comm_cycles: 0,
            makespan: 0,
        }
    }

    /// Rows of the compute array (used to assign tenant home rows).
    pub fn rows(&self) -> usize {
        self.topo.rows()
    }

    /// Pick a compute tile for the next job under the configured policy.
    fn place(&mut self, home_row: usize) -> usize {
        let b = self.topo.rows();
        match self.policy {
            PlacePolicy::RoundRobin => {
                let idx = self.cursor;
                self.cursor = (self.cursor + 1) % self.tile_free.len();
                idx
            }
            PlacePolicy::Locality => {
                let home_mem = self.topo.memory_for_row(home_row.min(b - 1));
                let mut best = 0usize;
                let mut best_score = u64::MAX;
                let mut best_hops = usize::MAX;
                for (idx, &free) in self.tile_free.iter().enumerate() {
                    let tile = Coord::new(idx / b, idx % b);
                    let hops = self.topo.hops(tile, home_mem);
                    let score = free.saturating_add(hops as u64 * self.rcfg.router_cycle);
                    if score < best_score || (score == best_score && hops < best_hops) {
                        best = idx;
                        best_score = score;
                        best_hops = hops;
                    }
                }
                best
            }
        }
    }

    /// Place one job and price its data movement on the mesh.
    ///
    /// Operands (`operand_words`) stream from the placed tile's same-row
    /// memory tile; after `compute_cycles` on the tile's PE the result
    /// (`result_words`) streams back to the memory tile of the tenant's
    /// `home_row`. Returns the job's schedule; `finish` is the absolute
    /// fabric cycle the result lands — the routed replacement for "PE
    /// cycles alone".
    pub fn route_job(
        &mut self,
        home_row: usize,
        operand_words: u64,
        compute_cycles: u64,
        result_words: u64,
    ) -> RoutedJob {
        let b = self.topo.rows();
        let idx = self.place(home_row);
        let tile = Coord::new(idx / b, idx % b);
        let src = self.topo.memory_for_row(tile.row);
        let home_mem = self.topo.memory_for_row(home_row.min(b - 1));

        // Operand stream: issue as soon as the tile is chosen; the link
        // reservation itself serializes contending streams.
        let (depart, arrive) =
            self.links.transfer(&self.topo, &self.rcfg, src, tile, operand_words, 0);
        // Compute waits for both the operands and the tile's PE.
        let ready = arrive.max(self.tile_free[idx]);
        let compute_end = ready + compute_cycles;
        // Result write-back to the tenant's home region.
        let (wb_depart, finish) = self.links.transfer(
            &self.topo,
            &self.rcfg,
            tile,
            home_mem,
            result_words,
            compute_end,
        );
        self.tile_free[idx] = compute_end;
        self.tile_jobs[idx] += 1;
        self.jobs_routed += 1;
        self.compute_cycles += compute_cycles;
        self.comm_cycles += (arrive - depart) + (finish - wb_depart);
        self.makespan = self.makespan.max(finish);
        RoutedJob { tile, depart, ready, finish, compute: compute_cycles }
    }

    /// Telemetry snapshot.
    pub fn stats(&self) -> FabricStats {
        FabricStats {
            b: self.topo.rows(),
            place: self.policy,
            jobs_routed: self.jobs_routed,
            makespan: self.makespan,
            compute_cycles: self.compute_cycles,
            comm_cycles: self.comm_cycles,
            max_link_busy: self.links.max_link_busy(),
            total_link_busy: self.links.total_busy(),
            tile_jobs: self.tile_jobs.clone(),
            link_busy: self.links.link_busy(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fabric(b: usize, place: PlacePolicy) -> Fabric {
        Fabric::new(&FabricConfig { place, ..FabricConfig::new(b) })
    }

    #[test]
    fn round_robin_cycles_all_tiles() {
        let mut f = fabric(2, PlacePolicy::RoundRobin);
        for _ in 0..8 {
            f.route_job(0, 16, 100, 4);
        }
        assert_eq!(f.stats().tile_jobs, vec![2, 2, 2, 2]);
    }

    #[test]
    fn locality_spreads_load_and_prefers_home_on_ties() {
        let mut f = fabric(2, PlacePolicy::Locality);
        // First placement: all tiles idle, home row 0 → nearest tile to
        // mem(0) = (0,2) is (0,1).
        let j = f.route_job(0, 16, 1000, 4);
        assert_eq!(j.tile, Coord::new(0, 1));
        // Three more jobs: load balance dominates, so all four tiles end
        // up claimed once before any tile is reused.
        for _ in 0..3 {
            f.route_job(0, 16, 1000, 4);
        }
        assert_eq!(f.stats().tile_jobs, vec![1, 1, 1, 1]);
    }

    #[test]
    fn routed_schedule_orders_phases() {
        let mut f = fabric(2, PlacePolicy::Locality);
        let j = f.route_job(1, 64, 500, 16);
        assert!(j.ready >= j.depart);
        assert!(j.finish > j.ready + 500, "finish must include write-back");
        let s = f.stats();
        assert_eq!(s.jobs_routed, 1);
        assert_eq!(s.compute_cycles, 500);
        assert!(s.comm_cycles > 0);
        assert!(s.makespan >= j.finish);
    }

    #[test]
    fn deterministic_given_call_sequence() {
        let run = |place| {
            let mut f = fabric(3, place);
            for i in 0..32u64 {
                f.route_job((i % 3) as usize, 64 + i, 200 + 7 * i, 16);
            }
            let s = f.stats();
            (s.makespan, s.max_link_busy, s.link_busy, s.tile_jobs)
        };
        assert_eq!(run(PlacePolicy::Locality), run(PlacePolicy::Locality));
        assert_eq!(run(PlacePolicy::RoundRobin), run(PlacePolicy::RoundRobin));
    }

    #[test]
    fn bigger_fabric_shortens_makespan_under_load() {
        let makespan = |b| {
            let mut f = fabric(b, PlacePolicy::Locality);
            for i in 0..64u64 {
                f.route_job((i % 2) as usize, 256, 5_000, 64);
            }
            f.stats().makespan
        };
        let (m1, m2, m4) = (makespan(1), makespan(2), makespan(4));
        assert!(m2 < m1, "2x2 must beat 1x1: {m2} vs {m1}");
        assert!(m4 < m2, "4x4 must beat 2x2: {m4} vs {m2}");
    }

    #[test]
    fn zero_word_route_is_compute_only_on_idle_fabric() {
        let mut f = fabric(2, PlacePolicy::Locality);
        let j = f.route_job(0, 0, 100, 0);
        assert_eq!((j.depart, j.ready), (0, 0));
        assert_eq!(j.finish, 100);
        assert_eq!(f.stats().comm_cycles, 0);
    }
}
