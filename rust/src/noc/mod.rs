//! REDEFINE CGRA simulator: a b×b compute-tile array plus a memory column,
//! connected by single-cycle routers on a 2-D mesh (§5.5, Fig 11(k)).
//!
//! Each compute tile hosts one PE as its Custom Function Unit; the last
//! column of tiles stores the input/output matrices (the paper's "last
//! column is used for storing input and output matrices"). Parallel DGEMM
//! decomposes the output into (n/b)×(n/b) blocks, one per tile; each tile
//! streams its A row-panel and B column-panel from the memory column,
//! computes on its PE, and writes its C block back (Fig 12).

pub mod placement;
pub mod router;
pub mod sim;
pub mod topology;

pub use placement::{Fabric, FabricConfig, FabricStats, PlacePolicy, RoutedJob};
pub use router::{LinkTraffic, RouterConfig};
pub use sim::{parallel_dgemm, parallel_dgemm_cfg, NocRunReport, TileReport};
pub use topology::{Coord, Topology};
