//! Router/link timing model of the REDEFINE NoC.
//!
//! ReconNoC [13] is a single-cycle router: one cycle per hop per flit, with
//! wormhole flow through 64-bit links. A transfer of `words` f64 words from
//! tile S to tile D under XY routing costs
//!
//! ```text
//! latency = hops · router_cycle + (words · flits_per_word − 1) · link_cycle
//! ```
//!
//! (head latency + serialization), and occupies every traversed link for
//! the serialization time — the contention the Fig-12 small-matrix regime
//! is dominated by. Link occupancy is tracked per directed link.

use super::topology::{Coord, Topology};
use std::collections::HashMap;

/// Router/link timing parameters.
#[derive(Debug, Clone)]
pub struct RouterConfig {
    /// Cycles per hop for the head flit (ReconNoC: 1).
    pub router_cycle: u64,
    /// Cycles per flit on a link (64-bit link, one f64 word per flit).
    pub link_cycle: u64,
    /// Memory-tile service cycles per word (SRAM bank read/write).
    pub mem_service_cycle: u64,
}

impl Default for RouterConfig {
    fn default() -> Self {
        Self { router_cycle: 1, link_cycle: 1, mem_service_cycle: 1 }
    }
}

/// Per-directed-link busy-time bookkeeping.
#[derive(Debug, Clone, Default)]
pub struct LinkTraffic {
    /// (from, to) → cycle at which the link becomes free.
    free_at: HashMap<(Coord, Coord), u64>,
    /// (from, to) → total busy cycles (utilization reporting).
    busy: HashMap<(Coord, Coord), u64>,
}

impl LinkTraffic {
    pub fn new() -> Self {
        Self::default()
    }

    /// Schedule a `words`-long transfer from `src` to `dst` starting no
    /// earlier than `start`; returns (departure, arrival) cycles.
    ///
    /// The transfer claims each link of the XY path in sequence; contention
    /// delays departure until every link is free (a conservative circuit-
    /// style reservation — wormhole with backpressure behaves likewise
    /// under saturation).
    pub fn transfer(
        &mut self,
        topo: &Topology,
        cfg: &RouterConfig,
        src: Coord,
        dst: Coord,
        words: u64,
        start: u64,
    ) -> (u64, u64) {
        if src == dst || words == 0 {
            return (start, start + words * cfg.mem_service_cycle);
        }
        let path = topo.xy_path(src, dst);
        let ser = words * cfg.link_cycle;
        // Find the earliest departure at which all links are free.
        let mut depart = start;
        loop {
            let mut pushed = depart;
            for w in path.windows(2) {
                let key = (w[0], w[1]);
                let free = self.free_at.get(&key).copied().unwrap_or(0);
                if free > pushed {
                    pushed = free;
                }
            }
            if pushed == depart {
                break;
            }
            depart = pushed;
        }
        // Claim the links.
        for w in path.windows(2) {
            let key = (w[0], w[1]);
            self.free_at.insert(key, depart + ser);
            *self.busy.entry(key).or_insert(0) += ser;
        }
        let hops = (path.len() - 1) as u64;
        let arrival = depart + hops * cfg.router_cycle + ser.saturating_sub(1)
            + words * cfg.mem_service_cycle;
        (depart, arrival)
    }

    /// Total busy cycles of the most-loaded link.
    pub fn max_link_busy(&self) -> u64 {
        self.busy.values().copied().max().unwrap_or(0)
    }

    /// Sum of busy cycles over all links.
    pub fn total_busy(&self) -> u64 {
        self.busy.values().sum()
    }

    /// Per-directed-link busy cycles, sorted by (from, to) coordinate so
    /// the listing is deterministic (utilization telemetry).
    pub fn link_busy(&self) -> Vec<((Coord, Coord), u64)> {
        let mut v: Vec<_> = self.busy.iter().map(|(&k, &b)| (k, b)).collect();
        v.sort_unstable_by_key(|&(k, _)| k);
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_latency_scales_with_hops_and_words() {
        let topo = Topology::new(2);
        let cfg = RouterConfig::default();
        let mut t = LinkTraffic::new();
        let (d1, a1) =
            t.transfer(&topo, &cfg, Coord::new(0, 2), Coord::new(0, 0), 16, 0);
        assert_eq!(d1, 0);
        // 2 hops + 16 flits + service.
        assert!(a1 >= 2 + 15 + 16, "arrival too early: {a1}");
        let mut t2 = LinkTraffic::new();
        let (_, a2) =
            t2.transfer(&topo, &cfg, Coord::new(0, 2), Coord::new(1, 0), 16, 0);
        assert!(a2 > a1, "more hops must take longer");
    }

    #[test]
    fn contention_serializes_shared_link() {
        let topo = Topology::new(2);
        let cfg = RouterConfig::default();
        let mut t = LinkTraffic::new();
        // Two transfers sharing the memory-column link (0,2)→(0,1).
        let (_, _) = t.transfer(&topo, &cfg, Coord::new(0, 2), Coord::new(0, 0), 100, 0);
        let (d2, _) = t.transfer(&topo, &cfg, Coord::new(0, 2), Coord::new(0, 1), 100, 0);
        assert!(d2 >= 100, "second transfer must wait for the shared link: {d2}");
    }

    #[test]
    fn disjoint_paths_do_not_contend() {
        let topo = Topology::new(2);
        let cfg = RouterConfig::default();
        let mut t = LinkTraffic::new();
        let (_, _) = t.transfer(&topo, &cfg, Coord::new(0, 2), Coord::new(0, 0), 100, 0);
        let (d2, _) = t.transfer(&topo, &cfg, Coord::new(1, 2), Coord::new(1, 0), 100, 0);
        assert_eq!(d2, 0, "row-1 path is disjoint from row-0 path");
    }

    #[test]
    fn same_tile_transfer_is_service_only() {
        let topo = Topology::new(2);
        let cfg = RouterConfig::default();
        let mut t = LinkTraffic::new();
        let (d, a) = t.transfer(&topo, &cfg, Coord::new(0, 0), Coord::new(0, 0), 10, 5);
        assert_eq!(d, 5);
        assert_eq!(a, 15);
    }

    #[test]
    fn zero_word_transfer_claims_nothing() {
        let topo = Topology::new(2);
        let cfg = RouterConfig::default();
        let mut t = LinkTraffic::new();
        let (d, a) = t.transfer(&topo, &cfg, Coord::new(0, 2), Coord::new(1, 0), 0, 7);
        assert_eq!((d, a), (7, 7), "zero words is the fast path: no hops, no service");
        assert_eq!(t.max_link_busy(), 0);
        assert!(t.link_busy().is_empty());
    }

    #[test]
    fn shared_link_occupancy_intervals_cannot_overlap() {
        let topo = Topology::new(2);
        let cfg = RouterConfig::default();
        let mut t = LinkTraffic::new();
        // Three transfers all crossing link (0,2)→(0,1); each occupies it
        // for `words` cycles from its departure. Serialization means the
        // [depart, depart+words) intervals are pairwise disjoint.
        let words = [40u64, 25, 60];
        let mut intervals: Vec<(u64, u64)> = Vec::new();
        for (i, &w) in words.iter().enumerate() {
            let dst = Coord::new(0, i % 2); // (0,0) or (0,1) — same first link
            let (d, _) = t.transfer(&topo, &cfg, Coord::new(0, 2), dst, w, 0);
            intervals.push((d, d + w));
        }
        intervals.sort_unstable();
        for pair in intervals.windows(2) {
            assert!(
                pair[0].1 <= pair[1].0,
                "occupancy intervals overlap: {:?} vs {:?}",
                pair[0],
                pair[1]
            );
        }
        // Busy accounting matches the serialized occupancy exactly.
        let busy: u64 = words.iter().sum();
        let shared = (Coord::new(0, 2), Coord::new(0, 1));
        let got = t.link_busy().iter().find(|(k, _)| *k == shared).map(|&(_, b)| b);
        assert_eq!(got, Some(busy));
    }

    #[test]
    fn link_busy_listing_is_sorted_and_complete() {
        let topo = Topology::new(2);
        let cfg = RouterConfig::default();
        let mut t = LinkTraffic::new();
        t.transfer(&topo, &cfg, Coord::new(0, 2), Coord::new(1, 0), 10, 0);
        let listing = t.link_busy();
        assert_eq!(listing.len(), 3, "3 hops → 3 directed links");
        assert!(listing.windows(2).all(|w| w[0].0 < w[1].0), "sorted by link key");
        assert_eq!(listing.iter().map(|&(_, b)| b).sum::<u64>(), t.total_busy());
    }
}
