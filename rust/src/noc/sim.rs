//! Parallel DGEMM on the REDEFINE tile array (§5.5, Fig 12).
//!
//! Decomposition: the n×n output is cut into a b×b grid of (n/b)×(n/b)
//! blocks, one per compute tile. A's row-panel `bi` and C's block-row live
//! in the memory tile of row `bi`; B's column-panel `bj` lives in the
//! memory tile of row `bj`. Each tile:
//!
//! 1. streams its A panel (m×n), B panel (n×m) and C block (m×m) from the
//!    memory column over the NoC (contending on shared links),
//! 2. runs the rectangular PE DGEMM kernel (values + cycles from the
//!    cycle-accurate PE simulator at the chosen enhancement level),
//! 3. streams its C block back.
//!
//! The makespan over tiles versus the single-PE latency gives the Fig-12
//! speed-up; for small matrices the memory-column traffic dominates and the
//! speed-up collapses — the paper's computation-to-communication argument.

use super::router::{LinkTraffic, RouterConfig};
use super::topology::{Coord, Topology};
use crate::codegen::{gen_gemm_rect, GemmLayout};
use crate::pe::{AeLevel, Pe, PeConfig};
use crate::util::{round_up, Mat};

/// Per-tile execution record.
#[derive(Debug, Clone)]
pub struct TileReport {
    pub coord: Coord,
    /// Output block indices (bi, bj).
    pub block: (usize, usize),
    /// Cycle at which all operands had arrived.
    pub operands_ready: u64,
    /// PE compute cycles for the block kernel.
    pub compute_cycles: u64,
    /// Cycle at which the C block write-back completed.
    pub finish: u64,
}

/// Result of a parallel DGEMM run.
#[derive(Debug, Clone)]
pub struct NocRunReport {
    pub n: usize,
    pub b: usize,
    pub ae: AeLevel,
    pub tiles: Vec<TileReport>,
    /// Makespan of the parallel run in cycles.
    pub makespan: u64,
    /// Single-PE latency for the same problem (same AE level).
    pub single_pe_cycles: u64,
    /// Busiest-link cycles (NoC hot-spot diagnostic).
    pub max_link_busy: u64,
    /// The assembled C ← A·B + C result (already verified against the
    /// host reference inside the run; exposed so conformance tests can
    /// cross-check it against other execution paths too).
    pub result: Mat,
}

impl NocRunReport {
    /// Fig-12 speed-up over the single-PE realization.
    pub fn speedup(&self) -> f64 {
        self.single_pe_cycles as f64 / self.makespan as f64
    }

    /// Mean computation-to-communication ratio across tiles.
    pub fn compute_comm_ratio(&self) -> f64 {
        let mut r = 0.0;
        for t in &self.tiles {
            let comm = (t.operands_ready + (t.finish - t.operands_ready - t.compute_cycles)) as f64;
            r += t.compute_cycles as f64 / comm.max(1.0);
        }
        r / self.tiles.len() as f64
    }
}

/// Run C ← A·B + C on a b×b REDEFINE tile array at enhancement level `ae`,
/// verifying the assembled result against the host reference.
///
/// Requires n % b == 0; tile blocks are zero-padded up to multiples of 4
/// for the PE kernel (the padding flops are part of the simulated cost, as
/// they would be on the real fabric).
pub fn parallel_dgemm(n: usize, b: usize, ae: AeLevel, a: &Mat, bm: &Mat, c: &Mat) -> NocRunReport {
    parallel_dgemm_cfg(n, b, ae, a, bm, c, &RouterConfig::default())
}

/// [`parallel_dgemm`] with an explicit router configuration (ablations).
#[allow(clippy::too_many_arguments)]
pub fn parallel_dgemm_cfg(
    n: usize,
    b: usize,
    ae: AeLevel,
    a: &Mat,
    bm: &Mat,
    c: &Mat,
    rcfg: &RouterConfig,
) -> NocRunReport {
    assert!(n % b == 0, "n ({n}) must divide by the tile-array order b ({b})");
    assert_eq!((a.rows(), a.cols()), (n, n));
    assert_eq!((bm.rows(), bm.cols()), (n, n));
    assert_eq!((c.rows(), c.cols()), (n, n));
    let topo = Topology::new(b);
    let rcfg = rcfg.clone();
    let mut links = LinkTraffic::new();
    let m = n / b; // block edge
    let mp = round_up(m, 4); // padded block edge for the PE kernel
    let kp = round_up(n, 4); // padded inner dimension

    let mut tiles = Vec::with_capacity(b * b);
    let mut result = c.clone();
    let mut makespan = 0u64;

    for bi in 0..b {
        for bj in 0..b {
            let coord = Coord::new(bi, bj);
            let mem_a = topo.memory_for_row(bi); // A panel + C block home
            let mem_b = topo.memory_for_row(bj); // B panel home

            // Operand streams (words) over the NoC, in issue order.
            let (_, t_a) = links.transfer(&topo, &rcfg, mem_a, coord, (m * n) as u64, 0);
            let (_, t_b) = links.transfer(&topo, &rcfg, mem_b, coord, (n * m) as u64, 0);
            let (_, t_c) = links.transfer(&topo, &rcfg, mem_a, coord, (m * m) as u64, 0);
            let ready = t_a.max(t_b).max(t_c);

            // Block kernel on the tile's PE (values + cycles).
            let a_blk = a.block(bi * m, 0, m, n);
            let b_blk = bm.block(0, bj * m, n, m);
            let c_blk = c.block(bi * m, bj * m, m, m);
            let layout = GemmLayout::rect(mp, mp, kp);
            let prog = gen_gemm_rect(mp, mp, kp, ae, &layout);
            let mut pe = Pe::new(PeConfig::paper(ae), layout.gm_words());
            pe.write_gm(0, &layout.pack(&a_blk, &b_blk, &c_blk));
            let stats = pe.run(&prog);
            let out = layout.unpack_c(&pe.gm, m, m);
            result.set_block(bi * m, bj * m, &out);

            // C write-back.
            let (_, finish) =
                links.transfer(&topo, &rcfg, coord, mem_a, (m * m) as u64, ready + stats.cycles);
            makespan = makespan.max(finish);
            tiles.push(TileReport {
                coord,
                block: (bi, bj),
                operands_ready: ready,
                compute_cycles: stats.cycles,
                finish,
            });
        }
    }

    // Verify the assembled result against the host reference.
    let want = crate::blas::level3::dgemm_ref(a, bm, c);
    let err = crate::util::rel_fro_error(result.as_slice(), want.as_slice());
    assert!(err < 1e-12, "NoC DGEMM numerics off: rel err {err}");

    // Single-PE baseline at the same level (padded the same way).
    let np = round_up(n, 4);
    let layout = GemmLayout::rect(np, np, np);
    let prog = gen_gemm_rect(np, np, np, ae, &layout);
    let mut pe = Pe::new(PeConfig::paper(ae), layout.gm_words());
    pe.write_gm(0, &layout.pack(a, bm, c));
    let single = pe.run(&prog).cycles;

    NocRunReport {
        n,
        b,
        ae,
        tiles,
        makespan,
        single_pe_cycles: single,
        max_link_busy: links.max_link_busy(),
        result,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Mat;

    fn run(n: usize, b: usize) -> NocRunReport {
        let a = Mat::random(n, n, 61);
        let bm = Mat::random(n, n, 62);
        let c = Mat::random(n, n, 63);
        parallel_dgemm(n, b, AeLevel::Ae5, &a, &bm, &c)
    }

    #[test]
    fn numerics_and_speedup_2x2() {
        let r = run(24, 2);
        assert!(r.speedup() > 1.5, "2x2 speed-up too low: {}", r.speedup());
        assert!(r.speedup() <= 4.0 + 1e-9, "2x2 speed-up above b²: {}", r.speedup());
    }

    #[test]
    fn numerics_3x3() {
        let r = run(24, 3);
        assert!(r.speedup() > 2.0, "3x3 speed-up too low: {}", r.speedup());
        assert!(r.speedup() <= 9.0 + 1e-9);
    }

    #[test]
    fn speedup_grows_with_matrix_size() {
        // The Fig-12 trend: speed-up approaches b² as n grows.
        let small = run(16, 2).speedup();
        let large = run(64, 2).speedup();
        assert!(
            large > small,
            "speed-up must grow with n: {small:.2} → {large:.2}"
        );
        assert!(large > 2.7, "2x2 speed-up at n=64 should approach 4: {large:.2}");
    }

    #[test]
    fn tiles_all_report() {
        let r = run(24, 2);
        assert_eq!(r.tiles.len(), 4);
        for t in &r.tiles {
            assert!(t.finish >= t.operands_ready + t.compute_cycles);
            assert!(t.compute_cycles > 0);
        }
        assert!(r.max_link_busy > 0);
    }

    #[test]
    #[should_panic(expected = "must divide")]
    fn rejects_indivisible() {
        run(25, 2);
    }
}
