//! Mesh topology of the REDEFINE tile array.
//!
//! The fabric is a (rows × cols) mesh of tiles; the **last column** holds
//! memory tiles (matrix storage), the rest are compute tiles with one PE
//! each. Routing is dimension-ordered XY (the ReconNoC router of [13] is a
//! low-overhead single-cycle router; XY is its deadlock-free baseline).

/// Tile coordinate (row, col).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Coord {
    pub row: usize,
    pub col: usize,
}

impl Coord {
    pub fn new(row: usize, col: usize) -> Self {
        Self { row, col }
    }
}

/// The tile-array topology.
#[derive(Debug, Clone)]
pub struct Topology {
    /// Compute array is `b × b`; one extra column of memory tiles.
    pub b: usize,
}

impl Topology {
    /// A b×b compute array with its memory column (paper: b ∈ {2, 3, 4}).
    pub fn new(b: usize) -> Self {
        assert!(b >= 1, "need at least one compute tile");
        Self { b }
    }

    pub fn rows(&self) -> usize {
        self.b
    }

    /// Total columns including the memory column.
    pub fn cols(&self) -> usize {
        self.b + 1
    }

    /// Number of compute tiles.
    pub fn compute_tiles(&self) -> usize {
        self.b * self.b
    }

    /// Coordinates of every compute tile (row-major).
    pub fn compute_coords(&self) -> Vec<Coord> {
        (0..self.b)
            .flat_map(|r| (0..self.b).map(move |c| Coord::new(r, c)))
            .collect()
    }

    /// Memory tile serving a given row (same-row memory column tile).
    pub fn memory_for_row(&self, row: usize) -> Coord {
        assert!(row < self.b);
        Coord::new(row, self.b)
    }

    /// XY-routed path from `from` to `to` (inclusive of endpoints):
    /// X (column) first, then Y (row) — matching ReconNoC's dimension order.
    pub fn xy_path(&self, from: Coord, to: Coord) -> Vec<Coord> {
        assert!(from.row < self.rows() && to.row < self.rows());
        assert!(from.col < self.cols() && to.col < self.cols());
        let mut path = vec![from];
        let mut cur = from;
        while cur.col != to.col {
            cur.col = if to.col > cur.col { cur.col + 1 } else { cur.col - 1 };
            path.push(cur);
        }
        while cur.row != to.row {
            cur.row = if to.row > cur.row { cur.row + 1 } else { cur.row - 1 };
            path.push(cur);
        }
        path
    }

    /// Hop count (links traversed) between two tiles under XY routing.
    pub fn hops(&self, from: Coord, to: Coord) -> usize {
        self.xy_path(from, to).len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geometry() {
        let t = Topology::new(3);
        assert_eq!(t.compute_tiles(), 9);
        assert_eq!(t.cols(), 4);
        assert_eq!(t.compute_coords().len(), 9);
        assert_eq!(t.memory_for_row(2), Coord::new(2, 3));
    }

    #[test]
    fn xy_path_is_x_then_y() {
        let t = Topology::new(4);
        let p = t.xy_path(Coord::new(3, 0), Coord::new(0, 4));
        assert_eq!(p.first(), Some(&Coord::new(3, 0)));
        assert_eq!(p.last(), Some(&Coord::new(0, 4)));
        // X leg first: the second node moves in column.
        assert_eq!(p[1], Coord::new(3, 1));
        assert_eq!(t.hops(Coord::new(3, 0), Coord::new(0, 4)), 7);
    }

    #[test]
    fn xy_path_length_is_manhattan_plus_one() {
        // Every pair: the XY path visits exactly Manhattan-distance + 1
        // tiles, each consecutive pair differing by one step in exactly
        // one dimension (the path is a lattice walk, column leg first).
        let t = Topology::new(3);
        for fr in 0..t.rows() {
            for fc in 0..t.cols() {
                for tr in 0..t.rows() {
                    for tc in 0..t.cols() {
                        let (from, to) = (Coord::new(fr, fc), Coord::new(tr, tc));
                        let p = t.xy_path(from, to);
                        let manhattan = fr.abs_diff(tr) + fc.abs_diff(tc);
                        assert_eq!(p.len(), manhattan + 1);
                        assert_eq!(t.hops(from, to), manhattan);
                        for w in p.windows(2) {
                            let dr = w[0].row.abs_diff(w[1].row);
                            let dc = w[0].col.abs_diff(w[1].col);
                            assert_eq!(dr + dc, 1, "non-unit step {w:?}");
                            // Column leg first: once the row changes the
                            // column must already match the destination.
                            if dr == 1 {
                                assert_eq!(w[0].col, to.col);
                            }
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn zero_hop_path() {
        let t = Topology::new(2);
        let c = Coord::new(1, 1);
        assert_eq!(t.hops(c, c), 0);
        assert_eq!(t.xy_path(c, c), vec![c]);
    }

    #[test]
    #[should_panic]
    fn out_of_range_coord() {
        let t = Topology::new(2);
        t.xy_path(Coord::new(0, 0), Coord::new(5, 0));
    }
}
