//! # redefine-blas
//!
//! Reproduction of *"Accelerating BLAS on Custom Architecture through
//! Algorithm-Architecture Co-design"* (Merchant et al., 2016).
//!
//! The crate provides, as a library:
//!
//! * [`pe`] — a cycle-accurate, functional+timing simulator of the paper's
//!   Processing Element at every enhancement level AE0–AE5 (§4.4–§5.4);
//! * [`codegen`] — BLAS kernels compiled to PE instruction streams, one
//!   emission strategy per enhancement (algorithms 1/3/4 of the paper);
//! * [`blas`] / [`lapack`] — a host reference BLAS (Levels 1–3, plus
//!   Strassen and Winograd baselines) and LAPACK-lite factorizations used
//!   as oracles and for the Fig-1 profiling experiment;
//! * [`dag`] — the DAG analysis of §4 (levels, widths, critical paths);
//! * [`noc`] — the REDEFINE tile-array/NoC simulator for parallel DGEMM
//!   (§5.5, Fig 12);
//! * [`energy`] — the power/energy model behind every Gflops/W column;
//! * [`platforms`] — analytical models of the comparison platforms
//!   (multicore + cache simulation, GPU roofline, platform database) for
//!   Fig 2 and Fig 11(j);
//! * [`runtime`] / [`coordinator`] — the L3 co-simulation stack: values
//!   from AOT-compiled XLA artifacts (PJRT), timing from the PE/NoC
//!   simulators, Python never on the request path;
//! * [`engine`] — the process-wide multi-tenant serving engine: one shared
//!   PE worker pool + one shared program cache behind per-tenant
//!   coordinator handles, with weighted-fair scheduling across tenants;
//! * [`metrics`] — CPF/FPC/Gflops-per-watt accounting and table printers;
//! * [`obs`] — the observability layer: typed per-request event tracing
//!   (`TraceSink`), per-request span reconstruction, unified
//!   engine/tenant metric snapshots with rolling windowed latency
//!   histograms, and JSONL / Chrome-trace exporters.

pub mod blas;
pub mod codegen;
pub mod coordinator;
pub mod dag;
pub mod energy;
pub mod engine;
pub mod lapack;
pub mod metrics;
pub mod noc;
pub mod obs;
pub mod pe;
pub mod platforms;
pub mod runtime;
pub mod util;
