//! PE configurations: the initial PE (AE0, §4.4) and the five architectural
//! enhancements AE1–AE5 (§5.1–§5.4), plus the timing parameters of the model.
//!
//! Timing constants marked "calibrated" were fitted once so the simulated
//! latency tables land near Tables 4–9 of the paper; they are not free knobs
//! per experiment — a single parameter set produces every table.

use std::fmt;

/// The architectural-enhancement level of the PE (paper §5).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum AeLevel {
    /// Initial PE (§4.4): RF + pipelined FPU, loads direct from GM,
    /// shallow outstanding-request window (no computation/communication
    /// overlap to speak of).
    Ae0,
    /// + Load-Store CFU and 256-kbit Local Memory (§5.1).
    Ae1,
    /// + DOT reconfigurable datapath (§5.2.1).
    Ae2,
    /// + Block Data Load/Store instructions (§5.2.2).
    Ae3,
    /// + 4× FPS↔LS-CFU bandwidth, 256-bit wide moves (§5.3).
    Ae4,
    /// + software pre-fetching via loop restructuring (§5.4, algorithm 4).
    Ae5,
}

impl AeLevel {
    pub const ALL: [AeLevel; 6] =
        [AeLevel::Ae0, AeLevel::Ae1, AeLevel::Ae2, AeLevel::Ae3, AeLevel::Ae4, AeLevel::Ae5];

    /// Local Memory + decoupled Load-Store CFU present?
    pub fn has_lm(self) -> bool {
        self >= AeLevel::Ae1
    }

    /// DOT2/3/4 reconfigurable datapath present?
    pub fn has_dot(self) -> bool {
        self >= AeLevel::Ae2
    }

    /// Single-handshake block GM transfers?
    pub fn has_block_ldst(self) -> bool {
        self >= AeLevel::Ae3
    }

    /// 256-bit FPS↔LS-CFU path (LmLd4/LmSt4)?
    pub fn has_wide_path(self) -> bool {
        self >= AeLevel::Ae4
    }

    /// Pre-fetching codegen (algorithm 4 loop structure)?
    pub fn has_prefetch(self) -> bool {
        self >= AeLevel::Ae5
    }

    /// Peak flops-per-cycle of the configuration (paper footnotes 6 and 7):
    /// 2 for the mul+add pair, 7 once the DOT4 RDP is present (4 mul + 3 add
    /// issued every cycle at full pipeline occupancy).
    pub fn peak_fpc(self) -> f64 {
        if self.has_dot() { 7.0 } else { 2.0 }
    }

    pub fn name(self) -> &'static str {
        match self {
            AeLevel::Ae0 => "AE0 (initial PE)",
            AeLevel::Ae1 => "AE1 (+LM, LS-CFU)",
            AeLevel::Ae2 => "AE2 (+DOT4 RDP)",
            AeLevel::Ae3 => "AE3 (+block ld/st)",
            AeLevel::Ae4 => "AE4 (+4x bandwidth)",
            AeLevel::Ae5 => "AE5 (+pre-fetch)",
        }
    }
}

impl fmt::Display for AeLevel {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.name())
    }
}

/// Full timing/structure configuration of a PE instance.
#[derive(Debug, Clone, PartialEq)]
pub struct PeConfig {
    pub ae: AeLevel,
    /// PE clock in GHz (paper operates the PE at 0.2 GHz).
    pub clock_ghz: f64,
    /// Adder pipeline depth (cycles).
    pub lat_add: u32,
    /// Multiplier pipeline depth.
    pub lat_mul: u32,
    /// Divider latency (non-pipelined).
    pub lat_div: u32,
    /// Square-root latency (non-pipelined).
    pub lat_sqrt: u32,
    /// Chained mul→add mac latency.
    pub lat_mac: u32,
    /// DOT RDP pipeline depth (paper: 15).
    pub lat_dot: u32,
    /// GM access latency — the paper models GM as a 20-stage pipelined delay.
    pub gm_latency: u32,
    /// GM port occupancy per scalar word: handshake + data (calibrated: the
    /// AE0 table is consistent with ≈2 port-cycles/word plus window stalls).
    pub gm_word_cycles: u32,
    /// Extra GM handshake cycles per request (amortized away by AE3 blocks).
    pub gm_req_overhead: u32,
    /// Outstanding-GM-request window at AE0 (shallow: the initial PE has no
    /// decoupled LS CFU, so latency is poorly hidden — calibrated depth 2).
    pub ae0_mem_window: u32,
    /// LM access latency (scratchpad SRAM).
    pub lm_latency: u32,
    /// LM port occupancy per scalar access (single-ported SRAM: calibrated 2).
    pub lm_word_cycles: u32,
    /// LM port occupancy of one 256-bit wide access at AE4.
    pub lm_wide_cycles: u32,
    /// Load-store queue depth of the decoupled LS CFU (AE1+).
    pub lsq_depth: usize,
    /// Instruction memory size in bytes (16 KB in the paper §4.5). The
    /// codegen streams programs, but we track the high-water mark of live
    /// loop bodies against this.
    pub imem_bytes: usize,
}

impl PeConfig {
    /// The paper's PE at a given enhancement level, with calibrated timing.
    pub fn paper(ae: AeLevel) -> Self {
        Self {
            ae,
            clock_ghz: 0.2,
            lat_add: 3,
            lat_mul: 4,
            lat_div: 18,
            lat_sqrt: 21,
            lat_mac: 6,
            lat_dot: 15,
            gm_latency: 20,
            gm_word_cycles: 1,
            gm_req_overhead: 1,
            ae0_mem_window: 3,
            lm_latency: 2,
            lm_word_cycles: 2,
            lm_wide_cycles: 1,
            lsq_depth: 16,
            imem_bytes: 16 * 1024,
        }
    }

    /// All six paper configurations in enhancement order.
    pub fn paper_sweep() -> Vec<Self> {
        AeLevel::ALL.iter().map(|&ae| Self::paper(ae)).collect()
    }

    /// Cycle time in nanoseconds.
    pub fn cycle_ns(&self) -> f64 {
        1.0 / self.clock_ghz
    }

    /// Latency of an arithmetic instruction class in cycles.
    pub fn arith_latency(&self, kind: ArithKind) -> u32 {
        match kind {
            ArithKind::Add => self.lat_add,
            ArithKind::Mul => self.lat_mul,
            ArithKind::Div => self.lat_div,
            ArithKind::Sqrt => self.lat_sqrt,
            ArithKind::Mac => self.lat_mac,
            ArithKind::Dot => self.lat_dot,
        }
    }
}

/// Arithmetic instruction classes (each maps to a functional unit).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArithKind {
    Add,
    Mul,
    Div,
    Sqrt,
    Mac,
    Dot,
}

impl ArithKind {
    /// Initiation interval: pipelined units accept one op/cycle, the divider
    /// and square-root are iterative (non-pipelined).
    pub fn initiation_interval(self, cfg: &PeConfig) -> u32 {
        match self {
            ArithKind::Div => cfg.lat_div,
            ArithKind::Sqrt => cfg.lat_sqrt,
            _ => 1,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn feature_ladder_is_monotone() {
        let mut prev = (false, false, false, false, false);
        for ae in AeLevel::ALL {
            let cur = (
                ae.has_lm(),
                ae.has_dot(),
                ae.has_block_ldst(),
                ae.has_wide_path(),
                ae.has_prefetch(),
            );
            // Features only ever turn on as the level rises.
            assert!(!prev.0 || cur.0);
            assert!(!prev.1 || cur.1);
            assert!(!prev.2 || cur.2);
            assert!(!prev.3 || cur.3);
            assert!(!prev.4 || cur.4);
            prev = cur;
        }
        assert!(AeLevel::Ae5.has_lm() && AeLevel::Ae5.has_prefetch());
        assert!(!AeLevel::Ae0.has_lm());
    }

    #[test]
    fn peak_fpc_matches_paper_footnotes() {
        assert_eq!(AeLevel::Ae0.peak_fpc(), 2.0);
        assert_eq!(AeLevel::Ae1.peak_fpc(), 2.0);
        assert_eq!(AeLevel::Ae2.peak_fpc(), 7.0);
        assert_eq!(AeLevel::Ae5.peak_fpc(), 7.0);
    }

    #[test]
    fn paper_config_constants() {
        let c = PeConfig::paper(AeLevel::Ae5);
        assert_eq!(c.gm_latency, 20); // §4.5: 20-stage pipelined delay
        assert_eq!(c.lat_dot, 15); // §5.2.1: 15-stage RDP
        assert_eq!(c.clock_ghz, 0.2); // §4.5.1
        assert_eq!(c.imem_bytes, 16 * 1024);
        assert_eq!(c.cycle_ns(), 5.0);
    }

    #[test]
    fn div_sqrt_not_pipelined() {
        let c = PeConfig::paper(AeLevel::Ae0);
        assert_eq!(ArithKind::Div.initiation_interval(&c), c.lat_div);
        assert_eq!(ArithKind::Sqrt.initiation_interval(&c), c.lat_sqrt);
        assert_eq!(ArithKind::Dot.initiation_interval(&c), 1);
        assert_eq!(ArithKind::Mac.initiation_interval(&c), 1);
    }
}
