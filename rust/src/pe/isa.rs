//! Instruction set of the Processing Element (paper §4.4–§5.4).
//!
//! The PE is an in-order, single-issue sequencer (the "Floating Point
//! Sequencer", FPS) in front of pipelined double-precision units, plus a
//! Load-Store CFU that owns the Local Memory (LM) and the Global Memory (GM)
//! port. The enhancements AE1–AE5 progressively enable instructions:
//!
//! * AE0 (initial PE, §4.4): `Ld`/`St` (GM↔RF), scalar FPU ops, `Fmac`.
//! * AE1 (§5.1): Local Memory + Load-Store CFU → `LmLd`/`LmSt` and
//!   background `BlkLd`/`BlkSt` issued by the LS engine (scalar GM handshake).
//! * AE2 (§5.2.1): the DOT reconfigurable datapath → `Dot { n: 2..4 }`.
//! * AE3 (§5.2.2): Block Data Load/Store — `BlkLd`/`BlkSt` become single
//!   instructions with one GM handshake per block instead of per word.
//! * AE4 (§5.3): 4× FPS↔LS-CFU bandwidth → `LmLd4`/`LmSt4` (256-bit moves).
//! * AE5 (§5.4): pre-fetching — a codegen change (algorithm 4), no new opcode.

/// Register index into the 64-entry, 64-bit register file.
pub type Reg = u8;

/// Word address (f64-granular) into GM or LM.
pub type Addr = u32;

/// Number of architectural registers in the FPS register file (paper §4.4).
pub const NUM_REGS: usize = 64;

/// Local Memory capacity in f64 words: 256 kbit = 32 KiB = 4096 words (§5.1).
pub const LM_WORDS: usize = 4096;

/// Depth of the DOT4 reconfigurable datapath pipeline (paper §5.2.1).
pub const DOT_PIPELINE_DEPTH: u32 = 15;

/// A single PE instruction.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Instr {
    /// GM → RF scalar load (AE0 data path).
    Ld { rd: Reg, gm: Addr },
    /// RF → GM scalar store.
    St { rs: Reg, gm: Addr },
    /// LM → RF scalar load (requires AE1 Local Memory).
    LmLd { rd: Reg, lm: Addr },
    /// RF → LM scalar store (requires AE1).
    LmSt { rs: Reg, lm: Addr },
    /// LM → RF[rd..rd+4] 256-bit load (requires AE4 wide path).
    LmLd4 { rd: Reg, lm: Addr },
    /// RF[rs..rs+4] → LM 256-bit store (requires AE4).
    LmSt4 { rs: Reg, lm: Addr },
    /// GM → LM block transfer executed by the LS CFU (single handshake at
    /// AE3+, per-word handshake before that).
    BlkLd { lm: Addr, gm: Addr, len: u32 },
    /// LM → GM block transfer.
    BlkSt { lm: Addr, gm: Addr, len: u32 },
    /// rd ← ra + rb.
    Fadd { rd: Reg, ra: Reg, rb: Reg },
    /// rd ← ra − rb.
    Fsub { rd: Reg, ra: Reg, rb: Reg },
    /// rd ← ra × rb.
    Fmul { rd: Reg, ra: Reg, rb: Reg },
    /// rd ← ra ÷ rb.
    Fdiv { rd: Reg, ra: Reg, rb: Reg },
    /// rd ← √ra.
    Fsqrt { rd: Reg, ra: Reg },
    /// rd ← rd + ra × rb (chained multiplier→adder, the AE0/AE1 mac path).
    Fmac { rd: Reg, ra: Reg, rb: Reg },
    /// rd ← (acc ? rd : 0) + Σ_{i<n} R[ra+i]·R[rb+i] on the RDP (AE2+).
    /// `n` ∈ {2, 3, 4} selects the DOT2/DOT3/DOT4 configuration (§5.2.1).
    Dot { rd: Reg, ra: Reg, rb: Reg, n: u8, acc: bool },
    /// Load immediate constant into rd (assembler convenience; the real PE
    /// reads constants from memory — costs one issue slot, no FU).
    Li { rd: Reg, val: f64 },
    /// No-operation (pipeline padding).
    Nop,
    /// Loop-boundary barrier: the simple FPS loop sequencer stalls at a
    /// backward branch until every in-flight operation has completed
    /// (fig 10 "before pre-fetching"). The AE5 restructured code (algorithm
    /// 4) software-pipelines across iterations and emits none of these.
    Barrier,
    /// Stop the sequencer.
    Halt,
}

impl Instr {
    /// Floating-point operations performed by this instruction (standard
    /// convention: one flop per add/sub/mul/div/sqrt; a mac is two).
    pub fn flops(&self) -> u64 {
        match *self {
            Instr::Fadd { .. } | Instr::Fsub { .. } | Instr::Fmul { .. } => 1,
            Instr::Fdiv { .. } | Instr::Fsqrt { .. } => 1,
            Instr::Fmac { .. } => 2,
            Instr::Dot { n, acc, .. } => {
                // n multiplies, n-1 reduction adds, +1 accumulate add.
                n as u64 + (n as u64 - 1) + if acc { 1 } else { 0 }
            }
            _ => 0,
        }
    }

    /// True if the instruction is executed by the Load-Store CFU.
    pub fn is_mem(&self) -> bool {
        matches!(
            self,
            Instr::Ld { .. }
                | Instr::St { .. }
                | Instr::LmLd { .. }
                | Instr::LmSt { .. }
                | Instr::LmLd4 { .. }
                | Instr::LmSt4 { .. }
                | Instr::BlkLd { .. }
                | Instr::BlkSt { .. }
        )
    }

    /// True if the instruction is executed by the FPS arithmetic pipelines.
    pub fn is_arith(&self) -> bool {
        matches!(
            self,
            Instr::Fadd { .. }
                | Instr::Fsub { .. }
                | Instr::Fmul { .. }
                | Instr::Fdiv { .. }
                | Instr::Fsqrt { .. }
                | Instr::Fmac { .. }
                | Instr::Dot { .. }
        )
    }

    /// Registers read by this instruction, written into a fixed buffer
    /// (hot path — the simulator calls this once per instruction).
    #[inline]
    pub fn srcs_into(&self, out: &mut [Reg; 12]) -> usize {
        let mut n = 0;
        let mut push = |r: Reg| {
            out[n] = r;
            n += 1;
        };
        match *self {
            Instr::St { rs, .. } | Instr::LmSt { rs, .. } => push(rs),
            Instr::LmSt4 { rs, .. } => {
                for k in 0..4 {
                    push(rs + k);
                }
            }
            Instr::Fadd { ra, rb, .. }
            | Instr::Fsub { ra, rb, .. }
            | Instr::Fmul { ra, rb, .. }
            | Instr::Fdiv { ra, rb, .. } => {
                push(ra);
                push(rb);
            }
            Instr::Fsqrt { ra, .. } => push(ra),
            Instr::Fmac { rd, ra, rb } => {
                push(rd);
                push(ra);
                push(rb);
            }
            Instr::Dot { rd, ra, rb, n: w, acc } => {
                for i in 0..w {
                    push(ra + i);
                    push(rb + i);
                }
                if acc {
                    push(rd);
                }
            }
            _ => {}
        }
        n
    }

    /// Registers written, into a fixed buffer (hot path).
    #[inline]
    pub fn dsts_into(&self, out: &mut [Reg; 4]) -> usize {
        let mut n = 0;
        let mut push = |r: Reg| {
            out[n] = r;
            n += 1;
        };
        match *self {
            Instr::Ld { rd, .. } | Instr::LmLd { rd, .. } | Instr::Li { rd, .. } => push(rd),
            Instr::LmLd4 { rd, .. } => {
                for k in 0..4 {
                    push(rd + k);
                }
            }
            Instr::Fadd { rd, .. }
            | Instr::Fsub { rd, .. }
            | Instr::Fmul { rd, .. }
            | Instr::Fdiv { rd, .. }
            | Instr::Fsqrt { rd, .. }
            | Instr::Fmac { rd, .. }
            | Instr::Dot { rd, .. } => push(rd),
            _ => {}
        }
        n
    }

    /// Registers read by this instruction, appended to `out`.
    pub fn srcs(&self, out: &mut Vec<Reg>) {
        match *self {
            Instr::St { rs, .. } | Instr::LmSt { rs, .. } => out.push(rs),
            Instr::LmSt4 { rs, .. } => out.extend((rs..rs + 4).collect::<Vec<_>>()),
            Instr::Fadd { ra, rb, .. }
            | Instr::Fsub { ra, rb, .. }
            | Instr::Fmul { ra, rb, .. }
            | Instr::Fdiv { ra, rb, .. } => {
                out.push(ra);
                out.push(rb);
            }
            Instr::Fsqrt { ra, .. } => out.push(ra),
            Instr::Fmac { rd, ra, rb } => {
                out.push(rd);
                out.push(ra);
                out.push(rb);
            }
            Instr::Dot { rd, ra, rb, n, acc } => {
                for i in 0..n {
                    out.push(ra + i);
                    out.push(rb + i);
                }
                if acc {
                    out.push(rd);
                }
            }
            _ => {}
        }
    }

    /// Registers written by this instruction, appended to `out`.
    pub fn dsts(&self, out: &mut Vec<Reg>) {
        match *self {
            Instr::Ld { rd, .. } | Instr::LmLd { rd, .. } | Instr::Li { rd, .. } => out.push(rd),
            Instr::LmLd4 { rd, .. } => out.extend((rd..rd + 4).collect::<Vec<_>>()),
            Instr::Fadd { rd, .. }
            | Instr::Fsub { rd, .. }
            | Instr::Fmul { rd, .. }
            | Instr::Fdiv { rd, .. }
            | Instr::Fsqrt { rd, .. }
            | Instr::Fmac { rd, .. }
            | Instr::Dot { rd, .. } => out.push(rd),
            _ => {}
        }
    }
}

/// A straight-line PE program (the codegen layer emits these; loops are
/// unrolled by the generator, mirroring the paper's unrolled 4×4 blocks).
#[derive(Debug, Clone, Default)]
pub struct Program {
    pub instrs: Vec<Instr>,
}

impl Program {
    pub fn new() -> Self {
        Self { instrs: Vec::new() }
    }

    pub fn push(&mut self, i: Instr) {
        self.instrs.push(i);
    }

    pub fn len(&self) -> usize {
        self.instrs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.instrs.is_empty()
    }

    /// Total flop count of the program.
    pub fn flops(&self) -> u64 {
        self.instrs.iter().map(Instr::flops).sum()
    }

    /// Count of DOT instructions (denominator of the paper's α metric,
    /// eq. 7: latency / total computations in terms of DOT4).
    pub fn dot_count(&self) -> u64 {
        self.instrs.iter().filter(|i| matches!(i, Instr::Dot { .. })).count() as u64
    }

    /// Validate static constraints: register indices in range, LM addresses
    /// in range, wide ops 4-aligned in the register file.
    pub fn validate(&self) -> Result<(), String> {
        let mut srcs = Vec::new();
        let mut dsts = Vec::new();
        for (pc, ins) in self.instrs.iter().enumerate() {
            srcs.clear();
            dsts.clear();
            ins.srcs(&mut srcs);
            ins.dsts(&mut dsts);
            for &r in srcs.iter().chain(dsts.iter()) {
                if (r as usize) >= NUM_REGS {
                    return Err(format!("pc {pc}: register r{r} out of range"));
                }
            }
            match *ins {
                Instr::LmLd { lm, .. } | Instr::LmSt { lm, .. } => {
                    if lm as usize >= LM_WORDS {
                        return Err(format!("pc {pc}: LM address {lm} out of range"));
                    }
                }
                Instr::LmLd4 { rd, lm } => {
                    if rd as usize + 4 > NUM_REGS || lm as usize + 4 > LM_WORDS {
                        return Err(format!("pc {pc}: wide load out of range"));
                    }
                }
                Instr::LmSt4 { rs, lm } => {
                    if rs as usize + 4 > NUM_REGS || lm as usize + 4 > LM_WORDS {
                        return Err(format!("pc {pc}: wide store out of range"));
                    }
                }
                Instr::BlkLd { lm, len, .. } | Instr::BlkSt { lm, len, .. } => {
                    if lm as usize + len as usize > LM_WORDS {
                        return Err(format!("pc {pc}: block transfer overruns LM"));
                    }
                }
                Instr::Dot { n, ra, rb, .. } => {
                    if !(2..=4).contains(&n) {
                        return Err(format!("pc {pc}: DOT width {n} unsupported"));
                    }
                    if ra as usize + n as usize > NUM_REGS || rb as usize + n as usize > NUM_REGS {
                        return Err(format!("pc {pc}: DOT operand window out of range"));
                    }
                }
                _ => {}
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flop_counts() {
        assert_eq!(Instr::Fadd { rd: 0, ra: 1, rb: 2 }.flops(), 1);
        assert_eq!(Instr::Fmac { rd: 0, ra: 1, rb: 2 }.flops(), 2);
        assert_eq!(Instr::Dot { rd: 0, ra: 4, rb: 8, n: 4, acc: true }.flops(), 8);
        assert_eq!(Instr::Dot { rd: 0, ra: 4, rb: 8, n: 4, acc: false }.flops(), 7);
        assert_eq!(Instr::Ld { rd: 0, gm: 0 }.flops(), 0);
    }

    #[test]
    fn src_dst_sets() {
        let mut s = Vec::new();
        let mut d = Vec::new();
        let i = Instr::Dot { rd: 0, ra: 4, rb: 8, n: 3, acc: true };
        i.srcs(&mut s);
        i.dsts(&mut d);
        assert_eq!(s, vec![4, 8, 5, 9, 6, 10, 0]);
        assert_eq!(d, vec![0]);
    }

    #[test]
    fn validate_catches_bad_reg() {
        let mut p = Program::new();
        p.push(Instr::Fadd { rd: 63, ra: 64, rb: 0 });
        assert!(p.validate().is_err());
    }

    #[test]
    fn validate_catches_bad_dot_width() {
        let mut p = Program::new();
        p.push(Instr::Dot { rd: 0, ra: 0, rb: 4, n: 5, acc: false });
        assert!(p.validate().is_err());
    }

    #[test]
    fn validate_catches_lm_overrun() {
        let mut p = Program::new();
        p.push(Instr::BlkLd { lm: (LM_WORDS - 2) as Addr, gm: 0, len: 16 });
        assert!(p.validate().is_err());
    }

    #[test]
    fn validate_ok_program() {
        let mut p = Program::new();
        p.push(Instr::Ld { rd: 0, gm: 0 });
        p.push(Instr::Ld { rd: 1, gm: 1 });
        p.push(Instr::Fmul { rd: 2, ra: 0, rb: 1 });
        p.push(Instr::St { rs: 2, gm: 2 });
        p.push(Instr::Halt);
        assert!(p.validate().is_ok());
        assert_eq!(p.flops(), 1);
    }

    #[test]
    fn mem_arith_classification() {
        assert!(Instr::Ld { rd: 0, gm: 0 }.is_mem());
        assert!(Instr::BlkLd { lm: 0, gm: 0, len: 4 }.is_mem());
        assert!(Instr::Dot { rd: 0, ra: 0, rb: 4, n: 4, acc: false }.is_arith());
        assert!(!Instr::Nop.is_mem());
        assert!(!Instr::Halt.is_arith());
    }
}
