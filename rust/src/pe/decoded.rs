//! Tier 1 of the two-tier PE execution engine: pre-decoded programs and
//! their one-time schedule.
//!
//! The serving engine's request path is "fixed program, many operands":
//! once a kernel is emitted for a (routine, shape, AE) key its timing
//! never changes — only operand values do. This module splits the work
//! accordingly:
//!
//! 1. **decode** ([`DecodedProgram::decode`]) — one pass per cached
//!    program that validates the stream (register/LM ranges, DOT widths,
//!    feature gates) and lowers the 16-byte [`Instr`] enum into a flat,
//!    cache-friendly array of 8-byte [`PackedOp`] words, with `Li`
//!    immediates and block-transfer descriptors hoisted into side tables.
//! 2. **schedule** ([`ScheduledProgram::execute`]) — the first execution
//!    runs the full cycle-accurate combined interpreter
//!    ([`Pe::run_decoded`]) and memoizes its [`PeStats`]; PE timing is
//!    data-independent, so the schedule holds for every later request.
//! 3. **replay** ([`Pe::replay`]) — every subsequent execution runs the
//!    lean value-only interpreter over the pre-decoded stream (no
//!    scoreboard, no queues, no stall attribution) and reuses the
//!    memoized stats. Values are bit-identical to the combined run.
//! 4. **batched replay** ([`replay_batch`]) — when a serving batch holds
//!    many requests for one warm kernel, a single pass over the decoded
//!    stream advances all their operand contexts at once
//!    ([`ScheduledProgram::replay_batch_scheduled`]), amortizing decode
//!    iteration and dispatch while staying bit-identical to step 3.
//!
//! [`Pe::run_decoded`]: super::core::Pe::run_decoded
//! [`Pe::replay`]: super::core::Pe::replay
//! [`replay_batch`]: super::core::replay_batch

use super::config::{AeLevel, PeConfig};
use super::core::{Pe, PeStats, ReplayCtx};
use super::isa::{Instr, Program};
use std::sync::OnceLock;

/// Opcode of one packed operation. `Halt` has no packed form — decoding
/// truncates at the first `Halt`, exactly where the sequencer stops.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub(crate) enum Op {
    Ld,
    St,
    LmLd,
    LmSt,
    LmLd4,
    LmSt4,
    BlkLd,
    BlkSt,
    Fadd,
    Fsub,
    Fmul,
    Fdiv,
    Fsqrt,
    Fmac,
    Dot,
    Li,
    Nop,
    Barrier,
}

/// One pre-decoded operation, packed into 8 bytes (half the 16-byte
/// [`Instr`] enum): opcode + up to three register operands + a 32-bit
/// word that is a memory address (`Ld`/`St`/`LmLd`…), a side-table index
/// (`Li`, `BlkLd`, `BlkSt`), or the DOT width/accumulate pair (`Dot`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub(crate) struct PackedOp {
    pub(crate) op: Op,
    /// Destination register (`rd`) or store source (`rs`).
    pub(crate) a: u8,
    /// First source register (`ra`).
    pub(crate) b: u8,
    /// Second source register (`rb`).
    pub(crate) c: u8,
    /// Address / side-table index / DOT parameters (see [`Op`]).
    pub(crate) addr: u32,
}

impl PackedOp {
    fn new(op: Op, a: u8, b: u8, c: u8, addr: u32) -> Self {
        Self { op, a, b, c, addr }
    }

    /// DOT width `n` and accumulate flag packed in `addr`.
    #[inline]
    pub(crate) fn dot_params(&self) -> (u8, bool) {
        ((self.addr & 0xFF) as u8, (self.addr >> 8) & 1 == 1)
    }

    /// Registers read, written into a fixed buffer — mirrors
    /// [`Instr::srcs_into`] (same registers, same order, so RAW hazard
    /// detection and `rf_accesses` accounting are unchanged).
    #[inline]
    pub(crate) fn srcs_into(&self, out: &mut [u8; 12]) -> usize {
        let mut n = 0;
        let mut push = |r: u8| {
            out[n] = r;
            n += 1;
        };
        match self.op {
            Op::St | Op::LmSt => push(self.a),
            Op::LmSt4 => {
                for k in 0..4 {
                    push(self.a + k);
                }
            }
            Op::Fadd | Op::Fsub | Op::Fmul | Op::Fdiv => {
                push(self.b);
                push(self.c);
            }
            Op::Fsqrt => push(self.b),
            Op::Fmac => {
                push(self.a);
                push(self.b);
                push(self.c);
            }
            Op::Dot => {
                let (w, acc) = self.dot_params();
                for i in 0..w {
                    push(self.b + i);
                    push(self.c + i);
                }
                if acc {
                    push(self.a);
                }
            }
            _ => {}
        }
        n
    }

    /// Registers written, into a fixed buffer — mirrors [`Instr::dsts_into`].
    #[inline]
    pub(crate) fn dsts_into(&self, out: &mut [u8; 4]) -> usize {
        let mut n = 0;
        let mut push = |r: u8| {
            out[n] = r;
            n += 1;
        };
        match self.op {
            Op::Ld | Op::LmLd | Op::Li => push(self.a),
            Op::LmLd4 => {
                for k in 0..4 {
                    push(self.a + k);
                }
            }
            Op::Fadd | Op::Fsub | Op::Fmul | Op::Fdiv | Op::Fsqrt | Op::Fmac | Op::Dot => {
                push(self.a)
            }
            _ => {}
        }
        n
    }

    /// Arithmetic class (functional unit), if any — mirrors the combined
    /// interpreter's structural-hazard classification.
    #[inline]
    pub(crate) fn arith_kind(&self) -> Option<super::config::ArithKind> {
        use super::config::ArithKind;
        match self.op {
            Op::Fadd | Op::Fsub => Some(ArithKind::Add),
            Op::Fmul => Some(ArithKind::Mul),
            Op::Fdiv => Some(ArithKind::Div),
            Op::Fsqrt => Some(ArithKind::Sqrt),
            Op::Fmac => Some(ArithKind::Mac),
            Op::Dot => Some(ArithKind::Dot),
            _ => None,
        }
    }

    /// Executed by the Load-Store CFU?
    #[inline]
    pub(crate) fn is_mem(&self) -> bool {
        matches!(
            self.op,
            Op::Ld | Op::St | Op::LmLd | Op::LmSt | Op::LmLd4 | Op::LmSt4 | Op::BlkLd | Op::BlkSt
        )
    }

    /// Occupies the GM port?
    #[inline]
    pub(crate) fn is_gm(&self) -> bool {
        matches!(self.op, Op::Ld | Op::St | Op::BlkLd | Op::BlkSt)
    }

    /// Floating-point operations — mirrors [`Instr::flops`].
    #[inline]
    pub(crate) fn flops(&self) -> u64 {
        match self.op {
            Op::Fadd | Op::Fsub | Op::Fmul | Op::Fdiv | Op::Fsqrt => 1,
            Op::Fmac => 2,
            Op::Dot => {
                let (n, acc) = self.dot_params();
                n as u64 + (n as u64 - 1) + if acc { 1 } else { 0 }
            }
            _ => 0,
        }
    }
}

/// A validated, feature-checked, pre-decoded instruction stream bound to
/// one [`AeLevel`]. Produced once per cached program by
/// [`DecodedProgram::decode`]; consumed by both tiers of the execution
/// engine ([`Pe::run_decoded`] and [`Pe::replay`]).
///
/// [`Pe::run_decoded`]: super::core::Pe::run_decoded
/// [`Pe::replay`]: super::core::Pe::replay
#[derive(Debug, Clone, PartialEq)]
pub struct DecodedProgram {
    ae: AeLevel,
    ops: Vec<PackedOp>,
    /// `Li` immediates, indexed by the op's `addr` field.
    consts: Vec<f64>,
    /// Block-transfer descriptors `(lm, gm, len)`, indexed by `addr`.
    blocks: Vec<(u32, u32, u32)>,
}

impl DecodedProgram {
    /// Validate `prog` (static constraints *and* the feature gates of
    /// `ae`) and lower it into the packed form. The stream is truncated
    /// at the first `Halt`, where the sequencer would stop anyway.
    ///
    /// This is the *only* validation point of the two-tier engine: it
    /// runs once per cached program instead of once per request, and a
    /// rejected program never reaches either interpreter.
    pub fn decode(prog: &Program, ae: AeLevel) -> Result<Self, String> {
        prog.validate()?;
        let mut ops = Vec::with_capacity(prog.len());
        let mut consts = Vec::new();
        let mut blocks = Vec::new();
        for ins in &prog.instrs {
            // Feature gates, with the loud messages Pe::run always had.
            match ins {
                Instr::LmLd { .. } | Instr::LmSt { .. } | Instr::BlkLd { .. }
                | Instr::BlkSt { .. }
                    if !ae.has_lm() =>
                {
                    return Err(format!("{ins:?} requires AE1 Local Memory (config is {ae})"))
                }
                Instr::LmLd4 { .. } | Instr::LmSt4 { .. } if !ae.has_wide_path() => {
                    return Err(format!("{ins:?} requires AE4 wide path (config is {ae})"))
                }
                Instr::Dot { .. } if !ae.has_dot() => {
                    return Err(format!("{ins:?} requires AE2 DOT RDP (config is {ae})"))
                }
                _ => {}
            }
            let packed = match *ins {
                Instr::Halt => break,
                Instr::Ld { rd, gm } => PackedOp::new(Op::Ld, rd, 0, 0, gm),
                Instr::St { rs, gm } => PackedOp::new(Op::St, rs, 0, 0, gm),
                Instr::LmLd { rd, lm } => PackedOp::new(Op::LmLd, rd, 0, 0, lm),
                Instr::LmSt { rs, lm } => PackedOp::new(Op::LmSt, rs, 0, 0, lm),
                Instr::LmLd4 { rd, lm } => PackedOp::new(Op::LmLd4, rd, 0, 0, lm),
                Instr::LmSt4 { rs, lm } => PackedOp::new(Op::LmSt4, rs, 0, 0, lm),
                Instr::BlkLd { lm, gm, len } => {
                    blocks.push((lm, gm, len));
                    PackedOp::new(Op::BlkLd, 0, 0, 0, (blocks.len() - 1) as u32)
                }
                Instr::BlkSt { lm, gm, len } => {
                    blocks.push((lm, gm, len));
                    PackedOp::new(Op::BlkSt, 0, 0, 0, (blocks.len() - 1) as u32)
                }
                Instr::Fadd { rd, ra, rb } => PackedOp::new(Op::Fadd, rd, ra, rb, 0),
                Instr::Fsub { rd, ra, rb } => PackedOp::new(Op::Fsub, rd, ra, rb, 0),
                Instr::Fmul { rd, ra, rb } => PackedOp::new(Op::Fmul, rd, ra, rb, 0),
                Instr::Fdiv { rd, ra, rb } => PackedOp::new(Op::Fdiv, rd, ra, rb, 0),
                Instr::Fsqrt { rd, ra } => PackedOp::new(Op::Fsqrt, rd, ra, 0, 0),
                Instr::Fmac { rd, ra, rb } => PackedOp::new(Op::Fmac, rd, ra, rb, 0),
                Instr::Dot { rd, ra, rb, n, acc } => {
                    PackedOp::new(Op::Dot, rd, ra, rb, n as u32 | ((acc as u32) << 8))
                }
                Instr::Li { rd, val } => {
                    consts.push(val);
                    PackedOp::new(Op::Li, rd, 0, 0, (consts.len() - 1) as u32)
                }
                Instr::Nop => PackedOp::new(Op::Nop, 0, 0, 0, 0),
                Instr::Barrier => PackedOp::new(Op::Barrier, 0, 0, 0, 0),
            };
            ops.push(packed);
        }
        Ok(Self { ae, ops, consts, blocks })
    }

    /// The enhancement level this stream was decoded (and feature-checked)
    /// for. Executing it on a PE configured differently is a hard error.
    pub fn ae(&self) -> AeLevel {
        self.ae
    }

    /// Number of decoded operations (the executed prefix of the program:
    /// everything before the first `Halt`).
    pub fn len(&self) -> usize {
        self.ops.len()
    }

    /// True if the program halts immediately.
    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }

    /// Resident size of the packed representation in bytes (ops + side
    /// tables) — the compaction the decode pass buys over `Vec<Instr>`.
    pub fn packed_bytes(&self) -> usize {
        self.ops.len() * std::mem::size_of::<PackedOp>()
            + self.consts.len() * std::mem::size_of::<f64>()
            + self.blocks.len() * std::mem::size_of::<(u32, u32, u32)>()
    }

    #[inline]
    pub(crate) fn ops(&self) -> &[PackedOp] {
        &self.ops
    }

    #[inline]
    pub(crate) fn const_at(&self, idx: u32) -> f64 {
        self.consts[idx as usize]
    }

    #[inline]
    pub(crate) fn block_at(&self, idx: u32) -> (usize, usize, usize) {
        let (lm, gm, len) = self.blocks[idx as usize];
        (lm as usize, gm as usize, len as usize)
    }
}

/// How a [`ScheduledProgram`] should be executed on a PE.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecMode {
    /// Tier-2 fast path: once the program's timing has been memoized by a
    /// first combined run, execute values only and reuse the stats.
    Replay,
    /// Always run the combined value+timing interpreter (the tier-1 pass,
    /// forced every time) — the reference the replay path is pinned to.
    Combined,
}

/// Which interpreter tier actually executed a [`ScheduledProgram`] —
/// reported by [`ScheduledProgram::execute_traced`] so callers (the pool's
/// telemetry) count what really ran, not what they predicted.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecTier {
    /// Tier-2 value-only replay against the memoized schedule.
    Replayed,
    /// Combined value+timing interpreter: first run of the program,
    /// [`ExecMode::Combined`], or a PE whose [`PeConfig`] differs from the
    /// one the schedule was taken under.
    Combined,
}

/// A pre-decoded program plus its memoized one-time schedule: the unit
/// the serving engine's [`ProgramCache`] stores and pool workers execute.
///
/// The first [`execute`](Self::execute) runs the cycle-accurate combined
/// interpreter and memoizes its [`PeStats`]; every later `Replay`-mode
/// execution runs the lean value-only interpreter and returns the
/// memoized stats. PE timing is operand-independent, so the memoized
/// stats equal a fresh combined run bit-for-bit (pinned by the
/// randomized equivalence tests).
///
/// [`ProgramCache`]: crate::coordinator::ProgramCache
#[derive(Debug)]
pub struct ScheduledProgram {
    decoded: DecodedProgram,
    /// The memoized schedule *and the full [`PeConfig`] it was taken
    /// under* — timing depends on every latency/port parameter, not just
    /// the AE level, so replay only trusts the memo on a config-identical
    /// PE. Filled by the first combined run; thread-safe so concurrent
    /// pool workers racing on a fresh program all produce (identical)
    /// stats and the first one wins.
    stats: OnceLock<(PeConfig, PeStats)>,
}

impl ScheduledProgram {
    /// Decode (and validate) `prog` for `ae`; the timing pass runs lazily
    /// on first execution.
    pub fn compile(prog: &Program, ae: AeLevel) -> Result<Self, String> {
        Ok(Self { decoded: DecodedProgram::decode(prog, ae)?, stats: OnceLock::new() })
    }

    /// The packed instruction stream.
    pub fn decoded(&self) -> &DecodedProgram {
        &self.decoded
    }

    /// The enhancement level the program was decoded for.
    pub fn ae(&self) -> AeLevel {
        self.decoded.ae()
    }

    /// The memoized timing of this program, if the schedule pass ran.
    pub fn scheduled_stats(&self) -> Option<&PeStats> {
        self.stats.get().map(|(_, st)| st)
    }

    /// The [`PeConfig`] the memoized schedule was taken under, if any.
    pub fn scheduled_config(&self) -> Option<&PeConfig> {
        self.stats.get().map(|(cfg, _)| cfg)
    }

    /// True once the one-time timing pass has run.
    pub fn is_scheduled(&self) -> bool {
        self.stats.get().is_some()
    }

    /// Execute on `pe` (whose GM must already hold this kernel's packed
    /// operands) and return the program's stats. See
    /// [`execute_traced`](Self::execute_traced).
    pub fn execute(&self, pe: &mut Pe, mode: ExecMode) -> PeStats {
        self.execute_traced(pe, mode).0
    }

    /// Execute on `pe` and also report which tier actually ran.
    ///
    /// In [`ExecMode::Replay`], a program scheduled under a [`PeConfig`]
    /// equal to `pe.cfg` runs the value-only tier and returns the
    /// memoized stats ([`ExecTier::Replayed`]). Otherwise — first
    /// execution, [`ExecMode::Combined`], or a config mismatch — the
    /// combined interpreter runs and its (config, stats) pair is memoized
    /// if the slot is still empty ([`ExecTier::Combined`]). Values in GM
    /// are bit-identical either way, and the returned stats always match
    /// a fresh combined run on the same PE.
    pub fn execute_traced(&self, pe: &mut Pe, mode: ExecMode) -> (PeStats, ExecTier) {
        if mode == ExecMode::Replay {
            if let Some((cfg, st)) = self.stats.get() {
                if *cfg == pe.cfg {
                    pe.replay(&self.decoded);
                    return (st.clone(), ExecTier::Replayed);
                }
            }
        }
        let st = pe.run_decoded(&self.decoded);
        let _ = self.stats.set((pe.cfg.clone(), st.clone()));
        (st, ExecTier::Combined)
    }

    /// Tier-2b batched execution: if this program's schedule is memoized
    /// under a config equal to `cfg`, advance every context in `ctxs`
    /// through one fused pass ([`super::core::replay_batch`]) and return
    /// the memoized stats (identical for every member — timing is
    /// operand-independent). Returns `None` and touches nothing when the
    /// memo is missing or was taken under a different config; the caller
    /// then falls back to per-member [`Self::execute_traced`], exactly as
    /// a cold single replay would.
    pub fn replay_batch_scheduled(
        &self,
        ctxs: &mut [ReplayCtx],
        cfg: &PeConfig,
    ) -> Option<PeStats> {
        match self.stats.get() {
            Some((scfg, st)) if scfg == cfg => {
                super::core::replay_batch(ctxs, &self.decoded);
                Some(st.clone())
            }
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pe::config::PeConfig;
    use crate::pe::isa::Instr as I;

    #[test]
    fn packed_op_is_eight_bytes() {
        assert_eq!(std::mem::size_of::<PackedOp>(), 8, "common ops must pack to ≤8 bytes");
    }

    #[test]
    fn decode_truncates_at_halt_and_fills_side_tables() {
        let mut p = Program::new();
        p.push(I::Li { rd: 0, val: 2.5 });
        p.push(I::BlkLd { lm: 0, gm: 4, len: 8 });
        p.push(I::Dot { rd: 8, ra: 0, rb: 4, n: 3, acc: true });
        p.push(I::Halt);
        p.push(I::Fadd { rd: 1, ra: 0, rb: 0 }); // dead: after Halt
        let d = DecodedProgram::decode(&p, AeLevel::Ae5).unwrap();
        assert_eq!(d.len(), 3, "Halt truncates; dead tail dropped");
        assert_eq!(d.const_at(0), 2.5);
        assert_eq!(d.block_at(0), (0, 4, 8));
        let (n, acc) = d.ops()[2].dot_params();
        assert_eq!((n, acc), (3, true));
        assert!(d.packed_bytes() < 3 * std::mem::size_of::<Instr>());
    }

    #[test]
    fn decode_rejects_feature_misuse() {
        let mut p = Program::new();
        p.push(I::Dot { rd: 0, ra: 0, rb: 4, n: 4, acc: false });
        p.push(I::Halt);
        let err = DecodedProgram::decode(&p, AeLevel::Ae1).unwrap_err();
        assert!(err.contains("requires AE2"), "got: {err}");
        assert!(DecodedProgram::decode(&p, AeLevel::Ae2).is_ok());

        let mut p = Program::new();
        p.push(I::LmLd { rd: 0, lm: 0 });
        let err = DecodedProgram::decode(&p, AeLevel::Ae0).unwrap_err();
        assert!(err.contains("requires AE1"), "got: {err}");

        let mut p = Program::new();
        p.push(I::LmLd4 { rd: 0, lm: 0 });
        let err = DecodedProgram::decode(&p, AeLevel::Ae3).unwrap_err();
        assert!(err.contains("requires AE4"), "got: {err}");
    }

    #[test]
    fn decode_rejects_invalid_programs() {
        let mut p = Program::new();
        p.push(I::Fadd { rd: 63, ra: 64, rb: 0 });
        assert!(DecodedProgram::decode(&p, AeLevel::Ae5).is_err());
    }

    #[test]
    fn packed_hazard_sets_match_instr_sets() {
        // The packed src/dst extraction must mirror Instr's exactly —
        // same registers, same order — for every opcode shape.
        let cases: Vec<Instr> = vec![
            I::Ld { rd: 3, gm: 9 },
            I::St { rs: 4, gm: 9 },
            I::LmLd { rd: 5, lm: 2 },
            I::LmSt { rs: 6, lm: 2 },
            I::LmLd4 { rd: 8, lm: 4 },
            I::LmSt4 { rs: 12, lm: 4 },
            I::Fadd { rd: 1, ra: 2, rb: 3 },
            I::Fsub { rd: 1, ra: 2, rb: 3 },
            I::Fmul { rd: 1, ra: 2, rb: 3 },
            I::Fdiv { rd: 1, ra: 2, rb: 3 },
            I::Fsqrt { rd: 1, ra: 2 },
            I::Fmac { rd: 1, ra: 2, rb: 3 },
            I::Dot { rd: 0, ra: 4, rb: 8, n: 3, acc: true },
            I::Dot { rd: 0, ra: 4, rb: 8, n: 2, acc: false },
            I::Li { rd: 7, val: 1.0 },
            I::Nop,
            I::Barrier,
        ];
        for ins in cases {
            let mut p = Program::new();
            p.push(ins);
            let d = DecodedProgram::decode(&p, AeLevel::Ae5).unwrap();
            let op = d.ops()[0];
            let (mut s12, mut d4) = ([0u8; 12], [0u8; 4]);
            let (ns, nd) = (op.srcs_into(&mut s12), op.dsts_into(&mut d4));
            let (mut is12, mut id4) = ([0u8; 12], [0u8; 4]);
            let (ins_ns, ins_nd) = (ins.srcs_into(&mut is12), ins.dsts_into(&mut id4));
            assert_eq!(&s12[..ns], &is12[..ins_ns], "{ins:?} srcs");
            assert_eq!(&d4[..nd], &id4[..ins_nd], "{ins:?} dsts");
            assert_eq!(op.flops(), ins.flops(), "{ins:?} flops");
            assert_eq!(op.is_mem(), ins.is_mem(), "{ins:?} is_mem");
        }
    }

    #[test]
    fn config_mismatch_falls_back_to_combined() {
        // The schedule depends on the full PeConfig, not just the AE
        // level: a same-AE PE with different timing parameters must not
        // be handed the memoized stats — it re-runs the combined
        // interpreter (correct values AND correct timing), while the memo
        // keeps serving config-identical PEs.
        let mut p = Program::new();
        p.push(I::Li { rd: 0, val: 2.0 });
        p.push(I::Fmul { rd: 1, ra: 0, rb: 0 });
        p.push(I::St { rs: 1, gm: 0 });
        p.push(I::Halt);
        let sched = ScheduledProgram::compile(&p, AeLevel::Ae0).unwrap();
        let mut pe = Pe::new(PeConfig::paper(AeLevel::Ae0), 4);
        let st_paper = sched.execute(&mut pe, ExecMode::Replay);
        assert_eq!(sched.scheduled_config(), Some(&PeConfig::paper(AeLevel::Ae0)));

        let mut slow_cfg = PeConfig::paper(AeLevel::Ae0);
        slow_cfg.lat_mul += 7;
        let mut slow = Pe::new(slow_cfg, 4);
        let (st_slow, tier) = sched.execute_traced(&mut slow, ExecMode::Replay);
        assert_eq!(tier, ExecTier::Combined, "config mismatch must not replay");
        assert!(st_slow.cycles > st_paper.cycles, "slower multiplier must cost cycles");
        assert_eq!(slow.read_gm(0, 1)[0], 4.0);

        // The memo still belongs to (and serves) the original config.
        let mut pe2 = Pe::new(PeConfig::paper(AeLevel::Ae0), 4);
        let (st2, tier2) = sched.execute_traced(&mut pe2, ExecMode::Replay);
        assert_eq!(tier2, ExecTier::Replayed);
        assert_eq!(st2, st_paper);
    }

    #[test]
    fn schedule_memoizes_once_and_replays() {
        let mut p = Program::new();
        p.push(I::Li { rd: 0, val: 3.0 });
        p.push(I::Li { rd: 1, val: 4.0 });
        p.push(I::Fmul { rd: 2, ra: 0, rb: 1 });
        p.push(I::St { rs: 2, gm: 0 });
        p.push(I::Halt);
        let sched = ScheduledProgram::compile(&p, AeLevel::Ae0).unwrap();
        assert!(!sched.is_scheduled());
        let mut pe = Pe::new(PeConfig::paper(AeLevel::Ae0), 16);
        let st1 = sched.execute(&mut pe, ExecMode::Replay); // combined pass
        assert!(sched.is_scheduled());
        assert_eq!(pe.read_gm(0, 1)[0], 12.0);
        pe.reset(16);
        let st2 = sched.execute(&mut pe, ExecMode::Replay); // lean replay
        assert_eq!(pe.read_gm(0, 1)[0], 12.0);
        assert_eq!(st1, st2, "memoized stats must equal the combined run");
        pe.reset(16);
        let st3 = sched.execute(&mut pe, ExecMode::Combined); // forced re-run
        assert_eq!(st1, st3);
    }
}
