//! The Processing Element (PE): ISA, configuration, and cycle-accurate
//! simulator — the custom-hardware substrate of the paper (§4.4–§5.4).
//!
//! The paper evaluated an RTL-level PE model; we substitute a cycle-accurate
//! software model (see DESIGN.md substitution ledger). The simulator is both
//! *functional* (executes real f64 values, so kernels are numerically
//! validated) and *timing* (reproduces the latency/CPF/Gflops-per-watt
//! tables through pipeline, scoreboard, port and queue modelling).
//!
//! Execution is **two-tier** ([`decoded`]): programs are validated and
//! lowered once into a compact pre-decoded stream, the cycle-accurate
//! timing model runs once per cached program ([`Pe::run_decoded`]), and
//! every later request replays values only ([`Pe::replay`]) against the
//! memoized [`PeStats`] schedule ([`ScheduledProgram`]).

pub mod config;
pub mod core;
pub mod decoded;
pub mod isa;

pub use config::{AeLevel, ArithKind, PeConfig};
pub use core::{replay_batch, Pe, PeStats, ReplayCtx};
pub use decoded::{DecodedProgram, ExecMode, ExecTier, ScheduledProgram};
pub use isa::{Addr, Instr, Program, Reg, DOT_PIPELINE_DEPTH, LM_WORDS, NUM_REGS};
