//! Cycle-accurate PE simulator core — the two-tier execution engine.
//!
//! The PE is an in-order, single-issue sequencer (FPS) with a register
//! scoreboard, pipelined arithmetic units, a DOT RDP, and a decoupled
//! Load-Store CFU owning the LM scratchpad and the GM port (§4.4–§5.3).
//!
//! Timing model: for an in-order machine, the issue time of instruction i is
//!
//! ```text
//! t(i) = max( t(i-1) + 1,                  -- single issue
//!             ready(srcs), ready(dst),     -- RAW + WAW scoreboard
//!             fu_free(kind),               -- structural (div/sqrt iterative)
//!             queue_space(LS engine) )     -- LSQ back-pressure
//! ```
//!
//! computed in one pass over the program (O(1) per instruction). Registers
//! are read at issue, so WAR hazards cannot occur in order. Memory ops are
//! granted their port in program order; completion times respect port
//! occupancy, GM pipeline latency (20 stages, §4.5) and block-transfer
//! ordering. This is exactly the fixed-point of a cycle-by-cycle simulation
//! of the same machine, evaluated directly.
//!
//! The simulator is *functional + timing*, and the two concerns are split
//! into tiers over one shared decode ([`super::decoded`]):
//!
//! * [`Pe::run_decoded`] — the **combined** interpreter: executes real f64
//!   values *and* the full timing model over a pre-decoded stream. Run
//!   once per cached program, it yields the program's [`PeStats`]
//!   schedule (timing is operand-independent).
//! * [`Pe::replay`] — the **value-only** interpreter: no scoreboard, no
//!   queues, no stall attribution — just the data path. Bit-identical
//!   values at a fraction of the cost; the serving engine's cache-hit
//!   path.
//! * [`replay_batch`] — the **operand-batched** value interpreter
//!   (tier 2b): one pass over the decoded stream advances N independent
//!   [`ReplayCtx`] operand contexts, amortizing decode iteration and
//!   dispatch across a batch of same-kernel requests while staying
//!   bit-identical to N single replays.
//! * [`Pe::run`] — convenience one-shot: decode + combined run, the
//!   historical entry point (validation now always happens, once, in the
//!   decode).

use super::config::{AeLevel, ArithKind, PeConfig};
use super::decoded::{DecodedProgram, Op};
use super::isa::{Program, NUM_REGS};
use std::collections::VecDeque;

/// Why an issue slot was lost (for the stall breakdown profile).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StallCause {
    RawDep,
    WawDep,
    FuBusy,
    LsqFull,
    MemWindow,
}

/// Cycle/energy/traffic statistics of one program execution.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct PeStats {
    /// Total latency in clock cycles (issue of first instruction to last
    /// completion — what Tables 4–9 report).
    pub cycles: u64,
    /// Instructions issued (excluding Halt).
    pub instructions: u64,
    /// Floating-point operations executed (standard 1-flop convention).
    pub flops: u64,
    /// DOT instructions executed (denominator of the paper's α, eq. 7).
    pub dot_ops: u64,
    /// Scalar mul/add/mac/div/sqrt operations.
    pub scalar_fu_ops: u64,
    /// Words moved over the GM port.
    pub gm_words: u64,
    /// GM requests (handshakes) — blocks count once at AE3+.
    pub gm_requests: u64,
    /// Words moved over the LM port.
    pub lm_words: u64,
    /// Register-file accesses (reads + writes).
    pub rf_accesses: u64,
    /// Issue-stall cycles by cause.
    pub stall_raw: u64,
    pub stall_waw: u64,
    pub stall_fu: u64,
    pub stall_lsq: u64,
    pub stall_mem_window: u64,
    /// Cycles the GM port was busy (for overlap accounting, fig 11(b)).
    pub gm_busy_cycles: u64,
    /// Cycles the LM port was busy.
    pub lm_busy_cycles: u64,
}

impl PeStats {
    /// Cycles-per-flop with the standard 2n³-style flop count (eq. 1).
    pub fn cpf(&self) -> f64 {
        self.cycles as f64 / self.flops.max(1) as f64
    }

    /// Flops-per-cycle (eq. 2).
    pub fn fpc(&self) -> f64 {
        1.0 / self.cpf()
    }

    /// Total issue stalls.
    pub fn stalls(&self) -> u64 {
        self.stall_raw + self.stall_waw + self.stall_fu + self.stall_lsq + self.stall_mem_window
    }

    /// Wall-clock seconds at the configured PE frequency.
    pub fn seconds(&self, cfg: &PeConfig) -> f64 {
        self.cycles as f64 * cfg.cycle_ns() * 1e-9
    }

    /// Achieved Gflops at the configured PE frequency.
    pub fn gflops(&self, cfg: &PeConfig) -> f64 {
        self.flops as f64 / self.seconds(cfg) / 1e9
    }
}

/// Recent-writes ring used for coarse memory ordering between block engines
/// and scalar accesses (a block fill must complete before a dependent read).
#[derive(Debug, Clone)]
struct RecentWrites {
    ring: VecDeque<(u64, u64, u64)>, // (start, end, ready_cycle)
    cap: usize,
    /// Conservative floor: completion of the oldest evicted entry.
    evicted_ready: u64,
}

impl RecentWrites {
    fn new(cap: usize) -> Self {
        Self { ring: VecDeque::with_capacity(cap), cap, evicted_ready: 0 }
    }

    fn record(&mut self, start: u64, len: u64, ready: u64) {
        if self.ring.len() == self.cap {
            if let Some((_, _, r)) = self.ring.pop_front() {
                self.evicted_ready = self.evicted_ready.max(r);
            }
        }
        self.ring.push_back((start, start + len, ready));
    }

    /// Earliest cycle a read of [start, start+len) may be serviced.
    fn ready_for(&self, start: u64, len: u64) -> u64 {
        let end = start + len;
        let mut t = self.evicted_ready;
        for &(s, e, r) in &self.ring {
            if start < e && s < end {
                t = t.max(r);
            }
        }
        t
    }
}

/// The PE machine: global memory, local memory, register file, and the
/// timing state of one execution.
pub struct Pe {
    pub cfg: PeConfig,
    pub gm: Vec<f64>,
    lm: Vec<f64>,
    regs: [f64; NUM_REGS],
}

impl Pe {
    /// Build a PE over a global memory of `gm_words` f64 words.
    pub fn new(cfg: PeConfig, gm_words: usize) -> Self {
        Self {
            cfg,
            gm: vec![0.0; gm_words],
            lm: vec![0.0; super::isa::LM_WORDS],
            regs: [0.0; NUM_REGS],
        }
    }

    /// Reset the architectural state (GM resized to `gm_words` and zeroed,
    /// LM and register file zeroed) so one PE instance can be reused across
    /// kernels — the persistent-worker path of the serving engine. A reset
    /// PE is bit-identical to a freshly constructed one, which the
    /// determinism tests rely on.
    pub fn reset(&mut self, gm_words: usize) {
        self.gm.clear();
        self.gm.resize(gm_words, 0.0);
        self.lm.fill(0.0);
        self.regs = [0.0; NUM_REGS];
    }

    /// Load data into GM at a word offset.
    pub fn write_gm(&mut self, offset: usize, data: &[f64]) {
        self.gm[offset..offset + data.len()].copy_from_slice(data);
    }

    /// Read back a GM region.
    pub fn read_gm(&self, offset: usize, len: usize) -> &[f64] {
        &self.gm[offset..offset + len]
    }

    /// Read back an LM region (introspection for tests/debugging).
    pub fn read_lm(&self, offset: usize, len: usize) -> &[f64] {
        &self.lm[offset..offset + len]
    }

    /// The architectural register file (introspection for tests/debugging).
    pub fn regs(&self) -> &[f64; NUM_REGS] {
        &self.regs
    }

    /// Execute a program to completion, returning its statistics.
    ///
    /// One-shot path: decodes (which validates the stream and the AE
    /// feature gates — codegen bugs stay loud) and runs the combined
    /// value+timing interpreter. Callers executing one cached program many
    /// times should decode once ([`super::ScheduledProgram`]) and
    /// [`Pe::replay`] instead.
    pub fn run(&mut self, prog: &Program) -> PeStats {
        let decoded = DecodedProgram::decode(prog, self.cfg.ae)
            .unwrap_or_else(|e| panic!("invalid PE program: {e}"));
        self.run_decoded(&decoded)
    }

    /// Tier-1 **combined** interpreter: execute values and the full
    /// cycle-accurate timing model over a pre-decoded stream.
    ///
    /// The returned [`PeStats`] depend only on the program and the PE
    /// configuration — never on operand values — which is what makes the
    /// schedule memoizable. Panics if `prog` was decoded for a different
    /// enhancement level than this PE is configured for.
    pub fn run_decoded(&mut self, prog: &DecodedProgram) -> PeStats {
        assert_eq!(
            self.cfg.ae,
            prog.ae(),
            "program decoded for {} cannot execute on a {} PE",
            prog.ae(),
            self.cfg.ae
        );
        let Self { cfg, gm, lm, regs } = self;
        let ae = cfg.ae;

        let mut st = PeStats::default();
        // Scoreboard: cycle at which each register's pending write lands.
        let mut reg_ready = [0u64; NUM_REGS];
        // Per-FU next-free cycle (structural hazards).
        let mut fu_free = [0u64; 6];
        // Port timelines.
        let mut gm_port_free: u64 = 0;
        let mut lm_port_free: u64 = 0;
        // LS queues: completion times of in-flight ops per engine.
        let mut gm_q: VecDeque<u64> = VecDeque::new();
        let mut lm_q: VecDeque<u64> = VecDeque::new();
        // Memory-ordering state.
        let mut lm_writes = RecentWrites::new(16);
        let mut gm_writes = RecentWrites::new(16);

        let mut t: u64 = 0; // issue cycle of the current instruction
        let mut finish: u64 = 0; // completion high-water mark
        let mut srcs = [0u8; 12];
        let mut dsts = [0u8; 4];

        for op in prog.ops() {
            let ns = op.srcs_into(&mut srcs);
            let nd = op.dsts_into(&mut dsts);
            let srcs = &srcs[..ns];
            let dsts = &dsts[..nd];

            // Earliest legal issue cycle and the binding constraint.
            let base = t; // t is already (prev issue + 1) from the update below
            let mut ready = base;
            let mut cause: Option<StallCause> = None;
            for &r in srcs {
                if reg_ready[r as usize] > ready {
                    ready = reg_ready[r as usize];
                    cause = Some(StallCause::RawDep);
                }
            }
            for &r in dsts {
                if reg_ready[r as usize] > ready {
                    ready = reg_ready[r as usize];
                    cause = Some(StallCause::WawDep);
                }
            }
            if let Some(kind) = op.arith_kind() {
                let f = fu_free[kind as usize];
                if f > ready {
                    ready = f;
                    cause = Some(StallCause::FuBusy);
                }
            }
            if op.is_mem() {
                let (q, depth) = if op.is_gm() {
                    (
                        &mut gm_q,
                        if ae == AeLevel::Ae0 { cfg.ae0_mem_window as usize } else { cfg.lsq_depth },
                    )
                } else {
                    (&mut lm_q, cfg.lsq_depth)
                };
                while let Some(&c) = q.front() {
                    if c <= ready {
                        q.pop_front();
                    } else {
                        break;
                    }
                }
                if q.len() >= depth {
                    // Wait for the oldest in-flight op to drain.
                    let c = *q.front().unwrap();
                    if c > ready {
                        ready = c;
                        cause = Some(if ae == AeLevel::Ae0 && op.is_gm() {
                            StallCause::MemWindow
                        } else {
                            StallCause::LsqFull
                        });
                    }
                    while let Some(&c2) = q.front() {
                        if c2 <= ready {
                            q.pop_front();
                        } else {
                            break;
                        }
                    }
                }
            }

            let issue = ready;
            if issue > base {
                let stall = issue - base;
                match cause {
                    Some(StallCause::RawDep) => st.stall_raw += stall,
                    Some(StallCause::WawDep) => st.stall_waw += stall,
                    Some(StallCause::FuBusy) => st.stall_fu += stall,
                    Some(StallCause::LsqFull) => st.stall_lsq += stall,
                    Some(StallCause::MemWindow) => st.stall_mem_window += stall,
                    None => {}
                }
            }

            st.instructions += 1;
            st.flops += op.flops();
            st.rf_accesses += (srcs.len() + dsts.len()) as u64;

            // Execute (values) + schedule (timing).
            let a = op.a as usize;
            let done = match op.op {
                Op::Li => {
                    regs[a] = prog.const_at(op.addr);
                    let done = issue + 1;
                    reg_ready[a] = done;
                    done
                }
                Op::Nop => issue + 1,
                Op::Barrier => {
                    // Loop-edge stall: the simple sequencer waits for every
                    // FPS-visible operation (register writebacks, scalar
                    // loads/stores) before fetching the next iteration. The
                    // LS CFU's autonomous block engine is NOT drained — it
                    // keeps streaming across iterations (§5.1 overlap).
                    let mut drain = issue;
                    for &r in reg_ready.iter() {
                        drain = drain.max(r);
                    }
                    for &c in gm_q.iter().chain(lm_q.iter()) {
                        drain = drain.max(c);
                    }
                    gm_q.clear();
                    lm_q.clear();
                    t = drain; // next instruction issues after the drain
                    drain
                }
                Op::Fadd => arith(
                    regs, a, regs[op.b as usize] + regs[op.c as usize],
                    ArithKind::Add, issue, cfg, &mut reg_ready, &mut fu_free, &mut st,
                ),
                Op::Fsub => arith(
                    regs, a, regs[op.b as usize] - regs[op.c as usize],
                    ArithKind::Add, issue, cfg, &mut reg_ready, &mut fu_free, &mut st,
                ),
                Op::Fmul => arith(
                    regs, a, regs[op.b as usize] * regs[op.c as usize],
                    ArithKind::Mul, issue, cfg, &mut reg_ready, &mut fu_free, &mut st,
                ),
                Op::Fdiv => arith(
                    regs, a, regs[op.b as usize] / regs[op.c as usize],
                    ArithKind::Div, issue, cfg, &mut reg_ready, &mut fu_free, &mut st,
                ),
                Op::Fsqrt => arith(
                    regs, a, regs[op.b as usize].sqrt(),
                    ArithKind::Sqrt, issue, cfg, &mut reg_ready, &mut fu_free, &mut st,
                ),
                Op::Fmac => arith(
                    regs, a, regs[a] + regs[op.b as usize] * regs[op.c as usize],
                    ArithKind::Mac, issue, cfg, &mut reg_ready, &mut fu_free, &mut st,
                ),
                Op::Dot => {
                    let (w, acc) = op.dot_params();
                    let (b, c) = (op.b as usize, op.c as usize);
                    let mut s = if acc { regs[a] } else { 0.0 };
                    for i in 0..w as usize {
                        s += regs[b + i] * regs[c + i];
                    }
                    st.dot_ops += 1;
                    arith(regs, a, s, ArithKind::Dot, issue, cfg, &mut reg_ready, &mut fu_free, &mut st)
                }
                Op::Ld => {
                    let addr = op.addr as usize;
                    let after = gm_writes.ready_for(op.addr as u64, 1);
                    let grant = (issue + 1).max(gm_port_free).max(after);
                    let busy = (cfg.gm_req_overhead + cfg.gm_word_cycles) as u64;
                    gm_port_free = grant + busy;
                    st.gm_busy_cycles += busy;
                    st.gm_words += 1;
                    st.gm_requests += 1;
                    let done = grant + cfg.gm_latency as u64;
                    regs[a] = gm[addr];
                    reg_ready[a] = done;
                    gm_q.push_back(done);
                    done
                }
                Op::St => {
                    let addr = op.addr as usize;
                    let grant = (issue + 1).max(gm_port_free);
                    let busy = (cfg.gm_req_overhead + cfg.gm_word_cycles) as u64;
                    gm_port_free = grant + busy;
                    st.gm_busy_cycles += busy;
                    st.gm_words += 1;
                    st.gm_requests += 1;
                    let done = grant + cfg.gm_latency as u64;
                    gm[addr] = regs[a];
                    gm_writes.record(op.addr as u64, 1, done);
                    gm_q.push_back(done);
                    done
                }
                Op::LmLd => {
                    let addr = op.addr as usize;
                    let after = lm_writes.ready_for(op.addr as u64, 1);
                    let grant = (issue + 1).max(lm_port_free).max(after);
                    lm_port_free = grant + cfg.lm_word_cycles as u64;
                    st.lm_busy_cycles += cfg.lm_word_cycles as u64;
                    st.lm_words += 1;
                    let done = grant + cfg.lm_latency as u64;
                    regs[a] = lm[addr];
                    reg_ready[a] = done;
                    lm_q.push_back(done);
                    done
                }
                Op::LmSt => {
                    let addr = op.addr as usize;
                    let grant = (issue + 1).max(lm_port_free);
                    lm_port_free = grant + cfg.lm_word_cycles as u64;
                    st.lm_busy_cycles += cfg.lm_word_cycles as u64;
                    st.lm_words += 1;
                    let done = grant + cfg.lm_latency as u64;
                    lm[addr] = regs[a];
                    lm_writes.record(op.addr as u64, 1, done);
                    lm_q.push_back(done);
                    done
                }
                Op::LmLd4 => {
                    let addr = op.addr as usize;
                    let after = lm_writes.ready_for(op.addr as u64, 4);
                    let grant = (issue + 1).max(lm_port_free).max(after);
                    lm_port_free = grant + cfg.lm_wide_cycles as u64;
                    st.lm_busy_cycles += cfg.lm_wide_cycles as u64;
                    st.lm_words += 4;
                    let done = grant + cfg.lm_latency as u64;
                    for i in 0..4 {
                        regs[a + i] = lm[addr + i];
                        reg_ready[a + i] = done;
                    }
                    lm_q.push_back(done);
                    done
                }
                Op::LmSt4 => {
                    let addr = op.addr as usize;
                    let grant = (issue + 1).max(lm_port_free);
                    lm_port_free = grant + cfg.lm_wide_cycles as u64;
                    st.lm_busy_cycles += cfg.lm_wide_cycles as u64;
                    st.lm_words += 4;
                    let done = grant + cfg.lm_latency as u64;
                    lm[addr..addr + 4].copy_from_slice(&regs[a..a + 4]);
                    lm_writes.record(op.addr as u64, 4, done);
                    lm_q.push_back(done);
                    done
                }
                Op::BlkLd => {
                    // GM -> LM block move by the LS CFU's autonomous block
                    // engine: it runs across loop barriers (the CFU
                    // "operates simultaneously with FPS", §5.1). At AE3+ a
                    // single handshake covers the block; before AE3 the
                    // engine pays a per-word GM handshake (§5.2.2). LM
                    // writes stream at one word/cycle and are charged to the
                    // LM port as *debt* behind which scalar accesses queue
                    // (single-ported SRAM), without blocking the GM stream.
                    let (lm_a, gm_a, len) = prog.block_at(op.addr);
                    let len64 = len as u64;
                    let after = gm_writes.ready_for(gm_a as u64, len64);
                    let grant = (issue + 1).max(gm_port_free).max(after);
                    let (gm_busy, reqs) = if ae.has_block_ldst() {
                        (cfg.gm_req_overhead as u64 + len64 * cfg.gm_word_cycles as u64, 1)
                    } else {
                        (len64 * (cfg.gm_req_overhead + cfg.gm_word_cycles) as u64, len64)
                    };
                    // With the AE4 wide path the SRAM port takes whole
                    // 256-bit lines from the block engine (len/4 cycles).
                    let lm_busy = if ae.has_wide_path() { len64.div_ceil(4) } else { len64 };
                    gm_port_free = grant + gm_busy;
                    lm_port_free = lm_port_free.max(grant) + lm_busy;
                    st.gm_busy_cycles += gm_busy;
                    st.lm_busy_cycles += lm_busy;
                    st.gm_words += len64;
                    st.gm_requests += reqs;
                    st.lm_words += len64;
                    let done = grant + cfg.gm_latency as u64 + gm_busy;
                    lm[lm_a..lm_a + len].copy_from_slice(&gm[gm_a..gm_a + len]);
                    lm_writes.record(lm_a as u64, len64, done);
                    done
                }
                Op::BlkSt => {
                    let (lm_a, gm_a, len) = prog.block_at(op.addr);
                    let len64 = len as u64;
                    let after = lm_writes.ready_for(lm_a as u64, len64);
                    let grant = (issue + 1).max(gm_port_free).max(after);
                    let (gm_busy, reqs) = if ae.has_block_ldst() {
                        (cfg.gm_req_overhead as u64 + len64 * cfg.gm_word_cycles as u64, 1)
                    } else {
                        (len64 * (cfg.gm_req_overhead + cfg.gm_word_cycles) as u64, len64)
                    };
                    let lm_busy = if ae.has_wide_path() { len64.div_ceil(4) } else { len64 };
                    gm_port_free = grant + gm_busy;
                    lm_port_free = lm_port_free.max(grant) + lm_busy;
                    st.gm_busy_cycles += gm_busy;
                    st.lm_busy_cycles += lm_busy;
                    st.gm_words += len64;
                    st.gm_requests += reqs;
                    st.lm_words += len64;
                    let done = grant + cfg.gm_latency as u64 + gm_busy;
                    gm[gm_a..gm_a + len].copy_from_slice(&lm[lm_a..lm_a + len]);
                    gm_writes.record(gm_a as u64, len64, done);
                    done
                }
            };

            finish = finish.max(done);
            t = t.max(issue + 1);
        }

        st.cycles = finish.max(t);
        st
    }

    /// Tier-2 **value-only replay**: execute just the data path of a
    /// pre-decoded stream — no scoreboard, no FU timelines, no LS queues,
    /// no stall attribution.
    ///
    /// Produces GM/LM/register state bit-identical to
    /// [`Pe::run_decoded`] on the same inputs (every f64 operation is
    /// evaluated in the same order with the same operands); the timing
    /// belongs to the program's memoized schedule, not to this call.
    /// Panics if `prog` was decoded for a different enhancement level.
    pub fn replay(&mut self, prog: &DecodedProgram) {
        assert_eq!(
            self.cfg.ae,
            prog.ae(),
            "program decoded for {} cannot execute on a {} PE",
            prog.ae(),
            self.cfg.ae
        );
        let Self { gm, lm, regs, .. } = self;
        for op in prog.ops() {
            let a = op.a as usize;
            match op.op {
                Op::Ld => regs[a] = gm[op.addr as usize],
                Op::St => gm[op.addr as usize] = regs[a],
                Op::LmLd => regs[a] = lm[op.addr as usize],
                Op::LmSt => lm[op.addr as usize] = regs[a],
                Op::LmLd4 => {
                    let addr = op.addr as usize;
                    regs[a..a + 4].copy_from_slice(&lm[addr..addr + 4]);
                }
                Op::LmSt4 => {
                    let addr = op.addr as usize;
                    lm[addr..addr + 4].copy_from_slice(&regs[a..a + 4]);
                }
                Op::BlkLd => {
                    let (lm_a, gm_a, len) = prog.block_at(op.addr);
                    lm[lm_a..lm_a + len].copy_from_slice(&gm[gm_a..gm_a + len]);
                }
                Op::BlkSt => {
                    let (lm_a, gm_a, len) = prog.block_at(op.addr);
                    gm[gm_a..gm_a + len].copy_from_slice(&lm[lm_a..lm_a + len]);
                }
                Op::Fadd => regs[a] = regs[op.b as usize] + regs[op.c as usize],
                Op::Fsub => regs[a] = regs[op.b as usize] - regs[op.c as usize],
                Op::Fmul => regs[a] = regs[op.b as usize] * regs[op.c as usize],
                Op::Fdiv => regs[a] = regs[op.b as usize] / regs[op.c as usize],
                Op::Fsqrt => regs[a] = regs[op.b as usize].sqrt(),
                Op::Fmac => regs[a] += regs[op.b as usize] * regs[op.c as usize],
                Op::Dot => {
                    let (w, acc) = op.dot_params();
                    let (b, c) = (op.b as usize, op.c as usize);
                    let mut s = if acc { regs[a] } else { 0.0 };
                    for i in 0..w as usize {
                        s += regs[b + i] * regs[c + i];
                    }
                    regs[a] = s;
                }
                Op::Li => regs[a] = prog.const_at(op.addr),
                Op::Nop | Op::Barrier => {}
            }
        }
    }
}

/// One request's architectural state for the **batched** replay tier: a
/// private GM window, LM scratchpad and register file, without the timing
/// machinery a full [`Pe`] carries. [`replay_batch`] advances N of these
/// through one shared decoded stream in a single pass.
///
/// Construction mirrors [`Pe::new`] / [`Pe::reset`] exactly (GM zeroed to
/// size, LM zeroed to [`super::isa::LM_WORDS`], registers zeroed), so a
/// context starts bit-identical to a fresh or reset PE — the property the
/// batched-replay equivalence tests pin.
pub struct ReplayCtx {
    /// The context's global-memory window (the packed operand image).
    pub gm: Vec<f64>,
    lm: Vec<f64>,
    regs: [f64; NUM_REGS],
}

impl ReplayCtx {
    /// Fresh context over a zeroed GM window of `gm_words` f64 words.
    pub fn new(gm_words: usize) -> Self {
        Self::from_gm(vec![0.0; gm_words])
    }

    /// Context over a pre-packed GM image (LM and registers zeroed) —
    /// equivalent to `Pe::reset(gm.len())` followed by `write_gm(0, &gm)`.
    pub fn from_gm(gm: Vec<f64>) -> Self {
        Self { gm, lm: vec![0.0; super::isa::LM_WORDS], regs: [0.0; NUM_REGS] }
    }

    /// Reset to the fresh state over a zeroed `gm_words` window, mirroring
    /// [`Pe::reset`] for pooled reuse across kernels.
    pub fn reset(&mut self, gm_words: usize) {
        self.gm.clear();
        self.gm.resize(gm_words, 0.0);
        self.lm.fill(0.0);
        self.regs = [0.0; NUM_REGS];
    }

    /// Read back an LM region (introspection for tests/debugging).
    pub fn read_lm(&self, offset: usize, len: usize) -> &[f64] {
        &self.lm[offset..offset + len]
    }

    /// The architectural register file (introspection for tests/debugging).
    pub fn regs(&self) -> &[f64; NUM_REGS] {
        &self.regs
    }
}

/// Tier-2b **operand-batched value replay**: advance every context in
/// `ctxs` through `prog` in a *single* pass over the decoded stream.
///
/// Each op is decoded once — side-table lookups ([`Op::Li`] constants,
/// [`Op::BlkLd`]/[`Op::BlkSt`] block descriptors, DOT width/accumulate
/// bits) are hoisted out of the per-context loop, and runs of adjacent
/// block moves are fused into one resolved transfer list streamed through
/// each context — so decode iteration, dispatch and loop control amortize
/// over the batch instead of being paid once per request.
///
/// Per context, every f64 operation is evaluated in exactly the order and
/// with exactly the operands of a standalone [`Pe::replay`] call (block
/// moves touch only GM/LM, so fusing them across an adjacent run never
/// reorders anything a register op observes): the result is bit-identical
/// to N independent replays, which the randomized two-tier property tests
/// pin. Timing is untouched — it belongs to the program's memoized
/// schedule, exactly as for single replay.
pub fn replay_batch(ctxs: &mut [ReplayCtx], prog: &DecodedProgram) {
    let ops = prog.ops();
    let mut i = 0;
    while i < ops.len() {
        let op = &ops[i];
        let a = op.a as usize;
        match op.op {
            Op::BlkLd | Op::BlkSt => {
                // Fuse the whole adjacent run of block moves: resolve each
                // side-table descriptor once, then stream the run through
                // every context before advancing the op cursor.
                let mut j = i + 1;
                while j < ops.len() && matches!(ops[j].op, Op::BlkLd | Op::BlkSt) {
                    j += 1;
                }
                let run: Vec<(bool, usize, usize, usize)> = ops[i..j]
                    .iter()
                    .map(|o| {
                        let (lm_a, gm_a, len) = prog.block_at(o.addr);
                        (matches!(o.op, Op::BlkLd), lm_a, gm_a, len)
                    })
                    .collect();
                for ctx in ctxs.iter_mut() {
                    let ReplayCtx { gm, lm, .. } = ctx;
                    for &(is_load, lm_a, gm_a, len) in &run {
                        if is_load {
                            lm[lm_a..lm_a + len].copy_from_slice(&gm[gm_a..gm_a + len]);
                        } else {
                            gm[gm_a..gm_a + len].copy_from_slice(&lm[lm_a..lm_a + len]);
                        }
                    }
                }
                i = j;
                continue;
            }
            Op::Ld => {
                let addr = op.addr as usize;
                for ctx in ctxs.iter_mut() {
                    ctx.regs[a] = ctx.gm[addr];
                }
            }
            Op::St => {
                let addr = op.addr as usize;
                for ctx in ctxs.iter_mut() {
                    ctx.gm[addr] = ctx.regs[a];
                }
            }
            Op::LmLd => {
                let addr = op.addr as usize;
                for ctx in ctxs.iter_mut() {
                    ctx.regs[a] = ctx.lm[addr];
                }
            }
            Op::LmSt => {
                let addr = op.addr as usize;
                for ctx in ctxs.iter_mut() {
                    ctx.lm[addr] = ctx.regs[a];
                }
            }
            Op::LmLd4 => {
                let addr = op.addr as usize;
                for ctx in ctxs.iter_mut() {
                    ctx.regs[a..a + 4].copy_from_slice(&ctx.lm[addr..addr + 4]);
                }
            }
            Op::LmSt4 => {
                let addr = op.addr as usize;
                for ctx in ctxs.iter_mut() {
                    ctx.lm[addr..addr + 4].copy_from_slice(&ctx.regs[a..a + 4]);
                }
            }
            Op::Fadd => {
                let (b, c) = (op.b as usize, op.c as usize);
                for ctx in ctxs.iter_mut() {
                    ctx.regs[a] = ctx.regs[b] + ctx.regs[c];
                }
            }
            Op::Fsub => {
                let (b, c) = (op.b as usize, op.c as usize);
                for ctx in ctxs.iter_mut() {
                    ctx.regs[a] = ctx.regs[b] - ctx.regs[c];
                }
            }
            Op::Fmul => {
                let (b, c) = (op.b as usize, op.c as usize);
                for ctx in ctxs.iter_mut() {
                    ctx.regs[a] = ctx.regs[b] * ctx.regs[c];
                }
            }
            Op::Fdiv => {
                let (b, c) = (op.b as usize, op.c as usize);
                for ctx in ctxs.iter_mut() {
                    ctx.regs[a] = ctx.regs[b] / ctx.regs[c];
                }
            }
            Op::Fsqrt => {
                let b = op.b as usize;
                for ctx in ctxs.iter_mut() {
                    ctx.regs[a] = ctx.regs[b].sqrt();
                }
            }
            Op::Fmac => {
                let (b, c) = (op.b as usize, op.c as usize);
                for ctx in ctxs.iter_mut() {
                    ctx.regs[a] += ctx.regs[b] * ctx.regs[c];
                }
            }
            Op::Dot => {
                let (w, acc) = op.dot_params();
                let (b, c) = (op.b as usize, op.c as usize);
                for ctx in ctxs.iter_mut() {
                    let regs = &mut ctx.regs;
                    let mut s = if acc { regs[a] } else { 0.0 };
                    for k in 0..w as usize {
                        s += regs[b + k] * regs[c + k];
                    }
                    regs[a] = s;
                }
            }
            Op::Li => {
                let v = prog.const_at(op.addr);
                for ctx in ctxs.iter_mut() {
                    ctx.regs[a] = v;
                }
            }
            Op::Nop | Op::Barrier => {}
        }
        i += 1;
    }
}

/// Common scheduling for scalar arithmetic: write value, set scoreboard,
/// advance the unit's structural timeline. A free function over the
/// destructured machine state so [`Pe::run_decoded`] can borrow the
/// config and the register file disjointly (no per-run `PeConfig` clone).
#[allow(clippy::too_many_arguments)]
#[inline(always)]
fn arith(
    regs: &mut [f64; NUM_REGS],
    rd: usize,
    value: f64,
    kind: ArithKind,
    issue: u64,
    cfg: &PeConfig,
    reg_ready: &mut [u64; NUM_REGS],
    fu_free: &mut [u64; 6],
    st: &mut PeStats,
) -> u64 {
    regs[rd] = value;
    let done = issue + cfg.arith_latency(kind) as u64;
    reg_ready[rd] = done;
    fu_free[kind as usize] = issue + kind.initiation_interval(cfg) as u64;
    if kind != ArithKind::Dot {
        st.scalar_fu_ops += 1;
    }
    done
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pe::config::{AeLevel, PeConfig};
    use crate::pe::isa::Instr as I;

    fn pe(ae: AeLevel) -> Pe {
        Pe::new(PeConfig::paper(ae), 1024)
    }

    #[test]
    fn computes_values_through_gm() {
        let mut pe = pe(AeLevel::Ae0);
        pe.write_gm(0, &[3.0, 4.0]);
        let mut p = Program::new();
        p.push(I::Ld { rd: 0, gm: 0 });
        p.push(I::Ld { rd: 1, gm: 1 });
        p.push(I::Fmul { rd: 2, ra: 0, rb: 1 });
        p.push(I::St { rs: 2, gm: 2 });
        p.push(I::Halt);
        let st = pe.run(&p);
        assert_eq!(pe.read_gm(2, 1)[0], 12.0);
        assert_eq!(st.flops, 1);
        assert!(st.cycles >= 20, "must see GM latency, got {}", st.cycles);
    }

    #[test]
    fn raw_dependency_stalls() {
        let mut pe = pe(AeLevel::Ae0);
        let mut p = Program::new();
        p.push(I::Li { rd: 0, val: 1.0 });
        p.push(I::Li { rd: 1, val: 2.0 });
        // Dependent adds: each must wait lat_add cycles for the previous.
        for _ in 0..10 {
            p.push(I::Fadd { rd: 0, ra: 0, rb: 1 });
        }
        p.push(I::Halt);
        let st = pe.run(&p);
        assert_eq!(pe.regs[0], 21.0);
        // 10 chained adds at latency lat_add: ≥ 9·lat_add cycles of chain.
        let lat = PeConfig::paper(AeLevel::Ae0).lat_add as u64;
        assert!(st.cycles >= 9 * lat, "chained adds too fast: {}", st.cycles);
        assert!(st.stall_raw > 0);
    }

    #[test]
    fn independent_adds_pipeline() {
        let mut pe = pe(AeLevel::Ae0);
        let mut p = Program::new();
        p.push(I::Li { rd: 62, val: 1.0 });
        p.push(I::Li { rd: 63, val: 2.0 });
        for r in 0..32u8 {
            p.push(I::Fadd { rd: r, ra: 62, rb: 63 });
        }
        p.push(I::Halt);
        let st = pe.run(&p);
        // 32 independent adds issue back-to-back: ~34 issue + 4 drain.
        assert!(st.cycles < 45, "independent adds did not pipeline: {}", st.cycles);
        assert_eq!(st.stall_raw, 0);
    }

    #[test]
    fn div_is_not_pipelined() {
        let mut pe = pe(AeLevel::Ae0);
        let mut p = Program::new();
        p.push(I::Li { rd: 60, val: 1.0 });
        p.push(I::Li { rd: 61, val: 3.0 });
        for r in 0..4u8 {
            p.push(I::Fdiv { rd: r, ra: 60, rb: 61 });
        }
        p.push(I::Halt);
        let st = pe.run(&p);
        let cfg = PeConfig::paper(AeLevel::Ae0);
        assert!(st.cycles as u32 >= 3 * cfg.lat_div, "divs pipelined?: {}", st.cycles);
        assert!(st.stall_fu > 0);
    }

    #[test]
    fn dot_requires_ae2() {
        let mut pe = pe(AeLevel::Ae2);
        pe.write_gm(0, &[1., 2., 3., 4., 10., 20., 30., 40.]);
        let mut p = Program::new();
        p.push(I::BlkLd { lm: 0, gm: 0, len: 8 });
        for i in 0..8u8 {
            p.push(I::LmLd { rd: i, lm: i as u32 });
        }
        p.push(I::Dot { rd: 8, ra: 0, rb: 4, n: 4, acc: false });
        p.push(I::St { rs: 8, gm: 16 });
        p.push(I::Halt);
        let st = pe.run(&p);
        assert_eq!(pe.read_gm(16, 1)[0], 1.0 * 10.0 + 2.0 * 20.0 + 3.0 * 30.0 + 4.0 * 40.0);
        assert_eq!(st.dot_ops, 1);
        assert_eq!(st.flops, 7);
    }

    #[test]
    #[should_panic(expected = "requires AE2")]
    fn dot_panics_before_ae2() {
        let mut pe = pe(AeLevel::Ae1);
        let mut p = Program::new();
        p.push(I::Dot { rd: 8, ra: 0, rb: 4, n: 4, acc: false });
        p.push(I::Halt);
        pe.run(&p);
    }

    #[test]
    #[should_panic(expected = "requires AE1")]
    fn lm_panics_on_ae0() {
        let mut pe = pe(AeLevel::Ae0);
        let mut p = Program::new();
        p.push(I::LmLd { rd: 0, lm: 0 });
        p.push(I::Halt);
        pe.run(&p);
    }

    #[test]
    #[should_panic(expected = "decoded for")]
    fn decoded_ae_must_match_pe_config() {
        // A stream decoded for one enhancement level must not silently run
        // on a PE configured for another (the feature gates were checked
        // against the decode-time level).
        let mut p = Program::new();
        p.push(I::Li { rd: 0, val: 1.0 });
        p.push(I::Halt);
        let d = crate::pe::DecodedProgram::decode(&p, AeLevel::Ae5).unwrap();
        pe(AeLevel::Ae1).run_decoded(&d);
    }

    #[test]
    fn block_load_then_read_orders_correctly() {
        let mut pe = pe(AeLevel::Ae3);
        pe.write_gm(0, &[7.0; 16]);
        let mut p = Program::new();
        p.push(I::BlkLd { lm: 0, gm: 0, len: 16 });
        p.push(I::LmLd { rd: 0, lm: 15 });
        p.push(I::St { rs: 0, gm: 100 });
        p.push(I::Halt);
        let st = pe.run(&p);
        assert_eq!(pe.read_gm(100, 1)[0], 7.0);
        // The scalar read must wait for the block fill (latency + 16 words).
        assert!(st.cycles > 20 + 16, "read overtook block fill: {}", st.cycles);
    }

    #[test]
    fn wide_load_moves_four_words() {
        let mut pe = pe(AeLevel::Ae4);
        pe.write_gm(0, &[1., 2., 3., 4.]);
        let mut p = Program::new();
        p.push(I::BlkLd { lm: 0, gm: 0, len: 4 });
        p.push(I::LmLd4 { rd: 0, lm: 0 });
        p.push(I::Dot { rd: 4, ra: 0, rb: 0, n: 4, acc: false });
        p.push(I::St { rs: 4, gm: 10 });
        p.push(I::Halt);
        pe.run(&p);
        assert_eq!(pe.read_gm(10, 1)[0], 1.0 + 4.0 + 9.0 + 16.0);
    }

    #[test]
    fn ae0_window_throttles_gm_loads() {
        // 64 independent GM loads: with the shallow AE0 window the total
        // must be far above the port-only bound, approaching latency-bound.
        let mut pe0 = pe(AeLevel::Ae0);
        let mut p = Program::new();
        for i in 0..64u8 {
            let r = i % 32;
            p.push(I::Ld { rd: r, gm: i as u32 });
        }
        p.push(I::Halt);
        let st = pe0.run(&p);
        let cfg = PeConfig::paper(AeLevel::Ae0);
        let per_load = st.cycles as f64 / 64.0;
        assert!(
            per_load > 3.0 && per_load < cfg.gm_latency as f64,
            "AE0 per-load cost {per_load} outside plausible window"
        );
        assert!(st.stall_mem_window > 0);
    }

    #[test]
    fn lm_faster_than_gm_roundtrip() {
        // Same data flow via LM (AE1) vs via GM (AE0): LM must win.
        let mk = |via_lm: bool| {
            let mut p = Program::new();
            if via_lm {
                p.push(I::BlkLd { lm: 0, gm: 0, len: 32 });
                for i in 0..32u8 {
                    p.push(I::LmLd { rd: i % 32, lm: i as u32 });
                }
            } else {
                for i in 0..32u8 {
                    p.push(I::Ld { rd: i % 32, gm: i as u32 });
                }
            }
            p.push(I::Halt);
            p
        };
        let mut a = pe(AeLevel::Ae1);
        a.write_gm(0, &[1.0; 64]);
        let with_lm = a.run(&mk(true)).cycles;
        let mut b = pe(AeLevel::Ae0);
        b.write_gm(0, &[1.0; 64]);
        let without = b.run(&mk(false)).cycles;
        assert!(
            with_lm < without,
            "LM path ({with_lm}) not faster than AE0 GM path ({without})"
        );
    }

    #[test]
    fn reset_makes_reuse_identical_to_fresh() {
        // A pooled worker reuses one Pe across kernels; after reset() the
        // run must be bit-identical to a fresh instance.
        let mk_prog = |seed: u8| {
            let mut p = Program::new();
            p.push(I::BlkLd { lm: 0, gm: 0, len: 8 });
            for i in 0..8u8 {
                p.push(I::LmLd { rd: i, lm: i as u32 });
            }
            p.push(I::Dot { rd: 8, ra: 0, rb: 4, n: 4, acc: false });
            p.push(I::Fadd { rd: 9, ra: 8, rb: seed % 8 });
            p.push(I::St { rs: 9, gm: 20 });
            p.push(I::Halt);
            p
        };
        let data: Vec<f64> = (0..16).map(|i| i as f64 * 0.5 - 3.0).collect();

        let mut reused = pe(AeLevel::Ae5);
        reused.write_gm(0, &data);
        reused.run(&mk_prog(1)); // dirty the state
        reused.reset(1024);
        reused.write_gm(0, &data);
        let st_reused = reused.run(&mk_prog(3));
        let out_reused = reused.read_gm(0, 32).to_vec();

        let mut fresh = pe(AeLevel::Ae5);
        fresh.write_gm(0, &data);
        let st_fresh = fresh.run(&mk_prog(3));
        let out_fresh = fresh.read_gm(0, 32).to_vec();

        assert_eq!(st_reused.cycles, st_fresh.cycles);
        assert_eq!(out_reused, out_fresh);
        // reset() also resizes GM.
        reused.reset(64);
        assert_eq!(reused.gm.len(), 64);
        assert!(reused.gm.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn replay_reproduces_combined_values_and_state() {
        // The tier-2 value path must leave GM, LM and the register file
        // bit-identical to the combined interpreter.
        let mut p = Program::new();
        p.push(I::BlkLd { lm: 0, gm: 0, len: 8 });
        for i in 0..8u8 {
            p.push(I::LmLd { rd: i, lm: i as u32 });
        }
        p.push(I::Dot { rd: 8, ra: 0, rb: 4, n: 4, acc: false });
        p.push(I::Fmac { rd: 8, ra: 0, rb: 1 });
        p.push(I::LmSt { rs: 8, lm: 40 });
        p.push(I::BlkSt { lm: 40, gm: 24, len: 1 });
        p.push(I::St { rs: 8, gm: 30 });
        p.push(I::Halt);
        let data: Vec<f64> = (0..8).map(|i| 0.25 * i as f64 - 0.9).collect();
        let d = crate::pe::DecodedProgram::decode(&p, AeLevel::Ae5).unwrap();

        let mut combined = pe(AeLevel::Ae5);
        combined.write_gm(0, &data);
        let st = combined.run_decoded(&d);
        assert!(st.cycles > 0);

        let mut replayed = pe(AeLevel::Ae5);
        replayed.write_gm(0, &data);
        replayed.replay(&d);

        assert_eq!(combined.gm, replayed.gm);
        assert_eq!(combined.read_lm(0, 64), replayed.read_lm(0, 64));
        assert_eq!(combined.regs(), replayed.regs());
    }

    #[test]
    fn replay_batch_matches_independent_replays() {
        // Tier-2b: N contexts through one pass must leave each context
        // bit-identical to its own standalone Pe::replay. The program
        // includes an adjacent BlkLd/BlkSt pair so the fusion path runs.
        let mut p = Program::new();
        p.push(I::BlkLd { lm: 0, gm: 0, len: 8 });
        p.push(I::BlkSt { lm: 0, gm: 8, len: 4 });
        for i in 0..8u8 {
            p.push(I::LmLd { rd: i, lm: i as u32 });
        }
        p.push(I::Dot { rd: 8, ra: 0, rb: 4, n: 4, acc: false });
        p.push(I::Fmac { rd: 8, ra: 0, rb: 1 });
        p.push(I::Li { rd: 9, val: -2.5 });
        p.push(I::Fdiv { rd: 10, ra: 8, rb: 9 });
        p.push(I::LmSt { rs: 10, lm: 40 });
        p.push(I::BlkSt { lm: 40, gm: 24, len: 1 });
        p.push(I::St { rs: 8, gm: 30 });
        p.push(I::Halt);
        let d = crate::pe::DecodedProgram::decode(&p, AeLevel::Ae5).unwrap();

        let images: Vec<Vec<f64>> = (0..5)
            .map(|k| (0..64).map(|i| (i as f64 + 1.0) * 0.125 - k as f64).collect())
            .collect();
        let mut ctxs: Vec<ReplayCtx> =
            images.iter().map(|img| ReplayCtx::from_gm(img.clone())).collect();
        replay_batch(&mut ctxs, &d);

        for (img, ctx) in images.iter().zip(&ctxs) {
            let mut solo = pe(AeLevel::Ae5);
            solo.reset(img.len());
            solo.write_gm(0, img);
            solo.replay(&d);
            assert_eq!(solo.gm, ctx.gm);
            assert_eq!(solo.read_lm(0, 64), ctx.read_lm(0, 64));
            assert_eq!(solo.regs(), ctx.regs());
        }
    }

    #[test]
    fn replay_ctx_reset_matches_fresh() {
        let mut ctx = ReplayCtx::from_gm(vec![7.0; 32]);
        let mut p = Program::new();
        p.push(I::Ld { rd: 0, gm: 0 });
        p.push(I::LmSt { rs: 0, lm: 3 });
        p.push(I::Halt);
        let d = crate::pe::DecodedProgram::decode(&p, AeLevel::Ae5).unwrap();
        replay_batch(std::slice::from_mut(&mut ctx), &d);
        assert_eq!(ctx.read_lm(3, 1), &[7.0]);
        ctx.reset(16);
        let fresh = ReplayCtx::new(16);
        assert_eq!(ctx.gm, fresh.gm);
        assert_eq!(ctx.read_lm(0, crate::pe::LM_WORDS), fresh.read_lm(0, crate::pe::LM_WORDS));
        assert_eq!(ctx.regs(), fresh.regs());
    }

    #[test]
    fn stats_accounting_consistent() {
        let mut pe = pe(AeLevel::Ae2);
        pe.write_gm(0, &[1.0; 32]);
        let mut p = Program::new();
        p.push(I::BlkLd { lm: 0, gm: 0, len: 8 });
        for i in 0..8u8 {
            p.push(I::LmLd { rd: i, lm: i as u32 });
        }
        p.push(I::Dot { rd: 10, ra: 0, rb: 4, n: 4, acc: false });
        p.push(I::Fadd { rd: 11, ra: 10, rb: 10 });
        p.push(I::Halt);
        let st = pe.run(&p);
        assert_eq!(st.instructions, 11);
        assert_eq!(st.gm_words, 8);
        assert_eq!(st.lm_words, 16); // 8 fill + 8 reads
        assert_eq!(st.flops, 8);
        assert_eq!(st.dot_ops, 1);
        assert_eq!(st.scalar_fu_ops, 1);
        assert!(st.cpf() > 0.0 && st.fpc() > 0.0);
    }
}
