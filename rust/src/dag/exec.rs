//! Executable kernel-granularity DAGs: the serving-side counterpart of the
//! scalar analysis [`Dag`](crate::dag::Dag).
//!
//! Where `builder::Dag` models individual floating-point operations (the §4
//! figures), an [`ExecGraph`] models whole cached kernels — DGEMM tiles,
//! DGEMV panels, Level-1 sequences — with predecessor edges and operand
//! buffer bindings. The coordinator expands a LAPACK factorization request
//! into one of these graphs (see `lapack::expand`), then dispatches nodes to
//! the worker pool *dependency-aware*: a node is only offered once every
//! predecessor completed, and completions release successors through
//! [`ExecState::complete`]. Ready sets are always reported in ascending node
//! order, so dispatch order is deterministic for a fixed completion order.

use crate::metrics::Routine;

/// A kernel-granularity BLAS call — exactly the kernel classes the program
/// cache already serves, so factorization nodes flow through the same
/// `ScheduledProgram` entries, replay tiers, and fabric routing as flat
/// requests.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum KernelCall {
    /// An m×p·p×k tile product (trailing-matrix update).
    Gemm { m: usize, p: usize, k: usize },
    /// An n×n matrix-vector product (panel / column update).
    Gemv { n: usize },
    /// A Level-1 sequence of length n (DDOT/DAXPY/DSCAL-equivalents).
    Level1 { routine: Routine, n: usize, alpha: f64 },
}

impl KernelCall {
    /// Stable lowercase tag for labels and obs events.
    pub fn tag(&self) -> &'static str {
        match self {
            KernelCall::Gemm { .. } => "gemm",
            KernelCall::Gemv { .. } => "gemv",
            KernelCall::Level1 { routine, .. } => match routine {
                Routine::Ddot => "ddot",
                Routine::Daxpy => "daxpy",
                Routine::Dnrm2 => "dnrm2",
                Routine::Dgemv => "gemv",
                Routine::Dgemm => "gemm",
            },
        }
    }

    /// Representative problem size (largest dimension).
    pub fn n(&self) -> usize {
        match *self {
            KernelCall::Gemm { m, p, k } => m.max(p).max(k),
            KernelCall::Gemv { n } => n,
            KernelCall::Level1 { n, .. } => n,
        }
    }
}

/// Rectangular region of the factorization buffer a node reads/writes —
/// the operand binding used to price NoC traffic for the node.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Region {
    pub row: usize,
    pub col: usize,
    pub rows: usize,
    pub cols: usize,
}

impl Region {
    /// Operand footprint in 8-byte words.
    pub fn words(&self) -> u64 {
        (self.rows * self.cols) as u64
    }
}

/// One executable node: a kernel call, its predecessor edges, a
/// human-readable label (e.g. `P2` or `U1,3`), and its buffer binding.
#[derive(Debug, Clone)]
pub struct ExecNode {
    pub call: KernelCall,
    pub preds: Vec<usize>,
    pub label: String,
    pub binding: Region,
}

/// A dependency DAG of kernel calls, topologically ordered by construction
/// (`push` rejects forward references, exactly like `builder::Dag`).
#[derive(Debug, Clone, Default)]
pub struct ExecGraph {
    nodes: Vec<ExecNode>,
}

impl ExecGraph {
    pub fn new() -> Self {
        Self::default()
    }

    /// Append a node depending on `preds` (each must already exist).
    pub fn push(
        &mut self,
        call: KernelCall,
        preds: &[usize],
        label: impl Into<String>,
        binding: Region,
    ) -> usize {
        for &p in preds {
            assert!(p < self.nodes.len(), "forward reference in exec graph");
        }
        self.nodes.push(ExecNode {
            call,
            preds: preds.to_vec(),
            label: label.into(),
            binding,
        });
        self.nodes.len() - 1
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    pub fn node(&self, i: usize) -> &ExecNode {
        &self.nodes[i]
    }

    pub fn nodes(&self) -> &[ExecNode] {
        &self.nodes
    }

    /// Successor adjacency (inverse of the stored predecessor edges), each
    /// list ascending.
    pub fn successors(&self) -> Vec<Vec<usize>> {
        let mut succ = vec![Vec::new(); self.nodes.len()];
        for (i, node) in self.nodes.iter().enumerate() {
            for &p in &node.preds {
                succ[p].push(i);
            }
        }
        // Pushed in ascending i order already; keep explicit for clarity.
        for s in &mut succ {
            s.sort_unstable();
        }
        succ
    }

    /// ASAP schedule under per-node costs: node start = max(pred finish),
    /// finish = start + cycles. Returns `(start, finish)` per node; the
    /// makespan (DAG critical path in cycles) is the max finish.
    pub fn schedule(&self, cycles: &[u64]) -> Vec<(u64, u64)> {
        assert_eq!(cycles.len(), self.nodes.len());
        let mut out = vec![(0u64, 0u64); self.nodes.len()];
        for (i, node) in self.nodes.iter().enumerate() {
            let start = node.preds.iter().map(|&p| out[p].1).max().unwrap_or(0);
            out[i] = (start, start + cycles[i]);
        }
        out
    }

    /// Critical path length in nodes (longest chain).
    pub fn critical_len(&self) -> usize {
        let mut depth = vec![0usize; self.nodes.len()];
        for (i, node) in self.nodes.iter().enumerate() {
            depth[i] = 1 + node.preds.iter().map(|&p| depth[p]).max().unwrap_or(0);
        }
        depth.into_iter().max().unwrap_or(0)
    }
}

/// Mutable execution state over an [`ExecGraph`]: tracks indegrees and
/// completions, releasing successors deterministically.
#[derive(Debug, Clone)]
pub struct ExecState {
    indegree: Vec<usize>,
    succ: Vec<Vec<usize>>,
    done: Vec<bool>,
    remaining: usize,
}

impl ExecState {
    pub fn new(g: &ExecGraph) -> Self {
        let indegree = g.nodes().iter().map(|n| n.preds.len()).collect::<Vec<_>>();
        Self {
            indegree,
            succ: g.successors(),
            done: vec![false; g.len()],
            remaining: g.len(),
        }
    }

    /// Nodes ready at the start (no predecessors), ascending.
    pub fn initial_ready(&self) -> Vec<usize> {
        (0..self.indegree.len()).filter(|&i| self.indegree[i] == 0).collect()
    }

    /// Mark node `i` complete; returns the successors this completion
    /// released (all predecessors now done), in ascending order.
    pub fn complete(&mut self, i: usize) -> Vec<usize> {
        assert!(!self.done[i], "node {i} completed twice");
        self.done[i] = true;
        self.remaining -= 1;
        let mut released = Vec::new();
        for &s in &self.succ[i] {
            self.indegree[s] -= 1;
            if self.indegree[s] == 0 {
                released.push(s);
            }
        }
        released
    }

    pub fn is_done(&self) -> bool {
        self.remaining == 0
    }

    pub fn completed(&self, i: usize) -> bool {
        self.done[i]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn reg() -> Region {
        Region { row: 0, col: 0, rows: 4, cols: 4 }
    }

    /// Diamond: 0 → {1, 2} → 3.
    fn diamond() -> ExecGraph {
        let mut g = ExecGraph::new();
        let a = g.push(KernelCall::Gemv { n: 8 }, &[], "P0", reg());
        let b = g.push(KernelCall::Gemm { m: 4, p: 4, k: 4 }, &[a], "U0,1", reg());
        let c = g.push(KernelCall::Gemm { m: 4, p: 4, k: 4 }, &[a], "U0,2", reg());
        g.push(KernelCall::Gemv { n: 4 }, &[b, c], "P1", reg());
        g
    }

    #[test]
    fn successors_invert_preds() {
        let g = diamond();
        assert_eq!(g.successors(), vec![vec![1, 2], vec![3], vec![3], vec![]]);
        assert_eq!(g.critical_len(), 3);
    }

    #[test]
    fn ready_release_order_is_deterministic() {
        let g = diamond();
        let mut st = ExecState::new(&g);
        assert_eq!(st.initial_ready(), vec![0]);
        assert_eq!(st.complete(0), vec![1, 2]);
        // Node 3 only releases once BOTH predecessors finished.
        assert_eq!(st.complete(2), Vec::<usize>::new());
        assert!(!st.is_done());
        assert_eq!(st.complete(1), vec![3]);
        assert_eq!(st.complete(3), Vec::<usize>::new());
        assert!(st.is_done());
    }

    #[test]
    #[should_panic(expected = "completed twice")]
    fn double_completion_rejected() {
        let g = diamond();
        let mut st = ExecState::new(&g);
        st.complete(0);
        st.complete(0);
    }

    #[test]
    #[should_panic(expected = "forward reference")]
    fn forward_reference_rejected() {
        let mut g = ExecGraph::new();
        g.push(KernelCall::Gemv { n: 4 }, &[7], "bad", reg());
    }

    #[test]
    fn schedule_respects_edges() {
        let g = diamond();
        // Costs: 10, 5, 7, 3.
        let s = g.schedule(&[10, 5, 7, 3]);
        assert_eq!(s[0], (0, 10));
        assert_eq!(s[1], (10, 15));
        assert_eq!(s[2], (10, 17));
        // Node 3 starts at max(15, 17) = 17.
        assert_eq!(s[3], (17, 20));
    }

    #[test]
    fn call_tags_are_stable() {
        assert_eq!(KernelCall::Gemm { m: 4, p: 4, k: 4 }.tag(), "gemm");
        assert_eq!(KernelCall::Gemv { n: 8 }.tag(), "gemv");
        let l1 = KernelCall::Level1 { routine: Routine::Daxpy, n: 16, alpha: 1.5 };
        assert_eq!(l1.tag(), "daxpy");
        assert_eq!(l1.n(), 16);
        assert_eq!(reg().words(), 16);
    }
}
