//! DAG analysis of BLAS routines (§4, Figs 3–6, Tables 2–3) and the
//! executable kernel-graph layer the serving stack dispatches.
//!
//! The paper derives its PE design from directed-acyclic-graph structure:
//! which operations can run in parallel (level width), how deep the
//! dependency chains are (critical path), and what macro-operations repeat
//! (the DOT4 pattern). [`builder`] and [`routines`] build those scalar DAGs
//! programmatically for ddot, dnrm2, daxpy, matrix-vector and the three
//! matrix-multiplication algorithms, and compute the §4 statistics.
//!
//! [`exec`] lifts the same idea to kernel granularity: an [`ExecGraph`] of
//! cached BLAS kernel calls with predecessor edges and operand bindings is
//! what a LAPACK factorization request expands into (`lapack::expand`), and
//! the coordinator's pipeline dispatches it dependency-aware — a node is
//! offered to the pool only after its predecessors complete.

pub mod builder;
pub mod exec;
pub mod routines;

pub use builder::{Dag, NodeId, OpKind, ReadySets};
pub use exec::{ExecGraph, ExecNode, ExecState, KernelCall, Region};
pub use routines::{
    daxpy_dag, ddot_dag, dgemv_dag, dnrm2_dag, gemm_block_dag, smm_block_dag, wmm_block_dag,
};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3_ddot_structure() {
        // n = 8 (the paper's fig 3): 8 parallel multiplies, then a binary
        // addition tree of depth 3.
        let d = ddot_dag(8);
        let widths = d.level_widths();
        assert_eq!(widths[0], 8, "first level: all multiplies in parallel");
        assert_eq!(widths[1..], [4, 2, 1], "addition tree levels");
        assert_eq!(d.critical_path(), 4);
        assert_eq!(d.count(OpKind::Mul), 8);
        assert_eq!(d.count(OpKind::Add), 7);
    }

    #[test]
    fn fig3_dnrm2_is_ddot_plus_sqrt() {
        let d = dnrm2_dag(8);
        let dd = ddot_dag(8);
        assert_eq!(d.critical_path(), dd.critical_path() + 1);
        assert_eq!(d.count(OpKind::Sqrt), 1);
        assert_eq!(d.count(OpKind::Mul), dd.count(OpKind::Mul));
    }

    #[test]
    fn fig3_daxpy_is_two_levels() {
        // All multiplies parallel, then all adds parallel: depth 2, width n.
        let d = daxpy_dag(8);
        assert_eq!(d.level_widths(), vec![8, 8]);
        assert_eq!(d.critical_path(), 2);
    }

    #[test]
    fn fig4_gemv_is_parallel_dots() {
        // n×n matrix-vector = n independent n-element inner products: all
        // n² multiplies are level 0 (the paper's observation).
        let d = dgemv_dag(4);
        assert_eq!(d.level_widths()[0], 16);
        assert_eq!(d.critical_path(), ddot_dag(4).critical_path());
    }

    #[test]
    fn fig5_gemm_2x2_counts() {
        // §4.3.4: 2×2 GEMM takes 8 multiplies and 4 additions.
        let d = gemm_block_dag(2);
        assert_eq!(d.count(OpKind::Mul), 8);
        assert_eq!(d.count(OpKind::Add), 4);
        assert_eq!(d.critical_path(), 2);
    }

    #[test]
    fn fig5_smm_vs_wmm_vs_gemm() {
        // Table 2: SMM = 7 multiplies, 18 add/subs; Table 3: WMM = 7 and 15;
        // GEMM = 8 and 4. SMM/WMM trade one multiply for many additions and
        // a deeper DAG — the §4.3.4 argument for choosing GEMM.
        let smm = smm_block_dag();
        let wmm = wmm_block_dag();
        let gemm = gemm_block_dag(2);
        assert_eq!(smm.count(OpKind::Mul), 7);
        assert_eq!(smm.count(OpKind::Add) + smm.count(OpKind::Sub), 18);
        assert_eq!(wmm.count(OpKind::Mul), 7);
        assert_eq!(wmm.count(OpKind::Add) + wmm.count(OpKind::Sub), 15);
        assert!(smm.critical_path() > gemm.critical_path());
        assert!(wmm.critical_path() > gemm.critical_path());
    }

    #[test]
    fn fig6_gemm_4x4_all_multiplies_parallel() {
        // §4.3.5: all n³ = 64 multiplies of the 4×4 GEMM can start at once.
        let d = gemm_block_dag(4);
        assert_eq!(d.level_widths()[0], 64);
        // Accumulation enforces ⌈log2(4)⌉ = 2 further levels of adds.
        assert_eq!(d.critical_path(), 3);
    }
}
