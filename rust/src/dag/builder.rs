//! Generic DAG builder with the analyses of §4: level structure (what can
//! execute in parallel), critical path, and op-kind counts.

use std::collections::HashMap;

/// Node identifier within a [`Dag`].
pub type NodeId = usize;

/// Operation kinds distinguished by the paper's DAG figures.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OpKind {
    /// Input value (matrix/vector element) — depth 0, not an operation.
    Input,
    Add,
    Sub,
    Mul,
    Div,
    Sqrt,
}

impl OpKind {
    /// Is this a floating-point operation (vs an input)?
    pub fn is_op(self) -> bool {
        !matches!(self, OpKind::Input)
    }
}

/// A dependency DAG of scalar operations.
#[derive(Debug, Clone, Default)]
pub struct Dag {
    kinds: Vec<OpKind>,
    preds: Vec<Vec<NodeId>>,
    labels: Vec<String>,
}

impl Dag {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add an input node.
    pub fn input(&mut self, label: impl Into<String>) -> NodeId {
        self.push(OpKind::Input, &[], label.into())
    }

    /// Add an operation node depending on `preds`.
    pub fn op(&mut self, kind: OpKind, preds: &[NodeId], label: impl Into<String>) -> NodeId {
        assert!(kind.is_op(), "use input() for inputs");
        assert!(!preds.is_empty(), "operation with no operands");
        self.push(kind, preds, label.into())
    }

    fn push(&mut self, kind: OpKind, preds: &[NodeId], label: String) -> NodeId {
        for &p in preds {
            assert!(p < self.kinds.len(), "forward reference in DAG");
        }
        self.kinds.push(kind);
        self.preds.push(preds.to_vec());
        self.labels.push(label);
        self.kinds.len() - 1
    }

    pub fn len(&self) -> usize {
        self.kinds.len()
    }

    pub fn is_empty(&self) -> bool {
        self.kinds.is_empty()
    }

    pub fn kind(&self, id: NodeId) -> OpKind {
        self.kinds[id]
    }

    pub fn label(&self, id: NodeId) -> &str {
        &self.labels[id]
    }

    /// Count of operation nodes of a kind.
    pub fn count(&self, kind: OpKind) -> usize {
        self.kinds.iter().filter(|&&k| k == kind).count()
    }

    /// Total operation nodes (excludes inputs).
    pub fn total_ops(&self) -> usize {
        self.kinds.iter().filter(|k| k.is_op()).count()
    }

    /// ASAP level of every node: inputs at level 0, an op at
    /// 1 + max(level of operands). (Nodes are topologically ordered by
    /// construction.)
    pub fn levels(&self) -> Vec<usize> {
        let mut lv = vec![0usize; self.len()];
        for i in 0..self.len() {
            if self.kinds[i].is_op() {
                lv[i] = 1 + self.preds[i].iter().map(|&p| lv[p]).max().unwrap_or(0);
            }
        }
        lv
    }

    /// Width of each operation level (level 1 upwards): `widths[0]` is the
    /// number of ops that can start immediately — the paper's "all
    /// multiplications can potentially be executed in parallel".
    pub fn level_widths(&self) -> Vec<usize> {
        let lv = self.levels();
        let mut hist: HashMap<usize, usize> = HashMap::new();
        for i in 0..self.len() {
            if self.kinds[i].is_op() {
                *hist.entry(lv[i]).or_insert(0) += 1;
            }
        }
        let max = hist.keys().copied().max().unwrap_or(0);
        (1..=max).map(|l| hist.get(&l).copied().unwrap_or(0)).collect()
    }

    /// Critical path length in operation levels.
    pub fn critical_path(&self) -> usize {
        self.levels().into_iter().max().unwrap_or(0)
    }

    /// Average parallelism: total ops / critical path.
    pub fn avg_parallelism(&self) -> f64 {
        self.total_ops() as f64 / self.critical_path().max(1) as f64
    }

    /// The §4 summary: (ops, critical path, max width, average parallelism).
    pub fn profile(&self) -> DagProfile {
        let widths = self.level_widths();
        DagProfile {
            ops: self.total_ops(),
            critical_path: self.critical_path(),
            max_width: widths.iter().copied().max().unwrap_or(0),
            avg_parallelism: self.avg_parallelism(),
        }
    }
}

/// Summary statistics of a DAG (the numbers behind Figs 3–6).
#[derive(Debug, Clone, PartialEq)]
pub struct DagProfile {
    pub ops: usize,
    pub critical_path: usize,
    pub max_width: usize,
    pub avg_parallelism: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn diamond_levels() {
        let mut d = Dag::new();
        let a = d.input("a");
        let b = d.input("b");
        let m1 = d.op(OpKind::Mul, &[a, b], "m1");
        let m2 = d.op(OpKind::Mul, &[a, b], "m2");
        let s = d.op(OpKind::Add, &[m1, m2], "s");
        assert_eq!(d.levels(), vec![0, 0, 1, 1, 2]);
        assert_eq!(d.level_widths(), vec![2, 1]);
        assert_eq!(d.critical_path(), 2);
        assert_eq!(d.kind(s), OpKind::Add);
        assert_eq!(d.total_ops(), 3);
    }

    #[test]
    #[should_panic(expected = "no operands")]
    fn op_needs_operands() {
        let mut d = Dag::new();
        d.op(OpKind::Add, &[], "bad");
    }

    #[test]
    fn profile_summary() {
        let mut d = Dag::new();
        let a = d.input("a");
        let m = d.op(OpKind::Mul, &[a, a], "m");
        d.op(OpKind::Sqrt, &[m], "r");
        let p = d.profile();
        assert_eq!(p.ops, 2);
        assert_eq!(p.critical_path, 2);
        assert_eq!(p.max_width, 1);
        assert!((p.avg_parallelism - 1.0).abs() < 1e-12);
    }

    #[test]
    fn empty_dag_is_safe() {
        let d = Dag::new();
        assert_eq!(d.critical_path(), 0);
        assert_eq!(d.level_widths(), Vec::<usize>::new());
    }
}
