//! Generic DAG builder with the analyses of §4: level structure (what can
//! execute in parallel), critical path, and op-kind counts.

use std::collections::HashMap;

/// Node identifier within a [`Dag`].
pub type NodeId = usize;

/// Operation kinds distinguished by the paper's DAG figures.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OpKind {
    /// Input value (matrix/vector element) — depth 0, not an operation.
    Input,
    Add,
    Sub,
    Mul,
    Div,
    Sqrt,
}

impl OpKind {
    /// Is this a floating-point operation (vs an input)?
    pub fn is_op(self) -> bool {
        !matches!(self, OpKind::Input)
    }
}

/// A dependency DAG of scalar operations.
#[derive(Debug, Clone, Default)]
pub struct Dag {
    kinds: Vec<OpKind>,
    preds: Vec<Vec<NodeId>>,
    labels: Vec<String>,
}

impl Dag {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add an input node.
    pub fn input(&mut self, label: impl Into<String>) -> NodeId {
        self.push(OpKind::Input, &[], label.into())
    }

    /// Add an operation node depending on `preds`.
    pub fn op(&mut self, kind: OpKind, preds: &[NodeId], label: impl Into<String>) -> NodeId {
        assert!(kind.is_op(), "use input() for inputs");
        assert!(!preds.is_empty(), "operation with no operands");
        self.push(kind, preds, label.into())
    }

    fn push(&mut self, kind: OpKind, preds: &[NodeId], label: String) -> NodeId {
        for &p in preds {
            assert!(p < self.kinds.len(), "forward reference in DAG");
        }
        self.kinds.push(kind);
        self.preds.push(preds.to_vec());
        self.labels.push(label);
        self.kinds.len() - 1
    }

    pub fn len(&self) -> usize {
        self.kinds.len()
    }

    pub fn is_empty(&self) -> bool {
        self.kinds.is_empty()
    }

    pub fn kind(&self, id: NodeId) -> OpKind {
        self.kinds[id]
    }

    pub fn label(&self, id: NodeId) -> &str {
        &self.labels[id]
    }

    /// Count of operation nodes of a kind.
    pub fn count(&self, kind: OpKind) -> usize {
        self.kinds.iter().filter(|&&k| k == kind).count()
    }

    /// Total operation nodes (excludes inputs).
    pub fn total_ops(&self) -> usize {
        self.kinds.iter().filter(|k| k.is_op()).count()
    }

    /// ASAP level of every node: inputs at level 0, an op at
    /// 1 + max(level of operands). (Nodes are topologically ordered by
    /// construction.)
    pub fn levels(&self) -> Vec<usize> {
        let mut lv = vec![0usize; self.len()];
        for i in 0..self.len() {
            if self.kinds[i].is_op() {
                lv[i] = 1 + self.preds[i].iter().map(|&p| lv[p]).max().unwrap_or(0);
            }
        }
        lv
    }

    /// Width of each operation level (level 1 upwards): `widths[0]` is the
    /// number of ops that can start immediately — the paper's "all
    /// multiplications can potentially be executed in parallel".
    pub fn level_widths(&self) -> Vec<usize> {
        let lv = self.levels();
        let mut hist: HashMap<usize, usize> = HashMap::new();
        for i in 0..self.len() {
            if self.kinds[i].is_op() {
                *hist.entry(lv[i]).or_insert(0) += 1;
            }
        }
        let max = hist.keys().copied().max().unwrap_or(0);
        (1..=max).map(|l| hist.get(&l).copied().unwrap_or(0)).collect()
    }

    /// Critical path length in operation levels.
    pub fn critical_path(&self) -> usize {
        self.levels().into_iter().max().unwrap_or(0)
    }

    /// Average parallelism: total ops / critical path.
    pub fn avg_parallelism(&self) -> f64 {
        self.total_ops() as f64 / self.critical_path().max(1) as f64
    }

    /// Predecessors of a node.
    pub fn preds(&self, id: NodeId) -> &[NodeId] {
        &self.preds[id]
    }

    /// Successor adjacency — the inverse of the stored predecessor edges,
    /// each list in ascending node order.
    pub fn successors(&self) -> Vec<Vec<NodeId>> {
        let mut succ = vec![Vec::new(); self.len()];
        for (i, ps) in self.preds.iter().enumerate() {
            for &p in ps {
                succ[p].push(i);
            }
        }
        for s in &mut succ {
            s.sort_unstable();
        }
        succ
    }

    /// Deterministic topological ready-set iterator: yields successive
    /// frontiers of nodes whose predecessors have all been yielded, each
    /// frontier in ascending node order. Concatenating the frontiers gives
    /// a canonical topological order (the executor's dispatch order for a
    /// fixed completion order).
    pub fn ready_sets(&self) -> ReadySets {
        let indegree = self.preds.iter().map(Vec::len).collect::<Vec<_>>();
        let ready = (0..self.len()).filter(|&i| indegree[i] == 0).collect();
        ReadySets { succ: self.successors(), indegree, ready }
    }

    /// The §4 summary: (ops, critical path, max width, average parallelism).
    pub fn profile(&self) -> DagProfile {
        let widths = self.level_widths();
        DagProfile {
            ops: self.total_ops(),
            critical_path: self.critical_path(),
            max_width: widths.iter().copied().max().unwrap_or(0),
            avg_parallelism: self.avg_parallelism(),
        }
    }
}

/// Iterator over topological ready frontiers — see [`Dag::ready_sets`].
#[derive(Debug, Clone)]
pub struct ReadySets {
    succ: Vec<Vec<NodeId>>,
    indegree: Vec<usize>,
    ready: Vec<NodeId>,
}

impl Iterator for ReadySets {
    type Item = Vec<NodeId>;

    fn next(&mut self) -> Option<Vec<NodeId>> {
        if self.ready.is_empty() {
            return None;
        }
        let frontier = std::mem::take(&mut self.ready);
        for &n in &frontier {
            for &s in &self.succ[n] {
                self.indegree[s] -= 1;
                if self.indegree[s] == 0 {
                    self.ready.push(s);
                }
            }
        }
        self.ready.sort_unstable();
        Some(frontier)
    }
}

/// Summary statistics of a DAG (the numbers behind Figs 3–6).
#[derive(Debug, Clone, PartialEq)]
pub struct DagProfile {
    pub ops: usize,
    pub critical_path: usize,
    pub max_width: usize,
    pub avg_parallelism: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn diamond_levels() {
        let mut d = Dag::new();
        let a = d.input("a");
        let b = d.input("b");
        let m1 = d.op(OpKind::Mul, &[a, b], "m1");
        let m2 = d.op(OpKind::Mul, &[a, b], "m2");
        let s = d.op(OpKind::Add, &[m1, m2], "s");
        assert_eq!(d.levels(), vec![0, 0, 1, 1, 2]);
        assert_eq!(d.level_widths(), vec![2, 1]);
        assert_eq!(d.critical_path(), 2);
        assert_eq!(d.kind(s), OpKind::Add);
        assert_eq!(d.total_ops(), 3);
    }

    #[test]
    #[should_panic(expected = "no operands")]
    fn op_needs_operands() {
        let mut d = Dag::new();
        d.op(OpKind::Add, &[], "bad");
    }

    #[test]
    fn profile_summary() {
        let mut d = Dag::new();
        let a = d.input("a");
        let m = d.op(OpKind::Mul, &[a, a], "m");
        d.op(OpKind::Sqrt, &[m], "r");
        let p = d.profile();
        assert_eq!(p.ops, 2);
        assert_eq!(p.critical_path, 2);
        assert_eq!(p.max_width, 1);
        assert!((p.avg_parallelism - 1.0).abs() < 1e-12);
    }

    #[test]
    fn successors_invert_preds() {
        let mut d = Dag::new();
        let a = d.input("a");
        let b = d.input("b");
        let m1 = d.op(OpKind::Mul, &[a, b], "m1");
        let m2 = d.op(OpKind::Mul, &[a, b], "m2");
        let s = d.op(OpKind::Add, &[m1, m2], "s");
        assert_eq!(d.successors(), vec![vec![m1, m2], vec![m1, m2], vec![s], vec![s], vec![]]);
        assert_eq!(d.preds(s), &[m1, m2]);
        assert_eq!(d.preds(a), &[] as &[NodeId]);
    }

    #[test]
    fn ready_sets_are_topological_and_ascending() {
        let mut d = Dag::new();
        let a = d.input("a");
        let b = d.input("b");
        let m1 = d.op(OpKind::Mul, &[a, b], "m1");
        let m2 = d.op(OpKind::Mul, &[a, b], "m2");
        let s = d.op(OpKind::Add, &[m1, m2], "s");
        let frontiers: Vec<_> = d.ready_sets().collect();
        assert_eq!(frontiers, vec![vec![a, b], vec![m1, m2], vec![s]]);
        // Concatenation is a topological order covering every node once.
        let order: Vec<_> = frontiers.into_iter().flatten().collect();
        assert_eq!(order.len(), d.len());
        let pos: Vec<_> = {
            let mut p = vec![0; d.len()];
            for (rank, &n) in order.iter().enumerate() {
                p[n] = rank;
            }
            p
        };
        for n in 0..d.len() {
            for &p in d.preds(n) {
                assert!(pos[p] < pos[n], "pred {p} not before {n}");
            }
        }
    }

    #[test]
    fn ready_sets_empty_dag() {
        assert_eq!(Dag::new().ready_sets().count(), 0);
    }

    #[test]
    fn empty_dag_is_safe() {
        let d = Dag::new();
        assert_eq!(d.critical_path(), 0);
        assert_eq!(d.level_widths(), Vec::<usize>::new());
    }
}
