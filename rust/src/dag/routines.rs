//! DAG constructions for the routines analysed in §4 (Figs 3–6) and the
//! 2×2-block SMM/WMM/GEMM comparison (Tables 2–3, Fig 5).

use super::builder::{Dag, NodeId, OpKind};

/// Binary addition tree over `vals`, returning the root.
fn add_tree(d: &mut Dag, mut vals: Vec<NodeId>, tag: &str) -> NodeId {
    assert!(!vals.is_empty());
    let mut level = 0;
    while vals.len() > 1 {
        level += 1;
        let mut next = Vec::with_capacity(vals.len().div_ceil(2));
        for pair in vals.chunks(2) {
            if pair.len() == 2 {
                next.push(d.op(OpKind::Add, pair, format!("{tag}_l{level}")));
            } else {
                next.push(pair[0]);
            }
        }
        vals = next;
    }
    vals[0]
}

/// ddot DAG (fig 3): n parallel multiplies, then an addition tree.
pub fn ddot_dag(n: usize) -> Dag {
    let mut d = Dag::new();
    let xs: Vec<_> = (0..n).map(|i| d.input(format!("x{i}"))).collect();
    let ys: Vec<_> = (0..n).map(|i| d.input(format!("y{i}"))).collect();
    let prods: Vec<_> =
        (0..n).map(|i| d.op(OpKind::Mul, &[xs[i], ys[i]], format!("p{i}"))).collect();
    add_tree(&mut d, prods, "sum");
    d
}

/// dnrm2 DAG (fig 3): like ddot with x = y plus a final square root.
pub fn dnrm2_dag(n: usize) -> Dag {
    let mut d = Dag::new();
    let xs: Vec<_> = (0..n).map(|i| d.input(format!("x{i}"))).collect();
    let prods: Vec<_> =
        (0..n).map(|i| d.op(OpKind::Mul, &[xs[i], xs[i]], format!("p{i}"))).collect();
    let s = add_tree(&mut d, prods, "sum");
    d.op(OpKind::Sqrt, &[s], "sqrt");
    d
}

/// daxpy DAG (fig 3): n independent (multiply, add) pairs — depth 2.
pub fn daxpy_dag(n: usize) -> Dag {
    let mut d = Dag::new();
    let alpha = d.input("alpha");
    for i in 0..n {
        let x = d.input(format!("x{i}"));
        let y = d.input(format!("y{i}"));
        let p = d.op(OpKind::Mul, &[alpha, x], format!("p{i}"));
        d.op(OpKind::Add, &[p, y], format!("s{i}"));
    }
    d
}

/// Matrix-vector DAG (fig 4): n independent ddot DAGs sharing x.
pub fn dgemv_dag(n: usize) -> Dag {
    let mut d = Dag::new();
    let xs: Vec<_> = (0..n).map(|j| d.input(format!("x{j}"))).collect();
    for i in 0..n {
        let mut prods = Vec::with_capacity(n);
        for (j, &xj) in xs.iter().enumerate() {
            let a = d.input(format!("a{i}{j}"));
            prods.push(d.op(OpKind::Mul, &[a, xj], format!("p{i}{j}")));
        }
        add_tree(&mut d, prods, &format!("row{i}"));
    }
    d
}

/// GEMM DAG for an n×n block (figs 5 and 6): n³ parallel multiplies, then
/// an addition tree per output element.
pub fn gemm_block_dag(n: usize) -> Dag {
    let mut d = Dag::new();
    let a: Vec<Vec<_>> = (0..n)
        .map(|i| (0..n).map(|k| d.input(format!("a{i}{k}"))).collect())
        .collect();
    let b: Vec<Vec<_>> = (0..n)
        .map(|k| (0..n).map(|j| d.input(format!("b{k}{j}"))).collect())
        .collect();
    for i in 0..n {
        for j in 0..n {
            let prods: Vec<_> = (0..n)
                .map(|k| d.op(OpKind::Mul, &[a[i][k], b[k][j]], format!("m{i}{j}{k}")))
                .collect();
            add_tree(&mut d, prods, &format!("c{i}{j}"));
        }
    }
    d
}

/// Strassen 2×2 block DAG (Table 2 / fig 5): block operations as nodes.
/// 7 multiplies, 18 additions/subtractions over four dependency levels.
pub fn smm_block_dag() -> Dag {
    let mut d = Dag::new();
    let a11 = d.input("A11");
    let a12 = d.input("A12");
    let a21 = d.input("A21");
    let a22 = d.input("A22");
    let b11 = d.input("B11");
    let b12 = d.input("B12");
    let b21 = d.input("B21");
    let b22 = d.input("B22");
    // Level 1 (T additions).
    let t1 = d.op(OpKind::Add, &[a11, a22], "T1");
    let t2 = d.op(OpKind::Add, &[b11, b22], "T2");
    let t3 = d.op(OpKind::Sub, &[b12, b22], "T3");
    let t4 = d.op(OpKind::Sub, &[b21, b11], "T4");
    let t5 = d.op(OpKind::Add, &[a11, a12], "T5");
    let t6 = d.op(OpKind::Sub, &[a21, a11], "T6");
    let t7 = d.op(OpKind::Add, &[b11, b12], "T7");
    let t8 = d.op(OpKind::Sub, &[a12, a22], "T8");
    let t9 = d.op(OpKind::Add, &[b21, b22], "T9");
    // Level 2 (M multiplies).
    let m1 = d.op(OpKind::Mul, &[t1, t2], "M1");
    let s1 = d.op(OpKind::Add, &[a21, a22], "A21+A22");
    let m2 = d.op(OpKind::Mul, &[s1, b11], "M2");
    let m3 = d.op(OpKind::Mul, &[a11, t3], "M3");
    let m4 = d.op(OpKind::Mul, &[a22, t4], "M4");
    let m5 = d.op(OpKind::Mul, &[t5, b22], "M5");
    let m6 = d.op(OpKind::Mul, &[t6, t7], "M6");
    let m7 = d.op(OpKind::Mul, &[t8, t9], "M7");
    // Level 3 (K combinations).
    let k1 = d.op(OpKind::Add, &[m1, m4], "K1");
    let k2 = d.op(OpKind::Sub, &[m5, m7], "K2");
    let k3 = d.op(OpKind::Sub, &[m1, m2], "K3");
    let k4 = d.op(OpKind::Add, &[m3, m6], "K4");
    d.op(OpKind::Add, &[m3, m5], "C12");
    d.op(OpKind::Add, &[m2, m4], "C21");
    // Level 4 (C blocks).
    d.op(OpKind::Sub, &[k1, k2], "C11");
    d.op(OpKind::Add, &[k3, k4], "C22");
    d
}

/// Winograd 2×2 block DAG (Table 3): 7 multiplies, 15 additions over six
/// dependency levels — deeper than SMM despite fewer additions.
pub fn wmm_block_dag() -> Dag {
    let mut d = Dag::new();
    let a11 = d.input("A11");
    let a12 = d.input("A12");
    let a21 = d.input("A21");
    let a22 = d.input("A22");
    let b11 = d.input("B11");
    let b12 = d.input("B12");
    let b21 = d.input("B21");
    let b22 = d.input("B22");
    let s1 = d.op(OpKind::Add, &[a21, a22], "S1");
    let s2 = d.op(OpKind::Sub, &[s1, a11], "S2");
    let s3 = d.op(OpKind::Sub, &[a11, a21], "S3");
    let s4 = d.op(OpKind::Sub, &[a12, s2], "S4");
    let t1 = d.op(OpKind::Sub, &[b12, b11], "T1");
    let t2 = d.op(OpKind::Sub, &[b22, t1], "T2");
    let t3 = d.op(OpKind::Sub, &[b22, b12], "T3");
    let t4 = d.op(OpKind::Sub, &[t2, b21], "T4");
    let m1 = d.op(OpKind::Mul, &[a11, b11], "M1");
    let m2 = d.op(OpKind::Mul, &[a12, b21], "M2");
    let m3 = d.op(OpKind::Mul, &[s4, b22], "M3");
    let m4 = d.op(OpKind::Mul, &[a22, t4], "M4");
    let m5 = d.op(OpKind::Mul, &[s1, t1], "M5");
    let m6 = d.op(OpKind::Mul, &[s2, t2], "M6");
    let m7 = d.op(OpKind::Mul, &[s3, t3], "M7");
    d.op(OpKind::Add, &[m1, m2], "C11");
    let u2 = d.op(OpKind::Add, &[m1, m6], "U2");
    let u3 = d.op(OpKind::Add, &[u2, m7], "U3");
    let u4 = d.op(OpKind::Add, &[u2, m5], "U4");
    d.op(OpKind::Add, &[u4, m3], "C12");
    d.op(OpKind::Sub, &[u3, m4], "C21");
    d.op(OpKind::Add, &[u3, m5], "C22");
    d
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ddot_scales() {
        for n in [2, 4, 16, 32] {
            let d = ddot_dag(n);
            assert_eq!(d.count(OpKind::Mul), n);
            assert_eq!(d.count(OpKind::Add), n - 1);
            assert_eq!(d.critical_path(), 1 + (n as f64).log2().ceil() as usize);
        }
    }

    #[test]
    fn gemv_op_counts() {
        let n = 6;
        let d = dgemv_dag(n);
        assert_eq!(d.count(OpKind::Mul), n * n);
        assert_eq!(d.count(OpKind::Add), n * (n - 1));
    }

    #[test]
    fn gemm_op_counts_match_paper() {
        // n³ multiplies, n³ − n² additions (§3.1).
        for n in [2, 3, 4] {
            let d = gemm_block_dag(n);
            assert_eq!(d.count(OpKind::Mul), n * n * n);
            assert_eq!(d.count(OpKind::Add), n * n * n - n * n);
        }
    }

    #[test]
    fn smm_deeper_than_wmm_shallower_counts() {
        let smm = smm_block_dag();
        let wmm = wmm_block_dag();
        assert_eq!(smm.critical_path(), 4, "Table 2 has four levels");
        assert_eq!(wmm.critical_path(), 6, "Table 3 has six levels");
        assert!(wmm.total_ops() < smm.total_ops());
    }

    #[test]
    fn daxpy_parallelism() {
        let d = daxpy_dag(16);
        assert_eq!(d.profile().max_width, 16);
        assert_eq!(d.profile().critical_path, 2);
    }
}
