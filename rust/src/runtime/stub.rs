//! Offline stand-in for the PJRT runtime (any build without *both* the
//! `pjrt` and `xla-rt` features). [`Runtime::new`] always fails, so the
//! coordinator keeps every value on the `ValueSource::PeSim` path — exactly
//! the behavior of a real-PJRT build in which PJRT failed to initialize.
//! The full method surface is kept so downstream code compiles identically
//! in every mode, which is what lets CI build-check the `pjrt` gate without
//! the vendored `xla` crate.

use super::{has_artifact, scan_artifacts, ArtifactKey, RtError, RtResult};
use crate::util::Mat;
use std::path::{Path, PathBuf};

/// Stub runtime. Never successfully constructed.
pub struct Runtime {
    dir: PathBuf,
}

impl Runtime {
    /// Always fails: no real PJRT client in this build (requires both the
    /// `pjrt` and `xla-rt` features plus the vendored `xla` crate), so no
    /// XLA value path exists.
    pub fn new(dir: impl AsRef<Path>) -> RtResult<Self> {
        let _ = dir.as_ref();
        Err(RtError::new(
            "PJRT runtime unavailable: crate built without the `pjrt` + `xla-rt` \
             features (values fall back to the PE simulator)",
        ))
    }

    /// Platform string of the backend (diagnostics).
    pub fn platform(&self) -> String {
        "stub (pjrt feature disabled)".into()
    }

    /// Artifacts available on disk (not loadable in this build).
    pub fn available(&self) -> Vec<ArtifactKey> {
        scan_artifacts(&self.dir)
    }

    /// True if an artifact exists for (op, n).
    pub fn has(&self, op: &str, n: usize) -> bool {
        has_artifact(&self.dir, op, n)
    }

    pub fn gemm(&mut self, _a: &Mat, _b: &Mat, _c: &Mat) -> RtResult<Mat> {
        Err(unavailable())
    }

    pub fn gemv(&mut self, _a: &Mat, _x: &[f64], _y: &[f64]) -> RtResult<Vec<f64>> {
        Err(unavailable())
    }

    pub fn dot(&mut self, _x: &[f64], _y: &[f64]) -> RtResult<f64> {
        Err(unavailable())
    }

    pub fn axpy(&mut self, _alpha: f64, _x: &[f64], _y: &[f64]) -> RtResult<Vec<f64>> {
        Err(unavailable())
    }

    pub fn nrm2(&mut self, _x: &[f64]) -> RtResult<f64> {
        Err(unavailable())
    }

    pub fn qr_panel(&mut self, _a: &Mat) -> RtResult<(Mat, f64)> {
        Err(unavailable())
    }
}

fn unavailable() -> RtError {
    RtError::new("pjrt feature disabled")
}
