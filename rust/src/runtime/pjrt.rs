//! Real PJRT runtime (compiled only with `--features pjrt`): loads the AOT
//! HLO-text artifacts and executes them on the CPU PJRT client via the
//! vendored `xla` crate.

use super::{has_artifact, scan_artifacts, ArtifactKey, RtError, RtResult};
use crate::util::Mat;
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// The PJRT runtime: client + compiled-executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    cache: HashMap<ArtifactKey, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Create a CPU-PJRT runtime over an artifact directory.
    pub fn new(dir: impl AsRef<Path>) -> RtResult<Self> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| RtError::new(format!("PJRT client: {e:?}")))?;
        Ok(Self { client, dir: dir.as_ref().to_path_buf(), cache: HashMap::new() })
    }

    /// Platform string of the PJRT backend (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Artifacts available on disk (not necessarily loaded yet).
    pub fn available(&self) -> Vec<ArtifactKey> {
        scan_artifacts(&self.dir)
    }

    /// True if an artifact exists for (op, n).
    pub fn has(&self, op: &str, n: usize) -> bool {
        has_artifact(&self.dir, op, n)
    }

    /// Load (and cache) the executable for (op, n).
    pub fn load(&mut self, op: &str, n: usize) -> RtResult<&xla::PjRtLoadedExecutable> {
        let key = ArtifactKey { op: op.to_string(), n };
        if !self.cache.contains_key(&key) {
            let path = self.dir.join(key.file_name());
            if !path.exists() {
                return Err(RtError::new(format!(
                    "artifact {} not found (run `make artifacts`)",
                    path.display()
                )));
            }
            let path_str = path.to_str().ok_or_else(|| RtError::new("non-utf8 path"))?;
            let proto = xla::HloModuleProto::from_text_file(path_str)
                .map_err(|e| RtError::new(format!("parse {}: {e:?}", path.display())))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| RtError::new(format!("compile {}: {e:?}", path.display())))?;
            self.cache.insert(key.clone(), exe);
        }
        Ok(self.cache.get(&key).unwrap())
    }

    /// Execute `gemm_nN`: C ← A·B + C over f64 [n,n] operands.
    pub fn gemm(&mut self, a: &Mat, b: &Mat, c: &Mat) -> RtResult<Mat> {
        let n = a.rows();
        assert!(a.cols() == n && b.rows() == n && b.cols() == n, "square only");
        assert!(c.rows() == n && c.cols() == n);
        let la = mat_literal(a)?;
        let lb = mat_literal(b)?;
        let lc = mat_literal(c)?;
        let exe = self.load("gemm", n)?;
        let out = run1(exe, &[la, lb, lc])?;
        let v = out.to_vec::<f64>().map_err(|e| RtError::new(format!("to_vec: {e:?}")))?;
        Ok(Mat::from_row_major(n, n, &v))
    }

    /// Execute `gemv_nN`: y ← A·x + y.
    pub fn gemv(&mut self, a: &Mat, x: &[f64], y: &[f64]) -> RtResult<Vec<f64>> {
        let n = a.rows();
        assert!(a.cols() == n && x.len() == n && y.len() == n);
        let la = mat_literal(a)?;
        let lx = xla::Literal::vec1(x);
        let ly = xla::Literal::vec1(y);
        let exe = self.load("gemv", n)?;
        let out = run1(exe, &[la, lx, ly])?;
        out.to_vec::<f64>().map_err(|e| RtError::new(format!("to_vec: {e:?}")))
    }

    /// Execute `dot_nN`: xᵀ·y.
    pub fn dot(&mut self, x: &[f64], y: &[f64]) -> RtResult<f64> {
        let n = x.len();
        assert_eq!(y.len(), n);
        let lx = xla::Literal::vec1(x);
        let ly = xla::Literal::vec1(y);
        let exe = self.load("dot", n)?;
        let out = run1(exe, &[lx, ly])?;
        out.get_first_element::<f64>().map_err(|e| RtError::new(format!("scalar: {e:?}")))
    }

    /// Execute `axpy_nN`: α·x + y (α passed in, not baked per-artifact).
    pub fn axpy(&mut self, alpha: f64, x: &[f64], y: &[f64]) -> RtResult<Vec<f64>> {
        let n = x.len();
        assert_eq!(y.len(), n);
        let la = xla::Literal::scalar(alpha);
        let lx = xla::Literal::vec1(x);
        let ly = xla::Literal::vec1(y);
        let exe = self.load("axpy", n)?;
        let out = run1(exe, &[la, lx, ly])?;
        out.to_vec::<f64>().map_err(|e| RtError::new(format!("to_vec: {e:?}")))
    }

    /// Execute `nrm2_nN`: ‖x‖₂.
    pub fn nrm2(&mut self, x: &[f64]) -> RtResult<f64> {
        let lx = xla::Literal::vec1(x);
        let exe = self.load("nrm2", x.len())?;
        let out = run1(exe, &[lx])?;
        out.get_first_element::<f64>().map_err(|e| RtError::new(format!("scalar: {e:?}")))
    }

    /// Execute `qr_panel_nN`: one DGEQR2 Householder panel step (v, τ, and
    /// the updated trailing block) — the L2 fused kernel.
    pub fn qr_panel(&mut self, a: &Mat) -> RtResult<(Mat, f64)> {
        let n = a.rows();
        let la = mat_literal(a)?;
        let exe = self.load("qr_panel", n)?;
        let result = exe
            .execute::<xla::Literal>(&[la])
            .map_err(|e| RtError::new(format!("execute: {e:?}")))?[0][0]
            .to_literal_sync()
            .map_err(|e| RtError::new(format!("sync: {e:?}")))?;
        let (out_a, out_tau) =
            result.to_tuple2().map_err(|e| RtError::new(format!("tuple2: {e:?}")))?;
        let v = out_a.to_vec::<f64>().map_err(|e| RtError::new(format!("to_vec: {e:?}")))?;
        let tau = out_tau
            .get_first_element::<f64>()
            .map_err(|e| RtError::new(format!("tau: {e:?}")))?;
        Ok((Mat::from_row_major(n, n, &v), tau))
    }
}

/// Row-major f64 literal for a matrix.
fn mat_literal(m: &Mat) -> RtResult<xla::Literal> {
    xla::Literal::vec1(&m.to_row_major())
        .reshape(&[m.rows() as i64, m.cols() as i64])
        .map_err(|e| RtError::new(format!("reshape: {e:?}")))
}

/// Execute and unwrap a 1-tuple result (aot.py lowers with
/// `return_tuple=True`).
fn run1(exe: &xla::PjRtLoadedExecutable, args: &[xla::Literal]) -> RtResult<xla::Literal> {
    let result = exe
        .execute::<xla::Literal>(args)
        .map_err(|e| RtError::new(format!("execute: {e:?}")))?[0][0]
        .to_literal_sync()
        .map_err(|e| RtError::new(format!("sync: {e:?}")))?;
    result.to_tuple1().map_err(|e| RtError::new(format!("tuple1: {e:?}")))
}
