//! Runtime for the AOT-compiled XLA artifacts (HLO text emitted by
//! `python/compile/aot.py`).
//!
//! This is the value domain of the L3 co-simulation: the coordinator takes
//! *numerics* from these executables and *timing* from the PE/NoC
//! simulators. Python never runs here — the HLO text files are the entire
//! interchange (see `/opt/xla-example` and DESIGN.md: HLO text rather than
//! serialized protos because xla_extension 0.5.1 rejects jax≥0.5's 64-bit
//! instruction ids).
//!
//! Build modes:
//!
//! * **default** (no features): the [`Runtime`] is a stub whose constructor
//!   always fails, so the coordinator keeps every value on the
//!   [`crate::coordinator::ValueSource::PeSim`] path. The crate builds and
//!   tests fully offline with no external dependencies.
//! * **`--features pjrt`** alone: still the stub — the feature is
//!   CI-checkable without the vendored `xla` crate, so the gate cannot rot
//!   unbuilt.
//! * **`--features pjrt,xla-rt`**: compiles the real PJRT client in
//!   `pjrt.rs`, which requires the vendored `xla` crate (add the dependency
//!   in `rust/Cargo.toml`, see the comment there).

use std::fmt;
use std::path::Path;

/// Artifact naming convention produced by `aot.py`:
/// `artifacts/<op>_n<N>.hlo.txt`, e.g. `gemm_n64.hlo.txt`.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct ArtifactKey {
    pub op: String,
    pub n: usize,
}

impl ArtifactKey {
    pub fn file_name(&self) -> String {
        format!("{}_n{}.hlo.txt", self.op, self.n)
    }
}

/// Runtime error — a dependency-free stand-in for `anyhow` so the default
/// build needs no external crates.
#[derive(Debug, Clone)]
pub struct RtError(String);

impl RtError {
    pub fn new(msg: impl Into<String>) -> Self {
        Self(msg.into())
    }
}

impl fmt::Display for RtError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for RtError {}

/// Result alias for runtime operations.
pub type RtResult<T> = Result<T, RtError>;

/// Artifacts present on disk under `dir` (not necessarily loadable —
/// shared by the real and the stub runtime, and usable without either).
pub fn scan_artifacts(dir: &Path) -> Vec<ArtifactKey> {
    let mut keys = Vec::new();
    let Ok(rd) = std::fs::read_dir(dir) else {
        return keys;
    };
    for e in rd.flatten() {
        let name = e.file_name().to_string_lossy().into_owned();
        if let Some(stem) = name.strip_suffix(".hlo.txt") {
            if let Some((op, n)) = stem.rsplit_once("_n") {
                if let Ok(n) = n.parse::<usize>() {
                    keys.push(ArtifactKey { op: op.to_string(), n });
                }
            }
        }
    }
    keys.sort_by(|a, b| (a.op.clone(), a.n).cmp(&(b.op.clone(), b.n)));
    keys
}

/// True if an artifact file exists for (op, n) under `dir`.
pub fn has_artifact(dir: &Path, op: &str, n: usize) -> bool {
    dir.join(ArtifactKey { op: op.into(), n }.file_name()).exists()
}

#[cfg(all(feature = "pjrt", feature = "xla-rt"))]
mod pjrt;
#[cfg(all(feature = "pjrt", feature = "xla-rt"))]
pub use pjrt::Runtime;

#[cfg(not(all(feature = "pjrt", feature = "xla-rt")))]
mod stub;
#[cfg(not(all(feature = "pjrt", feature = "xla-rt")))]
pub use stub::Runtime;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifact_key_naming() {
        let k = ArtifactKey { op: "gemm".into(), n: 64 };
        assert_eq!(k.file_name(), "gemm_n64.hlo.txt");
    }

    #[test]
    fn scan_parses_names_and_ignores_junk() {
        let dir = std::env::temp_dir().join("redefine-artifact-scan-test");
        let _ = std::fs::create_dir_all(&dir);
        std::fs::write(dir.join("gemm_n20.hlo.txt"), "x").unwrap();
        std::fs::write(dir.join("qr_panel_n32.hlo.txt"), "x").unwrap();
        std::fs::write(dir.join("junk.bin"), "x").unwrap();
        let av = scan_artifacts(&dir);
        assert!(av.iter().any(|k| k.op == "gemm" && k.n == 20));
        assert!(av.iter().any(|k| k.op == "qr_panel" && k.n == 32));
        assert_eq!(av.len(), 2);
        assert!(has_artifact(&dir, "gemm", 20));
        assert!(!has_artifact(&dir, "gemm", 999));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn scan_of_missing_dir_is_empty() {
        assert!(scan_artifacts(Path::new("/nonexistent-artifacts")).is_empty());
    }

    #[cfg(not(all(feature = "pjrt", feature = "xla-rt")))]
    #[test]
    fn stub_runtime_reports_unavailable() {
        let err = Runtime::new("/nonexistent-artifacts").err().expect("stub must not construct");
        assert!(err.to_string().contains("pjrt"), "unexpected error: {err}");
    }

    #[cfg(all(feature = "pjrt", feature = "xla-rt"))]
    #[test]
    fn missing_artifact_is_reported() {
        let mut rt = match Runtime::new("/nonexistent-artifacts") {
            Ok(rt) => rt,
            Err(_) => return, // no PJRT in this environment: skip
        };
        let a = crate::util::Mat::eye(4);
        let err = rt.gemm(&a, &a, &a).unwrap_err().to_string();
        assert!(err.contains("make artifacts"), "unexpected error: {err}");
    }
}
