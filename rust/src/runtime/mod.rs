//! PJRT runtime: loads the AOT-compiled XLA artifacts (HLO text emitted by
//! `python/compile/aot.py`) and executes them on the CPU PJRT client.
//!
//! This is the value domain of the L3 co-simulation: the coordinator takes
//! *numerics* from these executables and *timing* from the PE/NoC
//! simulators. Python never runs here — the HLO text files are the entire
//! interchange (see `/opt/xla-example` and DESIGN.md: HLO text rather than
//! serialized protos because xla_extension 0.5.1 rejects jax≥0.5's 64-bit
//! instruction ids).

use crate::util::Mat;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Artifact naming convention produced by `aot.py`:
/// `artifacts/<op>_n<N>.hlo.txt`, e.g. `gemm_n64.hlo.txt`.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct ArtifactKey {
    pub op: String,
    pub n: usize,
}

impl ArtifactKey {
    pub fn file_name(&self) -> String {
        format!("{}_n{}.hlo.txt", self.op, self.n)
    }
}

/// The PJRT runtime: client + compiled-executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    cache: HashMap<ArtifactKey, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Create a CPU-PJRT runtime over an artifact directory.
    pub fn new(dir: impl AsRef<Path>) -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT client: {e:?}"))?;
        Ok(Self { client, dir: dir.as_ref().to_path_buf(), cache: HashMap::new() })
    }

    /// Platform string of the PJRT backend (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Artifacts available on disk (not necessarily loaded yet).
    pub fn available(&self) -> Vec<ArtifactKey> {
        let mut keys = Vec::new();
        let Ok(rd) = std::fs::read_dir(&self.dir) else {
            return keys;
        };
        for e in rd.flatten() {
            let name = e.file_name().to_string_lossy().into_owned();
            if let Some(stem) = name.strip_suffix(".hlo.txt") {
                if let Some((op, n)) = stem.rsplit_once("_n") {
                    if let Ok(n) = n.parse::<usize>() {
                        keys.push(ArtifactKey { op: op.to_string(), n });
                    }
                }
            }
        }
        keys.sort_by(|a, b| (a.op.clone(), a.n).cmp(&(b.op.clone(), b.n)));
        keys
    }

    /// True if an artifact exists for (op, n).
    pub fn has(&self, op: &str, n: usize) -> bool {
        self.dir.join(ArtifactKey { op: op.into(), n }.file_name()).exists()
    }

    /// Load (and cache) the executable for (op, n).
    pub fn load(&mut self, op: &str, n: usize) -> Result<&xla::PjRtLoadedExecutable> {
        let key = ArtifactKey { op: op.to_string(), n };
        if !self.cache.contains_key(&key) {
            let path = self.dir.join(key.file_name());
            if !path.exists() {
                bail!("artifact {} not found (run `make artifacts`)", path.display());
            }
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 path")?,
            )
            .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {}: {e:?}", path.display()))?;
            self.cache.insert(key.clone(), exe);
        }
        Ok(self.cache.get(&key).unwrap())
    }

    /// Execute `gemm_nN`: C ← A·B + C over f64 [n,n] operands.
    pub fn gemm(&mut self, a: &Mat, b: &Mat, c: &Mat) -> Result<Mat> {
        let n = a.rows();
        assert!(a.cols() == n && b.rows() == n && b.cols() == n, "square only");
        assert!(c.rows() == n && c.cols() == n);
        let la = mat_literal(a)?;
        let lb = mat_literal(b)?;
        let lc = mat_literal(c)?;
        let exe = self.load("gemm", n)?;
        let out = run1(exe, &[la, lb, lc])?;
        let v = out.to_vec::<f64>().map_err(|e| anyhow!("to_vec: {e:?}"))?;
        Ok(Mat::from_row_major(n, n, &v))
    }

    /// Execute `gemv_nN`: y ← A·x + y.
    pub fn gemv(&mut self, a: &Mat, x: &[f64], y: &[f64]) -> Result<Vec<f64>> {
        let n = a.rows();
        assert!(a.cols() == n && x.len() == n && y.len() == n);
        let la = mat_literal(a)?;
        let lx = xla::Literal::vec1(x);
        let ly = xla::Literal::vec1(y);
        let exe = self.load("gemv", n)?;
        let out = run1(exe, &[la, lx, ly])?;
        out.to_vec::<f64>().map_err(|e| anyhow!("to_vec: {e:?}"))
    }

    /// Execute `dot_nN`: xᵀ·y.
    pub fn dot(&mut self, x: &[f64], y: &[f64]) -> Result<f64> {
        let n = x.len();
        assert_eq!(y.len(), n);
        let lx = xla::Literal::vec1(x);
        let ly = xla::Literal::vec1(y);
        let exe = self.load("dot", n)?;
        let out = run1(exe, &[lx, ly])?;
        out.get_first_element::<f64>().map_err(|e| anyhow!("scalar: {e:?}"))
    }

    /// Execute `axpy_nN`: α·x + y (α baked per-artifact? no — passed in).
    pub fn axpy(&mut self, alpha: f64, x: &[f64], y: &[f64]) -> Result<Vec<f64>> {
        let n = x.len();
        assert_eq!(y.len(), n);
        let la = xla::Literal::scalar(alpha);
        let lx = xla::Literal::vec1(x);
        let ly = xla::Literal::vec1(y);
        let exe = self.load("axpy", n)?;
        let out = run1(exe, &[la, lx, ly])?;
        out.to_vec::<f64>().map_err(|e| anyhow!("to_vec: {e:?}"))
    }

    /// Execute `nrm2_nN`: ‖x‖₂.
    pub fn nrm2(&mut self, x: &[f64]) -> Result<f64> {
        let lx = xla::Literal::vec1(x);
        let exe = self.load("nrm2", x.len())?;
        let out = run1(exe, &[lx])?;
        out.get_first_element::<f64>().map_err(|e| anyhow!("scalar: {e:?}"))
    }

    /// Execute `qr_panel_nN`: one DGEQR2 Householder panel step (v, τ, and
    /// the updated trailing block) — the L2 fused kernel.
    pub fn qr_panel(&mut self, a: &Mat) -> Result<(Mat, f64)> {
        let n = a.rows();
        let la = mat_literal(a)?;
        let exe = self.load("qr_panel", n)?;
        let result = exe
            .execute::<xla::Literal>(&[la])
            .map_err(|e| anyhow!("execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("sync: {e:?}"))?;
        let (out_a, out_tau) =
            result.to_tuple2().map_err(|e| anyhow!("tuple2: {e:?}"))?;
        let v = out_a.to_vec::<f64>().map_err(|e| anyhow!("to_vec: {e:?}"))?;
        let tau = out_tau.get_first_element::<f64>().map_err(|e| anyhow!("tau: {e:?}"))?;
        Ok((Mat::from_row_major(n, n, &v), tau))
    }
}

/// Row-major f64 literal for a matrix.
fn mat_literal(m: &Mat) -> Result<xla::Literal> {
    xla::Literal::vec1(&m.to_row_major())
        .reshape(&[m.rows() as i64, m.cols() as i64])
        .map_err(|e| anyhow!("reshape: {e:?}"))
}

/// Execute and unwrap a 1-tuple result (aot.py lowers with
/// `return_tuple=True`).
fn run1(exe: &xla::PjRtLoadedExecutable, args: &[xla::Literal]) -> Result<xla::Literal> {
    let result = exe
        .execute::<xla::Literal>(args)
        .map_err(|e| anyhow!("execute: {e:?}"))?[0][0]
        .to_literal_sync()
        .map_err(|e| anyhow!("sync: {e:?}"))?;
    result.to_tuple1().map_err(|e| anyhow!("tuple1: {e:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifact_key_naming() {
        let k = ArtifactKey { op: "gemm".into(), n: 64 };
        assert_eq!(k.file_name(), "gemm_n64.hlo.txt");
    }

    #[test]
    fn missing_artifact_is_reported() {
        let mut rt = match Runtime::new("/nonexistent-artifacts") {
            Ok(rt) => rt,
            Err(_) => return, // no PJRT in this environment: skip
        };
        let a = Mat::eye(4);
        let err = rt.gemm(&a, &a, &a).unwrap_err().to_string();
        assert!(err.contains("make artifacts"), "unexpected error: {err}");
    }

    #[test]
    fn available_parses_names() {
        let dir = std::env::temp_dir().join("redefine-artifact-test");
        let _ = std::fs::create_dir_all(&dir);
        std::fs::write(dir.join("gemm_n20.hlo.txt"), "x").unwrap();
        std::fs::write(dir.join("junk.bin"), "x").unwrap();
        let rt = match Runtime::new(&dir) {
            Ok(rt) => rt,
            Err(_) => return,
        };
        let av = rt.available();
        assert!(av.iter().any(|k| k.op == "gemm" && k.n == 20));
        assert!(rt.has("gemm", 20));
        assert!(!rt.has("gemm", 999));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
