//! DGEMM code generation for the PE, per enhancement level.
//!
//! One routine, six compilations — the co-design story of the paper:
//!
//! * **AE0** (§4.4, algorithm 3): 4×4 register-blocked GEMM, every operand
//!   fetched from GM, scalar `Fmac` compute walking one output row at a time
//!   (the natural translation of algorithm 1's loop nest).
//! * **AE1** (§5.1): operands staged through the Local Memory — an A row
//!   strip and a B column panel per block row/column — `LmLd` + `Fmac`.
//! * **AE2** (§5.2.1): the 16 c(i,j) updates of a block step become 16
//!   independent `DOT4` instructions with accumulate.
//! * **AE3** (§5.2.2): GM↔LM staging uses single-handshake Block Data
//!   Load/Store (timing change in the LS engine; same stream shape).
//! * **AE4** (§5.3): RF↔LM moves become 256-bit `LmLd4`/`LmSt4`.
//! * **AE5** (§5.4, algorithm 4 + fig 10): software pipelining — the k-loop
//!   is restructured so the block loads for iteration k+1 issue behind the
//!   DOT4s of iteration k, and the next B panel is pre-fetched into a
//!   double-buffered LM region while the current one is consumed.
//!
//! Register map: C block r0–r15 (column-major, c(i,j) = r[4j+i]); A block
//! r16–r31 (row-major, a(i,k) = r[16+4i+k]) so each row is a DOT4 `ra`
//! window; B block r32–r47 (column-major, b(k,j) = r[32+4j+k]) so each
//! column is a DOT4 `rb` window.

use super::layout::GemmLayout;
use crate::pe::{AeLevel, Instr, Program};

/// Base register of the C block.
const RC: u8 = 0;
/// Base register of the A block (row-major).
const RA: u8 = 16;
/// Base register of the B block (column-major).
const RB: u8 = 32;

/// LM word offsets for the GEMM working set.
#[derive(Debug, Clone, Copy)]
struct LmMap {
    /// A row strip: 4 rows × n, row r at `a + r*n`.
    a: u32,
    /// B column panels (double-buffered at AE5): col c at `b[buf] + c*n`.
    b: [u32; 2],
    /// C block scratch: column j at `c + 4j`.
    c: u32,
}

impl LmMap {
    fn new(n: usize) -> Self {
        let n = n as u32;
        let map = Self { a: 0, b: [4 * n, 8 * n], c: 12 * n };
        assert!(
            (map.c + 16) as usize <= crate::pe::LM_WORDS,
            "GEMM working set exceeds the 256-kbit Local Memory for n={n}"
        );
        map
    }
}

/// Generate the DGEMM program `C ← A·B + C` for an n×n problem (n % 4 == 0)
/// at the given enhancement level.
pub fn gen_gemm(n: usize, ae: AeLevel, layout: &GemmLayout) -> Program {
    assert!(n % 4 == 0 && n >= 4, "n must be a positive multiple of 4, got {n}");
    gen_gemm_rect(n, n, n, ae, layout)
}

/// Generate the rectangular DGEMM program C (m×p) ← A (m×k)·B (k×p) + C.
/// All dimensions must be multiples of 4 (the coordinator pads). This is
/// the kernel each REDEFINE tile runs in the parallel realization (§5.5):
/// an output block of m×p with the full inner dimension k.
pub fn gen_gemm_rect(m: usize, p: usize, k: usize, ae: AeLevel, layout: &GemmLayout) -> Program {
    for (d, name) in [(m, "m"), (p, "p"), (k, "k")] {
        assert!(d % 4 == 0 && d >= 4, "{name} must be a positive multiple of 4, got {d}");
    }
    assert_eq!((layout.m, layout.p, layout.k), (m, p, k), "layout/problem size mismatch");
    let mut prog = Program::new();
    if ae == AeLevel::Ae0 {
        gen_ae0(m, p, k, layout, &mut prog);
    } else {
        gen_lm(m, p, k, ae, layout, &mut prog);
    }
    prog.push(Instr::Halt);
    debug_assert!(prog.validate().is_ok());
    prog
}

/// AE0: everything from GM, scalar loads, Fmac compute.
fn gen_ae0(m: usize, pcols: usize, kdim: usize, l: &GemmLayout, p: &mut Program) {
    for ib in 0..m / 4 {
        for jb in 0..pcols / 4 {
            // Load the 4×4 C block (column-major registers).
            for j in 0..4 {
                for i in 0..4 {
                    p.push(Instr::Ld { rd: RC + (4 * j + i) as u8, gm: l.c(4 * ib + i, 4 * jb + j) as u32 });
                }
            }
            for kb in 0..kdim / 4 {
                emit_block_loads_gm(l, ib, jb, kb, p);
                emit_fmacs(p);
                // Simple loop sequencer: stall at the back-edge (fig 10).
                p.push(Instr::Barrier);
            }
            for j in 0..4 {
                for i in 0..4 {
                    p.push(Instr::St { rs: RC + (4 * j + i) as u8, gm: l.c(4 * ib + i, 4 * jb + j) as u32 });
                }
            }
        }
    }
}

/// AE1–AE5: operands staged through LM.
fn gen_lm(m: usize, pcols: usize, kdim: usize, ae: AeLevel, l: &GemmLayout, p: &mut Program) {
    let kb_count = kdim / 4;
    let lm = LmMap::new(kdim);
    let prefetch = ae.has_prefetch();

    for ib in 0..m / 4 {
        // Stage the A row strip (4 rows × k) for this block row.
        for r in 0..4 {
            p.push(Instr::BlkLd {
                lm: lm.a + (r * kdim) as u32,
                gm: l.a(4 * ib + r, 0) as u32,
                len: kdim as u32,
            });
        }
        // Without pre-fetch, each B panel is staged at the top of its jb
        // body; with AE5 the panel for jb+1 streams in behind the compute.
        if prefetch {
            emit_panel_load(kdim, l, 0, lm.b[0], p);
        }
        for jb in 0..pcols / 4 {
            let buf = if prefetch { lm.b[jb % 2] } else { lm.b[0] };
            if !prefetch {
                emit_panel_load(kdim, l, jb, buf, p);
            }
            // C block GM→LM→RF (one 4-word column at a time; C columns are
            // contiguous in GM).
            for j in 0..4 {
                p.push(Instr::BlkLd {
                    lm: lm.c + 4 * j as u32,
                    gm: l.c(4 * ib, 4 * jb + j) as u32,
                    len: 4,
                });
            }
            if prefetch && jb + 1 < pcols / 4 {
                // AE5: pre-fetch the next B panel into the other buffer now;
                // it streams on the GM engine under the whole k-loop below.
                emit_panel_load(kdim, l, jb + 1, lm.b[(jb + 1) % 2], p);
            }
            emit_c_rf_loads(ae, &lm, p);

            if prefetch {
                // Software-pipelined k-loop (algorithm 4): loads for step
                // kb+1 issue behind the DOT4s of step kb.
                emit_block_loads_lm(kdim, ae, &lm, buf, 0, p);
                for kb in 0..kb_count {
                    emit_dots(p);
                    if kb + 1 < kb_count {
                        emit_block_loads_lm(kdim, ae, &lm, buf, kb + 1, p);
                    }
                }
            } else {
                for kb in 0..kb_count {
                    emit_block_loads_lm(kdim, ae, &lm, buf, kb, p);
                    if ae.has_dot() {
                        emit_dots(p);
                    } else {
                        emit_fmacs(p);
                    }
                    // Simple loop sequencer: stall at the back-edge; the
                    // AE5 restructured loop (other branch) removes this.
                    p.push(Instr::Barrier);
                }
            }

            // C block RF→LM→GM.
            emit_c_rf_stores(ae, &lm, p);
            for j in 0..4 {
                p.push(Instr::BlkSt {
                    lm: lm.c + 4 * j as u32,
                    gm: l.c(4 * ib, 4 * jb + j) as u32,
                    len: 4,
                });
            }
        }
    }
}

/// Stage B panel `jb` (4 columns × k) into an LM buffer.
fn emit_panel_load(kdim: usize, l: &GemmLayout, jb: usize, buf: u32, p: &mut Program) {
    for c in 0..4 {
        p.push(Instr::BlkLd {
            lm: buf + (c * kdim) as u32,
            gm: l.b(0, 4 * jb + c) as u32,
            len: kdim as u32,
        });
    }
}

/// Load the A and B 4×4 blocks of step `kb` from LM into the register file.
fn emit_block_loads_lm(n: usize, ae: AeLevel, lm: &LmMap, buf: u32, kb: usize, p: &mut Program) {
    if ae.has_wide_path() {
        for i in 0..4u8 {
            p.push(Instr::LmLd4 { rd: RA + 4 * i, lm: lm.a + (i as usize * n + 4 * kb) as u32 });
        }
        for j in 0..4u8 {
            p.push(Instr::LmLd4 { rd: RB + 4 * j, lm: buf + (j as usize * n + 4 * kb) as u32 });
        }
    } else {
        for i in 0..4u8 {
            for k in 0..4u8 {
                p.push(Instr::LmLd {
                    rd: RA + 4 * i + k,
                    lm: lm.a + (i as usize * n + 4 * kb + k as usize) as u32,
                });
            }
        }
        for j in 0..4u8 {
            for k in 0..4u8 {
                p.push(Instr::LmLd {
                    rd: RB + 4 * j + k,
                    lm: buf + (j as usize * n + 4 * kb + k as usize) as u32,
                });
            }
        }
    }
}

/// Load the A and B blocks of step (ib, jb, kb) straight from GM (AE0).
fn emit_block_loads_gm(l: &GemmLayout, ib: usize, jb: usize, kb: usize, p: &mut Program) {
    for i in 0..4 {
        for k in 0..4 {
            p.push(Instr::Ld {
                rd: RA + (4 * i + k) as u8,
                gm: l.a(4 * ib + i, 4 * kb + k) as u32,
            });
        }
    }
    for j in 0..4 {
        for k in 0..4 {
            p.push(Instr::Ld {
                rd: RB + (4 * j + k) as u8,
                gm: l.b(4 * kb + k, 4 * jb + j) as u32,
            });
        }
    }
}

/// C block LM→RF.
fn emit_c_rf_loads(ae: AeLevel, lm: &LmMap, p: &mut Program) {
    if ae.has_wide_path() {
        for j in 0..4u8 {
            p.push(Instr::LmLd4 { rd: RC + 4 * j, lm: lm.c + 4 * j as u32 });
        }
    } else {
        for j in 0..4u8 {
            for i in 0..4u8 {
                p.push(Instr::LmLd { rd: RC + 4 * j + i, lm: lm.c + (4 * j + i) as u32 });
            }
        }
    }
}

/// C block RF→LM.
fn emit_c_rf_stores(ae: AeLevel, lm: &LmMap, p: &mut Program) {
    if ae.has_wide_path() {
        for j in 0..4u8 {
            p.push(Instr::LmSt4 { rs: RC + 4 * j, lm: lm.c + 4 * j as u32 });
        }
    } else {
        for j in 0..4u8 {
            for i in 0..4u8 {
                p.push(Instr::LmSt { rs: RC + 4 * j + i, lm: lm.c + (4 * j + i) as u32 });
            }
        }
    }
}

/// 64 scalar macs for one 4×4×4 block step, walking one output row at a
/// time (i outer, k middle, j inner): consecutive instructions touch the
/// four chains c(i, 0..4), the dependency pattern of the pre-DOT PE.
fn emit_fmacs(p: &mut Program) {
    for i in 0..4u8 {
        for k in 0..4u8 {
            for j in 0..4u8 {
                p.push(Instr::Fmac { rd: RC + 4 * j + i, ra: RA + 4 * i + k, rb: RB + 4 * j + k });
            }
        }
    }
}

/// 16 DOT4-with-accumulate for one block step (independent of each other).
fn emit_dots(p: &mut Program) {
    for i in 0..4u8 {
        for j in 0..4u8 {
            p.push(Instr::Dot { rd: RC + 4 * j + i, ra: RA + 4 * i, rb: RB + 4 * j, n: 4, acc: true });
        }
    }
}

/// Worst-case innermost-loop-body footprint in instructions for the DGEMM
/// kernel at an enhancement level. The real PE executes loop bodies from
/// its 16 KB instruction memory (§4.5); our generators unroll, so this
/// accounting (checked by `imem_fits_16kb`) keeps them honest: the body
/// that would live in imem must fit.
pub fn loop_body_instrs(ae: AeLevel) -> usize {
    let loads = if ae.has_wide_path() { 8 } else { 32 }; // A + B block
    let compute = if ae.has_dot() { 16 } else { 64 }; // DOTs vs Fmacs
    let barrier = usize::from(!ae.has_prefetch());
    // AE5 pipelines two bodies (loads for kb+1 behind dots for kb).
    let pipeline = if ae.has_prefetch() { loads } else { 0 };
    loads + compute + barrier + pipeline
}

/// Encoded instruction width assumed for imem accounting (64-bit words,
/// matching the 64-bit datapath).
pub const INSTR_BYTES: usize = 8;

/// Paper-convention flop count for an n×n DGEMM: the Tables 4–9 CPF column
/// is consistent with 3n³ (multiply, reduction add and accumulate counted
/// separately) — see DESIGN.md §Calibration.
pub fn paper_flops(n: usize) -> u64 {
    3 * (n as u64).pow(3)
}

/// Standard flop count (2n³).
pub fn std_flops(n: usize) -> u64 {
    2 * (n as u64).pow(3)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pe::{Pe, PeConfig};
    use crate::util::{assert_allclose, Mat};

    fn run_gemm(n: usize, ae: AeLevel) -> (Mat, crate::pe::PeStats) {
        let a = Mat::random(n, n, 100 + n as u64);
        let b = Mat::random(n, n, 200 + n as u64);
        let c0 = Mat::random(n, n, 300 + n as u64);
        let layout = GemmLayout::packed(n);
        let prog = gen_gemm(n, ae, &layout);
        let mut pe = Pe::new(PeConfig::paper(ae), layout.gm_words());
        pe.write_gm(0, &layout.pack(&a, &b, &c0));
        let st = pe.run(&prog);
        let got = layout.unpack_c(&pe.gm, n, n);
        // Host reference.
        let mut want = c0.clone();
        for i in 0..n {
            for j in 0..n {
                let mut s = want[(i, j)];
                for k in 0..n {
                    s += a[(i, k)] * b[(k, j)];
                }
                want[(i, j)] = s;
            }
        }
        assert_allclose(got.as_slice(), want.as_slice(), 1e-12);
        (got, st)
    }

    #[test]
    fn gemm_numerics_all_levels_n8() {
        for ae in AeLevel::ALL {
            run_gemm(8, ae);
        }
    }

    #[test]
    fn gemm_numerics_n20_ae0_ae5() {
        run_gemm(20, AeLevel::Ae0);
        run_gemm(20, AeLevel::Ae5);
    }

    #[test]
    fn each_enhancement_reduces_latency_n20() {
        let mut prev = u64::MAX;
        for ae in AeLevel::ALL {
            let (_, st) = run_gemm(20, ae);
            assert!(
                st.cycles < prev,
                "{ae}: {} cycles did not improve on previous {prev}",
                st.cycles
            );
            prev = st.cycles;
        }
    }

    #[test]
    fn dot_count_matches_alpha_denominator() {
        // α (eq. 7) denominator: n³/4 DOT4s for the multiply-accumulate work.
        let layout = GemmLayout::packed(16);
        let prog = gen_gemm(16, AeLevel::Ae5, &layout);
        assert_eq!(prog.dot_count(), (16u64).pow(3) / 4);
    }

    #[test]
    fn flop_conventions() {
        assert_eq!(paper_flops(20), 24_000);
        assert_eq!(std_flops(20), 16_000);
    }

    #[test]
    fn executed_flops_match_convention() {
        // Dot with acc does 8 flops per 4 macs = 2n³ total… plus C has no
        // extra ops; Fmac path does 2 flops per mac likewise.
        let (_, st) = run_gemm(8, AeLevel::Ae5);
        assert_eq!(st.flops, 2 * 8u64.pow(3));
        let (_, st0) = run_gemm(8, AeLevel::Ae0);
        assert_eq!(st0.flops, 2 * 8u64.pow(3));
    }

    #[test]
    #[should_panic(expected = "multiple of 4")]
    fn rejects_unpadded_n() {
        let layout = GemmLayout::packed(8);
        gen_gemm(6, AeLevel::Ae0, &layout);
    }

    #[test]
    fn imem_fits_16kb() {
        // §4.5: 16 KB instruction memory. Every level's innermost loop
        // body (plus generous room for the loop control the real PE would
        // carry) must fit.
        let imem = crate::pe::PeConfig::paper(AeLevel::Ae0).imem_bytes;
        for ae in AeLevel::ALL {
            let body = loop_body_instrs(ae) * INSTR_BYTES;
            assert!(
                body * 4 < imem,
                "{ae}: loop body {body} B leaves no imem headroom"
            );
        }
    }

    #[test]
    fn prefetch_outperforms_no_prefetch() {
        let (_, st4) = run_gemm(40, AeLevel::Ae4);
        let (_, st5) = run_gemm(40, AeLevel::Ae5);
        let gain = 1.0 - st5.cycles as f64 / st4.cycles as f64;
        assert!(gain > 0.10, "AE5 prefetch gain too small: {gain:.3}");
    }
}
