//! Peephole optimizer over PE instruction streams.
//!
//! The codegen layer emits one canonical stream per routine; this pass
//! applies the machine-level rewrites a production toolchain would:
//!
//! * **wide-load combining** (AE4+): four scalar `LmLd`/`LmSt` with
//!   consecutive LM addresses and consecutive registers fuse into one
//!   256-bit `LmLd4`/`LmSt4` — this is how AE2/AE3-era kernels benefit
//!   from the widened FPS↔CFU path without re-emission;
//! * **dead-code elimination**: arithmetic/`Li` results never read before
//!   being overwritten are dropped (backward liveness over the straight-
//!   line stream);
//! * **barrier coalescing**: adjacent loop-edge barriers collapse.
//!
//! Every rewrite preserves the functional semantics exactly (tested by
//! running original and optimized programs on the simulator and comparing
//! the full GM image).

use crate::pe::{AeLevel, Instr, Program};

/// What the optimizer did (for logs and ablation benches).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct OptReport {
    pub loads_combined: usize,
    pub stores_combined: usize,
    pub dead_removed: usize,
    pub barriers_merged: usize,
    pub before: usize,
    pub after: usize,
}

/// Optimize a program for the given enhancement level.
pub fn optimize(prog: &Program, ae: AeLevel) -> (Program, OptReport) {
    let mut rep = OptReport { before: prog.len(), ..Default::default() };
    let mut instrs = prog.instrs.clone();
    if ae.has_wide_path() {
        instrs = combine_wide(instrs, &mut rep);
    }
    instrs = dead_code(instrs, &mut rep);
    instrs = merge_barriers(instrs, &mut rep);
    rep.after = instrs.len();
    let out = Program { instrs };
    debug_assert!(out.validate().is_ok());
    (out, rep)
}

/// Fuse runs of 4 scalar LM accesses into wide ops. Only exact patterns
/// (rd, rd+1, rd+2, rd+3 over lm, lm+1, lm+2, lm+3 with rd and the run
/// 4-aligned) are rewritten.
fn combine_wide(instrs: Vec<Instr>, rep: &mut OptReport) -> Vec<Instr> {
    let mut out = Vec::with_capacity(instrs.len());
    let mut i = 0;
    while i < instrs.len() {
        if i + 3 < instrs.len() {
            if let Some(w) = try_fuse(&instrs[i..i + 4]) {
                match w {
                    Instr::LmLd4 { .. } => rep.loads_combined += 1,
                    _ => rep.stores_combined += 1,
                }
                out.push(w);
                i += 4;
                continue;
            }
        }
        out.push(instrs[i]);
        i += 1;
    }
    out
}

fn try_fuse(w: &[Instr]) -> Option<Instr> {
    match w[0] {
        Instr::LmLd { rd, lm } if rd % 4 == 0 => {
            for (k, ins) in w.iter().enumerate().skip(1) {
                match *ins {
                    Instr::LmLd { rd: r2, lm: l2 }
                        if r2 == rd + k as u8 && l2 == lm + k as u32 => {}
                    _ => return None,
                }
            }
            Some(Instr::LmLd4 { rd, lm })
        }
        Instr::LmSt { rs, lm } if rs % 4 == 0 => {
            for (k, ins) in w.iter().enumerate().skip(1) {
                match *ins {
                    Instr::LmSt { rs: r2, lm: l2 }
                        if r2 == rs + k as u8 && l2 == lm + k as u32 => {}
                    _ => return None,
                }
            }
            Some(Instr::LmSt4 { rs, lm })
        }
        _ => None,
    }
}

/// Backward-liveness dead-code elimination for pure register producers.
fn dead_code(instrs: Vec<Instr>, rep: &mut OptReport) -> Vec<Instr> {
    let mut live = [false; crate::pe::NUM_REGS];
    // Conservatively: anything live at program end stays live (results may
    // be inspected); only values overwritten before any use are dead.
    let mut keep = vec![true; instrs.len()];
    let mut srcs = Vec::new();
    let mut dsts = Vec::new();
    // Walk backwards, tracking "will be read before next write".
    let mut read_before_write = [true; crate::pe::NUM_REGS];
    for (idx, ins) in instrs.iter().enumerate().rev() {
        srcs.clear();
        dsts.clear();
        ins.srcs(&mut srcs);
        ins.dsts(&mut dsts);
        let pure = matches!(
            ins,
            Instr::Li { .. }
                | Instr::Fadd { .. }
                | Instr::Fsub { .. }
                | Instr::Fmul { .. }
                | Instr::Fdiv { .. }
                | Instr::Fsqrt { .. }
                | Instr::Fmac { .. }
                | Instr::Dot { .. }
        );
        if pure && !dsts.is_empty() && dsts.iter().all(|&d| !read_before_write[d as usize]) {
            keep[idx] = false;
            rep.dead_removed += 1;
            continue; // its reads do not become live
        }
        for &d in &dsts {
            read_before_write[d as usize] = false;
        }
        for &s in &srcs {
            read_before_write[s as usize] = true;
        }
        let _ = &mut live;
    }
    instrs
        .into_iter()
        .zip(keep)
        .filter_map(|(ins, k)| k.then_some(ins))
        .collect()
}

/// Collapse runs of barriers.
fn merge_barriers(instrs: Vec<Instr>, rep: &mut OptReport) -> Vec<Instr> {
    let mut out: Vec<Instr> = Vec::with_capacity(instrs.len());
    for ins in instrs {
        if matches!(ins, Instr::Barrier) && matches!(out.last(), Some(Instr::Barrier)) {
            rep.barriers_merged += 1;
            continue;
        }
        out.push(ins);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codegen::{gen_gemm, GemmLayout};
    use crate::pe::{Pe, PeConfig};
    use crate::util::Mat;

    #[test]
    fn fuses_aligned_scalar_loads() {
        let mut p = Program::new();
        for k in 0..4u8 {
            p.push(Instr::LmLd { rd: 16 + k, lm: 100 + k as u32 });
        }
        p.push(Instr::Halt);
        let (o, rep) = optimize(&p, AeLevel::Ae4);
        assert_eq!(rep.loads_combined, 1);
        assert!(matches!(o.instrs[0], Instr::LmLd4 { rd: 16, lm: 100 }));
    }

    #[test]
    fn does_not_fuse_unaligned_or_gapped() {
        let mut p = Program::new();
        for k in 0..4u8 {
            p.push(Instr::LmLd { rd: 17 + k, lm: 100 + k as u32 }); // rd not 4-aligned
        }
        p.push(Instr::Halt);
        let (_, rep) = optimize(&p, AeLevel::Ae4);
        assert_eq!(rep.loads_combined, 0);
        let mut p2 = Program::new();
        p2.push(Instr::LmLd { rd: 16, lm: 0 });
        p2.push(Instr::LmLd { rd: 17, lm: 2 }); // address gap
        p2.push(Instr::LmLd { rd: 18, lm: 3 });
        p2.push(Instr::LmLd { rd: 19, lm: 4 });
        let (_, rep2) = optimize(&p2, AeLevel::Ae4);
        assert_eq!(rep2.loads_combined, 0);
    }

    #[test]
    fn no_fusion_below_ae4() {
        let mut p = Program::new();
        for k in 0..4u8 {
            p.push(Instr::LmLd { rd: 16 + k, lm: 100 + k as u32 });
        }
        let (o, rep) = optimize(&p, AeLevel::Ae3);
        assert_eq!(rep.loads_combined, 0);
        assert_eq!(o.len(), 4);
    }

    #[test]
    fn removes_dead_li_and_keeps_used() {
        let mut p = Program::new();
        p.push(Instr::Li { rd: 0, val: 1.0 }); // dead: overwritten below
        p.push(Instr::Li { rd: 0, val: 2.0 });
        p.push(Instr::Li { rd: 1, val: 3.0 });
        p.push(Instr::Fadd { rd: 2, ra: 0, rb: 1 });
        p.push(Instr::St { rs: 2, gm: 0 });
        p.push(Instr::Halt);
        let (o, rep) = optimize(&p, AeLevel::Ae0);
        assert_eq!(rep.dead_removed, 1);
        assert_eq!(o.len(), p.len() - 1);
    }

    #[test]
    fn merges_barriers() {
        let mut p = Program::new();
        p.push(Instr::Nop);
        p.push(Instr::Barrier);
        p.push(Instr::Barrier);
        p.push(Instr::Barrier);
        p.push(Instr::Nop);
        let (o, rep) = optimize(&p, AeLevel::Ae0);
        assert_eq!(rep.barriers_merged, 2);
        assert_eq!(o.len(), 3);
    }

    #[test]
    fn ae3_gemm_optimized_for_ae4_matches_and_speeds_up() {
        // Emit the AE3-shaped stream (scalar LM ops), fuse for AE4, and
        // check both value-equivalence and a real cycle win.
        let n = 16;
        let layout = GemmLayout::packed(n);
        let prog3 = gen_gemm(n, AeLevel::Ae3, &layout);
        let (fused, rep) = optimize(&prog3, AeLevel::Ae4);
        assert!(rep.loads_combined > 0, "{rep:?}");

        let a = Mat::random(n, n, 1);
        let b = Mat::random(n, n, 2);
        let c = Mat::random(n, n, 3);
        let gm = layout.pack(&a, &b, &c);

        let mut pe_a = Pe::new(PeConfig::paper(AeLevel::Ae4), layout.gm_words());
        pe_a.write_gm(0, &gm);
        let st_orig = pe_a.run(&prog3);
        let c_orig = layout.unpack_c(&pe_a.gm, n, n);

        let mut pe_b = Pe::new(PeConfig::paper(AeLevel::Ae4), layout.gm_words());
        pe_b.write_gm(0, &gm);
        let st_fused = pe_b.run(&fused);
        let c_fused = layout.unpack_c(&pe_b.gm, n, n);

        assert_eq!(c_orig, c_fused, "optimization changed values");
        assert!(
            st_fused.cycles < st_orig.cycles,
            "fusion should win: {} vs {}",
            st_fused.cycles,
            st_orig.cycles
        );
    }

    #[test]
    fn optimizer_is_idempotent() {
        let layout = GemmLayout::packed(8);
        let p = gen_gemm(8, AeLevel::Ae3, &layout);
        let (o1, _) = optimize(&p, AeLevel::Ae4);
        let (o2, rep2) = optimize(&o1, AeLevel::Ae4);
        assert_eq!(o1.instrs, o2.instrs);
        assert_eq!(rep2.loads_combined + rep2.dead_removed + rep2.barriers_merged, 0);
    }
}
