//! Code generators: BLAS routines compiled to PE instruction streams.
//!
//! This layer is the *algorithm* half of the paper's algorithm-architecture
//! co-design: the same routine is emitted differently per enhancement level
//! (scalar macs vs DOT4, scalar vs block loads, with/without pre-fetch —
//! algorithms 1, 3 and 4 of the paper), and the PE simulator measures the
//! resulting latency.
//!
//! Data layout convention (marshalled by the coordinator, see
//! [`layout`]): **A row-major, B column-major, C/vectors column-major**
//! in PE global memory, so that DOT4 operand windows and Block Data
//! Load/Store transfers are contiguous.

pub mod gemm;
pub mod gemm_any;
pub mod gemv;
pub mod layout;
pub mod level1;
pub mod optimizer;

pub use gemm::{gen_gemm, gen_gemm_rect};
pub use gemm_any::gen_gemm_any;
pub use optimizer::{optimize, OptReport};
pub use gemv::gen_gemv;
pub use layout::GemmLayout;
pub use level1::{gen_daxpy, gen_ddot, gen_dnrm2};
