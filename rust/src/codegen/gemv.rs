//! DGEMV code generation: y ← A·x + y on the PE.
//!
//! Level-2 BLAS moves O(n²) data for O(n²) work — each element of A is used
//! exactly once, so DGEMV is bandwidth-bound on every platform the paper
//! measures (§3.2: 4–5% of peak on CPUs/GPUs). On the PE the co-designed
//! kernel reaches ≈40% of peak (abstract): x is staged once in LM, A rows
//! stream through LM in 4-row strips, and each strip is reduced with DOT4s
//! into four independent accumulators.
//!
//! Register map: y accumulators r0–r3 (strip rows), A row segments r16–r31
//! (row r at r16+4r), x segment r32–r35, scratch r48+.

use super::layout::VecLayout;
use crate::pe::{AeLevel, Instr, Program};

const RY: u8 = 0;
/// Secondary y partials (odd k-steps) — the DOT4 RDP is 15 stages deep, so
/// each row keeps two alternating partial accumulators.
const RY2: u8 = 4;
const RA: u8 = 16;
const RX: u8 = 32;

/// LM offsets: x vector at 0..n; double-buffered A strips (4 rows × n
/// each — the AE5 pre-fetch writes the next strip while the current one is
/// consumed); y strip scratch after them.
#[derive(Debug, Clone, Copy)]
struct LmMap {
    x: u32,
    a: [u32; 2],
    y: u32,
}

impl LmMap {
    fn new(n: usize) -> Self {
        let n32 = n as u32;
        let m = Self { x: 0, a: [n32, 5 * n32], y: 9 * n32 };
        assert!(
            (m.y + 4) as usize <= crate::pe::LM_WORDS,
            "GEMV working set exceeds LM for n={n}"
        );
        m
    }
}

/// Generate DGEMV `y ← A·x + y` (A n×n row-major, n % 4 == 0).
pub fn gen_gemv(n: usize, ae: AeLevel, l: &VecLayout) -> Program {
    assert_eq!(l.n, n);
    assert!(n % 4 == 0 && n >= 4, "n must be a positive multiple of 4, got {n}");
    let mut p = Program::new();
    if ae == AeLevel::Ae0 {
        gen_ae0(n, l, &mut p);
    } else {
        gen_lm(n, ae, l, &mut p);
    }
    p.push(Instr::Halt);
    debug_assert!(p.validate().is_ok());
    p
}

/// AE0: stream everything from GM with scalar loads and Fmacs.
fn gen_ae0(n: usize, l: &VecLayout, p: &mut Program) {
    for ib in 0..n / 4 {
        // y strip into the four accumulators.
        for r in 0..4u8 {
            p.push(Instr::Ld { rd: RY + r, gm: (l.base_y + 4 * ib + r as usize) as u32 });
        }
        for kb in 0..n / 4 {
            if kb > 0 {
                // Loop back-edge stall of the simple sequencer.
                p.push(Instr::Barrier);
            }
            // x segment.
            for k in 0..4u8 {
                p.push(Instr::Ld { rd: RX + k, gm: (l.base_x + 4 * kb + k as usize) as u32 });
            }
            // A 4×4 block, row-major rows.
            for r in 0..4u8 {
                for k in 0..4u8 {
                    p.push(Instr::Ld {
                        rd: RA + 4 * r + k,
                        gm: l.a(4 * ib + r as usize, 4 * kb + k as usize) as u32,
                    });
                }
            }
            // Interleave the four row chains (k middle, r inner).
            for k in 0..4u8 {
                for r in 0..4u8 {
                    p.push(Instr::Fmac { rd: RY + r, ra: RA + 4 * r + k, rb: RX + k });
                }
            }
        }
        for r in 0..4u8 {
            p.push(Instr::St { rs: RY + r, gm: (l.base_y + 4 * ib + r as usize) as u32 });
        }
    }
}

/// AE1+: x staged once in LM; A strips streamed GM→LM; DOT4 reduction.
fn gen_lm(n: usize, ae: AeLevel, l: &VecLayout, p: &mut Program) {
    let lm = LmMap::new(n);
    // Stage x once — the data-locality win of the Local Memory.
    p.push(Instr::BlkLd { lm: lm.x, gm: l.base_x as u32, len: n as u32 });

    let prefetch = ae.has_prefetch();
    // Pre-fetch pattern (fig 10): strip ib+1 (and its y segment) stream
    // into the other LM buffers while strip ib is reduced; nothing in the
    // body then waits on the GM port.
    if prefetch {
        p.push(Instr::BlkLd { lm: lm.y, gm: l.base_y as u32, len: 4 });
        emit_strip_load(n, l, 0, lm.a[0], p);
    }
    for ib in 0..n / 4 {
        let buf = if prefetch { lm.a[ib % 2] } else { lm.a[0] };
        let ybuf = if prefetch { lm.y + 4 * (ib % 2) as u32 } else { lm.y };
        if !prefetch {
            emit_strip_load(n, l, ib, buf, p);
            p.push(Instr::BlkLd { lm: ybuf, gm: (l.base_y + 4 * ib) as u32, len: 4 });
        } else if ib + 1 < n / 4 {
            // Fig-10 overlap: the next strip + y segment stream on the GM
            // engine underneath this strip's whole reduction loop.
            let ynext = lm.y + 4 * ((ib + 1) % 2) as u32;
            p.push(Instr::BlkLd { lm: ynext, gm: (l.base_y + 4 * (ib + 1)) as u32, len: 4 });
            emit_strip_load(n, l, ib + 1, lm.a[(ib + 1) % 2], p);
        }
        if ae.has_wide_path() {
            p.push(Instr::LmLd4 { rd: RY, lm: ybuf });
        } else {
            for r in 0..4u8 {
                p.push(Instr::LmLd { rd: RY + r, lm: ybuf + r as u32 });
            }
        }
        if ae.has_dot() {
            for r in 0..4u8 {
                p.push(Instr::Li { rd: RY2 + r, val: 0.0 });
            }
        }

        for kb in 0..n / 4 {
            // x segment and the four A row segments.
            if ae.has_wide_path() {
                p.push(Instr::LmLd4 { rd: RX, lm: lm.x + 4 * kb as u32 });
                for r in 0..4u8 {
                    p.push(Instr::LmLd4 { rd: RA + 4 * r, lm: buf + (r as usize * n + 4 * kb) as u32 });
                }
            } else {
                for k in 0..4u8 {
                    p.push(Instr::LmLd { rd: RX + k, lm: lm.x + (4 * kb + k as usize) as u32 });
                }
                for r in 0..4u8 {
                    for k in 0..4u8 {
                        p.push(Instr::LmLd {
                            rd: RA + 4 * r + k,
                            lm: buf + (r as usize * n + 4 * kb + k as usize) as u32,
                        });
                    }
                }
            }
            if ae.has_dot() {
                // Alternate partials by k-step parity to clear the RDP
                // pipeline latency between accumulations on one register.
                let base = if kb % 2 == 0 { RY } else { RY2 };
                for r in 0..4u8 {
                    p.push(Instr::Dot { rd: base + r, ra: RA + 4 * r, rb: RX, n: 4, acc: true });
                }
            } else {
                for k in 0..4u8 {
                    for r in 0..4u8 {
                        p.push(Instr::Fmac { rd: RY + r, ra: RA + 4 * r + k, rb: RX + k });
                    }
                }
            }
            if !prefetch {
                // Loop back-edge stall of the simple sequencer (fig 10).
                p.push(Instr::Barrier);
            }
        }

        // Fold the secondary partials, then the y strip back to GM.
        if ae.has_dot() {
            for r in 0..4u8 {
                p.push(Instr::Fadd { rd: RY + r, ra: RY + r, rb: RY2 + r });
            }
        }
        if ae.has_wide_path() {
            p.push(Instr::LmSt4 { rs: RY, lm: ybuf });
        } else {
            for r in 0..4u8 {
                p.push(Instr::LmSt { rs: RY + r, lm: ybuf + r as u32 });
            }
        }
        p.push(Instr::BlkSt { lm: ybuf, gm: (l.base_y + 4 * ib) as u32, len: 4 });
    }
}

/// Stream the 4-row A strip `ib` into LM (rows are contiguous, row-major A).
fn emit_strip_load(n: usize, l: &VecLayout, ib: usize, buf: u32, p: &mut Program) {
    for r in 0..4 {
        p.push(Instr::BlkLd {
            lm: buf + (r * n) as u32,
            gm: l.a(4 * ib + r, 0) as u32,
            len: n as u32,
        });
    }
}

/// Standard DGEMV flop count (2n²).
pub fn std_flops(n: usize) -> u64 {
    2 * (n as u64).pow(2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pe::{Pe, PeConfig, PeStats};
    use crate::util::{assert_allclose, Mat, XorShift64};

    fn run_gemv(n: usize, ae: AeLevel) -> PeStats {
        let a = Mat::random(n, n, 7);
        let mut rng = XorShift64::new(13);
        let x = rng.vec(n);
        let y0 = rng.vec(n);
        let l = VecLayout::gemv(n);
        let prog = gen_gemv(n, ae, &l);
        let mut pe = Pe::new(PeConfig::paper(ae), l.gm_words());
        // A row-major.
        let mut gm = vec![0.0; l.gm_words()];
        for i in 0..n {
            for k in 0..n {
                gm[l.a(i, k)] = a[(i, k)];
            }
        }
        gm[l.base_x..l.base_x + n].copy_from_slice(&x);
        gm[l.base_y..l.base_y + n].copy_from_slice(&y0);
        pe.write_gm(0, &gm);
        let st = pe.run(&prog);
        let got = pe.read_gm(l.base_y, n).to_vec();
        let mut want = y0.clone();
        for i in 0..n {
            for k in 0..n {
                want[i] += a[(i, k)] * x[k];
            }
        }
        assert_allclose(&got, &want, 1e-12);
        st
    }

    #[test]
    fn gemv_numerics_all_levels() {
        for ae in AeLevel::ALL {
            run_gemv(8, ae);
        }
    }

    #[test]
    fn gemv_numerics_larger() {
        run_gemv(40, AeLevel::Ae5);
        run_gemv(20, AeLevel::Ae2);
    }

    #[test]
    fn gemv_improves_with_enhancements() {
        let c0 = run_gemv(40, AeLevel::Ae0).cycles;
        let c2 = run_gemv(40, AeLevel::Ae2).cycles;
        let c5 = run_gemv(40, AeLevel::Ae5).cycles;
        assert!(c2 < c0, "AE2 {c2} !< AE0 {c0}");
        assert!(c5 < c2, "AE5 {c5} !< AE2 {c2}");
    }

    #[test]
    fn gemv_is_bandwidth_bound() {
        // At AE5, %peak must sit well below GEMM's (the paper's Level-2
        // story): bounded by the GM stream of A.
        let st = run_gemv(80, AeLevel::Ae5);
        let fpc = st.fpc();
        let pct = fpc / AeLevel::Ae5.peak_fpc();
        assert!(pct < 0.6, "GEMV unrealistically compute-efficient: {pct:.2}");
        assert!(pct > 0.1, "GEMV too slow: {pct:.3} of peak");
    }

    #[test]
    fn flops_convention() {
        assert_eq!(std_flops(10), 200);
    }
}
