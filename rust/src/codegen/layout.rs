//! GM data layout for PE kernels and marshalling helpers.
//!
//! The PE's Block Data Load/Store and DOT4 instructions want contiguous
//! operand windows, so the coordinator stores **A row-major** (rows feed the
//! DOT4 `ra` window), **B column-major** (columns feed `rb`), and **C
//! column-major** (C columns are stored back with wide moves). Vectors are
//! contiguous. This marshalling is part of the co-design: the paper likewise
//! stages operands in the Local Memory so that accesses are streams.

use crate::util::Mat;

/// Word offsets of the GEMM operands in PE global memory, for the general
/// rectangular problem C (m×p) ← A (m×k) · B (k×p) + C.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GemmLayout {
    /// Output rows (multiple of 4). For the square case m = p = k = n.
    pub m: usize,
    /// Output columns (multiple of 4).
    pub p: usize,
    /// Inner dimension (multiple of 4).
    pub k: usize,
    /// A (row-major) base word address.
    pub base_a: usize,
    /// B (column-major) base word address.
    pub base_b: usize,
    /// C (column-major) base word address.
    pub base_c: usize,
}

impl GemmLayout {
    /// Square packing: A | B | C contiguous from word 0.
    pub fn packed(n: usize) -> Self {
        Self::rect(n, n, n)
    }

    /// Rectangular packing: A (m×k) | B (k×p) | C (m×p).
    pub fn rect(m: usize, p: usize, k: usize) -> Self {
        assert!(
            m % 4 == 0 && p % 4 == 0 && k % 4 == 0,
            "PE kernels need dims % 4 == 0 (pad first), got {m}x{p}x{k}"
        );
        Self::rect_any(m, p, k)
    }

    /// Rectangular packing without the 4-alignment requirement — the
    /// layout of the DOT2/3 residual kernels
    /// ([`crate::codegen::gen_gemm_any`]), whose edge blocks use 2- and
    /// 3-lane dots instead of padding. The aligned generators still
    /// require [`GemmLayout::rect`].
    pub fn rect_any(m: usize, p: usize, k: usize) -> Self {
        Self { m, p, k, base_a: 0, base_b: m * k, base_c: m * k + k * p }
    }

    /// Back-compat accessor for the square case.
    pub fn n(&self) -> usize {
        assert!(self.m == self.p && self.p == self.k, "not square");
        self.m
    }

    /// Total GM words required.
    pub fn gm_words(&self) -> usize {
        self.base_c + self.m * self.p
    }

    /// GM word address of A(i, kk) — row-major, stride k.
    pub fn a(&self, i: usize, kk: usize) -> usize {
        self.base_a + i * self.k + kk
    }

    /// GM word address of B(kk, j) — column-major, stride k.
    pub fn b(&self, kk: usize, j: usize) -> usize {
        self.base_b + j * self.k + kk
    }

    /// GM word address of C(i, j) — column-major, stride m.
    pub fn c(&self, i: usize, j: usize) -> usize {
        self.base_c + j * self.m + i
    }

    /// Marshal host matrices into a GM image (zero-padding up to the layout
    /// dimensions if the inputs are smaller).
    pub fn pack(&self, a: &Mat, b: &Mat, c: &Mat) -> Vec<f64> {
        assert!(a.rows() <= self.m && a.cols() <= self.k, "A larger than layout");
        assert!(b.rows() <= self.k && b.cols() <= self.p, "B larger than layout");
        assert!(c.rows() <= self.m && c.cols() <= self.p, "C larger than layout");
        let mut gm = vec![0.0; self.gm_words()];
        for i in 0..a.rows() {
            for k in 0..a.cols() {
                gm[self.a(i, k)] = a[(i, k)];
            }
        }
        for k in 0..b.rows() {
            for j in 0..b.cols() {
                gm[self.b(k, j)] = b[(k, j)];
            }
        }
        for i in 0..c.rows() {
            for j in 0..c.cols() {
                gm[self.c(i, j)] = c[(i, j)];
            }
        }
        gm
    }

    /// Extract the (possibly padded) C result back into an (r × s) matrix.
    pub fn unpack_c(&self, gm: &[f64], r: usize, s: usize) -> Mat {
        let mut c = Mat::zeros(r, s);
        for i in 0..r {
            for j in 0..s {
                c[(i, j)] = gm[self.c(i, j)];
            }
        }
        c
    }
}

/// Layout for GEMV / Level-1 kernels: A row-major, x, y contiguous.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct VecLayout {
    pub n: usize,
    pub base_a: usize,
    pub base_x: usize,
    pub base_y: usize,
}

impl VecLayout {
    /// Packing for GEMV: A (n×n row-major) | x | y.
    pub fn gemv(n: usize) -> Self {
        assert!(n % 4 == 0, "PE kernels need n % 4 == 0, got {n}");
        Self { n, base_a: 0, base_x: n * n, base_y: n * n + n }
    }

    /// Packing for Level-1 (no matrix): x | y.
    pub fn level1(n: usize) -> Self {
        assert!(n % 4 == 0, "PE kernels need n % 4 == 0, got {n}");
        Self { n, base_a: 0, base_x: 0, base_y: n }
    }

    pub fn gm_words(&self) -> usize {
        self.base_y + self.n + 4 // +4 scratch words for scalar results
    }

    /// GM address of A(i, k), row-major.
    pub fn a(&self, i: usize, k: usize) -> usize {
        self.base_a + i * self.n + k
    }

    /// Scratch word for scalar outputs (ddot/dnrm2 results).
    pub fn scratch(&self) -> usize {
        self.base_y + self.n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn packed_layout_addresses() {
        let l = GemmLayout::packed(8);
        assert_eq!(l.a(0, 0), 0);
        assert_eq!(l.a(1, 0), 8); // row-major: next row jumps n
        assert_eq!(l.b(0, 1), 64 + 8); // col-major: next col jumps n
        assert_eq!(l.c(3, 2), 128 + 2 * 8 + 3);
        assert_eq!(l.gm_words(), 3 * 64);
    }

    #[test]
    #[should_panic(expected = "% 4 == 0")]
    fn rejects_unpadded() {
        GemmLayout::packed(10);
    }

    #[test]
    fn rect_any_allows_unaligned_dims() {
        let l = GemmLayout::rect_any(10, 10, 10);
        assert_eq!((l.base_a, l.base_b, l.base_c), (0, 100, 200));
        assert_eq!(l.gm_words(), 300);
        // Identical addressing to rect() where both are defined.
        assert_eq!(GemmLayout::rect_any(8, 8, 8), GemmLayout::rect(8, 8, 8));
    }

    #[test]
    fn pack_unpack_round_trip() {
        let a = Mat::random(8, 8, 1);
        let b = Mat::random(8, 8, 2);
        let c = Mat::random(8, 8, 3);
        let l = GemmLayout::packed(8);
        let gm = l.pack(&a, &b, &c);
        assert_eq!(gm[l.a(3, 5)], a[(3, 5)]);
        assert_eq!(gm[l.b(6, 1)], b[(6, 1)]);
        let c2 = l.unpack_c(&gm, 8, 8);
        assert_eq!(c2, c);
    }

    #[test]
    fn pack_pads_smaller_inputs() {
        let a = Mat::random(6, 6, 1);
        let b = Mat::random(6, 6, 2);
        let c = Mat::zeros(6, 6);
        let l = GemmLayout::packed(8);
        let gm = l.pack(&a, &b, &c);
        assert_eq!(gm[l.a(7, 7)], 0.0); // padded region
        assert_eq!(gm[l.a(5, 5)], a[(5, 5)]);
    }

    #[test]
    fn vec_layouts() {
        let l = VecLayout::gemv(12);
        assert_eq!(l.base_x, 144);
        assert_eq!(l.base_y, 156);
        assert_eq!(l.a(2, 3), 2 * 12 + 3);
        let l1 = VecLayout::level1(16);
        assert_eq!(l1.base_x, 0);
        assert_eq!(l1.base_y, 16);
        assert!(l1.scratch() >= 32);
    }
}
