//! Level-1 BLAS code generation: ddot, daxpy, dnrm2 (§4.1, Fig 3 DAGs).
//!
//! Level-1 routines move O(n) data for O(n) work, so they are GM-port bound
//! on the PE exactly as they are memory bound on CPUs/GPUs. The co-designed
//! kernels stream x/y through LM in 16-word groups (one group ahead at AE5,
//! the fig-10 overlap), reduce with DOT4 into four rotating partial
//! accumulators (the DAG of fig 3: parallel multiplies, then an addition
//! tree), and pay one final reduction tree + (for dnrm2) a square root.
//!
//! Register map: partial accumulators r0–r3, α r4, x segment r16–r19,
//! y segment r20–r23, scratch r48+.

use super::layout::VecLayout;
use crate::pe::{AeLevel, Instr, Program};

const RACC: u8 = 0;
const RALPHA: u8 = 4;
const RX: u8 = 16;
const RY: u8 = 20;

/// Elements streamed per LM group (32 amortizes the per-block handshake
/// over the GM stream while two groups still fit comfortably in LM).
const GROUP: usize = 32;

/// ddot: scratch ← xᵀy.
pub fn gen_ddot(n: usize, ae: AeLevel, l: &VecLayout) -> Program {
    gen_reduction(n, ae, l, false)
}

/// dnrm2: scratch ← √(xᵀx).
pub fn gen_dnrm2(n: usize, ae: AeLevel, l: &VecLayout) -> Program {
    gen_reduction(n, ae, l, true)
}

/// Shared generator for the two reduction routines (the paper notes their
/// DAGs are identical up to the final square root, §4.1).
fn gen_reduction(n: usize, ae: AeLevel, l: &VecLayout, nrm2: bool) -> Program {
    assert_eq!(l.n, n);
    assert!(n % 4 == 0 && n >= 4, "n must be a positive multiple of 4, got {n}");
    let mut p = Program::new();
    // Partial accumulators: the DOT4 RDP is 15 stages deep, so the dot path
    // rotates 8 partials to keep consecutive DOTs on one accumulator more
    // than a pipeline depth apart; the mac path needs only 4.
    let naccs: u8 = if ae.has_dot() { 8 } else { 4 };
    for r in 0..naccs {
        p.push(Instr::Li { rd: RACC + r, val: 0.0 });
    }

    if ae == AeLevel::Ae0 {
        // Direct GM streaming, scalar mac chains rotating over r0–r3;
        // the loop body covers 4 elements, with a sequencer stall at the
        // back-edge.
        for k in 0..n {
            p.push(Instr::Ld { rd: RX, gm: (l.base_x + k) as u32 });
            if nrm2 {
                p.push(Instr::Fmac { rd: RACC + (k % 4) as u8, ra: RX, rb: RX });
            } else {
                p.push(Instr::Ld { rd: RY, gm: (l.base_y + k) as u32 });
                p.push(Instr::Fmac { rd: RACC + (k % 4) as u8, ra: RX, rb: RY });
            }
            if k % 4 == 3 {
                p.push(Instr::Barrier);
            }
        }
    } else {
        // LM streaming in GROUP-element chunks; at AE5 the fill for group
        // g+1 is issued before the compute of group g (fig 10).
        let lm_x = 0u32;
        let lm_y = n as u32;
        let groups = n.div_ceil(GROUP);
        let fill = |g: usize, p: &mut Program| {
            if g >= groups {
                return;
            }
            let off = g * GROUP;
            let len = GROUP.min(n - off) as u32;
            p.push(Instr::BlkLd { lm: lm_x + off as u32, gm: (l.base_x + off) as u32, len });
            if !nrm2 {
                p.push(Instr::BlkLd { lm: lm_y + off as u32, gm: (l.base_y + off) as u32, len });
            }
        };
        let prefetch = ae.has_prefetch();
        fill(0, &mut p);
        for g in 0..groups {
            if prefetch {
                fill(g + 1, &mut p);
            }
            let off = g * GROUP;
            let len = GROUP.min(n - off);
            for c in 0..len / 4 {
                let lmo = (off + 4 * c) as u32;
                if ae.has_wide_path() {
                    p.push(Instr::LmLd4 { rd: RX, lm: lm_x + lmo });
                    if !nrm2 {
                        p.push(Instr::LmLd4 { rd: RY, lm: lm_y + lmo });
                    }
                } else {
                    for k in 0..4u8 {
                        p.push(Instr::LmLd { rd: RX + k, lm: lm_x + lmo + k as u32 });
                    }
                    if !nrm2 {
                        for k in 0..4u8 {
                            p.push(Instr::LmLd { rd: RY + k, lm: lm_y + lmo + k as u32 });
                        }
                    }
                }
                let rb = if nrm2 { RX } else { RY };
                if ae.has_dot() {
                    // Rotate accumulators so consecutive DOTs are independent.
                    let rd = RACC + ((off / 4 + c) % naccs as usize) as u8;
                    p.push(Instr::Dot { rd, ra: RX, rb, n: 4, acc: true });
                } else {
                    for k in 0..4u8 {
                        p.push(Instr::Fmac { rd: RACC + k, ra: RX + k, rb: rb + k });
                    }
                }
            }
            if !prefetch {
                fill(g + 1, &mut p);
                p.push(Instr::Barrier);
            }
        }
    }

    // Reduction tree over the partials (fig 3's addition levels).
    let mut stride = 1u8;
    while stride < naccs {
        let mut r = 0u8;
        while r + stride < naccs {
            p.push(Instr::Fadd { rd: RACC + r, ra: RACC + r, rb: RACC + r + stride });
            r += 2 * stride;
        }
        stride *= 2;
    }
    if nrm2 {
        p.push(Instr::Fsqrt { rd: RACC, ra: RACC });
    }
    p.push(Instr::St { rs: RACC, gm: l.scratch() as u32 });
    p.push(Instr::Halt);
    debug_assert!(p.validate().is_ok());
    p
}

/// daxpy: y ← αx + y.
pub fn gen_daxpy(n: usize, alpha: f64, ae: AeLevel, l: &VecLayout) -> Program {
    assert_eq!(l.n, n);
    assert!(n % 4 == 0 && n >= 4, "n must be a positive multiple of 4, got {n}");
    let mut p = Program::new();
    p.push(Instr::Li { rd: RALPHA, val: alpha });

    if ae == AeLevel::Ae0 {
        for k in 0..n {
            p.push(Instr::Ld { rd: RX, gm: (l.base_x + k) as u32 });
            p.push(Instr::Ld { rd: RY + (k % 4) as u8, gm: (l.base_y + k) as u32 });
            p.push(Instr::Fmac { rd: RY + (k % 4) as u8, ra: RX, rb: RALPHA });
            p.push(Instr::St { rs: RY + (k % 4) as u8, gm: (l.base_y + k) as u32 });
            if k % 4 == 3 {
                p.push(Instr::Barrier);
            }
        }
    } else {
        let lm_x = 0u32;
        let lm_y = n as u32;
        let groups = n.div_ceil(GROUP);
        let fill = |g: usize, p: &mut Program| {
            if g >= groups {
                return;
            }
            let off = g * GROUP;
            let len = GROUP.min(n - off) as u32;
            p.push(Instr::BlkLd { lm: lm_x + off as u32, gm: (l.base_x + off) as u32, len });
            p.push(Instr::BlkLd { lm: lm_y + off as u32, gm: (l.base_y + off) as u32, len });
        };
        let prefetch = ae.has_prefetch();
        fill(0, &mut p);
        for g in 0..groups {
            if prefetch {
                fill(g + 1, &mut p);
            }
            let off = g * GROUP;
            let len = GROUP.min(n - off);
            for c in 0..len / 4 {
                let lmo = (off + 4 * c) as u32;
                if ae.has_wide_path() {
                    p.push(Instr::LmLd4 { rd: RX, lm: lm_x + lmo });
                    p.push(Instr::LmLd4 { rd: RY, lm: lm_y + lmo });
                } else {
                    for k in 0..4u8 {
                        p.push(Instr::LmLd { rd: RX + k, lm: lm_x + lmo + k as u32 });
                        p.push(Instr::LmLd { rd: RY + k, lm: lm_y + lmo + k as u32 });
                    }
                }
                for k in 0..4u8 {
                    p.push(Instr::Fmac { rd: RY + k, ra: RX + k, rb: RALPHA });
                }
                if ae.has_wide_path() {
                    p.push(Instr::LmSt4 { rs: RY, lm: lm_y + lmo });
                } else {
                    for k in 0..4u8 {
                        p.push(Instr::LmSt { rs: RY + k, lm: lm_y + lmo + k as u32 });
                    }
                }
            }
            // Write the updated group back to GM.
            let blen = len as u32;
            p.push(Instr::BlkSt { lm: lm_y + off as u32, gm: (l.base_y + off) as u32, len: blen });
            if !prefetch {
                fill(g + 1, &mut p);
                p.push(Instr::Barrier);
            }
        }
    }
    p.push(Instr::Halt);
    debug_assert!(p.validate().is_ok());
    p
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pe::{Pe, PeConfig, PeStats};
    use crate::util::XorShift64;

    fn setup(n: usize, ae: AeLevel) -> (Pe, VecLayout, Vec<f64>, Vec<f64>) {
        let l = VecLayout::level1(n);
        let mut rng = XorShift64::new(n as u64 + 1);
        let x = rng.vec(n);
        let y = rng.vec(n);
        let mut pe = Pe::new(PeConfig::paper(ae), l.gm_words());
        pe.write_gm(l.base_x, &x);
        pe.write_gm(l.base_y, &y);
        (pe, l, x, y)
    }

    fn check_ddot(n: usize, ae: AeLevel) -> PeStats {
        let (mut pe, l, x, y) = setup(n, ae);
        let st = pe.run(&gen_ddot(n, ae, &l));
        let want: f64 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
        let got = pe.read_gm(l.scratch(), 1)[0];
        assert!((got - want).abs() < 1e-12 * want.abs().max(1.0), "{got} vs {want}");
        st
    }

    #[test]
    fn ddot_all_levels() {
        for ae in AeLevel::ALL {
            check_ddot(32, ae);
        }
    }

    #[test]
    fn ddot_odd_group_sizes() {
        // n not a multiple of GROUP exercises the tail-group path.
        check_ddot(20, AeLevel::Ae5);
        check_ddot(36, AeLevel::Ae3);
        check_ddot(4, AeLevel::Ae5);
    }

    #[test]
    fn dnrm2_matches_host() {
        for ae in [AeLevel::Ae0, AeLevel::Ae2, AeLevel::Ae5] {
            let n = 40;
            let (mut pe, l, x, _) = setup(n, ae);
            pe.run(&gen_dnrm2(n, ae, &l));
            let want = x.iter().map(|v| v * v).sum::<f64>().sqrt();
            let got = pe.read_gm(l.scratch(), 1)[0];
            assert!((got - want).abs() < 1e-12, "{got} vs {want}");
        }
    }

    #[test]
    fn daxpy_matches_host() {
        for ae in AeLevel::ALL {
            let n = 32;
            let alpha = 1.75;
            let (mut pe, l, x, y) = setup(n, ae);
            pe.run(&gen_daxpy(n, alpha, ae, &l));
            let got = pe.read_gm(l.base_y, n).to_vec();
            for k in 0..n {
                let want = alpha * x[k] + y[k];
                assert!((got[k] - want).abs() < 1e-12, "k={k}: {} vs {want}", got[k]);
            }
        }
    }

    #[test]
    fn ddot_improves_with_enhancements() {
        let c0 = check_ddot(64, AeLevel::Ae0).cycles;
        let c5 = check_ddot(64, AeLevel::Ae5).cycles;
        assert!(c5 < c0, "AE5 ddot {c5} !< AE0 {c0}");
    }

    #[test]
    fn ddot_is_memory_bound() {
        // The paper's abstract: DDOT reaches ~20% of PE peak — it must stay
        // far below GEMM's efficiency even at AE5.
        let st = check_ddot(512, AeLevel::Ae5);
        let pct = st.fpc() / AeLevel::Ae5.peak_fpc();
        assert!(pct < 0.45, "ddot unrealistically efficient: {pct:.2}");
    }

    #[test]
    fn dnrm2_uses_sqrt_unit() {
        let n = 16;
        let (mut pe, l, _, _) = setup(n, AeLevel::Ae5);
        let st_n = pe.run(&gen_dnrm2(n, AeLevel::Ae5, &l));
        // 2n mac flops + 7 reduction adds over 8 partials + the sqrt.
        assert_eq!(st_n.flops, 2 * n as u64 + 7 + 1);
    }
}
