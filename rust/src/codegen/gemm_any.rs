//! DGEMM for arbitrary sizes via the RDP's DOT2/DOT3 configurations.
//!
//! §5.2.1: "we further make this hardware structure reconfigurable to
//! support 2-element and 3-element vector inner products to support
//! different matrix sizes." This generator tiles n into blocks of length
//! {4, 3, 2} (n = 4q [+3][+2]), emits DOT4/DOT3/DOT2 per block shape, and
//! needs no zero padding — the alternative the coordinator's padding path
//! is ablated against (`cargo bench --bench ablations -- residual`).
//!
//! Register/LM layout matches [`super::gemm`] (strides stay 4 in the RF);
//! edge blocks simply use fewer lanes.

use super::layout::GemmLayout;
use crate::pe::{AeLevel, Instr, Program};

const RC: u8 = 0;
const RA: u8 = 16;
const RB: u8 = 32;

/// Decompose a dimension into DOT-compatible block lengths (4…4, then 3
/// and/or 2). Requires n ≥ 2 (a 1-length dimension has no RDP config; the
/// coordinator pads that degenerate case).
pub fn blocks(n: usize) -> Vec<(usize, usize)> {
    assert!(n >= 2, "RDP supports 2/3/4-element dots; pad n=1");
    let mut out = Vec::new();
    let mut start = 0;
    let mut rem = n;
    while rem > 0 {
        let len = match rem {
            2 | 3 => rem,
            5 => 3, // leave a 2-block, not a 1-block
            _ => 4,
        };
        out.push((start, len));
        start += len;
        rem -= len;
    }
    out
}

/// LM map (strides follow the k dimension, as in the aligned generator).
struct LmMap {
    a: u32,
    b: u32,
    c: u32,
}

impl LmMap {
    fn new(k: usize) -> Self {
        let k32 = k as u32;
        let m = Self { a: 0, b: 4 * k32, c: 8 * k32 };
        assert!((m.c + 16) as usize <= crate::pe::LM_WORDS, "working set exceeds LM");
        m
    }
}

/// Generate DGEMM `C ← A·B + C` for any n ≥ 2 at AE2+ (the RDP levels —
/// before AE2 there is no DOT hardware and the scalar-mac generator in
/// [`super::gemm`] handles any padded size).
pub fn gen_gemm_any(n: usize, ae: AeLevel, l: &GemmLayout) -> Program {
    assert!(ae.has_dot(), "gen_gemm_any targets the RDP levels (AE2+)");
    assert_eq!((l.m, l.p, l.k), (n, n, n), "layout mismatch");
    let mut p = Program::new();
    let blks = blocks(n);
    let lm = LmMap::new(n);
    let wide = ae.has_wide_path();
    let prefetch = ae.has_prefetch();

    for &(i0, ilen) in &blks {
        // A row strip for this block row (ilen rows × n, row r at lm.a+r*n).
        for r in 0..ilen {
            p.push(Instr::BlkLd { lm: lm.a + (r * n) as u32, gm: l.a(i0 + r, 0) as u32, len: n as u32 });
        }
        for &(j0, jlen) in &blks {
            // B panel: jlen columns × n.
            for c in 0..jlen {
                p.push(Instr::BlkLd { lm: lm.b + (c * n) as u32, gm: l.b(0, j0 + c) as u32, len: n as u32 });
            }
            // C block: one column segment at a time (contiguous in GM).
            for j in 0..jlen {
                p.push(Instr::BlkLd { lm: lm.c + (4 * j) as u32, gm: l.c(i0, j0 + j) as u32, len: ilen as u32 });
            }
            for j in 0..jlen as u8 {
                for i in 0..ilen as u8 {
                    p.push(Instr::LmLd { rd: RC + 4 * j + i, lm: lm.c + (4 * j + i) as u32 });
                }
            }
            // k loop over mixed-width blocks.
            for (kb, &(k0, klen)) in blks.iter().enumerate() {
                // Load the A (ilen×klen) and B (klen×jlen) blocks.
                for i in 0..ilen as u8 {
                    if wide && klen == 4 {
                        p.push(Instr::LmLd4 { rd: RA + 4 * i, lm: lm.a + (i as usize * n + k0) as u32 });
                    } else {
                        for k in 0..klen as u8 {
                            p.push(Instr::LmLd { rd: RA + 4 * i + k, lm: lm.a + (i as usize * n + k0 + k as usize) as u32 });
                        }
                    }
                }
                for j in 0..jlen as u8 {
                    if wide && klen == 4 {
                        p.push(Instr::LmLd4 { rd: RB + 4 * j, lm: lm.b + (j as usize * n + k0) as u32 });
                    } else {
                        for k in 0..klen as u8 {
                            p.push(Instr::LmLd { rd: RB + 4 * j + k, lm: lm.b + (j as usize * n + k0 + k as usize) as u32 });
                        }
                    }
                }
                // DOT{klen} with accumulate, one per output element.
                for i in 0..ilen as u8 {
                    for j in 0..jlen as u8 {
                        p.push(Instr::Dot {
                            rd: RC + 4 * j + i,
                            ra: RA + 4 * i,
                            rb: RB + 4 * j,
                            n: klen as u8,
                            acc: true,
                        });
                    }
                }
                if !prefetch && kb + 1 < blks.len() {
                    p.push(Instr::Barrier);
                }
            }
            // C back.
            for j in 0..jlen as u8 {
                for i in 0..ilen as u8 {
                    p.push(Instr::LmSt { rs: RC + 4 * j + i, lm: lm.c + (4 * j + i) as u32 });
                }
            }
            for j in 0..jlen {
                p.push(Instr::BlkSt { lm: lm.c + (4 * j) as u32, gm: l.c(i0, j0 + j) as u32, len: ilen as u32 });
            }
        }
    }
    p.push(Instr::Halt);
    debug_assert!(p.validate().is_ok());
    p
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pe::{Pe, PeConfig};
    use crate::util::{rel_fro_error, Mat};

    #[test]
    fn block_decomposition() {
        assert_eq!(blocks(8), vec![(0, 4), (4, 4)]);
        assert_eq!(blocks(6), vec![(0, 4), (4, 2)]);
        assert_eq!(blocks(7), vec![(0, 4), (4, 3)]);
        assert_eq!(blocks(9), vec![(0, 4), (4, 3), (7, 2)]);
        assert_eq!(blocks(5), vec![(0, 3), (3, 2)]);
        assert_eq!(blocks(2), vec![(0, 2)]);
        assert_eq!(blocks(3), vec![(0, 3)]);
        for n in 2..40 {
            let b = blocks(n);
            assert_eq!(b.iter().map(|x| x.1).sum::<usize>(), n);
            assert!(b.iter().all(|x| (2..=4).contains(&x.1)), "n={n}");
        }
    }

    #[test]
    #[should_panic(expected = "pad n=1")]
    fn rejects_one() {
        blocks(1);
    }

    fn check(n: usize, ae: AeLevel) -> u64 {
        let a = Mat::random(n, n, 900 + n as u64);
        let b = Mat::random(n, n, 901 + n as u64);
        let c = Mat::random(n, n, 902 + n as u64);
        let l = GemmLayout::rect_any(n, n, n);
        let prog = gen_gemm_any(n, ae, &l);
        let mut pe = Pe::new(PeConfig::paper(ae), 3 * n * n);
        pe.write_gm(0, &l.pack(&a, &b, &c));
        let st = pe.run(&prog);
        let got = l.unpack_c(&pe.gm, n, n);
        let want = crate::blas::level3::dgemm_ref(&a, &b, &c);
        let err = rel_fro_error(got.as_slice(), want.as_slice());
        assert!(err < 1e-12, "n={n} {ae}: err {err}");
        st.cycles
    }

    #[test]
    fn odd_sizes_all_rdp_levels() {
        for n in [2usize, 3, 5, 6, 7, 9, 10, 13, 17, 22] {
            for ae in [AeLevel::Ae2, AeLevel::Ae4, AeLevel::Ae5] {
                check(n, ae);
            }
        }
    }

    #[test]
    fn aligned_sizes_match_aligned_generator_numerics() {
        // Same semantics as gen_gemm for multiples of 4.
        check(8, AeLevel::Ae5);
        check(20, AeLevel::Ae3);
    }

    #[test]
    fn residual_vs_padding_tradeoff() {
        // n = 17 padded to 20 wastes (20³−17³)/20³ ≈ 39% of the macs. At
        // AE3 (no software pipelining on either side) the DOT2/3 residual
        // path wins; at AE5 the aligned kernel's pipelined k-loop and panel
        // double-buffering claw the padding waste back — the trade-off the
        // `ablations` bench quantifies.
        let n = 17;
        let resid3 = check(n, AeLevel::Ae3);
        let padded3 = crate::metrics::measure_gemm(20, AeLevel::Ae3).latency();
        assert!(
            resid3 < padded3,
            "AE3: DOT2/3 residual ({resid3}) should beat padding to 20 ({padded3})"
        );
        let resid5 = check(n, AeLevel::Ae5);
        let padded5 = crate::metrics::measure_gemm(20, AeLevel::Ae5).latency();
        let ratio = resid5 as f64 / padded5 as f64;
        assert!(
            (0.7..1.35).contains(&ratio),
            "AE5: residual/padded ratio {ratio:.2} outside expected band"
        );
    }
}
