//! DGETRF: LU factorization with partial pivoting (right-looking,
//! rank-1-update form — the XGETRF the paper cites alongside QR in §1).

use super::profile::{FlopProfile, ProfiledOp};
use crate::util::Mat;

/// LU factors: `lu` holds L (unit lower, below diagonal) and U (upper),
/// `piv[k]` is the row swapped with row k at step k.
#[derive(Debug, Clone)]
pub struct LuFactors {
    pub lu: Mat,
    pub piv: Vec<usize>,
}

impl LuFactors {
    /// Apply the recorded permutation to a copy of `b` (P·b).
    pub fn permute(&self, b: &[f64]) -> Vec<f64> {
        let mut x = b.to_vec();
        for (k, &p) in self.piv.iter().enumerate() {
            x.swap(k, p);
        }
        x
    }

    /// Solve A·x = b via the factors.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let n = self.lu.rows();
        assert_eq!(b.len(), n);
        let mut x = self.permute(b);
        // Forward: L·y = P·b (unit diagonal).
        for i in 0..n {
            for k in 0..i {
                x[i] -= self.lu[(i, k)] * x[k];
            }
        }
        // Backward: U·x = y.
        for i in (0..n).rev() {
            for k in i + 1..n {
                x[i] -= self.lu[(i, k)] * x[k];
            }
            x[i] /= self.lu[(i, i)];
        }
        x
    }
}

/// Factor A = P·L·U with partial pivoting. Returns factors and the flop
/// profile (DGER-dominated — the Level-2 analogue of Fig 1 for LU).
pub fn dgetrf(a: &Mat) -> (LuFactors, FlopProfile) {
    let n = a.rows();
    assert_eq!(a.cols(), n, "square only");
    let mut lu = a.clone();
    let mut piv = vec![0usize; n];
    let mut prof = FlopProfile::new();

    for k in 0..n {
        // Pivot search (IDAMAX).
        let col = lu.col(k);
        let mut p = k;
        let mut best = col[k].abs();
        for i in k + 1..n {
            if col[i].abs() > best {
                best = col[i].abs();
                p = i;
            }
        }
        piv[k] = p;
        assert!(best > 0.0, "singular matrix at step {k}");
        if p != k {
            for j in 0..n {
                let t = lu[(k, j)];
                lu[(k, j)] = lu[(p, j)];
                lu[(p, j)] = t;
            }
        }
        // Scale the pivot column (DSCAL).
        let pivval = lu[(k, k)];
        for i in k + 1..n {
            lu[(i, k)] /= pivval;
        }
        prof.add(ProfiledOp::Dscal, (n - k - 1) as u64);
        // Rank-1 update of the trailing matrix (DGER).
        for j in k + 1..n {
            let ukj = lu[(k, j)];
            for i in k + 1..n {
                let lik = lu[(i, k)];
                lu[(i, j)] -= lik * ukj;
            }
        }
        prof.add(ProfiledOp::Dger, 2 * ((n - k - 1) as u64).pow(2));
    }
    (LuFactors { lu, piv }, prof)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{Mat, XorShift64};

    #[test]
    fn solves_random_system() {
        let n = 16;
        let a = Mat::random_spd(n, 41); // well-conditioned
        let mut rng = XorShift64::new(42);
        let x0 = rng.vec(n);
        // b = A·x0
        let b = crate::blas::level2::dgemv_ref(&a, &x0, &vec![0.0; n]);
        let (f, _) = dgetrf(&a);
        let x = f.solve(&b);
        crate::util::assert_allclose(&x, &x0, 1e-9);
    }

    #[test]
    fn pivoting_handles_zero_leading_entry() {
        let a = Mat::from_row_major(2, 2, &[0., 1., 1., 0.]);
        let (f, _) = dgetrf(&a);
        let x = f.solve(&[2.0, 3.0]);
        crate::util::assert_allclose(&x, &[3.0, 2.0], 1e-12);
    }

    #[test]
    fn profile_is_dger_dominated() {
        let a = Mat::random_spd(48, 43);
        let (_, prof) = dgetrf(&a);
        assert!(prof.fraction(super::ProfiledOp::Dger) > 0.95);
    }

    #[test]
    #[should_panic(expected = "singular")]
    fn singular_matrix_detected() {
        let a = Mat::zeros(3, 3);
        dgetrf(&a);
    }
}
