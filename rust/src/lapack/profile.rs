//! Flop profiler for the Fig-1 experiment: attributes every floating-point
//! operation of a factorization to the BLAS routine that performed it,
//! mirroring the paper's Intel VTune™ time attribution.

use std::collections::BTreeMap;

/// BLAS routine classes the profiler attributes work to.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum ProfiledOp {
    Ddot,
    Dnrm2,
    Daxpy,
    Dscal,
    Dgemv,
    Dger,
    Dgemm,
    Dtrsm,
    Other,
}

impl ProfiledOp {
    pub fn name(self) -> &'static str {
        match self {
            ProfiledOp::Ddot => "DDOT",
            ProfiledOp::Dnrm2 => "DNRM2",
            ProfiledOp::Daxpy => "DAXPY",
            ProfiledOp::Dscal => "DSCAL",
            ProfiledOp::Dgemv => "DGEMV",
            ProfiledOp::Dger => "DGER",
            ProfiledOp::Dgemm => "DGEMM",
            ProfiledOp::Dtrsm => "DTRSM",
            ProfiledOp::Other => "other",
        }
    }
}

/// Accumulated flops per BLAS routine.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FlopProfile {
    counts: BTreeMap<ProfiledOp, u64>,
}

impl FlopProfile {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record `flops` operations attributed to `op`.
    pub fn add(&mut self, op: ProfiledOp, flops: u64) {
        *self.counts.entry(op).or_insert(0) += flops;
    }

    /// Total flops recorded.
    pub fn total(&self) -> u64 {
        self.counts.values().sum()
    }

    /// Flops attributed to one routine.
    pub fn flops(&self, op: ProfiledOp) -> u64 {
        self.counts.get(&op).copied().unwrap_or(0)
    }

    /// Fraction of total work in one routine (0..1).
    pub fn fraction(&self, op: ProfiledOp) -> f64 {
        self.flops(op) as f64 / self.total().max(1) as f64
    }

    /// Routines sorted by descending share.
    pub fn breakdown(&self) -> Vec<(ProfiledOp, u64, f64)> {
        let total = self.total().max(1) as f64;
        let mut v: Vec<_> =
            self.counts.iter().map(|(&op, &f)| (op, f, f as f64 / total)).collect();
        v.sort_by(|a, b| b.1.cmp(&a.1));
        v
    }

    /// Render as a Fig-1-style report.
    pub fn report(&self, title: &str) -> String {
        let mut s = format!("{title}: {} flops total\n", self.total());
        for (op, flops, frac) in self.breakdown() {
            s.push_str(&format!("  {:<6} {:>14} flops  {:>6.2}%\n", op.name(), flops, 100.0 * frac));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_and_fractions() {
        let mut p = FlopProfile::new();
        p.add(ProfiledOp::Dgemv, 99);
        p.add(ProfiledOp::Ddot, 1);
        assert_eq!(p.total(), 100);
        assert!((p.fraction(ProfiledOp::Dgemv) - 0.99).abs() < 1e-12);
        assert_eq!(p.breakdown()[0].0, ProfiledOp::Dgemv);
    }

    #[test]
    fn report_contains_rows() {
        let mut p = FlopProfile::new();
        p.add(ProfiledOp::Dgemm, 10);
        let r = p.report("DGEQRF");
        assert!(r.contains("DGEMM"));
        assert!(r.contains("10"));
    }

    #[test]
    fn empty_profile_is_safe() {
        let p = FlopProfile::new();
        assert_eq!(p.total(), 0);
        assert_eq!(p.fraction(ProfiledOp::Dgemm), 0.0);
    }
}
