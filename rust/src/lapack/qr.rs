//! Householder QR: DGEQR2 (unblocked, Level-2-rich) and DGEQRF (blocked,
//! Level-3-rich) — the Fig-1 routines.
//!
//! DGEQR2 applies each reflector with a matrix-vector product (DGEMV) and a
//! rank-1 update (DGER); DGEQRF factors nb-column panels with DGEQR2 and
//! applies the compact-WY block reflector to the trailing matrix with
//! matrix-matrix products (DGEMM) — which is why the paper's profile shows
//! DGEQR2 ≈ 99% matrix-vector work and DGEQRF ≈ 99% DGEMM.

use super::profile::{FlopProfile, ProfiledOp};
use crate::util::Mat;

/// QR factorization result: R in the upper triangle of `a`, Householder
/// vectors below the diagonal (unit leading 1 implicit), scalar factors τ.
#[derive(Debug, Clone)]
pub struct QrFactors {
    pub a: Mat,
    pub tau: Vec<f64>,
}

impl QrFactors {
    /// Extract the upper-triangular/trapezoidal R.
    pub fn r(&self) -> Mat {
        let (m, n) = (self.a.rows(), self.a.cols());
        let mut r = Mat::zeros(m.min(n), n);
        for j in 0..n {
            for i in 0..=j.min(m.min(n) - 1) {
                r[(i, j)] = self.a[(i, j)];
            }
        }
        r
    }
}

/// Generate a Householder reflector for `x`: returns (τ, β) and rewrites
/// `x[1..]` with the vector tail (v₀ = 1 implicit), `x[0]` with β.
fn house(x: &mut [f64], prof: &mut FlopProfile) -> f64 {
    let alpha = x[0];
    let norm_tail = crate::blas::level1::dnrm2(&x[1..]);
    prof.add(ProfiledOp::Dnrm2, 2 * (x.len() as u64 - 1));
    if norm_tail == 0.0 {
        // Already upper-triangular in this column.
        return 0.0;
    }
    let sigma = alpha.hypot(norm_tail);
    let beta = if alpha >= 0.0 { -sigma } else { sigma };
    let tau = (beta - alpha) / beta;
    let scale = 1.0 / (alpha - beta);
    for v in x[1..].iter_mut() {
        *v *= scale;
    }
    prof.add(ProfiledOp::Dscal, x.len() as u64 - 1);
    x[0] = beta;
    tau
}

/// Unblocked Householder QR (LAPACK DGEQR2), with flop attribution.
pub fn dgeqr2_profiled(a: &Mat) -> (QrFactors, FlopProfile) {
    let mut prof = FlopProfile::new();
    let fac = dgeqr2_into(a.clone(), &mut prof);
    (fac, prof)
}

/// Unblocked Householder QR (LAPACK DGEQR2).
pub fn dgeqr2(a: &Mat) -> QrFactors {
    let mut prof = FlopProfile::new();
    dgeqr2_into(a.clone(), &mut prof)
}

fn dgeqr2_into(mut a: Mat, prof: &mut FlopProfile) -> QrFactors {
    let (m, n) = (a.rows(), a.cols());
    let k = m.min(n);
    let mut tau = vec![0.0; k];
    for j in 0..k {
        // Reflector for column j.
        let mut col = a.col(j)[j..].to_vec();
        let t = house(&mut col, prof);
        tau[j] = t;
        for (i, &v) in col.iter().enumerate() {
            a[(j + i, j)] = v;
        }
        if t == 0.0 || j + 1 == n {
            continue;
        }
        // Apply (I − τ v vᵀ) to the trailing matrix A[j.., j+1..]:
        //   w = A₂ᵀ v   (DGEMV)
        //   A₂ ← A₂ − τ v wᵀ  (DGER)
        let rows = m - j;
        let cols = n - j - 1;
        let mut v = vec![1.0];
        v.extend_from_slice(&a.col(j)[j + 1..]);
        let mut w = vec![0.0; cols];
        for (jj, wv) in w.iter_mut().enumerate() {
            let colv = &a.col(j + 1 + jj)[j..];
            let mut s = 0.0;
            for i in 0..rows {
                s += colv[i] * v[i];
            }
            *wv = s;
        }
        prof.add(ProfiledOp::Dgemv, 2 * (rows as u64) * (cols as u64));
        for jj in 0..cols {
            let twj = t * w[jj];
            let colv = &mut a.col_mut(j + 1 + jj)[j..];
            for i in 0..rows {
                colv[i] -= v[i] * twj;
            }
        }
        prof.add(ProfiledOp::Dger, 2 * (rows as u64) * (cols as u64));
    }
    QrFactors { a, tau }
}

/// Blocked Householder QR (LAPACK DGEQRF) with panel width `nb`,
/// compact-WY trailing update, and flop attribution.
pub fn dgeqrf_profiled(a: &Mat, nb: usize) -> (QrFactors, FlopProfile) {
    assert!(nb > 0);
    let mut prof = FlopProfile::new();
    let (m, n) = (a.rows(), a.cols());
    let k = m.min(n);
    let mut a = a.clone();
    let mut tau = vec![0.0; k];

    let mut j0 = 0;
    while j0 < k {
        let jb = nb.min(k - j0);
        // Factor the panel A[j0.., j0..j0+jb] with DGEQR2.
        let panel = a.block(j0, j0, m - j0, jb);
        let panel_fac = dgeqr2_into(panel, &mut prof);
        a.set_block(j0, j0, &panel_fac.a);
        tau[j0..j0 + jb].copy_from_slice(&panel_fac.tau);

        if j0 + jb < n {
            // Form T (jb×jb upper triangular) for the block reflector
            // I − V·T·Vᵀ, then update the trailing matrix with DGEMMs.
            let rows = m - j0;
            let cols = n - j0 - jb;
            // V: rows×jb unit lower trapezoidal.
            let mut v = Mat::zeros(rows, jb);
            for jj in 0..jb {
                v[(jj, jj)] = 1.0;
                for i in jj + 1..rows {
                    v[(i, jj)] = a[(j0 + i, j0 + jj)];
                }
            }
            let t = form_t(&v, &tau[j0..j0 + jb], &mut prof);
            // W = Vᵀ · A₂  (jb × cols)
            let a2 = a.block(j0, j0 + jb, rows, cols);
            let w = matmul_prof(&v.transpose(), &a2, ProfiledOp::Dgemm, &mut prof);
            // W ← Tᵀ · W
            let w = matmul_prof(&t.transpose(), &w, ProfiledOp::Dgemm, &mut prof);
            // A₂ ← A₂ − V·W
            let vw = matmul_prof(&v, &w, ProfiledOp::Dgemm, &mut prof);
            let mut a2new = a2;
            for jj in 0..cols {
                for i in 0..rows {
                    a2new[(i, jj)] -= vw[(i, jj)];
                }
            }
            a.set_block(j0, j0 + jb, &a2new);
        }
        j0 += jb;
    }
    (QrFactors { a, tau }, prof)
}

/// Blocked Householder QR (LAPACK DGEQRF), default panel width 8.
pub fn dgeqrf(a: &Mat) -> QrFactors {
    dgeqrf_profiled(a, 8).0
}

/// T factor of the compact-WY representation (LAPACK DLARFT, forward
/// columnwise): H₀·H₁⋯ = I − V·T·Vᵀ.
fn form_t(v: &Mat, tau: &[f64], prof: &mut FlopProfile) -> Mat {
    let jb = v.cols();
    let rows = v.rows();
    let mut t = Mat::zeros(jb, jb);
    for i in 0..jb {
        t[(i, i)] = tau[i];
        if i > 0 {
            // t_col = −τᵢ · T[0..i,0..i] · (V[:,0..i]ᵀ · V[:,i])
            let mut vtv = vec![0.0; i];
            for (jj, out) in vtv.iter_mut().enumerate() {
                let mut s = 0.0;
                for r in 0..rows {
                    s += v[(r, jj)] * v[(r, i)];
                }
                *out = s;
            }
            prof.add(ProfiledOp::Dgemv, 2 * (rows as u64) * (i as u64));
            for r in 0..i {
                let mut s = 0.0;
                for c in r..i {
                    s += t[(r, c)] * vtv[c];
                }
                t[(r, i)] = -tau[i] * s;
            }
        }
    }
    t
}

/// Dense matmul with flop attribution.
fn matmul_prof(a: &Mat, b: &Mat, op: ProfiledOp, prof: &mut FlopProfile) -> Mat {
    let c = crate::blas::level3::dgemm_ref(a, b, &Mat::zeros(a.rows(), b.cols()));
    prof.add(op, 2 * (a.rows() * a.cols() * b.cols()) as u64);
    c
}

/// Materialize Q (m×m) from the factors — test/diagnostic helper
/// (LAPACK DORGQR semantics, full Q).
pub fn form_q(f: &QrFactors) -> Mat {
    let m = f.a.rows();
    let k = f.tau.len();
    let mut q = Mat::eye(m);
    // Q = H₀·H₁⋯H_{k−1}; apply in reverse to I.
    for j in (0..k).rev() {
        let t = f.tau[j];
        if t == 0.0 {
            continue;
        }
        let rows = m - j;
        let mut v = vec![1.0];
        v.extend_from_slice(&f.a.col(j)[j + 1..]);
        // Q[j.., :] ← Q[j.., :] − τ·v·(vᵀ·Q[j.., :])
        for c in 0..m {
            let mut s = 0.0;
            for i in 0..rows {
                s += v[i] * q[(j + i, c)];
            }
            let ts = t * s;
            for i in 0..rows {
                q[(j + i, c)] -= v[i] * ts;
            }
        }
    }
    q
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blas::level3::dgemm_ref;
    use crate::util::{assert_allclose, Mat};

    fn check_qr(a: &Mat, f: &QrFactors, tol: f64) {
        let q = form_q(f);
        // QᵀQ = I
        let qtq = dgemm_ref(&q.transpose(), &q, &Mat::zeros(q.rows(), q.rows()));
        assert_allclose(qtq.as_slice(), Mat::eye(q.rows()).as_slice(), tol);
        // Q·R = A
        let mut r_full = Mat::zeros(a.rows(), a.cols());
        let r = f.r();
        r_full.set_block(0, 0, &r);
        let qr = dgemm_ref(&q, &r_full, &Mat::zeros(a.rows(), a.cols()));
        assert_allclose(qr.as_slice(), a.as_slice(), tol);
    }

    #[test]
    fn dgeqr2_reconstructs_square() {
        let a = Mat::random(12, 12, 31);
        let f = dgeqr2(&a);
        check_qr(&a, &f, 1e-10);
    }

    #[test]
    fn dgeqr2_reconstructs_tall() {
        let a = Mat::random(16, 9, 32);
        let f = dgeqr2(&a);
        check_qr(&a, &f, 1e-10);
    }

    #[test]
    fn dgeqrf_matches_dgeqr2_r() {
        let a = Mat::random(20, 20, 33);
        let f2 = dgeqr2(&a);
        let ff = dgeqrf_profiled(&a, 6).0;
        // R is unique up to sign of rows; the Householder convention fixes
        // signs identically, so they must match exactly.
        assert_allclose(ff.r().as_slice(), f2.r().as_slice(), 1e-9);
        check_qr(&a, &ff, 1e-10);
    }

    #[test]
    fn dgeqrf_various_panel_widths() {
        let a = Mat::random(17, 13, 34);
        for nb in [1, 3, 8, 32] {
            let f = dgeqrf_profiled(&a, nb).0;
            check_qr(&a, &f, 1e-10);
        }
    }

    #[test]
    fn fig1_dgeqr2_is_gemv_dominated() {
        let a = Mat::random(96, 96, 35);
        let (_, prof) = dgeqr2_profiled(&a);
        let l2 = prof.fraction(ProfiledOp::Dgemv) + prof.fraction(ProfiledOp::Dger);
        assert!(l2 > 0.95, "DGEQR2 Level-2 share too small: {l2:.3}");
    }

    #[test]
    fn fig1_dgeqrf_is_gemm_dominated() {
        let a = Mat::random(128, 128, 36);
        let (_, prof) = dgeqrf_profiled(&a, 16);
        let gemm = prof.fraction(ProfiledOp::Dgemm);
        assert!(gemm > 0.80, "DGEQRF DGEMM share too small: {gemm:.3}");
    }

    #[test]
    fn rank_deficient_column_is_safe() {
        // A zero column below the diagonal → τ = 0 path.
        let mut a = Mat::random(8, 8, 37);
        for i in 1..8 {
            a[(i, 0)] = 0.0;
        }
        let f = dgeqr2(&a);
        check_qr(&a, &f, 1e-10);
    }
}
