//! LAPACK-lite: the factorizations the paper motivates BLAS with (§1, Fig 1)
//! built on this crate's BLAS — DGEQR2/DGEQRF (Householder QR), DGETRF
//! (partial-pivot LU), DPOTRF (Cholesky) — plus an operation profiler that
//! reproduces the Fig-1 observation: DGEQR2 spends ~99% of its work in
//! DGEMV, DGEQRF ~99% in DGEMM.

pub mod profile;
pub mod qr;

mod lu;
mod cholesky;

pub use cholesky::dpotrf;
pub use lu::dgetrf;
pub use profile::{FlopProfile, ProfiledOp};
pub use qr::{dgeqr2, dgeqr2_profiled, dgeqrf, dgeqrf_profiled, form_q, QrFactors};
