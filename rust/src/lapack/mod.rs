//! LAPACK-lite: the factorizations the paper motivates BLAS with (§1, Fig 1)
//! built on this crate's BLAS — DGEQR2/DGEQRF (Householder QR), DGETRF
//! (partial-pivot LU), DPOTRF (Cholesky) — plus an operation profiler that
//! reproduces the Fig-1 observation: DGEQR2 spends ~99% of its work in
//! DGEMV, DGEQRF ~99% in DGEMM.
//!
//! These are not just host references: [`expand`] decomposes each
//! factorization into a dependency DAG of cached BLAS kernel calls
//! (`dag::ExecGraph`), which is how the serving engine executes
//! `Request::Dgeqrf/Dgetrf/Dpotrf` — panel and trailing-update nodes flow
//! through the same program cache, replay tiers, and fabric routing as flat
//! BLAS requests, and the Fig-1 [`FlopProfile`] rides along in the
//! factorization `Response`.

pub mod expand;
pub mod profile;
pub mod qr;

mod lu;
mod cholesky;

pub use cholesky::dpotrf;
pub use expand::{default_nb, Expansion, FactorKind, Factors};
pub use lu::{dgetrf, LuFactors};
pub use profile::{FlopProfile, ProfiledOp};
pub use qr::{dgeqr2, dgeqr2_profiled, dgeqrf, dgeqrf_profiled, form_q, QrFactors};
