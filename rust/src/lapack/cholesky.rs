//! DPOTRF: Cholesky factorization (lower), right-looking — the XPBTRF
//! family member the paper cites in §1.

use super::profile::{FlopProfile, ProfiledOp};
use crate::util::Mat;

/// Factor SPD A = L·Lᵀ (lower triangle). Returns L and the flop profile
/// (DSYRK/DGEMM-class work dominates for large n).
pub fn dpotrf(a: &Mat) -> (Mat, FlopProfile) {
    let n = a.rows();
    assert_eq!(a.cols(), n, "square only");
    let mut l = a.clone();
    let mut prof = FlopProfile::new();

    for k in 0..n {
        let mut d = l[(k, k)];
        for j in 0..k {
            d -= l[(k, j)] * l[(k, j)];
        }
        prof.add(ProfiledOp::Ddot, 2 * k as u64);
        assert!(d > 0.0, "matrix not positive definite at step {k}");
        let lkk = d.sqrt();
        l[(k, k)] = lkk;
        // Column update: L[i,k] = (A[i,k] − Σ_j L[i,j]·L[k,j]) / L[k,k]
        // — a matrix-vector product over the factored panel (DGEMV class).
        for i in k + 1..n {
            let mut s = l[(i, k)];
            for j in 0..k {
                s -= l[(i, j)] * l[(k, j)];
            }
            l[(i, k)] = s / lkk;
        }
        prof.add(ProfiledOp::Dgemv, 2 * (k as u64) * ((n - k - 1) as u64));
    }
    // Zero the upper triangle.
    for j in 1..n {
        for i in 0..j {
            l[(i, j)] = 0.0;
        }
    }
    (l, prof)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blas::level3::dgemm_ref;
    use crate::util::{assert_allclose, Mat};

    #[test]
    fn reconstructs_spd_matrix() {
        let a = Mat::random_spd(12, 51);
        let (l, _) = dpotrf(&a);
        let llt = dgemm_ref(&l, &l.transpose(), &Mat::zeros(12, 12));
        assert_allclose(llt.as_slice(), a.as_slice(), 1e-9);
    }

    #[test]
    fn factor_is_lower_triangular() {
        let a = Mat::random_spd(8, 52);
        let (l, _) = dpotrf(&a);
        for j in 1..8 {
            for i in 0..j {
                assert_eq!(l[(i, j)], 0.0);
            }
        }
    }

    #[test]
    #[should_panic(expected = "not positive definite")]
    fn rejects_indefinite() {
        let a = Mat::from_row_major(2, 2, &[1., 2., 2., 1.]); // eigenvalues 3, −1
        dpotrf(&a);
    }

    #[test]
    fn profile_has_gemv_work() {
        let a = Mat::random_spd(32, 53);
        let (_, prof) = dpotrf(&a);
        assert!(prof.fraction(super::ProfiledOp::Dgemv) > 0.5);
    }
}
