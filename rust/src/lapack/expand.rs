//! Blocked-decomposition builders: expand a LAPACK factorization into an
//! executable kernel DAG ([`ExecGraph`]) the serving pipeline dispatches.
//!
//! This is the companion paper's move (arXiv:1610.08705): a factorization is
//! not one opaque call but a dependency graph of BLAS kernels — per-panel
//! Level-1/2 sequences (the DGEQR2-style panel, the LU pivot-column scale,
//! the Cholesky column update) and Level-2/3 trailing-matrix updates. The
//! builders here emit the classic right-looking block pattern over
//! `B = ceil(n/nb)` panel columns:
//!
//! * panel nodes `Pk` factor block column `k`; `Pk` depends on the trailing
//!   update `U(k-1),k` that last wrote that column;
//! * update nodes `Uk,j` (for `j > k`) apply panel `k` to block column `j`
//!   and depend on both `Pk` and the previous update `U(k-1),j` of the same
//!   column.
//!
//! Node kernel calls use only the classes the program cache already serves
//! (DGEMM tiles, DGEMV, Level-1 sequences), so repeated factorizations of
//! one shape replay cached programs. Factor *values* come from the host
//! reference (`dgeqrf_profiled` / `dgetrf` / `dpotrf`) computed at expansion
//! time, exactly like the Level-1/2 serving path: kernels model timing with
//! fixed operand seeds (data-independent), values are resolved host-side.
//! The host run also yields the Fig-1 [`FlopProfile`] that the factorization
//! `Response` reports.

use super::lu::LuFactors;
use super::profile::FlopProfile;
use super::qr::QrFactors;
use super::{dgeqrf_profiled, dgetrf, dpotrf};
use crate::dag::exec::{ExecGraph, KernelCall, Region};
use crate::metrics::Routine;
use crate::util::Mat;

/// Which factorization a request asks for.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FactorKind {
    /// Blocked Householder QR (DGEQRF).
    Qr,
    /// Partial-pivot LU (DGETRF).
    Lu,
    /// Cholesky, lower (DPOTRF).
    Chol,
}

impl FactorKind {
    /// CLI spelling (`--lapack qr|lu|chol`).
    pub fn tag(self) -> &'static str {
        match self {
            FactorKind::Qr => "qr",
            FactorKind::Lu => "lu",
            FactorKind::Chol => "chol",
        }
    }

    /// LAPACK routine name served for this kind.
    pub fn op_name(self) -> &'static str {
        match self {
            FactorKind::Qr => "dgeqrf",
            FactorKind::Lu => "dgetrf",
            FactorKind::Chol => "dpotrf",
        }
    }

    pub fn parse(s: &str) -> Option<FactorKind> {
        match s {
            "qr" => Some(FactorKind::Qr),
            "lu" => Some(FactorKind::Lu),
            "chol" => Some(FactorKind::Chol),
            _ => None,
        }
    }
}

/// Host-computed factor payload of a served factorization.
#[derive(Debug, Clone)]
pub enum Factors {
    Qr(QrFactors),
    Lu(LuFactors),
    Chol(Mat),
}

/// A factorization expanded for serving: the kernel DAG, the host factors,
/// the Fig-1 flop attribution, and the panel width used.
#[derive(Debug, Clone)]
pub struct Expansion {
    pub graph: ExecGraph,
    pub factors: Factors,
    pub profile: FlopProfile,
    pub nb: usize,
}

/// Default panel width for a served factorization of order n.
pub fn default_nb(n: usize) -> usize {
    let nb = if n >= 48 { 8 } else { 4 };
    nb.min(n.max(1))
}

/// The shared right-looking block DAG over `B = ceil(n/nb)` panel columns.
fn blocked_graph(n: usize, nb: usize, kind: FactorKind) -> ExecGraph {
    assert!(n > 0 && nb > 0);
    let nblocks = n.div_ceil(nb);
    let mut g = ExecGraph::new();
    // Last trailing update written into each block column.
    let mut prev_update: Vec<Option<usize>> = vec![None; nblocks];
    for k in 0..nblocks {
        let col0 = k * nb;
        let jb = nb.min(n - col0);
        let rows = n - col0;
        let mut preds = Vec::new();
        if let Some(u) = prev_update[k] {
            preds.push(u);
        }
        let panel_call = match kind {
            // DGEQR2 panel: DGEMV/DGER-dominated Level-2 sequence.
            FactorKind::Qr => KernelCall::Gemv { n: rows },
            // Pivot-column scale: a DSCAL-equivalent Level-1 sweep (the
            // cached kernel set has no DSCAL; DAXPY is its timing twin).
            FactorKind::Lu => KernelCall::Level1 { routine: Routine::Daxpy, n: rows, alpha: 1.0 },
            // Diagonal/column dot products (reduction convention α = 1.5).
            FactorKind::Chol => KernelCall::Level1 { routine: Routine::Ddot, n: rows, alpha: 1.5 },
        };
        let p = g.push(
            panel_call,
            &preds,
            format!("P{k}"),
            Region { row: col0, col: col0, rows, cols: jb },
        );
        for j in k + 1..nblocks {
            let jc0 = j * nb;
            let jbj = nb.min(n - jc0);
            let mut upreds = vec![p];
            if let Some(u) = prev_update[j] {
                upreds.push(u);
            }
            upreds.sort_unstable();
            let update_call = match kind {
                // Compact-WY / right-looking rank-jb update: DGEMM.
                FactorKind::Qr | FactorKind::Lu => KernelCall::Gemm { m: rows, p: jbj, k: jb },
                // Cholesky column update is DGEMV-class over the panel.
                FactorKind::Chol => KernelCall::Gemv { n: rows },
            };
            let u = g.push(
                update_call,
                &upreds,
                format!("U{k},{j}"),
                Region { row: col0, col: jc0, rows, cols: jbj },
            );
            prev_update[j] = Some(u);
        }
    }
    g
}

/// Expand a blocked Householder QR (DGEQRF) of square `a` with panel
/// width `nb`.
pub fn expand_dgeqrf(a: &Mat, nb: usize) -> Expansion {
    assert_eq!(a.rows(), a.cols(), "square only");
    let (fac, profile) = dgeqrf_profiled(a, nb);
    Expansion {
        graph: blocked_graph(a.rows(), nb, FactorKind::Qr),
        factors: Factors::Qr(fac),
        profile,
        nb,
    }
}

/// Expand a partial-pivot LU (DGETRF) of square `a`.
pub fn expand_dgetrf(a: &Mat, nb: usize) -> Expansion {
    assert_eq!(a.rows(), a.cols(), "square only");
    let (fac, profile) = dgetrf(a);
    Expansion {
        graph: blocked_graph(a.rows(), nb, FactorKind::Lu),
        factors: Factors::Lu(fac),
        profile,
        nb,
    }
}

/// Expand a Cholesky factorization (DPOTRF) of SPD `a`.
pub fn expand_dpotrf(a: &Mat, nb: usize) -> Expansion {
    assert_eq!(a.rows(), a.cols(), "square only");
    let (l, profile) = dpotrf(a);
    Expansion {
        graph: blocked_graph(a.rows(), nb, FactorKind::Chol),
        factors: Factors::Chol(l),
        profile,
        nb,
    }
}

/// Expand by kind with the default panel width.
pub fn expand(kind: FactorKind, a: &Mat) -> Expansion {
    let nb = default_nb(a.rows());
    match kind {
        FactorKind::Qr => expand_dgeqrf(a, nb),
        FactorKind::Lu => expand_dgetrf(a, nb),
        FactorKind::Chol => expand_dpotrf(a, nb),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn nodes_for(nblocks: usize) -> usize {
        nblocks + nblocks * (nblocks - 1) / 2
    }

    #[test]
    fn block_counts_and_critical_path() {
        // n = 64, nb = 8 → 8 panels, 28 updates.
        let g = blocked_graph(64, 8, FactorKind::Qr);
        assert_eq!(g.len(), nodes_for(8));
        // The chain P0 → U0,1 → P1 → U1,2 → … alternates panels and
        // updates: critical length 2B − 1.
        assert_eq!(g.critical_len(), 15);
    }

    #[test]
    fn ragged_tail_block() {
        // n = 10, nb = 4 → blocks of 4, 4, 2.
        let g = blocked_graph(10, 4, FactorKind::Lu);
        assert_eq!(g.len(), nodes_for(3));
        // Last panel covers the 2-wide tail.
        let last_panel = g
            .nodes()
            .iter()
            .rev()
            .find(|n| n.label.starts_with('P'))
            .unwrap();
        assert_eq!(last_panel.binding.cols, 2);
        assert_eq!(last_panel.binding.rows, 2);
    }

    #[test]
    fn panel_depends_on_previous_update_of_its_column() {
        let g = blocked_graph(12, 4, FactorKind::Qr);
        // Node order: P0, U0,1, U0,2, P1, U1,2, P2.
        assert_eq!(g.node(0).preds, Vec::<usize>::new());
        assert_eq!(g.node(1).preds, vec![0]);
        assert_eq!(g.node(2).preds, vec![0]);
        assert_eq!(g.node(3).preds, vec![1], "P1 waits on U0,1");
        assert_eq!(g.node(4).preds, vec![2, 3], "U1,2 waits on U0,2 and P1");
        assert_eq!(g.node(5).preds, vec![4], "P2 waits on U1,2");
        assert_eq!(g.node(3).label, "P1");
        assert_eq!(g.node(4).label, "U1,2");
    }

    #[test]
    fn kind_selects_kernel_classes() {
        let qr = blocked_graph(16, 4, FactorKind::Qr);
        assert!(matches!(qr.node(0).call, KernelCall::Gemv { .. }));
        assert!(matches!(qr.node(1).call, KernelCall::Gemm { .. }));
        let lu = blocked_graph(16, 4, FactorKind::Lu);
        assert!(
            matches!(lu.node(0).call, KernelCall::Level1 { routine: Routine::Daxpy, .. })
        );
        let ch = blocked_graph(16, 4, FactorKind::Chol);
        assert!(
            matches!(ch.node(0).call, KernelCall::Level1 { routine: Routine::Ddot, .. })
        );
        assert!(matches!(ch.node(1).call, KernelCall::Gemv { .. }));
    }

    #[test]
    fn expansion_factors_match_host_reference() {
        let a = Mat::random(20, 20, 77);
        let e = expand_dgeqrf(&a, 8);
        let (host, _) = dgeqrf_profiled(&a, 8);
        match &e.factors {
            Factors::Qr(f) => {
                crate::util::assert_allclose(f.a.as_slice(), host.a.as_slice(), 1e-15);
                crate::util::assert_allclose(&f.tau, &host.tau, 1e-15);
            }
            _ => panic!("wrong payload"),
        }
        assert!(e.profile.total() > 0);
        assert_eq!(e.graph.len(), nodes_for(3));
    }

    #[test]
    fn default_nb_tracks_size() {
        assert_eq!(default_nb(64), 8);
        assert_eq!(default_nb(32), 4);
        assert_eq!(default_nb(3), 3);
        assert_eq!(default_nb(1), 1);
    }

    #[test]
    fn factor_kind_round_trips() {
        for k in [FactorKind::Qr, FactorKind::Lu, FactorKind::Chol] {
            assert_eq!(FactorKind::parse(k.tag()), Some(k));
        }
        assert_eq!(FactorKind::parse("svd"), None);
    }
}
