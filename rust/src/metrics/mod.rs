//! Experiment metrics and harness: one-stop functions that run a BLAS
//! routine on the simulated PE at a given enhancement level and return the
//! paper's reported quantities (latency, CPF, FPC, %peak, Gflops/W, α).
//!
//! The bench binaries (`paper_tables`, `paper_figures`) and the examples
//! are thin printers over this module, so every number in EXPERIMENTS.md is
//! regenerated from one code path.

pub mod paper;

use crate::codegen::{self, layout::VecLayout, GemmLayout};
use crate::energy::PowerModel;
use crate::pe::{AeLevel, ExecMode, ExecTier, Pe, PeConfig, PeStats, Program, ScheduledProgram};
use crate::util::{Mat, XorShift64};

/// Which BLAS routine a measurement ran.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Routine {
    Dgemm,
    Dgemv,
    Ddot,
    Daxpy,
    Dnrm2,
}

impl Routine {
    pub fn name(self) -> &'static str {
        match self {
            Routine::Dgemm => "DGEMM",
            Routine::Dgemv => "DGEMV",
            Routine::Ddot => "DDOT",
            Routine::Daxpy => "DAXPY",
            Routine::Dnrm2 => "DNRM2",
        }
    }

    /// Paper-convention flop count (mul + add + accumulate counted
    /// separately — the convention under Tables 4–9; see DESIGN.md).
    pub fn paper_flops(self, n: usize) -> u64 {
        let n = n as u64;
        match self {
            Routine::Dgemm => 3 * n.pow(3),
            Routine::Dgemv => 3 * n.pow(2),
            Routine::Ddot => 3 * n,
            Routine::Daxpy => 2 * n,
            Routine::Dnrm2 => 3 * n + 1,
        }
    }

    /// Standard flop count (one flop per add/mul).
    pub fn std_flops(self, n: usize) -> u64 {
        let n = n as u64;
        match self {
            Routine::Dgemm => 2 * n.pow(3),
            Routine::Dgemv => 2 * n.pow(2),
            Routine::Ddot => 2 * n,
            Routine::Daxpy => 2 * n,
            Routine::Dnrm2 => 2 * n + 1,
        }
    }
}

/// One measurement: a routine, a size, an enhancement level, and the
/// resulting simulator statistics.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub routine: Routine,
    pub n: usize,
    pub ae: AeLevel,
    pub stats: PeStats,
    pub cfg: PeConfig,
}

impl Measurement {
    /// Latency in clock cycles (the paper's tables).
    pub fn latency(&self) -> u64 {
        self.stats.cycles
    }

    /// CPF in the paper's 3n³ convention (Tables 4–9).
    pub fn paper_cpf(&self) -> f64 {
        self.stats.cycles as f64 / self.routine.paper_flops(self.n) as f64
    }

    /// FPC in the paper's convention (fig 11(d)).
    pub fn paper_fpc(&self) -> f64 {
        1.0 / self.paper_cpf()
    }

    /// Percentage of the configuration's peak FPC attained (fig 11(e)).
    pub fn pct_peak_fpc(&self) -> f64 {
        100.0 * self.paper_fpc() / self.ae.peak_fpc()
    }

    /// CPF with standard flop counting.
    pub fn std_cpf(&self) -> f64 {
        self.stats.cycles as f64 / self.routine.std_flops(self.n) as f64
    }

    /// α = latency / total computations in DOT4 terms (eq. 7, fig 11(b)).
    /// The DOT4-work denominator is n³/4 for DGEMM regardless of level.
    pub fn alpha(&self) -> f64 {
        let dot4_work = match self.routine {
            Routine::Dgemm => (self.n as u64).pow(3) / 4,
            Routine::Dgemv => (self.n as u64).pow(2) / 4,
            Routine::Ddot | Routine::Daxpy | Routine::Dnrm2 => self.n as u64 / 4,
        };
        self.stats.cycles as f64 / dot4_work.max(1) as f64
    }

    /// Gflops/W in the paper's convention (Tables 4–9 columns).
    pub fn gflops_per_watt(&self) -> f64 {
        PowerModel::paper().gflops_per_watt(
            self.ae,
            &self.cfg,
            &self.stats,
            self.routine.paper_flops(self.n),
        )
    }

    /// Achieved Gflops (standard convention) at the PE clock.
    pub fn gflops(&self) -> f64 {
        self.routine.std_flops(self.n) as f64 / self.stats.seconds(&self.cfg) / 1e9
    }
}

/// Run DGEMM on the PE simulator and verify the result against host BLAS.
pub fn measure_gemm(n: usize, ae: AeLevel) -> Measurement {
    let a = Mat::random(n, n, 0xA0 + n as u64);
    let b = Mat::random(n, n, 0xB0 + n as u64);
    let c = Mat::random(n, n, 0xC0 + n as u64);
    measure_gemm_with(n, ae, &a, &b, &c)
}

/// Run DGEMM with caller-provided operands (numerics checked).
pub fn measure_gemm_with(n: usize, ae: AeLevel, a: &Mat, b: &Mat, c: &Mat) -> Measurement {
    assert!(n % 4 == 0, "pad to a multiple of 4 first");
    let layout = GemmLayout::packed(n);
    let prog = codegen::gen_gemm(n, ae, &layout);
    let cfg = PeConfig::paper(ae);
    let mut pe = Pe::new(cfg.clone(), layout.gm_words());
    pe.write_gm(0, &layout.pack(a, b, c));
    let stats = pe.run(&prog);
    // Numerical check against the host reference.
    let got = layout.unpack_c(&pe.gm, n, n);
    let want = crate::blas::level3::dgemm_ref(a, b, c);
    let err = crate::util::rel_fro_error(got.as_slice(), want.as_slice());
    assert!(err < 1e-12, "PE DGEMM numerics off: rel err {err}");
    Measurement { routine: Routine::Dgemm, n, ae, stats, cfg }
}

/// Run DGEMV on the PE simulator (numerics checked).
pub fn measure_gemv(n: usize, ae: AeLevel) -> Measurement {
    let l = VecLayout::gemv(n);
    let prog = codegen::gen_gemv(n, ae, &l);
    measure_gemv_prog(n, ae, &prog)
}

/// [`measure_gemv`] with a pre-compiled program — the serving engine's
/// cached-kernel path (the coordinator emits each (shape, AE) program once
/// and reuses it; PE timing is data-independent, so the fixed operand seeds
/// double as a numerical cross-check of the cached stream).
pub fn measure_gemv_prog(n: usize, ae: AeLevel, prog: &Program) -> Measurement {
    let mut pe = Pe::new(PeConfig::paper(ae), 0);
    measure_gemv_prog_on(&mut pe, n, ae, prog)
}

/// [`measure_gemv_prog`] on a caller-provided PE, which is [`Pe::reset`] to
/// this kernel's GM image and reused — the pooled-worker path, where one
/// long-lived PE per worker serves every routine. A reset PE is
/// bit-identical to a fresh one, so this returns exactly the measurement of
/// [`measure_gemv_prog`]. `pe` must be configured for `ae`.
pub fn measure_gemv_prog_on(pe: &mut Pe, n: usize, ae: AeLevel, prog: &Program) -> Measurement {
    let fx = gemv_setup(pe, n);
    let stats = pe.run(prog);
    gemv_check(pe, n, &fx);
    Measurement { routine: Routine::Dgemv, n, ae, stats, cfg: pe.cfg.clone() }
}

/// [`measure_gemv_prog_on`] over a pre-decoded, schedulable kernel — the
/// two-tier serving path. In [`ExecMode::Replay`], a kernel whose timing
/// pass already ran (on a config-identical PE) executes values-only and
/// returns the memoized stats (identical to a fresh combined run: PE
/// timing is data-independent); the first execution, or
/// [`ExecMode::Combined`], runs the full combined interpreter. Numerics
/// are checked either way. Also reports which tier actually ran, for the
/// pool's telemetry.
pub fn measure_gemv_sched_on(
    pe: &mut Pe,
    n: usize,
    ae: AeLevel,
    sched: &ScheduledProgram,
    mode: ExecMode,
) -> (Measurement, ExecTier) {
    let fx = gemv_setup(pe, n);
    let (stats, tier) = sched.execute_traced(pe, mode);
    gemv_check(pe, n, &fx);
    (Measurement { routine: Routine::Dgemv, n, ae, stats, cfg: pe.cfg.clone() }, tier)
}

/// Reset `pe` to the DGEMV kernel's fixed-seed GM image (operands are
/// pure functions of `n`, so every measurement of a shape is comparable).
fn gemv_setup(pe: &mut Pe, n: usize) -> (Mat, Vec<f64>, Vec<f64>, VecLayout) {
    let a = Mat::random(n, n, 0xD0 + n as u64);
    let mut rng = XorShift64::new(0xE0 + n as u64);
    let x = rng.vec(n);
    let y = rng.vec(n);
    let l = VecLayout::gemv(n);
    pe.reset(l.gm_words());
    let mut gm = vec![0.0; l.gm_words()];
    for i in 0..n {
        for k in 0..n {
            gm[l.a(i, k)] = a[(i, k)];
        }
    }
    gm[l.base_x..l.base_x + n].copy_from_slice(&x);
    gm[l.base_y..l.base_y + n].copy_from_slice(&y);
    pe.write_gm(0, &gm);
    (a, x, y, l)
}

/// Cross-check the DGEMV kernel's output against the host reference.
fn gemv_check(pe: &Pe, n: usize, fx: &(Mat, Vec<f64>, Vec<f64>, VecLayout)) {
    let (a, x, y, l) = fx;
    let got = pe.read_gm(l.base_y, n).to_vec();
    let want = crate::blas::level2::dgemv_ref(a, x, y);
    crate::util::assert_allclose(&got, &want, 1e-12);
}

/// Run a Level-1 routine on the PE simulator (numerics checked).
pub fn measure_level1(routine: Routine, n: usize, ae: AeLevel) -> Measurement {
    let l = VecLayout::level1(n);
    let alpha = 1.5;
    let prog = match routine {
        Routine::Ddot => codegen::gen_ddot(n, ae, &l),
        Routine::Dnrm2 => codegen::gen_dnrm2(n, ae, &l),
        Routine::Daxpy => codegen::gen_daxpy(n, alpha, ae, &l),
        _ => panic!("not a level-1 routine: {routine:?}"),
    };
    measure_level1_prog(routine, n, alpha, ae, &prog)
}

/// [`measure_level1`] with a pre-compiled program (the cached-kernel path).
/// `alpha` must match the constant baked into a DAXPY program; it is
/// ignored for the reduction routines.
pub fn measure_level1_prog(
    routine: Routine,
    n: usize,
    alpha: f64,
    ae: AeLevel,
    prog: &Program,
) -> Measurement {
    let mut pe = Pe::new(PeConfig::paper(ae), 0);
    measure_level1_prog_on(&mut pe, routine, n, alpha, ae, prog)
}

/// [`measure_level1_prog`] on a caller-provided PE (reset and reused) — the
/// pooled-worker path, exactly as [`measure_gemv_prog_on`].
pub fn measure_level1_prog_on(
    pe: &mut Pe,
    routine: Routine,
    n: usize,
    alpha: f64,
    ae: AeLevel,
    prog: &Program,
) -> Measurement {
    let fx = level1_setup(pe, n);
    let stats = pe.run(prog);
    level1_check(pe, routine, n, alpha, &fx);
    Measurement { routine, n, ae, stats, cfg: pe.cfg.clone() }
}

/// [`measure_level1_prog_on`] over a pre-decoded, schedulable kernel —
/// the two-tier serving path (see [`measure_gemv_sched_on`] for the
/// replay/combined semantics and the reported tier).
pub fn measure_level1_sched_on(
    pe: &mut Pe,
    routine: Routine,
    n: usize,
    alpha: f64,
    ae: AeLevel,
    sched: &ScheduledProgram,
    mode: ExecMode,
) -> (Measurement, ExecTier) {
    let fx = level1_setup(pe, n);
    let (stats, tier) = sched.execute_traced(pe, mode);
    level1_check(pe, routine, n, alpha, &fx);
    (Measurement { routine, n, ae, stats, cfg: pe.cfg.clone() }, tier)
}

/// Reset `pe` to the Level-1 kernel's fixed-seed GM image.
fn level1_setup(pe: &mut Pe, n: usize) -> (Vec<f64>, Vec<f64>, VecLayout) {
    let l = VecLayout::level1(n);
    let mut rng = XorShift64::new(0xF0 + n as u64);
    let x = rng.vec(n);
    let y = rng.vec(n);
    pe.reset(l.gm_words());
    pe.write_gm(l.base_x, &x);
    pe.write_gm(l.base_y, &y);
    (x, y, l)
}

/// Cross-check a Level-1 kernel's output against the host reference.
fn level1_check(pe: &Pe, routine: Routine, n: usize, alpha: f64, fx: &(Vec<f64>, Vec<f64>, VecLayout)) {
    let (x, y, l) = fx;
    match routine {
        Routine::Ddot => {
            let want: f64 = x.iter().zip(y).map(|(a, b)| a * b).sum();
            let got = pe.read_gm(l.scratch(), 1)[0];
            assert!((got - want).abs() < 1e-10, "ddot numerics: {got} vs {want}");
        }
        Routine::Dnrm2 => {
            let want = x.iter().map(|v| v * v).sum::<f64>().sqrt();
            let got = pe.read_gm(l.scratch(), 1)[0];
            assert!((got - want).abs() < 1e-10, "dnrm2 numerics: {got} vs {want}");
        }
        Routine::Daxpy => {
            let got = pe.read_gm(l.base_y, n).to_vec();
            for k in 0..n {
                let want = alpha * x[k] + y[k];
                assert!((got[k] - want).abs() < 1e-10, "daxpy numerics at {k}");
            }
        }
        _ => unreachable!(),
    }
}

/// The paper's representative matrix sizes (§4.5.1).
pub const PAPER_SIZES: [usize; 5] = [20, 40, 60, 80, 100];

/// Full enhancement sweep for DGEMM over the paper's sizes.
/// Returns `[ae][size]` measurements.
pub fn gemm_sweep(sizes: &[usize]) -> Vec<Vec<Measurement>> {
    AeLevel::ALL
        .iter()
        .map(|&ae| sizes.iter().map(|&n| measure_gemm(n, ae)).collect())
        .collect()
}

/// Render a paper-style table (one row per metric, one column per size).
pub fn format_table(title: &str, sizes: &[usize], rows: &[(&str, Vec<String>)]) -> String {
    let mut s = String::new();
    s.push_str(&format!("### {title}\n"));
    s.push_str(&format!("{:<38}", "Matrix Size"));
    for n in sizes {
        s.push_str(&format!("{:>12}", format!("{n}x{n}")));
    }
    s.push('\n');
    for (label, cells) in rows {
        s.push_str(&format!("{label:<38}"));
        for c in cells {
            s.push_str(&format!("{c:>12}"));
        }
        s.push('\n');
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_flop_conventions() {
        assert_eq!(Routine::Dgemm.paper_flops(20), 24_000);
        assert_eq!(Routine::Dgemm.std_flops(20), 16_000);
        assert_eq!(Routine::Dgemv.paper_flops(10), 300);
        assert_eq!(Routine::Ddot.paper_flops(8), 24);
    }

    #[test]
    fn measurement_metrics_consistent() {
        let m = measure_gemm(8, AeLevel::Ae5);
        assert!(m.paper_cpf() > 0.0);
        assert!((m.paper_fpc() * m.paper_cpf() - 1.0).abs() < 1e-12);
        assert!(m.pct_peak_fpc() > 0.0 && m.pct_peak_fpc() < 100.0);
        assert!(m.alpha() >= 1.0, "α < 1 impossible: {}", m.alpha());
        assert!(m.gflops_per_watt() > 0.0);
    }

    #[test]
    fn gemv_and_level1_measurements_run() {
        let m = measure_gemv(8, AeLevel::Ae3);
        assert!(m.latency() > 0);
        for r in [Routine::Ddot, Routine::Daxpy, Routine::Dnrm2] {
            let m = measure_level1(r, 16, AeLevel::Ae4);
            assert!(m.latency() > 0, "{r:?}");
        }
    }

    #[test]
    fn measurement_on_reused_pe_matches_fresh() {
        // The pooled-worker path (one reset-reused PE per worker) must
        // produce bit-identical measurements to a fresh PE per kernel.
        let ae = AeLevel::Ae4;
        let gl = VecLayout::gemv(8);
        let gprog = codegen::gen_gemv(8, ae, &gl);
        let fresh = measure_gemv_prog(8, ae, &gprog);
        // Dirty the reusable PE with an unrelated kernel first.
        let mut pe = Pe::new(PeConfig::paper(ae), 7);
        let ll = VecLayout::level1(16);
        let dprog = codegen::gen_ddot(16, ae, &ll);
        let _ = measure_level1_prog_on(&mut pe, Routine::Ddot, 16, 1.5, ae, &dprog);
        let reused = measure_gemv_prog_on(&mut pe, 8, ae, &gprog);
        assert_eq!(fresh.latency(), reused.latency());
        assert_eq!(fresh.stats.instructions, reused.stats.instructions);
        let f1 = measure_level1_prog(Routine::Ddot, 16, 1.5, ae, &dprog);
        let r1 = measure_level1_prog_on(&mut pe, Routine::Ddot, 16, 1.5, ae, &dprog);
        assert_eq!(f1.latency(), r1.latency());
    }

    #[test]
    fn sched_measurement_matches_prog_measurement() {
        // The two-tier path (schedule once, replay after) must return the
        // exact stats of the combined one-shot path, for the first run
        // (timing pass), warm replays, and forced combined re-runs alike.
        let ae = AeLevel::Ae5;
        let n = 12;
        let gprog = codegen::gen_gemv(n, ae, &VecLayout::gemv(n));
        let want = measure_gemv_prog(n, ae, &gprog);
        let sched = ScheduledProgram::compile(&gprog, ae).expect("gemv kernel decodes");
        let mut pe = Pe::new(PeConfig::paper(ae), 0);
        let (first, t1) = measure_gemv_sched_on(&mut pe, n, ae, &sched, ExecMode::Replay);
        assert!(sched.is_scheduled(), "first execution must memoize the schedule");
        assert_eq!(t1, ExecTier::Combined, "first execution is the timing pass");
        let (warm, t2) = measure_gemv_sched_on(&mut pe, n, ae, &sched, ExecMode::Replay);
        assert_eq!(t2, ExecTier::Replayed);
        let (forced, t3) = measure_gemv_sched_on(&mut pe, n, ae, &sched, ExecMode::Combined);
        assert_eq!(t3, ExecTier::Combined);
        assert_eq!(want.stats, first.stats);
        assert_eq!(want.stats, warm.stats, "memoized stats must equal a fresh run");
        assert_eq!(want.stats, forced.stats);

        let dprog = codegen::gen_ddot(16, ae, &VecLayout::level1(16));
        let w = measure_level1_prog(Routine::Ddot, 16, 1.5, ae, &dprog);
        let dsched = ScheduledProgram::compile(&dprog, ae).expect("ddot kernel decodes");
        let (r1, _) =
            measure_level1_sched_on(&mut pe, Routine::Ddot, 16, 1.5, ae, &dsched, ExecMode::Replay);
        let (r2, d2) =
            measure_level1_sched_on(&mut pe, Routine::Ddot, 16, 1.5, ae, &dsched, ExecMode::Replay);
        assert_eq!(d2, ExecTier::Replayed);
        assert_eq!(w.stats, r1.stats);
        assert_eq!(w.stats, r2.stats);
    }

    #[test]
    fn table_formatter_shapes_output() {
        let t = format_table(
            "Demo",
            &[20, 40],
            &[("Latency", vec!["1".into(), "2".into()])],
        );
        assert!(t.contains("Demo"));
        assert!(t.contains("20x20"));
        assert!(t.lines().count() == 3);
    }
}
