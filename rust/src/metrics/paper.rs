//! The paper's published numbers (Tables 4–9, Fig 11/12 anchors), kept in
//! one place so benches, tests and EXPERIMENTS.md compare against the same
//! source of truth.

/// Matrix sizes of the enhancement tables (§4.5.1).
pub const SIZES: [usize; 5] = [20, 40, 60, 80, 100];

/// Latencies in cycles, rows = AE0..AE5 (Tables 4, 5, 6, 7, 8, 9).
pub const LATENCY: [[u64; 5]; 6] = [
    [39_000, 310_075, 1_040_754, 2_457_600, 4_770_000],
    [23_000, 178_471, 595_421, 1_410_662, 2_730_365],
    [15_251, 113_114, 371_699, 877_124, 1_696_921],
    [12_745, 97_136, 324_997, 784_838, 1_519_083],
    [7_079, 52_624, 174_969, 422_924, 818_178],
    [5_561, 38_376, 124_741, 298_161, 573_442],
];

/// Gflops/W columns of the same tables.
pub const GFLOPS_W: [[f64; 5]; 6] = [
    [16.66, 16.87, 17.15, 17.25, 17.38],
    [14.87, 15.53, 15.77, 15.81, 15.98],
    [10.52, 11.49, 11.85, 11.93, 12.06],
    [12.59, 13.38, 13.56, 13.33, 13.47],
    [22.67, 24.71, 25.19, 24.95, 25.02],
    [28.86, 33.88, 35.33, 35.11, 35.70],
];

/// Fig 11(a) headline speed-ups AE0→AE5 at n = 20/40/60.
pub const FIG11A_SPEEDUP: [f64; 3] = [7.0, 8.13, 8.34];

/// Abstract/§5 headline efficiencies: fraction of peak FPC at AE5.
pub const PCT_PEAK_DGEMM: f64 = 0.74;
pub const PCT_PEAK_DGEMV: f64 = 0.40;
pub const PCT_PEAK_DDOT: f64 = 0.20;

/// Paper CPF (3n³ convention) for a table cell.
pub fn paper_cpf(ae_idx: usize, size_idx: usize) -> f64 {
    LATENCY[ae_idx][size_idx] as f64 / (3 * SIZES[size_idx].pow(3)) as f64
}

/// Per-enhancement improvement (1 − L_next/L_prev) the paper reports
/// between consecutive tables, at a size index.
pub fn paper_improvement(ae_from: usize, size_idx: usize) -> f64 {
    1.0 - LATENCY[ae_from + 1][size_idx] as f64 / LATENCY[ae_from][size_idx] as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpf_matches_table9_footnote() {
        // Table 9 @ n=100: 573442 / 3e6 ≈ 0.191 → 74% of peak FPC 7.
        let cpf = paper_cpf(5, 4);
        assert!((cpf - 0.191).abs() < 0.001);
        let pct = (1.0 / cpf) / 7.0;
        assert!((pct - PCT_PEAK_DGEMM).abs() < 0.02);
    }

    #[test]
    fn improvements_match_tables() {
        // Table 5 row: 41–42.6% improvement from AE0.
        assert!((0.40..0.44).contains(&paper_improvement(0, 0)));
        // Table 8: 44.4–46.14%.
        assert!((0.44..0.47).contains(&paper_improvement(3, 4)));
        // Table 9: 21.44–29.9%.
        assert!((0.21..0.30).contains(&paper_improvement(4, 0)));
    }
}
