//! Power/energy model of the PE — the source of every Gflops/W column in
//! Tables 4–9 and of the Fig 11(j) comparison.
//!
//! The paper reports energy efficiency per enhancement level at a 0.2 GHz
//! operating point. Working backwards from its own tables (see DESIGN.md
//! §Calibration), the five Gflops/W columns are mutually consistent with a
//! *fixed per-configuration power*:
//!
//! * AE0 (FPS + FPU + RF):                ≈ 7.2 mW
//! * AE1 (+ Load-Store CFU + 256-kbit LM): ≈ 13.7 mW
//! * AE2..AE5 (+ DOT4 RDP):               ≈ 29.3 mW
//!
//! i.e. the paper's numbers embed a component-level static power budget and
//! no measurable activity dependence (as expected from a synthesis-tool
//! power report at constant utilization). We model exactly that: a
//! component breakdown whose sums hit those budgets, plus an optional
//! activity-proportional term (default small) for sensitivity studies.

use crate::pe::{AeLevel, PeConfig, PeStats};

/// Per-component power breakdown in milliwatts at the 0.2 GHz design point.
#[derive(Debug, Clone)]
pub struct PowerModel {
    /// FPS front end: fetch/decode/sequencing + register file.
    pub fps_mw: f64,
    /// Pipelined FPU (adder + multiplier + div/sqrt).
    pub fpu_mw: f64,
    /// Load-Store CFU control (AE1+).
    pub ls_cfu_mw: f64,
    /// 256-kbit Local Memory SRAM (AE1+).
    pub lm_mw: f64,
    /// DOT4 reconfigurable datapath (AE2+): 4 multipliers + adder tree.
    pub rdp_mw: f64,
    /// Wide 256-bit FPS↔CFU datapath (AE4+).
    pub wide_path_mw: f64,
    /// Dynamic energy per flop (pJ) — activity-proportional term.
    pub pj_per_flop: f64,
    /// Dynamic energy per GM word moved (pJ).
    pub pj_per_gm_word: f64,
    /// Dynamic energy per LM word moved (pJ).
    pub pj_per_lm_word: f64,
}

impl Default for PowerModel {
    fn default() -> Self {
        Self::paper()
    }
}

impl PowerModel {
    /// The calibrated model (budgets above, small activity terms).
    pub fn paper() -> Self {
        Self {
            fps_mw: 3.4,
            fpu_mw: 3.8,
            ls_cfu_mw: 2.1,
            lm_mw: 4.4,
            rdp_mw: 14.2,
            wide_path_mw: 1.4,
            pj_per_flop: 1.0,
            pj_per_gm_word: 12.0,
            pj_per_lm_word: 2.0,
        }
    }

    /// Static power of a PE configuration in watts.
    pub fn static_watts(&self, ae: AeLevel) -> f64 {
        let mut mw = self.fps_mw + self.fpu_mw;
        if ae.has_lm() {
            mw += self.ls_cfu_mw + self.lm_mw;
        }
        if ae.has_dot() {
            mw += self.rdp_mw;
        }
        if ae.has_wide_path() {
            mw += self.wide_path_mw;
        }
        mw * 1e-3
    }

    /// Total energy of a run in joules (static · time + activity).
    pub fn energy_joules(&self, ae: AeLevel, cfg: &PeConfig, st: &PeStats) -> f64 {
        let time_s = st.seconds(cfg);
        let static_j = self.static_watts(ae) * time_s;
        let dyn_j = 1e-12
            * (self.pj_per_flop * st.flops as f64
                + self.pj_per_gm_word * st.gm_words as f64
                + self.pj_per_lm_word * st.lm_words as f64);
        static_j + dyn_j
    }

    /// Average power of a run in watts.
    pub fn avg_watts(&self, ae: AeLevel, cfg: &PeConfig, st: &PeStats) -> f64 {
        self.energy_joules(ae, cfg, st) / st.seconds(cfg)
    }

    /// Gflops/W with a caller-supplied flop count (the paper uses the 3n³
    /// convention for DGEMM — pass [`crate::codegen::gemm::paper_flops`]).
    pub fn gflops_per_watt(
        &self,
        ae: AeLevel,
        cfg: &PeConfig,
        st: &PeStats,
        flops: u64,
    ) -> f64 {
        let gflops = flops as f64 / st.seconds(cfg) / 1e9;
        gflops / self.avg_watts(ae, cfg, st)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn static_power_ladder() {
        let m = PowerModel::paper();
        let p0 = m.static_watts(AeLevel::Ae0);
        let p1 = m.static_watts(AeLevel::Ae1);
        let p2 = m.static_watts(AeLevel::Ae2);
        let p3 = m.static_watts(AeLevel::Ae3);
        let p5 = m.static_watts(AeLevel::Ae5);
        assert!(p0 < p1 && p1 < p2, "power must grow with hardware: {p0} {p1} {p2}");
        assert_eq!(p2, p3, "AE3 adds no datapath hardware");
        assert!(p5 > p2, "wide path adds power");
        // Calibration anchors (DESIGN.md): ~7.2 / ~13.7 / ~28-29 mW.
        assert!((p0 * 1e3 - 7.2).abs() < 0.5, "AE0 power {p0}");
        assert!((p1 * 1e3 - 13.7).abs() < 0.5, "AE1 power {p1}");
        assert!((p2 * 1e3 - 27.9).abs() < 1.0, "AE2 power {p2}");
    }

    #[test]
    fn energy_scales_with_time() {
        let m = PowerModel::paper();
        let cfg = PeConfig::paper(AeLevel::Ae0);
        let mut st = PeStats { cycles: 1000, flops: 100, ..Default::default() };
        let e1 = m.energy_joules(AeLevel::Ae0, &cfg, &st);
        st.cycles = 2000;
        let e2 = m.energy_joules(AeLevel::Ae0, &cfg, &st);
        assert!(e2 > 1.9 * e1 && e2 < 2.1 * e1);
    }

    #[test]
    fn gflops_per_watt_sane_range() {
        // A fully-utilized AE5 PE: 3n³-convention flops at ~0.19 CPF should
        // land in the tens of Gflops/W (paper: 35.7).
        let m = PowerModel::paper();
        let cfg = PeConfig::paper(AeLevel::Ae5);
        let st = PeStats {
            cycles: 573_442,
            flops: 2_000_000,
            gm_words: 30_000,
            lm_words: 1_500_000,
            ..Default::default()
        };
        let gw = m.gflops_per_watt(AeLevel::Ae5, &cfg, &st, 3_000_000);
        assert!(gw > 20.0 && gw < 50.0, "AE5 Gflops/W out of range: {gw}");
    }
}
