//! Winograd's Matrix Multiplication (WMM) — the §4.3.3 baseline.
//!
//! The Strassen–Winograd variant of Table 3: same 7 block multiplies per
//! recursion step but only **15** block additions (vs SMM's 18). Same
//! O(n^2.81) asymptotic complexity; slightly lower constant — the paper's
//! observation that "execution time of WMM is observed to be slightly less
//! than SMM due to fewer additions".

use crate::util::Mat;

/// Recursion cut-off (below: plain GEMM).
const CUTOFF: usize = 8;

/// Multiply C = A·B with the Strassen–Winograd algorithm.
pub fn winograd_multiply(a: &Mat, b: &Mat) -> Mat {
    let n = a.rows();
    assert_eq!(a.cols(), n, "WMM needs square A");
    assert_eq!(b.rows(), n, "dims");
    assert_eq!(b.cols(), n, "WMM needs square B");
    if n == 0 {
        return Mat::zeros(0, 0);
    }
    let p = n.next_power_of_two();
    if p != n {
        let c = winograd_rec(&a.padded(p, p), &b.padded(p, p));
        return c.block(0, 0, n, n);
    }
    winograd_rec(a, b)
}

fn winograd_rec(a: &Mat, b: &Mat) -> Mat {
    let n = a.rows();
    if n <= CUTOFF {
        return crate::blas::level3::dgemm_ref(a, b, &Mat::zeros(n, n));
    }
    let h = n / 2;
    let (a11, a12, a21, a22) =
        (a.block(0, 0, h, h), a.block(0, h, h, h), a.block(h, 0, h, h), a.block(h, h, h, h));
    let (b11, b12, b21, b22) =
        (b.block(0, 0, h, h), b.block(0, h, h, h), b.block(h, 0, h, h), b.block(h, h, h, h));

    // The S/T pre-additions of Table 3 (8 of the 15 additions).
    let s1 = add(&a21, &a22);
    let s2 = sub(&s1, &a11);
    let s3 = sub(&a11, &a21);
    let s4 = sub(&a12, &s2);
    let t1 = sub(&b12, &b11);
    let t2 = sub(&b22, &t1);
    let t3 = sub(&b22, &b12);
    let t4 = sub(&t2, &b21);

    // Seven recursive multiplies.
    let m1 = winograd_rec(&a11, &b11);
    let m2 = winograd_rec(&a12, &b21);
    let m3 = winograd_rec(&s4, &b22);
    let m4 = winograd_rec(&a22, &t4);
    let m5 = winograd_rec(&s1, &t1);
    let m6 = winograd_rec(&s2, &t2);
    let m7 = winograd_rec(&s3, &t3);

    // The U post-additions (7 more, 15 total).
    let u1 = add(&m1, &m2); // C11
    let u2 = add(&m1, &m6);
    let u3 = add(&u2, &m7);
    let u4 = add(&u2, &m5);
    let u5 = add(&u4, &m3); // C12
    let u6 = sub(&u3, &m4); // C21
    let u7 = add(&u3, &m5); // C22

    let mut c = Mat::zeros(n, n);
    c.set_block(0, 0, &u1);
    c.set_block(0, h, &u5);
    c.set_block(h, 0, &u6);
    c.set_block(h, h, &u7);
    c
}

fn add(a: &Mat, b: &Mat) -> Mat {
    let mut c = a.clone();
    for (ci, bi) in c.as_mut_slice().iter_mut().zip(b.as_slice()) {
        *ci += bi;
    }
    c
}

fn sub(a: &Mat, b: &Mat) -> Mat {
    let mut c = a.clone();
    for (ci, bi) in c.as_mut_slice().iter_mut().zip(b.as_slice()) {
        *ci -= bi;
    }
    c
}

/// Per-recursion-step op counts (block multiplies, block additions):
/// 7 and 15 (Table 3 / §4.3.3).
pub fn wmm_step_op_counts() -> (usize, usize) {
    (7, 15)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::assert_allclose;

    #[test]
    fn matches_gemm_power_of_two() {
        let a = Mat::random(32, 32, 7);
        let b = Mat::random(32, 32, 8);
        let want = crate::blas::level3::dgemm_ref(&a, &b, &Mat::zeros(32, 32));
        let got = winograd_multiply(&a, &b);
        assert_allclose(got.as_slice(), want.as_slice(), 1e-10);
    }

    #[test]
    fn matches_gemm_odd_size() {
        let a = Mat::random(17, 17, 9);
        let b = Mat::random(17, 17, 10);
        let want = crate::blas::level3::dgemm_ref(&a, &b, &Mat::zeros(17, 17));
        let got = winograd_multiply(&a, &b);
        assert_allclose(got.as_slice(), want.as_slice(), 1e-10);
    }

    #[test]
    fn fewer_additions_than_strassen() {
        let (_, wmm_adds) = wmm_step_op_counts();
        let (_, smm_adds) = crate::blas::strassen::smm_step_op_counts();
        assert!(wmm_adds < smm_adds, "Table 3 vs Table 2: 15 < 18");
    }

    #[test]
    fn agrees_with_strassen() {
        let a = Mat::random(24, 24, 11);
        let b = Mat::random(24, 24, 12);
        let w = winograd_multiply(&a, &b);
        let s = crate::blas::strassen::strassen_multiply(&a, &b);
        assert_allclose(w.as_slice(), s.as_slice(), 1e-10);
    }
}
