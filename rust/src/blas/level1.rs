//! Level-1 BLAS: O(n) vector operations (§4.1 of the paper).

/// ddot: xᵀy.
pub fn ddot(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len(), "ddot length mismatch");
    x.iter().zip(y).map(|(a, b)| a * b).sum()
}

/// daxpy: y ← αx + y.
pub fn daxpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    assert_eq!(x.len(), y.len(), "daxpy length mismatch");
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// dnrm2: ‖x‖₂, with the scaled accumulation of the reference BLAS
/// (avoids overflow/underflow, Netlib DNRM2 algorithm).
pub fn dnrm2(x: &[f64]) -> f64 {
    let mut scale = 0.0f64;
    let mut ssq = 1.0f64;
    for &v in x {
        if v != 0.0 {
            let a = v.abs();
            if scale < a {
                ssq = 1.0 + ssq * (scale / a).powi(2);
                scale = a;
            } else {
                ssq += (a / scale).powi(2);
            }
        }
    }
    scale * ssq.sqrt()
}

/// dscal: x ← αx.
pub fn dscal(alpha: f64, x: &mut [f64]) {
    for v in x.iter_mut() {
        *v *= alpha;
    }
}

/// dcopy: y ← x.
pub fn dcopy(x: &[f64], y: &mut [f64]) {
    assert_eq!(x.len(), y.len());
    y.copy_from_slice(x);
}

/// dswap: x ↔ y.
pub fn dswap(x: &mut [f64], y: &mut [f64]) {
    assert_eq!(x.len(), y.len());
    for (a, b) in x.iter_mut().zip(y.iter_mut()) {
        std::mem::swap(a, b);
    }
}

/// dasum: Σ|xᵢ|.
pub fn dasum(x: &[f64]) -> f64 {
    x.iter().map(|v| v.abs()).sum()
}

/// idamax: index of the element with largest magnitude (0-based;
/// first such index on ties, as in the reference BLAS). Panics on empty.
pub fn idamax(x: &[f64]) -> usize {
    assert!(!x.is_empty(), "idamax of empty vector");
    let mut best = 0;
    let mut bestv = x[0].abs();
    for (i, &v) in x.iter().enumerate().skip(1) {
        if v.abs() > bestv {
            best = i;
            bestv = v.abs();
        }
    }
    best
}

/// drot: apply a plane (Givens) rotation: (x, y) ← (c·x + s·y, c·y − s·x).
pub fn drot(x: &mut [f64], y: &mut [f64], c: f64, s: f64) {
    assert_eq!(x.len(), y.len());
    for (a, b) in x.iter_mut().zip(y.iter_mut()) {
        let xa = *a;
        *a = c * xa + s * *b;
        *b = c * *b - s * xa;
    }
}

/// drotg: construct a Givens rotation annihilating b: returns (c, s, r).
pub fn drotg(a: f64, b: f64) -> (f64, f64, f64) {
    if b == 0.0 {
        return (1.0, 0.0, a);
    }
    let r = a.hypot(b);
    let r = if a.abs() > b.abs() && a < 0.0 || a.abs() <= b.abs() && b < 0.0 { -r } else { r };
    (a / r, b / r, r)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::XorShift64;

    #[test]
    fn ddot_basics() {
        assert_eq!(ddot(&[1., 2., 3.], &[4., 5., 6.]), 32.0);
        assert_eq!(ddot(&[], &[]), 0.0);
    }

    #[test]
    fn daxpy_basics() {
        let mut y = vec![1., 1.];
        daxpy(2.0, &[3., 4.], &mut y);
        assert_eq!(y, vec![7., 9.]);
    }

    #[test]
    fn dnrm2_matches_naive_in_normal_range() {
        let mut rng = XorShift64::new(5);
        let x = rng.vec(100);
        let naive = x.iter().map(|v| v * v).sum::<f64>().sqrt();
        assert!((dnrm2(&x) - naive).abs() < 1e-12);
    }

    #[test]
    fn dnrm2_avoids_overflow() {
        let x = vec![1e200, 1e200];
        assert!((dnrm2(&x) - 1e200 * 2f64.sqrt()).abs() / 1e200 < 1e-12);
        assert_eq!(dnrm2(&[0.0, 0.0]), 0.0);
    }

    #[test]
    fn dnrm2_avoids_underflow() {
        let x = vec![1e-200, 1e-200];
        assert!((dnrm2(&x) - 1e-200 * 2f64.sqrt()).abs() / 1e-200 < 1e-12);
    }

    #[test]
    fn dscal_dcopy_dswap() {
        let mut x = vec![1., 2.];
        dscal(3.0, &mut x);
        assert_eq!(x, vec![3., 6.]);
        let mut y = vec![0., 0.];
        dcopy(&x, &mut y);
        assert_eq!(y, x);
        let mut z = vec![9., 9.];
        dswap(&mut y, &mut z);
        assert_eq!(y, vec![9., 9.]);
        assert_eq!(z, vec![3., 6.]);
    }

    #[test]
    fn dasum_idamax() {
        assert_eq!(dasum(&[-1., 2., -3.]), 6.0);
        assert_eq!(idamax(&[-1., 2., -3.]), 2);
        assert_eq!(idamax(&[5., 5.]), 0); // first on ties
    }

    #[test]
    fn rotation_annihilates() {
        let (c, s, r) = drotg(3.0, 4.0);
        assert!((r.abs() - 5.0).abs() < 1e-12);
        let mut x = vec![3.0];
        let mut y = vec![4.0];
        drot(&mut x, &mut y, c, s);
        assert!((x[0] - r).abs() < 1e-12);
        assert!(y[0].abs() < 1e-12);
    }

    #[test]
    fn drotg_zero_b() {
        let (c, s, r) = drotg(7.0, 0.0);
        assert_eq!((c, s, r), (1.0, 0.0, 7.0));
    }
}
