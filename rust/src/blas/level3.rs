//! Level-3 BLAS: O(n³) matrix-matrix operations.
//!
//! Includes all six loop orderings of Table 1 (ijk/jik dot forms, ikj/jki
//! gaxpy forms, kij/kji outer-product forms), the 4×4-blocked DGEMM of the
//! paper's algorithm 3, and dtrsm/dsyrk used by the LAPACK-lite layer.

use crate::util::Mat;

/// The six GEMM loop orderings of Table 1.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LoopOrder {
    Ijk,
    Jik,
    Ikj,
    Jki,
    Kij,
    Kji,
}

impl LoopOrder {
    pub const ALL: [LoopOrder; 6] =
        [LoopOrder::Ijk, LoopOrder::Jik, LoopOrder::Ikj, LoopOrder::Jki, LoopOrder::Kij, LoopOrder::Kji];

    /// Inner-loop operation per Table 1 (dot vs saxpy).
    pub fn inner_kernel(self) -> &'static str {
        match self {
            LoopOrder::Ijk | LoopOrder::Jik => "dot",
            _ => "saxpy",
        }
    }
}

/// Reference DGEMM: C' = A·B + C (jki order — the reference BLAS favourite:
/// stride-1 over the column-major A and C).
pub fn dgemm_ref(a: &Mat, b: &Mat, c: &Mat) -> Mat {
    dgemm_order(a, b, c, LoopOrder::Jki)
}

/// DGEMM with an explicit loop ordering (Table 1). All orders produce the
/// same C — their difference is the memory access pattern, which the
/// platform models in [`crate::platforms`] consume.
pub fn dgemm_order(a: &Mat, b: &Mat, c: &Mat, order: LoopOrder) -> Mat {
    let (m, kk) = (a.rows(), a.cols());
    let n = b.cols();
    assert_eq!(b.rows(), kk, "inner dims");
    assert_eq!((c.rows(), c.cols()), (m, n), "C dims");
    let mut out = c.clone();
    match order {
        LoopOrder::Ijk => {
            for i in 0..m {
                for j in 0..n {
                    let mut s = out[(i, j)];
                    for k in 0..kk {
                        s += a[(i, k)] * b[(k, j)];
                    }
                    out[(i, j)] = s;
                }
            }
        }
        LoopOrder::Jik => {
            for j in 0..n {
                for i in 0..m {
                    let mut s = out[(i, j)];
                    for k in 0..kk {
                        s += a[(i, k)] * b[(k, j)];
                    }
                    out[(i, j)] = s;
                }
            }
        }
        LoopOrder::Ikj => {
            for i in 0..m {
                for k in 0..kk {
                    let aik = a[(i, k)];
                    for j in 0..n {
                        out[(i, j)] += aik * b[(k, j)];
                    }
                }
            }
        }
        LoopOrder::Jki => {
            for j in 0..n {
                for k in 0..kk {
                    let bkj = b[(k, j)];
                    for i in 0..m {
                        out[(i, j)] += a[(i, k)] * bkj;
                    }
                }
            }
        }
        LoopOrder::Kij => {
            for k in 0..kk {
                for i in 0..m {
                    let aik = a[(i, k)];
                    for j in 0..n {
                        out[(i, j)] += aik * b[(k, j)];
                    }
                }
            }
        }
        LoopOrder::Kji => {
            for k in 0..kk {
                for j in 0..n {
                    let bkj = b[(k, j)];
                    for i in 0..m {
                        out[(i, j)] += a[(i, k)] * bkj;
                    }
                }
            }
        }
    }
    out
}

/// Blocked DGEMM (algorithm 3 of the paper): 4×4 blocks with an unblocked
/// clean-up for sizes that are not multiples of the block.
pub fn dgemm_blocked(a: &Mat, b: &Mat, c: &Mat, block: usize) -> Mat {
    assert!(block > 0);
    let (m, kk) = (a.rows(), a.cols());
    let n = b.cols();
    assert_eq!(b.rows(), kk);
    assert_eq!((c.rows(), c.cols()), (m, n));
    let mut out = c.clone();
    for i0 in (0..m).step_by(block) {
        let ih = block.min(m - i0);
        for j0 in (0..n).step_by(block) {
            let jh = block.min(n - j0);
            for k0 in (0..kk).step_by(block) {
                let kh = block.min(kk - k0);
                // BLOCK4MUL + BLOCK4ADD of algorithm 3.
                for j in j0..j0 + jh {
                    for k in k0..k0 + kh {
                        let bkj = b[(k, j)];
                        for i in i0..i0 + ih {
                            out[(i, j)] += a[(i, k)] * bkj;
                        }
                    }
                }
            }
        }
    }
    out
}

/// dsyrk (lower): C ← α·A·Aᵀ + β·C, only the lower triangle updated.
pub fn dsyrk_lower(alpha: f64, a: &Mat, beta: f64, c: &mut Mat) {
    let n = a.rows();
    assert_eq!(c.rows(), n);
    assert_eq!(c.cols(), n);
    for j in 0..n {
        for i in j..n {
            let mut s = 0.0;
            for k in 0..a.cols() {
                s += a[(i, k)] * a[(j, k)];
            }
            c[(i, j)] = alpha * s + beta * c[(i, j)];
        }
    }
}

/// dtrsm (left, lower, non-unit): solve L·X = B in place (B overwritten
/// with X). Column-oriented forward substitution.
pub fn dtrsm_left_lower(l: &Mat, b: &mut Mat) {
    let n = l.rows();
    assert_eq!(l.cols(), n);
    assert_eq!(b.rows(), n);
    for j in 0..b.cols() {
        for i in 0..n {
            let mut s = b[(i, j)];
            for k in 0..i {
                s -= l[(i, k)] * b[(k, j)];
            }
            assert!(l[(i, i)] != 0.0, "singular L at {i}");
            b[(i, j)] = s / l[(i, i)];
        }
    }
}

/// dtrsm (right, upper, non-unit): solve X·U = B in place.
pub fn dtrsm_right_upper(u: &Mat, b: &mut Mat) {
    let n = u.rows();
    assert_eq!(u.cols(), n);
    assert_eq!(b.cols(), n);
    for i in 0..b.rows() {
        for j in 0..n {
            let mut s = b[(i, j)];
            for k in 0..j {
                s -= b[(i, k)] * u[(k, j)];
            }
            assert!(u[(j, j)] != 0.0, "singular U at {j}");
            b[(i, j)] = s / u[(j, j)];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{assert_allclose, Mat};

    #[test]
    fn all_loop_orders_agree() {
        let a = Mat::random(9, 7, 1);
        let b = Mat::random(7, 5, 2);
        let c = Mat::random(9, 5, 3);
        let want = dgemm_order(&a, &b, &c, LoopOrder::Ijk);
        for order in LoopOrder::ALL {
            let got = dgemm_order(&a, &b, &c, order);
            assert_allclose(got.as_slice(), want.as_slice(), 1e-13);
        }
    }

    #[test]
    fn table1_inner_kernels() {
        assert_eq!(LoopOrder::Ijk.inner_kernel(), "dot");
        assert_eq!(LoopOrder::Jik.inner_kernel(), "dot");
        for o in [LoopOrder::Ikj, LoopOrder::Jki, LoopOrder::Kij, LoopOrder::Kji] {
            assert_eq!(o.inner_kernel(), "saxpy");
        }
    }

    #[test]
    fn blocked_matches_reference_various_blocks() {
        let a = Mat::random(13, 11, 4);
        let b = Mat::random(11, 9, 5);
        let c = Mat::random(13, 9, 6);
        let want = dgemm_ref(&a, &b, &c);
        for block in [1, 2, 4, 5, 16] {
            let got = dgemm_blocked(&a, &b, &c, block);
            assert_allclose(got.as_slice(), want.as_slice(), 1e-13);
        }
    }

    #[test]
    fn gemm_identity() {
        let a = Mat::random(6, 6, 7);
        let got = dgemm_ref(&a, &Mat::eye(6), &Mat::zeros(6, 6));
        assert_allclose(got.as_slice(), a.as_slice(), 0.0);
    }

    #[test]
    fn dsyrk_matches_explicit() {
        let a = Mat::random(6, 4, 8);
        let mut c = Mat::zeros(6, 6);
        dsyrk_lower(1.0, &a, 0.0, &mut c);
        for i in 0..6 {
            for j in 0..=i {
                let mut want = 0.0;
                for k in 0..4 {
                    want += a[(i, k)] * a[(j, k)];
                }
                assert!((c[(i, j)] - want).abs() < 1e-13);
            }
        }
    }

    #[test]
    fn trsm_left_lower_solves() {
        let n = 6;
        let mut l = Mat::random(n, n, 9);
        for i in 0..n {
            for j in i + 1..n {
                l[(i, j)] = 0.0;
            }
            l[(i, i)] = 3.0 + l[(i, i)].abs();
        }
        let x0 = Mat::random(n, 3, 10);
        // B = L·X0
        let b = dgemm_ref(&l, &x0, &Mat::zeros(n, 3));
        let mut x = b.clone();
        dtrsm_left_lower(&l, &mut x);
        assert_allclose(x.as_slice(), x0.as_slice(), 1e-11);
    }

    #[test]
    fn trsm_right_upper_solves() {
        let n = 5;
        let mut u = Mat::random(n, n, 11);
        for i in 0..n {
            for j in 0..i {
                u[(i, j)] = 0.0;
            }
            u[(i, i)] = 3.0 + u[(i, i)].abs();
        }
        let x0 = Mat::random(4, n, 12);
        let b = dgemm_ref(&x0, &u, &Mat::zeros(4, n));
        let mut x = b.clone();
        dtrsm_right_upper(&u, &mut x);
        assert_allclose(x.as_slice(), x0.as_slice(), 1e-11);
    }
}
