//! Strassen's Matrix Multiplication (SMM) — the §4.3.2 baseline.
//!
//! Implements the recursion of Table 2 (T1–T9, M1–M7, K1–K4) with zero
//! padding to the next power of two for odd sizes — exactly the scheme the
//! paper discusses (and rejects for the PE) in §4.3.4: 7 block multiplies,
//! 18 block additions per recursion level, O(n^2.81) asymptotically.

use crate::util::Mat;

/// Recursion cut-off: below this the multiplication falls back to GEMM.
const CUTOFF: usize = 8;

/// Multiply C = A·B with Strassen's algorithm (square matrices).
pub fn strassen_multiply(a: &Mat, b: &Mat) -> Mat {
    let n = a.rows();
    assert_eq!(a.cols(), n, "SMM needs square A");
    assert_eq!(b.rows(), n, "dims");
    assert_eq!(b.cols(), n, "SMM needs square B");
    if n == 0 {
        return Mat::zeros(0, 0);
    }
    // Zero-pad to the next power of two (§4.3.4 discussion).
    let p = n.next_power_of_two();
    if p != n {
        let c = strassen_rec(&a.padded(p, p), &b.padded(p, p));
        return c.block(0, 0, n, n);
    }
    strassen_rec(a, b)
}

fn strassen_rec(a: &Mat, b: &Mat) -> Mat {
    let n = a.rows();
    if n <= CUTOFF {
        return crate::blas::level3::dgemm_ref(a, b, &Mat::zeros(n, n));
    }
    let h = n / 2;
    let (a11, a12, a21, a22) =
        (a.block(0, 0, h, h), a.block(0, h, h, h), a.block(h, 0, h, h), a.block(h, h, h, h));
    let (b11, b12, b21, b22) =
        (b.block(0, 0, h, h), b.block(0, h, h, h), b.block(h, 0, h, h), b.block(h, h, h, h));

    // Level 1 of Table 2: the T additions.
    let t1 = add(&a11, &a22);
    let t2 = add(&b11, &b22);
    let t3 = sub(&b12, &b22);
    let t4 = sub(&b21, &b11);
    let t5 = add(&a11, &a12);
    let t6 = sub(&a21, &a11);
    let t7 = add(&b11, &b12);
    let t8 = sub(&a12, &a22);
    let t9 = add(&b21, &b22);

    // Level 2: the seven recursive multiplies M1–M7 (Table 2).
    let m1 = strassen_rec(&t1, &t2);
    let m2 = strassen_rec(&add(&a21, &a22), &b11);
    let m3 = strassen_rec(&a11, &t3);
    let m4 = strassen_rec(&a22, &t4);
    let m5 = strassen_rec(&t5, &b22);
    let m6 = strassen_rec(&t6, &t7);
    let m7 = strassen_rec(&t8, &t9);

    // Levels 3–4: K combinations and the C blocks.
    let k1 = add(&m1, &m4); // M1 + M4
    let k2 = sub(&m5, &m7); // M5 - M7
    let c11 = sub(&k1, &k2); // M1 + M4 - M5 + M7
    let c12 = add(&m3, &m5);
    let c21 = add(&m2, &m4);
    let k3 = sub(&m1, &m2); // M1 - M2
    let k4 = add(&m3, &m6); // M3 + M6
    let c22 = add(&k3, &k4);

    let mut c = Mat::zeros(n, n);
    c.set_block(0, 0, &c11);
    c.set_block(0, h, &c12);
    c.set_block(h, 0, &c21);
    c.set_block(h, h, &c22);
    c
}

fn add(a: &Mat, b: &Mat) -> Mat {
    let mut c = a.clone();
    for (ci, bi) in c.as_mut_slice().iter_mut().zip(b.as_slice()) {
        *ci += bi;
    }
    c
}

fn sub(a: &Mat, b: &Mat) -> Mat {
    let mut c = a.clone();
    for (ci, bi) in c.as_mut_slice().iter_mut().zip(b.as_slice()) {
        *ci -= bi;
    }
    c
}

/// Operation counts of one Strassen recursion step on 2×2 blocks:
/// (block multiplies, block additions) — Table 2: 7 and 18.
pub fn smm_step_op_counts() -> (usize, usize) {
    (7, 18)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::assert_allclose;

    #[test]
    fn matches_gemm_power_of_two() {
        let a = Mat::random(32, 32, 1);
        let b = Mat::random(32, 32, 2);
        let want = crate::blas::level3::dgemm_ref(&a, &b, &Mat::zeros(32, 32));
        let got = strassen_multiply(&a, &b);
        assert_allclose(got.as_slice(), want.as_slice(), 1e-10);
    }

    #[test]
    fn matches_gemm_odd_size_via_padding() {
        let a = Mat::random(23, 23, 3);
        let b = Mat::random(23, 23, 4);
        let want = crate::blas::level3::dgemm_ref(&a, &b, &Mat::zeros(23, 23));
        let got = strassen_multiply(&a, &b);
        assert_allclose(got.as_slice(), want.as_slice(), 1e-10);
    }

    #[test]
    fn small_sizes_fall_back() {
        let a = Mat::random(4, 4, 5);
        let b = Mat::random(4, 4, 6);
        let want = crate::blas::level3::dgemm_ref(&a, &b, &Mat::zeros(4, 4));
        assert_allclose(strassen_multiply(&a, &b).as_slice(), want.as_slice(), 1e-12);
    }

    #[test]
    fn table2_op_counts() {
        assert_eq!(smm_step_op_counts(), (7, 18));
    }
}
