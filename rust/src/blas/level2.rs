//! Level-2 BLAS: O(n²) matrix-vector operations (§4.2 of the paper).

use crate::util::Mat;

/// dgemv (reference): y' = A·x + y, returned as a new vector.
pub fn dgemv_ref(a: &Mat, x: &[f64], y: &[f64]) -> Vec<f64> {
    assert_eq!(a.cols(), x.len(), "dgemv dims");
    assert_eq!(a.rows(), y.len(), "dgemv dims");
    let mut out = y.to_vec();
    // Column-sweep (jki saxpy form — the reference BLAS access pattern,
    // stride-1 over the column-major A).
    for j in 0..a.cols() {
        let xj = x[j];
        let col = a.col(j);
        for i in 0..a.rows() {
            out[i] += col[i] * xj;
        }
    }
    out
}

/// dgemv, transposed: y' = Aᵀ·x + y.
pub fn dgemv_t(a: &Mat, x: &[f64], y: &[f64]) -> Vec<f64> {
    assert_eq!(a.rows(), x.len(), "dgemv^T dims");
    assert_eq!(a.cols(), y.len(), "dgemv^T dims");
    let mut out = y.to_vec();
    for j in 0..a.cols() {
        out[j] += crate::blas::level1::ddot(a.col(j), x);
    }
    out
}

/// dger: A ← A + α·x·yᵀ (rank-1 update).
pub fn dger(a: &mut Mat, alpha: f64, x: &[f64], y: &[f64]) {
    assert_eq!(a.rows(), x.len(), "dger dims");
    assert_eq!(a.cols(), y.len(), "dger dims");
    for j in 0..a.cols() {
        let ayj = alpha * y[j];
        let col = a.col_mut(j);
        for i in 0..col.len() {
            col[i] += x[i] * ayj;
        }
    }
}

/// dtrmv (lower, non-unit): x ← L·x.
pub fn dtrmv_lower(l: &Mat, x: &mut [f64]) {
    let n = l.rows();
    assert_eq!(l.cols(), n);
    assert_eq!(x.len(), n);
    // Walk bottom-up so untouched x entries are still the inputs.
    for i in (0..n).rev() {
        let mut s = 0.0;
        for k in 0..=i {
            s += l[(i, k)] * x[k];
        }
        x[i] = s;
    }
}

/// dtrsv (lower, non-unit): solve L·z = b in place (x holds b on entry,
/// z on exit). Forward substitution.
pub fn dtrsv_lower(l: &Mat, x: &mut [f64]) {
    let n = l.rows();
    assert_eq!(l.cols(), n);
    assert_eq!(x.len(), n);
    for i in 0..n {
        let mut s = x[i];
        for k in 0..i {
            s -= l[(i, k)] * x[k];
        }
        assert!(l[(i, i)] != 0.0, "singular triangular matrix at {i}");
        x[i] = s / l[(i, i)];
    }
}

/// dsymv: y' = A·x + y for symmetric A (only the lower triangle is read).
pub fn dsymv_lower(a: &Mat, x: &[f64], y: &[f64]) -> Vec<f64> {
    let n = a.rows();
    assert_eq!(a.cols(), n);
    let mut out = y.to_vec();
    for i in 0..n {
        let mut s = 0.0;
        for k in 0..n {
            let v = if k <= i { a[(i, k)] } else { a[(k, i)] };
            s += v * x[k];
        }
        out[i] += s;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{assert_allclose, Mat, XorShift64};

    #[test]
    fn dgemv_identity() {
        let a = Mat::eye(3);
        let y = dgemv_ref(&a, &[1., 2., 3.], &[10., 10., 10.]);
        assert_allclose(&y, &[11., 12., 13.], 0.0);
    }

    #[test]
    fn dgemv_matches_naive() {
        let a = Mat::random(7, 5, 3);
        let mut rng = XorShift64::new(4);
        let x = rng.vec(5);
        let y = rng.vec(7);
        let got = dgemv_ref(&a, &x, &y);
        let mut want = y.clone();
        for i in 0..7 {
            for k in 0..5 {
                want[i] += a[(i, k)] * x[k];
            }
        }
        assert_allclose(&got, &want, 1e-14);
    }

    #[test]
    fn dgemv_t_matches_transpose() {
        let a = Mat::random(6, 6, 9);
        let mut rng = XorShift64::new(10);
        let x = rng.vec(6);
        let y = rng.vec(6);
        let got = dgemv_t(&a, &x, &y);
        let want = dgemv_ref(&a.transpose(), &x, &y);
        assert_allclose(&got, &want, 1e-13);
    }

    #[test]
    fn dger_rank1() {
        let mut a = Mat::zeros(2, 3);
        dger(&mut a, 2.0, &[1., 2.], &[3., 4., 5.]);
        assert_eq!(a[(1, 2)], 2.0 * 2.0 * 5.0);
        assert_eq!(a[(0, 0)], 6.0);
    }

    #[test]
    fn trsv_inverts_trmv() {
        let n = 8;
        let mut l = Mat::random(n, n, 21);
        for i in 0..n {
            for j in i + 1..n {
                l[(i, j)] = 0.0;
            }
            l[(i, i)] = 2.0 + l[(i, i)].abs(); // well-conditioned diagonal
        }
        let mut rng = XorShift64::new(22);
        let x0 = rng.vec(n);
        let mut x = x0.clone();
        dtrmv_lower(&l, &mut x);
        dtrsv_lower(&l, &mut x);
        assert_allclose(&x, &x0, 1e-12);
    }

    #[test]
    fn dsymv_uses_lower_triangle() {
        let a = Mat::random_spd(5, 2);
        let mut rng = XorShift64::new(23);
        let x = rng.vec(5);
        let y = vec![0.0; 5];
        let got = dsymv_lower(&a, &x, &y);
        let want = dgemv_ref(&a, &x, &y);
        assert_allclose(&got, &want, 1e-12);
    }
}
