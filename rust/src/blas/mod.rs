//! Host reference BLAS — the numerical substrate and oracle.
//!
//! The paper builds on Netlib BLAS semantics; this module provides clean
//! Rust implementations of the routines the paper analyses (§3–§4):
//! Level-1 (ddot, daxpy, dnrm2, dscal, dcopy, dswap, dasum, idamax, drot),
//! Level-2 (dgemv, dger, dtrmv, dtrsv), Level-3 (dgemm in all six loop
//! orders of Table 1, blocked dgemm per algorithm 3, dtrsm, dsyrk), and the
//! Strassen/Winograd baselines of §4.3 (Tables 2–3).
//!
//! These are correctness references for the PE codegen, the XLA artifacts,
//! and the platform models — written for clarity, not host speed (the hot
//! path of this project is the simulator, not host BLAS).

pub mod level1;
pub mod level2;
pub mod level3;
pub mod strassen;
pub mod winograd;

pub use level1::*;
pub use level2::{dgemv_ref, dger, dtrmv_lower, dtrsv_lower};
pub use level3::{dgemm_blocked, dgemm_ref, LoopOrder};
pub use strassen::strassen_multiply;
pub use winograd::winograd_multiply;
