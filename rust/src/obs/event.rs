//! Typed trace events and per-request span reconstruction.
//!
//! Every stage of the serving path emits one [`Event`] per observable
//! transition — admission, shedding, cache traffic, dispatch, execution
//! tier, fabric routing, completion — all tagged with the request's
//! [`ReqId`] (the pipeline's dense job id) and carrying **dual
//! timestamps**: a simulated-cycle anchor (`sim`, deterministic run to
//! run) and an optional host-nanosecond stamp (`host_ns`, present only
//! when the sink opted into the host clock, never deterministic).
//!
//! All events are emitted from the coordinator's dispatcher thread —
//! admission, staging and finalization run there in strict submission
//! order — so the emission order of a closed-loop (`serve_batch`) run is
//! deterministic run to run. Worker-side truth (which execution tier ran
//! a tile) travels back inside `Done` messages and is re-emitted at
//! finalize time, sorted by tile index, to keep the log independent of
//! host worker interleaving. [`Event::sim_signature`] renders exactly the
//! run-deterministic fields; the `tests/obs.rs` suite pins two identically
//! seeded runs to identical signature sequences.

use crate::coordinator::ShedReason;
use crate::noc::Coord;

/// Per-request trace id: the serving pipeline's dense job id (`u64`),
/// assigned at admission and threaded through `Job`/`Done`/`RoutedJob`.
pub type ReqId = u64;

/// The id of events that precede id assignment (a shed arrival never
/// enters the pipeline) or of untraced solo work. Matches the pipeline's
/// reserved solo job id, so solo blocking calls are naturally untagged.
pub const NO_REQ: ReqId = u64::MAX;

/// Which execution tier ran a kernel on a pool worker (see the PR 3/6
/// two-tier split): value-only replay, operand-batched replay, or the
/// full combined interpreter (cold kernels and `ExecMode::Combined`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Tier {
    /// Tier-2 value replay of a memoized schedule (`Pe::replay`).
    Replay,
    /// Tier-2b operand-batched replay (`pe::replay_batch` member).
    Batched,
    /// Combined functional+timing interpreter (first-touch or forced).
    Combined,
}

impl Tier {
    /// Stable lowercase name (used by the exporters).
    pub fn name(&self) -> &'static str {
        match self {
            Tier::Replay => "replay",
            Tier::Batched => "batched",
            Tier::Combined => "combined",
        }
    }
}

/// One typed trace event.
#[derive(Debug, Clone, PartialEq)]
pub struct Event {
    /// The request this event belongs to ([`NO_REQ`] for shed arrivals).
    pub req: ReqId,
    /// Simulated-cycle anchor: the fabric departure cycle for routed jobs,
    /// the response's completion cycles for `Completed`, 0 where no
    /// simulated clock applies. Deterministic run to run.
    pub sim: u64,
    /// Host wall-clock nanoseconds since the sink's epoch, when the sink
    /// runs with the host clock enabled. Never deterministic; excluded
    /// from [`Event::sim_signature`].
    pub host_ns: Option<u64>,
    /// What happened.
    pub kind: EventKind,
}

/// Event payloads, one variant per observable serving transition.
#[derive(Debug, Clone, PartialEq)]
pub enum EventKind {
    /// The request entered the pipeline (operands about to be staged).
    Admitted {
        /// Submission-order sequence number within the serve call.
        seq: usize,
        /// Routine name (`"dgemm"`, `"ddot"`, …).
        op: &'static str,
        /// Problem size.
        n: usize,
        /// Packed-GM admission price of the request, in bytes.
        bytes: u64,
    },
    /// An open-loop arrival was rejected before admission.
    Shed {
        /// Arrival sequence number (the would-be outcome seq).
        seq: usize,
        /// Which backpressure rule rejected it.
        reason: ShedReason,
    },
    /// Staging this request hit a warm program-cache entry.
    CacheHit,
    /// Staging this request missed the program cache (kernel emitted).
    CacheMiss,
    /// Staging this request evicted a resident kernel.
    CacheEvicted,
    /// A pool job for this request entered the shared worker queue.
    Dispatched {
        /// The tenant's scheduler lane.
        lane: usize,
        /// Estimated simulated-cycle cost at submission (repriced at
        /// dispatch; excluded from the deterministic signature because a
        /// cold kernel's estimate depends on the timing-pass race).
        cost: u64,
    },
    /// A pool worker finished a kernel for this request.
    Executed {
        /// Which execution tier ran it.
        tier: Tier,
    },
    /// A finalized job was placed and priced on the modeled fabric.
    FabricRouted {
        /// The compute tile the job ran on.
        tile: Coord,
        /// Absolute fabric cycle the operand stream departed.
        depart: u64,
        /// Cycle the operands finished arriving (compute starts).
        ready: u64,
        /// Cycle the result landed in the home memory region.
        finish: u64,
        /// Pure PE compute cycles within `[ready, finish]`.
        compute: u64,
    },
    /// A factorization DAG node became ready: all its predecessors had
    /// completed. Emitted at finalize time on the deterministic topological
    /// schedule (`sim` = the node's earliest-start cycle), so the log shows
    /// the DAG's dependency structure and critical path independent of
    /// worker interleaving.
    NodeReleased {
        /// Node index within the factorization's kernel graph.
        node: usize,
        /// Kernel class tag (`"gemm"`, `"gemv"`, `"ddot"`, …).
        call: &'static str,
        /// Kernel problem size (largest dimension).
        n: usize,
    },
    /// A factorization DAG node's kernel completed (`sim` = its finish
    /// cycle on the topological schedule).
    NodeCompleted {
        /// Node index within the factorization's kernel graph.
        node: usize,
        /// The node kernel's simulated cycles.
        cycles: u64,
    },
    /// The response was finalized and handed back.
    Completed {
        /// Host nanoseconds spent queued (arrival → admission); 0 in
        /// closed-loop serving, which admits on demand.
        queue_ns: u64,
        /// Host nanoseconds from admission to completion; 0 in
        /// closed-loop serving.
        service_ns: u64,
        /// The response's simulated cost (fabric completion time under a
        /// fabric, PE makespan otherwise).
        cycles: u64,
    },
}

impl EventKind {
    /// Stable lowercase tag (the `ev` key of the JSONL schema).
    pub fn tag(&self) -> &'static str {
        match self {
            EventKind::Admitted { .. } => "admitted",
            EventKind::Shed { .. } => "shed",
            EventKind::CacheHit => "cache_hit",
            EventKind::CacheMiss => "cache_miss",
            EventKind::CacheEvicted => "cache_evicted",
            EventKind::Dispatched { .. } => "dispatched",
            EventKind::Executed { .. } => "executed",
            EventKind::FabricRouted { .. } => "fabric_routed",
            EventKind::NodeReleased { .. } => "node_released",
            EventKind::NodeCompleted { .. } => "node_completed",
            EventKind::Completed { .. } => "completed",
        }
    }
}

impl Event {
    /// Render exactly the run-deterministic fields of this event: request
    /// id, simulated-cycle anchor, and the payload minus host-derived
    /// values (`host_ns`, queue/service latencies) and minus the
    /// dispatch-cost estimate (racy for cold kernels). Two identically
    /// seeded closed-loop runs produce identical signature sequences —
    /// pinned by `tests/obs.rs`.
    pub fn sim_signature(&self) -> String {
        let body = match &self.kind {
            EventKind::Admitted { seq, op, n, bytes } => {
                format!("admitted seq={seq} op={op} n={n} bytes={bytes}")
            }
            EventKind::Shed { seq, reason } => format!("shed seq={seq} reason={reason:?}"),
            EventKind::CacheHit => "cache_hit".into(),
            EventKind::CacheMiss => "cache_miss".into(),
            EventKind::CacheEvicted => "cache_evicted".into(),
            EventKind::Dispatched { lane, .. } => format!("dispatched lane={lane}"),
            EventKind::Executed { tier } => format!("executed tier={}", tier.name()),
            EventKind::FabricRouted { tile, depart, ready, finish, compute } => format!(
                "fabric_routed tile={},{} depart={depart} ready={ready} finish={finish} \
                 compute={compute}",
                tile.row, tile.col
            ),
            EventKind::NodeReleased { node, call, n } => {
                format!("node_released node={node} call={call} n={n}")
            }
            EventKind::NodeCompleted { node, cycles } => {
                format!("node_completed node={node} cycles={cycles}")
            }
            EventKind::Completed { cycles, .. } => format!("completed cycles={cycles}"),
        };
        format!("req={} sim={} {}", self.req, self.sim, body)
    }
}

/// One request's lifecycle, reconstructed from its events: queue/service
/// wall time, simulated compute-vs-communication split, cache traffic and
/// execution tiers. Built by [`response_traces`].
#[derive(Debug, Clone, PartialEq)]
pub struct ResponseTrace {
    /// The request id the events were grouped by.
    pub req: ReqId,
    /// Submission sequence number (from `Admitted`, when present).
    pub seq: Option<usize>,
    /// Routine name (from `Admitted`).
    pub op: Option<&'static str>,
    /// Problem size (from `Admitted`).
    pub n: usize,
    /// Packed-GM admission price, bytes (from `Admitted`).
    pub bytes: u64,
    /// Host ns queued before admission (0 in closed-loop serving).
    pub queue_ns: u64,
    /// Host ns from admission to completion (0 in closed-loop serving).
    pub service_ns: u64,
    /// `queue_ns + service_ns` — must equal the open-loop outcome's total
    /// latency (pinned by `tests/obs.rs`).
    pub total_ns: u64,
    /// The response's simulated cost (from `Completed`).
    pub cycles: u64,
    /// Pure PE compute cycles: the sum over routed jobs on a fabric, the
    /// response cycles themselves off-fabric (where delivery is free).
    pub compute_cycles: u64,
    /// Communication cycles: Σ over routed jobs of
    /// `(finish − depart) − compute`. 0 off-fabric.
    pub comm_cycles: u64,
    /// Cache hits / misses / evictions charged to staging this request.
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub cache_evictions: u64,
    /// Pool jobs dispatched / kernel executions observed.
    pub dispatched: usize,
    /// Execution tiers, in tile order.
    pub tiers: Vec<Tier>,
    /// Factorization DAG nodes completed (0 for flat BLAS requests).
    pub nodes: usize,
    /// Whether a `Completed` event was seen.
    pub completed: bool,
}

impl ResponseTrace {
    fn new(req: ReqId) -> Self {
        Self {
            req,
            seq: None,
            op: None,
            n: 0,
            bytes: 0,
            queue_ns: 0,
            service_ns: 0,
            total_ns: 0,
            cycles: 0,
            compute_cycles: 0,
            comm_cycles: 0,
            cache_hits: 0,
            cache_misses: 0,
            cache_evictions: 0,
            dispatched: 0,
            tiers: Vec::new(),
            nodes: 0,
            completed: false,
        }
    }
}

/// Group a flat event log into per-request spans, in first-seen request
/// order. Shed events ([`NO_REQ`]) are skipped — they never became
/// requests; count them directly from the log instead.
pub fn response_traces(events: &[Event]) -> Vec<ResponseTrace> {
    let mut order: Vec<ReqId> = Vec::new();
    let mut traces: std::collections::HashMap<ReqId, ResponseTrace> =
        std::collections::HashMap::new();
    let mut routed_compute: std::collections::HashMap<ReqId, u64> =
        std::collections::HashMap::new();
    for ev in events {
        if ev.req == NO_REQ {
            continue;
        }
        let t = traces.entry(ev.req).or_insert_with(|| {
            order.push(ev.req);
            ResponseTrace::new(ev.req)
        });
        match &ev.kind {
            EventKind::Admitted { seq, op, n, bytes } => {
                t.seq = Some(*seq);
                t.op = Some(*op);
                t.n = *n;
                t.bytes = *bytes;
            }
            EventKind::Shed { .. } => {}
            EventKind::CacheHit => t.cache_hits += 1,
            EventKind::CacheMiss => t.cache_misses += 1,
            EventKind::CacheEvicted => t.cache_evictions += 1,
            EventKind::Dispatched { .. } => t.dispatched += 1,
            EventKind::Executed { tier } => t.tiers.push(*tier),
            EventKind::FabricRouted { depart, finish, compute, .. } => {
                t.compute_cycles += compute;
                t.comm_cycles += (finish - depart).saturating_sub(*compute);
                *routed_compute.entry(ev.req).or_insert(0) += compute;
            }
            EventKind::NodeReleased { .. } => {}
            EventKind::NodeCompleted { .. } => t.nodes += 1,
            EventKind::Completed { queue_ns, service_ns, cycles } => {
                t.queue_ns = *queue_ns;
                t.service_ns = *service_ns;
                t.total_ns = queue_ns + service_ns;
                t.cycles = *cycles;
                t.completed = true;
            }
        }
    }
    let mut out = Vec::with_capacity(order.len());
    for req in order {
        let mut t = traces.remove(&req).expect("trace grouped above");
        // Off-fabric there are no routed jobs: operand delivery is free,
        // so the whole simulated cost is compute.
        if !routed_compute.contains_key(&req) {
            t.compute_cycles = t.cycles;
            t.comm_cycles = 0;
        }
        out.push(t);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(req: ReqId, kind: EventKind) -> Event {
        Event { req, sim: 0, host_ns: None, kind }
    }

    #[test]
    fn traces_group_by_request_in_first_seen_order() {
        let log = vec![
            ev(7, EventKind::Admitted { seq: 0, op: "dgemm", n: 16, bytes: 1024 }),
            ev(9, EventKind::Admitted { seq: 1, op: "ddot", n: 32, bytes: 512 }),
            ev(7, EventKind::CacheMiss),
            ev(7, EventKind::Dispatched { lane: 0, cost: 10 }),
            ev(9, EventKind::CacheHit),
            ev(7, EventKind::Executed { tier: Tier::Combined }),
            ev(7, EventKind::Completed { queue_ns: 0, service_ns: 0, cycles: 400 }),
            ev(9, EventKind::Completed { queue_ns: 5, service_ns: 7, cycles: 90 }),
        ];
        let traces = response_traces(&log);
        assert_eq!(traces.len(), 2);
        assert_eq!(traces[0].req, 7);
        assert_eq!(traces[0].op, Some("dgemm"));
        assert_eq!(traces[0].cache_misses, 1);
        assert_eq!(traces[0].dispatched, 1);
        assert_eq!(traces[0].tiers, vec![Tier::Combined]);
        assert!(traces[0].completed);
        // Off-fabric: all simulated cost is compute.
        assert_eq!((traces[0].compute_cycles, traces[0].comm_cycles), (400, 0));
        assert_eq!(traces[1].req, 9);
        assert_eq!(traces[1].total_ns, 12);
        assert_eq!(traces[1].queue_ns + traces[1].service_ns, traces[1].total_ns);
    }

    #[test]
    fn fabric_events_split_compute_from_comm() {
        let log = vec![
            ev(3, EventKind::Admitted { seq: 0, op: "dgemm", n: 16, bytes: 1024 }),
            Event {
                req: 3,
                sim: 100,
                host_ns: None,
                kind: EventKind::FabricRouted {
                    tile: Coord::new(0, 1),
                    depart: 100,
                    ready: 140,
                    finish: 400,
                    compute: 200,
                },
            },
            Event {
                req: 3,
                sim: 500,
                host_ns: None,
                kind: EventKind::FabricRouted {
                    tile: Coord::new(1, 0),
                    depart: 500,
                    ready: 520,
                    finish: 800,
                    compute: 250,
                },
            },
            ev(3, EventKind::Completed { queue_ns: 0, service_ns: 0, cycles: 800 }),
        ];
        let t = &response_traces(&log)[0];
        assert_eq!(t.compute_cycles, 450);
        // (400-100-200) + (800-500-250) = 100 + 50.
        assert_eq!(t.comm_cycles, 150);
        assert_eq!(t.cycles, 800);
    }

    #[test]
    fn shed_events_are_not_requests() {
        let log = vec![Event {
            req: NO_REQ,
            sim: 0,
            host_ns: None,
            kind: EventKind::Shed { seq: 4, reason: ShedReason::QueueDepth },
        }];
        assert!(response_traces(&log).is_empty());
    }

    #[test]
    fn node_events_count_into_traces() {
        let log = vec![
            ev(5, EventKind::Admitted { seq: 0, op: "dgeqrf", n: 12, bytes: 1152 }),
            Event {
                req: 5,
                sim: 0,
                host_ns: None,
                kind: EventKind::NodeReleased { node: 0, call: "gemv", n: 12 },
            },
            Event {
                req: 5,
                sim: 40,
                host_ns: None,
                kind: EventKind::NodeCompleted { node: 0, cycles: 40 },
            },
            Event {
                req: 5,
                sim: 40,
                host_ns: None,
                kind: EventKind::NodeReleased { node: 1, call: "gemm", n: 12 },
            },
            Event {
                req: 5,
                sim: 90,
                host_ns: None,
                kind: EventKind::NodeCompleted { node: 1, cycles: 50 },
            },
            ev(5, EventKind::Completed { queue_ns: 0, service_ns: 0, cycles: 90 }),
        ];
        let t = &response_traces(&log)[0];
        assert_eq!(t.nodes, 2);
        assert_eq!(t.cycles, 90);
        // The successor's release anchor never precedes its predecessor's
        // completion anchor on the topological schedule.
        assert!(log[3].sim >= log[2].sim);
        assert!(log[1].sim_signature().contains("call=gemv"));
        assert_eq!(log[4].kind.tag(), "node_completed");
    }

    #[test]
    fn sim_signature_excludes_host_and_racy_fields() {
        let a = Event {
            req: 1,
            sim: 9,
            host_ns: Some(123),
            kind: EventKind::Dispatched { lane: 2, cost: 777 },
        };
        let b = Event {
            req: 1,
            sim: 9,
            host_ns: Some(999_999),
            kind: EventKind::Dispatched { lane: 2, cost: 1 },
        };
        assert_eq!(a.sim_signature(), b.sim_signature());
        assert!(a.sim_signature().contains("lane=2"));
        assert!(!a.sim_signature().contains("777"));
    }
}
