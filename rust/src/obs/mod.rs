//! Observability: typed per-request tracing, span reconstruction, unified
//! metric snapshots, and trace export.
//!
//! The paper's co-design argument rests on *attribution* — knowing where
//! cycles go (compute vs. communication vs. stalls) is what justified the
//! PE enhancements and the fabric scaling claims. This module gives the
//! serving stack the same property end to end:
//!
//! * [`event`] — the typed event vocabulary ([`Event`] / [`EventKind`]):
//!   admission, shedding, cache traffic, dispatch, execution tier, fabric
//!   routing, completion, each tagged with a per-request [`ReqId`] and
//!   dual (simulated-cycle + optional host-ns) timestamps, plus
//!   [`response_traces`] to fold a log into per-request
//!   [`ResponseTrace`] spans (queue wait / service / compute vs. comm);
//! * [`sink`] — where events go ([`TraceSink`]): with no sink configured
//!   events are never constructed and serving is bit-identical to the
//!   untraced path (pinned by `tests/obs.rs`); [`BufferSink`] collects
//!   in memory for export;
//! * [`registry`] — counters, gauges, rolling windowed latency histograms
//!   ([`WindowedHistogram`], the long-lived-daemon prerequisite), and the
//!   [`EngineSnapshot`] / [`TenantSnapshot`] structs behind
//!   [`crate::engine::Engine::snapshot`] and
//!   [`crate::coordinator::Coordinator::snapshot`];
//! * [`export`] — [`to_jsonl`] (JSON Lines, `serve --trace-out`) and
//!   [`to_chrome`] (Chrome trace-event JSON for Perfetto,
//!   `--trace-format chrome`).
//!
//! Wiring: attach a sink with
//! [`crate::coordinator::Coordinator::set_trace_sink`], serve, then drain
//! the sink and export.

pub mod event;
pub mod export;
pub mod registry;
pub mod sink;

pub use event::{response_traces, Event, EventKind, ReqId, ResponseTrace, Tier, NO_REQ};
pub use export::{to_chrome, to_jsonl};
pub use registry::{
    Counter, EngineSnapshot, Gauge, RollingLatency, RollingSnapshot, TenantSnapshot,
    WindowedHistogram,
};
pub use sink::{BufferSink, NullSink, TraceSink};
