//! Trace sinks: where emitted events go.
//!
//! The serving path holds an `Option<Arc<dyn TraceSink>>` and emits
//! through a closure-taking helper, so with **no sink configured the
//! event is never even constructed** — the traced and untraced code paths
//! are bit-identical (pinned by `tests/obs.rs` and the `hot_paths`
//! `obs.off_overhead_x` gate). [`NullSink`] exists for the pathological
//! middle ground (sink attached, events discarded); [`BufferSink`] is the
//! production collector behind `--trace-out`.

use super::event::Event;
use std::sync::Mutex;
use std::time::Instant;

/// A destination for trace events. Implementations must be cheap and
/// non-blocking from the caller's perspective — `emit` runs on the
/// serving dispatcher thread.
pub trait TraceSink: Send + Sync {
    /// Consume one event.
    fn emit(&self, ev: Event);
}

/// Discards every event. Useful to measure the cost of event
/// construction alone, and as the explicit "tracing attached but off"
/// state.
#[derive(Debug, Default)]
pub struct NullSink;

impl TraceSink for NullSink {
    fn emit(&self, _ev: Event) {}
}

/// Buffers every event in memory, optionally stamping each with host
/// nanoseconds since the sink's construction.
///
/// Built without the host clock ([`BufferSink::new`]) the captured log is
/// fully deterministic for closed-loop runs; with it
/// ([`BufferSink::with_host_clock`]) events additionally carry wall-clock
/// latencies for span reconstruction and Chrome-trace export.
///
/// # Examples
///
/// ```
/// use redefine_blas::obs::{BufferSink, Event, EventKind, TraceSink};
///
/// let sink = BufferSink::new();
/// sink.emit(Event { req: 0, sim: 0, host_ns: None, kind: EventKind::CacheMiss });
/// assert_eq!(sink.len(), 1);
/// let log = sink.take();
/// assert_eq!(log[0].kind, EventKind::CacheMiss);
/// assert!(log[0].host_ns.is_none(), "no host clock unless opted in");
/// ```
#[derive(Debug)]
pub struct BufferSink {
    events: Mutex<Vec<Event>>,
    epoch: Option<Instant>,
}

impl Default for BufferSink {
    fn default() -> Self {
        Self::new()
    }
}

impl BufferSink {
    /// A buffering sink with no host clock: events keep whatever
    /// `host_ns` the emitter set (always `None` on the serving path), so
    /// the captured log is deterministic.
    pub fn new() -> Self {
        Self { events: Mutex::new(Vec::new()), epoch: None }
    }

    /// A buffering sink that stamps every event with host nanoseconds
    /// since this call.
    pub fn with_host_clock() -> Self {
        Self { events: Mutex::new(Vec::new()), epoch: Some(Instant::now()) }
    }

    /// Number of buffered events.
    pub fn len(&self) -> usize {
        self.events.lock().expect("trace buffer").len()
    }

    /// Whether the buffer is empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drain and return the buffered log, in emission order.
    pub fn take(&self) -> Vec<Event> {
        std::mem::take(&mut *self.events.lock().expect("trace buffer"))
    }
}

impl TraceSink for BufferSink {
    fn emit(&self, mut ev: Event) {
        if let Some(t0) = self.epoch {
            ev.host_ns = Some(t0.elapsed().as_nanos() as u64);
        }
        self.events.lock().expect("trace buffer").push(ev);
    }
}

#[cfg(test)]
mod tests {
    use super::super::event::EventKind;
    use super::*;

    #[test]
    fn host_clock_stamps_monotonically() {
        let sink = BufferSink::with_host_clock();
        for _ in 0..3 {
            sink.emit(Event { req: 1, sim: 0, host_ns: None, kind: EventKind::CacheHit });
        }
        let log = sink.take();
        assert_eq!(log.len(), 3);
        let stamps: Vec<u64> = log.iter().map(|e| e.host_ns.expect("stamped")).collect();
        assert!(stamps.windows(2).all(|w| w[0] <= w[1]), "host stamps must not go backwards");
        assert!(sink.is_empty(), "take drains");
    }

    #[test]
    fn null_sink_discards() {
        // Nothing to observe — just exercise the object-safe path.
        let sink: std::sync::Arc<dyn TraceSink> = std::sync::Arc::new(NullSink);
        sink.emit(Event { req: 0, sim: 0, host_ns: None, kind: EventKind::CacheMiss });
    }
}
