//! Unified metrics registry: counters, gauges, rolling windowed latency
//! histograms, and the engine/tenant snapshot structs that subsume the
//! scattered stat structs (`BatchStats`, `OpenLoopStats`, `CacheStats`,
//! `PoolJobCounts`, `FabricStats`, lane service) behind one call.
//!
//! [`crate::engine::Engine::snapshot`] and
//! [`crate::coordinator::Coordinator::snapshot`] return these; `main.rs`
//! reporting is built entirely on them, so every number the CLI prints is
//! reachable programmatically.
//!
//! The rolling histograms ([`WindowedHistogram`]) are the long-lived-
//! daemon prerequisite from the ROADMAP: instead of one per-run snapshot,
//! samples land in a ring of fixed-width time buckets and a snapshot
//! merges only the buckets inside the trailing window — stale buckets age
//! out as the clock advances. Merging is exact because the underlying
//! [`Histogram`] buckets are fixed power-of-two ranges (see
//! [`Histogram::merge`]).

use crate::coordinator::{BatchStats, CacheStats, OpenLoopStats, PoolJobCounts};
use crate::engine::latency::{Histogram, LatencySnapshot};
use crate::engine::{LaneService, SchedPolicy};
use crate::noc::FabricStats;
use std::sync::atomic::{AtomicU64, Ordering};

/// A monotonically increasing counter (thread-safe, relaxed ordering —
/// telemetry, not synchronization).
///
/// # Examples
///
/// ```
/// use redefine_blas::obs::Counter;
///
/// let c = Counter::default();
/// c.inc();
/// c.add(4);
/// assert_eq!(c.get(), 5);
/// ```
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    /// Increment by one.
    pub fn inc(&self) {
        self.add(1);
    }

    /// Increment by `n`.
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A last-value gauge with a high-water mark helper.
///
/// # Examples
///
/// ```
/// use redefine_blas::obs::Gauge;
///
/// let g = Gauge::default();
/// g.set(3);
/// g.record_max(2);
/// assert_eq!(g.get(), 3);
/// g.record_max(9);
/// assert_eq!(g.get(), 9);
/// ```
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    /// Overwrite the value.
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    /// Raise the value to `v` if larger (high-water mark).
    pub fn record_max(&self, v: u64) {
        self.0.fetch_max(v, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Sentinel for a ring slot that has never been written.
const EMPTY_SLOT: u64 = u64::MAX;

/// A rolling windowed log₂ histogram: a ring of fixed-width time buckets,
/// each holding a [`Histogram`]. Recording into a bucket whose ring slot
/// last held an older bucket resets that slot, so the structure is O(ring)
/// memory forever; a snapshot merges only the buckets inside the trailing
/// window ending at the newest sample.
///
/// # Examples
///
/// ```
/// use redefine_blas::obs::WindowedHistogram;
///
/// // 4 buckets of 1000 ns → a 4 µs trailing window.
/// let mut w = WindowedHistogram::new(1000, 4);
/// w.record(0, 10);
/// w.record(3_500, 20);
/// assert_eq!(w.snapshot().count, 2);
/// // Advance far enough and the first sample ages out.
/// w.record(7_900, 30);
/// assert_eq!(w.snapshot().count, 2); // 20 and 30 remain
/// ```
#[derive(Debug, Clone)]
pub struct WindowedHistogram {
    bucket_ns: u64,
    /// (absolute bucket index, histogram) per ring slot.
    slots: Vec<(u64, Histogram)>,
    /// Largest `at_ns` seen — the window's notion of "now".
    last_ns: u64,
}

impl WindowedHistogram {
    /// A window of `buckets` buckets, each `bucket_ns` wide.
    pub fn new(bucket_ns: u64, buckets: usize) -> Self {
        assert!(bucket_ns >= 1 && buckets >= 1, "window needs at least one real bucket");
        Self { bucket_ns, slots: vec![(EMPTY_SLOT, Histogram::new()); buckets], last_ns: 0 }
    }

    /// Total width of the trailing window, in ns.
    pub fn window_ns(&self) -> u64 {
        self.bucket_ns * self.slots.len() as u64
    }

    /// Record sample `v` taken at time `at_ns` (ns since the serving run's
    /// epoch; must come from one monotonic clock per run).
    pub fn record(&mut self, at_ns: u64, v: u64) {
        let idx = at_ns / self.bucket_ns;
        let slot = (idx % self.slots.len() as u64) as usize;
        if self.slots[slot].0 != idx {
            self.slots[slot] = (idx, Histogram::new());
        }
        self.slots[slot].1.record(v);
        self.last_ns = self.last_ns.max(at_ns);
    }

    /// Forget everything (a new serving run restarts the epoch).
    pub fn reset(&mut self) {
        for s in self.slots.iter_mut() {
            *s = (EMPTY_SLOT, Histogram::new());
        }
        self.last_ns = 0;
    }

    /// Merge the live buckets of the trailing window into one histogram.
    pub fn merged(&self) -> Histogram {
        let cur = self.last_ns / self.bucket_ns;
        let len = self.slots.len() as u64;
        let mut out = Histogram::new();
        for (idx, h) in &self.slots {
            if *idx != EMPTY_SLOT && idx + len > cur {
                out.merge(h);
            }
        }
        out
    }

    /// Percentile summary of the trailing window.
    pub fn snapshot(&self) -> LatencySnapshot {
        self.merged().snapshot()
    }
}

/// The three rolling latency windows the open-loop driver feeds (queue /
/// service / total, same decomposition as [`OpenLoopStats`]).
#[derive(Debug, Clone)]
pub struct RollingLatency {
    pub queue: WindowedHistogram,
    pub service: WindowedHistogram,
    pub total: WindowedHistogram,
}

impl RollingLatency {
    /// Default daemon window: 8 buckets × 250 ms = a 2 s trailing window.
    pub fn daemon_default() -> Self {
        Self::new(250_000_000, 8)
    }

    /// All three windows with the same geometry.
    pub fn new(bucket_ns: u64, buckets: usize) -> Self {
        Self {
            queue: WindowedHistogram::new(bucket_ns, buckets),
            service: WindowedHistogram::new(bucket_ns, buckets),
            total: WindowedHistogram::new(bucket_ns, buckets),
        }
    }

    /// Restart the epoch (called at the start of each open-loop run).
    pub fn reset(&mut self) {
        self.queue.reset();
        self.service.reset();
        self.total.reset();
    }

    /// Record one served request's decomposition at completion time.
    pub fn record(&mut self, at_ns: u64, queue_ns: u64, service_ns: u64, total_ns: u64) {
        self.queue.record(at_ns, queue_ns);
        self.service.record(at_ns, service_ns);
        self.total.record(at_ns, total_ns);
    }

    /// Percentile summary of the trailing window.
    pub fn snapshot(&self) -> RollingSnapshot {
        RollingSnapshot {
            window_ns: self.total.window_ns(),
            queue: self.queue.snapshot(),
            service: self.service.snapshot(),
            total: self.total.snapshot(),
        }
    }
}

/// Point-in-time summary of a [`RollingLatency`] trailing window.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RollingSnapshot {
    /// Width of the trailing window, ns.
    pub window_ns: u64,
    /// Queue-latency percentiles over the window.
    pub queue: LatencySnapshot,
    /// Service-latency percentiles over the window.
    pub service: LatencySnapshot,
    /// Total-latency percentiles over the window.
    pub total: LatencySnapshot,
}

/// Everything the engine knows about itself, in one value — the shared
/// totals side of the telemetry split (see
/// [`crate::engine::Engine::snapshot`]).
#[derive(Debug, Clone)]
pub struct EngineSnapshot {
    /// Persistent PE workers in the shared pool.
    pub workers: usize,
    /// Tenant handles created so far.
    pub tenants: usize,
    /// The fairness currency the pool schedules under.
    pub sched: SchedPolicy,
    /// Shared program-cache totals across every tenant.
    pub cache: CacheStats,
    /// Shared pool execution totals across every tenant.
    pub jobs: PoolJobCounts,
    /// Per-tenant-lane service telemetry, in attach order.
    pub lanes: Vec<LaneService>,
    /// Fabric telemetry, when the engine models one.
    pub fabric: Option<FabricStats>,
}

/// Everything one tenant knows about itself, in one value — the
/// per-tenant slice of the telemetry split (see
/// [`crate::coordinator::Coordinator::snapshot`]).
#[derive(Debug, Clone)]
pub struct TenantSnapshot {
    /// This tenant's home fabric row (0 without a fabric).
    pub home_row: usize,
    /// Workers in the pool serving this tenant.
    pub pool_size: usize,
    /// This tenant's program-cache counters (shared resident count).
    pub cache: CacheStats,
    /// Shared cache totals across the tenant's engine.
    pub shared_cache: CacheStats,
    /// Pool jobs executed for this tenant, by kind.
    pub jobs: PoolJobCounts,
    /// Telemetry of the last `serve_batch` / open-loop run's pipeline.
    pub batch: Option<BatchStats>,
    /// Aggregate stats of the last open-loop run, if one ran.
    pub open_loop: Option<OpenLoopStats>,
    /// Rolling windowed latency percentiles (fed by open-loop serving).
    pub rolling: RollingSnapshot,
    /// Fabric telemetry of the tenant's engine, when it models one.
    pub fabric: Option<FabricStats>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn windowed_histogram_ages_out_stale_buckets() {
        let mut w = WindowedHistogram::new(100, 4);
        w.record(0, 1); // bucket 0
        w.record(150, 2); // bucket 1
        w.record(399, 3); // bucket 3 — window now [0, 3], all live
        assert_eq!(w.snapshot().count, 3);
        // Bucket 4 wraps onto slot 0 and evicts bucket 0's sample; the
        // window becomes [1, 4].
        w.record(420, 4);
        assert_eq!(w.snapshot().count, 3);
        // Jump far ahead: only the new bucket remains live.
        w.record(5_000, 5);
        let s = w.snapshot();
        assert_eq!(s.count, 1);
        assert_eq!(s.max, 5);
    }

    #[test]
    fn windowed_merge_matches_plain_histogram_within_one_bucket() {
        // Samples confined to one bucket: the window must report exactly
        // what a plain histogram would.
        let mut w = WindowedHistogram::new(1_000_000, 8);
        let mut h = Histogram::new();
        let mut x = 5u64;
        for _ in 0..500 {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            let v = x >> 45;
            w.record(x % 1_000_000, v);
            h.record(v);
        }
        assert_eq!(w.snapshot(), h.snapshot());
    }

    #[test]
    fn rolling_latency_resets_between_runs() {
        let mut r = RollingLatency::new(1000, 4);
        r.record(10, 1, 2, 3);
        assert_eq!(r.snapshot().total.count, 1);
        r.reset();
        assert_eq!(r.snapshot(), RollingSnapshot { window_ns: 4000, ..RollingSnapshot::default() });
    }
}
