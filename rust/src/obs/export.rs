//! Event-log exporters: JSON Lines (one event per line, the
//! `--trace-out` default) and Chrome trace-event JSON (`--trace-format
//! chrome`, loadable in Perfetto / `chrome://tracing`).
//!
//! Both exporters are hand-rolled (the crate stays serde-free) and route
//! every string through [`crate::util::json::escape`] — the same escaping
//! the `hot_paths` bench writer uses.
//!
//! Chrome-trace layout: per tenant, one *requests* process (pid
//! `100 + tenant`) whose complete (`"ph":"X"`) spans run on the **host
//! clock** (µs since the sink epoch, one track per request sequence
//! number), and one *fabric* process (pid `200 + tenant`) whose spans run
//! on the **simulated fabric clock** rendered as 1 cycle = 1 µs, one
//! track per fabric tile — a routed run renders as a per-tile timeline.
//! Factorization requests add a *dag* process (pid `300 + tenant`) on the
//! simulated kernel clock, one track per DAG node: each span runs from the
//! node's release (all predecessors done) to its completion, so the
//! critical path of a served factorization reads directly off the trace.
//! Request spans need host timestamps, so they appear only for sinks
//! built with the host clock; fabric and dag spans are purely simulated
//! and always export.

use super::event::{Event, EventKind, NO_REQ};
use crate::coordinator::ShedReason;
use crate::util::json::escape;

fn shed_reason_name(r: ShedReason) -> &'static str {
    match r {
        ShedReason::QueueDepth => "queue_depth",
        ShedReason::QueueBytes => "queue_bytes",
    }
}

/// Shared JSONL prefix: tag, tenant, request id (omitted for shed
/// arrivals), simulated anchor, host stamp (omitted without a host clock).
fn push_common(out: &mut String, ev: &Event, tenant: usize) {
    out.push_str("{\"ev\":\"");
    out.push_str(ev.kind.tag());
    out.push_str(&format!("\",\"tenant\":{tenant}"));
    if ev.req != NO_REQ {
        out.push_str(&format!(",\"req\":{}", ev.req));
    }
    out.push_str(&format!(",\"sim\":{}", ev.sim));
    if let Some(h) = ev.host_ns {
        out.push_str(&format!(",\"host_ns\":{h}"));
    }
}

/// Render per-tenant event logs as JSON Lines: one self-contained JSON
/// object per event, in emission order, tenants concatenated in the given
/// order. Every line carries `ev` (the event tag), `tenant`, `sim`, and
/// the event's typed payload; `req` is present for every event of an
/// admitted request.
pub fn to_jsonl(groups: &[(usize, Vec<Event>)]) -> String {
    let mut out = String::new();
    for (tenant, events) in groups {
        for ev in events {
            push_common(&mut out, ev, *tenant);
            match &ev.kind {
                EventKind::Admitted { seq, op, n, bytes } => {
                    out.push_str(&format!(
                        ",\"seq\":{seq},\"op\":\"{}\",\"n\":{n},\"bytes\":{bytes}",
                        escape(op)
                    ));
                }
                EventKind::Shed { seq, reason } => {
                    out.push_str(&format!(
                        ",\"seq\":{seq},\"reason\":\"{}\"",
                        shed_reason_name(*reason)
                    ));
                }
                EventKind::CacheHit | EventKind::CacheMiss | EventKind::CacheEvicted => {}
                EventKind::Dispatched { lane, cost } => {
                    out.push_str(&format!(",\"lane\":{lane},\"cost\":{cost}"));
                }
                EventKind::Executed { tier } => {
                    out.push_str(&format!(",\"tier\":\"{}\"", tier.name()));
                }
                EventKind::FabricRouted { tile, depart, ready, finish, compute } => {
                    out.push_str(&format!(
                        ",\"tile_row\":{},\"tile_col\":{},\"depart\":{depart},\"ready\":{ready},\
                         \"finish\":{finish},\"compute\":{compute}",
                        tile.row, tile.col
                    ));
                }
                EventKind::NodeReleased { node, call, n } => {
                    out.push_str(&format!(
                        ",\"node\":{node},\"call\":\"{}\",\"n\":{n}",
                        escape(call)
                    ));
                }
                EventKind::NodeCompleted { node, cycles } => {
                    out.push_str(&format!(",\"node\":{node},\"cycles\":{cycles}"));
                }
                EventKind::Completed { queue_ns, service_ns, cycles } => {
                    out.push_str(&format!(
                        ",\"queue_ns\":{queue_ns},\"service_ns\":{service_ns},\"cycles\":{cycles}"
                    ));
                }
            }
            out.push_str("}\n");
        }
    }
    out
}

/// One Chrome trace-event object (complete or metadata phase).
fn chrome_event(
    events: &mut Vec<String>,
    name: &str,
    cat: &str,
    pid: usize,
    tid: u64,
    ts_us: f64,
    dur_us: f64,
    args: &str,
) {
    events.push(format!(
        "{{\"name\":\"{}\",\"cat\":\"{cat}\",\"ph\":\"X\",\"pid\":{pid},\"tid\":{tid},\
         \"ts\":{ts_us:.3},\"dur\":{dur_us:.3},\"args\":{{{args}}}}}",
        escape(name)
    ));
}

fn chrome_process_name(events: &mut Vec<String>, pid: usize, name: &str) {
    events.push(format!(
        "{{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":{pid},\"tid\":0,\
         \"args\":{{\"name\":\"{}\"}}}}",
        escape(name)
    ));
}

/// Render per-tenant event logs as Chrome trace-event JSON (the
/// `{"traceEvents":[...]}` object form). See the module docs for the
/// process/track layout. Every emitted phase is `"X"` (complete) or `"M"`
/// (metadata) — no unmatched begin/end pairs, pinned by `tests/obs.rs`.
pub fn to_chrome(groups: &[(usize, Vec<Event>)]) -> String {
    let mut events: Vec<String> = Vec::new();
    for (tenant, log) in groups {
        // Request spans on the host clock: Admitted → Completed per req.
        let mut admitted: std::collections::HashMap<u64, (usize, &'static str, usize, u64)> =
            std::collections::HashMap::new();
        let mut spans = 0usize;
        let mut routed = 0usize;
        let mut dag_spans = 0usize;
        // DAG node release anchors: (req, node) → (call, n, release sim).
        let mut released: std::collections::HashMap<(u64, usize), (&'static str, usize, u64)> =
            std::collections::HashMap::new();
        for ev in log {
            match &ev.kind {
                EventKind::Admitted { seq, op, n, .. } => {
                    if let Some(h) = ev.host_ns {
                        admitted.insert(ev.req, (*seq, *op, *n, h));
                    }
                }
                EventKind::Completed { .. } => {
                    if let (Some(h), Some((seq, op, n, at))) =
                        (ev.host_ns, admitted.remove(&ev.req))
                    {
                        if spans == 0 {
                            chrome_process_name(
                                &mut events,
                                100 + tenant,
                                &format!("tenant {tenant} requests (host clock)"),
                            );
                        }
                        spans += 1;
                        chrome_event(
                            &mut events,
                            &format!("{op} n={n} req={}", ev.req),
                            "request",
                            100 + tenant,
                            seq as u64,
                            at as f64 / 1000.0,
                            h.saturating_sub(at) as f64 / 1000.0,
                            &format!("\"req\":{},\"cycles_sim\":{}", ev.req, ev.sim),
                        );
                    }
                }
                EventKind::FabricRouted { tile, depart, ready, finish, compute } => {
                    if routed == 0 {
                        chrome_process_name(
                            &mut events,
                            200 + tenant,
                            &format!("tenant {tenant} fabric (1 cycle = 1 µs)"),
                        );
                    }
                    routed += 1;
                    chrome_event(
                        &mut events,
                        &format!("req={} tile=({},{})", ev.req, tile.row, tile.col),
                        "fabric",
                        200 + tenant,
                        (tile.row * 16 + tile.col) as u64,
                        *depart as f64,
                        (finish - depart) as f64,
                        &format!("\"req\":{},\"ready\":{ready},\"compute\":{compute}", ev.req),
                    );
                }
                EventKind::NodeReleased { node, call, n } => {
                    released.insert((ev.req, *node), (*call, *n, ev.sim));
                }
                EventKind::NodeCompleted { node, .. } => {
                    if let Some((call, n, at)) = released.remove(&(ev.req, *node)) {
                        if dag_spans == 0 {
                            chrome_process_name(
                                &mut events,
                                300 + tenant,
                                &format!("tenant {tenant} dag nodes (1 cycle = 1 µs)"),
                            );
                        }
                        dag_spans += 1;
                        chrome_event(
                            &mut events,
                            &format!("{call} n={n} node={node} req={}", ev.req),
                            "dag",
                            300 + tenant,
                            *node as u64,
                            at as f64,
                            ev.sim.saturating_sub(at) as f64,
                            &format!("\"req\":{},\"node\":{node}", ev.req),
                        );
                    }
                }
                _ => {}
            }
        }
    }
    format!("{{\"traceEvents\":[{}]}}\n", events.join(","))
}

#[cfg(test)]
mod tests {
    use super::super::event::Tier;
    use super::*;
    use crate::noc::Coord;

    fn log() -> Vec<Event> {
        vec![
            Event {
                req: 0,
                sim: 0,
                host_ns: Some(100),
                kind: EventKind::Admitted { seq: 0, op: "dgemm", n: 16, bytes: 4096 },
            },
            Event { req: 0, sim: 0, host_ns: Some(110), kind: EventKind::CacheMiss },
            Event {
                req: 0,
                sim: 0,
                host_ns: Some(120),
                kind: EventKind::Dispatched { lane: 0, cost: 42 },
            },
            Event {
                req: 0,
                sim: 0,
                host_ns: Some(400),
                kind: EventKind::Executed { tier: Tier::Combined },
            },
            Event {
                req: 0,
                sim: 50,
                host_ns: Some(420),
                kind: EventKind::FabricRouted {
                    tile: Coord::new(1, 0),
                    depart: 50,
                    ready: 80,
                    finish: 300,
                    compute: 180,
                },
            },
            Event {
                req: 0,
                sim: 300,
                host_ns: Some(500),
                kind: EventKind::Completed { queue_ns: 10, service_ns: 390, cycles: 300 },
            },
            Event {
                req: NO_REQ,
                sim: 0,
                host_ns: Some(600),
                kind: EventKind::Shed { seq: 1, reason: ShedReason::QueueDepth },
            },
        ]
    }

    #[test]
    fn jsonl_emits_one_line_per_event_with_typed_keys() {
        let s = to_jsonl(&[(0, log())]);
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 7);
        assert!(lines[0].contains("\"ev\":\"admitted\""));
        assert!(lines[0].contains("\"op\":\"dgemm\"") && lines[0].contains("\"bytes\":4096"));
        assert!(lines[1].contains("\"ev\":\"cache_miss\"") && lines[1].contains("\"req\":0"));
        assert!(lines[2].contains("\"lane\":0") && lines[2].contains("\"cost\":42"));
        assert!(lines[3].contains("\"tier\":\"combined\""));
        assert!(lines[4].contains("\"tile_row\":1") && lines[4].contains("\"finish\":300"));
        assert!(lines[5].contains("\"queue_ns\":10") && lines[5].contains("\"cycles\":300"));
        assert!(lines[6].contains("\"reason\":\"queue_depth\""));
        assert!(!lines[6].contains("\"req\""), "shed arrivals have no request id");
        assert!(lines.iter().all(|l| l.starts_with('{') && l.ends_with('}')));
    }

    #[test]
    fn chrome_spans_are_complete_phases_only() {
        let s = to_chrome(&[(0, log())]);
        assert!(s.starts_with("{\"traceEvents\":["));
        assert_eq!(s.matches("\"ph\":\"B\"").count(), 0);
        assert_eq!(s.matches("\"ph\":\"E\"").count(), 0);
        // One request span + one fabric span, plus two process names.
        assert_eq!(s.matches("\"ph\":\"X\"").count(), 2);
        assert_eq!(s.matches("\"ph\":\"M\"").count(), 2);
        assert!(s.contains("\"cat\":\"request\"") && s.contains("\"cat\":\"fabric\""));
        // Host span: 100 ns → 0.100 µs start, 400 ns → 0.400 µs duration.
        assert!(s.contains("\"ts\":0.100,\"dur\":0.400"), "host span mis-scaled: {s}");
        // Fabric span: simulated cycles verbatim as µs.
        assert!(s.contains("\"ts\":50.000,\"dur\":250.000"), "fabric span mis-scaled: {s}");
    }

    #[test]
    fn chrome_without_host_clock_still_exports_fabric() {
        let mut l = log();
        for e in l.iter_mut() {
            e.host_ns = None;
        }
        let s = to_chrome(&[(0, l)]);
        assert_eq!(s.matches("\"cat\":\"request\"").count(), 0, "no host clock, no spans");
        assert_eq!(s.matches("\"cat\":\"fabric\"").count(), 1);
    }

    #[test]
    fn dag_node_events_export_as_lines_and_spans() {
        let l = vec![
            Event {
                req: 2,
                sim: 0,
                host_ns: None,
                kind: EventKind::NodeReleased { node: 0, call: "gemv", n: 12 },
            },
            Event {
                req: 2,
                sim: 40,
                host_ns: None,
                kind: EventKind::NodeCompleted { node: 0, cycles: 40 },
            },
            Event {
                req: 2,
                sim: 40,
                host_ns: None,
                kind: EventKind::NodeReleased { node: 1, call: "gemm", n: 12 },
            },
            Event {
                req: 2,
                sim: 90,
                host_ns: None,
                kind: EventKind::NodeCompleted { node: 1, cycles: 50 },
            },
        ];
        let s = to_jsonl(&[(0, l.clone())]);
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].contains("\"ev\":\"node_released\""));
        assert!(lines[0].contains("\"call\":\"gemv\""));
        assert!(lines[3].contains("\"ev\":\"node_completed\""));
        assert!(lines[3].contains("\"cycles\":50"));
        let c = to_chrome(&[(0, l)]);
        // Two dag node spans on the simulated clock, pid 300 + tenant.
        assert_eq!(c.matches("\"cat\":\"dag\"").count(), 2);
        assert!(c.contains("\"pid\":300"));
        assert!(c.contains("\"ts\":40.000,\"dur\":50.000"), "node 1 span mis-scaled: {c}");
    }

    #[test]
    fn empty_log_is_valid_chrome_json() {
        assert_eq!(to_chrome(&[]).trim(), "{\"traceEvents\":[]}");
        assert_eq!(to_jsonl(&[]), "");
    }
}
