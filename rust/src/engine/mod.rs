//! Process-wide multi-tenant serving engine.
//!
//! The paper's scalability story (§5.5) attaches many PEs to one REDEFINE
//! fabric and serves whatever work arrives; the serving-side analogue is
//! **one resident runtime amortized across callers** (the KBLAS /
//! persistent-kernel approach). This module is that resident runtime: an
//! [`Engine`] owns exactly one process-wide pool of PE workers and one
//! shared [`ProgramCache`], and hands out per-tenant
//! [`Coordinator`] handles ([`Engine::tenant`]) that keep the whole
//! existing coordinator API while routing through the shared resources.
//!
//! What sharing buys:
//! * **warm kernels cross tenants** — a `ScheduledProgram` emitted,
//!   decoded and timing-scheduled for one tenant replays for every other
//!   tenant requesting the same (routine, shape, AE) key;
//! * **one worker fleet** — PE simulations from all tenants interleave on
//!   the same host threads instead of every coordinator spawning its own;
//! * **fair scheduling** — per-tenant submission lanes drained by a
//!   weighted fair scheduler, so one tenant's large DGEMM batch cannot
//!   starve another tenant's Level-1 traffic. The default currency is
//!   **estimated simulated cycles** ([`SchedPolicy::Cycles`]: deficit
//!   round-robin over per-job cost estimates), so a tenant flooding huge
//!   DGEMM tile kernels and a tenant submitting DDOT kernels receive
//!   cycle service in proportion to their weights — the slot-based WRR of
//!   PR 4 ([`SchedPolicy::Slots`]) counted both the same per dispatch and
//!   stays available as the pinned baseline (see `queue`). Estimates are
//!   repriced at dispatch time, so a kernel whose timing pass memoizes
//!   while its jobs sit queued is debited by real cycles, not the stale
//!   submission-time op count;
//! * **scoped cache residency** — [`EngineConfig::cache_quota`] bounds
//!   each tenant's resident kernel count, so a shape-churning tenant
//!   evicts within its own set instead of flushing a sibling's warm
//!   kernels out of the shared capped cache.
//!
//! Accounting splits both ways: the engine reports shared totals
//! ([`Engine::cache_stats`], [`Engine::pool_job_counts`]) while every
//! tenant coordinator reports its own slice
//! ([`Coordinator::cache_stats`], [`Coordinator::pool_job_counts`]).
//!
//! A standalone [`Coordinator::new`] builds a private single-tenant engine
//! under the hood, so its behavior (dispatch order, stats, values, cycles,
//! energy) is unchanged — pinned by the serving tests.
//!
//! Factorization DAG workloads need no engine-side support: dependency
//! gating lives in the coordinator's pipeline, which submits a DAG node's
//! job only once its predecessors complete — the shared lanes and the
//! fair scheduler only ever see ready jobs, priced in the same
//! estimated-cycle currency as flat BLAS kernels. A factorization tenant
//! therefore receives proportional cycle service against a DGEMM-flooding
//! tenant with no scheduler changes (pinned by the `lapack_serve` tests).

pub mod latency;
pub(crate) mod queue;
pub mod traffic;

pub use latency::{Histogram, LatencySnapshot};
pub use queue::SchedPolicy;
pub use traffic::{Arrival, ArrivalKind, TrafficConfig};

use crate::coordinator::cache::ProgramCache;
use crate::coordinator::pool::PoolCore;
use crate::coordinator::{CacheStats, Coordinator, CoordinatorConfig, PoolJobCounts};
use crate::noc::{Fabric, FabricConfig, FabricStats};
use crate::obs::EngineSnapshot;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Engine configuration.
///
/// # Examples
///
/// Configs are plain data — building one spawns nothing:
///
/// ```
/// use redefine_blas::engine::{EngineConfig, SchedPolicy};
///
/// let cfg = EngineConfig { workers: 2, sched: SchedPolicy::Slots, ..EngineConfig::default() };
/// assert_eq!(cfg.workers, 2);
/// assert_eq!(EngineConfig::default().sched, SchedPolicy::Cycles);
/// ```
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Number of persistent PE workers in the shared pool.
    pub workers: usize,
    /// LRU capacity of the shared program cache, in resident kernels
    /// (`None` = unbounded). Tenant-level `cache_capacity` settings are
    /// ignored under an engine — residency is a shared property.
    pub cache_capacity: Option<usize>,
    /// Per-tenant residency quota of the shared cache (`None` =
    /// unscoped): each tenant may keep at most this many kernels
    /// resident, and an overflowing insertion evicts within the
    /// overflowing tenant's *own* set — a churning tenant cannot flush a
    /// sibling's warm kernels. Composes with `cache_capacity` (the global
    /// cap still bounds the total).
    pub cache_quota: Option<usize>,
    /// Fairness currency of the shared pool's scheduler: cycle-cost
    /// deficit round-robin ([`SchedPolicy::Cycles`], the default) or the
    /// slot-based WRR baseline ([`SchedPolicy::Slots`]).
    pub sched: SchedPolicy,
    /// Model the engine as a b×b REDEFINE fabric (`Some`): every pool job
    /// is placed on a compute tile and its operand/result movement is
    /// priced on the mesh, so job completion = communication + compute.
    /// `None` (the default, `--fabric 0`) keeps the location-free pool —
    /// free, instantaneous operand delivery, exactly the pre-fabric
    /// behavior.
    pub fabric: Option<FabricConfig>,
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self {
            workers: 4,
            cache_capacity: None,
            cache_quota: None,
            sched: SchedPolicy::Cycles,
            fabric: None,
        }
    }
}

/// State shared by the engine and every tenant coordinator: the worker
/// pool and the program cache. Reference-counted so the workers outlive
/// the [`Engine`] value for as long as any tenant handle is alive; the
/// last drop closes the job queue and joins the workers.
pub(crate) struct EngineShared {
    pub(crate) pool: PoolCore,
    pub(crate) cache: ProgramCache,
    /// The modeled fabric, when the engine runs location-aware
    /// (`EngineConfig::fabric`). Locked once per finalized request by the
    /// coordinators; finalization runs in strict submission order per
    /// tenant, so routed schedules are deterministic.
    pub(crate) fabric: Option<Mutex<Fabric>>,
    /// Tenants attached so far — assigns each tenant a home fabric row
    /// (attach order modulo rows) for region-aware placement.
    pub(crate) fabric_tenants: AtomicUsize,
}

/// The multi-tenant serving engine: one shared PE worker pool + one shared
/// program cache behind per-tenant [`Coordinator`] handles.
///
/// ```no_run
/// use redefine_blas::coordinator::CoordinatorConfig;
/// use redefine_blas::engine::{Engine, EngineConfig};
///
/// let engine = Engine::new(EngineConfig { workers: 4, ..EngineConfig::default() });
/// let mut a = engine.tenant(CoordinatorConfig::default());
/// let mut b = engine.tenant_weighted(CoordinatorConfig::default(), 3);
/// // `a` and `b` serve through one pool and share warm kernels; under the
/// // default cycle-cost scheduler `b` receives up to 3 estimated
/// // simulated cycles of service per scheduler round to `a`'s 1.
/// ```
pub struct Engine {
    shared: Arc<EngineShared>,
    tenants: AtomicUsize,
}

/// One tenant lane's slice of the fair scheduler's service telemetry, in
/// tenant attach order (see [`Engine::lane_service`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LaneService {
    /// The lane's scheduling weight.
    pub weight: u64,
    /// Cumulative estimated simulated cycles dispatched from this lane.
    /// Costs are repriced at dispatch time: exact memoized cycles for any
    /// kernel whose schedule exists by then (even if it was cold at
    /// submission), decoded op count only for kernels still cold at
    /// dispatch.
    pub served_cost: u64,
}

impl Engine {
    /// Spawn the shared worker pool and build the shared program cache.
    pub fn new(cfg: EngineConfig) -> Self {
        let cache = ProgramCache::with_limits(cfg.cache_capacity, cfg.cache_quota);
        let fabric = cfg.fabric.as_ref().map(|f| Mutex::new(Fabric::new(f)));
        let shared = Arc::new(EngineShared {
            pool: PoolCore::new(cfg.workers, cfg.sched),
            cache,
            fabric,
            fabric_tenants: AtomicUsize::new(0),
        });
        Self { shared, tenants: AtomicUsize::new(0) }
    }

    /// Attach a tenant with scheduling weight 1. The returned
    /// [`Coordinator`] exposes the full per-tenant API (serve loops,
    /// BLAS entry points, stats) but executes on the shared pool and
    /// shares the engine's program cache.
    ///
    /// # Examples
    ///
    /// ```no_run
    /// use redefine_blas::coordinator::CoordinatorConfig;
    /// use redefine_blas::engine::{Engine, EngineConfig};
    ///
    /// let engine = Engine::new(EngineConfig::default());
    /// let mut tenant = engine.tenant(CoordinatorConfig::default());
    /// let (dot, _meas, _src) = tenant.ddot(&[1.0, 2.0], &[3.0, 4.0]);
    /// assert_eq!(dot, 11.0);
    /// ```
    pub fn tenant(&self, cfg: CoordinatorConfig) -> Coordinator {
        self.tenant_weighted(cfg, 1)
    }

    /// [`Engine::tenant`] with an explicit fair-scheduler weight: when
    /// lanes contend, a weight-`w` tenant accrues `w` units of service per
    /// scheduler round — estimated simulated cycles under the default
    /// [`SchedPolicy::Cycles`], dispatch slots under
    /// [`SchedPolicy::Slots`]. Weight bounds *relative service rate*, not
    /// priority — every backlogged tenant accrues every round.
    pub fn tenant_weighted(&self, cfg: CoordinatorConfig, weight: u64) -> Coordinator {
        assert!(weight >= 1, "tenant weight must be at least 1");
        self.tenants.fetch_add(1, Ordering::Relaxed);
        Coordinator::attach(Arc::clone(&self.shared), cfg, weight)
    }

    /// Workers in the shared pool.
    pub fn worker_count(&self) -> usize {
        self.shared.pool.worker_count()
    }

    /// Tenant handles created so far (handles are never reclaimed — a
    /// dropped tenant just leaves an empty scheduler lane).
    pub fn tenant_count(&self) -> usize {
        self.tenants.load(Ordering::Relaxed)
    }

    /// Shared program-cache totals across every tenant. The per-tenant
    /// slices ([`Coordinator::cache_stats`]) partition these hit/miss/
    /// eviction counters exactly.
    pub fn cache_stats(&self) -> CacheStats {
        self.shared.cache.stats()
    }

    /// Shared pool execution totals across every tenant.
    pub fn pool_job_counts(&self) -> PoolJobCounts {
        self.shared.pool.counts()
    }

    /// Fabric telemetry snapshot (per-link utilization, makespan,
    /// compute/comm split) when the engine models a fabric; `None` under
    /// the location-free pool.
    pub fn fabric_stats(&self) -> Option<FabricStats> {
        self.shared.fabric.as_ref().map(|f| f.lock().expect("fabric lock").stats())
    }

    /// The fairness currency the shared pool schedules under.
    pub fn sched(&self) -> SchedPolicy {
        self.shared.pool.sched()
    }

    /// Per-tenant-lane service telemetry, in tenant attach order: each
    /// lane's weight and the cumulative estimated simulated cycles
    /// dispatched from it. Under [`SchedPolicy::Cycles`] the served costs
    /// of continuously backlogged lanes track the weight ratio (the
    /// proportional-service property pinned by the queue tests and
    /// asserted end to end by the `hot_paths` bench).
    ///
    /// # Examples
    ///
    /// ```no_run
    /// use redefine_blas::coordinator::CoordinatorConfig;
    /// use redefine_blas::engine::{Engine, EngineConfig};
    ///
    /// let engine = Engine::new(EngineConfig::default());
    /// let _a = engine.tenant(CoordinatorConfig::default());
    /// let _b = engine.tenant_weighted(CoordinatorConfig::default(), 3);
    /// let lanes = engine.lane_service(); // attach order: [a, b]
    /// assert_eq!((lanes[0].weight, lanes[1].weight), (1, 3));
    /// ```
    pub fn lane_service(&self) -> Vec<LaneService> {
        self.shared
            .pool
            .lane_service()
            .into_iter()
            .map(|(weight, served_cost)| LaneService { weight, served_cost })
            .collect()
    }

    /// Everything the engine knows about itself, in one value: worker and
    /// tenant counts, the scheduling policy, shared cache and pool totals,
    /// per-lane service, and the fabric view. Every engine-wide number the
    /// CLI prints is derivable from this (the per-tenant counterpart is
    /// [`Coordinator::snapshot`]).
    ///
    /// # Examples
    ///
    /// ```no_run
    /// use redefine_blas::engine::{Engine, EngineConfig};
    ///
    /// let engine = Engine::new(EngineConfig::default());
    /// let snap = engine.snapshot();
    /// assert_eq!(snap.workers, 4);
    /// assert!(snap.fabric.is_none(), "location-free pool by default");
    /// ```
    pub fn snapshot(&self) -> EngineSnapshot {
        EngineSnapshot {
            workers: self.worker_count(),
            tenants: self.tenant_count(),
            sched: self.sched(),
            cache: self.cache_stats(),
            jobs: self.pool_job_counts(),
            lanes: self.lane_service(),
            fabric: self.fabric_stats(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pe::AeLevel;
    use crate::util::Mat;

    fn cfg(ae: AeLevel, b: usize) -> CoordinatorConfig {
        CoordinatorConfig {
            ae,
            b,
            artifact_dir: "/nonexistent".into(),
            verify: false,
            ..CoordinatorConfig::default()
        }
    }

    #[test]
    fn engine_reports_workers_and_tenants() {
        let engine = Engine::new(EngineConfig { workers: 3, ..EngineConfig::default() });
        assert_eq!(engine.worker_count(), 3);
        assert_eq!(engine.tenant_count(), 0);
        assert_eq!(engine.sched(), SchedPolicy::Cycles, "cycle-cost DRR is the default");
        let _a = engine.tenant(cfg(AeLevel::Ae5, 2));
        let _b = engine.tenant_weighted(cfg(AeLevel::Ae2, 1), 4);
        assert_eq!(engine.tenant_count(), 2);
        let service = engine.lane_service();
        assert_eq!(service.len(), 2);
        assert_eq!((service[0].weight, service[1].weight), (1, 4));
        assert_eq!((service[0].served_cost, service[1].served_cost), (0, 0));
    }

    #[test]
    fn tenants_share_the_program_cache() {
        let engine = Engine::new(EngineConfig { workers: 2, ..EngineConfig::default() });
        let mut a = engine.tenant(cfg(AeLevel::Ae5, 2));
        let mut b = engine.tenant(cfg(AeLevel::Ae5, 2));
        let n = 16;
        let (x, y, z) = (Mat::random(n, n, 1), Mat::random(n, n, 2), Mat::zeros(n, n));
        let ra = a.dgemm(&x, &y, &z);
        let rb = b.dgemm(&x, &y, &z);
        // Same shape, same AE: identical simulated cost either way, and
        // the second tenant never re-emits the kernel.
        assert_eq!(ra.makespan, rb.makespan);
        let shared = engine.cache_stats();
        assert_eq!(shared.misses, 1, "one emission serves both tenants: {shared:?}");
        assert_eq!(b.cache_stats().misses, 0, "tenant b must ride tenant a's kernel");
    }

    #[test]
    fn pool_outlives_the_engine_value() {
        let mut tenant = {
            let engine = Engine::new(EngineConfig { workers: 2, ..EngineConfig::default() });
            engine.tenant(cfg(AeLevel::Ae4, 2))
        };
        // The engine value is gone; the shared pool must still serve.
        let n = 8;
        let (x, y, z) = (Mat::random(n, n, 3), Mat::random(n, n, 4), Mat::zeros(n, n));
        let r = tenant.dgemm(&x, &y, &z);
        let want = crate::blas::level3::dgemm_ref(&x, &y, &z);
        let err = crate::util::rel_fro_error(r.c.as_slice(), want.as_slice());
        assert!(err < 1e-12, "post-engine-drop DGEMM wrong: {err}");
    }
}
