//! Log-bucketed latency histograms — dependency-free tail-latency tracking
//! for the open-loop serving mode.
//!
//! Open-loop serving (see [`crate::engine::traffic`]) measures per-request
//! queue/service/total latency in nanoseconds. Storing every sample would make
//! overload runs (which is exactly when latency matters) allocate without
//! bound, so samples land in power-of-two buckets: bucket `i >= 1` counts
//! values `v` with `2^(i-1) <= v < 2^i`, bucket 0 counts exact zeros. A
//! quantile is then the upper bound of the bucket containing that rank,
//! clamped to the largest value actually observed — a conservative (never
//! under-reported) tail estimate with at most 2x relative error, which is
//! plenty to rank schedulers against each other.

/// Fixed-size log₂ histogram of `u64` samples (nanoseconds, by convention).
///
/// # Examples
///
/// ```
/// use redefine_blas::engine::latency::Histogram;
///
/// let mut h = Histogram::new();
/// for v in 1..=1000u64 {
///     h.record(v);
/// }
/// let s = h.snapshot();
/// assert_eq!(s.count, 1000);
/// assert!(s.p50 >= 500 && s.p50 <= 1023); // bucket upper bound, never below rank
/// assert_eq!(s.max, 1000); // quantiles clamp to the observed maximum
/// assert!(s.p50 <= s.p95 && s.p95 <= s.p99 && s.p99 <= s.max);
/// ```
#[derive(Debug, Clone)]
pub struct Histogram {
    /// counts[0] = zeros; counts[i] = values in [2^(i-1), 2^i) for i in 1..=64.
    counts: [u64; 65],
    total: u64,
    max: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    /// An empty histogram.
    pub fn new() -> Self {
        Self { counts: [0; 65], total: 0, max: 0 }
    }

    /// Record one sample.
    pub fn record(&mut self, v: u64) {
        let bucket = (64 - v.leading_zeros()) as usize; // 0 for v == 0
        self.counts[bucket] += 1;
        self.total += 1;
        self.max = self.max.max(v);
    }

    /// Number of recorded samples.
    pub fn count(&self) -> u64 {
        self.total
    }

    /// Largest recorded sample (0 when empty).
    pub fn max_value(&self) -> u64 {
        self.max
    }

    /// Whether any sample has been recorded.
    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    /// The `q`-quantile (`q` in [0, 1]) as the upper bound of the bucket
    /// holding that rank, clamped to the observed maximum. Returns 0 for an
    /// empty histogram.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let rank = ((q * self.total as f64).ceil() as u64).clamp(1, self.total);
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                let upper = match i {
                    0 => 0,
                    64 => u64::MAX,
                    _ => (1u64 << i) - 1,
                };
                return upper.min(self.max);
            }
        }
        self.max
    }

    /// Fold another histogram into this one. Merging is exact: because
    /// buckets are fixed power-of-two ranges, merged quantiles equal the
    /// quantiles of a single histogram fed both sample streams (the windowed
    /// rollup in [`crate::obs`] depends on this).
    pub fn merge(&mut self, other: &Histogram) {
        for (dst, src) in self.counts.iter_mut().zip(other.counts.iter()) {
            *dst += src;
        }
        self.total += other.total;
        self.max = self.max.max(other.max);
    }

    /// Summarize into fixed percentiles.
    pub fn snapshot(&self) -> LatencySnapshot {
        LatencySnapshot {
            count: self.total,
            p50: self.quantile(0.50),
            p95: self.quantile(0.95),
            p99: self.quantile(0.99),
            max: self.max,
        }
    }
}

/// Point-in-time percentile summary of a [`Histogram`]. All values share the
/// unit of the recorded samples (nanoseconds, by convention).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct LatencySnapshot {
    /// Number of samples behind the percentiles.
    pub count: u64,
    /// Median (bucket upper bound, clamped to `max`).
    pub p50: u64,
    /// 95th percentile.
    pub p95: u64,
    /// 99th percentile.
    pub p99: u64,
    /// Largest recorded sample — exact, not bucketed.
    pub max: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_histogram_reports_zeros() {
        let h = Histogram::new();
        assert!(h.is_empty());
        assert_eq!(h.snapshot(), LatencySnapshot::default());
        assert_eq!(h.quantile(0.99), 0);
    }

    #[test]
    fn zeros_land_in_bucket_zero() {
        let mut h = Histogram::new();
        for _ in 0..10 {
            h.record(0);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 10);
        assert_eq!((s.p50, s.p99, s.max), (0, 0, 0));
    }

    #[test]
    fn quantiles_bound_uniform_ramp() {
        let mut h = Histogram::new();
        for v in 1..=1000u64 {
            h.record(v);
        }
        let s = h.snapshot();
        // Rank 500 sits in [256, 512); the bucket upper bound 511 is >= the
        // true median and < 2x it.
        assert!(s.p50 >= 500 && s.p50 <= 1023, "p50 = {}", s.p50);
        // Rank 990 sits in [512, 1024); clamped to the observed max of 1000.
        assert_eq!(s.p99, 1000);
        assert_eq!(s.max, 1000);
    }

    #[test]
    fn quantiles_are_monotone() {
        let mut h = Histogram::new();
        let mut x = 88u64;
        for _ in 0..5000 {
            // Cheap LCG spreading samples over many buckets.
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            h.record(x >> 40);
        }
        let s = h.snapshot();
        assert!(s.p50 <= s.p95 && s.p95 <= s.p99 && s.p99 <= s.max);
    }

    #[test]
    fn single_sample_is_its_own_tail() {
        let mut h = Histogram::new();
        h.record(777);
        let s = h.snapshot();
        assert_eq!((s.p50, s.p95, s.p99, s.max), (777, 777, 777, 777));
    }

    #[test]
    fn merged_quantiles_match_single_combined_histogram() {
        // Two disjoint streams recorded separately then merged must report
        // exactly the quantiles of one histogram fed both streams.
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        let mut combined = Histogram::new();
        let mut x = 17u64;
        for i in 0..4000 {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            let v = x >> 38;
            if i % 2 == 0 {
                a.record(v);
            } else {
                b.record(v);
            }
            combined.record(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), combined.count());
        assert_eq!(a.max_value(), combined.max_value());
        for q in [0.5, 0.9, 0.95, 0.99, 1.0] {
            assert_eq!(a.quantile(q), combined.quantile(q), "quantile {q} drifted");
        }
        assert_eq!(a.snapshot(), combined.snapshot());
    }

    #[test]
    fn merge_with_empty_is_identity() {
        let mut h = Histogram::new();
        h.record(10);
        h.record(1000);
        let before = h.snapshot();
        h.merge(&Histogram::new());
        assert_eq!(h.snapshot(), before);
        let mut empty = Histogram::new();
        empty.merge(&h);
        assert_eq!(empty.snapshot(), before);
    }

    #[test]
    fn huge_samples_do_not_overflow() {
        let mut h = Histogram::new();
        h.record(u64::MAX);
        h.record(u64::MAX - 1);
        let s = h.snapshot();
        assert_eq!(s.max, u64::MAX);
        assert_eq!(s.p99, u64::MAX);
    }
}
