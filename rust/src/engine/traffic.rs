//! Open-loop workload generator — seeded, deterministic arrival processes
//! for the always-on serving mode.
//!
//! Closed-loop driving (`serve_batch` over a fixed request list) measures
//! throughput but hides queueing: the next request is only offered once the
//! previous one finishes, so the engine is never overloaded and tail latency
//! is meaningless. Open-loop driving offers requests on a schedule that does
//! **not** react to completions — exactly how "millions of users" hit a BLAS
//! service — and is what makes the DRR scheduler, cache quotas and admission
//! budgets measurable under load. With [`TrafficConfig::lapack_fraction`]
//! set, a share of arrivals are LAPACK factorizations
//! (`Request::RandomFactor`) that the pipeline expands into dependency DAGs
//! of cached kernels, mixing graph workloads with flat BLAS in one queue.
//!
//! Everything here is deterministic given [`TrafficConfig::seed`]: the same
//! config yields bit-identical arrival times and request payloads, which is
//! what lets CI smoke runs and the overload tests pin their expectations.
//!
//! # Examples
//!
//! ```
//! use redefine_blas::engine::traffic::{self, TrafficConfig};
//!
//! let cfg = TrafficConfig {
//!     rate_rps: 5_000.0,
//!     duration_ns: 10_000_000, // 10 ms => ~50 arrivals
//!     seed: 7,
//!     ..TrafficConfig::default()
//! };
//! let a = traffic::generate(&cfg);
//! let b = traffic::generate(&cfg);
//! assert_eq!(a.len(), b.len());
//! assert!(a.iter().zip(&b).all(|(x, y)| x.at_ns == y.at_ns));
//! ```

use crate::coordinator::request::Request;
use crate::lapack::FactorKind;
use crate::util::{Mat, XorShift64};

/// Shape of the arrival process.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArrivalKind {
    /// Poisson process: independent exponential inter-arrival gaps with mean
    /// `1 / rate_rps`.
    Poisson,
    /// Bursty process: burst epochs arrive as a Poisson process at
    /// `rate_rps / size`, and each epoch delivers `size` requests with the
    /// same timestamp — the mean request rate stays `rate_rps`, but the
    /// instantaneous load hammers the admission window.
    Burst {
        /// Requests per burst epoch (clamped to >= 1).
        size: usize,
    },
}

/// Parameters of one tenant's open-loop workload.
#[derive(Debug, Clone)]
pub struct TrafficConfig {
    /// Arrival process shape.
    pub kind: ArrivalKind,
    /// Mean offered load in requests per second.
    pub rate_rps: f64,
    /// Length of the arrival window in nanoseconds; arrivals are generated
    /// in `[start_ns, start_ns + duration_ns)`.
    pub duration_ns: u64,
    /// Virtual start of this tenant's window — lets tenants churn (join the
    /// service mid-run) instead of all arriving at t = 0.
    pub start_ns: u64,
    /// PRNG seed; same seed ⇒ identical arrival sequence.
    pub seed: u64,
    /// Upper bound for drawn problem sizes (same convention as
    /// `random_workload`: sizes are `8 + below(max_n - 8)`).
    pub max_n: usize,
    /// Probability in [0, 1] that a request uses the hot shape `hot_n`
    /// instead of a fresh random size — models the skewed shape popularity
    /// the program cache exists for.
    pub hot_fraction: f64,
    /// The hot problem size.
    pub hot_n: usize,
    /// Probability in [0, 1] that an arrival is a LAPACK factorization
    /// (`Request::RandomFactor`, rotating QR → LU → Cholesky by sequence
    /// index) instead of a flat BLAS call. At the default 0.0 the gate
    /// draws nothing from the payload PRNG, so flat-BLAS sequences are
    /// bit-identical to a config without factorizations.
    pub lapack_fraction: f64,
    /// Problem size of factorization arrivals (flat BLAS sizes still draw
    /// from `max_n` / `hot_n`).
    pub lapack_n: usize,
}

impl Default for TrafficConfig {
    fn default() -> Self {
        Self {
            kind: ArrivalKind::Poisson,
            rate_rps: 500.0,
            duration_ns: 100_000_000, // 100 ms
            start_ns: 0,
            seed: 42,
            max_n: 32,
            hot_fraction: 0.5,
            hot_n: 16,
            lapack_fraction: 0.0,
            lapack_n: 24,
        }
    }
}

impl TrafficConfig {
    /// Derive tenant `i` of `tenants` from this base config: a distinct seed
    /// (so payloads and gaps differ) and a staggered `start_ns` (tenant 0
    /// starts at the base offset, the last tenant roughly half a window
    /// later) — cheap tenant churn without a separate lifecycle model.
    pub fn for_tenant(&self, i: usize, tenants: usize) -> TrafficConfig {
        let stagger = self.duration_ns / (2 * tenants.max(1) as u64);
        TrafficConfig {
            seed: self.seed.wrapping_add(0x9E37_79B9_7F4A_7C15u64.wrapping_mul(i as u64 + 1)),
            start_ns: self.start_ns + stagger * i as u64,
            ..self.clone()
        }
    }
}

/// One request with its virtual arrival timestamp (nanoseconds from the
/// start of the serving run).
#[derive(Debug)]
pub struct Arrival {
    /// Dense arrival index within the tenant's sequence (0-based); outcomes
    /// are reported back in `seq` order.
    pub seq: usize,
    /// Virtual arrival time in nanoseconds.
    pub at_ns: u64,
    /// The BLAS request offered at that instant.
    pub req: Request,
}

/// Arrival timestamps only — the renewal process without request payloads.
/// Split out so property tests can check rate/determinism over tens of
/// thousands of arrivals without materializing operand data.
pub fn arrival_times(cfg: &TrafficConfig) -> Vec<u64> {
    assert!(cfg.rate_rps > 0.0, "rate_rps must be positive");
    let mut rng = XorShift64::new(cfg.seed);
    let mut times = Vec::new();
    let end = cfg.start_ns.saturating_add(cfg.duration_ns);
    match cfg.kind {
        ArrivalKind::Poisson => {
            let mean_gap_ns = 1e9 / cfg.rate_rps;
            let mut t = cfg.start_ns as f64;
            loop {
                t += exp_gap(&mut rng, mean_gap_ns);
                if t >= end as f64 {
                    break;
                }
                times.push(t as u64);
            }
        }
        ArrivalKind::Burst { size } => {
            let size = size.max(1);
            // Burst epochs at rate / size keep the mean request rate.
            let mean_gap_ns = 1e9 * size as f64 / cfg.rate_rps;
            let mut t = cfg.start_ns as f64;
            loop {
                t += exp_gap(&mut rng, mean_gap_ns);
                if t >= end as f64 {
                    break;
                }
                for _ in 0..size {
                    times.push(t as u64);
                }
            }
        }
    }
    times
}

/// Exponential gap with the given mean; `u` in [0, 1) keeps `1 - u` in
/// (0, 1], so the log is finite and the gap non-negative.
fn exp_gap(rng: &mut XorShift64, mean_ns: f64) -> f64 {
    -(1.0 - rng.next_f64()).ln() * mean_ns
}

/// Generate the full arrival sequence: timestamps from [`arrival_times`]
/// plus per-request payloads drawn with the same five-way op mix as
/// `random_workload`, skewed towards the hot shape by
/// [`TrafficConfig::hot_fraction`]. Payload draws use an independent PRNG
/// stream, so `generate(cfg)` agrees with `arrival_times(cfg)` timestamp
/// for timestamp.
pub fn generate(cfg: &TrafficConfig) -> Vec<Arrival> {
    let times = arrival_times(cfg);
    let mut rng = XorShift64::new(cfg.seed ^ 0x5DEECE66D);
    let hot_n = cfg.hot_n.max(4);
    times
        .into_iter()
        .enumerate()
        .map(|(seq, at_ns)| {
            // Short-circuit keeps the gate from consuming a PRNG draw when
            // factorizations are off, so flat-BLAS payloads stay stable.
            if cfg.lapack_fraction > 0.0 && rng.next_f64() < cfg.lapack_fraction {
                let kind = [FactorKind::Qr, FactorKind::Lu, FactorKind::Chol][seq % 3];
                let req = Request::RandomFactor {
                    kind,
                    n: cfg.lapack_n.max(4),
                    seed: cfg.seed.wrapping_add(seq as u64),
                };
                return Arrival { seq, at_ns, req };
            }
            let n = if rng.next_f64() < cfg.hot_fraction {
                hot_n
            } else {
                8 + rng.below(cfg.max_n.saturating_sub(8).max(1))
            };
            let op_seed = cfg.seed.wrapping_add(seq as u64);
            let req = match rng.below(5) {
                0 => Request::RandomDgemm { n, seed: op_seed },
                1 => {
                    let a = Mat::random(n, n, op_seed);
                    Request::Dgemv { a, x: rng.vec(n), y: rng.vec(n) }
                }
                2 => Request::Ddot { x: rng.vec(n), y: rng.vec(n) },
                3 => {
                    let alpha = [0.5, 1.0, 1.5][rng.below(3)];
                    Request::Daxpy { alpha, x: rng.vec(n), y: rng.vec(n) }
                }
                _ => Request::Dnrm2 { x: rng.vec(n) },
            };
            Arrival { seq, at_ns, req }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn times_are_sorted_and_inside_window() {
        let cfg = TrafficConfig {
            rate_rps: 10_000.0,
            duration_ns: 50_000_000,
            start_ns: 5_000_000,
            seed: 11,
            ..TrafficConfig::default()
        };
        let times = arrival_times(&cfg);
        assert!(!times.is_empty());
        assert!(times.windows(2).all(|w| w[0] <= w[1]));
        assert!(times.iter().all(|&t| t >= cfg.start_ns && t < cfg.start_ns + cfg.duration_ns));
    }

    #[test]
    fn burst_emits_whole_groups() {
        let cfg = TrafficConfig {
            kind: ArrivalKind::Burst { size: 4 },
            rate_rps: 8_000.0,
            duration_ns: 50_000_000,
            seed: 3,
            ..TrafficConfig::default()
        };
        let times = arrival_times(&cfg);
        assert!(!times.is_empty());
        assert_eq!(times.len() % 4, 0);
        for group in times.chunks(4) {
            assert!(group.iter().all(|&t| t == group[0]), "burst members share a timestamp");
        }
    }

    #[test]
    fn generate_matches_arrival_times() {
        let cfg = TrafficConfig {
            rate_rps: 5_000.0,
            duration_ns: 20_000_000,
            seed: 9,
            ..TrafficConfig::default()
        };
        let times = arrival_times(&cfg);
        let arrivals = generate(&cfg);
        assert_eq!(times.len(), arrivals.len());
        for (i, (t, a)) in times.iter().zip(&arrivals).enumerate() {
            assert_eq!(a.seq, i);
            assert_eq!(a.at_ns, *t);
        }
    }

    #[test]
    fn hot_fraction_one_pins_every_shape() {
        let cfg = TrafficConfig {
            rate_rps: 5_000.0,
            duration_ns: 20_000_000,
            seed: 21,
            hot_fraction: 1.0,
            hot_n: 12,
            ..TrafficConfig::default()
        };
        let arrivals = generate(&cfg);
        assert!(!arrivals.is_empty());
        assert!(arrivals.iter().all(|a| a.req.n() == 12));
    }

    #[test]
    fn lapack_fraction_mixes_factorizations() {
        let base = TrafficConfig {
            rate_rps: 5_000.0,
            duration_ns: 20_000_000,
            seed: 13,
            ..TrafficConfig::default()
        };
        // Fraction 1.0: every arrival is a factorization, kinds rotate by seq.
        let all = generate(&TrafficConfig { lapack_fraction: 1.0, lapack_n: 16, ..base.clone() });
        assert!(!all.is_empty());
        assert!(all
            .iter()
            .all(|a| matches!(a.req, Request::RandomFactor { n: 16, .. })));
        assert!(matches!(all[0].req, Request::RandomFactor { kind: FactorKind::Qr, .. }));
        if all.len() > 2 {
            assert!(matches!(all[1].req, Request::RandomFactor { kind: FactorKind::Lu, .. }));
            assert!(matches!(all[2].req, Request::RandomFactor { kind: FactorKind::Chol, .. }));
        }
        // Fraction 0.0 (the default) emits no factorizations and is
        // deterministic: two generations agree payload for payload.
        let flat = generate(&base);
        assert!(flat.iter().all(|a| !matches!(a.req, Request::RandomFactor { .. })));
        let again = generate(&base);
        for (a, b) in flat.iter().zip(&again) {
            assert_eq!(a.req.name(), b.req.name());
            assert_eq!(a.req.n(), b.req.n());
        }
        // A partial mix offers both populations.
        let mixed = generate(&TrafficConfig { lapack_fraction: 0.3, ..base });
        assert!(mixed.iter().any(|a| matches!(a.req, Request::RandomFactor { .. })));
        assert!(mixed.iter().any(|a| !matches!(a.req, Request::RandomFactor { .. })));
    }

    #[test]
    fn tenant_derivation_staggers_and_reseeds() {
        let base = TrafficConfig { seed: 100, duration_ns: 80_000_000, ..TrafficConfig::default() };
        let t0 = base.for_tenant(0, 4);
        let t3 = base.for_tenant(3, 4);
        assert_eq!(t0.start_ns, base.start_ns);
        assert_eq!(t3.start_ns, base.start_ns + 3 * (base.duration_ns / 8));
        assert_ne!(t0.seed, t3.seed);
        assert_ne!(arrival_times(&t0), arrival_times(&t3));
    }
}
