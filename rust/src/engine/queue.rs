//! Weighted round-robin job queue — the fair scheduler of the shared
//! engine pool.
//!
//! One lane per tenant. Workers pop in WRR order: the scheduler visits
//! lanes cyclically and serves up to `weight` items from a lane before
//! moving to the next, so a tenant flooding its lane (a large DGEMM batch
//! queueing hundreds of tile kernels) cannot starve another tenant's
//! Level-1 traffic — every backlogged lane is served at least `weight`
//! items per round. A single lane degenerates to plain FIFO, which is what
//! keeps a standalone single-tenant coordinator's dispatch order identical
//! to the pre-engine pool.
//!
//! The queue is deliberately dumb about *time*: fairness is defined over
//! dispatch slots, not simulated cycles, because the simulated cost of a
//! job is only known after it runs. Weights bound relative service rates
//! whenever lanes contend.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

struct Lane<T> {
    weight: u64,
    items: VecDeque<T>,
}

struct State<T> {
    lanes: Vec<Lane<T>>,
    /// Lane currently being served by the round-robin scan.
    cursor: usize,
    /// Items the cursor lane may still take before the scan advances.
    credit: u64,
    /// False once `close()` ran: pops drain the backlog, then return `None`.
    open: bool,
}

/// Multi-producer multi-consumer queue with weighted round-robin lane
/// scheduling. Producers push onto their own lane; consumers (pool
/// workers) pop in WRR order across all lanes.
pub(crate) struct WrrQueue<T> {
    state: Mutex<State<T>>,
    ready: Condvar,
}

impl<T> WrrQueue<T> {
    pub fn new() -> Self {
        Self {
            state: Mutex::new(State { lanes: Vec::new(), cursor: 0, credit: 0, open: true }),
            ready: Condvar::new(),
        }
    }

    /// Register a new lane with scheduling weight `weight` (≥ 1); returns
    /// its lane id. Lanes are never removed — a tenant that goes away just
    /// leaves an empty lane, which the scheduler skips for free.
    pub fn add_lane(&self, weight: u64) -> usize {
        assert!(weight >= 1, "lane weight must be at least 1");
        let mut st = self.state.lock().expect("wrr queue poisoned");
        st.lanes.push(Lane { weight, items: VecDeque::new() });
        st.lanes.len() - 1
    }

    /// Enqueue `item` on `lane` and wake one waiting consumer.
    pub fn push(&self, lane: usize, item: T) {
        let mut st = self.state.lock().expect("wrr queue poisoned");
        assert!(st.open, "push after close");
        st.lanes[lane].items.push_back(item);
        drop(st);
        self.ready.notify_one();
    }

    /// Dequeue the next item in weighted round-robin order, blocking while
    /// the queue is open but empty. Returns `None` once the queue is
    /// closed *and* fully drained.
    pub fn pop(&self) -> Option<T> {
        let mut st = self.state.lock().expect("wrr queue poisoned");
        loop {
            if let Some(item) = Self::pop_locked(&mut st) {
                return Some(item);
            }
            if !st.open {
                return None;
            }
            st = self.ready.wait(st).expect("wrr queue poisoned");
        }
    }

    /// Close the queue: producers may no longer push, the backlog still
    /// drains, and blocked consumers wake up (to drain or exit).
    pub fn close(&self) {
        self.state.lock().expect("wrr queue poisoned").open = false;
        self.ready.notify_all();
    }

    /// The WRR scan. Terminates because it only loops while some lane is
    /// non-empty, and every iteration either serves an item or advances
    /// the cursor past an empty lane (of which there are finitely many).
    fn pop_locked(st: &mut State<T>) -> Option<T> {
        if st.lanes.iter().all(|l| l.items.is_empty()) {
            return None;
        }
        loop {
            if st.credit == 0 {
                st.cursor = (st.cursor + 1) % st.lanes.len();
                st.credit = st.lanes[st.cursor].weight;
            }
            if let Some(item) = st.lanes[st.cursor].items.pop_front() {
                st.credit -= 1;
                return Some(item);
            }
            st.credit = 0;
        }
    }
}

impl<T> Default for WrrQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_lane_is_fifo() {
        let q = WrrQueue::new();
        let lane = q.add_lane(1);
        for i in 0..10 {
            q.push(lane, i);
        }
        for want in 0..10 {
            assert_eq!(q.pop(), Some(want));
        }
    }

    #[test]
    fn close_drains_backlog_then_ends() {
        let q = WrrQueue::new();
        let lane = q.add_lane(1);
        q.push(lane, 7);
        q.close();
        assert_eq!(q.pop(), Some(7));
        assert_eq!(q.pop(), None);
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn pop_blocks_until_a_push_arrives() {
        let q = std::sync::Arc::new(WrrQueue::new());
        let lane = q.add_lane(1);
        let q2 = std::sync::Arc::clone(&q);
        let h = std::thread::spawn(move || q2.pop());
        std::thread::sleep(std::time::Duration::from_millis(20));
        q.push(lane, 42);
        assert_eq!(h.join().expect("popper thread"), Some(42));
    }

    /// The no-starvation property: however much one lane floods, a
    /// backlogged sibling lane is served every round — with equal weights,
    /// after any 2k + 2 dispatches the light lane has been served at
    /// least k times (while it still has backlog).
    #[test]
    fn flooded_lane_cannot_starve_the_other() {
        let q = WrrQueue::new();
        let flood = q.add_lane(1);
        let light = q.add_lane(1);
        for i in 0..100 {
            q.push(flood, (flood, i));
        }
        for i in 0..10 {
            q.push(light, (light, i));
        }
        let mut seen_light = 0u64;
        for step in 0..110u64 {
            let (lane, _) = q.pop().expect("queued item");
            if lane == light {
                seen_light += 1;
            }
            if seen_light < 10 {
                assert!(
                    seen_light >= (step / 2).saturating_sub(1),
                    "light lane starved: served {seen_light} in {} dispatches",
                    step + 1
                );
            }
        }
        assert_eq!(seen_light, 10, "every light item must eventually dispatch");
    }

    #[test]
    fn weights_bias_service_proportionally() {
        let q = WrrQueue::new();
        let heavy = q.add_lane(3);
        let light = q.add_lane(1);
        for i in 0..60 {
            q.push(heavy, (heavy, i));
        }
        for i in 0..20 {
            q.push(light, (light, i));
        }
        // While both lanes have backlog every full round serves 3 heavy +
        // 1 light items, so the first 40 dispatches split exactly 30/10.
        let mut heavy_served = 0;
        for _ in 0..40 {
            let (lane, _) = q.pop().expect("queued item");
            if lane == heavy {
                heavy_served += 1;
            }
        }
        assert_eq!(heavy_served, 30, "weight-3 lane must take 3 of every 4 dispatches");
    }

    #[test]
    fn items_within_a_lane_stay_fifo_under_contention() {
        let q = WrrQueue::new();
        let a = q.add_lane(2);
        let b = q.add_lane(1);
        for i in 0..30 {
            q.push(a, (a, i));
            q.push(b, (b, i));
        }
        let mut next = [0; 2];
        for _ in 0..60 {
            let (lane, i) = q.pop().expect("queued item");
            assert_eq!(i, next[lane], "lane {lane} reordered");
            next[lane] += 1;
        }
    }
}
