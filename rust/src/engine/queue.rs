//! Fair job queue of the shared engine pool: weighted round-robin over
//! dispatch slots, or deficit round-robin over estimated simulated cycles.
//!
//! One lane per tenant. Every queued item carries a **cost** (the
//! submitter's estimate of the simulated cycles the job will burn — see
//! `Job::cost_estimate`), and the queue supports two currencies of
//! fairness, selected by [`SchedPolicy`]:
//!
//! * [`SchedPolicy::Slots`] — the original weighted round-robin: the
//!   scheduler visits lanes cyclically and serves up to `weight` *items*
//!   from a lane before moving on. Simple and starvation-free, but blind
//!   to cost: a tenant whose items are 56×56 DGEMM tile kernels receives
//!   orders of magnitude more simulated cycles per slot than a tenant
//!   queueing DDOT kernels. Kept reachable as the pinned baseline.
//! * [`SchedPolicy::Cycles`] — deficit round-robin (DRR) over the cost
//!   estimates: each backlogged lane banks a cycle *deficit* that accrues
//!   per scheduler round in proportion to its weight, and a lane may only
//!   dispatch its head item once its balance covers the item's cost. Over
//!   any contended interval, the simulated-cycle service of backlogged
//!   lanes converges to the weight ratio (within one maximal item cost per
//!   lane — the classic DRR bound), regardless of how mismatched the
//!   per-item costs are. Idle lanes forfeit their balance, so a tenant
//!   cannot bank credit while absent. Instead of spinning the round clock
//!   one quantum at a time, the scheduler fast-forwards it by the minimal
//!   whole number of rounds that makes some lane solvent — identical
//!   accrual, O(lanes) work per dispatch.
//!
//! Under either policy a single lane degenerates to plain FIFO, which is
//! what keeps a standalone single-tenant coordinator's dispatch order
//! identical to the pre-engine pool. Per-lane cumulative dispatched cost
//! is tracked ([`WrrQueue::lane_served`]) so fairness is observable, not
//! just implemented.
//!
//! The queue never sees a dependency: factorization DAG nodes are held
//! back by the coordinator's pipeline until their predecessors complete,
//! so every lane item is dispatchable — DRR accounting stays a pure
//! cost-per-lane ledger with no notion of blocked work.
//!
//! Costs are **repriced at dispatch time** when the queue carries a
//! repricer ([`WrrQueue::with_repricer`]): a job whose kernel memoized its
//! real `PeStats.cycles` *while the job sat queued* is debited (and
//! telemetered) by the sharpened cost, not the stale submission-time
//! estimate — the first few jobs of a new shape no longer distort DRR
//! fairness just because they were priced before the timing pass landed.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

/// The fairness currency of the shared engine's job scheduler.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SchedPolicy {
    /// Weighted round-robin over dispatch slots: `weight` items per lane
    /// per round. Cost-blind — the PR 4 baseline.
    Slots,
    /// Deficit round-robin over estimated simulated cycles: `weight`
    /// cycles of deficit per lane per round. Cost-aware — the default.
    #[default]
    Cycles,
}

struct Lane<T> {
    weight: u64,
    /// Queued (cost, item) pairs, FIFO within the lane.
    items: VecDeque<(u64, T)>,
    /// DRR cycle balance: accrued but not yet spent service. Reset when
    /// the lane goes idle (no banking while absent).
    deficit: u64,
    /// Cumulative cost of items dispatched from this lane (telemetry).
    served: u64,
}

struct State<T> {
    lanes: Vec<Lane<T>>,
    /// Lane currently being served by the round-robin scan.
    cursor: usize,
    /// Slots policy: items the cursor lane may still take this turn.
    credit: u64,
    /// False once `close()` ran: pops drain the backlog, then return `None`.
    open: bool,
}

/// Multi-producer multi-consumer queue with weighted fair lane scheduling.
/// Producers push onto their own lane; consumers (pool workers) pop in
/// policy order across all lanes.
pub(crate) struct WrrQueue<T> {
    policy: SchedPolicy,
    state: Mutex<State<T>>,
    ready: Condvar,
    /// Optional dispatch-time cost refresher: re-reads an item's current
    /// cost just before the scheduler commits to it, so estimates that
    /// sharpened while the item sat queued (a kernel's timing pass
    /// memoizing mid-queue) are debited at their real value.
    repricer: Option<Box<dyn Fn(&T) -> u64 + Send + Sync>>,
}

impl<T> WrrQueue<T> {
    pub fn new(policy: SchedPolicy) -> Self {
        Self {
            policy,
            state: Mutex::new(State { lanes: Vec::new(), cursor: 0, credit: 0, open: true }),
            ready: Condvar::new(),
            repricer: None,
        }
    }

    /// Install a dispatch-time repricer (builder style, before the queue
    /// is shared). With one installed, every solvency check, deficit
    /// debit and `lane_served` tally uses `f(item)` evaluated at dispatch
    /// time instead of the frozen submission-time cost.
    pub fn with_repricer(mut self, f: impl Fn(&T) -> u64 + Send + Sync + 'static) -> Self {
        self.repricer = Some(Box::new(f));
        self
    }

    /// The scheduling policy this queue dispatches under.
    pub fn policy(&self) -> SchedPolicy {
        self.policy
    }

    /// Register a new lane with scheduling weight `weight` (≥ 1); returns
    /// its lane id. Lanes are never removed — a tenant that goes away just
    /// leaves an empty lane, which the scheduler skips for free.
    pub fn add_lane(&self, weight: u64) -> usize {
        assert!(weight >= 1, "lane weight must be at least 1");
        let mut st = self.state.lock().expect("wrr queue poisoned");
        if st.lanes.is_empty() {
            // Cold start: the scan begins at lane 0 with a full slot
            // credit, so the first tenant is served first in the first
            // round (the cursor used to advance before serving, pushing
            // lane 0 to the back of round one).
            st.cursor = 0;
            st.credit = weight;
        }
        st.lanes.push(Lane { weight, items: VecDeque::new(), deficit: 0, served: 0 });
        st.lanes.len() - 1
    }

    /// Enqueue `item` on `lane` with estimated cost `cost` (simulated
    /// cycles; clamped to ≥ 1 so a zero estimate cannot starve the DRR
    /// accounting) and wake one waiting consumer.
    pub fn push(&self, lane: usize, cost: u64, item: T) {
        let mut st = self.state.lock().expect("wrr queue poisoned");
        assert!(st.open, "push after close");
        st.lanes[lane].items.push_back((cost.max(1), item));
        drop(st);
        self.ready.notify_one();
    }

    /// Dequeue the next item in fair order, blocking while the queue is
    /// open but empty. Returns `None` once the queue is closed *and*
    /// fully drained.
    pub fn pop(&self) -> Option<T> {
        let mut st = self.state.lock().expect("wrr queue poisoned");
        loop {
            let popped = match self.policy {
                SchedPolicy::Slots => self.pop_slots(&mut st),
                SchedPolicy::Cycles => self.pop_cycles(&mut st),
            };
            if let Some(item) = popped {
                return Some(item);
            }
            if !st.open {
                return None;
            }
            st = self.ready.wait(st).expect("wrr queue poisoned");
        }
    }

    /// Close the queue: producers may no longer push, the backlog still
    /// drains, and blocked consumers wake up (to drain or exit).
    pub fn close(&self) {
        self.state.lock().expect("wrr queue poisoned").open = false;
        self.ready.notify_all();
    }

    /// Per-lane (weight, cumulative dispatched cost) snapshot — the
    /// observable the proportional-service assertions read.
    pub fn lane_served(&self) -> Vec<(u64, u64)> {
        let st = self.state.lock().expect("wrr queue poisoned");
        st.lanes.iter().map(|l| (l.weight, l.served)).collect()
    }

    /// Refresh the stored cost of `lane`'s head item from the repricer, if
    /// one is installed — the executed-cycle feedback point: an estimate
    /// frozen at submission is replaced by whatever the job is known to
    /// cost *now* (clamped ≥ 1, like pushes).
    fn reprice_head(&self, lane: &mut Lane<T>) {
        if let Some(reprice) = &self.repricer {
            if let Some((cost, item)) = lane.items.front_mut() {
                *cost = reprice(item).max(1);
            }
        }
    }

    /// The slot-WRR scan. Terminates because it only runs while some lane
    /// is non-empty, and every iteration either serves an item or advances
    /// the cursor (each advance refills the credit, so a non-empty lane is
    /// served within one full cycle of the lanes).
    fn pop_slots(&self, st: &mut State<T>) -> Option<T> {
        if st.lanes.iter().all(|l| l.items.is_empty()) {
            return None;
        }
        loop {
            if st.credit > 0 {
                // Slots are cost-blind for *scheduling*, but the service
                // telemetry must still record the dispatch-time cost.
                self.reprice_head(&mut st.lanes[st.cursor]);
                if let Some((cost, item)) = st.lanes[st.cursor].items.pop_front() {
                    st.credit -= 1;
                    st.lanes[st.cursor].served += cost;
                    return Some(item);
                }
            }
            st.cursor = (st.cursor + 1) % st.lanes.len();
            st.credit = st.lanes[st.cursor].weight;
        }
    }

    /// The DRR scan. A lane dispatches while its banked deficit covers its
    /// head item's cost; when no backlogged lane is solvent, the round
    /// clock fast-forwards: every backlogged lane accrues `k · weight`
    /// cycles where `k` is the minimal number of whole rounds that makes
    /// at least one lane solvent (so the loop terminates after one
    /// top-up). Idle lanes forfeit their balance. Head costs are repriced
    /// as the scan visits each lane, so solvency, the deficit debit and
    /// the round top-up all price jobs at dispatch-time accuracy.
    fn pop_cycles(&self, st: &mut State<T>) -> Option<T> {
        if st.lanes.iter().all(|l| l.items.is_empty()) {
            return None;
        }
        loop {
            // One round-robin scan from the cursor for a solvent lane.
            for _ in 0..st.lanes.len() {
                let lane = &mut st.lanes[st.cursor];
                self.reprice_head(lane);
                match lane.items.front() {
                    Some(&(cost, _)) if cost <= lane.deficit => {
                        let (cost, item) = lane.items.pop_front().expect("front checked above");
                        lane.deficit -= cost;
                        lane.served += cost;
                        // The lane keeps the cursor only while its balance
                        // covers its next item (FIFO burst within
                        // deficit); otherwise its turn ends — a drained
                        // lane also forfeits its balance.
                        self.reprice_head(lane);
                        match lane.items.front() {
                            Some(&(next, _)) if next <= lane.deficit => {}
                            Some(_) => st.cursor = (st.cursor + 1) % st.lanes.len(),
                            None => {
                                lane.deficit = 0;
                                st.cursor = (st.cursor + 1) % st.lanes.len();
                            }
                        }
                        return Some(item);
                    }
                    Some(_) => {}
                    None => lane.deficit = 0,
                }
                st.cursor = (st.cursor + 1) % st.lanes.len();
            }
            // No backlogged lane can afford its head item: advance the
            // round clock. `need / weight` rounds (rounded up) make lane
            // `i` solvent; the minimum over backlogged lanes is granted to
            // all of them at once — proportional accrual, fast-forwarded.
            let k = st
                .lanes
                .iter()
                .filter(|l| !l.items.is_empty())
                .map(|l| {
                    let head = l.items.front().expect("filtered to backlogged").0;
                    (head - l.deficit).div_ceil(l.weight)
                })
                .min()
                .expect("pop_cycles runs only while some lane is backlogged");
            for lane in st.lanes.iter_mut().filter(|l| !l.items.is_empty()) {
                lane.deficit = lane.deficit.saturating_add(k.saturating_mul(lane.weight));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A DGEMM tile kernel's ballpark simulated cost, vs a DDOT kernel's —
    /// the orders-of-magnitude mismatch the DRR scheduler exists for.
    const TILE_COST: u64 = 120_000;
    const DDOT_COST: u64 = 600;

    #[test]
    fn single_lane_is_fifo_under_both_policies() {
        for policy in [SchedPolicy::Slots, SchedPolicy::Cycles] {
            let q = WrrQueue::new(policy);
            assert_eq!(q.policy(), policy);
            let lane = q.add_lane(1);
            for i in 0..10 {
                q.push(lane, 1 + (i % 3), i);
            }
            for want in 0..10 {
                assert_eq!(q.pop(), Some(want), "{policy:?}");
            }
        }
    }

    #[test]
    fn close_drains_backlog_then_ends() {
        let q = WrrQueue::new(SchedPolicy::Cycles);
        let lane = q.add_lane(1);
        q.push(lane, 5, 7);
        q.close();
        assert_eq!(q.pop(), Some(7));
        assert_eq!(q.pop(), None);
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn pop_blocks_until_a_push_arrives() {
        let q = std::sync::Arc::new(WrrQueue::new(SchedPolicy::Cycles));
        let lane = q.add_lane(1);
        let q2 = std::sync::Arc::clone(&q);
        let h = std::thread::spawn(move || q2.pop());
        std::thread::sleep(std::time::Duration::from_millis(20));
        q.push(lane, 3, 42);
        assert_eq!(h.join().expect("popper thread"), Some(42));
    }

    /// The cold-start lane-bias fix: the very first dispatch must come
    /// from lane 0 (the standalone/first tenant), not from lane 1 — the
    /// old scan advanced the cursor before serving, so lane 0 was served
    /// *last* in the first round.
    #[test]
    fn cold_start_serves_lane_zero_first() {
        let q = WrrQueue::new(SchedPolicy::Slots);
        let a = q.add_lane(1);
        let b = q.add_lane(1);
        for i in 0..2 {
            q.push(a, 1, (a, i));
            q.push(b, 1, (b, i));
        }
        let order: Vec<_> = (0..4).map(|_| q.pop().expect("queued item")).collect();
        assert_eq!(order, vec![(a, 0), (b, 0), (a, 1), (b, 1)], "lane 0 must open the round");
    }

    /// Same property under DRR: with equal weights and equal costs the
    /// round top-up makes every lane solvent at once, and the scan starts
    /// at lane 0.
    #[test]
    fn cold_start_serves_lane_zero_first_under_drr() {
        let q = WrrQueue::new(SchedPolicy::Cycles);
        let a = q.add_lane(1);
        let b = q.add_lane(1);
        q.push(a, 10, (a, 0));
        q.push(b, 10, (b, 0));
        assert_eq!(q.pop(), Some((a, 0)), "lane 0 must open the round");
        assert_eq!(q.pop(), Some((b, 0)));
    }

    /// The no-starvation property: however much one lane floods, a
    /// backlogged sibling lane is served every round — with equal weights,
    /// after any 2k + 2 dispatches the light lane has been served at
    /// least k times (while it still has backlog).
    #[test]
    fn flooded_lane_cannot_starve_the_other() {
        let q = WrrQueue::new(SchedPolicy::Slots);
        let flood = q.add_lane(1);
        let light = q.add_lane(1);
        for i in 0..100 {
            q.push(flood, 1, (flood, i));
        }
        for i in 0..10 {
            q.push(light, 1, (light, i));
        }
        let mut seen_light = 0u64;
        for step in 0..110u64 {
            let (lane, _) = q.pop().expect("queued item");
            if lane == light {
                seen_light += 1;
            }
            if seen_light < 10 {
                assert!(
                    seen_light >= (step / 2).saturating_sub(1),
                    "light lane starved: served {seen_light} in {} dispatches",
                    step + 1
                );
            }
        }
        assert_eq!(seen_light, 10, "every light item must eventually dispatch");
    }

    #[test]
    fn weights_bias_slot_service_proportionally() {
        let q = WrrQueue::new(SchedPolicy::Slots);
        let heavy = q.add_lane(3);
        let light = q.add_lane(1);
        for i in 0..60 {
            q.push(heavy, 1, (heavy, i));
        }
        for i in 0..20 {
            q.push(light, 1, (light, i));
        }
        // While both lanes have backlog every full round serves 3 heavy +
        // 1 light items, so the first 40 dispatches split exactly 30/10.
        let mut heavy_served = 0;
        for _ in 0..40 {
            let (lane, _) = q.pop().expect("queued item");
            if lane == heavy {
                heavy_served += 1;
            }
        }
        assert_eq!(heavy_served, 30, "weight-3 lane must take 3 of every 4 dispatches");
    }

    #[test]
    fn items_within_a_lane_stay_fifo_under_contention() {
        for policy in [SchedPolicy::Slots, SchedPolicy::Cycles] {
            let q = WrrQueue::new(policy);
            let a = q.add_lane(2);
            let b = q.add_lane(1);
            for i in 0..30 {
                q.push(a, 7, (a, i));
                q.push(b, 3, (b, i));
            }
            let mut next = [0; 2];
            for _ in 0..60 {
                let (lane, i) = q.pop().expect("queued item");
                assert_eq!(i, next[lane], "{policy:?}: lane {lane} reordered");
                next[lane] += 1;
            }
        }
    }

    /// The tentpole acceptance property: two backlogged lanes with weights
    /// 1:3 and deliberately mismatched per-item costs — one flooding
    /// DGEMM-tile-sized jobs, one DDOT-sized jobs — must receive
    /// simulated-cycle service within 25% of 1:3 under the cycles
    /// scheduler.
    #[test]
    fn drr_cycle_service_tracks_weights_despite_cost_mismatch() {
        let q = WrrQueue::new(SchedPolicy::Cycles);
        let gemm = q.add_lane(1); // few huge items
        let ddot = q.add_lane(3); // many tiny items
        for i in 0..12 {
            q.push(gemm, TILE_COST, (gemm, i));
        }
        for i in 0..3_200 {
            q.push(ddot, DDOT_COST, (ddot, i));
        }
        // Dispatch until the DDOT lane has been served 3000 items; both
        // lanes stay backlogged throughout the measured window.
        let mut ddot_items = 0u64;
        while ddot_items < 3_000 {
            let (lane, _) = q.pop().expect("queued item");
            if lane == ddot {
                ddot_items += 1;
            }
        }
        let served = q.lane_served();
        let (gemm_cycles, ddot_cycles) = (served[gemm].1, served[ddot].1);
        assert_eq!(ddot_cycles, 3_000 * DDOT_COST);
        let ratio = ddot_cycles as f64 / gemm_cycles as f64;
        assert!(
            (2.25..=3.75).contains(&ratio),
            "cycle service must track the 1:3 weights within 25%: \
             gemm {gemm_cycles}, ddot {ddot_cycles}, ratio {ratio:.2}"
        );
    }

    /// The same workload under the slot-WRR baseline demonstrably violates
    /// cycle proportionality: slots are cost-blind, so the DGEMM lane
    /// receives orders of magnitude more simulated cycles than its 1:3
    /// weight share.
    #[test]
    fn slot_wrr_violates_cycle_proportionality_on_mismatched_costs() {
        let q = WrrQueue::new(SchedPolicy::Slots);
        let gemm = q.add_lane(1);
        let ddot = q.add_lane(3);
        for i in 0..100 {
            q.push(gemm, TILE_COST, (gemm, i));
        }
        for i in 0..3_200 {
            q.push(ddot, DDOT_COST, (ddot, i));
        }
        // 40 full rounds: 40 gemm items + 120 ddot items, both backlogged.
        for _ in 0..160 {
            let _ = q.pop().expect("queued item");
        }
        let served = q.lane_served();
        let (gemm_cycles, ddot_cycles) = (served[gemm].1, served[ddot].1);
        assert_eq!(gemm_cycles, 40 * TILE_COST);
        assert_eq!(ddot_cycles, 120 * DDOT_COST);
        let ratio = ddot_cycles as f64 / gemm_cycles as f64;
        assert!(
            ratio < 2.25,
            "slot WRR should hand the heavy lane far more than its cycle share \
             (got ratio {ratio:.3}, weights say 3.0)"
        );
    }

    /// The executed-cycle feedback bugfix: a shape's cost estimate that
    /// sharpens *while its jobs sit queued* (the kernel's timing pass
    /// memoizing mid-queue) must be what the scheduler debits and
    /// telemeters at dispatch — not the stale submission-time estimate.
    #[test]
    fn dispatch_time_repricing_reads_the_sharpened_estimate() {
        use std::sync::atomic::{AtomicU64, Ordering};
        use std::sync::Arc;
        const COLD_EST: u64 = 100; // decoded op count
        const REAL_COST: u64 = 12_000; // memoized PeStats.cycles
        for policy in [SchedPolicy::Cycles, SchedPolicy::Slots] {
            // The "memo": every queued job of this shape prices at
            // whatever the memo currently says.
            let memo = Arc::new(AtomicU64::new(COLD_EST));
            let m = Arc::clone(&memo);
            let q = WrrQueue::new(policy).with_repricer(move |_: &u64| m.load(Ordering::Relaxed));
            let lane = q.add_lane(1);
            q.push(lane, COLD_EST, 1);
            q.push(lane, COLD_EST, 2);
            // The shape's schedule memoizes while both jobs are queued.
            memo.store(REAL_COST, Ordering::Relaxed);
            assert_eq!(q.pop(), Some(1), "{policy:?}");
            assert_eq!(q.pop(), Some(2), "{policy:?}");
            let served = q.lane_served();
            assert_eq!(
                served[lane].1,
                2 * REAL_COST,
                "{policy:?}: lane must be debited the dispatch-time cost, \
                 not the frozen submission estimate"
            );
        }
    }

    /// Without a repricer the pre-fix behavior is preserved: submission
    /// costs stay frozen (the baseline the existing tests pin).
    #[test]
    fn without_a_repricer_submission_costs_stay_frozen() {
        let q = WrrQueue::new(SchedPolicy::Cycles);
        let lane = q.add_lane(1);
        q.push(lane, 70, 1);
        q.push(lane, 30, 2);
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.lane_served()[lane].1, 100);
    }

    /// DRR must not let an idle lane bank credit: a lane that was empty
    /// while another served gets no retroactive burst when it wakes up.
    #[test]
    fn idle_lane_forfeits_its_deficit() {
        let q = WrrQueue::new(SchedPolicy::Cycles);
        let a = q.add_lane(1);
        let b = q.add_lane(1);
        for i in 0..6 {
            q.push(a, 100, (a, i));
        }
        // b is idle while a drains half its backlog.
        for _ in 0..3 {
            assert_eq!(q.pop().map(|(l, _)| l), Some(a));
        }
        for i in 0..4 {
            q.push(b, 100, (b, i));
        }
        // From here service alternates: b holds no banked balance from its
        // idle period, so it cannot burst ahead of a.
        let mut a_seen = 0;
        let mut b_seen = 0;
        for step in 0..6 {
            let (lane, _) = q.pop().expect("queued item");
            if lane == a {
                a_seen += 1;
            } else {
                b_seen += 1;
            }
            assert!(
                (a_seen as i64 - b_seen as i64).abs() <= 1,
                "step {step}: idle lane banked credit (a {a_seen}, b {b_seen})"
            );
        }
    }
}
