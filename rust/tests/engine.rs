//! Multi-tenant engine tests: single-tenant equivalence with the
//! standalone coordinator, concurrent multi-tenant serving ≡ isolated
//! per-tenant loops (values/cycles/energy), cross-tenant program-cache
//! sharing (the PR acceptance invariant), shared-LRU eviction under
//! cross-tenant churn, and stat partitioning.
//!
//! The fair scheduler's no-starvation property is pinned by unit tests on
//! the WRR queue itself (`engine::queue`); here we pin the end-to-end
//! consequences: every tenant's batch completes with results identical to
//! an isolated coordinator's, regardless of what the other tenants do.

use redefine_blas::coordinator::{
    request::{random_workload, repeated_gemm_workload},
    Coordinator, CoordinatorConfig, Response,
};
use redefine_blas::engine::{Engine, EngineConfig, SchedPolicy};
use redefine_blas::pe::AeLevel;
use redefine_blas::util::Mat;

fn cfg(ae: AeLevel, b: usize) -> CoordinatorConfig {
    CoordinatorConfig {
        ae,
        b,
        artifact_dir: "/nonexistent".into(),
        verify: false,
        ..CoordinatorConfig::default()
    }
}

/// Field-by-field response equality (values + simulated cost report).
fn assert_same_responses(lhs: &[Response], rhs: &[Response]) {
    assert_eq!(lhs.len(), rhs.len());
    for (i, (a, b)) in lhs.iter().zip(rhs).enumerate() {
        assert_eq!(a.op, b.op, "request {i}");
        assert_eq!(a.n, b.n, "request {i}");
        assert_eq!(a.source, b.source, "request {i}");
        assert_eq!(a.cycles, b.cycles, "request {i}: simulated cycles must be identical");
        assert_eq!(a.energy_j, b.energy_j, "request {i}");
        assert_eq!(a.matrix, b.matrix, "request {i}: matrix payload");
        assert_eq!(a.vector, b.vector, "request {i}: vector payload");
        assert_eq!(a.scalar, b.scalar, "request {i}: scalar payload");
    }
}

#[test]
fn single_tenant_engine_matches_standalone_coordinator() {
    // The PR acceptance invariant: routing through the engine changes
    // nothing for a single tenant — values, cycles, energy and stats all
    // match the standalone coordinator (which is itself pinned against
    // the sequential reference loop).
    let reqs = random_workload(8, 24, 4_242);
    let mut standalone = Coordinator::new(cfg(AeLevel::Ae5, 2));
    let r_standalone = standalone.serve_batch(reqs.clone());
    let engine = Engine::new(EngineConfig { workers: 4, ..EngineConfig::default() });
    let mut tenant = engine.tenant(cfg(AeLevel::Ae5, 2));
    let r_tenant = tenant.serve_batch(reqs);
    assert_same_responses(&r_standalone, &r_tenant);
    assert_eq!(standalone.cache_stats(), tenant.cache_stats());
    // Tier splits (replays vs combined) may vary with worker races, but
    // the per-kind job counts are exact.
    let (js, jt) = (standalone.pool_job_counts(), tenant.pool_job_counts());
    assert_eq!((js.gemm_tiles, js.gemv, js.level1), (jt.gemm_tiles, jt.gemv, jt.level1));
    assert_eq!(js.replays + js.combined_runs, jt.replays + jt.combined_runs);
    // Single tenant: the tenant slice IS the engine total.
    assert_eq!(tenant.cache_stats(), engine.cache_stats());
    assert_eq!(tenant.pool_job_counts(), engine.pool_job_counts());
}

#[test]
fn concurrent_tenants_match_isolated_coordinators() {
    // Two tenants at different AE levels and weights, serving
    // concurrently on one shared pool, must each produce exactly what an
    // isolated coordinator produces for the same workload — the
    // multi-tenant ≡ interleaved-sequential invariant (simulated timing
    // is independent of host scheduling and of the other tenant).
    let wa = random_workload(6, 24, 1_001);
    let wb = random_workload(6, 24, 2_002);
    let mut ia = Coordinator::new(cfg(AeLevel::Ae5, 2));
    let ra_ref = ia.serve_batch(wa.clone());
    let mut ib = Coordinator::new(cfg(AeLevel::Ae3, 2));
    let rb_ref = ib.serve_batch(wb.clone());

    let engine = Engine::new(EngineConfig { workers: 4, ..EngineConfig::default() });
    let mut ta = engine.tenant(cfg(AeLevel::Ae5, 2));
    let mut tb = engine.tenant_weighted(cfg(AeLevel::Ae3, 2), 3);
    let (ra, rb) = std::thread::scope(|s| {
        let ha = s.spawn(|| ta.serve_batch(wa));
        let hb = s.spawn(|| tb.serve_batch(wb));
        (ha.join().expect("tenant a"), hb.join().expect("tenant b"))
    });
    assert_same_responses(&ra_ref, &ra);
    assert_same_responses(&rb_ref, &rb);
    // The shared totals are exactly the sum of the tenant slices.
    let (sa, sb, total) = (ta.cache_stats(), tb.cache_stats(), engine.cache_stats());
    assert_eq!(sa.hits + sb.hits, total.hits);
    assert_eq!(sa.misses + sb.misses, total.misses);
    let (ja, jb, jt) = (ta.pool_job_counts(), tb.pool_job_counts(), engine.pool_job_counts());
    assert_eq!(ja.gemm_tiles + jb.gemm_tiles, jt.gemm_tiles);
    assert_eq!(ja.gemv + jb.gemv, jt.gemv);
    assert_eq!(ja.level1 + jb.level1, jt.level1);
}

#[test]
fn cross_tenant_cache_hits_exceed_isolated_coordinators() {
    // The tentpole acceptance criterion: a 2-tenant repeated-shape
    // workload must show *cross-tenant* program-cache hits — shared
    // CacheStats.hits strictly greater than the sum two isolated
    // coordinators would see, because the second tenant never pays the
    // emission miss.
    let k = 4;
    let mut iso_hits = 0;
    for seed in [10u64, 20] {
        let mut co = Coordinator::new(cfg(AeLevel::Ae5, 2));
        let _ = co.serve_batch(repeated_gemm_workload(k, 16, seed));
        iso_hits += co.cache_stats().hits;
    }
    assert_eq!(iso_hits, 2 * (k as u64 - 1), "each isolated tenant pays its own miss");

    let engine = Engine::new(EngineConfig { workers: 4, ..EngineConfig::default() });
    let mut ta = engine.tenant(cfg(AeLevel::Ae5, 2));
    let mut tb = engine.tenant(cfg(AeLevel::Ae5, 2));
    let _ = ta.serve_batch(repeated_gemm_workload(k, 16, 10));
    let _ = tb.serve_batch(repeated_gemm_workload(k, 16, 20));
    let shared = engine.cache_stats();
    assert_eq!(shared.misses, 1, "one emission serves both tenants: {shared:?}");
    assert_eq!(shared.hits, 2 * k as u64 - 1, "every other request rides it: {shared:?}");
    assert!(
        shared.hits > iso_hits,
        "shared cache must add cross-tenant hits: {} vs isolated {iso_hits}",
        shared.hits
    );
    // Tenant tallies partition the shared totals; the riding tenant never
    // misses.
    let (sa, sb) = (ta.cache_stats(), tb.cache_stats());
    assert_eq!(sa.hits + sb.hits, shared.hits);
    assert_eq!(sa.misses + sb.misses, shared.misses);
    assert_eq!(sb.misses, 0, "tenant b must never emit: {sb:?}");
    assert_eq!(sb.hits, k as u64, "all of tenant b's requests are warm: {sb:?}");
}

#[test]
fn shared_lru_eviction_survives_cross_tenant_churn() {
    // Two tenants alternating shapes under a capacity-1 shared cache:
    // every switch evicts the other tenant's kernel, values stay correct,
    // residency stays bounded, and eviction counts partition.
    let engine = Engine::new(EngineConfig {
        workers: 4,
        cache_capacity: Some(1),
        ..EngineConfig::default()
    });
    let mut ta = engine.tenant(cfg(AeLevel::Ae5, 2));
    let mut tb = engine.tenant(cfg(AeLevel::Ae5, 2));
    for round in 0..3u64 {
        for (which, n) in [(0usize, 8usize), (1, 16)] {
            let a = Mat::random(n, n, 100 + round * 10 + which as u64);
            let b = Mat::random(n, n, 200 + round * 10 + which as u64);
            let c = Mat::zeros(n, n);
            let co = if which == 0 { &mut ta } else { &mut tb };
            let r = co.dgemm(&a, &b, &c);
            let want = redefine_blas::blas::level3::dgemm_ref(&a, &b, &c);
            let err = redefine_blas::util::rel_fro_error(r.c.as_slice(), want.as_slice());
            assert!(err < 1e-12, "churned DGEMM round {round} n={n} wrong: {err}");
        }
    }
    let s = engine.cache_stats();
    assert_eq!(s.entries, 1, "cap must bound shared residency: {s:?}");
    assert_eq!(s.misses, 6, "every alternation re-emits: {s:?}");
    assert_eq!(s.evictions, 5, "every switch after the first evicts: {s:?}");
    let (sa, sb) = (ta.cache_stats(), tb.cache_stats());
    assert_eq!(sa.evictions + sb.evictions, s.evictions);
    assert_eq!(sa.misses + sb.misses, s.misses);
}

#[test]
fn mixed_ae_tenants_share_workers_without_cross_talk() {
    // A 1-worker engine forces tenants at different enhancement levels to
    // interleave on the same PE worker: per-level measurements must still
    // equal an isolated coordinator's (the worker swaps PE configurations
    // per job).
    let engine = Engine::new(EngineConfig { workers: 1, ..EngineConfig::default() });
    let mut t0 = engine.tenant(cfg(AeLevel::Ae0, 1));
    let mut t5 = engine.tenant(cfg(AeLevel::Ae5, 1));
    let n = 16;
    let x: Vec<f64> = (0..n).map(|i| 0.25 * i as f64).collect();
    let y: Vec<f64> = (0..n).map(|i| 1.0 - 0.125 * i as f64).collect();
    for round in 0..2 {
        let (d0, m0, _) = t0.ddot(&x, &y);
        let (d5, m5, _) = t5.ddot(&x, &y);
        let want = redefine_blas::blas::level1::ddot(&x, &y);
        assert!((d0 - want).abs() < 1e-12);
        assert!((d5 - want).abs() < 1e-12);
        let mut iso0 = Coordinator::new(cfg(AeLevel::Ae0, 1));
        let mut iso5 = Coordinator::new(cfg(AeLevel::Ae5, 1));
        assert_eq!(m0.latency(), iso0.ddot(&x, &y).1.latency(), "round {round}: AE0 drifted");
        assert_eq!(m5.latency(), iso5.ddot(&x, &y).1.latency(), "round {round}: AE5 drifted");
        assert!(m0.latency() > m5.latency(), "AE5 must beat AE0 on the same kernel");
    }
    // Distinct AE levels are distinct cache keys: both kernels resident.
    assert_eq!(engine.cache_stats().entries, 2);
}

#[test]
fn cycles_scheduler_preserves_results_and_accounting() {
    // The cycle-cost DRR scheduler only reorders *dispatch*: concurrent
    // tenants under either scheduling policy must produce exactly the
    // isolated coordinators' responses (values, simulated cycles, energy)
    // and the same partitioned accounting — simulated results never
    // depend on the fairness currency.
    let wa = random_workload(6, 24, 7_001);
    let wb = random_workload(6, 24, 7_002);
    let mut ia = Coordinator::new(cfg(AeLevel::Ae5, 2));
    let ra_ref = ia.serve_batch(wa.clone());
    let mut ib = Coordinator::new(cfg(AeLevel::Ae3, 2));
    let rb_ref = ib.serve_batch(wb.clone());
    for sched in [SchedPolicy::Slots, SchedPolicy::Cycles] {
        let engine = Engine::new(EngineConfig { workers: 2, sched, ..EngineConfig::default() });
        assert_eq!(engine.sched(), sched);
        let mut ta = engine.tenant(cfg(AeLevel::Ae5, 2));
        let mut tb = engine.tenant_weighted(cfg(AeLevel::Ae3, 2), 3);
        let (ra, rb) = std::thread::scope(|s| {
            let ha = s.spawn(|| ta.serve_batch(wa.clone()));
            let hb = s.spawn(|| tb.serve_batch(wb.clone()));
            (ha.join().expect("tenant a"), hb.join().expect("tenant b"))
        });
        assert_same_responses(&ra_ref, &ra);
        assert_same_responses(&rb_ref, &rb);
        let (sa, sb, total) = (ta.cache_stats(), tb.cache_stats(), engine.cache_stats());
        assert_eq!(sa.hits + sb.hits, total.hits, "{sched:?}");
        assert_eq!(sa.misses + sb.misses, total.misses, "{sched:?}");
        // The counting invariant: one hit-or-miss event per request.
        assert_eq!(total.hits + total.misses, 12, "{sched:?}: one event per request");
        // Every dispatched job was priced: the lane service telemetry is
        // live and covers both tenants.
        let service = engine.lane_service();
        assert_eq!(service.len(), 2);
        assert!(service.iter().all(|l| l.served_cost > 0), "{sched:?}: {service:?}");
    }
}

#[test]
fn cache_quota_stops_a_churning_tenant_from_evicting_a_sibling() {
    // The tentpole quota criterion: under a shared capped cache, an
    // adversarial tenant cycling through distinct DGEMM shapes must not
    // be able to evict a sibling tenant's resident kernel — its own set
    // is bounded by the quota and its evictions land on its own kernels.
    let engine = Engine::new(EngineConfig {
        workers: 2,
        cache_capacity: Some(4),
        cache_quota: Some(2),
        ..EngineConfig::default()
    });
    let mut sibling = engine.tenant(cfg(AeLevel::Ae5, 2));
    let mut churn = engine.tenant(cfg(AeLevel::Ae5, 2));
    // The sibling warms one kernel (n=16 → one GemmRect key).
    let a = Mat::random(16, 16, 9_000);
    let b = Mat::random(16, 16, 9_001);
    let _ = sibling.dgemm(&a, &b, &Mat::zeros(16, 16));
    assert_eq!(sibling.cache_stats().misses, 1);
    // The churner floods distinct shapes — far more than cap and quota.
    for n in [8usize, 24, 32, 40, 48, 56] {
        let x = Mat::random(n, n, n as u64);
        let y = Mat::random(n, n, n as u64 + 1);
        let r = churn.dgemm(&x, &y, &Mat::zeros(n, n));
        let want = redefine_blas::blas::level3::dgemm_ref(&x, &y, &Mat::zeros(n, n));
        let err = redefine_blas::util::rel_fro_error(r.c.as_slice(), want.as_slice());
        assert!(err < 1e-12, "churned DGEMM n={n} wrong: {err}");
    }
    // The sibling's kernel is still warm: re-requesting it must hit, not
    // re-emit.
    let _ = sibling.dgemm(&a, &b, &Mat::zeros(16, 16));
    let ss = sibling.cache_stats();
    assert_eq!(ss.misses, 1, "sibling must never re-emit under churn: {ss:?}");
    assert_eq!(ss.hits, 1, "sibling's repeat must ride its warm kernel: {ss:?}");
    assert_eq!(ss.evictions, 0, "no eviction may be charged to the sibling: {ss:?}");
    // The churner ate its own quota: 6 distinct shapes through a quota of
    // 2 evicts 4 of its own kernels, and the shared cache stays bounded.
    let sc = churn.cache_stats();
    assert_eq!(sc.evictions, 4, "churn evictions must hit the churner's own set: {sc:?}");
    let shared = engine.cache_stats();
    assert!(shared.entries <= 4, "global cap must hold: {shared:?}");
}

#[test]
fn community_kernel_promotes_out_of_the_inserting_tenants_quota() {
    // Quota accounting bugfix: an entry is charged to its first inserter
    // only while the inserter dominates its use. Once a sibling's warm
    // hits overtake the inserter's own, the kernel is community property
    // (shared/unowned) — the inserter's own quota pressure must no longer
    // evict what every other tenant rides on.
    let engine =
        Engine::new(EngineConfig { workers: 2, cache_quota: Some(1), ..EngineConfig::default() });
    let mut first = engine.tenant(cfg(AeLevel::Ae5, 2));
    let mut rider = engine.tenant(cfg(AeLevel::Ae5, 2));
    let a = Mat::random(16, 16, 9_100);
    let b = Mat::random(16, 16, 9_101);
    // The first tenant pays the emission; the rider's repeated warm
    // traffic then dominates and promotes the kernel.
    let _ = first.dgemm(&a, &b, &Mat::zeros(16, 16));
    for round in 0..2u64 {
        let x = Mat::random(16, 16, 9_200 + round);
        let y = Mat::random(16, 16, 9_300 + round);
        let _ = rider.dgemm(&x, &y, &Mat::zeros(16, 16));
    }
    assert_eq!(rider.cache_stats().misses, 0, "the rider only rides the warm kernel");
    // The inserter moves on to a new shape: its quota of 1 must charge the
    // new private kernel only, not the promoted community kernel.
    let _ = first.dgemm(&Mat::random(8, 8, 1), &Mat::random(8, 8, 2), &Mat::zeros(8, 8));
    let _ = rider.dgemm(&a, &b, &Mat::zeros(16, 16));
    let (sf, sr) = (first.cache_stats(), rider.cache_stats());
    assert_eq!(sr.misses, 0, "the community kernel must survive the inserter's quota: {sr:?}");
    assert_eq!(sf.evictions, 0, "nothing evicts once the dominated entry is unowned: {sf:?}");
    assert_eq!(engine.cache_stats().entries, 2, "both kernels stay resident");
}

#[test]
fn weighted_tenant_batches_complete_under_flood() {
    // End-to-end no-starvation smoke: a light tenant's small batch served
    // concurrently with a heavy tenant's large batch on one worker must
    // complete with exactly the isolated results (the WRR queue keeps
    // offering the light lane slots; the property itself is unit-tested
    // on the queue).
    let heavy_work = repeated_gemm_workload(12, 16, 5_000);
    let light_work = random_workload(4, 16, 6_000);
    let mut iso = Coordinator::new(cfg(AeLevel::Ae5, 2));
    let light_ref = iso.serve_batch(light_work.clone());

    let engine = Engine::new(EngineConfig { workers: 1, ..EngineConfig::default() });
    let mut heavy = engine.tenant(cfg(AeLevel::Ae5, 2));
    let mut light = engine.tenant_weighted(cfg(AeLevel::Ae5, 2), 2);
    let (hr, lr) = std::thread::scope(|s| {
        let hh = s.spawn(|| heavy.serve_batch(heavy_work));
        let lh = s.spawn(|| light.serve_batch(light_work));
        (hh.join().expect("heavy tenant"), lh.join().expect("light tenant"))
    });
    assert_eq!(hr.len(), 12);
    assert_same_responses(&light_ref, &lr);
}
