//! Fabric serving integration tests: the location-aware engine
//! (`--fabric b`) must keep serving values pinned to the host reference,
//! stay bit-deterministic run to run (same seed + placement ⇒ identical
//! link-busy counts and makespan, including under replay batching), improve
//! makespan monotonically with fabric order on a contended workload, and
//! leave the `--fabric 0` (location-free) path untouched.

use redefine_blas::blas;
use redefine_blas::coordinator::request::{random_workload, repeated_gemm_workload, Request};
use redefine_blas::coordinator::{Coordinator, CoordinatorConfig, OpenLoopOptions, Response};
use redefine_blas::engine::traffic::{self, ArrivalKind, TrafficConfig};
use redefine_blas::engine::{Engine, EngineConfig};
use redefine_blas::noc::{FabricConfig, FabricStats, PlacePolicy};
use redefine_blas::pe::AeLevel;
use redefine_blas::util::{rel_fro_error, Mat};

fn cfg(fabric: Option<FabricConfig>) -> CoordinatorConfig {
    CoordinatorConfig {
        ae: AeLevel::Ae5,
        b: 2,
        artifact_dir: "/nonexistent".into(),
        verify: false,
        fabric,
        ..CoordinatorConfig::default()
    }
}

fn fab(b: usize, place: PlacePolicy) -> Option<FabricConfig> {
    Some(FabricConfig { place, ..FabricConfig::new(b) })
}

/// Exact (bit-level) equality of two response streams, values and costs.
fn assert_identical(a: &[Response], b: &[Response]) {
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.op, y.op);
        assert_eq!(x.n, y.n);
        assert_eq!(x.cycles, y.cycles, "{} n={}: cycles drifted", x.op, x.n);
        assert_eq!(x.energy_j, y.energy_j);
        assert_eq!(x.matrix, y.matrix);
        assert_eq!(x.vector, y.vector);
        assert_eq!(x.scalar, y.scalar);
    }
}

/// Value-only equality: same results, costs free to differ (used to pin
/// that placement policy is a *scheduling* decision, never a value one).
fn assert_same_values(a: &[Response], b: &[Response]) {
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.op, y.op);
        assert_eq!(x.n, y.n);
        assert_eq!(x.matrix, y.matrix);
        assert_eq!(x.vector, y.vector);
        assert_eq!(x.scalar, y.scalar);
    }
}

#[test]
fn fabric_off_matches_default_serving() {
    // `--fabric 0` maps to `fabric: None`; pin that this is bit- and
    // stat-identical to the pre-fabric coordinator (same code path, but
    // the contract is now load-bearing for the CLI parity smoke).
    let reqs = random_workload(10, 24, 5);
    let mut base = Coordinator::new(cfg(None));
    let mut off = Coordinator::new(CoordinatorConfig { fabric: None, ..cfg(None) });
    let ra = base.serve_batch(reqs.clone());
    let rb = off.serve_batch(reqs);
    assert_identical(&ra, &rb);
    assert!(off.fabric_stats().is_none(), "fabric off must report no fabric telemetry");
    assert_eq!(
        format!("{:?}", base.cache_stats()),
        format!("{:?}", off.cache_stats()),
        "cache stats drifted with fabric off"
    );
}

#[test]
fn fabric_serving_matches_host_reference() {
    // Routed delivery reprices time, never values: every response on a
    // fabric must still match the host reference BLAS at 1e-12, and the
    // absolute fabric clock must advance across same-shape requests.
    let n = 16;
    let mut reqs: Vec<Request> = Vec::new();
    let mut want: Vec<Mat> = Vec::new();
    for s in 0..3u64 {
        let a = Mat::random(n, n, 500 + s);
        let b = Mat::random(n, n, 600 + s);
        let c = Mat::random(n, n, 700 + s);
        want.push(blas::level3::dgemm_ref(&a, &b, &c));
        reqs.push(Request::Dgemm { a, b, c });
    }
    let x: Vec<f64> = (0..32).map(|i| 0.25 * i as f64).collect();
    let y: Vec<f64> = (0..32).map(|i| 1.5 - 0.125 * i as f64).collect();
    let dot = blas::level1::ddot(&x, &y);
    reqs.push(Request::Ddot { x, y });

    let mut co = Coordinator::new(cfg(fab(2, PlacePolicy::Locality)));
    let resps = co.serve_batch(reqs);
    assert_eq!(resps.len(), 4);
    for (i, w) in want.iter().enumerate() {
        let got = resps[i].matrix.as_ref().expect("dgemm matrix");
        let err = rel_fro_error(got.as_slice(), w.as_slice());
        assert!(err < 1e-12, "fabric DGEMM {i}: rel err {err}");
        assert!(resps[i].cycles > 0);
        if i > 0 {
            assert!(
                resps[i].cycles > resps[i - 1].cycles,
                "fabric clock must advance across contended requests"
            );
        }
    }
    let got = resps[3].scalar.expect("ddot scalar");
    assert!((got - dot).abs() <= 1e-12 * dot.abs().max(1.0), "fabric DDOT: {got} vs {dot}");

    let fs = co.fabric_stats().expect("fabric telemetry");
    assert_eq!(fs.b, 2);
    assert_eq!(fs.place, PlacePolicy::Locality);
    // 3 DGEMMs × 4 tiles + 1 DDOT measurement.
    assert_eq!(fs.jobs_routed, 13);
    assert!(fs.makespan > 0 && fs.max_link_busy > 0 && fs.comm_cycles > 0);
}

#[test]
fn fabric_runs_are_deterministic() {
    // Same seed + same placement ⇒ identical responses, per-link busy
    // counts, tile occupancy, and makespan — run to run, regardless of
    // host worker interleaving (routing happens at finalize time, which
    // is strict submission order).
    let run = |place: PlacePolicy| -> (Vec<Response>, FabricStats) {
        let mut co = Coordinator::new(cfg(fab(3, place)));
        let resps = co.serve_batch(random_workload(20, 28, 9));
        let fs = co.fabric_stats().expect("fabric telemetry");
        (resps, fs)
    };
    for place in [PlacePolicy::Locality, PlacePolicy::RoundRobin] {
        let (ra, fa) = run(place);
        let (rb, fb) = run(place);
        assert_identical(&ra, &rb);
        assert_eq!(fa, fb, "fabric stats drifted across identical runs ({place:?})");
    }
}

#[test]
fn fabric_determinism_holds_under_replay_batching() {
    // The operand-batched replay fast path coalesces same-shape tiles
    // across requests; it must leave routed schedules untouched (same
    // cycles in ⇒ same schedule out).
    let reqs = repeated_gemm_workload(12, 16, 7);
    let mut plain = Coordinator::new(cfg(fab(2, PlacePolicy::Locality)));
    let mut batched = Coordinator::new(CoordinatorConfig {
        replay_batch: Some(8),
        ..cfg(fab(2, PlacePolicy::Locality))
    });
    let ra = plain.serve_batch(reqs.clone());
    let rb = batched.serve_batch(reqs);
    assert_identical(&ra, &rb);
    assert_eq!(
        plain.fabric_stats().expect("plain fabric"),
        batched.fabric_stats().expect("batched fabric"),
        "replay batching changed the routed schedule"
    );
}

#[test]
fn bigger_fabric_improves_serving_makespan() {
    // The scaling curve the bench records: same 64-tile-job workload, the
    // only variable is fabric order — makespan must improve monotonically
    // b = 1 → 2 → 3 → 4 while the job count stays fixed.
    let mut spans = Vec::new();
    for b in [1usize, 2, 3, 4] {
        let mut co = Coordinator::new(cfg(fab(b, PlacePolicy::Locality)));
        let _ = co.serve_batch(repeated_gemm_workload(16, 16, 3));
        let fs = co.fabric_stats().expect("fabric telemetry");
        assert_eq!(fs.jobs_routed, 64, "b={b}: workload must route 64 tile jobs");
        assert!(fs.compute_comm_ratio() > 0.0);
        spans.push((b, fs.makespan));
    }
    for w in spans.windows(2) {
        let ((b0, m0), (b1, m1)) = (w[0], w[1]);
        assert!(m1 < m0, "fabric {b1}x{b1} must beat {b0}x{b0}: {m1} vs {m0}");
    }
}

#[test]
fn placement_policy_never_changes_values() {
    let reqs = random_workload(12, 24, 21);
    let mut loc = Coordinator::new(cfg(fab(2, PlacePolicy::Locality)));
    let mut rr = Coordinator::new(cfg(fab(2, PlacePolicy::RoundRobin)));
    let ra = loc.serve_batch(reqs.clone());
    let rb = rr.serve_batch(reqs);
    assert_same_values(&ra, &rb);
    assert_eq!(
        loc.fabric_stats().expect("loc").jobs_routed,
        rr.fabric_stats().expect("rr").jobs_routed
    );
}

#[test]
fn fabric_open_loop_accounting_holds() {
    // Routed open-loop serving under bursty overload: every offered
    // arrival is either served or explicitly shed, and the fabric routes
    // at least one job per served request.
    let mut co = Coordinator::new(CoordinatorConfig {
        admission_window: Some(2),
        queue_depth: Some(2),
        ..cfg(fab(2, PlacePolicy::Locality))
    });
    let arrivals = traffic::generate(&TrafficConfig {
        kind: ArrivalKind::Burst { size: 8 },
        rate_rps: 4000.0,
        duration_ns: 20_000_000,
        max_n: 24,
        ..TrafficConfig::default()
    });
    let report = co.serve_open_loop(arrivals, &OpenLoopOptions::default());
    assert_eq!(report.stats.offered, report.stats.served + report.stats.shed);
    assert!(report.stats.served > 0, "some arrivals must be served");
    assert!(report.stats.shed > 0, "bursts of 8 into a depth-2 queue must shed");
    let fs = co.fabric_stats().expect("fabric telemetry");
    assert!(fs.jobs_routed >= report.stats.served as u64);
}

#[test]
fn tenants_get_distinct_home_rows() {
    // Home rows cycle through fabric rows in attach order, giving each
    // tenant its own memory region for write-back consolidation.
    let engine = Engine::new(EngineConfig {
        fabric: fab(2, PlacePolicy::Locality),
        ..EngineConfig::default()
    });
    let a = engine.tenant(cfg(None));
    let b = engine.tenant(cfg(None));
    let c = engine.tenant(cfg(None));
    assert_eq!((a.home_row(), b.home_row(), c.home_row()), (0, 1, 0));
    assert!(engine.fabric_stats().is_some());
}
