//! Serving-engine tests: program-cache determinism (pointer-equal shared
//! kernels), `serve_batch` vs `serve_one` equivalence across admission
//! windows (request-count and byte-budget), pooled Level-1/2 execution,
//! LRU capping, two-tier replay-vs-combined equivalence, tier-2b
//! replay-batch coalescing, residual-kernel serving, and the pooled
//! path's makespan behavior.

use redefine_blas::coordinator::{
    request::{random_workload, repeated_gemm_workload, Request},
    Coordinator, CoordinatorConfig, ProgramCache, Response, ValueSource,
};
use redefine_blas::engine::SchedPolicy;
use redefine_blas::pe::{AeLevel, ExecMode};
use redefine_blas::util::{Mat, XorShift64};
use std::sync::Arc;

fn coord(ae: AeLevel, b: usize) -> Coordinator {
    Coordinator::new(CoordinatorConfig {
        ae,
        b,
        artifact_dir: "/nonexistent".into(),
        verify: false,
        ..CoordinatorConfig::default()
    })
}

fn coord_with(admission_window: Option<usize>, cache_capacity: Option<usize>) -> Coordinator {
    Coordinator::new(CoordinatorConfig {
        ae: AeLevel::Ae5,
        b: 2,
        artifact_dir: "/nonexistent".into(),
        verify: false,
        admission_window,
        cache_capacity,
        ..CoordinatorConfig::default()
    })
}

fn coord_bytes(admission_bytes: Option<u64>) -> Coordinator {
    Coordinator::new(CoordinatorConfig {
        ae: AeLevel::Ae5,
        b: 2,
        artifact_dir: "/nonexistent".into(),
        verify: false,
        admission_bytes,
        ..CoordinatorConfig::default()
    })
}

/// An explicit all-level batch — DGEMM, DGEMV, DDOT, DAXPY, DNRM2 — with
/// repeated shapes so cache hits and in-flight measurement sharing are
/// both exercised.
fn mixed_requests() -> Vec<Request> {
    let mut rng = XorShift64::new(0xABCD);
    vec![
        Request::RandomDgemm { n: 20, seed: 11 },
        Request::Ddot { x: rng.vec(64), y: rng.vec(64) },
        Request::Dgemv { a: Mat::random(12, 12, 12), x: rng.vec(12), y: rng.vec(12) },
        Request::Ddot { x: rng.vec(64), y: rng.vec(64) }, // same kernel as #1
        Request::Daxpy { alpha: 1.5, x: rng.vec(32), y: rng.vec(32) },
        Request::RandomDgemm { n: 20, seed: 13 }, // same shape as #0
        Request::Dnrm2 { x: rng.vec(16) },
        Request::Daxpy { alpha: 1.5, x: rng.vec(32), y: rng.vec(32) }, // shared α kernel
        Request::Dgemv { a: Mat::random(12, 12, 14), x: rng.vec(12), y: rng.vec(12) },
        Request::RandomDgemm { n: 12, seed: 15 },
    ]
}

/// Field-by-field response equality (Response carries one payload plus the
/// simulated cost report).
fn assert_same_responses(lhs: &[Response], rhs: &[Response]) {
    assert_eq!(lhs.len(), rhs.len());
    for (i, (a, b)) in lhs.iter().zip(rhs).enumerate() {
        assert_eq!(a.op, b.op, "request {i}");
        assert_eq!(a.n, b.n, "request {i}");
        assert_eq!(a.source, b.source, "request {i}");
        assert_eq!(a.cycles, b.cycles, "request {i}: simulated cycles must be identical");
        assert_eq!(a.energy_j, b.energy_j, "request {i}");
        assert_eq!(a.matrix, b.matrix, "request {i}: matrix payload");
        assert_eq!(a.vector, b.vector, "request {i}: vector payload");
        assert_eq!(a.scalar, b.scalar, "request {i}: scalar payload");
    }
}

#[test]
fn cache_same_key_returns_the_identical_arc() {
    let cache = ProgramCache::new();
    let p1 = cache.gemm_rect(12, 12, 24, AeLevel::Ae5);
    let p2 = cache.gemm_rect(12, 12, 24, AeLevel::Ae5);
    assert!(Arc::ptr_eq(&p1, &p2), "same (routine, shape, ae) must share one Program");
    let p3 = cache.gemm_rect(12, 12, 24, AeLevel::Ae3);
    assert!(!Arc::ptr_eq(&p1, &p3), "AE level is part of the key");
    let s = cache.stats();
    assert_eq!(s.hits, 1);
    assert_eq!(s.misses, 2);
    assert_eq!(s.entries, 2);
}

#[test]
fn coordinator_reuses_one_program_across_a_request_stream() {
    let mut co = coord(AeLevel::Ae5, 2);
    let resps = co.serve_batch(repeated_gemm_workload(6, 20, 77));
    assert_eq!(resps.len(), 6);
    let s = co.cache_stats();
    assert_eq!(s.misses, 1, "one shape → one emission: {s:?}");
    assert_eq!(s.hits, 5, "five cache hits: {s:?}");
    // All six responses simulate identical tile timing (same shape).
    let cycles: Vec<u64> = resps.iter().map(|r| r.cycles).collect();
    assert!(cycles.windows(2).all(|w| w[0] == w[1]), "same shape, same makespan: {cycles:?}");
}

#[test]
fn serve_batch_matches_serve_one_exactly() {
    let reqs = random_workload(10, 28, 2026);
    let mut seq = coord(AeLevel::Ae5, 2);
    let mut bat = coord(AeLevel::Ae5, 2);
    let r_seq: Vec<_> = reqs.clone().into_iter().map(|r| seq.serve_one(r)).collect();
    let r_bat = bat.serve_batch(reqs);
    assert_same_responses(&r_seq, &r_bat);
}

#[test]
fn mixed_batch_equals_sequential_under_any_window() {
    // The acceptance invariant: an all-level batch (DGEMM + DGEMV + DDOT +
    // DAXPY + DNRM2) returns values/cycles/energy identical to the
    // sequential serve_one loop, for every admission window — including
    // W=1 (fully serialized staging) and unbounded. Cache counters must
    // agree too: attaching to an in-flight kernel is the batched analogue
    // of a sequential memo hit.
    let reqs = mixed_requests();
    let mut seq = coord(AeLevel::Ae5, 2);
    let r_seq: Vec<_> = reqs.clone().into_iter().map(|r| seq.serve_one(r)).collect();
    for window in [Some(1), Some(2), Some(3), Some(reqs.len()), None] {
        let mut bat = coord_with(window, None);
        let r_bat = bat.serve_batch(reqs.clone());
        assert_same_responses(&r_seq, &r_bat);
        assert_eq!(
            seq.cache_stats(),
            bat.cache_stats(),
            "cache accounting must not depend on the window ({window:?})"
        );
        let bs = bat.last_batch_stats().expect("batch ran");
        assert_eq!(bs.requests, reqs.len());
        assert!(
            bs.peak_staged <= window.unwrap_or(usize::MAX),
            "window {window:?} violated: peak {}",
            bs.peak_staged
        );
    }
}

#[test]
fn every_request_records_exactly_one_cache_event() {
    // The measurement-memo accounting invariant: hits + misses equals the
    // number of requests served — the memo hit, the in-flight attach, and
    // the submit-side miss are mutually exclusive per request, and the
    // measurement path's program fetch adds no second event. Holds on the
    // sequential and the batched path alike.
    let reqs = mixed_requests();
    let total = reqs.len() as u64;
    let mut seq = coord(AeLevel::Ae5, 2);
    for r in reqs.clone() {
        let _ = seq.serve_one(r);
    }
    let s = seq.cache_stats();
    assert_eq!(s.hits + s.misses, total, "sequential: one event per request: {s:?}");
    let mut bat = coord(AeLevel::Ae5, 2);
    let _ = bat.serve_batch(reqs);
    let b = bat.cache_stats();
    assert_eq!(b.hits + b.misses, total, "batched: one event per request: {b:?}");
    assert_eq!(s, b, "the two paths must account identically");
}

#[test]
fn slot_wrr_baseline_still_serves_identically() {
    // The pinned baseline: a coordinator scheduling under the slot-WRR
    // policy returns exactly the sequential responses — the fairness
    // currency is reachable via config and changes dispatch order only.
    let reqs = mixed_requests();
    let mut seq = coord(AeLevel::Ae5, 2);
    let r_seq: Vec<_> = reqs.clone().into_iter().map(|r| seq.serve_one(r)).collect();
    let mut slots = Coordinator::new(CoordinatorConfig {
        ae: AeLevel::Ae5,
        b: 2,
        artifact_dir: "/nonexistent".into(),
        verify: false,
        sched: SchedPolicy::Slots,
        ..CoordinatorConfig::default()
    });
    let r_slots = slots.serve_batch(reqs);
    assert_same_responses(&r_seq, &r_slots);
}

#[test]
fn admission_window_bounds_staged_requests() {
    let reqs = mixed_requests();
    let total = reqs.len();
    // Unbounded: everything is staged up front.
    let mut unbounded = coord_with(None, None);
    unbounded.serve_batch(reqs.clone());
    assert_eq!(unbounded.last_batch_stats().unwrap().peak_staged, total);
    // Bounded: never more than W requests' operands staged at once.
    for w in [1usize, 2, 4] {
        let mut co = coord_with(Some(w), None);
        co.serve_batch(reqs.clone());
        let bs = co.last_batch_stats().unwrap();
        assert_eq!(bs.requests, total);
        assert!(bs.peak_staged <= w, "window {w} violated: peak {}", bs.peak_staged);
        // The window is actually used, not trivially satisfied.
        assert_eq!(bs.peak_staged, w.min(total), "pool should be kept as full as allowed");
    }
}

#[test]
fn level1_and_gemv_jobs_run_on_pool_workers() {
    // The paper's point: one co-designed PE path serves every BLAS level.
    // After a mixed batch, the pool — not the dispatcher — must have
    // executed DGEMV and Level-1 kernels alongside the DGEMM tiles.
    let mut co = coord(AeLevel::Ae5, 2);
    co.serve_batch(mixed_requests());
    let counts = co.pool_job_counts();
    assert!(counts.gemm_tiles >= 12, "3 DGEMMs × 4 tiles expected: {counts:?}");
    assert_eq!(counts.gemv, 1, "one DGEMV shape → one pooled kernel: {counts:?}");
    assert_eq!(counts.level1, 3, "ddot + daxpy + dnrm2 kernels: {counts:?}");
    // Shared kernels are attached, not re-simulated.
    let bs = co.last_batch_stats().unwrap();
    assert_eq!(bs.shared_measurements, 3, "repeat ddot + daxpy + dgemv: {bs:?}");
}

#[test]
fn pooled_level12_deterministic_across_runs() {
    // Fresh coordinators, same requests: every simulated quantity of the
    // pooled Level-1/2 path must repeat bit-for-bit.
    let reqs = mixed_requests();
    let r1 = coord(AeLevel::Ae5, 2).serve_batch(reqs.clone());
    let r2 = coord(AeLevel::Ae5, 2).serve_batch(reqs);
    assert_same_responses(&r1, &r2);
}

#[test]
fn capped_cache_batch_still_matches_sequential() {
    // An adversarially small LRU cap forces evictions mid-batch; values
    // and simulated timing must not change (re-emitted kernels are
    // identical), and evictions must be counted.
    let reqs = mixed_requests();
    let mut seq = coord(AeLevel::Ae5, 2);
    let r_seq: Vec<_> = reqs.clone().into_iter().map(|r| seq.serve_one(r)).collect();
    let mut capped = coord_with(None, Some(1));
    let r_cap = capped.serve_batch(reqs);
    assert_same_responses(&r_seq, &r_cap);
    let s = capped.cache_stats();
    assert!(s.evictions > 0, "cap 1 over many shapes must evict: {s:?}");
    assert_eq!(s.entries, 1, "cap must bound residency: {s:?}");
}

#[test]
fn serve_batch_is_deterministic_across_runs() {
    // Run the same batch twice on fresh coordinators: every simulated
    // quantity must repeat bit-for-bit (host thread scheduling must not
    // leak into results).
    let reqs = random_workload(8, 24, 555);
    let r1 = coord(AeLevel::Ae5, 2).serve_batch(reqs.clone());
    let r2 = coord(AeLevel::Ae5, 2).serve_batch(reqs);
    for (a, b) in r1.iter().zip(&r2) {
        assert_eq!(a.cycles, b.cycles);
        assert_eq!(a.energy_j, b.energy_j);
        assert_eq!(a.matrix, b.matrix);
        assert_eq!(a.vector, b.vector);
        assert_eq!(a.scalar, b.scalar);
    }
}

#[test]
fn combined_exec_mode_matches_replay_exactly() {
    // The two-tier acceptance invariant on the serve path: forcing the
    // combined interpreter on every kernel (ExecMode::Combined) and the
    // default cache-hit value replay must produce identical responses —
    // values, simulated cycles and energy — for an all-level batch, and
    // against the sequential reference loop.
    let reqs = mixed_requests();
    let mut seq = coord(AeLevel::Ae5, 2);
    let r_seq: Vec<_> = reqs.clone().into_iter().map(|r| seq.serve_one(r)).collect();
    let mut replay = coord(AeLevel::Ae5, 2);
    let r_replay = replay.serve_batch(reqs.clone());
    let mut combined = Coordinator::new(CoordinatorConfig {
        ae: AeLevel::Ae5,
        b: 2,
        artifact_dir: "/nonexistent".into(),
        verify: false,
        exec: ExecMode::Combined,
        ..CoordinatorConfig::default()
    });
    let r_combined = combined.serve_batch(reqs);
    assert_same_responses(&r_seq, &r_replay);
    assert_same_responses(&r_seq, &r_combined);
    // The combined pool never replays; the replay pool did the timing
    // pass at most once per distinct kernel.
    let cc = combined.pool_job_counts();
    assert_eq!(cc.replays, 0, "combined mode must not replay: {cc:?}");
    assert!(cc.combined_runs > 0);
    let rc = replay.pool_job_counts();
    assert_eq!(rc.replays + rc.combined_runs, rc.gemm_tiles + rc.gemv + rc.level1);
}

#[test]
fn repeated_shape_serving_replays_at_every_ae() {
    // Same-shape request streams must converge to the replay fast path at
    // every enhancement level, with responses identical to the sequential
    // loop (which itself runs the one-shot combined path for DGEMM).
    for ae in AeLevel::ALL {
        let reqs = repeated_gemm_workload(4, 12, 31_000);
        let mut seq = coord(ae, 2);
        let r_seq: Vec<_> = reqs.clone().into_iter().map(|r| seq.serve_one(r)).collect();
        let mut bat = coord(ae, 2);
        let r_bat = bat.serve_batch(reqs);
        assert_same_responses(&r_seq, &r_bat);
        let jc = bat.pool_job_counts();
        assert_eq!(jc.gemm_tiles, 16, "{ae}: 4 requests x 4 tiles");
        assert!(
            jc.replays >= jc.gemm_tiles - 4,
            "{ae}: at most the first request's tiles may run combined: {jc:?}"
        );
    }
}

#[test]
fn cached_kernel_carries_its_schedule_after_serving() {
    // After a repeated-shape stream, the resident ScheduledProgram holds
    // the memoized timing pass — the state the replay path feeds on.
    let mut co = coord(AeLevel::Ae5, 2);
    let _ = co.serve_batch(repeated_gemm_workload(3, 16, 555));
    // n=16, b=2 → padded 16, tile m=8, k=16.
    let sched = co.cache().gemm_rect(8, 8, 16, AeLevel::Ae5);
    assert!(sched.is_scheduled(), "serving must have scheduled the cached kernel");
    let stats = sched.scheduled_stats().expect("scheduled");
    assert!(stats.cycles > 0 && stats.instructions > 0);
}

#[test]
fn pooled_bigger_array_is_faster() {
    // Makespan monotonicity through the pooled path (the seed's
    // bigger_array_is_faster invariant must survive the serving engine).
    let n = 48;
    let a = Mat::random(n, n, 81);
    let b = Mat::random(n, n, 82);
    let c = Mat::zeros(n, n);
    let m1 = coord(AeLevel::Ae5, 1).dgemm(&a, &b, &c).makespan;
    let m2 = coord(AeLevel::Ae5, 2).dgemm(&a, &b, &c).makespan;
    let m3 = coord(AeLevel::Ae5, 3).dgemm(&a, &b, &c).makespan;
    assert!(m2 < m1, "2x2 ({m2}) not faster than 1x1 ({m1})");
    assert!(m3 < m2, "3x3 ({m3}) not faster than 2x2 ({m2})");
}

#[test]
fn pool_sized_by_tile_array() {
    assert_eq!(coord(AeLevel::Ae5, 1).pool_size(), 1);
    assert_eq!(coord(AeLevel::Ae5, 3).pool_size(), 9);
}

#[test]
fn byte_budget_batch_matches_sequential() {
    // The byte-budget invariant: for any admission_bytes setting the
    // batched responses (values, cycles, energy, cache accounting) are
    // identical to the sequential loop — the budget only throttles
    // staging, never results.
    let reqs = mixed_requests();
    let mut seq = coord(AeLevel::Ae5, 2);
    let r_seq: Vec<_> = reqs.clone().into_iter().map(|r| seq.serve_one(r)).collect();
    for budget in [Some(1u64), Some(4 << 10), Some(64 << 10), Some(u64::MAX), None] {
        let mut bat = coord_bytes(budget);
        let r_bat = bat.serve_batch(reqs.clone());
        assert_same_responses(&r_seq, &r_bat);
        assert_eq!(
            seq.cache_stats(),
            bat.cache_stats(),
            "cache accounting must not depend on the byte budget ({budget:?})"
        );
    }
}

#[test]
fn byte_budget_bounds_staged_bytes() {
    let reqs = mixed_requests();
    let cfg = CoordinatorConfig { ae: AeLevel::Ae5, b: 2, ..CoordinatorConfig::default() };
    let max_single = reqs.iter().map(|r| cfg.staged_bytes(r)).max().expect("nonempty");
    let sum_all: u64 = reqs.iter().map(|r| cfg.staged_bytes(r)).sum();
    // Unbudgeted: everything stages up front.
    let mut unbounded = coord_bytes(None);
    unbounded.serve_batch(reqs.clone());
    let bs = unbounded.last_batch_stats().unwrap();
    assert_eq!(bs.peak_staged_bytes, sum_all, "unbudgeted batch must stage everything");
    // A budget that fits the largest request is a hard bound.
    for budget in [max_single, 2 * max_single] {
        let mut co = coord_bytes(Some(budget));
        co.serve_batch(reqs.clone());
        let bs = co.last_batch_stats().unwrap();
        assert!(
            bs.peak_staged_bytes <= budget,
            "budget {budget} violated: peak {} B",
            bs.peak_staged_bytes
        );
        assert_eq!(bs.requests, reqs.len());
    }
    // A budget below every request still makes progress, one at a time.
    let mut tiny = coord_bytes(Some(1));
    let r = tiny.serve_batch(reqs.clone());
    assert_eq!(r.len(), reqs.len());
    let bs = tiny.last_batch_stats().unwrap();
    assert_eq!(bs.peak_staged, 1, "sub-minimal budget must serialize staging");
    assert!(bs.peak_staged_bytes <= max_single, "only one oversized request may stage");
}

#[test]
fn admission_window_and_byte_budget_compose_over_random_workloads() {
    // Property test over the joint (admission_window × admission_bytes)
    // space: for randomized mixed-level workloads — with an oversized
    // DGEMM planted mid-queue — the batch must (a) never wedge (every
    // response returned, in order, equal to the sequential loop), and
    // (b) never stage more than the byte budget except for the
    // admit-one-alone case, where the peak is exactly one oversized
    // request's image.
    let base = CoordinatorConfig {
        ae: AeLevel::Ae5,
        b: 2,
        artifact_dir: "/nonexistent".into(),
        verify: false,
        ..CoordinatorConfig::default()
    };
    for seed in [11u64, 22] {
        // A big request mid-queue: larger than most byte budgets below.
        let mut reqs = random_workload(7, 20, seed);
        reqs.insert(3, Request::RandomDgemm { n: 40, seed: 1_000 + seed });
        let max_single = reqs.iter().map(|r| base.staged_bytes(r)).max().expect("nonempty");
        let min_single = reqs.iter().map(|r| base.staged_bytes(r)).min().expect("nonempty");
        let mut seq = Coordinator::new(base.clone());
        let r_seq: Vec<_> = reqs.clone().into_iter().map(|r| seq.serve_one(r)).collect();
        for window in [Some(1), Some(2), Some(4), None] {
            for budget in [Some(1), Some(min_single), Some(max_single / 2), None] {
                let mut co = Coordinator::new(CoordinatorConfig {
                    admission_window: window,
                    admission_bytes: budget,
                    ..base.clone()
                });
                let r_bat = co.serve_batch(reqs.clone());
                assert_same_responses(&r_seq, &r_bat);
                let bs = co.last_batch_stats().expect("batch ran");
                assert_eq!(bs.requests, reqs.len(), "w={window:?} b={budget:?}");
                assert!(
                    bs.peak_staged <= window.unwrap_or(usize::MAX),
                    "w={window:?} b={budget:?}: window violated: {bs:?}"
                );
                // The byte bound, with the admit-one exception: a peak
                // above the budget is only legal when it is a single
                // oversized request staged alone.
                if let Some(budget) = budget {
                    assert!(
                        bs.peak_staged_bytes <= budget.max(max_single),
                        "w={window:?} b={budget:?}: byte budget violated: {bs:?}"
                    );
                    if bs.peak_staged_bytes > budget {
                        assert!(
                            max_single > budget,
                            "w={window:?} b={budget:?}: overage without an oversized request"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn residual_serving_matches_host_blas() {
    // Non-4-aligned shapes served on the cached DOT2/3 residual kernel:
    // values must match host BLAS at every RDP level, and repeats must
    // hit the cache (the ROADMAP gap this closes: the coordinator used to
    // always pad).
    for ae in [AeLevel::Ae2, AeLevel::Ae4, AeLevel::Ae5] {
        let mut co = Coordinator::new(CoordinatorConfig {
            ae,
            b: 1,
            artifact_dir: "/nonexistent".into(),
            verify: false,
            residual: true,
            ..CoordinatorConfig::default()
        });
        for n in [6usize, 9, 13, 17] {
            let a = Mat::random(n, n, 3_000 + n as u64);
            let b = Mat::random(n, n, 3_100 + n as u64);
            let c = Mat::random(n, n, 3_200 + n as u64);
            let r = co.dgemm(&a, &b, &c);
            let want = redefine_blas::blas::level3::dgemm_ref(&a, &b, &c);
            let err = redefine_blas::util::rel_fro_error(r.c.as_slice(), want.as_slice());
            assert!(err < 1e-12, "{ae} residual n={n} wrong: {err}");
            assert_eq!(r.tiles.len(), 1, "residual path is single-PE");
        }
    }
}

#[test]
fn residual_kernels_are_cached_and_replayed() {
    let mut co = Coordinator::new(CoordinatorConfig {
        ae: AeLevel::Ae5,
        b: 1,
        artifact_dir: "/nonexistent".into(),
        verify: false,
        residual: true,
        ..CoordinatorConfig::default()
    });
    let resps = co.serve_batch(repeated_gemm_workload(4, 10, 6_000));
    assert_eq!(resps.len(), 4);
    let s = co.cache_stats();
    assert_eq!(s.misses, 1, "one residual shape → one emission: {s:?}");
    assert_eq!(s.hits, 3, "repeats must hit the residual kernel: {s:?}");
    let jc = co.pool_job_counts();
    assert_eq!(jc.gemm_tiles, 4, "one untiled kernel per request");
    assert!(jc.replays >= 3, "cache-hit residual requests must replay: {jc:?}");
    // The cycle cost differs from the padded path (different kernel), but
    // is identical across same-shape requests.
    let cycles: Vec<u64> = resps.iter().map(|r| r.cycles).collect();
    assert!(cycles.windows(2).all(|w| w[0] == w[1]), "same shape, same cost: {cycles:?}");
}

#[test]
fn residual_without_rdp_falls_back_to_padding() {
    // AE0/AE1 have no DOT hardware: residual mode must quietly keep the
    // padded tile path and still serve correct values.
    let mut co = Coordinator::new(CoordinatorConfig {
        ae: AeLevel::Ae1,
        b: 2,
        artifact_dir: "/nonexistent".into(),
        verify: false,
        residual: true,
        ..CoordinatorConfig::default()
    });
    let n = 10;
    let a = Mat::random(n, n, 7_000);
    let b = Mat::random(n, n, 7_001);
    let c = Mat::zeros(n, n);
    let r = co.dgemm(&a, &b, &c);
    assert_eq!(r.tiles.len(), 4, "no RDP → padded tiled path");
    let want = redefine_blas::blas::level3::dgemm_ref(&a, &b, &c);
    let err = redefine_blas::util::rel_fro_error(r.c.as_slice(), want.as_slice());
    assert!(err < 1e-12, "fallback DGEMM wrong: {err}");
}

#[test]
fn residual_and_padded_agree_numerically() {
    // Same problem through both paths: different summation groupings
    // (DOT2/3 vs padded DOT4), so values agree to FP reassociation, and
    // both match host BLAS.
    let n = 14;
    let a = Mat::random(n, n, 8_000);
    let b = Mat::random(n, n, 8_001);
    let c = Mat::random(n, n, 8_002);
    let mk = |residual: bool| {
        Coordinator::new(CoordinatorConfig {
            ae: AeLevel::Ae5,
            b: 1,
            artifact_dir: "/nonexistent".into(),
            verify: false,
            residual,
            ..CoordinatorConfig::default()
        })
    };
    let rp = mk(false).dgemm(&a, &b, &c);
    let rr = mk(true).dgemm(&a, &b, &c);
    let err = redefine_blas::util::rel_fro_error(rr.c.as_slice(), rp.c.as_slice());
    assert!(err < 1e-12, "residual vs padded numerics: {err}");
    assert_ne!(rp.makespan, rr.makespan, "different kernels should cost differently");
}

/// A coordinator with the tier-2b tile coalescer enabled at `cap`.
fn coord_replay_batch(cap: usize) -> Coordinator {
    Coordinator::new(CoordinatorConfig {
        ae: AeLevel::Ae5,
        b: 2,
        artifact_dir: "/nonexistent".into(),
        verify: false,
        replay_batch: Some(cap),
        ..CoordinatorConfig::default()
    })
}

#[test]
fn replay_batched_serving_matches_sequential_exactly() {
    // The tentpole invariant: coalescing same-kernel tiles into batched
    // replay jobs changes host-side dispatch only — responses (values,
    // simulated cycles, energy) stay identical to the sequential loop at
    // every coalescing cap, cold and warm.
    let reqs = repeated_gemm_workload(8, 16, 4_400);
    let mut seq = coord(AeLevel::Ae5, 2);
    let r_seq: Vec<_> = reqs.clone().into_iter().map(|r| seq.serve_one(r)).collect();
    for cap in [1usize, 4, 64] {
        let mut bat = coord_replay_batch(cap);
        // First pass is cold: coalesced jobs fall back to sequential
        // member execution (one of them pays the timing pass). The second
        // pass replays every member through the fused warm path.
        let r_cold = bat.serve_batch(reqs.clone());
        assert_same_responses(&r_seq, &r_cold);
        let r_warm = bat.serve_batch(reqs.clone());
        assert_same_responses(&r_seq, &r_warm);
        let jc = bat.pool_job_counts();
        assert_eq!(jc.gemm_tiles, 64, "cap {cap}: two passes x 8 requests x 4 tiles");
        assert_eq!(
            jc.replays + jc.combined_runs,
            jc.gemm_tiles,
            "cap {cap}: per-member accounting must survive coalescing: {jc:?}"
        );
        if cap > 1 {
            assert!(jc.batched_replays >= 1, "cap {cap}: warm pass must coalesce: {jc:?}");
        } else {
            assert_eq!(jc.batched_replays, 0, "cap 1 degenerates to plain tile jobs");
        }
    }
}

#[test]
fn mixed_key_batches_coalesce_only_same_key_runs() {
    // Two interleaved shapes under replay batching: each kernel's tiles
    // coalesce into their own batched job; the two keys never share one.
    let mut reqs = Vec::new();
    for i in 0..4u64 {
        reqs.push(Request::RandomDgemm { n: 16, seed: 5_000 + i });
        reqs.push(Request::RandomDgemm { n: 24, seed: 5_100 + i });
    }
    let mut seq = coord(AeLevel::Ae5, 2);
    let r_seq: Vec<_> = reqs.clone().into_iter().map(|r| seq.serve_one(r)).collect();
    let mut bat = coord_replay_batch(64);
    // Warm both kernels through the solo path so the coalesced groups take
    // the fused warm fast path deterministically.
    for n in [16usize, 24] {
        let (a, b, c) = (Mat::random(n, n, 1), Mat::random(n, n, 2), Mat::zeros(n, n));
        let _ = bat.dgemm(&a, &b, &c);
    }
    let before = bat.pool_job_counts();
    let r_bat = bat.serve_batch(reqs);
    assert_same_responses(&r_seq, &r_bat);
    let after = bat.pool_job_counts();
    assert_eq!(after.gemm_tiles - before.gemm_tiles, 32, "8 requests x 4 tiles");
    assert_eq!(
        after.batched_replays - before.batched_replays,
        2,
        "exactly one coalesced job per kernel, never across keys: {after:?}"
    );
    assert_eq!(after.replays - before.replays, 32, "every coalesced tile value-replays");
    assert_eq!(after.replays + after.combined_runs, after.gemm_tiles + after.gemv + after.level1);
}

#[test]
fn oversized_admit_reports_truthful_peak_bytes() {
    // Regression pin for the admission accounting: the "always admit one"
    // escape hatch must price the oversized request at its true packed
    // size — peak_staged_bytes reports what was actually pinned, not the
    // budget it overflowed. Checked with and without the tile coalescer,
    // which must not perturb byte accounting.
    let cfg = CoordinatorConfig { ae: AeLevel::Ae5, b: 2, ..CoordinatorConfig::default() };
    let big = Request::RandomDgemm { n: 40, seed: 77 };
    let big_bytes = cfg.staged_bytes(&big);
    assert!(big_bytes > 64, "test premise: the planted request is oversized");
    let batch = vec![
        Request::RandomDgemm { n: 8, seed: 1 },
        big,
        Request::RandomDgemm { n: 8, seed: 2 },
    ];
    for replay_batch in [None, Some(8)] {
        let mut co = Coordinator::new(CoordinatorConfig {
            ae: AeLevel::Ae5,
            b: 2,
            artifact_dir: "/nonexistent".into(),
            verify: false,
            admission_bytes: Some(64),
            replay_batch,
            ..CoordinatorConfig::default()
        });
        let resps = co.serve_batch(batch.clone());
        assert_eq!(resps.len(), 3);
        let bs = co.last_batch_stats().unwrap();
        assert_eq!(
            bs.peak_staged_bytes, big_bytes,
            "replay_batch {replay_batch:?}: oversized admit-one must report its true size"
        );
        assert_eq!(bs.peak_staged, 1, "a 64 B budget serializes staging");
    }
}

#[test]
fn batch_values_match_host_blas() {
    // End-to-end value audit of the batched path against the oracle.
    let mut co = coord(AeLevel::Ae4, 2);
    let reqs: Vec<Request> =
        (0..4).map(|i| Request::RandomDgemm { n: 18, seed: 9_000 + i }).collect();
    let resps = co.serve_batch(reqs.clone());
    for (req, resp) in reqs.into_iter().zip(resps) {
        let Request::Dgemm { a, b, c } = req.materialize() else { unreachable!() };
        let want = redefine_blas::blas::level3::dgemm_ref(&a, &b, &c);
        let got = resp.matrix.expect("matrix payload");
        let err = redefine_blas::util::rel_fro_error(got.as_slice(), want.as_slice());
        assert!(err < 1e-12, "batched DGEMM off: {err}");
        assert_eq!(resp.source, ValueSource::PeSim);
    }
}
