//! Serving-engine tests: program-cache determinism (pointer-equal shared
//! kernels), `serve_batch` vs `serve_one` equivalence, and the pooled
//! path's makespan behavior.

use redefine_blas::coordinator::{
    request::{random_workload, repeated_gemm_workload, Request},
    Coordinator, CoordinatorConfig, ProgramCache, ValueSource,
};
use redefine_blas::pe::AeLevel;
use redefine_blas::util::Mat;
use std::sync::Arc;

fn coord(ae: AeLevel, b: usize) -> Coordinator {
    Coordinator::new(CoordinatorConfig {
        ae,
        b,
        artifact_dir: "/nonexistent".into(),
        verify: false,
    })
}

#[test]
fn cache_same_key_returns_the_identical_arc() {
    let cache = ProgramCache::new();
    let p1 = cache.gemm_rect(12, 12, 24, AeLevel::Ae5);
    let p2 = cache.gemm_rect(12, 12, 24, AeLevel::Ae5);
    assert!(Arc::ptr_eq(&p1, &p2), "same (routine, shape, ae) must share one Program");
    let p3 = cache.gemm_rect(12, 12, 24, AeLevel::Ae3);
    assert!(!Arc::ptr_eq(&p1, &p3), "AE level is part of the key");
    let s = cache.stats();
    assert_eq!(s.hits, 1);
    assert_eq!(s.misses, 2);
    assert_eq!(s.entries, 2);
}

#[test]
fn coordinator_reuses_one_program_across_a_request_stream() {
    let mut co = coord(AeLevel::Ae5, 2);
    let resps = co.serve_batch(repeated_gemm_workload(6, 20, 77));
    assert_eq!(resps.len(), 6);
    let s = co.cache_stats();
    assert_eq!(s.misses, 1, "one shape → one emission: {s:?}");
    assert_eq!(s.hits, 5, "five cache hits: {s:?}");
    // All six responses simulate identical tile timing (same shape).
    let cycles: Vec<u64> = resps.iter().map(|r| r.cycles).collect();
    assert!(cycles.windows(2).all(|w| w[0] == w[1]), "same shape, same makespan: {cycles:?}");
}

#[test]
fn serve_batch_matches_serve_one_exactly() {
    let reqs = random_workload(10, 28, 2026);
    let mut seq = coord(AeLevel::Ae5, 2);
    let mut bat = coord(AeLevel::Ae5, 2);
    let r_seq: Vec<_> = reqs.clone().into_iter().map(|r| seq.serve_one(r)).collect();
    let r_bat = bat.serve_batch(reqs);
    assert_eq!(r_seq.len(), r_bat.len());
    for (i, (a, b)) in r_seq.iter().zip(&r_bat).enumerate() {
        assert_eq!(a.op, b.op, "request {i}");
        assert_eq!(a.n, b.n, "request {i}");
        assert_eq!(a.source, b.source, "request {i}");
        assert_eq!(a.cycles, b.cycles, "request {i}: simulated cycles must be identical");
        assert_eq!(a.energy_j, b.energy_j, "request {i}");
        assert_eq!(a.matrix, b.matrix, "request {i}: matrix payload");
        assert_eq!(a.vector, b.vector, "request {i}: vector payload");
        assert_eq!(a.scalar, b.scalar, "request {i}: scalar payload");
    }
}

#[test]
fn serve_batch_is_deterministic_across_runs() {
    // Run the same batch twice on fresh coordinators: every simulated
    // quantity must repeat bit-for-bit (host thread scheduling must not
    // leak into results).
    let reqs = random_workload(8, 24, 555);
    let r1 = coord(AeLevel::Ae5, 2).serve_batch(reqs.clone());
    let r2 = coord(AeLevel::Ae5, 2).serve_batch(reqs);
    for (a, b) in r1.iter().zip(&r2) {
        assert_eq!(a.cycles, b.cycles);
        assert_eq!(a.energy_j, b.energy_j);
        assert_eq!(a.matrix, b.matrix);
        assert_eq!(a.vector, b.vector);
        assert_eq!(a.scalar, b.scalar);
    }
}

#[test]
fn pooled_bigger_array_is_faster() {
    // Makespan monotonicity through the pooled path (the seed's
    // bigger_array_is_faster invariant must survive the serving engine).
    let n = 48;
    let a = Mat::random(n, n, 81);
    let b = Mat::random(n, n, 82);
    let c = Mat::zeros(n, n);
    let m1 = coord(AeLevel::Ae5, 1).dgemm(&a, &b, &c).makespan;
    let m2 = coord(AeLevel::Ae5, 2).dgemm(&a, &b, &c).makespan;
    let m3 = coord(AeLevel::Ae5, 3).dgemm(&a, &b, &c).makespan;
    assert!(m2 < m1, "2x2 ({m2}) not faster than 1x1 ({m1})");
    assert!(m3 < m2, "3x3 ({m3}) not faster than 2x2 ({m2})");
}

#[test]
fn pool_sized_by_tile_array() {
    assert_eq!(coord(AeLevel::Ae5, 1).pool_size(), 1);
    assert_eq!(coord(AeLevel::Ae5, 3).pool_size(), 9);
}

#[test]
fn batch_values_match_host_blas() {
    // End-to-end value audit of the batched path against the oracle.
    let mut co = coord(AeLevel::Ae4, 2);
    let reqs: Vec<Request> =
        (0..4).map(|i| Request::RandomDgemm { n: 18, seed: 9_000 + i }).collect();
    let resps = co.serve_batch(reqs.clone());
    for (req, resp) in reqs.into_iter().zip(resps) {
        let Request::Dgemm { a, b, c } = req.materialize() else { unreachable!() };
        let want = redefine_blas::blas::level3::dgemm_ref(&a, &b, &c);
        let got = resp.matrix.expect("matrix payload");
        let err = redefine_blas::util::rel_fro_error(got.as_slice(), want.as_slice());
        assert!(err < 1e-12, "batched DGEMM off: {err}");
        assert_eq!(resp.source, ValueSource::PeSim);
    }
}
