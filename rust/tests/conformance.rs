//! Conformance suite: randomized sweep of the codegen-on-PE kernels across
//! every enhancement level (AE0–AE5) and ~20 shapes — including
//! non-multiple-of-4 shapes, which go through the coordinator's
//! zero-padding convention — checked against the host reference BLAS
//! within 1e-12 relative error.
//!
//! These tests pin the co-design contract: one routine, six compilations,
//! identical numerics at every level and every (padded) shape.

use redefine_blas::blas;
use redefine_blas::codegen::{self, layout::VecLayout, GemmLayout};
use redefine_blas::pe::{AeLevel, Pe, PeConfig};
use redefine_blas::util::{rel_fro_error, round_up, Mat, XorShift64};

/// ~20 shapes, aligned and unaligned, small enough for debug-build runs.
const SHAPES: [usize; 20] =
    [4, 5, 6, 7, 8, 9, 10, 12, 13, 15, 16, 18, 20, 21, 24, 25, 27, 28, 30, 32];

/// One non-4-aligned shape exercised for every routine at every AE level.
const UNALIGNED: usize = 10;

fn is_aligned(n: usize) -> bool {
    n % 4 == 0
}

/// Run DGEMM through the padding convention: emit at np = round_up(n, 4),
/// zero-pad operands, extract the leading n×n block.
fn check_gemm(n: usize, ae: AeLevel, seed: u64) {
    let np = round_up(n, 4);
    let a = Mat::random(n, n, seed);
    let b = Mat::random(n, n, seed + 1);
    let c = Mat::random(n, n, seed + 2);
    let layout = GemmLayout::rect(np, np, np);
    let prog = codegen::gen_gemm_rect(np, np, np, ae, &layout);
    let mut pe = Pe::new(PeConfig::paper(ae), layout.gm_words());
    pe.write_gm(0, &layout.pack(&a, &b, &c));
    let st = pe.run(&prog);
    assert!(st.cycles > 0);
    let got = layout.unpack_c(&pe.gm, n, n);
    let want = blas::level3::dgemm_ref(&a, &b, &c);
    let err = rel_fro_error(got.as_slice(), want.as_slice());
    assert!(err < 1e-12, "DGEMM n={n} (np={np}) {ae}: rel err {err}");
}

fn check_gemv(n: usize, ae: AeLevel, seed: u64) {
    let np = round_up(n, 4);
    let a = Mat::random(n, n, seed);
    let mut rng = XorShift64::new(seed + 10);
    let x = rng.vec(n);
    let y = rng.vec(n);
    let l = VecLayout::gemv(np);
    let prog = codegen::gen_gemv(np, ae, &l);
    let mut pe = Pe::new(PeConfig::paper(ae), l.gm_words());
    let mut gm = vec![0.0; l.gm_words()];
    for i in 0..n {
        for k in 0..n {
            gm[l.a(i, k)] = a[(i, k)];
        }
    }
    gm[l.base_x..l.base_x + n].copy_from_slice(&x);
    gm[l.base_y..l.base_y + n].copy_from_slice(&y);
    pe.write_gm(0, &gm);
    pe.run(&prog);
    let got = pe.read_gm(l.base_y, n).to_vec();
    let want = blas::level2::dgemv_ref(&a, &x, &y);
    for i in 0..n {
        let scale = want[i].abs().max(1.0);
        assert!(
            (got[i] - want[i]).abs() <= 1e-12 * scale,
            "DGEMV n={n} (np={np}) {ae} row {i}: {} vs {}",
            got[i],
            want[i]
        );
    }
    // Zero-padded tail rows must stay zero (A and y padding are zeros).
    let tail = pe.read_gm(l.base_y + n, np - n).to_vec();
    assert!(tail.iter().all(|&v| v == 0.0), "DGEMV padding leaked: {tail:?}");
}

fn check_ddot(n: usize, ae: AeLevel, seed: u64) {
    let np = round_up(n, 4);
    let mut rng = XorShift64::new(seed);
    let x = rng.vec(n);
    let y = rng.vec(n);
    let l = VecLayout::level1(np);
    let prog = codegen::gen_ddot(np, ae, &l);
    let mut pe = Pe::new(PeConfig::paper(ae), l.gm_words());
    pe.write_gm(l.base_x, &x);
    pe.write_gm(l.base_y, &y);
    pe.run(&prog);
    let got = pe.read_gm(l.scratch(), 1)[0];
    let want = blas::level1::ddot(&x, &y);
    assert!(
        (got - want).abs() <= 1e-12 * want.abs().max(1.0),
        "DDOT n={n} (np={np}) {ae}: {got} vs {want}"
    );
}

fn check_daxpy(n: usize, ae: AeLevel, seed: u64) {
    let np = round_up(n, 4);
    let alpha = 1.75;
    let mut rng = XorShift64::new(seed);
    let x = rng.vec(n);
    let y = rng.vec(n);
    let l = VecLayout::level1(np);
    let prog = codegen::gen_daxpy(np, alpha, ae, &l);
    let mut pe = Pe::new(PeConfig::paper(ae), l.gm_words());
    pe.write_gm(l.base_x, &x);
    pe.write_gm(l.base_y, &y);
    pe.run(&prog);
    let got = pe.read_gm(l.base_y, np).to_vec();
    for k in 0..n {
        let want = alpha * x[k] + y[k];
        assert!(
            (got[k] - want).abs() <= 1e-12 * want.abs().max(1.0),
            "DAXPY n={n} (np={np}) {ae} k={k}: {} vs {want}",
            got[k]
        );
    }
    assert!(got[n..].iter().all(|&v| v == 0.0), "DAXPY padding leaked");
}

fn check_dnrm2(n: usize, ae: AeLevel, seed: u64) {
    let np = round_up(n, 4);
    let mut rng = XorShift64::new(seed);
    let x = rng.vec(n);
    let l = VecLayout::level1(np);
    let prog = codegen::gen_dnrm2(np, ae, &l);
    let mut pe = Pe::new(PeConfig::paper(ae), l.gm_words());
    pe.write_gm(l.base_x, &x);
    pe.run(&prog);
    let got = pe.read_gm(l.scratch(), 1)[0];
    let want = x.iter().map(|v| v * v).sum::<f64>().sqrt();
    assert!(
        (got - want).abs() <= 1e-12 * want.abs().max(1.0),
        "DNRM2 n={n} (np={np}) {ae}: {got} vs {want}"
    );
}

#[test]
fn gemm_shape_sweep_across_levels() {
    let mut saw_unaligned = false;
    for (i, &n) in SHAPES.iter().enumerate() {
        let ae = AeLevel::ALL[i % 6];
        saw_unaligned |= !is_aligned(n);
        check_gemm(n, ae, 1000 + i as u64);
    }
    assert!(saw_unaligned, "sweep must include padded shapes");
}

#[test]
fn gemm_every_level_aligned_and_padded() {
    for (j, &ae) in AeLevel::ALL.iter().enumerate() {
        check_gemm(8, ae, 2000 + j as u64);
        check_gemm(UNALIGNED, ae, 2100 + j as u64);
    }
}

#[test]
fn gemv_every_level_aligned_and_padded() {
    for (j, &ae) in AeLevel::ALL.iter().enumerate() {
        check_gemv(12, ae, 3000 + j as u64);
        check_gemv(UNALIGNED, ae, 3100 + j as u64);
    }
}

#[test]
fn gemv_shape_sweep() {
    for (i, &n) in SHAPES.iter().enumerate() {
        let ae = AeLevel::ALL[(i + 3) % 6];
        check_gemv(n, ae, 3200 + i as u64);
    }
}

#[test]
fn ddot_every_level_aligned_and_padded() {
    for (j, &ae) in AeLevel::ALL.iter().enumerate() {
        check_ddot(64, ae, 4000 + j as u64);
        check_ddot(UNALIGNED, ae, 4100 + j as u64);
        check_ddot(45, ae, 4200 + j as u64); // crosses a 32-word LM group
    }
}

#[test]
fn daxpy_every_level_aligned_and_padded() {
    for (j, &ae) in AeLevel::ALL.iter().enumerate() {
        check_daxpy(64, ae, 5000 + j as u64);
        check_daxpy(UNALIGNED, ae, 5100 + j as u64);
        check_daxpy(33, ae, 5200 + j as u64);
    }
}

#[test]
fn dnrm2_every_level_aligned_and_padded() {
    for (j, &ae) in AeLevel::ALL.iter().enumerate() {
        check_dnrm2(64, ae, 6000 + j as u64);
        check_dnrm2(UNALIGNED, ae, 6100 + j as u64);
    }
}

#[test]
fn level1_shape_sweep() {
    for (i, &n) in SHAPES.iter().enumerate() {
        let ae = AeLevel::ALL[(i + 1) % 6];
        check_ddot(n, ae, 7000 + i as u64);
        check_daxpy(n, ae, 7100 + i as u64);
        check_dnrm2(n, ae, 7200 + i as u64);
    }
}

#[test]
fn noc_parallel_dgemm_matches_host_and_serving() {
    // Value-level tie between the standalone NoC simulator, the host
    // reference BLAS, and the serving path: all three must agree at 1e-12
    // on the same operands (n % b == 0, as parallel_dgemm requires).
    use redefine_blas::coordinator::{Coordinator, CoordinatorConfig};
    use redefine_blas::noc::parallel_dgemm;
    for (n, b) in [(24usize, 2usize), (24, 3)] {
        let a = Mat::random(n, n, 910 + b as u64);
        let bm = Mat::random(n, n, 920 + b as u64);
        let c = Mat::random(n, n, 930 + b as u64);
        let want = blas::level3::dgemm_ref(&a, &bm, &c);

        let noc = parallel_dgemm(n, b, AeLevel::Ae5, &a, &bm, &c);
        let err = rel_fro_error(noc.result.as_slice(), want.as_slice());
        assert!(err < 1e-12, "NoC sim DGEMM n={n} b={b}: rel err {err}");

        let mut co = Coordinator::new(CoordinatorConfig {
            ae: AeLevel::Ae5,
            b,
            artifact_dir: "/nonexistent".into(),
            verify: false,
            ..CoordinatorConfig::default()
        });
        let served = co.dgemm(&a, &bm, &c);
        let err = rel_fro_error(served.c.as_slice(), noc.result.as_slice());
        assert!(err < 1e-12, "serving vs NoC sim DGEMM n={n} b={b}: rel err {err}");
    }
}

#[test]
fn coordinator_serves_unaligned_shapes() {
    // The full request path (pad → cache → pool → merge) at an
    // awkward size on every tiled level.
    use redefine_blas::coordinator::{Coordinator, CoordinatorConfig};
    let n = 13;
    let a = Mat::random(n, n, 901);
    let b = Mat::random(n, n, 902);
    let c = Mat::random(n, n, 903);
    let want = blas::level3::dgemm_ref(&a, &b, &c);
    for ae in AeLevel::ALL {
        let mut co = Coordinator::new(CoordinatorConfig {
            ae,
            b: 2,
            artifact_dir: "/nonexistent".into(),
            verify: false,
            ..CoordinatorConfig::default()
        });
        let r = co.dgemm(&a, &b, &c);
        let err = rel_fro_error(r.c.as_slice(), want.as_slice());
        assert!(err < 1e-12, "coordinator DGEMM n={n} {ae}: rel err {err}");
    }
}
