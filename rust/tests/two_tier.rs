//! Two-tier execution equivalence: randomized programs over every AE
//! level, pinning (a) tier-2 value replay bit-identical to the combined
//! interpreter — GM, LM and register file — (b) the memoized
//! [`ScheduledProgram`] stats equal to a fresh `Pe::run`, including after
//! `Pe::reset` reuse on a pooled-worker-style PE, and (c) the tier-2b
//! batched replay (`replay_batch`) bit-identical to N independent
//! `Pe::replay` calls over the same operand contexts.

use redefine_blas::pe::{
    replay_batch, AeLevel, DecodedProgram, ExecMode, Instr, Pe, PeConfig, Program, ReplayCtx,
    ScheduledProgram, LM_WORDS, NUM_REGS,
};
use redefine_blas::util::XorShift64;

/// GM footprint of every random program (small, so block transfers and
/// scalar accesses overlap and exercise the memory-ordering paths).
const GM_WORDS: usize = 256;

/// A random *valid* program for `ae`: scalar GM loads/stores, the full
/// arithmetic set (add/sub/mul/div/sqrt/mac), and — gated on the level's
/// features — LM scalar traffic, block transfers, DOT2/3/4 and wide
/// 256-bit moves, interleaved with barriers. Register/address ranges stay
/// inside the validator's bounds by construction; values may still go
/// nonfinite (div by ~0, sqrt of negatives), which the bit-exact
/// comparison must survive.
fn random_program(ae: AeLevel, seed: u64, len: usize) -> Program {
    let mut rng = XorShift64::new(seed);
    let mut p = Program::new();
    // Seed the register file with live values before the random body.
    for r in 0..8u8 {
        p.push(Instr::Li { rd: r, val: rng.range_f64(-4.0, 4.0) });
    }
    for _ in 0..len {
        match rng.below(14) {
            0 => p.push(Instr::Ld {
                rd: rng.below(NUM_REGS) as u8,
                gm: rng.below(GM_WORDS) as u32,
            }),
            1 => p.push(Instr::St {
                rs: rng.below(NUM_REGS) as u8,
                gm: rng.below(GM_WORDS) as u32,
            }),
            2 => p.push(Instr::Fadd {
                rd: rng.below(NUM_REGS) as u8,
                ra: rng.below(NUM_REGS) as u8,
                rb: rng.below(NUM_REGS) as u8,
            }),
            3 => p.push(Instr::Fsub {
                rd: rng.below(NUM_REGS) as u8,
                ra: rng.below(NUM_REGS) as u8,
                rb: rng.below(NUM_REGS) as u8,
            }),
            4 => p.push(Instr::Fmul {
                rd: rng.below(NUM_REGS) as u8,
                ra: rng.below(NUM_REGS) as u8,
                rb: rng.below(NUM_REGS) as u8,
            }),
            5 => p.push(Instr::Fmac {
                rd: rng.below(NUM_REGS) as u8,
                ra: rng.below(NUM_REGS) as u8,
                rb: rng.below(NUM_REGS) as u8,
            }),
            6 => p.push(Instr::Fdiv {
                rd: rng.below(NUM_REGS) as u8,
                ra: rng.below(NUM_REGS) as u8,
                rb: rng.below(NUM_REGS) as u8,
            }),
            7 => p.push(Instr::Fsqrt {
                rd: rng.below(NUM_REGS) as u8,
                ra: rng.below(NUM_REGS) as u8,
            }),
            8 => p.push(Instr::Li {
                rd: rng.below(NUM_REGS) as u8,
                val: rng.range_f64(-10.0, 10.0),
            }),
            9 if ae.has_lm() => p.push(Instr::LmLd {
                rd: rng.below(NUM_REGS) as u8,
                lm: rng.below(256) as u32,
            }),
            10 if ae.has_lm() => p.push(Instr::LmSt {
                rs: rng.below(NUM_REGS) as u8,
                lm: rng.below(256) as u32,
            }),
            11 if ae.has_lm() => {
                let lm = rng.below(240) as u32;
                let gm = rng.below(GM_WORDS - 16) as u32;
                let blk_len = 1 + rng.below(16) as u32;
                if rng.below(2) == 0 {
                    p.push(Instr::BlkLd { lm, gm, len: blk_len });
                } else {
                    p.push(Instr::BlkSt { lm, gm, len: blk_len });
                }
            }
            12 if ae.has_dot() => p.push(Instr::Dot {
                rd: rng.below(NUM_REGS) as u8,
                ra: rng.below(61) as u8,
                rb: rng.below(61) as u8,
                n: (2 + rng.below(3)) as u8,
                acc: rng.below(2) == 1,
            }),
            13 if ae.has_wide_path() => {
                let lm = rng.below(252) as u32;
                if rng.below(2) == 0 {
                    p.push(Instr::LmLd4 { rd: rng.below(61) as u8, lm });
                } else {
                    p.push(Instr::LmSt4 { rs: rng.below(61) as u8, lm });
                }
            }
            // Feature not available at this level: issue-slot fillers so
            // every draw still emits an instruction.
            n => p.push(if n % 2 == 0 { Instr::Nop } else { Instr::Barrier }),
        }
    }
    p.push(Instr::Halt);
    p
}

/// Bit-exact architectural-state comparison (GM, LM, register file) —
/// `to_bits` so NaNs produced by random div/sqrt still compare equal when
/// the data paths truly agree.
fn assert_state_bits(tag: &str, reference: &Pe, got: &Pe) {
    assert_eq!(reference.gm.len(), got.gm.len(), "{tag}: GM size");
    for (i, (x, y)) in reference.gm.iter().zip(got.gm.iter()).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{tag}: GM[{i}] {x} vs {y}");
    }
    let (rl, gl) = (reference.read_lm(0, LM_WORDS), got.read_lm(0, LM_WORDS));
    for (i, (x, y)) in rl.iter().zip(gl.iter()).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{tag}: LM[{i}] {x} vs {y}");
    }
    for (i, (x, y)) in reference.regs().iter().zip(got.regs().iter()).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{tag}: R{i} {x} vs {y}");
    }
}

#[test]
fn replay_matches_combined_for_random_programs_at_every_ae() {
    for (ai, ae) in AeLevel::ALL.into_iter().enumerate() {
        // One long-lived "pooled worker" PE, reset-reused across kernels.
        let mut pooled = Pe::new(PeConfig::paper(ae), GM_WORDS);
        for round in 0..6u64 {
            let seed = 1_000 * (ai as u64 + 1) + round;
            let tag = format!("{ae} seed {seed}");
            let prog = random_program(ae, seed, 300);
            let data = XorShift64::new(seed ^ 0xDA7A).vec(GM_WORDS);

            // Reference: fresh PE, one-shot combined path.
            let mut fresh = Pe::new(PeConfig::paper(ae), GM_WORDS);
            fresh.write_gm(0, &data);
            let st_fresh = fresh.run(&prog);

            let sched =
                ScheduledProgram::compile(&prog, ae).expect("generator only emits valid programs");
            assert!(!sched.is_scheduled());

            // First pooled execution: the one-time timing pass.
            pooled.reset(GM_WORDS);
            pooled.write_gm(0, &data);
            let st_sched = sched.execute(&mut pooled, ExecMode::Replay);
            assert!(sched.is_scheduled());
            assert_eq!(st_fresh, st_sched, "{tag}: timing pass vs fresh Pe::run");
            assert_state_bits(&format!("{tag} (timing pass)"), &fresh, &pooled);

            // Second pooled execution: lean value replay + memoized stats.
            pooled.reset(GM_WORDS);
            pooled.write_gm(0, &data);
            let st_replay = sched.execute(&mut pooled, ExecMode::Replay);
            assert_eq!(st_fresh, st_replay, "{tag}: memoized stats vs fresh Pe::run");
            assert_state_bits(&format!("{tag} (replay)"), &fresh, &pooled);

            // Forced combined re-run: the schedule must reproduce exactly.
            pooled.reset(GM_WORDS);
            pooled.write_gm(0, &data);
            let st_comb = sched.execute(&mut pooled, ExecMode::Combined);
            assert_eq!(st_fresh, st_comb, "{tag}: forced combined re-run");
            assert_state_bits(&format!("{tag} (combined re-run)"), &fresh, &pooled);
        }
    }
}

#[test]
fn decode_is_deterministic_and_compact() {
    for ae in AeLevel::ALL {
        let prog = random_program(ae, 42, 200);
        let d1 = DecodedProgram::decode(&prog, ae).expect("valid by construction");
        let d2 = DecodedProgram::decode(&prog, ae).expect("valid by construction");
        assert_eq!(d1, d2, "decode must be a pure function of (program, ae)");
        assert_eq!(d1.ae(), ae);
        assert_eq!(d1.len(), prog.len() - 1, "everything but Halt decodes");
        let enum_bytes = prog.len() * std::mem::size_of::<Instr>();
        assert!(
            d1.packed_bytes() < enum_bytes * 3 / 4,
            "{ae}: packed {} bytes not compact vs {} enum bytes",
            d1.packed_bytes(),
            enum_bytes
        );
    }
}

/// Bit-exact comparison of a batched-replay operand context against the
/// reference PE that ran the same kernel over the same operands.
fn assert_ctx_bits(tag: &str, reference: &Pe, got: &ReplayCtx) {
    assert_eq!(reference.gm.len(), got.gm.len(), "{tag}: GM size");
    for (i, (x, y)) in reference.gm.iter().zip(got.gm.iter()).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{tag}: GM[{i}] {x} vs {y}");
    }
    let (rl, gl) = (reference.read_lm(0, LM_WORDS), got.read_lm(0, LM_WORDS));
    for (i, (x, y)) in rl.iter().zip(gl.iter()).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{tag}: LM[{i}] {x} vs {y}");
    }
    for (i, (x, y)) in reference.regs().iter().zip(got.regs().iter()).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{tag}: R{i} {x} vs {y}");
    }
}

#[test]
fn batched_replay_matches_sequential_replays_at_every_ae() {
    for (ai, ae) in AeLevel::ALL.into_iter().enumerate() {
        // Long-lived contexts and a long-lived reference PE, reset-reused
        // across kernels the way pooled workers reuse their PEs.
        let mut ctxs: Vec<ReplayCtx> = (0..5).map(|_| ReplayCtx::new(GM_WORDS)).collect();
        let mut solo = Pe::new(PeConfig::paper(ae), GM_WORDS);
        for round in 0..4u64 {
            let seed = 20_000 * (ai as u64 + 1) + round;
            let tag = format!("{ae} seed {seed}");
            let prog = random_program(ae, seed, 300);
            let d = DecodedProgram::decode(&prog, ae).expect("valid by construction");
            // Distinct operand images per member.
            for (m, ctx) in ctxs.iter_mut().enumerate() {
                ctx.reset(GM_WORDS);
                let data = XorShift64::new(seed ^ (0xC0FFEE + m as u64)).vec(GM_WORDS);
                ctx.gm.copy_from_slice(&data);
            }
            // One fused pass over all members...
            replay_batch(&mut ctxs, &d);
            // ...must be bit-identical to N independent Pe::replay calls
            // over the same operands.
            for (m, ctx) in ctxs.iter().enumerate() {
                let data = XorShift64::new(seed ^ (0xC0FFEE + m as u64)).vec(GM_WORDS);
                solo.reset(GM_WORDS);
                solo.write_gm(0, &data);
                solo.replay(&d);
                assert_ctx_bits(&format!("{tag} member {m}"), &solo, ctx);
            }
        }
    }
}

#[test]
fn replay_survives_heavy_reset_reuse_across_shapes() {
    // Pooled-worker torture: one PE serves alternating kernels of
    // different AE-compatible shapes, resetting between every run; each
    // replay must still match its own fresh reference bit-for-bit.
    let ae = AeLevel::Ae5;
    let progs: Vec<Program> = (0..4).map(|i| random_program(ae, 7_000 + i, 250)).collect();
    let scheds: Vec<ScheduledProgram> =
        progs.iter().map(|p| ScheduledProgram::compile(p, ae).unwrap()).collect();
    let mut pooled = Pe::new(PeConfig::paper(ae), GM_WORDS);
    for pass in 0..3 {
        for (i, (prog, sched)) in progs.iter().zip(&scheds).enumerate() {
            let data = XorShift64::new(0xBEEF + i as u64).vec(GM_WORDS);
            let mut fresh = Pe::new(PeConfig::paper(ae), GM_WORDS);
            fresh.write_gm(0, &data);
            let st_fresh = fresh.run(prog);
            pooled.reset(GM_WORDS);
            pooled.write_gm(0, &data);
            let st = sched.execute(&mut pooled, ExecMode::Replay);
            assert_eq!(st_fresh, st, "pass {pass} prog {i}");
            assert_state_bits(&format!("pass {pass} prog {i}"), &fresh, &pooled);
        }
    }
}
